//! The TCP fabric against the in-process fabric: identical collectives,
//! identical bits, identical traffic accounting.
//!
//! Every test runs one thread per rank (each thread owning a real
//! `TcpTransport` over loopback sockets — the same topology `redsync
//! launch` builds with processes) and, where it matters, replays the
//! exact same collective over `LocalFabric` to hold the two fabrics
//! bit-identical.  A watchdog turns would-be deadlocks into failures
//! instead of hung test runs.

use redsync::collectives::transport::TrafficStats;
use redsync::collectives::{allgather, allreduce_mean, concat, LocalFabric, Transport};
use redsync::net::{free_loopback_addr, TcpOptions, TcpTransport};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Bootstrap a full TCP mesh on loopback; returned in rank order.
fn tcp_fabric(world: usize) -> Vec<TcpTransport> {
    let addr = free_loopback_addr();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let addr = addr.clone();
            thread::spawn(move || {
                TcpTransport::connect(&TcpOptions::new(world, rank, addr))
                    .expect("tcp bootstrap")
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Run `f` once per rank on its own thread.  Panics (instead of hanging)
/// if any rank is still blocked after 60s — the deadlock watchdog.
fn run_ranks<T, F, R>(transports: Vec<T>, f: F) -> Vec<R>
where
    T: Transport + Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
    R: Send + 'static,
{
    let f = Arc::new(f);
    let (done_tx, done_rx) = channel();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            thread::spawn(move || {
                let r = f(t);
                let _ = done.send(());
                r
            })
        })
        .collect();
    drop(done_tx);
    for _ in 0..handles.len() {
        done_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("a rank did not finish within 60s (deadlock or crash)");
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// One round of sparse + dense synchronization, the §5.3/§2.2 pair the
/// coordinator drives every step.  Returns the raw words and result bits
/// so comparisons are bit-exact, never float-approximate.
fn sync_round<T: Transport>(t: &T) -> (Vec<u32>, Vec<u32>) {
    // variable-length allgather: rank r contributes r + 3 words
    let msg: Vec<u32> = (0..t.rank() + 3).map(|i| (t.rank() * 1000 + i) as u32).collect();
    let gathered = concat(allgather(t, msg));
    // dense allreduce over f32s with rank-dependent values
    let mut x: Vec<f32> =
        (0..257).map(|i| (t.rank() + 1) as f32 * (i as f32 + 0.5) * 0.1).collect();
    allreduce_mean(t, &mut x);
    (gathered, x.iter().map(|v| v.to_bits()).collect())
}

#[test]
fn tcp_collectives_bitmatch_local_fabric() {
    let world = 4;

    let mut local = LocalFabric::new(world);
    let local_stats = Arc::clone(&local.stats);
    let local_results = run_ranks(local.take_all(), |t| sync_round(&t));

    let tcp = tcp_fabric(world);
    let tcp_stats: Vec<Arc<TrafficStats>> = tcp.iter().map(|t| Arc::clone(&t.stats)).collect();
    let tcp_results = run_ranks(tcp, |t| sync_round(&t));

    for (rank, (l, t)) in local_results.iter().zip(&tcp_results).enumerate() {
        assert_eq!(l.0, t.0, "rank {rank}: allgather words differ across fabrics");
        assert_eq!(l.1, t.1, "rank {rank}: allreduce result bits differ across fabrics");
    }

    // identical collectives move identical payloads: the per-process TCP
    // counters must sum to exactly the shared LocalFabric counter
    let tcp_bytes: u64 = tcp_stats.iter().map(|s| s.bytes()).sum();
    let tcp_msgs: u64 = tcp_stats.iter().map(|s| s.message_count()).sum();
    assert_eq!(tcp_bytes, local_stats.bytes(), "fabric byte accounting differs");
    assert_eq!(tcp_msgs, local_stats.message_count(), "fabric message accounting differs");
}

#[test]
fn tcp_allgather_traffic_matches_eq1_bandwidth_term() {
    // Same exact accounting as the LocalFabric test in collectives/mod.rs:
    // payload (p-1)·m per rank — Eq. 1's bandwidth term — plus the
    // deterministic recursive-doubling block headers.
    let world = 4;
    let msg_words = 50usize;
    let tcp = tcp_fabric(world);
    let stats: Vec<Arc<TrafficStats>> = tcp.iter().map(|t| Arc::clone(&t.stats)).collect();
    run_ranks(tcp, move |t| {
        allgather(&t, vec![0u32; msg_words]);
    });
    let total: u64 = stats.iter().map(|s| s.bytes() / 4).sum();
    let payload = (world * (world - 1) * msg_words) as u64;
    let lg = world.trailing_zeros() as u64;
    let headers = world as u64 * (lg + 2 * (world as u64 - 1));
    assert_eq!(total, payload + headers);
}

#[test]
fn multi_megabyte_exchange_over_tcp() {
    // 1.5M words = 6 MB each way: far beyond one socket buffer, so this
    // exercises framing across partial reads/writes and the writer
    // thread's role in keeping symmetric exchange deadlock-free.
    let n = 1_500_000usize;
    let tcp = tcp_fabric(2);
    let results = run_ranks(tcp, move |t| {
        let peer = 1 - t.rank();
        let msg: Vec<u32> =
            (0..n as u32).map(|i| i.wrapping_mul(0x9E37_79B9) ^ t.rank() as u32).collect();
        t.exchange(peer, msg)
    });
    for (rank, got) in results.iter().enumerate() {
        let peer = (1 - rank) as u32;
        assert_eq!(got.len(), n);
        for (i, &w) in got.iter().enumerate() {
            assert_eq!(w, (i as u32).wrapping_mul(0x9E37_79B9) ^ peer, "word {i} corrupted");
        }
    }
}

#[test]
fn exchange_with_self_peer_over_tcp() {
    let tcp = tcp_fabric(3);
    run_ranks(tcp, |t| {
        let rank = t.rank() as u32;
        assert_eq!(t.exchange(t.rank(), vec![rank, !rank]), vec![rank, !rank]);
    });
}

#[test]
fn all_pairs_symmetric_exchange_is_deadlock_free() {
    // Every rank exchanges a non-trivial payload with every other rank in
    // ascending-peer order.  With blocking sends this ordering deadlocks
    // (all ranks first target rank 0... which targets rank 1); the
    // buffered-send contract of both fabrics must absorb it.  The
    // run_ranks watchdog converts a hang into a failure.
    let world = 4;
    let words = 100_000usize;
    let body = move |t: &dyn Transport| {
        for peer in 0..4usize {
            if peer == t.rank() {
                continue;
            }
            let got = t.exchange(peer, vec![t.rank() as u32; words]);
            assert_eq!(got, vec![peer as u32; words]);
        }
    };
    let mut local = LocalFabric::new(world);
    run_ranks(local.take_all(), move |t| body(&t));
    let tcp = tcp_fabric(world);
    run_ranks(tcp, move |t| body(&t));
}
