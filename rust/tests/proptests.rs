//! Property-based tests on the coordinator-level invariants: selection
//! contracts, wire-format round-trips, collective algebra, residual mass
//! conservation and the cost-model/simulator agreement.

use redsync::collectives::{allgather, allreduce_mean, concat, FusionPlan, LocalFabric, Transport};
use redsync::compression::message::{
    apply_gathered_plain, pack_plain, pack_quant, quant_words, unpack_plain, unpack_quant,
};
use redsync::compression::{
    exact_topk, threshold_binary_search, trimmed_topk, Accumulation, BinarySearchParams,
    QuantizedSet, ResidualState,
};
use redsync::costmodel;
use redsync::simnet::{allgather_time, allreduce_time, Machine};
use redsync::tensor::SparseTensor;
use redsync::util::proptest::{check, ensure, ensure_close};
use std::thread;

/// All three selectors pick supersets of each other's guarantees:
/// trimmed == exact (same k elements), binary search ⊇ exact's threshold.
#[test]
fn prop_trimmed_equals_exact_topk() {
    check(40, |g| {
        let n = g.size(64..20_000);
        let k = g.size(1..(n / 8).max(2));
        let x = g.vec_normal(n, 1.0);
        let e = exact_topk(&x, k, None);
        let t = trimmed_topk(&x, k, 0.2, None);
        ensure(t.sparse.len() == k, format!("trimmed returned {}", t.sparse.len()))?;
        // same index set (both exact selections of the same keys, ties
        // broken identically by magnitude)
        let mut ei = e.sparse.indices.clone();
        let mut ti = t.sparse.indices.clone();
        ei.sort_unstable();
        ti.sort_unstable();
        let e_min = e.sparse.values.iter().map(|v| v.abs()).fold(f32::MAX, f32::min);
        let t_min = t.sparse.values.iter().map(|v| v.abs()).fold(f32::MAX, f32::min);
        // tie-tolerant check: the kth magnitude must agree
        ensure_close(e_min as f64, t_min as f64, 1e-6, "kth magnitude")?;
        Ok(())
    });
}

#[test]
fn prop_binary_search_bounds() {
    check(40, |g| {
        let n = g.size(256..40_000);
        let k = g.size(4..(n / 16).max(5));
        let x = g.vec_normal(n, 1.0);
        let s = threshold_binary_search(&x, k, BinarySearchParams::default(), None);
        ensure(
            s.sparse.len() >= k.min(n),
            format!("bs returned {} < k={k}", s.sparse.len()),
        )?;
        // the 2k bound can be overshot only on pathological ties; the
        // uniform/normal generators never tie
        ensure(
            s.sparse.len() <= 2 * k + 1,
            format!("bs returned {} > 2k={}", s.sparse.len(), 2 * k),
        )?;
        // threshold property
        for &v in &s.sparse.values {
            ensure(v.abs() > s.threshold, "value below threshold")?;
        }
        Ok(())
    });
}

#[test]
fn prop_signed_selection_is_single_signed() {
    check(30, |g| {
        let n = g.size(128..10_000);
        let k = g.size(1..(n / 10).max(2));
        let x = g.vec_normal(n, 1.0);
        let sign = if g.bool() { 1.0 } else { -1.0 };
        for sel in [
            exact_topk(&x, k, Some(sign)),
            trimmed_topk(&x, k, 0.2, Some(sign)),
            threshold_binary_search(&x, k, BinarySearchParams::default(), Some(sign)),
        ] {
            for &v in &sel.sparse.values {
                ensure(v * sign > 0.0, format!("wrong-signed value {v} for sign {sign}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_roundtrip_plain_and_quant() {
    check(50, |g| {
        let n = g.size(1..500);
        let mut idx: Vec<u32> = (0..n as u32).collect();
        g.rng().shuffle(&mut idx);
        idx.truncate(g.size(1..n.max(2)));
        idx.sort_unstable();
        let vals = g.vec_normal(idx.len(), 2.0);
        let s = SparseTensor::new(idx.clone(), vals);
        let (s2, used) = unpack_plain(&pack_plain(&s)).map_err(|e| e.to_string())?;
        ensure(used == 1 + 2 * s.len(), "plain length")?;
        ensure(s2.indices == s.indices && s2.values == s.values, "plain roundtrip")?;

        let q = QuantizedSet { indices: idx, mean: g.f32(-3.0..3.0) };
        let (q2, used) = unpack_quant(&pack_quant(&q)).map_err(|e| e.to_string())?;
        ensure(used == q.len() + 2, "quant length")?;
        ensure(q2 == q, "quant roundtrip")?;
        Ok(())
    });
}

#[test]
fn prop_truncated_wire_rejected() {
    check(30, |g| {
        let k = g.size(2..100);
        let s = SparseTensor::new((0..k as u32).collect(), g.vec_normal(k, 1.0));
        let buf = pack_plain(&s);
        let cut = g.size(1..buf.len());
        ensure(unpack_plain(&buf[..cut]).is_err(), "truncated message accepted")?;
        Ok(())
    });
}

/// Quantized-RGC roundtrip: a single-signed selection survives
/// from_sparse → pack → unpack → dequantize with bit-exact indices and
/// mean, and the §5.2.3 mass identity `mean·k == Σvalues` holds.
#[test]
fn prop_quant_rgc_encode_decode_roundtrip() {
    check(50, |g| {
        let n = g.size(8..4_000);
        let k = g.size(1..(n / 4).max(2));
        let sign = if g.bool() { 1.0f32 } else { -1.0 };
        let mut idx: Vec<u32> = (0..n as u32).collect();
        g.rng().shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        // single-signed values, as the sign alternation guarantees
        let mut vals: Vec<f32> =
            g.vec_normal(k, 1.5).iter().map(|v| (v.abs() + 0.01) * sign).collect();
        // sometimes a non-finite gradient sneaks in: the quantizer and
        // the wire must stay total — bit-exact mean (NaN payloads
        // included), no panic — even though the mean goes non-finite
        let finite = g.bool();
        if !finite {
            for _ in 0..g.size(1..4) {
                let at = g.size(0..k);
                vals[at] = if g.bool() { f32::NAN } else { f32::INFINITY * sign };
            }
        }
        let s = SparseTensor::new(idx, vals);

        let q = QuantizedSet::from_sparse(&s);
        let (q2, used) = unpack_quant(&pack_quant(&q)).map_err(|e| e.to_string())?;
        ensure(used == quant_words(k), "wire length")?;
        ensure(q2.indices == s.indices, "indices must survive the wire")?;
        ensure(q2.mean.to_bits() == q.mean.to_bits(), "mean must be bit-exact")?;

        let d = q2.dequantize();
        ensure(d.indices == s.indices, "dequantize keeps the index set")?;
        ensure(
            d.values.iter().all(|v| v.to_bits() == q.mean.to_bits()),
            "dequantize is constant-valued",
        )?;
        if !finite {
            // the sign and mass identities only hold for finite selections
            return Ok(());
        }
        ensure(q2.mean * sign > 0.0, "mean must carry the selection's sign")?;
        // mass preservation: mean * k == sum(values) up to f32 rounding
        ensure_close(
            q.mean as f64 * k as f64,
            s.value_sum() as f64,
            1e-4,
            "quantization preserves mass",
        )
    });
}

/// FusionPlan::gather and scatter_into are exact inverses on arbitrary
/// layer splits: every bucket reconstructs its layers bit-for-bit, every
/// layer is covered exactly once.
#[test]
fn prop_fusion_gather_scatter_inverse() {
    check(40, |g| {
        let n_layers = g.size(1..12);
        let sizes: Vec<usize> = (0..n_layers).map(|_| g.size(1..300)).collect();
        let cap = g.size(1..600);
        let layers: Vec<Vec<f32>> = sizes.iter().map(|&n| g.vec_normal(n, 2.0)).collect();

        let plan = FusionPlan::greedy(&sizes, cap);
        let mut out: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0.0f32; n]).collect();
        let mut covered = vec![false; n_layers];
        for b in &plan.buckets {
            let fused = b.gather(|i| layers[i].as_slice());
            ensure(fused.len() == b.total_elems, "gather length == bucket total")?;
            b.scatter_into(&fused, &mut out);
            for &(i, n) in &b.layers {
                ensure(!covered[i], format!("layer {i} in two buckets"))?;
                ensure(n == sizes[i], "bucket records the true layer size")?;
                covered[i] = true;
            }
        }
        ensure(covered.iter().all(|&c| c), "every layer fused exactly once")?;
        for (orig, round) in layers.iter().zip(&out) {
            ensure(
                orig.iter().zip(round.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "gather ∘ scatter_into must be the identity, bit-for-bit",
            )?;
        }
        Ok(())
    });
}

/// Residual mass conservation: accumulate - send == keep (SGD rule).
#[test]
fn prop_residual_mass_conserved() {
    check(30, |g| {
        let n = g.size(64..4_000);
        let mut r = ResidualState::new(n, Accumulation::Sgd);
        let mut accumulated = 0f64;
        let mut sent = 0f64;
        for _ in 0..4 {
            let grad = g.vec_normal(n, 1.0);
            accumulated += grad.iter().map(|&v| v as f64).sum::<f64>();
            r.accumulate(&grad);
            let k = (n / 20).max(1);
            let sel = exact_topk(r.residual(), k, None);
            sent += sel.sparse.values.iter().map(|&v| v as f64).sum::<f64>();
            r.mask(&sel.sparse);
        }
        let kept: f64 = r.residual().iter().map(|&v| v as f64).sum();
        ensure_close(accumulated, sent + kept, 1e-2 * n as f64 * 1e-4 + 1e-3, "mass")?;
        Ok(())
    });
}

/// Sparse synchronization over the real fabric == serial scatter-add.
#[test]
fn prop_sparse_sync_equals_serial() {
    check(8, |g| {
        let world = *g.pick(&[2usize, 4, 8]);
        let n = g.size(64..512);
        // random per-rank contributions
        let contributions: Vec<SparseTensor> = (0..world)
            .map(|_| {
                let k = g.size(1..(n / 4).max(2));
                let mut idx: Vec<u32> = (0..n as u32).collect();
                g.rng().shuffle(&mut idx);
                idx.truncate(k);
                idx.sort_unstable();
                let vals = g.vec_normal(k, 1.0);
                SparseTensor::new(idx, vals)
            })
            .collect();
        let mut expect = vec![0f32; n];
        for c in &contributions {
            c.scatter_add(&mut expect, 1.0 / world as f32);
        }
        let mut fabric = LocalFabric::new(world);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                let c = contributions[t.rank()].clone();
                thread::spawn(move || {
                    let gathered = concat(allgather(&t, pack_plain(&c)));
                    let mut dense = vec![0f32; n];
                    apply_gathered_plain(&gathered, t.world(), &mut dense, 1.0 / t.world() as f32)
                        .unwrap();
                    dense
                })
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            ensure(got == expect, "rank result differs from serial reference")?;
        }
        Ok(())
    });
}

/// allreduce_mean over the fabric == arithmetic mean, all ranks agree.
#[test]
fn prop_allreduce_mean_exact() {
    check(8, |g| {
        let world = *g.pick(&[2usize, 4, 8]);
        let n = g.size(1..2_000);
        let data: Vec<Vec<f32>> = (0..world).map(|_| g.vec_normal(n, 1.0)).collect();
        let mut expect = vec![0f64; n];
        for d in &data {
            for (e, &v) in expect.iter_mut().zip(d) {
                *e += v as f64;
            }
        }
        let expect: Vec<f32> = expect.iter().map(|&v| (v / world as f64) as f32).collect();
        let mut fabric = LocalFabric::new(world);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                let mut x = data[t.rank()].clone();
                thread::spawn(move || {
                    allreduce_mean(&t, &mut x);
                    x
                })
            })
            .collect();
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results {
            ensure(r == &results[0], "ranks disagree")?;
        }
        for (got, want) in results[0].iter().zip(&expect) {
            ensure((got - want).abs() <= 1e-4 * want.abs().max(1.0), "mean wrong")?;
        }
        Ok(())
    });
}

/// The simnet collective walkers equal the closed-form Eq. 1/2 costs.
#[test]
fn prop_simnet_matches_costmodel() {
    check(40, |g| {
        let m = if g.bool() { Machine::muradin() } else { Machine::piz_daint() };
        let p = 1usize << g.size(1..8);
        let elems = g.size(1_000..20_000_000) as f64;
        let d = g.f32(1e-4..0.05) as f64;

        // Eq. 2 vs walked allreduce (gamma term differs by elems vs bytes
        // convention — compare the transfer parts by zeroing gamma)
        let mut m0 = m.clone();
        m0.gamma_reduce = 0.0;
        let dense_walk = allreduce_time(&m0, p, elems * 4.0);
        let pf = p as f64;
        let dense_closed = 2.0 * pf.log2() * m0.alpha + 2.0 * (pf - 1.0) / pf * elems * 4.0 * m0.beta;
        ensure_close(dense_walk, dense_closed, 1e-9 * dense_closed.max(1.0), "dense")?;

        // Eq. 1 transfer vs walked allgather
        let wire = costmodel::PLAIN_WIRE_BYTES;
        let sparse_walk = allgather_time(&m, p, elems * d * wire);
        let sparse_closed = pf.log2() * m.alpha + (pf - 1.0) * elems * d * wire * m.beta;
        ensure_close(sparse_walk, sparse_closed, 1e-9 * sparse_closed.max(1.0), "sparse")?;
        Ok(())
    });
}

/// Cost model sanity: bandwidth ratio formula (the §5.5 "12.8%" point).
#[test]
fn prop_bandwidth_ratio_monotone_in_p_and_d() {
    check(30, |g| {
        let p = 1usize << g.size(1..8);
        let d = g.f32(1e-4..0.01) as f64;
        let r1 = costmodel::bandwidth_ratio(p, d, costmodel::PLAIN_WIRE_BYTES);
        let r2 = costmodel::bandwidth_ratio(p * 2, d, costmodel::PLAIN_WIRE_BYTES);
        let r3 = costmodel::bandwidth_ratio(p, d * 2.0, costmodel::PLAIN_WIRE_BYTES);
        ensure(r2 > r1, "ratio must grow with p")?;
        ensure(r3 > r1, "ratio must grow with density")?;
        let rq = costmodel::bandwidth_ratio(p, d, costmodel::QUANT_WIRE_BYTES);
        ensure_close(rq, r1 / 2.0, 1e-12, "quantization halves the ratio")?;
        Ok(())
    });
}

/// Quantization bound: dequantized error never exceeds the value spread.
#[test]
fn prop_quantization_error_bounded() {
    check(30, |g| {
        let k = g.size(1..400);
        // single-signed values, as the §5.2.3 alternation guarantees
        let vals: Vec<f32> = g.vec_normal(k, 1.0).iter().map(|v| v.abs() + 0.01).collect();
        let s = SparseTensor::new((0..k as u32).collect(), vals.clone());
        let q = QuantizedSet::from_sparse(&s);
        let lo = vals.iter().cloned().fold(f32::MAX, f32::min);
        let hi = vals.iter().cloned().fold(f32::MIN, f32::max);
        ensure(q.mean >= lo - 1e-5 && q.mean <= hi + 1e-5, "mean outside range")?;
        let d = q.dequantize();
        ensure(d.len() == k, "dequantize length")?;
        ensure(d.values.iter().all(|&v| (v - q.mean).abs() < 1e-7), "constant values")?;
        Ok(())
    });
}

/// Zero-copy apply parity: `BucketDone::apply_to` (in-place message
/// views over one gather buffer) must be bit-identical to the
/// historical owned-decode walk — `unpack_plain`/`unpack_quant` into
/// fresh tensors, then scatter — on random gathered blobs, INCLUDING
/// truncated blobs: both walks must fail on the same input and leave
/// the same partially-applied parameters behind.
#[test]
fn prop_view_apply_matches_owned_decode_apply() {
    use redsync::collectives::Gathered;
    use redsync::pipeline::BucketDone;

    /// The pre-zero-copy decompression walk, verbatim.
    fn apply_owned(
        gathered: &[Vec<u32>],
        layers: &[(usize, bool)],
        params: &mut [Vec<f32>],
        scale: f32,
    ) -> Result<(), String> {
        for rank_blob in gathered {
            let mut off = 0usize;
            for &(li, quantized) in layers {
                if quantized {
                    let (q, used) = unpack_quant(&rank_blob[off..])
                        .map_err(|e| format!("layer {li}: {e}"))?;
                    let add = q.mean * scale;
                    for &i in &q.indices {
                        params[li][i as usize] += add;
                    }
                    off += used;
                } else {
                    let (s, used) = unpack_plain(&rank_blob[off..])
                        .map_err(|e| format!("layer {li}: {e}"))?;
                    s.scatter_add(&mut params[li], scale);
                    off += used;
                }
            }
        }
        Ok(())
    }

    check(60, |g| {
        let n_layers = g.size(1..4);
        let n_ranks = g.size(1..5);
        let dim = g.size(8..300);
        let layers: Vec<(usize, bool)> = (0..n_layers).map(|li| (li, g.bool())).collect();
        // each rank's blob: one message per layer, random sparse content
        let mut gathered: Vec<Vec<u32>> = (0..n_ranks)
            .map(|_| {
                let mut blob = Vec::new();
                for &(_, quantized) in &layers {
                    let k = g.size(0..dim / 2);
                    let mut idx: Vec<u32> = (0..dim as u32).collect();
                    g.rng().shuffle(&mut idx);
                    idx.truncate(k);
                    idx.sort_unstable();
                    if quantized {
                        blob.extend(pack_quant(&QuantizedSet {
                            indices: idx,
                            mean: g.f32(-2.0..2.0),
                        }));
                    } else {
                        let vals = g.vec_normal(idx.len(), 1.5);
                        blob.extend(pack_plain(&SparseTensor::new(idx, vals)));
                    }
                }
                blob
            })
            .collect();
        // sometimes truncate one rank's blob mid-message — error parity
        if g.bool() && !gathered[n_ranks - 1].is_empty() {
            let cut = g.size(0..gathered[n_ranks - 1].len());
            gathered[n_ranks - 1].truncate(cut);
        }

        let scale = g.f32(-1.0..1.0);
        let init: Vec<Vec<f32>> = (0..n_layers).map(|_| g.vec_normal(dim, 0.5)).collect();

        let mut owned_params = init.clone();
        let owned_res = apply_owned(&gathered, &layers, &mut owned_params, scale);

        let mut view_params = init;
        let done = BucketDone {
            bucket: 0,
            layers: layers.clone(),
            gathered: Gathered::from_parts(&gathered),
            selected: 0,
            elems: 0,
            msg_words: 0,
            comm_secs: 0.0,
        };
        let view_res = done.apply_to(&mut view_params, scale);

        ensure(
            owned_res.is_ok() == view_res.is_ok(),
            format!("error parity: owned {owned_res:?} vs view {view_res:?}"),
        )?;
        if let (Err(a), Err(b)) = (&owned_res, &view_res) {
            ensure(a == b, format!("error text diverged: {a} vs {b}"))?;
        }
        for (li, (a, b)) in owned_params.iter().zip(&view_params).enumerate() {
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                ensure(
                    x.to_bits() == y.to_bits(),
                    format!("layer {li} elem {i}: {x} != {y} (bitwise)"),
                )?;
            }
        }
        Ok(())
    });
}

/// Eq. 1 vs Eq. 2 crossover: sparse wins exactly below the crossover
/// density returned by the solver.
#[test]
fn prop_crossover_density_is_a_boundary() {
    check(25, |g| {
        let m = Machine::muradin();
        let p = 1usize << g.size(1..7);
        let elems = g.size(100_000..50_000_000) as f64;
        if let Some(dc) =
            costmodel::crossover_density(&m, p, elems, 0.0, costmodel::PLAIN_WIRE_BYTES)
        {
            ensure(
                costmodel::sparse_wins(&m, p, elems, dc * 0.5, 0.0, costmodel::PLAIN_WIRE_BYTES),
                "below crossover must win",
            )?;
            ensure(
                !costmodel::sparse_wins(&m, p, elems, dc * 1.5, 0.0, costmodel::PLAIN_WIRE_BYTES)
                    || dc * 1.5 > 1.0,
                "above crossover must lose",
            )?;
        }
        Ok(())
    });
}

/// Step-latency histograms ride the obs gather as fixed-size frames;
/// the wire form must round-trip every field exactly and reject any
/// frame of the wrong length (the gather concatenates one frame per
/// rank, so a length drift would desynchronize the whole decode).
#[test]
fn prop_step_hist_wire_roundtrip() {
    use redsync::obs::Hist;
    check(40, |g| {
        let mut h = Hist::default();
        let n_obs = g.size(0..200);
        for _ in 0..n_obs {
            // span the full bucket range, zeros and multi-second outliers
            let bits = g.size(1..40);
            h.observe(g.size(0..1usize << bits) as u64);
        }
        let rank = g.size(0..1024) as u32;
        let w = h.encode(rank);
        let (r2, h2) = Hist::decode(&w).map_err(|e| e.to_string())?;
        ensure(r2 == rank, "rank must survive the wire")?;
        ensure(h2.count == h.count, "count must survive the wire")?;
        ensure(h2.sum_us == h.sum_us, "sum must survive the wire")?;
        ensure(h2.buckets == h.buckets, "buckets must survive the wire")?;
        // exact-length contract: anything shorter or longer is rejected
        let cut = g.size(0..w.len());
        ensure(Hist::decode(&w[..cut]).is_err(), "truncated frame accepted")?;
        let mut long = w.clone();
        long.push(0);
        ensure(Hist::decode(&long).is_err(), "oversized frame accepted")?;
        Ok(())
    });
}

/// Cross-rank aggregation is a fold over a commutative monoid: the
/// cluster stats must not depend on gather arrival order, and merging
/// histograms in any grouping must give the same totals.
#[test]
fn prop_step_hist_aggregation_is_order_free() {
    use redsync::obs::{aggregate_step_hists, Hist};
    check(30, |g| {
        let world = g.size(1..9);
        let mut hists: Vec<(u32, Hist)> = (0..world as u32)
            .map(|rank| {
                let mut h = Hist::default();
                for _ in 0..g.size(0..60) {
                    h.observe(g.size(0..2_000_000) as u64);
                }
                (rank, h)
            })
            .collect();
        let base = aggregate_step_hists(&hists);
        // commutativity: any permutation of the gathered frames agrees
        g.rng().shuffle(&mut hists);
        let perm = aggregate_step_hists(&hists);
        ensure(perm.step_p50_us == base.step_p50_us, "p50 depends on order")?;
        ensure(perm.step_p99_us == base.step_p99_us, "p99 depends on order")?;
        ensure(perm.rank_skew == base.rank_skew, "skew depends on order")?;
        // associativity: ((a ∪ b) ∪ c) == (a ∪ (b ∪ c)) for the merge
        if world >= 3 {
            let (a, b, c) = (&hists[0].1, &hists[1].1, &hists[2].1);
            let mut left = a.clone();
            left.merge(b);
            left.merge(c);
            let mut bc = b.clone();
            bc.merge(c);
            let mut right = a.clone();
            right.merge(&bc);
            ensure(left.count == right.count, "merge count not associative")?;
            ensure(left.sum_us == right.sum_us, "merge sum not associative")?;
            ensure(left.buckets == right.buckets, "merge buckets not associative")?;
        }
        Ok(())
    });
}

/// Elastic checkpoints carry the training trajectory (params, residual
/// V + momentum U, dense velocity) across kills and rejoins, so the
/// RSCK container must round-trip arbitrary shapes exactly and reject
/// *every* single-bit corruption via its FNV trailer — the rejoin path
/// restores residual state from these blobs blindly.
#[test]
fn prop_checkpoint_roundtrip_and_every_bitflip_rejected() {
    use redsync::coordinator::{Checkpoint, LayerState};
    check(12, |g| {
        let n_layers = g.size(1..4);
        let layers: Vec<LayerState> = (0..n_layers)
            .map(|_| {
                let n = g.size(1..9);
                LayerState {
                    params: g.vec_normal(n, 1.0),
                    residual: if g.bool() {
                        Some((g.vec_normal(n, 1.0), g.vec_normal(n, 1.0)))
                    } else {
                        None
                    },
                    velocity: if g.bool() { Some(g.vec_normal(n, 1.0)) } else { None },
                }
            })
            .collect();
        let ck = Checkpoint {
            step: g.size(0..100_000) as u64,
            seed: g.size(0..100_000) as u64,
            view_epoch: g.size(0..8) as u64,
            layers,
        };
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).map_err(|e| format!("parse: {e}"))?;
        ensure(back == ck, "roundtrip changed the state")?;
        for bit in 0..bytes.len() * 8 {
            let mut corrupt = bytes.clone();
            corrupt[bit / 8] ^= 1 << (bit % 8);
            ensure(
                Checkpoint::from_bytes(&corrupt).is_err(),
                format!("flipping bit {bit} of {} was accepted", bytes.len() * 8),
            )?;
        }
        Ok(())
    });
}
