//! Elastic-membership chaos matrix (DESIGN.md §Elastic-Membership).
//!
//! Injected kills and stalls across both sync engines, both real
//! fabrics and both collective schedules, with the two load-bearing
//! pins:
//!
//! * **Post-reshape bit-identity** — a 4-rank run that loses rank 2
//!   mid-training reshapes to a 3-rank world and, from the reshape
//!   barrier onward, is *bit-identical* to a fresh 3-rank run started
//!   from the survivors' dumped checkpoints.
//! * **Residual-preserving rejoin** — a killed-then-rejoined rank
//!   resumes with its residual/momentum state intact, bit-compared
//!   against an uninterrupted run's checkpoint at the same step.
//!
//! No artifacts needed: the driver runs over
//! `elastic::synthetic::SyntheticWorkload`, whose gradients are pure in
//! `(seed, view_epoch, rank, world, step, layer)`.

use redsync::collectives::{Topology, Transport};
use redsync::coordinator::metrics::RejoinStats;
use redsync::coordinator::Checkpoint;
use redsync::elastic::synthetic::{self, FrozenWorkload, SyntheticWorkload};
use redsync::elastic::{
    fresh_checkpoint, run_elastic_worker, run_local_fleet, ElasticOpts, ElasticStatus, FaultSpec,
    FleetOutcome, RankOutcome, StallSpec,
};
use redsync::net::{
    free_loopback_addr, MixedFabric, MixedOptions, TcpOptions, TcpTransport, UnixOptions,
    UnixTransport,
};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

const SEED: u64 = 0xE1A5;

static NEXT_NS: AtomicU32 = AtomicU32::new(0);

/// Fresh socket-path namespace: unique per process *and* per call.
fn socket_ns() -> String {
    format!("/tmp/rs-el-{}-{}", std::process::id(), NEXT_NS.fetch_add(1, Ordering::Relaxed))
}

fn opts(steps: usize, pipeline: bool) -> ElasticOpts {
    ElasticOpts {
        steps,
        pipeline,
        fusion_cap_elems: 3000,
        // a generous lease (4x this) so loaded CI machines cannot
        // false-positive; kill detection is transport-driven and fast
        // regardless
        heartbeat: Duration::from_millis(100),
        log_every: 2,
        ..ElasticOpts::default()
    }
}

fn fresh(o: &ElasticOpts) -> Checkpoint {
    fresh_checkpoint(synthetic::init_params(SEED), &synthetic::specs(), o.optimizer, SEED)
}

/// Run a fleet over the in-process fabric (handles rejoin generations).
fn run_local(world: usize, o: &ElasticOpts) -> FleetOutcome {
    let specs = synthetic::specs();
    run_local_fleet(
        world,
        &specs,
        o,
        |_r| Ok(fresh(o)),
        |_r| Ok(SyntheticWorkload { seed: SEED }),
    )
    .expect("fleet")
}

/// Run a fleet over the in-process fabric, each rank resuming from
/// `{prefix}_rank{r}.rsck`-style files named by `path_of`.
fn run_local_resumed(
    world: usize,
    o: &ElasticOpts,
    path_of: impl Fn(usize) -> String + Send + Sync,
) -> FleetOutcome {
    let specs = synthetic::specs();
    run_local_fleet(
        world,
        &specs,
        o,
        |r| Checkpoint::load(path_of(r)).map_err(|e| format!("resume rank {r}: {e}")),
        |_r| Ok(SyntheticWorkload { seed: SEED }),
    )
    .expect("fleet")
}

/// Run every rank of a socket fleet in threads (shrink only — the
/// in-process orchestrator owns rejoin), bootstrapping each rank's
/// endpoint with `connect`.
fn run_sockets<T, C>(world: usize, o: &ElasticOpts, connect: C) -> Vec<RankOutcome>
where
    T: Transport + Sync + Send + 'static,
    C: Fn(usize) -> T + Send + Sync + 'static,
{
    let connect = Arc::new(connect);
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let connect = Arc::clone(&connect);
            let o = o.clone();
            thread::spawn(move || {
                let t = connect(rank);
                let specs = synthetic::specs();
                let init = fresh(&o);
                let mut w = SyntheticWorkload { seed: SEED };
                run_elastic_worker(&t, &specs, init, None, &o, &mut w)
                    .unwrap_or_else(|e| panic!("rank {rank}: {e}"))
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
}

fn run_tcp(world: usize, o: &ElasticOpts) -> Vec<RankOutcome> {
    let addr = free_loopback_addr();
    run_sockets(world, o, move |rank| {
        TcpTransport::connect(&TcpOptions::new(world, rank, addr.clone())).expect("tcp bootstrap")
    })
}

fn run_unix(world: usize, o: &ElasticOpts) -> Vec<RankOutcome> {
    let base = socket_ns();
    run_sockets(world, o, move |rank| {
        UnixTransport::connect(&UnixOptions::new(world, rank, base.clone()))
            .expect("unix bootstrap")
    })
}

/// Mixed fabric split as 2 "nodes": Unix sockets intra-node, TCP across.
fn run_mixed(world: usize, o: &ElasticOpts) -> Vec<RankOutcome> {
    let addr = free_loopback_addr();
    let topo = Topology::new(2, world / 2);
    run_sockets(world, o, move |rank| {
        MixedFabric::connect(&MixedOptions::new(world, rank, addr.clone(), topo))
            .expect("mixed bootstrap")
    })
}

fn tmp_prefix(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("redsync_elastic_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("tmp dir");
    dir.join("ck").to_string_lossy().into_owned()
}

// ---------------------------------------------------------------------
// No-fault baseline: the elastic stack must not change the math
// ---------------------------------------------------------------------

#[test]
fn no_fault_runs_agree_across_engines_and_fabrics() {
    let world = 4;
    let o_seq = opts(8, false);
    let o_pipe = opts(8, true);
    let local_seq = run_local(world, &o_seq);
    let local_pipe = run_local(world, &o_pipe);
    let tcp_seq = run_tcp(world, &o_seq);
    let tcp_pipe = run_tcp(world, &o_pipe);

    let mut hashes = Vec::new();
    for (label, ranks) in [
        ("local/seq", &local_seq.ranks),
        ("local/pipe", &local_pipe.ranks),
        ("tcp/seq", &tcp_seq),
        ("tcp/pipe", &tcp_pipe),
    ] {
        for o in ranks.iter() {
            assert_eq!(o.status, ElasticStatus::Finished, "{label}");
            assert!(o.replicas_consistent, "{label}");
            assert!(o.events.is_empty(), "{label}: spurious membership events");
            assert_eq!(o.epoch, 0, "{label}");
        }
        hashes.push((label, ranks[0].param_hash));
    }
    let h0 = hashes[0].1;
    for (label, h) in &hashes {
        assert_eq!(*h, h0, "{label} diverged from local/seq");
    }
}

#[test]
fn elastic_traffic_is_fully_multiplexed() {
    // without faults, every byte on the fabric went through the mux
    // (ctrl + bucket + heartbeat tags) — exact accounting, word for word
    let fleet = run_local(2, &opts(5, true));
    let mux_words: u64 = fleet.ranks.iter().map(|o| o.mux_words).sum();
    assert_eq!(fleet.bytes, mux_words * 4, "raw fabric bytes == muxed words");
    for o in &fleet.ranks {
        assert!(o.ctrl_words > 0, "control stream is accounted");
        assert!(o.ctrl_words <= o.mux_words);
    }
}

// ---------------------------------------------------------------------
// Kill → reshape → bit-identical continuation (the acceptance pin)
// ---------------------------------------------------------------------

/// Which fabric carries a chaos-matrix case.
#[derive(Clone, Copy)]
enum Fabric {
    Local,
    Tcp,
    Unix,
    Mixed,
}

impl Fabric {
    fn label(self) -> &'static str {
        match self {
            Fabric::Local => "local",
            Fabric::Tcp => "tcp",
            Fabric::Unix => "unix",
            Fabric::Mixed => "mixed",
        }
    }
}

/// Shared body: 4 ranks, rank 2 killed at step 6 of 12; survivors must
/// reshape to a 3-rank world and match a fresh 3-rank run resumed from
/// their reshape checkpoints, bit for bit.
fn kill_reshape_case(pipeline: bool, fabric: Fabric) {
    let world = 4;
    let prefix = tmp_prefix(&format!("kill_p{}_{}", pipeline as u8, fabric.label()));
    let mut o = opts(12, pipeline);
    o.kill = vec![FaultSpec { rank: 2, step: 6 }];
    o.ckpt_prefix = Some(prefix.clone());

    let ranks: Vec<RankOutcome> = match fabric {
        Fabric::Local => run_local(world, &o).ranks,
        Fabric::Tcp => run_tcp(world, &o),
        Fabric::Unix => run_unix(world, &o),
        Fabric::Mixed => run_mixed(world, &o),
    };

    assert_eq!(ranks[2].status, ElasticStatus::Killed);
    let mut survivor_hash = None;
    for r in [0usize, 1, 3] {
        let out = &ranks[r];
        assert_eq!(out.status, ElasticStatus::Finished, "rank {r}");
        assert!(out.replicas_consistent, "rank {r}");
        assert_eq!(out.view, vec![0, 1, 3], "rank {r} final view");
        assert_eq!(out.epoch, 1, "rank {r} final epoch");
        assert_eq!(out.events.len(), 1, "rank {r} events");
        let e = &out.events[0];
        assert_eq!(e.lost, vec![2]);
        assert_eq!(e.world_after, 3);
        assert_eq!(e.resume_step, 6, "all ranks completed exactly 6 steps");
        // detection must happen within (a generous multiple of) the
        // heartbeat lease — transport-level detection is near-immediate
        assert!(e.detect_secs < 2.0, "detect took {}s", e.detect_secs);
        match survivor_hash {
            None => survivor_hash = Some(out.param_hash),
            Some(h) => assert_eq!(out.param_hash, h, "survivors agree"),
        }
    }

    // run B: a fresh 3-rank world started from the survivors' dumped
    // reshape state (files keyed by the old world ranks; B's rank r
    // takes over survivor members[r]) — from the barrier onward the
    // trajectories must be bit-identical
    let o_b = opts(12, pipeline);
    let survivors = [0usize, 1, 3];
    let b = run_local_resumed(3, &o_b, move |r| {
        format!("{prefix}_reshape_e1_rank{}.rsck", survivors[r])
    });
    for (r, out) in b.ranks.iter().enumerate() {
        assert_eq!(out.status, ElasticStatus::Finished, "B rank {r}");
        assert!(out.replicas_consistent, "B rank {r}");
        assert_eq!(out.epoch, 1, "B resumes inside view epoch 1");
    }
    assert_eq!(
        b.ranks[0].param_hash,
        survivor_hash.unwrap(),
        "fresh 3-rank run from the reshape checkpoints must match the survivors bit-for-bit"
    );

    // the reporter's loss curves agree from the barrier on
    let a_tail: Vec<(usize, f32)> = ranks[0]
        .loss_curve
        .iter()
        .copied()
        .filter(|&(s, _)| s >= 6)
        .collect();
    let b_tail: Vec<(usize, f32)> =
        b.ranks[0].loss_curve.iter().copied().filter(|&(s, _)| s >= 6).collect();
    assert_eq!(a_tail, b_tail, "post-barrier loss trajectories");
}

#[test]
fn kill_reshape_bit_identity_local_sequential() {
    kill_reshape_case(false, Fabric::Local);
}

#[test]
fn kill_reshape_bit_identity_local_pipelined() {
    kill_reshape_case(true, Fabric::Local);
}

#[test]
fn kill_reshape_bit_identity_tcp_sequential() {
    kill_reshape_case(false, Fabric::Tcp);
}

#[test]
fn kill_reshape_bit_identity_tcp_pipelined() {
    kill_reshape_case(true, Fabric::Tcp);
}

#[test]
fn kill_reshape_bit_identity_unix_sequential() {
    kill_reshape_case(false, Fabric::Unix);
}

#[test]
fn kill_reshape_bit_identity_unix_pipelined() {
    kill_reshape_case(true, Fabric::Unix);
}

#[test]
fn kill_reshape_bit_identity_mixed_sequential() {
    kill_reshape_case(false, Fabric::Mixed);
}

#[test]
fn kill_reshape_bit_identity_mixed_pipelined() {
    kill_reshape_case(true, Fabric::Mixed);
}

// ---------------------------------------------------------------------
// Hierarchical schedule under loss
// ---------------------------------------------------------------------

#[test]
fn hierarchical_survives_whole_node_loss() {
    // 2x2 topology; both ranks of node 1 die at step 4: the survivors
    // form a whole node, so the hierarchical schedule survives as 1x2
    let world = 4;
    let mut o = opts(10, false);
    o.topology = Some(Topology::new(2, 2));
    o.hierarchical = true;
    o.kill = vec![FaultSpec { rank: 2, step: 4 }, FaultSpec { rank: 3, step: 4 }];
    let fleet = run_local(world, &o);
    for r in [0usize, 1] {
        let out = &fleet.ranks[r];
        assert_eq!(out.status, ElasticStatus::Finished, "rank {r}");
        assert!(out.replicas_consistent, "rank {r}");
        assert_eq!(out.view, vec![0, 1]);
        let last = out.events.last().expect("events");
        assert_eq!(last.world_after, 2);
        let lost: Vec<usize> =
            out.events.iter().flat_map(|e| e.lost.iter().copied()).collect();
        assert_eq!(lost.len(), 2, "both node-1 ranks reported lost: {lost:?}");
        assert!(lost.contains(&2) && lost.contains(&3));
    }
    assert_eq!(fleet.ranks[0].param_hash, fleet.ranks[1].param_hash);
    assert_eq!(fleet.ranks[2].status, ElasticStatus::Killed);
    assert_eq!(fleet.ranks[3].status, ElasticStatus::Killed);
}

// ---------------------------------------------------------------------
// Stalls: short ones are ridden out, long ones get evicted (TCP)
// ---------------------------------------------------------------------

#[test]
fn short_stall_is_ridden_out_without_membership_changes() {
    let world = 3;
    let mut with_stall = opts(8, false);
    with_stall.heartbeat = Duration::from_millis(150); // lease 600ms
    with_stall.stall = vec![StallSpec { rank: 1, step: 3, millis: 40 }];
    let stalled = run_local(world, &with_stall);
    let mut plain_opts = opts(8, false);
    plain_opts.heartbeat = Duration::from_millis(150);
    let plain = run_local(world, &plain_opts);
    for o in &stalled.ranks {
        assert_eq!(o.status, ElasticStatus::Finished);
        assert!(o.events.is_empty(), "a sub-lease stall must not reshape");
    }
    assert_eq!(
        stalled.ranks[0].param_hash, plain.ranks[0].param_hash,
        "a ridden-out stall changes nothing"
    );
}

#[test]
fn long_stall_over_tcp_is_detected_and_evicted() {
    // rank 2 freezes (monitor included — a SIGSTOP-faithful stall) for
    // well over the lease: survivors sever the link, reshape to a
    // 2-rank world and finish; the stalled rank wakes up evicted
    let world = 3;
    let mut o = opts(12, false);
    o.heartbeat = Duration::from_millis(50); // lease 200ms
    o.min_ranks = 2;
    o.stall = vec![StallSpec { rank: 2, step: 4, millis: 1500 }];
    let ranks = run_tcp(world, &o);
    for r in [0usize, 1] {
        let out = &ranks[r];
        assert_eq!(out.status, ElasticStatus::Finished, "rank {r}");
        assert!(out.replicas_consistent, "rank {r}");
        assert_eq!(out.view, vec![0, 1], "rank {r}");
        let e = out.events.last().expect("reshape event");
        assert_eq!(e.lost, vec![2]);
        assert_eq!(e.world_after, 2);
    }
    assert_eq!(ranks[0].param_hash, ranks[1].param_hash);
    assert_eq!(
        ranks[2].status,
        ElasticStatus::Evicted,
        "the stalled rank must discover its eviction"
    );
}

// ---------------------------------------------------------------------
// Residual-preserving rejoin (the second acceptance pin)
// ---------------------------------------------------------------------

#[test]
fn rejoin_restores_residual_and_momentum_bit_exactly() {
    let world = 4;

    // reference: an uninterrupted elastic run checkpointing at step 6
    let ref_prefix = tmp_prefix("rejoin_ref");
    let mut o_ref = opts(6, false);
    o_ref.ckpt_prefix = Some(ref_prefix.clone());
    o_ref.ckpt_every = 6;
    let r = run_local(world, &o_ref);
    for o in &r.ranks {
        assert_eq!(o.status, ElasticStatus::Finished);
    }
    let reference =
        Checkpoint::load(format!("{ref_prefix}_rank2.rsck")).expect("reference ckpt");
    assert_eq!(reference.step, 6);

    // faulted run: rank 2 dies at step 6 (right after its checkpoint),
    // survivors shrink to 3 and run on; at step 12 rank 2 rejoins,
    // restoring its own residual/momentum and streaming params from the
    // donor; the full world then finishes step 18 together
    let a_prefix = tmp_prefix("rejoin_a");
    let mut o = opts(18, false);
    o.kill = vec![FaultSpec { rank: 2, step: 6 }];
    o.rejoin = vec![FaultSpec { rank: 2, step: 12 }];
    o.ckpt_prefix = Some(a_prefix.clone());
    o.ckpt_every = 6;
    let fleet = run_local(world, &o);

    for (rank, out) in fleet.ranks.iter().enumerate() {
        assert_eq!(out.status, ElasticStatus::Finished, "rank {rank}");
        assert!(out.replicas_consistent, "rank {rank}");
        assert_eq!(out.view, vec![0, 1, 2, 3], "full world after rejoin");
        assert_eq!(out.epoch, 2, "kill bumped to 1, rejoin to 2");
    }
    let survivors_events = &fleet.ranks[0].events;
    assert!(
        survivors_events.iter().any(|e| e.lost == vec![2] && e.epoch == 1),
        "loss event: {survivors_events:?}"
    );
    assert!(
        survivors_events.iter().any(|e| e.joined == vec![2] && e.epoch == 2),
        "join event: {survivors_events:?}"
    );

    // the rejoiner's restored state: per-rank residual/momentum (and
    // dense velocity) bit-identical to the uninterrupted run's
    // checkpoint at the same step; params advanced to the barrier by
    // the donor stream
    let joined =
        Checkpoint::load(format!("{a_prefix}_join_rank2.rsck")).expect("join ckpt");
    assert_eq!(joined.step, 12, "rejoined at the barrier");
    assert_eq!(joined.view_epoch, 2);
    assert_eq!(reference.layers.len(), joined.layers.len());
    for (li, (a, b)) in reference.layers.iter().zip(&joined.layers).enumerate() {
        assert_eq!(
            a.residual, b.residual,
            "layer {li}: residual/momentum must survive the kill bit-for-bit"
        );
        assert_eq!(a.velocity, b.velocity, "layer {li}: dense velocity");
    }
    // and the donor stream really advanced the params past the checkpoint
    assert_ne!(
        reference.layers[0].params, joined.layers[0].params,
        "params at step 12 differ from the step-6 checkpoint"
    );
}

// ---------------------------------------------------------------------
// Content-addressed checkpoint repository + delta rejoin
// ---------------------------------------------------------------------

/// Run a fleet whose workload freezes some layers (zero gradients), so
/// chunks of those layers stay bit-stable across steps and the delta
/// rejoin has real content to skip.
fn run_local_frozen(world: usize, o: &ElasticOpts, frozen: &[usize]) -> FleetOutcome {
    let specs = synthetic::specs();
    let frozen = frozen.to_vec();
    run_local_fleet(
        world,
        &specs,
        o,
        |_r| Ok(fresh(o)),
        move |_r| Ok(FrozenWorkload { seed: SEED, frozen: frozen.clone() }),
    )
    .expect("fleet")
}

/// Kill rank 2 at step 6, rejoin it at step 12 of 18, checkpointing
/// every 6 steps into both the RSCK prefix and the chunk repo.
fn delta_opts(tag: &str) -> (String, ElasticOpts) {
    let prefix = tmp_prefix(tag);
    let mut o = opts(18, false);
    o.kill = vec![FaultSpec { rank: 2, step: 6 }];
    o.rejoin = vec![FaultSpec { rank: 2, step: 12 }];
    o.ckpt_prefix = Some(prefix.clone());
    o.ckpt_every = 6;
    o.ckpt_repo = Some(format!("{prefix}_repo"));
    (prefix, o)
}

fn summed(f: &FleetOutcome, pick: fn(&RejoinStats) -> u64) -> u64 {
    f.ranks.iter().map(|o| pick(&o.rejoin)).sum()
}

#[test]
fn delta_rejoin_moves_fewer_words_than_a_full_image() {
    let world = 4;
    // layers 0, 3, 4 (4300 of 6600 params) are frozen: their chunks at
    // the rejoiner's stale step-6 checkpoint still match the donors'
    // step-12 manifest, so only the live layers' chunks travel
    let frozen = [0usize, 3, 4];

    let (a_prefix, o_a) = delta_opts("delta_a");
    let a = run_local_frozen(world, &o_a, &frozen);
    let (b_prefix, mut o_b) = delta_opts("delta_b");
    o_b.rejoin_full_image = true;
    let b = run_local_frozen(world, &o_b, &frozen);

    for (label, fleet) in [("delta", &a), ("full", &b)] {
        for (rank, out) in fleet.ranks.iter().enumerate() {
            assert_eq!(out.status, ElasticStatus::Finished, "{label} rank {rank}");
            assert!(out.replicas_consistent, "{label} rank {rank}");
            assert_eq!(out.view, vec![0, 1, 2, 3], "{label} rank {rank}");
        }
    }
    // both rejoin flavors restore the same bytes, so the runs finish
    // bit-identical — the delta path changes traffic, never state
    assert_eq!(a.ranks[0].param_hash, b.ranks[0].param_hash);
    let a_join = Checkpoint::load(format!("{a_prefix}_join_rank2.rsck")).expect("join ckpt");
    let b_join = Checkpoint::load(format!("{b_prefix}_join_rank2.rsck")).expect("join ckpt");
    assert_eq!(
        a_join.to_bytes(),
        b_join.to_bytes(),
        "delta and full-image rejoin agree bit-for-bit"
    );

    // word-exact accounting: the full-image stream is one ctrl message
    // per layer (its params + the mux tag word), and the delta run's
    // counterfactual figure prices exactly that
    let full_words: u64 = synthetic::SIZES.iter().map(|&n| n as u64 + 1).sum();
    assert_eq!(summed(&b, |r| r.join_words), full_words, "full-image join words");
    assert_eq!(summed(&a, |r| r.full_image_words), full_words);
    let delta_words = summed(&a, |r| r.join_words);
    assert!(
        delta_words < full_words,
        "delta rejoin must move strictly fewer words ({delta_words} vs {full_words})"
    );

    // the frozen layers' chunks were reused, the rest fetched — and
    // every fetched chunk passed its digest check
    let rj = &a.ranks[2].rejoin;
    assert!(rj.reused_chunks > 0, "frozen layers satisfied from the stale checkpoint");
    assert!(rj.fetched_chunks > 0, "live layers actually travelled");
    assert_eq!(rj.verified_chunks, rj.fetched_chunks, "every fetched chunk digest-verified");
    assert_eq!(rj.retries, 0, "clean run needs no retries");
    assert_eq!(rj.failovers, 0, "clean run needs no failovers");

    // the per-rank chunk repos saw writes, dedup across steps, and
    // eviction-driven collection under the 2-deep keep policy
    let rp = &a.ranks[0].repo;
    assert!(rp.manifests_written > 0, "repo manifests written");
    assert!(rp.chunks_written > 0, "repo chunks written");
    assert!(rp.chunks_deduped > 0, "frozen layers dedup across steps");
    assert!(rp.chunks_collected > 0, "evicted manifests release their chunks");
}

#[test]
fn donor_loss_and_corruption_mid_rejoin_fail_over_bit_identically() {
    let world = 4;
    let frozen = [0usize, 3, 4];

    // X: the clean three-donor delta rejoin (reference bytes)
    let (x_prefix, mut o_x) = delta_opts("failover_x");
    o_x.rejoin_donors = 3;
    let x = run_local_frozen(world, &o_x, &frozen);
    for (rank, out) in x.ranks.iter().enumerate() {
        assert_eq!(out.status, ElasticStatus::Finished, "X rank {rank}");
    }
    let x_join = Checkpoint::load(format!("{x_prefix}_join_rank2.rsck")).expect("X join ckpt");

    // Y: donor 0 dies after serving one chunk; the rejoiner's fetch
    // fails over to donors 1 and 3 and restores the same bytes, then
    // the view sheds the dead donor and finishes
    let (y_prefix, mut o_y) = delta_opts("failover_y");
    o_y.rejoin_donors = 3;
    o_y.join_kill = vec![0];
    let y = run_local_frozen(world, &o_y, &frozen);
    assert_eq!(y.ranks[0].status, ElasticStatus::Killed, "donor 0 died mid-rejoin");
    for r in [1usize, 2, 3] {
        assert_eq!(y.ranks[r].status, ElasticStatus::Finished, "Y rank {r}");
        assert!(y.ranks[r].replicas_consistent, "Y rank {r}");
        assert_eq!(y.ranks[r].view, vec![1, 2, 3], "Y sheds the dead donor");
    }
    assert!(y.ranks[2].rejoin.failovers >= 1, "the rejoiner recorded the failover");
    let y_join = Checkpoint::load(format!("{y_prefix}_join_rank2.rsck")).expect("Y join ckpt");
    assert_eq!(
        x_join.to_bytes(),
        y_join.to_bytes(),
        "killing a donor mid-rejoin still converges bit-identically"
    );

    // Z: a donor flips one bit in the first chunk it serves; the digest
    // check catches it, a retry round fetches it clean
    let (z_prefix, mut o_z) = delta_opts("failover_z");
    o_z.rejoin_donors = 3;
    o_z.join_corrupt = vec![0];
    let z = run_local_frozen(world, &o_z, &frozen);
    for (rank, out) in z.ranks.iter().enumerate() {
        assert_eq!(out.status, ElasticStatus::Finished, "Z rank {rank}");
        assert!(out.replicas_consistent, "Z rank {rank}");
    }
    let zj = &z.ranks[2].rejoin;
    assert!(zj.retries >= 1, "the corrupt chunk was detected and retried");
    assert_eq!(zj.verified_chunks, zj.fetched_chunks, "only verified chunks were applied");
    let z_join = Checkpoint::load(format!("{z_prefix}_join_rank2.rsck")).expect("Z join ckpt");
    assert_eq!(x_join.to_bytes(), z_join.to_bytes(), "corruption is repaired bit-identically");
    assert_eq!(z.ranks[0].param_hash, x.ranks[0].param_hash, "the clean finish is unchanged");
}
