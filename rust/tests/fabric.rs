//! The fabric matrix: Unix-socket and mixed link-class fabrics against
//! TCP and the in-process fabric.
//!
//! Every fabric frames messages identically, so each test runs the same
//! collective schedule — sequential and mux-multiplexed, flat and
//! hierarchical — over several fabrics and holds results bit-identical
//! and the summed `TrafficStats` word-exact.  A watchdog turns would-be
//! deadlocks into failures.  Socket paths are namespaced per test
//! (pid + counter) so parallel tests never collide.

use redsync::collectives::transport::TrafficStats;
use redsync::collectives::{
    allgather, allreduce_mean, concat, hierarchical_allgather, LinkClass, LocalFabric, TagChannel,
    TagMux, Topology, Transport,
};
use redsync::net::{
    free_loopback_addr, socket_base, MixedFabric, MixedOptions, TcpOptions, TcpTransport,
    UnixOptions, UnixTransport,
};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

static NEXT_NS: AtomicU32 = AtomicU32::new(0);

/// Fresh socket-path namespace: unique per process *and* per call.
fn test_base() -> String {
    format!("/tmp/rs-fab-{}-{}", std::process::id(), NEXT_NS.fetch_add(1, Ordering::Relaxed))
}

/// Bootstrap a full TCP mesh on loopback; returned in rank order.
fn tcp_fabric(world: usize) -> Vec<TcpTransport> {
    let addr = free_loopback_addr();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let opts = TcpOptions::new(world, rank, addr.clone());
            thread::spawn(move || TcpTransport::connect(&opts).expect("tcp bootstrap"))
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Bootstrap a full AF_UNIX mesh under a fresh namespace.
fn unix_fabric(world: usize) -> Vec<UnixTransport> {
    let base = test_base();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let opts = UnixOptions::new(world, rank, base.clone());
            thread::spawn(move || UnixTransport::connect(&opts).expect("unix bootstrap"))
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Bootstrap a mixed fabric: Unix for same-node pairs, TCP across
/// "nodes" (all on this host — the link-class split is what's under
/// test, not actual placement).
fn mixed_fabric(topo: Topology) -> Vec<MixedFabric> {
    let world = topo.world();
    let addr = free_loopback_addr();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let opts = MixedOptions::new(world, rank, addr.clone(), topo);
            thread::spawn(move || MixedFabric::connect(&opts).expect("mixed bootstrap"))
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Run `f` once per rank on its own thread.  Panics (instead of hanging)
/// if any rank is still blocked after 60s — the deadlock watchdog.
fn run_ranks<T, F, R>(transports: Vec<T>, f: F) -> Vec<R>
where
    T: Transport + Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
    R: Send + 'static,
{
    let f = Arc::new(f);
    let (done_tx, done_rx) = channel();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            thread::spawn(move || {
                let r = f(t);
                let _ = done.send(());
                r
            })
        })
        .collect();
    drop(done_tx);
    for _ in 0..handles.len() {
        done_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("a rank did not finish within 60s (deadlock or crash)");
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// The engine × algorithm matrix the coordinator can drive, as raw
/// collectives: sequential and mux-multiplexed engines, flat and
/// hierarchical schedules, plus a dense allreduce.  Returns every result
/// word so the comparison is bit-exact, never float-approximate.
fn engine_matrix<T: Transport + Sync>(t: &T, topo: Topology) -> Vec<u32> {
    let mut out = Vec::new();
    let msg: Vec<u32> = (0..t.rank() + 3).map(|i| (t.rank() * 1000 + i) as u32).collect();
    // sequential × flat
    out.extend(concat(allgather(t, msg.clone())));
    // sequential × hierarchical
    for blob in hierarchical_allgather(t, topo, msg.clone()) {
        out.extend(blob);
    }
    // dense allreduce bits
    let mut x: Vec<f32> =
        (0..257).map(|i| (t.rank() + 1) as f32 * (i as f32 + 0.5) * 0.1).collect();
    allreduce_mean(t, &mut x);
    out.extend(x.iter().map(|v| v.to_bits()));
    // pipelined engine surrogate: the same flat + hierarchical schedules
    // through a mux bucket channel (every byte gains a tag word — on
    // every fabric equally)
    let mux = Arc::new(TagMux::new(t, 2));
    let chan = TagChannel::new(Arc::clone(&mux), 1);
    out.extend(concat(allgather(&chan, msg.clone())));
    for blob in hierarchical_allgather(&chan, topo, msg) {
        out.extend(blob);
    }
    out
}

/// Sum of per-endpoint traffic counters.
fn total_words(stats: &[Arc<TrafficStats>]) -> (u64, u64) {
    (
        stats.iter().map(|s| s.bytes() / 4).sum(),
        stats.iter().map(|s| s.message_count()).sum(),
    )
}

#[test]
fn engine_matrix_bitmatches_across_all_four_fabrics() {
    let world = 4;
    let topo = Topology::new(2, 2);

    let mut local = LocalFabric::new(world);
    let local_stats = Arc::clone(&local.stats);
    let want = run_ranks(local.take_all(), move |t| engine_matrix(&t, topo));
    let want_words = (local_stats.bytes() / 4, local_stats.message_count());

    let tcp = tcp_fabric(world);
    let tcp_stats: Vec<_> = tcp.iter().map(|t| Arc::clone(&t.stats)).collect();
    let got_tcp = run_ranks(tcp, move |t| engine_matrix(&t, topo));

    let unix = unix_fabric(world);
    let unix_stats: Vec<_> = unix.iter().map(|t| Arc::clone(&t.stats)).collect();
    let got_unix = run_ranks(unix, move |t| engine_matrix(&t, topo));

    let mixed = mixed_fabric(topo);
    let mixed_stats: Vec<_> = mixed.iter().map(|t| Arc::clone(&t.stats)).collect();
    let got_mixed = run_ranks(mixed, move |t| engine_matrix(&t, topo));

    for (name, got) in [("tcp", &got_tcp), ("unix", &got_unix), ("mixed", &got_mixed)] {
        for (rank, (w, g)) in want.iter().zip(got.iter()).enumerate() {
            assert_eq!(w, g, "rank {rank}: {name} fabric diverged from LocalFabric");
        }
    }
    // identical schedules move identical words: every fabric's summed
    // counters must equal the shared LocalFabric counter, word-exact
    for (name, stats) in
        [("tcp", &tcp_stats), ("unix", &unix_stats), ("mixed", &mixed_stats)]
    {
        assert_eq!(total_words(stats), want_words, "{name} traffic accounting differs");
    }
}

#[test]
fn multi_megabyte_exchange_over_unix() {
    // 1.5M words = 6 MB each way: far beyond one socket buffer, so this
    // exercises framing across partial reads/writes and the writer
    // thread's batching under backpressure.
    let n = 1_500_000usize;
    let unix = unix_fabric(2);
    let results = run_ranks(unix, move |t| {
        let peer = 1 - t.rank();
        let msg: Vec<u32> =
            (0..n as u32).map(|i| i.wrapping_mul(0x9E37_79B9) ^ t.rank() as u32).collect();
        t.exchange(peer, msg)
    });
    for (rank, got) in results.iter().enumerate() {
        let peer = (1 - rank) as u32;
        assert_eq!(got.len(), n);
        for (i, &w) in got.iter().enumerate() {
            assert_eq!(w, (i as u32).wrapping_mul(0x9E37_79B9) ^ peer, "word {i} corrupted");
        }
    }
}

#[test]
fn unbatched_writes_move_identical_bytes_with_more_syscalls() {
    // the REDSYNC_NO_WRITE_BATCH lever must change syscall counts only —
    // never results, never payload accounting
    let run = |batch: bool| {
        let base = test_base();
        let handles: Vec<_> = (0..2usize)
            .map(|rank| {
                let mut opts = UnixOptions::new(2, rank, base.clone());
                opts.batch = batch;
                thread::spawn(move || UnixTransport::connect(&opts).expect("unix bootstrap"))
            })
            .collect();
        let ts: Vec<UnixTransport> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let stats: Vec<_> = ts.iter().map(|t| Arc::clone(&t.stats)).collect();
        let links: Vec<_> = ts.iter().map(|t| t.link_stats()).collect();
        let results = run_ranks(ts, |t| {
            let peer = 1 - t.rank();
            // a burst of small frames: the batched writer can coalesce,
            // the unbatched one must not
            let mut got = Vec::new();
            for i in 0..64u32 {
                t.send(peer, vec![t.rank() as u32, i]);
            }
            for _ in 0..64 {
                got.extend(t.recv(peer));
            }
            got
        });
        let words: u64 = stats.iter().map(|s| s.bytes() / 4).sum();
        let writes: u64 = links
            .iter()
            .flat_map(|l| l.snapshot())
            .map(|lt| lt.writes)
            .sum();
        (results, words, writes)
    };
    let (batched, batched_words, batched_writes) = run(true);
    let (unbatched, unbatched_words, unbatched_writes) = run(false);
    assert_eq!(batched, unbatched, "batching changed the bits");
    assert_eq!(batched_words, unbatched_words, "batching changed payload accounting");
    assert!(
        batched_writes <= unbatched_writes,
        "batched {batched_writes} writes !<= unbatched {unbatched_writes}"
    );
}

#[test]
fn mixed_fabric_splits_link_classes_by_topology() {
    // 2 "nodes" × 2 ranks: {0,1} and {2,3} share a node
    let topo = Topology::new(2, 2);
    let mixed = mixed_fabric(topo);
    for t in &mixed {
        let rank = t.rank();
        for peer in 0..4usize {
            let want = if peer == rank {
                LinkClass::Mem
            } else if topo.same_node(rank, peer) {
                LinkClass::Unix
            } else {
                LinkClass::Tcp
            };
            assert_eq!(t.class_of(peer), want, "rank {rank} -> {peer}");
        }
    }
    // all-pairs exchange: per-rank link classes account for every byte
    let results = run_ranks(mixed, |t| {
        for peer in 0..4usize {
            let got = t.exchange(peer, vec![t.rank() as u32; 25]);
            assert_eq!(got, vec![peer as u32; 25]);
        }
        let lt = t.link_traffic();
        let class_bytes: u64 = lt.iter().map(|l| l.bytes).sum();
        (lt, class_bytes, t.stats.bytes())
    });
    for (rank, (lt, class_bytes, total_bytes)) in results.iter().enumerate() {
        assert_eq!(class_bytes, total_bytes, "rank {rank}: unclassified bytes");
        // 4 sends of 25 words each: 1 self (mem), 1 same-node (unix),
        // 2 cross-node (tcp)
        let by = |c: LinkClass| lt.iter().find(|l| l.class == c).expect("class present");
        assert_eq!((by(LinkClass::Mem).frames, by(LinkClass::Mem).bytes), (1, 100));
        assert_eq!((by(LinkClass::Unix).frames, by(LinkClass::Unix).bytes), (1, 100));
        assert_eq!((by(LinkClass::Tcp).frames, by(LinkClass::Tcp).bytes), (2, 200));
        assert_eq!(by(LinkClass::Mem).writes, 0, "mem links never enter the kernel");
    }
}

#[test]
fn socket_files_are_gone_after_fabric_teardown() {
    let base = test_base();
    {
        let handles: Vec<_> = (0..3usize)
            .map(|rank| {
                let opts = UnixOptions::new(3, rank, base.clone());
                thread::spawn(move || UnixTransport::connect(&opts).expect("unix bootstrap"))
            })
            .collect();
        let ts: Vec<UnixTransport> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        drop(ts);
    }
    let sb = socket_base(&base);
    assert_eq!(sb, base, "a path-like rendezvous is used verbatim");
    for suffix in [".rdv", ".r1", ".r2"] {
        let path = format!("{base}{suffix}");
        assert!(
            !std::path::Path::new(&path).exists(),
            "{path} left behind after bootstrap + teardown"
        );
    }
}

#[test]
fn overlong_rendezvous_path_fails_fast_with_counsel() {
    let base = format!("/tmp/{}", "x".repeat(120));
    let err = UnixTransport::connect(&UnixOptions::new(2, 0, base)).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("sockaddr_un"), "want the why: {msg}");
    assert!(msg.contains("--rendezvous"), "want the fix: {msg}");
}
