//! End-to-end pin for the Chrome trace exporter: rings → drain →
//! `write_chrome_trace` → parse the file back with `util::json` and
//! check the structural invariants a Perfetto load relies on
//! (metadata first, X events time-sorted and zero-anchored, rank→pid /
//! lane→tid mapping, step/tag args, spans nested inside their step).

use redsync::obs::{self, LaneDump, RankDump, Span};
use redsync::util::json::Value;

/// A deterministic two-rank timeline shaped like one pipelined step:
/// the main lane's `step` span encloses two comm lanes whose
/// select/pack/allgather intervals overlap each other.
fn synthetic_dumps() -> Vec<RankDump> {
    let base = 10_000u64; // non-zero so the exporter's normalization is visible
    let span = |phase, step, tag, t0: u64, t1: u64| Span {
        phase,
        step,
        tag,
        t0_us: base + t0,
        t1_us: base + t1,
    };
    vec![
        RankDump {
            rank: 0,
            lanes: vec![
                LaneDump {
                    lane: obs::LANE_MAIN,
                    dropped: 0,
                    spans: vec![span(obs::SPAN_STEP, 3, 0, 0, 1_000)],
                },
                LaneDump {
                    lane: obs::LANE_COMM_BASE,
                    dropped: 0,
                    spans: vec![
                        span(obs::SPAN_SELECT, 3, 0, 100, 300),
                        span(obs::SPAN_PACK, 3, 0, 300, 380),
                        span(obs::SPAN_COMM_SPARSE, 3, 0, 380, 900),
                    ],
                },
                LaneDump {
                    lane: obs::LANE_COMM_BASE + 1,
                    dropped: 0,
                    spans: vec![
                        span(obs::SPAN_SELECT, 3, 1, 150, 420),
                        span(obs::SPAN_COMM_SPARSE, 3, 1, 430, 950),
                    ],
                },
            ],
        },
        RankDump {
            rank: 1,
            lanes: vec![LaneDump {
                lane: obs::LANE_MAIN,
                dropped: 0,
                spans: vec![span(obs::SPAN_STEP, 3, 0, 40, 1_020)],
            }],
        },
    ]
}

fn num(v: &Value, key: &str) -> f64 {
    v.at(&[key]).and_then(|x| x.as_f64()).unwrap_or_else(|| panic!("missing {key}"))
}

fn name(v: &Value) -> &str {
    v.at(&["name"]).and_then(|x| x.as_str()).unwrap_or("")
}

#[test]
fn trace_export_roundtrips_through_json() {
    let dumps = synthetic_dumps();
    assert_eq!(obs::span_count(&dumps), 6);

    let path = std::env::temp_dir().join("redsync_obs_trace_roundtrip.json");
    let path = path.to_str().expect("utf-8 temp path");
    obs::write_chrome_trace(path, &dumps).expect("trace write");
    let text = std::fs::read_to_string(path).expect("trace readback");
    let _ = std::fs::remove_file(path);
    let doc = Value::parse(&text).expect("exported trace must be valid JSON");

    assert_eq!(
        doc.at(&["displayTimeUnit"]).and_then(|v| v.as_str()),
        Some("ms"),
        "display unit tag"
    );
    let events = doc.at(&["traceEvents"]).and_then(|v| v.as_arr()).expect("traceEvents array");

    // metadata strictly precedes every X event
    let first_x = events
        .iter()
        .position(|e| e.at(&["ph"]).and_then(|p| p.as_str()) == Some("X"))
        .expect("at least one X event");
    for (i, e) in events.iter().enumerate() {
        let ph = e.at(&["ph"]).and_then(|p| p.as_str()).unwrap();
        if i < first_x {
            assert_eq!(ph, "M", "event {i} before the first X must be metadata");
        } else {
            assert_eq!(ph, "X", "event {i} after the first X must be a span");
        }
    }
    // 2 process_name + 4 thread_name metadata events
    assert_eq!(events.iter().filter(|e| name(e) == "process_name").count(), 2);
    assert_eq!(events.iter().filter(|e| name(e) == "thread_name").count(), 4);

    let xs: Vec<&Value> = events[first_x..].iter().collect();
    assert_eq!(xs.len(), 6, "one X event per span");

    // zero-anchored and time-sorted
    assert_eq!(num(xs[0], "ts"), 0.0, "earliest span anchors the timeline");
    let ts: Vec<f64> = xs.iter().map(|e| num(e, "ts")).collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "X events sorted by ts: {ts:?}");

    // rank -> pid, lane -> tid, phase -> name, step/tag -> args
    for e in &xs {
        let pid = num(e, "pid") as u32;
        let tid = num(e, "tid") as u32;
        assert!(pid <= 1, "pid is the rank");
        if pid == 1 {
            assert_eq!(tid, obs::LANE_MAIN, "rank 1 only recorded on main");
        }
        assert_eq!(num(e.at(&["args"]).unwrap(), "step") as u32, 3);
    }
    let comm: Vec<&&Value> = xs.iter().filter(|e| name(e) == "comm_sparse").collect();
    assert_eq!(comm.len(), 2);
    let tags: Vec<u32> =
        comm.iter().map(|e| num(e.at(&["args"]).unwrap(), "tag") as u32).collect();
    assert_eq!(tags, vec![0, 1], "bucket tags survive export");

    // nesting: every rank-0 comm-lane span lies inside rank 0's step span
    let step0 = xs
        .iter()
        .find(|e| name(e) == "step" && num(e, "pid") == 0.0)
        .expect("rank 0 step span");
    let (s0, s1) = (num(step0, "ts"), num(step0, "ts") + num(step0, "dur"));
    for e in xs.iter().filter(|e| num(e, "pid") == 0.0 && num(e, "tid") > 0.0) {
        let (t0, t1) = (num(e, "ts"), num(e, "ts") + num(e, "dur"));
        assert!(s0 <= t0 && t1 <= s1, "{} [{t0},{t1}] outside step [{s0},{s1}]", name(e));
    }
    // and the two comm lanes genuinely overlap each other
    let (a0, a1) = (num(comm[0], "ts"), num(comm[0], "ts") + num(comm[0], "dur"));
    let (b0, b1) = (num(comm[1], "ts"), num(comm[1], "ts") + num(comm[1], "dur"));
    assert!(a0 < b1 && b0 < a1, "comm lanes must overlap: [{a0},{a1}] vs [{b0},{b1}]");
}

#[test]
fn guards_feed_registered_rings_end_to_end() {
    obs::set_enabled(true);
    // rank id 7: private to this test, so drain_rank cannot race other
    // tests in this binary
    let ring = obs::ring(7, obs::LANE_MAIN, 16);
    {
        let _g = ring.guard(obs::SPAN_COMPUTE, 5, 2);
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    obs::set_enabled(false);
    let lanes = obs::drain_rank(7);
    assert_eq!(lanes.len(), 1);
    assert_eq!(lanes[0].lane, obs::LANE_MAIN);
    assert_eq!(lanes[0].spans.len(), 1);
    let s = &lanes[0].spans[0];
    assert_eq!((s.phase, s.step, s.tag), (obs::SPAN_COMPUTE, 5, 2));
    assert!(s.t1_us > s.t0_us, "guard records a positive interval");
    assert!(obs::drain_rank(7).is_empty(), "drain deregisters the ring");

    // the drained guard span exports cleanly too
    let doc = obs::chrome_trace(&[RankDump { rank: 7, lanes: vec![lanes[0].clone()] }]);
    let events = doc.at(&["traceEvents"]).and_then(|v| v.as_arr()).unwrap();
    assert!(events.iter().any(|e| name(e) == "compute"));
}
