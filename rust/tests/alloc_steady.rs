//! Steady-state allocation pin for the zero-copy hot path
//! (DESIGN.md §Zero-Copy-Hot-Path).
//!
//! A counting `GlobalAlloc` wraps the system allocator; after a warm-up
//! phase (scratch buffers grown, threshold caches primed), one sync
//! step's allocation COUNT must be
//!
//! * independent of k — quadrupling the selection density must not add
//!   a single allocation: selection, packing and the apply walk run
//!   entirely in reused buffers and borrowed views, so no per-element
//!   (O(k) or O(p·k)) allocation survives anywhere on the path;
//! * O(buckets) small bookkeeping at world 1 (timer strings, the
//!   gather buffer, the `BucketDone` layer list), with only
//!   O(messages) = O(buckets·lg p) fabric bookkeeping on top at p > 1.
//!
//! The counter counts `alloc`/`realloc` calls, not bytes: a `Vec` that
//! reuses its capacity is free, which is exactly the property under
//! test.

use redsync::collectives::LocalFabric;
use redsync::compression::{Accumulation, CompressorConfig, Method};
use redsync::pipeline::{build_buckets, BucketDone, LayerSpec, Sequential, SyncEngine};
use redsync::util::rng::Pcg32;
use redsync::util::timer::PhaseTimer;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// The two tests share the global counter; libtest runs tests on
/// parallel threads, so they serialize on this lock.
static TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Layer mix covering every host selection path: sampled binary search
/// (large layer), trimmed top-k, exact top-k, and a quantized layer.
const SIZES: &[usize] = &[40_000, 9_000, 9_000, 12_000];
const FUSION_CAP: usize = 20_000;
const WARMUP: usize = 10;
const MEASURED: usize = 10;

fn specs() -> Vec<LayerSpec> {
    vec![
        LayerSpec { li: 0, n: SIZES[0], method: Method::SampledBinarySearch, quantize: false },
        LayerSpec { li: 1, n: SIZES[1], method: Method::TrimmedTopk, quantize: true },
        LayerSpec { li: 2, n: SIZES[2], method: Method::ExactTopk, quantize: false },
        LayerSpec { li: 3, n: SIZES[3], method: Method::TrimmedTopk, quantize: false },
    ]
}

fn fixed_grads() -> Vec<Vec<f32>> {
    SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let mut rng = Pcg32::seeded(0xA110C ^ i as u64);
            let mut g = vec![0f32; n];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect()
}

/// Run `steps` single-rank sync steps; returns allocation counts
/// sampled after `WARMUP` steps and at the end.
fn run_single_rank(density: f64, steps: usize) -> (usize, usize, usize) {
    let specs = specs();
    let buckets = build_buckets(&specs, FUSION_CAP, Accumulation::Momentum { momentum: 0.9 });
    let n_buckets = buckets.len();
    let cc = CompressorConfig { density, ..Default::default() };
    let mut fabric = LocalFabric::new(1);
    let t = fabric.take(0);
    let mut engine = Sequential::new(&t, None, buckets, cc);
    let mut params: Vec<Vec<f32>> = SIZES.iter().map(|&n| vec![0f32; n]).collect();
    let grads = fixed_grads();
    let mut timer = PhaseTimer::new();
    let mut after_warmup = 0usize;
    for step in 0..steps {
        if step == WARMUP {
            after_warmup = allocs();
        }
        engine
            .sync_step(&grads, density, &mut timer, &mut |done: BucketDone| {
                done.apply_to(&mut params, -0.01)
            })
            .expect("sync step");
    }
    (n_buckets, after_warmup, allocs())
}

#[test]
fn steady_state_step_allocations_are_independent_of_k() {
    let _serial = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // same engine, same steps, 4x the density (4x k per layer): the
    // per-step allocation count must not move at all — any per-element
    // allocation on the produce/pack/apply path would scale with k and
    // fail this exactly-equal pin
    let (buckets, a0, a1) = run_single_rank(0.004, WARMUP + MEASURED);
    let per_step_lo = (a1 - a0) / MEASURED;
    let (_, b0, b1) = run_single_rank(0.016, WARMUP + MEASURED);
    let per_step_hi = (b1 - b0) / MEASURED;
    // slack 4 absorbs the occasional capacity-doubling realloc when a
    // threshold-search step selects more than any warm-up step did;
    // anything O(k) would shift the count by hundreds
    assert!(
        per_step_lo.abs_diff(per_step_hi) <= 4,
        "steady-state allocations scale with k: {per_step_lo} at k vs {per_step_hi} at 4k"
    );
    // O(buckets) bookkeeping: timer phase strings, the gather buffer,
    // the BucketDone layer list — nothing per element, nothing per rank
    assert!(
        per_step_lo <= 40 * buckets,
        "steady-state step allocates {per_step_lo} times for {buckets} buckets"
    );
}

/// Tracing on must not add steady-state allocations: the span ring is
/// pre-allocated once at engine construction and `record`/guard drops
/// write into it in place, so the traced per-step count must sit within
/// the same realloc slack as the disabled baseline.
#[test]
fn tracing_enabled_steady_state_allocates_like_disabled() {
    let _serial = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (_, a0, a1) = run_single_rank(0.004, WARMUP + MEASURED);
    let base = (a1 - a0) / MEASURED;

    redsync::obs::set_enabled(true);
    let (_, b0, b1) = run_single_rank(0.004, WARMUP + MEASURED);
    redsync::obs::set_enabled(false);
    let traced = (b1 - b0) / MEASURED;

    // the traced engine registered a ring under rank 0 and filled it;
    // drain deregisters it so later tests see a clean registry
    let dumps = redsync::obs::drain_rank(0);
    assert!(
        dumps.iter().any(|d| !d.spans.is_empty()),
        "the traced run must have recorded spans"
    );

    assert!(
        traced.abs_diff(base) <= 4,
        "tracing adds steady-state allocations: {base} disabled vs {traced} enabled"
    );
}

/// 4-rank in-process fabric: the collective's own bookkeeping joins the
/// count (pack/unpack block lists, channel nodes), all O(messages) —
/// still independent of k.  Measured differentially (short run vs long
/// run, same seeds) so thread/fabric setup cancels out.
#[test]
fn multi_rank_step_allocations_are_independent_of_k() {
    let _serial = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());

    fn run_world(density: f64, steps: usize) -> usize {
        let mut fabric = LocalFabric::new(4);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                std::thread::spawn(move || {
                    let specs = specs();
                    let buckets =
                        build_buckets(&specs, FUSION_CAP, Accumulation::Momentum { momentum: 0.9 });
                    // timing disabled: the produce loop must skip every
                    // clock read (the PhaseClock enabled-check path)
                    let cc = CompressorConfig { density, timing: false, ..Default::default() };
                    let mut engine = Sequential::new(&t, None, buckets, cc);
                    let mut params: Vec<Vec<f32>> =
                        SIZES.iter().map(|&n| vec![0f32; n]).collect();
                    let grads = fixed_grads();
                    let mut timer = PhaseTimer::new();
                    for _ in 0..steps {
                        engine
                            .sync_step(&grads, density, &mut timer, &mut |done: BucketDone| {
                                done.apply_to(&mut params, -0.01)
                            })
                            .expect("sync step");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        allocs()
    }

    let extra = 8; // differential: extra steps beyond the base run
    for density in [0.004f64, 0.016] {
        let t0 = allocs();
        let t1 = run_world(density, WARMUP);
        let t2 = run_world(density, WARMUP + extra);
        // (t2 - t1) - (t1 - t0) = extra steps' worth of allocations
        let base = t1 - t0;
        let long = t2 - t1;
        let per_step = (long.saturating_sub(base)) / extra;
        // 4 ranks x O(buckets · lg p) messages + O(buckets) bookkeeping
        // per rank; k never enters
        assert!(
            per_step <= 4 * 80 * 3,
            "density {density}: {per_step} allocations per steady step across 4 ranks"
        );
    }
}
