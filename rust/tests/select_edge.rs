//! Degenerate-gradient edge cases through every selector, plus the
//! scalar↔SIMD kernel parity net.
//!
//! The NaN policy under test (select.rs module docs): NaN keys sort
//! last under a total order and are never selected while finite
//! candidates remain; threshold compares are IEEE ordered `>` so NaN
//! never passes them, identically on the scalar oracle and the SSE2/
//! AVX2 backends.  A single NaN/Inf gradient element — or an all-zero,
//! constant, or length-1 layer — must never panic a selector, on any
//! backend, and a full LocalFabric run over salted gradients must keep
//! replicas bit-identical.

use redsync::collectives::{LocalFabric, Transport};
use redsync::compression::simd::{self, Backend};
use redsync::compression::{
    exact_topk, threshold_binary_search, trimmed_topk, Accumulation, BinarySearchParams,
    CachedThresholdSelector, CompressorConfig, Method, Selection,
};
use redsync::coordinator::metrics::param_hash;
use redsync::pipeline::{build_buckets, BucketDone, LayerSpec, Sequential, SyncEngine};
use redsync::tensor::SparseTensor;
use redsync::util::proptest::{check, ensure};
use redsync::util::rng::Pcg32;
use redsync::util::timer::PhaseTimer;
use std::thread;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Pcg32::seeded(seed);
    let mut v = vec![0f32; n];
    r.fill_normal(&mut v, 1.0);
    v
}

/// Every degenerate input class the satellite names: NaN, Inf, all-zero,
/// length-1 — plus the constant/−0.0/all-NaN corners around them.
fn degenerate_inputs() -> Vec<(&'static str, Vec<f32>)> {
    let base = randn(513, 42);
    let mut nan_salted = base.clone();
    nan_salted[7] = f32::NAN;
    nan_salted[500] = -f32::NAN;
    let mut inf_salted = base.clone();
    inf_salted[3] = f32::INFINITY;
    inf_salted[200] = f32::NEG_INFINITY;
    let mut nan_heavy = base.clone();
    for (i, v) in nan_heavy.iter_mut().enumerate().take(120) {
        if i % 3 == 0 {
            *v = f32::NAN;
        }
    }
    vec![
        ("nan_salted", nan_salted),
        ("inf_salted", inf_salted),
        ("nan_heavy", nan_heavy),
        ("all_zero", vec![0.0; 257]),
        ("neg_zero", vec![-0.0; 64]),
        ("all_nan", vec![f32::NAN; 129]),
        ("constant", vec![1.0; 300]),
        ("len1_finite", vec![2.5]),
        ("len1_zero", vec![0.0]),
        ("len1_nan", vec![f32::NAN]),
    ]
}

/// NaN is only ever selected when fewer non-NaN candidates than k exist
/// (and the k >= n pass-through, which returns the layer verbatim).
fn assert_nan_policy(name: &str, x: &[f32], k: usize, sel: &SparseTensor) {
    let non_nan = x.iter().filter(|v| !v.is_nan()).count();
    if k < x.len() && k <= non_nan {
        assert!(
            sel.values.iter().all(|v| !v.is_nan()),
            "{name}: NaN selected with k={k}, {non_nan} finite-capable candidates"
        );
    }
}

#[test]
fn degenerate_gradients_never_panic_any_selector() {
    for (name, x) in degenerate_inputs() {
        let n = x.len();
        let ks = [0usize, 1, 7, n / 2, n, n + 5];
        for &k in &ks {
            for sign in [None, Some(1.0f32), Some(-1.0f32)] {
                let runs: [(&str, Selection); 3] = [
                    ("exact", exact_topk(&x, k, sign)),
                    ("trimmed", trimmed_topk(&x, k, 0.2, sign)),
                    (
                        "binary_search",
                        threshold_binary_search(&x, k, BinarySearchParams::default(), sign),
                    ),
                ];
                for (which, sel) in &runs {
                    let len = sel.sparse.len();
                    assert!(len <= n, "{name}/{which}: selected {len} > n={n}");
                    if k == 0 {
                        assert_eq!(len, 0, "{name}/{which}: k=0 must select nothing");
                    } else {
                        assert!(
                            len >= k.min(n) || *which == "binary_search",
                            "{name}/{which}: selected {len} < k.min(n)={}",
                            k.min(n)
                        );
                    }
                    assert!(
                        sel.sparse.indices.windows(2).all(|w| w[0] < w[1]),
                        "{name}/{which}: indices not strictly ascending"
                    );
                    assert_nan_policy(&format!("{name}/{which}"), &x, k, &sel.sparse);
                }
                // binary search also guarantees >= k.min(n) — its fallback
                // is the exact selector, which is total
                let bs = &runs[2].1;
                if k > 0 {
                    assert!(
                        bs.sparse.len() >= k.min(n),
                        "{name}/binary_search: {} < {}",
                        bs.sparse.len(),
                        k.min(n)
                    );
                }
            }
        }
    }
}

#[test]
fn cached_selector_survives_degenerate_sequences() {
    // cold cache, zero layers, NaN poisoning, then recovery — the
    // elastic-reshape reset path plus every drift re-search in sequence
    let mut sel = CachedThresholdSelector::new(3, BinarySearchParams::default());
    let normal = randn(2048, 7);
    let zeros = vec![0f32; 2048];
    let mut poisoned = normal.clone();
    for (i, v) in poisoned.iter_mut().enumerate() {
        if i % 5 == 0 {
            *v = f32::NAN;
        }
    }
    let k = 32;
    for (round, x) in
        [&normal, &zeros, &poisoned, &normal, &zeros, &zeros, &poisoned, &normal]
            .iter()
            .enumerate()
    {
        let searched = sel.will_search();
        let out = sel.select(x, k, None);
        let len = out.sparse.len();
        if searched {
            // a full search delivers at least k, even on zeros/NaN (the
            // degenerate-stats exact fallback)
            assert!(len >= k, "round {round} (search): selected {len} < k={k}");
        } else {
            // warm reuse may under-deliver on a drifted distribution, but
            // the drift guard re-searches on empty or > 4k compactions
            assert!((1..=4 * k).contains(&len), "round {round} (warm): {len} out of [1,4k]");
        }
        assert_nan_policy(&format!("cached round {round}"), x, k, &out.sparse);
    }
    // a reset mid-stream (what an elastic reshape does) leaves no stale
    // threshold behind: the next call searches and still delivers
    sel.reset();
    assert!(sel.will_search());
    let out = sel.select(&zeros, k, None);
    assert_eq!(out.sparse.len(), k, "cold cache on zeros must exact-fallback");
}

#[test]
fn selectors_identical_under_forced_scalar_knob() {
    // REDSYNC_NO_SIMD only influences detection, not semantics: detect()
    // honors the knob, and the active backend's selector output equals
    // the explicit scalar kernels' on every degenerate input (the
    // process-wide bit-parity this knob exists to let CI A/B).
    std::env::set_var("REDSYNC_NO_SIMD", "1");
    assert_eq!(Backend::detect(), Backend::Scalar);
    std::env::remove_var("REDSYNC_NO_SIMD");
    for (name, x) in degenerate_inputs() {
        let mut via_active = SparseTensor::default();
        let mut via_scalar = SparseTensor::default();
        for thr in [0.0f32, 0.5, f32::NAN] {
            via_active.clear();
            simd::compact_gt_abs(simd::active(), &x, thr, &mut via_active);
            via_scalar.clear();
            simd::compact_gt_abs(Backend::Scalar, &x, thr, &mut via_scalar);
            assert_eq!(via_active.indices, via_scalar.indices, "{name} thr {thr}");
            assert!(
                via_active
                    .values
                    .iter()
                    .zip(&via_scalar.values)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "{name} thr {thr}: values diverge from scalar oracle"
            );
        }
    }
}

/// The dedicated scalar↔SIMD parity proptest: every kernel, every
/// hardware backend, random data salted with specials, bit-for-bit.
#[test]
fn prop_kernel_backends_bit_identical() {
    let backends = simd::available();
    check(40, |g| {
        let n = g.size(1..3000);
        let mut x = g.vec_normal(n, 1.5);
        for _ in 0..g.size(0..10) {
            let at = g.size(0..n);
            x[at] = match g.size(0..7) {
                0 => f32::NAN,
                1 => -f32::NAN,
                2 => f32::INFINITY,
                3 => f32::NEG_INFINITY,
                4 => -0.0,
                5 => 1e-42, // denormal
                _ => f32::MAX,
            };
        }
        let thr = match g.size(0..4) {
            0 => 0.0,
            1 => x[g.size(0..n)].abs(),
            2 => g.f32(0.0..2.0),
            _ => f32::NAN,
        };
        let sign = if g.bool() { 1.0f32 } else { -1.0 };

        let mut oracle = SparseTensor::default();
        simd::compact_gt_abs(Backend::Scalar, &x, thr, &mut oracle);
        let want_abs = simd::count_gt_abs(Backend::Scalar, &x, thr);
        let want_sgn = simd::count_gt_signed(Backend::Scalar, &x, thr, sign);
        let mut packed_oracle = Vec::new();
        simd::extend_value_bits(Backend::Scalar, &x, &mut packed_oracle);
        let mut keys_oracle = vec![0f32; n];
        simd::abs_keys(Backend::Scalar, &x, &mut keys_oracle);

        for &b in &backends {
            let mut got = SparseTensor::default();
            simd::compact_gt_abs(b, &x, thr, &mut got);
            ensure(got.indices == oracle.indices, format!("{b:?}: compact indices"))?;
            ensure(
                got.values.iter().zip(&oracle.values).all(|(a, c)| a.to_bits() == c.to_bits()),
                format!("{b:?}: compact values"),
            )?;
            ensure(simd::count_gt_abs(b, &x, thr) == want_abs, format!("{b:?}: count abs"))?;
            ensure(
                simd::count_gt_signed(b, &x, thr, sign) == want_sgn,
                format!("{b:?}: count signed"),
            )?;
            let mut packed = Vec::new();
            simd::extend_value_bits(b, &x, &mut packed);
            ensure(packed == packed_oracle, format!("{b:?}: packed value bits"))?;
            let mut keys = vec![0f32; n];
            simd::abs_keys(b, &x, &mut keys);
            ensure(
                keys.iter().zip(&keys_oracle).all(|(a, c)| a.to_bits() == c.to_bits()),
                format!("{b:?}: abs keys"),
            )?;
        }

        // scatter-add: ascending unique indices into a dense buffer, the
        // §5.4 apply walk
        let dim = n + g.size(1..64);
        let mut indices: Vec<u32> = (0..n as u32).collect();
        g.rng().shuffle(&mut indices);
        indices.truncate(g.size(1..n.max(2)));
        indices.sort_unstable();
        let bits: Vec<u32> = x[..indices.len()].iter().map(|v| v.to_bits()).collect();
        let init = g.vec_normal(dim, 0.5);
        let scale = g.f32(-1.0..1.0);
        let mut dense_oracle = init.clone();
        simd::scatter_add_bits(Backend::Scalar, &indices, &bits, &mut dense_oracle, scale);
        for &b in &backends {
            let mut dense = init.clone();
            simd::scatter_add_bits(b, &indices, &bits, &mut dense, scale);
            ensure(
                dense.iter().zip(&dense_oracle).all(|(a, c)| a.to_bits() == c.to_bits()),
                format!("{b:?}: scatter bits"),
            )?;
        }
        Ok(())
    });
}

// ------------------------------------------------------------------
// LocalFabric end-to-end: salted gradients through both engines' math
// ------------------------------------------------------------------

const SIZES: &[usize] = &[2500, 600, 1, 1800];
const WORLD: usize = 2;
const STEPS: usize = 6;
const DENSITY: f64 = 0.02;

fn specs() -> Vec<LayerSpec> {
    SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| LayerSpec {
            li: i,
            n,
            method: if n >= 1500 { Method::SampledBinarySearch } else { Method::TrimmedTopk },
            quantize: i % 2 == 0,
        })
        .collect()
}

/// Deterministic gradient, salted with the edge cases: NaN on a
/// quantized trimmed layer, ±Inf on a binary-search layer, a length-1
/// layer that only ever sees zero, and one fully zero step.
fn salted_grad(rank: usize, step: usize, li: usize, n: usize) -> Vec<f32> {
    if li == 2 {
        return vec![0.0; n]; // the length-1 all-zero layer
    }
    if step == 3 {
        return vec![0.0; n]; // an all-zero step for every layer
    }
    let mut rng = Pcg32::seeded(((rank as u64) << 32) ^ ((step as u64) << 8) ^ li as u64);
    let mut g = vec![0f32; n];
    rng.fill_normal(&mut g, 1.0);
    if step == 1 && li == 0 {
        g[5] = f32::NAN;
        g[100] = -f32::NAN;
    }
    if step == 2 && li == 3 && rank == 0 {
        g[7] = f32::INFINITY;
        g[8] = f32::NEG_INFINITY;
    }
    g
}

fn run_salted<T: Transport>(t: &T) -> u64 {
    let buckets = build_buckets(&specs(), 3000, Accumulation::Momentum { momentum: 0.9 });
    let cfg = CompressorConfig { density: DENSITY, ..Default::default() };
    let mut engine = Sequential::new(t, None, buckets, cfg);
    let mut params: Vec<Vec<f32>> = SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| randn(n, 0xBEEF ^ i as u64))
        .collect();
    let scale = -0.05 / t.world() as f32;
    let mut timer = PhaseTimer::new();
    for step in 0..STEPS {
        let grads: Vec<Vec<f32>> =
            SIZES.iter().enumerate().map(|(i, &n)| salted_grad(t.rank(), step, i, n)).collect();
        engine
            .sync_step(&grads, DENSITY, &mut timer, &mut |done: BucketDone| {
                done.apply_to(&mut params, scale)
            })
            .unwrap_or_else(|e| panic!("rank {} step {step}: {e}", t.rank()));
    }
    param_hash(&params)
}

#[test]
fn salted_gradients_over_local_fabric_stay_bit_identical() {
    let mut local = LocalFabric::new(WORLD);
    let handles: Vec<_> = local
        .take_all()
        .into_iter()
        .map(|t| thread::spawn(move || run_salted(&t)))
        .collect();
    let hashes: Vec<u64> = handles.into_iter().map(|h| h.join().expect("rank panicked")).collect();
    assert!(
        hashes.iter().all(|&h| h == hashes[0]),
        "replicas diverged under salted gradients: {hashes:x?}"
    );
    // NaN must never leak into the synchronized parameters: every rank
    // applies only selected (non-NaN) values, so a NaN gradient stays in
    // the local residual and the hash above is a real equality, not
    // NaN-poisoned coincidence.  Re-run one rank solo to inspect params.
    let mut solo = LocalFabric::new(1);
    let t = solo.take_all().pop().unwrap();
    let buckets = build_buckets(&specs(), 3000, Accumulation::Momentum { momentum: 0.9 });
    let cfg = CompressorConfig { density: DENSITY, ..Default::default() };
    let mut engine = Sequential::new(&t, None, buckets, cfg);
    let mut params: Vec<Vec<f32>> =
        SIZES.iter().enumerate().map(|(i, &n)| randn(n, 0xBEEF ^ i as u64)).collect();
    let mut timer = PhaseTimer::new();
    for step in 0..STEPS {
        let grads: Vec<Vec<f32>> =
            SIZES.iter().enumerate().map(|(i, &n)| salted_grad(0, step, i, n)).collect();
        engine
            .sync_step(&grads, DENSITY, &mut timer, &mut |done: BucketDone| {
                done.apply_to(&mut params, -0.05)
            })
            .unwrap_or_else(|e| panic!("solo step {step}: {e}"));
    }
    for (li, p) in params.iter().enumerate() {
        assert!(
            p.iter().all(|v| !v.is_nan()),
            "layer {li}: NaN leaked into parameters through selection"
        );
    }
}
