//! Topology-aware communicator acceptance tests.
//!
//! * The 8-rank (2 nodes × 4 ranks) hierarchical schedule is
//!   **bit-identical** to the flat sparse-allgather schedule — on the
//!   in-process fabric and over real loopback TCP, on both sync
//!   engines.  The hierarchical path may only change *where* bytes
//!   travel, never the math.
//! * The hierarchical schedule's byte count is pinned word-for-word to
//!   the cost-model accounting (`costmodel::hierarchical_payload_words`
//!   + deterministic framing).
//! * The `auto` picker's per-bucket choices equal the cost model's
//!   argmin, with all three regimes (dense / sparse / hierarchical)
//!   represented.
//! * Group↔world rank translation round-trips (proptest).

use redsync::collectives::group::{Algo, ProcessGroup, Topology};
use redsync::collectives::transport::TrafficStats;
use redsync::collectives::{
    hierarchical_allgather, hierarchical_traffic_words, LocalFabric, TagMux, Transport,
};
use redsync::compression::{Accumulation, CompressorConfig, Method};
use redsync::coordinator::metrics::param_hash;
use redsync::costmodel;
use redsync::net::{free_loopback_addr, TcpOptions, TcpTransport};
use redsync::pipeline::{
    build_buckets, BucketDone, BucketState, LayerSpec, Pipelined, Sequential, SyncEngine,
    BUCKET_TAG_BASE,
};
use redsync::simnet::Machine;
use redsync::util::proptest::{check, ensure};
use redsync::util::rng::Pcg32;
use redsync::util::timer::PhaseTimer;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Synthetic model: greedy fusion (cap 3000) yields multiple buckets,
/// some multi-layer, mixing plain and quantized layers.
const SIZES: &[usize] = &[2500, 600, 600, 600, 1800, 900, 400, 2200];
const FUSION_CAP: usize = 3000;
const WORLD: usize = 8;
const TOPO: Topology = Topology { nodes: 2, ranks_per_node: 4 };
const STEPS: usize = 12;
const DENSITY: f64 = 0.02;
const LR: f32 = 0.05;

fn specs() -> Vec<LayerSpec> {
    SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| LayerSpec {
            li: i,
            n,
            method: if n >= 1500 { Method::SampledBinarySearch } else { Method::TrimmedTopk },
            quantize: i % 2 == 0,
        })
        .collect()
}

fn grad(rank: usize, step: usize, li: usize, n: usize) -> Vec<f32> {
    let mut rng = Pcg32::seeded(((rank as u64) << 32) ^ ((step as u64) << 8) ^ li as u64);
    let mut g = vec![0f32; n];
    rng.fill_normal(&mut g, 1.0);
    g
}

fn cc() -> CompressorConfig {
    CompressorConfig { density: DENSITY, ..Default::default() }
}

fn acc() -> Accumulation {
    Accumulation::Momentum { momentum: 0.9 }
}

fn make_buckets(algo: Algo) -> Vec<BucketState> {
    let mut buckets = build_buckets(&specs(), FUSION_CAP, acc());
    for b in &mut buckets {
        b.set_algo(algo);
    }
    buckets
}

fn run_steps(engine: &mut dyn SyncEngine, rank: usize, world: usize) -> u64 {
    let mut params: Vec<Vec<f32>> = SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let mut rng = Pcg32::seeded(0xBEEF ^ i as u64); // identical on every rank
            let mut p = vec![0f32; n];
            rng.fill_normal(&mut p, 0.5);
            p
        })
        .collect();
    let scale = -LR / world as f32;
    let mut timer = PhaseTimer::new();
    for step in 0..STEPS {
        let grads: Vec<Vec<f32>> =
            SIZES.iter().enumerate().map(|(i, &n)| grad(rank, step, i, n)).collect();
        engine
            .sync_step(&grads, DENSITY, &mut timer, &mut |done: BucketDone| {
                done.apply_to(&mut params, scale)
            })
            .unwrap_or_else(|e| panic!("rank {rank} step {step}: {e}"));
    }
    param_hash(&params)
}

fn run_sequential<T: Transport>(t: &T, algo: Algo) -> u64 {
    let mut engine = Sequential::with_topology(t, TOPO, None, make_buckets(algo), cc());
    run_steps(&mut engine, t.rank(), t.world())
}

fn run_pipelined<T: Transport + Send + Sync>(t: T, algo: Algo) -> u64 {
    let (rank, world) = (t.rank(), t.world());
    let buckets = make_buckets(algo);
    let n = buckets.len() as u32;
    let mux = Arc::new(TagMux::new(t, BUCKET_TAG_BASE + n));
    let mut engine = Pipelined::with_topology(mux, TOPO, buckets, 3, cc());
    run_steps(&mut engine, rank, world)
}

/// One thread per rank, with a deadlock watchdog.
fn run_ranks<T, F>(transports: Vec<T>, f: F) -> Vec<u64>
where
    T: Transport + Send + 'static,
    F: Fn(T) -> u64 + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let (done_tx, done_rx) = channel();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            thread::spawn(move || {
                let r = f(t);
                let _ = done.send(());
                r
            })
        })
        .collect();
    drop(done_tx);
    for _ in 0..handles.len() {
        done_rx
            .recv_timeout(Duration::from_secs(120))
            .expect("a rank did not finish within 120s (deadlock or crash)");
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn tcp_fabric(world: usize) -> Vec<TcpTransport> {
    let addr = free_loopback_addr();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let addr = addr.clone();
            thread::spawn(move || {
                TcpTransport::connect(&TcpOptions::new(world, rank, addr)).expect("tcp bootstrap")
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn all_equal(hashes: &[u64]) -> bool {
    hashes.iter().all(|&h| h == hashes[0])
}

#[test]
fn hierarchical_bit_identical_to_flat_on_local_fabric() {
    let mut local = LocalFabric::new(WORLD);
    let flat = run_ranks(local.take_all(), |t| run_sequential(&t, Algo::Sparse));
    assert!(all_equal(&flat), "flat replicas drifted: {flat:x?}");

    let mut local = LocalFabric::new(WORLD);
    let hier = run_ranks(local.take_all(), |t| run_sequential(&t, Algo::Hierarchical));
    assert!(all_equal(&hier), "hierarchical replicas drifted: {hier:x?}");
    assert_eq!(flat[0], hier[0], "hierarchical schedule changed the math");

    // and through the pipelined engine's per-bucket tag channels
    let mut local = LocalFabric::new(WORLD);
    let piped = run_ranks(local.take_all(), |t| run_pipelined(t, Algo::Hierarchical));
    assert!(all_equal(&piped), "pipelined hierarchical replicas drifted: {piped:x?}");
    assert_eq!(flat[0], piped[0], "pipelined hierarchical diverged from the oracle");
}

#[test]
fn hierarchical_bit_identical_to_flat_over_tcp_loopback() {
    let flat = run_ranks(tcp_fabric(WORLD), |t| run_sequential(&t, Algo::Sparse));
    assert!(all_equal(&flat), "flat replicas drifted over tcp: {flat:x?}");

    let hier = run_ranks(tcp_fabric(WORLD), |t| run_sequential(&t, Algo::Hierarchical));
    assert!(all_equal(&hier), "hierarchical replicas drifted over tcp: {hier:x?}");
    assert_eq!(flat[0], hier[0], "hierarchical diverged over tcp");

    // the TCP schedule agrees with the in-process fabric bit-for-bit
    let mut local = LocalFabric::new(WORLD);
    let local_hier = run_ranks(local.take_all(), |t| run_sequential(&t, Algo::Hierarchical));
    assert_eq!(local_hier[0], hier[0], "fabrics diverged under the hierarchical schedule");
}

#[test]
fn hierarchical_traffic_matches_cost_model_term() {
    // uniform per-rank message: the fabric counters must equal the
    // cost-model payload term plus the deterministic block framing
    let m_words = 200usize;
    let mut fabric = LocalFabric::new(WORLD);
    let stats: Arc<TrafficStats> = Arc::clone(&fabric.stats);
    let handles: Vec<_> = fabric
        .take_all()
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let gathered = hierarchical_allgather(&t, TOPO, vec![3u32; m_words]);
                assert_eq!(gathered.len(), WORLD);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let payload = costmodel::hierarchical_payload_words(TOPO.nodes, TOPO.ranks_per_node, m_words);
    let (acct_payload, headers) =
        hierarchical_traffic_words(TOPO.nodes, TOPO.ranks_per_node, m_words);
    assert_eq!(acct_payload, payload, "schedule accounting vs cost-model bandwidth term");
    let total = stats.words.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(
        total,
        payload + headers,
        "fabric moved {total} words; cost model charges {payload} payload + {headers} framing"
    );
    // the model charges only the payload; framing must stay noise
    assert!(headers < payload / 10, "framing {headers} not negligible vs payload {payload}");
}

#[test]
fn auto_picker_matches_cost_model_argmin() {
    // replicate the worker's plan: derive each bucket's cost inputs and
    // check the picker returns the argmin of the three closed forms
    let machine = Machine::fatnode();
    let (nodes, rpn) = (TOPO.nodes, TOPO.ranks_per_node);
    let p = nodes * rpn;
    // buckets spanning the regimes: one huge layer, a mid-size layer and
    // a pile of fused small layers
    let plan: Vec<Vec<(usize, Method, bool)>> = vec![
        vec![(40_000_000, Method::SampledBinarySearch, false)],
        vec![(2_000_000, Method::TrimmedTopk, false), (1_500_000, Method::TrimmedTopk, true)],
        (0..24).map(|_| (3_000usize, Method::TrimmedTopk, false)).collect(),
    ];
    let mut picks = Vec::new();
    for layers in &plan {
        let cost = costmodel::bucket_cost(&machine, layers, 1e-3);
        let (algo, times) = costmodel::pick_algo(&machine, nodes, rpn, &cost, 1e-3);
        // independent argmin over the three closed forms
        let td = costmodel::t_dense(&machine, p, cost.m_elems);
        let ts = costmodel::t_sparse(&machine, p, cost.m_elems, 1e-3, cost.t_select, cost.wire_bytes);
        let th = costmodel::t_hierarchical(
            &machine,
            nodes,
            rpn,
            cost.m_elems,
            1e-3,
            cost.t_select,
            cost.wire_bytes,
        );
        let want = if td <= ts && td <= th {
            Algo::Dense
        } else if ts <= th {
            Algo::Sparse
        } else {
            Algo::Hierarchical
        };
        assert_eq!(algo, want, "picker disagrees with argmin for {layers:?} ({times:?})");
        assert_eq!(times, [td, ts, th], "reported times must be the model's");
        picks.push(algo);
    }
    // pin the concrete regime split on fat nodes: big -> hierarchical,
    // fused-small -> dense
    assert_eq!(picks[0], Algo::Hierarchical, "40M-element bucket should go hierarchical");
    assert_eq!(picks[2], Algo::Dense, "24 fused 3K layers should be demoted to dense");
    assert!(picks.contains(&Algo::Hierarchical) && picks.contains(&Algo::Dense));
}

#[test]
fn prop_group_rank_translation_roundtrip() {
    check(80, |g| {
        let nodes = g.size(1..7);
        let rpn = g.size(1..7);
        let topo = Topology::new(nodes, rpn);
        let rank = g.size(0..topo.world());
        // node/local decomposition round-trips
        let (node, local) = (topo.node_of(rank), topo.local_of(rank));
        ensure(topo.world_rank(node, local) == rank, "world_rank inverse")?;
        // leader membership: leader_of is the node's first member, a
        // leader, and listed exactly once in leaders()
        let leader = topo.leader_of(rank);
        let members = topo.node_members(node);
        ensure(members[0] == leader, "leader is member[0]")?;
        ensure(members.len() == rpn, "node size")?;
        ensure(members.contains(&rank), "rank in own node")?;
        ensure(topo.is_leader(leader), "leader_of yields a leader")?;
        let leaders = topo.leaders();
        ensure(leaders.len() == nodes, "one leader per node")?;
        ensure(leaders.iter().filter(|&&l| l == leader).count() == 1, "leader listed once")?;
        // a ProcessGroup over the node members translates both ways
        let mut fabric = LocalFabric::new(topo.world());
        let t = fabric.take(rank);
        let group = ProcessGroup::new(&t, members.clone());
        ensure(group.rank() == local, "group-local rank == topology local rank")?;
        ensure(group.world() == rpn, "group world")?;
        for (l, &w) in members.iter().enumerate() {
            ensure(group.world_rank(l) == w, "local -> world")?;
            ensure(group.local_rank(w) == Some(l), "world -> local")?;
        }
        if nodes > 1 {
            // any rank of another node is not a member of this group
            let outsider = topo.world_rank((node + 1) % nodes, 0);
            ensure(group.local_rank(outsider).is_none(), "outsider has no local rank")?;
        }
        Ok(())
    });
}
