//! Chaos/fault injection for the network stack: a corrupt, truncated or
//! dying peer must surface a clean `Err` from `recv_checked` — never a
//! hang, never a panic.
//!
//! The TCP tests impersonate rank 1 of a 2-rank job by speaking the
//! bootstrap protocol by hand ([`fake_rank1`]), then injecting raw bytes
//! into the established mesh link.  The tag tests inject malformed
//! bucket-tagged messages under a `TagMux`.

use redsync::collectives::mux::{TagChannel, TagMux};
use redsync::collectives::{LocalFabric, Transport};
use redsync::net::frame::{read_frame, write_frame, MAX_FRAME_WORDS};
use redsync::net::{free_loopback_addr, TcpOptions, TcpTransport, UnixTransport};
use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::os::unix::net::UnixStream;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// `REG` frame kind of the rank-0 rendezvous protocol (net/tcp.rs wire
/// constant: "RDS" + kind 1).
const REG: u32 = 0x5244_5301;

/// Spawn the real rank 0 of a 2-rank job.
fn rank0(addr: String) -> thread::JoinHandle<TcpTransport> {
    thread::spawn(move || TcpTransport::connect(&TcpOptions::new(2, 0, addr)).expect("rank 0"))
}

/// Impersonate rank 1: register with rank 0, swallow the directory, and
/// return the raw mesh socket to rank 0.  (In a 2-rank world rank 1
/// neither dials nor accepts anyone else, so this one socket is the
/// whole mesh.)
fn fake_rank1(addr: &str) -> TcpStream {
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut s = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    panic!("rendezvous never came up: {e}");
                }
                thread::sleep(Duration::from_millis(10));
            }
        }
    };
    // [REG, world, rank, listen_port] — the port is never dialed here
    write_frame(&mut s, &[REG, 2, 1, 1]).unwrap();
    s.flush().unwrap();
    let dir = read_frame(&mut s).unwrap().expect("directory frame");
    assert_eq!(dir[1], 2, "directory should echo world=2");
    s
}

/// Run `f` with a watchdog: a hang is a test failure, not a stuck suite.
fn with_timeout<R: Send + 'static>(f: impl FnOnce() -> R + Send + 'static) -> R {
    let (tx, rx) = channel();
    thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(30)).expect("operation hung (expected a clean error)")
}

#[test]
fn truncated_frame_header_is_clean_error() {
    let addr = free_loopback_addr();
    let h = rank0(addr.clone());
    let mut fake = fake_rank1(&addr);
    let t0 = h.join().unwrap();
    // half a length prefix, then FIN
    fake.write_all(&[0x03, 0x00]).unwrap();
    fake.flush().unwrap();
    drop(fake);
    let err = with_timeout(move || t0.recv_checked(1)).unwrap_err();
    assert_eq!(err.peer, 1);
    assert!(err.reason.contains("broke"), "want a stream-broke cause, got: {err}");
}

#[test]
fn oversized_length_prefix_is_clean_error() {
    let addr = free_loopback_addr();
    let h = rank0(addr.clone());
    let mut fake = fake_rank1(&addr);
    let t0 = h.join().unwrap();
    // a frame claiming u32::MAX words: must be rejected before any
    // allocation, not trusted and waited for
    fake.write_all(&u32::MAX.to_le_bytes()).unwrap();
    fake.flush().unwrap();
    let err = with_timeout(move || t0.recv_checked(1)).unwrap_err();
    assert!(err.reason.contains("broke"), "{err}");
    assert!(
        MAX_FRAME_WORDS < u32::MAX as usize,
        "cap must be enforceable from a u32 length prefix"
    );
    drop(fake);
}

#[test]
fn peer_fin_mid_message_is_clean_error() {
    let addr = free_loopback_addr();
    let h = rank0(addr.clone());
    let mut fake = fake_rank1(&addr);
    let t0 = h.join().unwrap();
    // a valid header promising 8 words, 3 words of payload, then FIN
    let mut partial = Vec::new();
    partial.extend_from_slice(&8u32.to_le_bytes());
    for w in [1u32, 2, 3] {
        partial.extend_from_slice(&w.to_le_bytes());
    }
    fake.write_all(&partial).unwrap();
    fake.flush().unwrap();
    let _ = fake.shutdown(Shutdown::Write);
    let err = with_timeout(move || t0.recv_checked(1)).unwrap_err();
    assert!(err.reason.contains("broke"), "{err}");
    drop(fake);
}

#[test]
fn unix_peer_fin_mid_message_is_clean_error() {
    // same injection as the TCP test above, but over a Unix-socket link:
    // the shared data plane must classify the mid-frame EOF identically
    let (mine, theirs) = UnixStream::pair().expect("socketpair");
    let t0 = UnixTransport::from_streams(0, 2, vec![None, Some(mine)]);
    let mut fake = theirs;
    // a valid header promising 8 words, 3 words of payload, then FIN
    let mut partial = Vec::new();
    partial.extend_from_slice(&8u32.to_le_bytes());
    for w in [1u32, 2, 3] {
        partial.extend_from_slice(&w.to_le_bytes());
    }
    fake.write_all(&partial).unwrap();
    fake.flush().unwrap();
    let _ = fake.shutdown(Shutdown::Write);
    let err = with_timeout(move || t0.recv_checked(1)).unwrap_err();
    assert_eq!(err.peer, 1);
    assert!(err.reason.contains("broke"), "{err}");
    drop(fake);
}

#[test]
fn clean_fin_between_frames_is_clean_error_not_hang() {
    let addr = free_loopback_addr();
    let h = rank0(addr.clone());
    let mut fake = fake_rank1(&addr);
    let t0 = h.join().unwrap();
    // one intact frame, then a graceful close
    write_frame(&mut fake, &[7, 8, 9]).unwrap();
    fake.flush().unwrap();
    let _ = fake.shutdown(Shutdown::Write);
    let (msg, err) = with_timeout(move || {
        let msg = t0.recv_checked(1);
        let err = t0.recv_checked(1);
        (msg, err)
    });
    assert_eq!(msg.unwrap(), vec![7, 8, 9], "data before the FIN is delivered");
    let err = err.unwrap_err();
    assert!(err.reason.contains("closed"), "{err}");
    drop(fake);
}

#[test]
fn out_of_order_bucket_tags_route_without_loss() {
    // tags arriving in any order are routed, FIFO per tag — no message
    // crosses channels, none is dropped
    let mut fabric = LocalFabric::new(2);
    let a = Arc::new(TagMux::new(fabric.take(0), 4));
    let b = fabric.take(1);
    // peer interleaves three buckets' streams in scrambled order (the
    // tag word travels at the end of each message)
    for (tag, val) in [(3u32, 30u32), (1, 10), (2, 20), (1, 11), (3, 31), (2, 21)] {
        b.send(0, vec![val, tag]);
    }
    for tag in [1u32, 2, 3] {
        let chan = TagChannel::new(Arc::clone(&a), tag);
        assert_eq!(chan.recv(1), vec![tag * 10]);
        assert_eq!(chan.recv(1), vec![tag * 10 + 1]);
    }
}

#[test]
fn foreign_bucket_tag_is_clean_error() {
    // a tag outside the engine's window (corrupt peer, or an engine
    // mismatch across ranks) must error out, not park forever
    let mut fabric = LocalFabric::new(2);
    let a = Arc::new(TagMux::new(fabric.take(0), 3));
    let b = fabric.take(1);
    b.send(0, vec![1, 2, 3, 42]);
    let chan = TagChannel::new(Arc::clone(&a), 0);
    let err = with_timeout(move || chan.recv_checked(1)).unwrap_err();
    assert!(err.reason.contains("outside"), "{err}");
}

#[test]
fn untagged_message_on_multiplexed_fabric_is_clean_error() {
    // a raw (sequential-engine) peer talking to a pipelined rank: its
    // empty keepalive-style message has no tag word at all
    let mut fabric = LocalFabric::new(2);
    let a = Arc::new(TagMux::new(fabric.take(0), 2));
    let b = fabric.take(1);
    b.send(0, vec![]);
    let chan = TagChannel::new(Arc::clone(&a), 1);
    let err = with_timeout(move || chan.recv_checked(1)).unwrap_err();
    assert!(err.reason.contains("untagged"), "{err}");
}

#[test]
fn mux_over_tcp_surfaces_stream_breakage() {
    // the full stack: corrupt frame -> tcp reader exits -> mux recv on a
    // bucket channel reports the transport error
    let addr = free_loopback_addr();
    let h = rank0(addr.clone());
    let mut fake = fake_rank1(&addr);
    let t0 = h.join().unwrap();
    fake.write_all(&u32::MAX.to_le_bytes()).unwrap();
    fake.flush().unwrap();
    let err = with_timeout(move || {
        let mux = Arc::new(TagMux::new(t0, 2));
        let chan = TagChannel::new(mux, 1);
        chan.recv_checked(1)
    })
    .unwrap_err();
    assert_eq!(err.peer, 1);
    drop(fake);
}
