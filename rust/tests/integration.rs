//! Integration tests across the whole stack: runtime artifacts, the
//! trainer over the in-process fabric, the device-selection path, and
//! the CLI-level config plumbing.
//!
//! Tests that need artifacts skip gracefully when `make artifacts` has
//! not been run (CI always builds them first).

use redsync::compression::PolicyThresholds;
use redsync::config::{preset, TrainConfig, WarmupKind};
use redsync::coordinator::metrics::phase;
use redsync::coordinator::{TrainError, Trainer};
use redsync::models::schema::Manifest;
use redsync::optim::{LrSchedule, Optimizer};
use redsync::simnet::iteration::Strategy;
use std::path::PathBuf;

fn manifest() -> Option<Manifest> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(Manifest::load(dir).unwrap())
}

fn base_cfg() -> TrainConfig {
    TrainConfig {
        model: "lm_tiny".into(),
        world: 2,
        steps: 10,
        strategy: Strategy::Rgc,
        density: 0.02,
        thresholds: PolicyThresholds { thsd1: 512, thsd2: 8 * 1024 },
        log_every: 2,
        eval_every: 0,
        ..TrainConfig::default()
    }
}

#[test]
fn full_stack_rgc_all_strategies_and_worlds() {
    let Some(m) = manifest() else { return };
    for strategy in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
        for world in [1usize, 2, 4] {
            let cfg = TrainConfig { world, strategy, ..base_cfg() };
            let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
            assert!(r.replicas_consistent, "{} x{world}", strategy.label());
            assert!(r.final_loss.is_finite());
        }
    }
}

#[test]
fn rgc_matches_dense_quality_on_short_run() {
    // not bit-identical, but same ballpark loss after the same steps
    let Some(m) = manifest() else { return };
    let steps = 40;
    let lr = LrSchedule::Constant { lr: 0.3 };
    let dense = Trainer::new(
        &m,
        TrainConfig { strategy: Strategy::Dense, steps, lr: lr.clone(), ..base_cfg() },
    )
    .unwrap()
    .run()
    .unwrap();
    let rgc = Trainer::new(
        &m,
        TrainConfig { strategy: Strategy::Rgc, steps, lr, density: 0.05, ..base_cfg() },
    )
    .unwrap()
    .run()
    .unwrap();
    let d = dense.final_loss;
    let r = rgc.final_loss;
    assert!(
        (d - r).abs() < 0.5 * d,
        "RGC strayed too far from dense: {r} vs {d}"
    );
}

#[test]
fn device_select_path_runs_and_learns() {
    // the full L1 path: selection through the Pallas-kernel artifacts
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig {
        device_select: true,
        steps: 6,
        world: 2,
        ..base_cfg()
    };
    let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
    assert!(r.replicas_consistent);
    assert!(r.phases.total(phase::SELECT) > 0.0);
}

#[test]
fn device_and_host_select_agree_end_to_end() {
    // same config, host vs device selection: identical training result.
    // Restricted to trimmed-top-k layers (exact-k semantics on both
    // sides); binary-search layers may legitimately pick different
    // [k, 2k] sets on host vs device.
    let Some(m) = manifest() else { return };
    let host_cfg = TrainConfig {
        steps: 5,
        world: 2,
        thresholds: PolicyThresholds { thsd1: 512, thsd2: 1 << 30 },
        ..base_cfg()
    };
    let dev_cfg = TrainConfig { device_select: true, ..host_cfg.clone() };
    let host = Trainer::new(&m, host_cfg).unwrap().run().unwrap();
    let dev = Trainer::new(&m, dev_cfg).unwrap().run().unwrap();
    assert!(
        (host.final_loss - dev.final_loss).abs() < 5e-3,
        "host {} vs device {}",
        host.final_loss,
        dev.final_loss
    );
}

#[test]
fn momentum_and_nesterov_paths() {
    let Some(m) = manifest() else { return };
    for opt in [
        Optimizer::Sgd,
        Optimizer::Momentum { momentum: 0.9 },
        Optimizer::Nesterov { momentum: 0.9 },
    ] {
        let cfg = TrainConfig { optimizer: opt, steps: 12, ..base_cfg() };
        let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
        assert!(r.replicas_consistent, "{opt:?}");
        assert!(r.final_loss.is_finite(), "{opt:?}");
    }
}

#[test]
fn local_clipping_keeps_training_stable() {
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig {
        clip: Some(0.25),
        lr: LrSchedule::Constant { lr: 1.0 }, // aggressive without clip
        steps: 20,
        ..base_cfg()
    };
    let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
    assert!(r.final_loss.is_finite());
    assert!(r.replicas_consistent);
}

#[test]
fn warmup_transitions_dense_to_sparse() {
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig {
        warmup: WarmupKind::DenseEpochs(1),
        steps_per_epoch: 5,
        steps: 10,
        ..base_cfg()
    };
    let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
    // both phases present: dense comm (epoch 0) and sparse comm (epoch 1)
    assert!(r.phases.total(phase::COMM_DENSE) > 0.0);
    assert!(r.phases.total(phase::COMM_SPARSE) > 0.0);
    assert!(r.replicas_consistent);
}

#[test]
fn dgc_warmup_density_decays() {
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig {
        warmup: WarmupKind::Dgc,
        steps_per_epoch: 2,
        steps: 12,
        log_every: 2,
        ..base_cfg()
    };
    let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
    // sent density must decrease epoch over epoch
    let d: Vec<f64> = r.sent_density.iter().map(|&(_, d)| d).collect();
    assert!(d.len() >= 3);
    assert!(
        d.first().unwrap() > d.last().unwrap(),
        "density did not decay: {d:?}"
    );
}

#[test]
fn union_density_exceeds_per_rank_density() {
    // §5.3: distinct indices across ranks ≈ world × per-rank density
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig { world: 4, density: 0.01, steps: 6, ..base_cfg() };
    let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
    let (_, union) = *r.union_density.last().unwrap();
    let (_, sent) = *r.sent_density.last().unwrap();
    assert!(union > 1.5 * sent, "union {union} vs sent {sent}");
    // upper bound: world ranks, each sending up to ~2k (binary-search
    // layers return between k and 2k elements)
    assert!(union <= 2.0 * 4.0 * sent + 1e-9, "union {union} vs sent {sent}");
}

#[test]
fn quantized_traffic_below_plain() {
    let Some(m) = manifest() else { return };
    let plain = Trainer::new(&m, TrainConfig { eval_every: 0, ..base_cfg() })
        .unwrap()
        .run()
        .unwrap();
    let quant = Trainer::new(
        &m,
        TrainConfig { strategy: Strategy::QuantRgc, eval_every: 0, ..base_cfg() },
    )
    .unwrap()
    .run()
    .unwrap();
    assert!(
        quant.bytes < plain.bytes,
        "quantized {} !< plain {}",
        quant.bytes,
        plain.bytes
    );
}

#[test]
fn single_worker_degenerates_gracefully() {
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig { world: 1, steps: 5, ..base_cfg() };
    let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
    assert!(r.replicas_consistent);
    assert!(r.final_loss.is_finite());
}

#[test]
fn run_is_deterministic_for_fixed_seed() {
    let Some(m) = manifest() else { return };
    let a = Trainer::new(&m, base_cfg()).unwrap().run().unwrap();
    let b = Trainer::new(&m, base_cfg()).unwrap().run().unwrap();
    assert_eq!(a.final_loss, b.final_loss);
    assert_eq!(
        a.loss_curve, b.loss_curve,
        "training must be bit-deterministic for a fixed seed"
    );
}

#[test]
fn seeds_change_the_run() {
    let Some(m) = manifest() else { return };
    let a = Trainer::new(&m, base_cfg()).unwrap().run().unwrap();
    let b = Trainer::new(&m, TrainConfig { seed: 7, ..base_cfg() })
        .unwrap()
        .run()
        .unwrap();
    assert_ne!(a.final_loss, b.final_loss);
}

#[test]
fn presets_run_end_to_end_smoke() {
    let Some(m) = manifest() else { return };
    let mut cfg = preset("smoke").unwrap();
    cfg.steps = 6;
    let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
    assert!(r.replicas_consistent);
}

#[test]
fn invalid_configs_rejected_by_trainer() {
    let Some(m) = manifest() else { return };
    // non-power-of-two world
    let cfg = TrainConfig { world: 3, ..base_cfg() };
    assert!(matches!(Trainer::new(&m, cfg), Err(TrainError::Config(_))));
    // unknown model
    let cfg = TrainConfig { model: "missing".into(), ..base_cfg() };
    assert!(matches!(Trainer::new(&m, cfg), Err(TrainError::UnknownModel(_))));
}

#[test]
fn mlp_models_train_all_strategies() {
    let Some(m) = manifest() else { return };
    for strategy in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
        let cfg = TrainConfig {
            model: "mlp_small".into(),
            strategy,
            steps: 8,
            thresholds: PolicyThresholds { thsd1: 1024, thsd2: 64 * 1024 },
            optimizer: Optimizer::Nesterov { momentum: 0.9 },
            lr: LrSchedule::Constant { lr: 0.05 },
            ..base_cfg()
        };
        let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
        assert!(r.replicas_consistent, "{}", strategy.label());
    }
}

#[test]
fn binary_search_policy_branch_exercised() {
    // mlp_wide's 1024x1024 fc (4 MB) crosses thsd2 -> SampledBinarySearch
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig {
        model: "mlp_wide".into(),
        thresholds: PolicyThresholds { thsd1: 1024, thsd2: 256 * 1024 },
        steps: 8,
        lr: LrSchedule::Constant { lr: 0.05 },
        ..base_cfg()
    };
    let schema = &m.models["mlp_wide"];
    let big = schema.params.iter().filter(|p| p.bytes() >= 256 * 1024).count();
    assert!(big >= 1, "mlp_wide must have a binary-search layer");
    let r = Trainer::new(&m, cfg).unwrap().run().unwrap();
    assert!(r.replicas_consistent);
}

#[test]
fn fusion_reduces_messages_and_preserves_results() {
    // §5.3 tensor fusion: batching small allgathers must not change the
    // training result (same messages, fewer collectives)
    let Some(m) = manifest() else { return };
    let unfused_cfg = TrainConfig { steps: 8, world: 2, ..base_cfg() };
    let fused_cfg = TrainConfig { fusion_cap_elems: 1 << 20, ..unfused_cfg.clone() };
    let unfused = Trainer::new(&m, unfused_cfg).unwrap().run().unwrap();
    let fused = Trainer::new(&m, fused_cfg).unwrap().run().unwrap();
    assert_eq!(
        unfused.final_loss, fused.final_loss,
        "fusion changed the training result"
    );
    assert!(fused.replicas_consistent);
    assert!(
        fused.messages < unfused.messages,
        "fusion should reduce message count: {} vs {}",
        fused.messages,
        unfused.messages
    );
    // payload is the same modulo per-message headers
    assert!(fused.bytes <= unfused.bytes);
}

#[test]
fn fusion_respects_cap_granularity() {
    // a tiny cap degenerates to singleton groups == unfused behavior
    let Some(m) = manifest() else { return };
    let single = TrainConfig { fusion_cap_elems: 1, steps: 5, ..base_cfg() };
    let none = TrainConfig { fusion_cap_elems: 0, steps: 5, ..base_cfg() };
    let a = Trainer::new(&m, single).unwrap().run().unwrap();
    let b = Trainer::new(&m, none).unwrap().run().unwrap();
    assert_eq!(a.final_loss, b.final_loss);
    assert_eq!(a.messages, b.messages);
}

#[test]
fn pipelined_trainer_matches_sequential_bit_for_bit() {
    // the full-stack engine A/B: same artifacts, same data, both
    // engines — the loss curves must agree to the bit and every
    // replica set must stay internally consistent
    let Some(m) = manifest() else { return };
    let seq_cfg = TrainConfig { fusion_cap_elems: 8 * 1024, ..base_cfg() };
    let pipe_cfg = TrainConfig { pipeline: true, inflight: 2, ..seq_cfg.clone() };
    let seq = Trainer::new(&m, seq_cfg).unwrap().run().unwrap();
    let piped = Trainer::new(&m, pipe_cfg).unwrap().run().unwrap();
    assert!(seq.replicas_consistent && piped.replicas_consistent);
    assert_eq!(
        seq.final_loss.to_bits(),
        piped.final_loss.to_bits(),
        "engines diverged: {} vs {}",
        seq.final_loss,
        piped.final_loss
    );
    assert_eq!(seq.loss_curve.len(), piped.loss_curve.len());
    for ((s1, l1), (s2, l2)) in seq.loss_curve.iter().zip(&piped.loss_curve) {
        assert_eq!(s1, s2);
        assert_eq!(l1.to_bits(), l2.to_bits(), "loss curves diverged at step {s1}");
    }
    // pipelined moves the same payload plus one tag word per message
    assert_eq!(seq.messages, piped.messages);
    assert_eq!(piped.bytes, seq.bytes + 4 * piped.messages);
}
