//! Determinism + traffic audit for the sync engines.
//!
//! The `Pipelined` engine reorders *work* (selection and collectives run
//! concurrently across buckets on a comm pool) but must not reorder
//! *math*: after any number of steps its parameters are bit-identical to
//! the `Sequential` oracle's, on both the in-process fabric and real
//! loopback TCP sockets.  Its only wire-visible difference is the
//! one-word bucket tag per message, which the traffic audit pins
//! exactly (the Eq. 1 "headers once per in-flight bucket" accounting).
//!
//! No artifacts needed: the engines are driven directly with synthetic
//! deterministic gradients, the same way the worker drives them.

use redsync::collectives::mux::{TagChannel, TagMux};
use redsync::collectives::transport::TrafficStats;
use redsync::collectives::{LocalFabric, Transport};
use redsync::compression::{Accumulation, CompressorConfig, Method};
use redsync::coordinator::metrics::param_hash;
use redsync::net::{free_loopback_addr, TcpOptions, TcpTransport};
use redsync::pipeline::{
    build_buckets, BucketDone, LayerSpec, Pipelined, Sequential, SyncEngine, BUCKET_TAG_BASE,
};
use redsync::util::rng::Pcg32;
use redsync::util::timer::PhaseTimer;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Synthetic model: sizes chosen so greedy fusion (cap 3000) yields four
/// buckets, two of them multi-layer — singleton and fused paths both hit.
const SIZES: &[usize] = &[2500, 600, 600, 600, 1800, 900, 400, 2200];
const FUSION_CAP: usize = 3000;
const WORLD: usize = 4;
const STEPS: usize = 20;
const DENSITY: f64 = 0.02;
const LR: f32 = 0.05;

fn specs(quantize_mix: bool) -> Vec<LayerSpec> {
    SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| LayerSpec {
            li: i,
            n,
            // exercise both selection paths: big layers binary-search
            // (threshold cache), the rest trimmed top-k
            method: if n >= 1500 { Method::SampledBinarySearch } else { Method::TrimmedTopk },
            quantize: quantize_mix && i % 2 == 0,
        })
        .collect()
}

/// Deterministic per-(rank, step, layer) gradient — rank-dependent so the
/// gathered merge actually mixes different index sets.
fn grad(rank: usize, step: usize, li: usize, n: usize) -> Vec<f32> {
    let mut rng = Pcg32::seeded(((rank as u64) << 32) ^ ((step as u64) << 8) ^ li as u64);
    let mut g = vec![0f32; n];
    rng.fill_normal(&mut g, 1.0);
    g
}

/// Run STEPS synthetic training steps through an engine; returns the
/// FNV hash over the final parameter bits.
fn run_steps(engine: &mut dyn SyncEngine, rank: usize, world: usize) -> u64 {
    let mut params: Vec<Vec<f32>> = SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let mut rng = Pcg32::seeded(0xBEEF ^ i as u64); // identical on every rank
            let mut p = vec![0f32; n];
            rng.fill_normal(&mut p, 0.5);
            p
        })
        .collect();
    let scale = -LR / world as f32;
    let mut timer = PhaseTimer::new();
    for step in 0..STEPS {
        let grads: Vec<Vec<f32>> =
            SIZES.iter().enumerate().map(|(i, &n)| grad(rank, step, i, n)).collect();
        engine
            .sync_step(&grads, DENSITY, &mut timer, &mut |done: BucketDone| {
                // the worker's §5.4 decompression walk (shared impl)
                done.apply_to(&mut params, scale)
            })
            .unwrap_or_else(|e| panic!("rank {rank} step {step}: {e}"));
    }
    param_hash(&params)
}

fn cc() -> CompressorConfig {
    CompressorConfig { density: DENSITY, ..Default::default() }
}

fn acc() -> Accumulation {
    Accumulation::Momentum { momentum: 0.9 }
}

fn run_sequential<T: Transport>(t: &T, quantize_mix: bool) -> u64 {
    let buckets = build_buckets(&specs(quantize_mix), FUSION_CAP, acc());
    let mut engine = Sequential::new(t, None, buckets, cc());
    run_steps(&mut engine, t.rank(), t.world())
}

fn run_pipelined<T: Transport + Send + Sync>(t: T, inflight: usize, quantize_mix: bool) -> u64 {
    let (rank, world) = (t.rank(), t.world());
    let buckets = build_buckets(&specs(quantize_mix), FUSION_CAP, acc());
    let n = buckets.len() as u32;
    let mux = Arc::new(TagMux::new(t, BUCKET_TAG_BASE + n));
    let mut engine = Pipelined::new(mux, buckets, inflight, cc());
    run_steps(&mut engine, rank, world)
}

/// One thread per rank, with a deadlock watchdog.
fn run_ranks<T, F>(transports: Vec<T>, f: F) -> Vec<u64>
where
    T: Transport + Send + 'static,
    F: Fn(T) -> u64 + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let (done_tx, done_rx) = channel();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            thread::spawn(move || {
                let r = f(t);
                let _ = done.send(());
                r
            })
        })
        .collect();
    drop(done_tx);
    for _ in 0..handles.len() {
        done_rx
            .recv_timeout(Duration::from_secs(120))
            .expect("a rank did not finish within 120s (deadlock or crash)");
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Bootstrap a full TCP mesh on loopback; returned in rank order.
fn tcp_fabric(world: usize) -> Vec<TcpTransport> {
    let addr = free_loopback_addr();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let addr = addr.clone();
            thread::spawn(move || {
                TcpTransport::connect(&TcpOptions::new(world, rank, addr)).expect("tcp bootstrap")
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn all_equal(hashes: &[u64]) -> bool {
    hashes.iter().all(|&h| h == hashes[0])
}

#[test]
fn pipelined_matches_sequential_on_local_fabric() {
    for quantize_mix in [false, true] {
        let mut local = LocalFabric::new(WORLD);
        let seq = run_ranks(local.take_all(), move |t| run_sequential(&t, quantize_mix));
        assert!(all_equal(&seq), "sequential replicas drifted: {seq:x?}");

        let mut local = LocalFabric::new(WORLD);
        let piped = run_ranks(local.take_all(), move |t| run_pipelined(t, 2, quantize_mix));
        assert!(all_equal(&piped), "pipelined replicas drifted: {piped:x?}");

        assert_eq!(
            seq[0], piped[0],
            "engines diverged (quantize_mix={quantize_mix}): params not bit-identical"
        );
    }
}

#[test]
fn pipelined_matches_sequential_over_tcp_loopback() {
    let seq = run_ranks(tcp_fabric(WORLD), |t| run_sequential(&t, true));
    assert!(all_equal(&seq), "sequential replicas drifted over tcp: {seq:x?}");

    let piped = run_ranks(tcp_fabric(WORLD), |t| run_pipelined(t, 2, true));
    assert!(all_equal(&piped), "pipelined replicas drifted over tcp: {piped:x?}");

    assert_eq!(seq[0], piped[0], "engines diverged over tcp");

    // and TCP agrees with the in-process fabric bit-for-bit
    let mut local = LocalFabric::new(WORLD);
    let local_seq = run_ranks(local.take_all(), |t| run_sequential(&t, true));
    assert_eq!(local_seq[0], seq[0], "fabrics diverged");
}

#[test]
fn window_edges_still_bit_identical() {
    // window 1 (fully serialized issue) and window >= buckets (all in
    // flight) must still match the oracle
    let mut local = LocalFabric::new(WORLD);
    let seq = run_ranks(local.take_all(), |t| run_sequential(&t, false));
    for inflight in [1usize, 8] {
        let mut local = LocalFabric::new(WORLD);
        let piped = run_ranks(local.take_all(), move |t| run_pipelined(t, inflight, false));
        assert!(all_equal(&piped), "inflight={inflight} replicas drifted");
        assert_eq!(seq[0], piped[0], "inflight={inflight} diverged from oracle");
    }
}

#[test]
fn pipelined_traffic_is_sequential_plus_one_tag_word_per_message() {
    // Eq. 1 header audit: the pipelined engine moves exactly the same
    // messages as the sequential one, plus one mux tag word per message
    // — the per-bucket framing is charged once per in-flight message,
    // never per layer.
    let mut local = LocalFabric::new(WORLD);
    let seq_stats = Arc::clone(&local.stats);
    let seq = run_ranks(local.take_all(), |t| run_sequential(&t, true));

    let mut local = LocalFabric::new(WORLD);
    let pipe_stats: Arc<TrafficStats> = Arc::clone(&local.stats);
    let piped = run_ranks(local.take_all(), |t| run_pipelined(t, 3, true));

    assert_eq!(seq[0], piped[0], "audit precondition: same math");
    assert_eq!(
        seq_stats.message_count(),
        pipe_stats.message_count(),
        "same collective schedule => same message count"
    );
    let seq_words = seq_stats.bytes() / 4;
    let pipe_words = pipe_stats.bytes() / 4;
    assert_eq!(
        pipe_words,
        seq_words + pipe_stats.message_count(),
        "mux overhead must be exactly one tag word per message"
    );
}

#[test]
fn empty_engine_is_a_no_op() {
    let mut local = LocalFabric::new(1);
    let t = local.take(0);
    let mux = Arc::new(TagMux::new(t, BUCKET_TAG_BASE));
    let mut engine = Pipelined::new(mux, Vec::new(), 2, cc());
    assert_eq!(engine.n_buckets(), 0);
    let mut timer = PhaseTimer::new();
    engine
        .sync_step(&[], DENSITY, &mut timer, &mut |_| {
            Err("no buckets, no apply".to_string())
        })
        .unwrap();
}

#[test]
fn tag_channels_keep_control_traffic_separate_during_sync() {
    // while bucket collectives are in flight, a control-tag allreduce
    // (the loop's dense/loss traffic) must pass through untouched — the
    // worker's exact sharing pattern
    use redsync::collectives::allreduce_mean;
    use redsync::pipeline::CTRL_TAG;
    let mut local = LocalFabric::new(WORLD);
    let results = run_ranks(local.take_all(), |t| {
        let rank = t.rank();
        let world = t.world();
        let buckets = build_buckets(&specs(false), FUSION_CAP, acc());
        let n = buckets.len() as u32;
        let mux = Arc::new(TagMux::new(t, BUCKET_TAG_BASE + n));
        let ctrl = TagChannel::new(Arc::clone(&mux), CTRL_TAG);
        let mut engine = Pipelined::new(mux, buckets, 2, cc());
        let mut timer = PhaseTimer::new();
        for step in 0..3 {
            let grads: Vec<Vec<f32>> =
                SIZES.iter().enumerate().map(|(i, &n)| grad(rank, step, i, n)).collect();
            engine.sync_step(&grads, DENSITY, &mut timer, &mut |_| Ok(())).unwrap();
            // control collective between syncs, like the loss average
            let mut l = [(rank + 1) as f32];
            allreduce_mean(&ctrl, &mut l);
            let expect: f32 = (1..=world).map(|r| r as f32).sum::<f32>() / world as f32;
            assert_eq!(l[0], expect);
        }
        0u64
    });
    assert_eq!(results.len(), WORLD);
}
