//! Calibration + live re-planning pins (DESIGN.md §Observability).
//!
//! Three facts keep `--recalib-every` honest, and each gets pinned
//! here:
//!
//! 1. The straggler scenario is real: on the `fatnode` datasheet the
//!    §5.5 picker chooses the hierarchical schedule at 2x4, and on
//!    `fatnode-straggler` (one slow worker per node degrading every
//!    intra-node collective) the same buckets flip to flat sparse — so
//!    a static plan priced on the datasheet is provably wrong on the
//!    degraded fabric.
//! 2. The calibrator recovers: feeding it one recalibration window of
//!    hierarchical observations synthesized from the straggler's
//!    closed-form cost makes `replan` switch every bucket to the
//!    algorithm the truth machine would have picked.
//! 3. Switching live is safe: sparse and hierarchical deliver the same
//!    gathered contributions in world-rank order, so a mid-run
//!    `set_algos` flip leaves the final parameters bit-identical to a
//!    run that used the target plan from step 0 — on both engines.

use redsync::collectives::mux::TagMux;
use redsync::collectives::{Algo, LocalFabric, Topology, Transport};
use redsync::compression::{Accumulation, CompressorConfig, Method};
use redsync::coordinator::metrics::param_hash;
use redsync::costmodel::{self, BucketCost, PLAIN_WIRE_BYTES};
use redsync::obs::Calibrator;
use redsync::pipeline::{
    build_buckets, BucketDone, LayerSpec, Pipelined, Sequential, SyncEngine, BUCKET_TAG_BASE,
};
use redsync::simnet::Machine;
use redsync::util::rng::Pcg32;
use redsync::util::timer::PhaseTimer;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// The ISSUE scenario's topology: 2 nodes x 4 ranks.
const NODES: usize = 2;
const RPN: usize = 4;
const PLAN_DENSITY: f64 = 1e-3;

fn cost_of(m_elems: f64) -> BucketCost {
    BucketCost { m_elems, t_select: 0.0, wire_bytes: PLAIN_WIRE_BYTES }
}

#[test]
fn straggler_preset_flips_the_static_plan() {
    let healthy = Machine::fatnode();
    let degraded = Machine::fatnode_straggler();
    for m_elems in [1e6, 4e6, 16e6, 64e6] {
        let cost = cost_of(m_elems);
        let (h, _) = costmodel::pick_algo(&healthy, NODES, RPN, &cost, PLAN_DENSITY);
        let (d, _) = costmodel::pick_algo(&degraded, NODES, RPN, &cost, PLAN_DENSITY);
        assert_eq!(h, Algo::Hierarchical, "datasheet pick for {m_elems:e} elems");
        assert_eq!(d, Algo::Sparse, "straggler pick for {m_elems:e} elems");
    }
}

#[test]
fn calibrated_replan_recovers_from_a_straggler_within_one_window() {
    // one --recalib-every window of observations must be enough
    const RECALIB_EVERY: usize = 16;
    let datasheet = Machine::fatnode();
    let truth = Machine::fatnode_straggler();
    let costs = [cost_of(4e6), cost_of(16e6)];
    // static plan on the datasheet: hierarchical everywhere (wrong on
    // the degraded fabric, per the pin above)
    let current: Vec<Algo> = costs
        .iter()
        .map(|c| costmodel::pick_algo(&datasheet, NODES, RPN, c, PLAN_DENSITY).0)
        .collect();
    assert_eq!(current, vec![Algo::Hierarchical; 2]);

    let mut calib = Calibrator::new(datasheet, None, NODES, RPN, costs.len());
    let cc = costmodel::comm_coeffs(Algo::Hierarchical, NODES, RPN);
    for _ in 0..RECALIB_EVERY {
        for (b, cost) in costs.iter().enumerate() {
            // the packed blob: D·m index/value pairs, two words each
            let words = (cost.m_elems * PLAN_DENSITY * 2.0) as usize;
            let bytes = 4.0 * words as f64;
            let secs = cc.inter_rounds * truth.alpha
                + cc.inter_bytes * bytes * truth.beta
                + cc.intra_rounds * truth.intra_alpha
                + cc.intra_bytes * bytes * truth.intra_beta;
            calib.observe_bucket(b, Algo::Hierarchical, words, secs);
        }
    }
    let (next, switches) = calib.replan(&costs, PLAN_DENSITY, &current);
    assert_eq!(next, vec![Algo::Sparse; 2], "calibrated picker must flip to flat sparse");
    assert_eq!(switches, 2);
    // the flip matches what pricing on the truth machine would pick,
    // i.e. measured step time improves under the degraded fabric
    for cost in &costs {
        let (want, _) = costmodel::pick_algo(&truth, NODES, RPN, cost, PLAN_DENSITY);
        assert_eq!(want, Algo::Sparse);
    }
    // the under-prediction that triggered the flip is on the ledger
    let s = calib.summary();
    assert_eq!(s.replans, 1);
    assert_eq!(s.switches, 2);
    assert!(s.error_ratio() > 1.5, "datasheet plan must under-predict: {}", s.error_ratio());
}

// ------------------------------------------------- live-switch identity

/// Synthetic model shared with tests/pipeline.rs: greedy fusion (cap
/// 3000) yields four buckets, singleton and fused paths both hit.
const SIZES: &[usize] = &[2500, 600, 600, 600, 1800, 900, 400, 2200];
const FUSION_CAP: usize = 3000;
const WORLD: usize = 4;
const STEPS: usize = 12;
const SWITCH_AT: usize = 6;
const DENSITY: f64 = 0.02;
const LR: f32 = 0.05;

fn specs() -> Vec<LayerSpec> {
    SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| LayerSpec {
            li: i,
            n,
            method: if n >= 1500 { Method::SampledBinarySearch } else { Method::TrimmedTopk },
            quantize: i % 2 == 0,
        })
        .collect()
}

fn grad(rank: usize, step: usize, li: usize, n: usize) -> Vec<f32> {
    let mut rng = Pcg32::seeded(((rank as u64) << 32) ^ ((step as u64) << 8) ^ li as u64);
    let mut g = vec![0f32; n];
    rng.fill_normal(&mut g, 1.0);
    g
}

fn cc() -> CompressorConfig {
    CompressorConfig { density: DENSITY, ..Default::default() }
}

fn acc() -> Accumulation {
    Accumulation::Momentum { momentum: 0.9 }
}

/// Run STEPS synthetic steps, applying `switch_to` at the SWITCH_AT
/// step barrier when set — the worker's re-plan protocol in miniature.
fn run_with_plan(
    engine: &mut dyn SyncEngine,
    rank: usize,
    world: usize,
    start: Algo,
    switch_to: Option<Algo>,
) -> u64 {
    engine.set_algos(&vec![start; engine.n_buckets()]);
    let mut params: Vec<Vec<f32>> = SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let mut rng = Pcg32::seeded(0xBEEF ^ i as u64); // identical on every rank
            let mut p = vec![0f32; n];
            rng.fill_normal(&mut p, 0.5);
            p
        })
        .collect();
    let scale = -LR / world as f32;
    let mut timer = PhaseTimer::new();
    for step in 0..STEPS {
        if step == SWITCH_AT {
            if let Some(a) = switch_to {
                engine.set_algos(&vec![a; engine.n_buckets()]);
            }
        }
        let grads: Vec<Vec<f32>> =
            SIZES.iter().enumerate().map(|(i, &n)| grad(rank, step, i, n)).collect();
        engine
            .sync_step(&grads, DENSITY, &mut timer, &mut |done: BucketDone| {
                done.apply_to(&mut params, scale)
            })
            .unwrap_or_else(|e| panic!("rank {rank} step {step}: {e}"));
    }
    param_hash(&params)
}

/// One thread per rank, with a deadlock watchdog.
fn run_ranks<T, F>(transports: Vec<T>, f: F) -> Vec<u64>
where
    T: Transport + Send + 'static,
    F: Fn(T) -> u64 + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let (done_tx, done_rx) = channel();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            thread::spawn(move || {
                let r = f(t);
                let _ = done.send(());
                r
            })
        })
        .collect();
    drop(done_tx);
    for _ in 0..handles.len() {
        done_rx
            .recv_timeout(Duration::from_secs(120))
            .expect("a rank did not finish within 120s (deadlock or crash)");
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn seq_hashes(start: Algo, switch_to: Option<Algo>) -> Vec<u64> {
    let mut local = LocalFabric::new(WORLD);
    run_ranks(local.take_all(), move |t| {
        let topo = Topology::parse("2x2").unwrap();
        let buckets = build_buckets(&specs(), FUSION_CAP, acc());
        let mut engine = Sequential::with_topology(&t, topo, None, buckets, cc());
        run_with_plan(&mut engine, t.rank(), t.world(), start, switch_to)
    })
}

fn pipe_hashes(start: Algo, switch_to: Option<Algo>) -> Vec<u64> {
    let mut local = LocalFabric::new(WORLD);
    run_ranks(local.take_all(), move |t| {
        let (rank, world) = (t.rank(), t.world());
        let topo = Topology::parse("2x2").unwrap();
        let buckets = build_buckets(&specs(), FUSION_CAP, acc());
        let n = buckets.len() as u32;
        let mux = Arc::new(TagMux::new(t, BUCKET_TAG_BASE + n));
        let mut engine = Pipelined::with_topology(mux, topo, buckets, 2, cc());
        run_with_plan(&mut engine, rank, world, start, switch_to)
    })
}

fn all_equal(hashes: &[u64]) -> bool {
    hashes.iter().all(|&h| h == hashes[0])
}

#[test]
fn mid_run_switch_is_bit_identical_on_the_sequential_engine() {
    let stat_sparse = seq_hashes(Algo::Sparse, None);
    let stat_hier = seq_hashes(Algo::Hierarchical, None);
    assert!(all_equal(&stat_sparse), "sparse replicas drifted: {stat_sparse:x?}");
    assert!(all_equal(&stat_hier), "hierarchical replicas drifted: {stat_hier:x?}");
    // the two schedules gather the same contributions in rank order
    assert_eq!(stat_sparse[0], stat_hier[0], "schedules must agree bit-for-bit");

    for (start, target) in [(Algo::Hierarchical, Algo::Sparse), (Algo::Sparse, Algo::Hierarchical)]
    {
        let switched = seq_hashes(start, Some(target));
        assert!(all_equal(&switched), "switched replicas drifted: {switched:x?}");
        assert_eq!(
            switched[0], stat_sparse[0],
            "mid-run {start:?}->{target:?} switch perturbed the parameters"
        );
    }
}

#[test]
fn mid_run_switch_is_bit_identical_on_the_pipelined_engine() {
    let stat_sparse = pipe_hashes(Algo::Sparse, None);
    assert!(all_equal(&stat_sparse), "sparse replicas drifted: {stat_sparse:x?}");
    // pipelined agrees with the sequential oracle on the same plan
    assert_eq!(stat_sparse[0], seq_hashes(Algo::Sparse, None)[0], "engines diverged");

    let switched = pipe_hashes(Algo::Hierarchical, Some(Algo::Sparse));
    assert!(all_equal(&switched), "switched replicas drifted: {switched:x?}");
    assert_eq!(
        switched[0], stat_sparse[0],
        "mid-run hierarchical->sparse switch perturbed the pipelined engine"
    );
}
