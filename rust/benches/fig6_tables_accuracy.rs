//! Fig. 6 + Table 1 + Table 2 reproduction (proxy workloads): convergence
//! and final quality of SGD vs RGC vs quantized RGC, plus the big-batch
//! sweep of Table 2.
//!
//! The paper's datasets (ImageNet/Cifar10/PTB/Wiki2) are substituted with
//! synthetic tasks with a real loss landscape (DESIGN.md §Substitutions);
//! the claim under test is *optimizer equivalence* — all three strategies
//! reach quality within noise of each other — which is dataset-portable.
//!
//! ```sh
//! make artifacts && cargo bench --bench fig6_tables_accuracy
//! ```

use redsync::config::{preset, TrainConfig};
use redsync::coordinator::{train, TrainReport};
use redsync::simnet::iteration::Strategy;

fn run(mut cfg: TrainConfig, strategy: Strategy) -> TrainReport {
    cfg.strategy = strategy;
    let r = train(cfg).expect("run");
    assert!(r.replicas_consistent, "replica drift under {}", strategy.label());
    r
}

fn main() {
    if redsync::models::schema::Manifest::load(
        redsync::models::schema::Manifest::default_dir(),
    )
    .is_err()
    {
        eprintln!("artifacts not built; run `make artifacts` first");
        std::process::exit(1);
    }

    // ---- Fig. 6 / Table 1 (MLP-classifier proxy for the CNN rows) ----
    println!("# Fig. 6 / Table 1 — convergence, MLP classifier proxy (accuracy; higher=better)");
    let mut cfg = preset("fig6-mlp").unwrap();
    cfg.steps = 400;
    cfg.eval_every = 100;
    println!("{:>10} {:>12} {:>10} {:>12}", "strategy", "final loss", "accuracy", "traffic");
    let mut evals = Vec::new();
    for s in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
        let r = run(cfg.clone(), s);
        println!(
            "{:>10} {:>12.4} {:>10.4} {:>12}",
            s.label(),
            r.final_loss,
            r.final_eval.unwrap(),
            redsync::util::fmt_bytes(r.bytes as usize)
        );
        evals.push(r.final_eval.unwrap());
    }
    // paper claim: all within noise (Table 1 deltas are < 1 point)
    for (i, label) in ["RGC", "quant-RGC"].iter().enumerate() {
        let delta = (evals[i + 1] - evals[0]).abs();
        println!("  |Δ accuracy| {label} vs SGD = {delta:.4}");
        assert!(delta < 0.15, "{label} accuracy diverged from SGD by {delta}");
    }

    // ---- Fig. 6 right / Table 1 LM rows (held-out loss; lower=better) ----
    println!("\n# Fig. 6 (right) / Table 1 LM rows — lm_small held-out loss");
    let mut cfg = preset("fig6-lm").unwrap();
    cfg.steps = 200;
    cfg.eval_every = 50;
    println!("{:>10} {:>12} {:>12} {:>12}", "strategy", "final loss", "eval loss", "traffic");
    let mut lm_evals = Vec::new();
    for s in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
        let r = run(cfg.clone(), s);
        println!(
            "{:>10} {:>12.4} {:>12.4} {:>12}",
            s.label(),
            r.final_loss,
            r.final_eval.unwrap(),
            redsync::util::fmt_bytes(r.bytes as usize)
        );
        lm_evals.push(r.final_eval.unwrap());
    }
    for (i, label) in ["RGC", "quant-RGC"].iter().enumerate() {
        let delta = (lm_evals[i + 1] - lm_evals[0]).abs();
        println!("  |Δ eval loss| {label} vs SGD = {delta:.4}");
        assert!(
            delta < 0.35 * lm_evals[0],
            "{label} LM quality diverged from SGD by {delta}"
        );
    }

    // ---- Table 2: batch-size sweep (RGC robust to big batch) ----
    println!("\n# Table 2 — quality vs (effective) batch size, MLP proxy");
    println!("# effective batch grows with world size (weak scaling, fixed per-rank batch)");
    println!("{:>8} {:>10} {:>10} {:>10}", "world", "SGD", "RGC", "quantRGC");
    for world in [2usize, 4, 8, 16] {
        let mut cfg = preset("table2").unwrap();
        cfg.world = world;
        cfg.steps = 250;
        cfg.eval_every = cfg.steps - 1;
        let mut row = Vec::new();
        for s in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
            row.push(run(cfg.clone(), s).final_eval.unwrap());
        }
        println!("{world:>8} {:>10.4} {:>10.4} {:>10.4}", row[0], row[1], row[2]);
    }
    println!("\nTable-2 shape: RGC quality tracks SGD across batch scales");
}
