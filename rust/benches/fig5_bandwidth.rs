//! Fig. 5 reproduction: effective device-to-device allreduce bandwidth
//! vs message size on both machine presets, computed the way the paper
//! measures it — `S/t · 2(n-1)/n` — plus a *real* measurement over the
//! in-process fabric (bytes moved / wall time) as a sanity check of the
//! collective implementations.
//!
//! Paper shape: Piz Daint saturates ~1.5 GB/s, Muradin ~3.5 GB/s; small
//! messages are latency-bound.
//!
//! ```sh
//! cargo bench --bench fig5_bandwidth
//! ```

use redsync::collectives::{allreduce_mean, LocalFabric};
use redsync::simnet::{allreduce_bandwidth, Machine};
use std::thread;
use std::time::Instant;

fn measured_fabric_bandwidth(world: usize, elems: usize) -> f64 {
    let mut fabric = LocalFabric::new(world);
    let start = Instant::now();
    let reps = 3;
    let handles: Vec<_> = fabric
        .take_all()
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let mut x = vec![1.0f32; elems];
                for _ in 0..reps {
                    allreduce_mean(&t, &mut x);
                }
                assert!((x[0] - 1.0).abs() < 1e-6);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let t = start.elapsed().as_secs_f64() / reps as f64;
    let s = (elems * 4) as f64;
    (s / t) * 2.0 * (world as f64 - 1.0) / world as f64
}

fn main() {
    println!("# Fig. 5 — allreduce bandwidth vs data size (model, per machine preset)");
    println!(
        "{:>12} {:>14} {:>14} {:>14} {:>14}",
        "bytes", "daint p=8", "daint p=64", "muradin p=4", "muradin p=8"
    );
    let daint = Machine::piz_daint();
    let muradin = Machine::muradin();
    for log2 in [12usize, 14, 16, 18, 20, 22, 24, 26] {
        let bytes = (1usize << log2) as f64;
        println!(
            "{:>12} {:>12.2}GB {:>12.2}GB {:>12.2}GB {:>12.2}GB",
            redsync::util::fmt_bytes(bytes as usize),
            allreduce_bandwidth(&daint, 8, bytes) / 1e9,
            allreduce_bandwidth(&daint, 64, bytes) / 1e9,
            allreduce_bandwidth(&muradin, 4, bytes) / 1e9,
            allreduce_bandwidth(&muradin, 8, bytes) / 1e9,
        );
    }
    // shape assertions: saturation near link rate, latency-bound smalls
    let big = allreduce_bandwidth(&muradin, 8, 256e6);
    let small = allreduce_bandwidth(&muradin, 8, 4096.0);
    assert!(big > 3.0e9 && big < 3.6e9, "muradin saturation {big:e}");
    assert!(small < big / 2.0, "small messages should be latency-bound");

    println!("\n# measured in-process fabric (real threads, Rabenseifner):");
    println!("{:>12} {:>8} {:>14}", "bytes", "world", "eff. bw");
    for (world, elems) in [(4usize, 1usize << 20), (8, 1 << 20), (8, 1 << 22)] {
        let bw = measured_fabric_bandwidth(world, elems);
        println!(
            "{:>12} {:>8} {:>12.2}GB",
            redsync::util::fmt_bytes(elems * 4),
            world,
            bw / 1e9
        );
    }
}
