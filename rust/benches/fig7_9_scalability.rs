//! Figs. 7-9 reproduction: speedup of dense baseline vs RGC vs quantized
//! RGC for the paper's DNN zoo, weak scaling.
//!
//! * Fig. 7 — Piz Daint, 2..128 GPUs: VGG16 / AlexNet / ResNet50 / LSTM
//! * Fig. 8 — Muradin, 2..8 GPUs: ImageNet CNNs
//! * Fig. 9 — Muradin: LSTM PTB / Wiki2, VGG16-Cifar
//!
//! Prints the paper's headline ratios next to ours; asserts the *shape*
//! (who wins, roughly by how much, concavity) rather than absolutes.
//!
//! ```sh
//! cargo bench --bench fig7_9_scalability
//! ```

use redsync::models::zoo;
use redsync::simnet::iteration::{speedup, SimConfig, Strategy};
use redsync::simnet::Machine;

struct Claim {
    fig: &'static str,
    model: &'static str,
    machine: &'static str,
    p: usize,
    /// paper speedup ratio vs baseline (RGC, quant-RGC)
    paper: (f64, f64),
}

const CLAIMS: &[Claim] = &[
    Claim { fig: "7", model: "vgg16", machine: "piz-daint", p: 128, paper: (1.42, 1.71) },
    Claim { fig: "7", model: "alexnet", machine: "piz-daint", p: 128, paper: (0.94, 1.17) },
    Claim { fig: "7", model: "lstm-ptb", machine: "piz-daint", p: 32, paper: (1.47, 1.76) },
    Claim { fig: "8", model: "vgg16", machine: "muradin", p: 8, paper: (1.55, 1.64) },
    Claim { fig: "8", model: "alexnet", machine: "muradin", p: 8, paper: (1.96, 2.26) },
    Claim { fig: "8", model: "resnet50", machine: "muradin", p: 8, paper: (0.83, 0.85) },
    Claim { fig: "9", model: "vgg16-cifar", machine: "muradin", p: 8, paper: (1.16, 1.24) },
    Claim { fig: "9", model: "lstm-ptb", machine: "muradin", p: 8, paper: (2.11, 2.06) },
];

fn main() {
    let cfg = SimConfig::default();

    for (fig, machine, models, gpus) in [
        (
            "Fig. 7 — Piz Daint",
            Machine::piz_daint(),
            vec!["vgg16", "alexnet", "resnet50", "lstm-ptb"],
            vec![2usize, 4, 8, 16, 32, 64, 128],
        ),
        (
            "Fig. 8 — Muradin CNNs",
            Machine::muradin(),
            vec!["alexnet", "vgg16", "resnet50"],
            vec![2, 4, 8],
        ),
        (
            "Fig. 9 — Muradin LSTM + VGG16-Cifar",
            Machine::muradin(),
            vec!["lstm-ptb", "lstm-wiki2", "vgg16-cifar"],
            vec![2, 4, 8],
        ),
    ] {
        println!("# {fig}");
        for name in &models {
            let model = zoo::by_name(name).unwrap();
            println!("  {} ({}):", model.name, redsync::util::fmt_bytes(model.model_bytes()));
            println!(
                "  {:>5} {:>10} {:>10} {:>10} {:>8} {:>8}",
                "gpus", "baseline", "RGC", "quantRGC", "R/base", "Q/base"
            );
            for &p in &gpus {
                let d = speedup(&model, &machine, p, Strategy::Dense, &cfg);
                let r = speedup(&model, &machine, p, Strategy::Rgc, &cfg);
                let q = speedup(&model, &machine, p, Strategy::QuantRgc, &cfg);
                println!(
                    "  {p:>5} {d:>10.2} {r:>10.2} {q:>10.2} {:>8.2} {:>8.2}",
                    r / d,
                    q / d
                );
            }
        }
        println!();
    }

    println!("# paper-vs-measured at the headline points (ratio vs dense baseline):");
    println!(
        "{:>4} {:>12} {:>10} {:>5} {:>14} {:>14} {:>6}",
        "fig", "model", "machine", "p", "paper (R, Q)", "ours (R, Q)", "shape"
    );
    let mut shape_ok = true;
    for c in CLAIMS {
        let model = zoo::by_name(c.model).unwrap();
        let machine = Machine::by_name(c.machine).unwrap();
        let d = speedup(&model, &machine, c.p, Strategy::Dense, &cfg);
        let r = speedup(&model, &machine, c.p, Strategy::Rgc, &cfg) / d;
        let q = speedup(&model, &machine, c.p, Strategy::QuantRgc, &cfg) / d;
        // shape: agree on which side of ~1.0 each ratio falls; and quant
        // must track plain within 15% (the paper itself sees quant-vs-
        // plain flip at small scale when binary-search re-search cost
        // outweighs the halved messages — §6.4's LSTM observation, which
        // our sim reproduces for bs-heavy models at p=8)
        let win_shape = (c.paper.0 > 1.05) == (r > 1.0) || (c.paper.0 - 1.0).abs() < 0.2;
        let quant_shape = q >= r * 0.85 || (c.paper.1 >= c.paper.0) == (q >= r);
        let ok = win_shape && quant_shape;
        shape_ok &= ok;
        println!(
            "{:>4} {:>12} {:>10} {:>5} ({:>5.2},{:>5.2}) ({:>5.2},{:>5.2}) {:>6}",
            c.fig,
            c.model,
            c.machine,
            c.p,
            c.paper.0,
            c.paper.1,
            r,
            q,
            if ok { "OK" } else { "MISS" }
        );
    }
    assert!(shape_ok, "scalability shape differs from the paper");
    println!("\nall headline shapes hold");
}
