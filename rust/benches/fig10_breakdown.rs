//! Fig. 10 reproduction: per-phase time decomposition of RedSync on Piz
//! Daint while scaling to 128 nodes — mask / select / pack / comm /
//! unpack / compute proportions for RGC and quantized RGC.
//!
//! Paper headline: for ResNet50 at 128 GPUs most of the iteration is
//! spent in *unpack* (69% RGC / 67% quant-RGC of the sync path), because
//! decompression cost grows linearly with p (Eq. 1's p·γ₁ term).
//!
//! Also cross-checks the breakdown of a *real* in-process training run
//! (lm_tiny) against the simulated phase vocabulary.
//!
//! ```sh
//! cargo bench --bench fig10_breakdown
//! ```

use redsync::models::zoo;
use redsync::simnet::iteration::{simulate_iteration, SimConfig, Strategy};
use redsync::simnet::Machine;

fn row(model: &str, p: usize, strategy: Strategy, cfg: &SimConfig) -> [f64; 6] {
    let m = zoo::by_name(model).unwrap();
    let machine = Machine::piz_daint();
    let b = simulate_iteration(&m, &machine, p, strategy, cfg);
    let sum = b.component_sum();
    [
        b.compute / sum,
        b.mask / sum,
        b.select / sum,
        b.pack / sum,
        b.comm / sum,
        b.unpack / sum,
    ]
}

fn main() {
    let cfg = SimConfig::default();
    println!("# Fig. 10 — phase decomposition on Piz Daint (fractions of component sum)");
    for model in ["resnet50", "vgg16", "alexnet", "lstm-ptb"] {
        println!("\n## {model}");
        println!(
            "{:>5} {:>10} {:>9} {:>7} {:>8} {:>7} {:>7} {:>8}",
            "gpus", "strategy", "compute", "mask", "select", "pack", "comm", "unpack"
        );
        for p in [16usize, 32, 64, 128] {
            for s in [Strategy::Rgc, Strategy::QuantRgc] {
                let r = row(model, p, s, &cfg);
                println!(
                    "{:>5} {:>10} {:>8.1}% {:>6.1}% {:>7.1}% {:>6.1}% {:>6.1}% {:>7.1}%",
                    p,
                    s.label(),
                    100.0 * r[0],
                    100.0 * r[1],
                    100.0 * r[2],
                    100.0 * r[3],
                    100.0 * r[4],
                    100.0 * r[5]
                );
            }
        }
    }

    // paper's headline: ResNet50 @128, unpack dominates the sync path
    let r128 = row("resnet50", 128, Strategy::Rgc, &cfg);
    let q128 = row("resnet50", 128, Strategy::QuantRgc, &cfg);
    let sync_frac =
        |r: &[f64; 6]| r[5] / (r[1] + r[2] + r[3] + r[4] + r[5]).max(f64::EPSILON);
    println!(
        "\n# resnet50 @128: unpack share of sync path — RGC {:.0}% (paper 69%), quant {:.0}% (paper 67%)",
        100.0 * sync_frac(&r128),
        100.0 * sync_frac(&q128)
    );
    assert!(
        sync_frac(&r128) > 0.4,
        "unpack must dominate resnet50's sync path at 128 GPUs"
    );

    // unpack grows linearly with p (Eq. 1 p·γ₁): 32 -> 128 should be ~4x
    let m = zoo::by_name("resnet50").unwrap();
    let machine = Machine::piz_daint();
    let u32x = simulate_iteration(&m, &machine, 32, Strategy::Rgc, &cfg).unpack;
    let u128x = simulate_iteration(&m, &machine, 128, Strategy::Rgc, &cfg).unpack;
    println!("# unpack 32->128 GPUs: {:.2}x (model predicts 4.0x)", u128x / u32x);
    assert!((u128x / u32x - 4.0).abs() < 0.2);

    // real-run cross-check: the trainer's phase timers use the same
    // vocabulary; RGC must show select+pack+unpack > 0 and dense must not
    if let Ok(manifest) =
        redsync::models::schema::Manifest::load(redsync::models::schema::Manifest::default_dir())
    {
        use redsync::config::preset;
        use redsync::coordinator::metrics::phase;
        use redsync::coordinator::Trainer;
        let mut cfg = preset("smoke").unwrap();
        cfg.steps = 10;
        let r = Trainer::new(&manifest, cfg).unwrap().run().unwrap();
        println!("\n# real lm_tiny x2 run — measured phase fractions:");
        for &p in phase::ALL {
            let f = r.phase_fraction(p);
            if f > 0.0 {
                println!("  {p:<12} {:>5.1}%", 100.0 * f);
            }
        }
        assert!(r.phases.total(phase::UNPACK) > 0.0);
    } else {
        println!("\n(artifacts not built; skipping the real-run cross-check)");
    }
}
