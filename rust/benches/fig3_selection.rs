//! Fig. 3 reproduction: communication-set selection time vs parameter
//! size for the four methods — exact top-k (the radixSelect baseline),
//! trimmed top-k (Alg. 2), threshold binary search (Alg. 3) and the
//! estimated synchronization time of the same data over a 3.5 GB/s link.
//!
//! Paper shape: exact selection grows linearly and crosses the comm time;
//! trimmed is ~38x and binary search ~16x faster at 64 MB.
//!
//! ```sh
//! cargo bench --bench fig3_selection
//! ```

use redsync::compression::{
    exact_topk, threshold_binary_search, trimmed_topk, BinarySearchParams,
};
use redsync::simnet::{allreduce_time, Machine};
use redsync::util::rng::Pcg32;
use redsync::util::timer::bench;

fn main() {
    let density = 1e-3;
    let reps = 7;
    let machine = Machine::muradin();

    println!("# Fig. 3 — selection time vs parameter size (uniform random data)");
    println!("# density {density}, median of {reps} reps; comm = 8-GPU allreduce @3.5GB/s");
    println!(
        "{:>12} {:>10} {:>11} {:>11} {:>11} {:>11} {:>8} {:>8}",
        "elems", "bytes", "exact(ms)", "trim(ms)", "bs(ms)", "comm(ms)", "x-trim", "x-bs"
    );

    let mut speedup_at_16m = (0.0, 0.0);
    for log2 in [14usize, 16, 18, 20, 22, 24] {
        let n = 1usize << log2;
        let mut rng = Pcg32::seeded(log2 as u64);
        // paper: standard uniform distribution
        let x: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let k = ((n as f64 * density).ceil() as usize).max(1);

        let te = bench(reps, || exact_topk(&x, k, None)).median;
        let tt = bench(reps, || trimmed_topk(&x, k, 0.2, None)).median;
        let tb = bench(reps, || {
            threshold_binary_search(&x, k, BinarySearchParams::default(), None)
        })
        .median;
        let comm = allreduce_time(&machine, 8, (n * 4) as f64);

        println!(
            "{:>12} {:>10} {:>11.3} {:>11.3} {:>11.3} {:>11.3} {:>7.1}x {:>7.1}x",
            n,
            redsync::util::fmt_bytes(n * 4),
            te * 1e3,
            tt * 1e3,
            tb * 1e3,
            comm * 1e3,
            te / tt,
            te / tb
        );
        if log2 == 24 {
            speedup_at_16m = (te / tt, te / tb);
        }
    }

    println!(
        "\n# paper @64MB(16Mi elems): trimmed 38.1x, binary-search 16.2x vs radixSelect"
    );
    println!(
        "# here  @64MB(16Mi elems): trimmed {:.1}x, binary-search {:.1}x vs exact top-k",
        speedup_at_16m.0, speedup_at_16m.1
    );
    assert!(
        speedup_at_16m.0 > 2.0 && speedup_at_16m.1 > 2.0,
        "selection speedup shape lost"
    );
}
