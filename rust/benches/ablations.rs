//! Ablation studies for the design choices DESIGN.md calls out — beyond
//! the paper's own tables:
//!
//! 1. selection method (exact / trimmed / binary-search) end-to-end,
//! 2. threshold-reuse interval for the sampled binary search (§5.2.2),
//! 3. tensor fusion cap (§5.3),
//! 4. density sweep (traffic vs quality),
//! 5. §5.5 policy thresholds vs everything-one-method.
//!
//! ```sh
//! make artifacts && cargo bench --bench ablations
//! ```

use redsync::compression::{
    threshold_binary_search, BinarySearchParams, CachedThresholdSelector, PolicyThresholds,
};
use redsync::config::TrainConfig;
use redsync::coordinator::train;
use redsync::simnet::iteration::Strategy;
use redsync::util::rng::Pcg32;
use redsync::util::timer::bench;

fn base() -> TrainConfig {
    TrainConfig {
        model: "lm_tiny".into(),
        world: 2,
        steps: 20,
        strategy: Strategy::Rgc,
        density: 0.02,
        thresholds: PolicyThresholds { thsd1: 512, thsd2: 8 * 1024 },
        log_every: 20,
        eval_every: 0,
        ..TrainConfig::default()
    }
}

fn main() {
    if redsync::models::schema::Manifest::load(
        redsync::models::schema::Manifest::default_dir(),
    )
    .is_err()
    {
        eprintln!("artifacts not built; run `make artifacts` first");
        std::process::exit(1);
    }

    // ---- 1. per-layer policy vs single-method (thsd sweep) ----
    println!("# ablation: §5.5 policy thresholds (lm_tiny x2, 20 steps)");
    println!("{:>28} {:>12} {:>12} {:>10}", "policy", "final loss", "traffic", "msgs");
    for (label, thsd1, thsd2) in [
        ("all dense (thsd1=inf)", usize::MAX, usize::MAX),
        ("all trimmed (1B/inf)", 1, usize::MAX),
        ("all binary-search (1B/1B)", 1, 1),
        ("paper-style mix (512/8K)", 512, 8 * 1024),
    ] {
        let cfg = TrainConfig {
            thresholds: PolicyThresholds { thsd1, thsd2 },
            ..base()
        };
        let r = train(cfg).expect("run");
        assert!(r.replicas_consistent);
        println!(
            "{label:>28} {:>12.4} {:>12} {:>10}",
            r.final_loss,
            redsync::util::fmt_bytes(r.bytes as usize),
            r.messages
        );
    }

    // ---- 2. density sweep: traffic vs quality ----
    println!("\n# ablation: density sweep (lm_tiny x2, 30 steps)");
    println!("{:>10} {:>12} {:>12} {:>14}", "density", "final loss", "traffic", "KB/step/rank");
    for density in [0.1, 0.02, 0.005, 0.001] {
        let cfg = TrainConfig { density, steps: 30, ..base() };
        let r = train(cfg).expect("run");
        println!(
            "{density:>10} {:>12.4} {:>12} {:>14.1}",
            r.final_loss,
            redsync::util::fmt_bytes(r.bytes as usize),
            r.bytes_per_step_per_rank() / 1024.0
        );
    }

    // ---- 3. fusion cap ----
    println!("\n# ablation: tensor fusion cap (messages/collectives per run)");
    println!("{:>14} {:>10} {:>12} {:>12}", "cap (elems)", "msgs", "traffic", "final loss");
    for cap in [0usize, 1 << 12, 1 << 16, 1 << 22] {
        let cfg = TrainConfig { fusion_cap_elems: cap, ..base() };
        let r = train(cfg).expect("run");
        println!(
            "{:>14} {:>10} {:>12} {:>12.4}",
            if cap == 0 { "off".to_string() } else { cap.to_string() },
            r.messages,
            redsync::util::fmt_bytes(r.bytes as usize),
            r.final_loss
        );
    }

    // ---- 4. threshold-reuse interval (§5.2.2) ----
    println!("\n# ablation: sampled binary-search reuse interval (1M elems, drifting data)");
    println!("{:>10} {:>12} {:>14}", "interval", "time (ms)", "mean |set|/k");
    let n = 1 << 20;
    let k = (n as f64 * 0.001) as usize;
    for interval in [1usize, 2, 5, 10] {
        let mut sel = CachedThresholdSelector::new(interval, BinarySearchParams::default());
        let mut rng = Pcg32::seeded(7);
        let mut x = vec![0f32; n];
        rng.fill_normal(&mut x, 1.0);
        let mut sizes = Vec::new();
        let stats = bench(10, || {
            // drift the distribution between calls (residual dynamics)
            for v in x.iter_mut().take(n / 64) {
                *v *= 1.01;
            }
            let s = sel.select(&x, k, None);
            sizes.push(s.sparse.len() as f64 / k as f64);
        });
        let mean_ratio = sizes.iter().sum::<f64>() / sizes.len() as f64;
        println!("{interval:>10} {:>12.3} {:>14.2}", stats.median * 1e3, mean_ratio);
    }

    // ---- 5. binary-search probes (J-way §Perf parameter) ----
    println!("\n# ablation: J-way bisection probes (16Mi elems, fallback path)");
    println!("{:>8} {:>12}", "probes", "time (ms)");
    let n = 1 << 24;
    let mut rng = Pcg32::seeded(9);
    // heavy-tie distribution defeats the sampling fast path -> exercises
    // the J-way ladder
    let x: Vec<f32> = (0..n).map(|_| (rng.next_f32() * 8.0).floor() / 8.0).collect();
    let k = (n as f64 * 0.001) as usize;
    for probes in [1usize, 3, 7, 15] {
        let p = BinarySearchParams { probes, ..Default::default() };
        let stats = bench(3, || threshold_binary_search(&x, k, p, None));
        println!("{probes:>8} {:>12.2}", stats.median * 1e3);
    }

    println!("\nablations complete");
}
