//! End-to-end throughput of the *real* three-layer stack: steps/sec of
//! the Rust trainer on this host for dense vs RGC vs quantized RGC, and
//! the traffic each moves — the testbed-scale counterpart of the Figs.
//! 7-9 wall-clock claims (§Perf in EXPERIMENTS.md tracks this table).
//!
//! On a 1-core CPU testbed compute dominates (like ResNet50 in the
//! paper); the *traffic* columns carry the reproduction claim, and the
//! phase split shows where the time goes.
//!
//! ```sh
//! make artifacts && cargo bench --bench e2e_throughput
//! ```

use redsync::config::{preset, TrainConfig};
use redsync::coordinator::metrics::phase;
use redsync::coordinator::train;
use redsync::simnet::iteration::Strategy;

fn bench_model(model: &str, world: usize, steps: usize) {
    println!("\n## {model} x{world}, {steps} steps");
    println!(
        "{:>10} {:>10} {:>12} {:>12} {:>9} {:>9} {:>9}",
        "strategy", "steps/s", "traffic", "KB/step/rk", "compute%", "comm%", "sync%"
    );
    let mut base = TrainConfig {
        model: model.into(),
        world,
        steps,
        thresholds: redsync::config::presets::proxy_thresholds(),
        density: 1e-3,
        log_every: steps.max(1),
        eval_every: 0,
        ..preset("smoke").unwrap()
    };
    for s in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
        base.strategy = s;
        let r = train(base.clone()).expect("run");
        assert!(r.replicas_consistent);
        let comm = r.phase_fraction(phase::COMM_DENSE) + r.phase_fraction(phase::COMM_SPARSE);
        let sync = comm
            + r.phase_fraction(phase::SELECT)
            + r.phase_fraction(phase::MASK)
            + r.phase_fraction(phase::PACK)
            + r.phase_fraction(phase::UNPACK);
        println!(
            "{:>10} {:>10.2} {:>12} {:>12.1} {:>8.1}% {:>8.1}% {:>8.1}%",
            s.label(),
            steps as f64 / r.wall_secs,
            redsync::util::fmt_bytes(r.bytes as usize),
            r.bytes_per_step_per_rank() / 1024.0,
            100.0 * r.phase_fraction(phase::COMPUTE),
            100.0 * comm,
            100.0 * sync,
        );
    }
}

fn main() {
    if redsync::models::schema::Manifest::load(
        redsync::models::schema::Manifest::default_dir(),
    )
    .is_err()
    {
        eprintln!("artifacts not built; run `make artifacts` first");
        std::process::exit(1);
    }
    bench_model("lm_tiny", 2, 40);
    bench_model("lm_small", 4, 20);
    bench_model("mlp_wide", 4, 30);
}
