//! End-to-end throughput of the *real* three-layer stack: steps/sec of
//! the Rust trainer on this host for dense vs RGC vs quantized RGC, and
//! the traffic each moves — the testbed-scale counterpart of the Figs.
//! 7-9 wall-clock claims (§Perf in EXPERIMENTS.md tracks this table).
//!
//! On a 1-core CPU testbed compute dominates (like ResNet50 in the
//! paper); the *traffic* columns carry the reproduction claim, and the
//! phase split shows where the time goes.
//!
//! ```sh
//! make artifacts && cargo bench --bench e2e_throughput
//! ```
//!
//! `--pipeline-smoke [OUT.json]` runs the artifact-free engine A/B
//! instead: the same synthetic multi-bucket sync schedule driven through
//! the `Sequential` and `Pipelined` engines over real loopback TCP,
//! asserting bit-identical parameters and reporting the wall-clock
//! ratio.  CI runs this and uploads `BENCH_pipeline.json`.
//!
//! `--topology-smoke [OUT.json]` is the flat-vs-hierarchical A/B over
//! loopback TCP (8 ranks as 2 nodes × 4): asserts the two schedules
//! stay bit-identical, reports wall-clock and measured wire bytes per
//! schedule, and the intra-node union compression the value-merging
//! reduce would add.  CI runs this and uploads `BENCH_topology.json`.
//!
//! `--hotpath-smoke [OUT.json]` is the zero-copy hot-path A/B (no
//! network at all): pack + §5.4 apply for 8 ranks at density 0.01
//! through the historical owned-decode walk vs the borrowed-view /
//! pack-in-place walk, asserting bit-identical parameters and reporting
//! the speedup.  It then runs the scalar-vs-SIMD kernel A/B: the
//! select→pack→apply chain through each runtime-detected backend
//! (scalar / SSE2 / AVX2), pinning bit-parity against the scalar oracle
//! and that no SIMD backend is slower than scalar.  CI runs this and
//! uploads `BENCH_hotpath.json`.
//!
//! `--elastic-smoke [OUT.json]` kills rank 2 of a 4-rank loopback-TCP
//! elastic run mid-training and records the recovery timeline —
//! detect → reshape → resume — plus the post-reshape consistency
//! verdict, to `BENCH_elastic.json` (uploaded by CI).
//!
//! `--obs-smoke [FABRIC] [OUT.json]` is the tracing A/B: the pipelined
//! engine with span rings + the telemetry calibrator off vs on (min of
//! 3 reps each, overhead pinned < 2%), a cross-lane overlap check on
//! the drained timeline (a comm lane's allgather in flight while
//! another lane selects/packs), and a short elastic kill leg whose
//! detect/reshape spans must land.  FABRIC picks the wire under the
//! A/B: `local` (in-process), `tcp` (default), `unix` or `mixed`.
//! Writes `trace_obs.json` (Chrome/Perfetto) next to `BENCH_obs.json`;
//! CI runs all four fabrics and uploads both files.
//!
//! `--calib-smoke [OUT.json]` is the cost-model calibration A/B
//! (acceptance for `--recalib-every`): pins that the §5.5 picker flips
//! from hierarchical to flat sparse between the `fatnode` datasheet and
//! the `fatnode-straggler` preset at 2x4, that a [`Calibrator`] fed one
//! recalibration window of straggler-truth observations re-plans to the
//! algorithm the truth machine picks (with the predicted step-time
//! improvement reported), and that switching algorithms live mid-run
//! leaves parameters bit-identical to the static target plan over real
//! loopback TCP.  CI runs this and uploads `BENCH_calib.json`.
//!
//! `--fabric-smoke [OUT.json]` is the link-class A/B: the pipelined
//! engine's small-frame storm over loopback TCP frame-per-write vs TCP
//! batched `writev` vs Unix sockets — bit-identical parameters,
//! identical socket frames, strictly fewer write syscalls when batching
//! — plus a bulk-push leg pinning Unix intra-node throughput against
//! loopback TCP.  CI runs this and uploads `BENCH_fabric.json`.
//!
//! `--ckpt-smoke [OUT.json]` is the rejoin A/B over the in-process
//! fleet: the same kill-then-rejoin schedule restored once by the
//! full-image donor stream and once by the content-addressed delta
//! rejoin (chunk repo + manifest diff), asserting bit-identical final
//! state and strictly fewer join words on the delta path, and reporting
//! chunk/dedup/verify counts.  CI runs this and uploads
//! `BENCH_ckpt.json`.

use redsync::collectives::mux::TagMux;
use redsync::collectives::{Algo, Gathered, LinkClass, LocalFabric, Topology, Transport};
use redsync::compression::message::{
    merge_plain, pack_plain, pack_plain_into, pack_quant, pack_quant_into, plain_words,
    unpack_plain, unpack_quant,
};
use redsync::compression::simd;
use redsync::compression::{trimmed_topk, Accumulation, CompressorConfig, Method, QuantizedSet};
use redsync::config::{preset, TrainConfig};
use redsync::coordinator::metrics::{param_hash, phase};
use redsync::coordinator::train;
use redsync::costmodel::{self, BucketCost, PLAIN_WIRE_BYTES};
use redsync::net::{
    free_loopback_addr, LinkClassStats, MixedFabric, MixedOptions, TcpOptions, TcpTransport,
    UnixOptions, UnixTransport,
};
use redsync::obs::Calibrator;
use redsync::pipeline::{
    build_buckets, BucketDone, LayerSpec, Pipelined, Sequential, SyncEngine, BUCKET_TAG_BASE,
};
use redsync::simnet::iteration::Strategy;
use redsync::simnet::{IntraLink, Machine};
use redsync::tensor::SparseTensor;
use redsync::util::rng::Pcg32;
use redsync::util::timer::PhaseTimer;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

fn bench_model(model: &str, world: usize, steps: usize) {
    println!("\n## {model} x{world}, {steps} steps");
    println!(
        "{:>12} {:>10} {:>12} {:>12} {:>9} {:>9} {:>9}",
        "strategy", "steps/s", "traffic", "KB/step/rk", "compute%", "comm%", "sync%"
    );
    let mut base = TrainConfig {
        model: model.into(),
        world,
        steps,
        thresholds: redsync::config::presets::proxy_thresholds(),
        density: 1e-3,
        log_every: steps.max(1),
        eval_every: 0,
        ..preset("smoke").unwrap()
    };
    // dense / RGC / quant-RGC on the sequential engine, then RGC again on
    // the pipelined engine (fused buckets so there is something to
    // overlap) — the e2e counterpart of the engine A/B below
    let runs: [(&str, Strategy, bool); 4] = [
        ("baseline", Strategy::Dense, false),
        ("RGC", Strategy::Rgc, false),
        ("quant-RGC", Strategy::QuantRgc, false),
        ("RGC+pipe", Strategy::Rgc, true),
    ];
    for (label, s, pipeline) in runs {
        base.strategy = s;
        base.pipeline = pipeline;
        base.inflight = 4;
        base.fusion_cap_elems = if pipeline { 16 * 1024 } else { base.fusion_cap_elems };
        let r = train(base.clone()).expect("run");
        assert!(r.replicas_consistent);
        let comm = r.phase_fraction(phase::COMM_DENSE) + r.phase_fraction(phase::COMM_SPARSE);
        let sync = comm
            + r.phase_fraction(phase::SELECT)
            + r.phase_fraction(phase::MASK)
            + r.phase_fraction(phase::PACK)
            + r.phase_fraction(phase::UNPACK);
        println!(
            "{:>12} {:>10.2} {:>12} {:>12.1} {:>8.1}% {:>8.1}% {:>8.1}%",
            label,
            steps as f64 / r.wall_secs,
            redsync::util::fmt_bytes(r.bytes as usize),
            r.bytes_per_step_per_rank() / 1024.0,
            100.0 * r.phase_fraction(phase::COMPUTE),
            100.0 * comm,
            100.0 * sync,
        );
    }
}

// ---------------------------------------------------------------------
// Engine A/B over loopback TCP (no artifacts needed)
// ---------------------------------------------------------------------

/// Synthetic model for the engine A/B: enough distinct buckets that the
/// pipelined engine has work to overlap.
const SMOKE_SIZES: &[usize] = &[48_000, 16_000, 16_000, 40_000, 24_000, 8_000, 32_000, 20_000];
const SMOKE_FUSION_CAP: usize = 50_000;
const SMOKE_WORLD: usize = 4;
const SMOKE_STEPS: usize = 30;
const SMOKE_DENSITY: f64 = 0.01;
const SMOKE_INFLIGHT: usize = 4;

fn smoke_specs() -> Vec<LayerSpec> {
    SMOKE_SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| LayerSpec {
            li: i,
            n,
            method: Method::TrimmedTopk,
            quantize: i % 2 == 1,
        })
        .collect()
}

fn smoke_acc() -> Accumulation {
    Accumulation::Momentum { momentum: 0.9 }
}

fn smoke_grad(rank: usize, step: usize, li: usize, n: usize) -> Vec<f32> {
    let mut rng = Pcg32::seeded(((rank as u64) << 32) ^ ((step as u64) << 8) ^ li as u64);
    let mut g = vec![0f32; n];
    rng.fill_normal(&mut g, 1.0);
    g
}

fn smoke_steps(engine: &mut dyn SyncEngine, rank: usize, world: usize) -> u64 {
    smoke_steps_plan(engine, rank, world, None, None)
}

/// The smoke schedule with plan control: an optional live algorithm
/// switch at a step barrier (the worker's `--recalib-every` protocol in
/// miniature) and an optional telemetry calibrator fed from every
/// bucket's measured collective, re-planning every 10 steps — the
/// instrumented leg of the obs A/B prices exactly what a calibrated
/// rank 0 pays.
fn smoke_steps_plan(
    engine: &mut dyn SyncEngine,
    rank: usize,
    world: usize,
    switch: Option<(usize, Algo)>,
    mut calib: Option<(Calibrator, Vec<BucketCost>)>,
) -> u64 {
    let mut params: Vec<Vec<f32>> = SMOKE_SIZES
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let mut rng = Pcg32::seeded(0xD0 ^ i as u64);
            let mut p = vec![0f32; n];
            rng.fill_normal(&mut p, 0.5);
            p
        })
        .collect();
    let scale = -0.05 / world as f32;
    let mut timer = PhaseTimer::new();
    // calib attribution only: every smoke schedule starts flat sparse
    let mut algos = vec![Algo::Sparse; engine.n_buckets()];
    let track = calib.is_some();
    let mut comm_obs: Vec<(usize, usize, f64)> = Vec::new();
    for step in 0..SMOKE_STEPS {
        if let Some((at, algo)) = switch {
            if step == at {
                algos = vec![algo; engine.n_buckets()];
                engine.set_algos(&algos);
            }
        }
        let grads: Vec<Vec<f32>> =
            SMOKE_SIZES.iter().enumerate().map(|(i, &n)| smoke_grad(rank, step, i, n)).collect();
        comm_obs.clear();
        let obs_buf = &mut comm_obs;
        engine
            .sync_step(&grads, SMOKE_DENSITY, &mut timer, &mut |done: BucketDone| {
                if track {
                    obs_buf.push((done.bucket, done.msg_words, done.comm_secs));
                }
                done.apply_to(&mut params, scale)
            })
            .expect("sync step");
        if let Some((c, costs)) = calib.as_mut() {
            for &(b, words, secs) in comm_obs.iter() {
                c.observe_bucket(b, algos[b], words, secs);
            }
            if (step + 1) % 10 == 0 {
                // flat world: the picker can only confirm the sparse
                // plan (dense is never promoted live), so this prices
                // the re-plan without perturbing the schedule
                let (_, switches) = c.replan(costs, SMOKE_DENSITY, &algos);
                assert_eq!(switches, 0, "flat re-plan must keep the sparse schedule");
            }
        }
    }
    param_hash(&params)
}

fn tcp_fabric(world: usize) -> Vec<TcpTransport> {
    let addr = free_loopback_addr();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let addr = addr.clone();
            thread::spawn(move || {
                TcpTransport::connect(&TcpOptions::new(world, rank, addr)).expect("tcp bootstrap")
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Run one engine flavor on every rank over a fresh loopback TCP mesh;
/// returns (wall seconds, per-rank param hashes).
fn smoke_run(pipelined: bool) -> (f64, Vec<u64>) {
    let cc = CompressorConfig { density: SMOKE_DENSITY, ..Default::default() };
    let acc = smoke_acc();
    let transports = tcp_fabric(SMOKE_WORLD);
    let start = Instant::now();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let (rank, world) = (t.rank(), t.world());
                let buckets = build_buckets(&smoke_specs(), SMOKE_FUSION_CAP, acc);
                if pipelined {
                    let n = buckets.len() as u32;
                    let mux = Arc::new(TagMux::new(t, BUCKET_TAG_BASE + n));
                    let mut engine = Pipelined::new(mux, buckets, SMOKE_INFLIGHT, cc);
                    smoke_steps(&mut engine, rank, world)
                } else {
                    let mut engine = Sequential::new(&t, None, buckets, cc);
                    smoke_steps(&mut engine, rank, world)
                }
            })
        })
        .collect();
    let hashes: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (start.elapsed().as_secs_f64(), hashes)
}

/// The acceptance A/B: Pipelined must beat Sequential wall-clock on a
/// multi-bucket model over loopback TCP while staying bit-identical.
fn pipeline_smoke(json_path: Option<&str>) {
    let n_buckets = build_buckets(&smoke_specs(), SMOKE_FUSION_CAP, smoke_acc()).len();
    println!(
        "# engine A/B: {} ranks x {} steps, {} layers -> {} fused buckets, density {}, inflight {}",
        SMOKE_WORLD,
        SMOKE_STEPS,
        SMOKE_SIZES.len(),
        n_buckets,
        SMOKE_DENSITY,
        SMOKE_INFLIGHT
    );
    // warm-up run to populate page cache / thread stacks fairly
    let _ = smoke_run(false);
    let (seq_secs, seq_hashes) = smoke_run(false);
    let (pipe_secs, pipe_hashes) = smoke_run(true);

    let consistent = seq_hashes.iter().all(|&h| h == seq_hashes[0])
        && pipe_hashes.iter().all(|&h| h == pipe_hashes[0]);
    let bit_identical = consistent && seq_hashes[0] == pipe_hashes[0];
    let speedup = seq_secs / pipe_secs;
    println!("{:>12} {:>10} {:>10}", "engine", "wall(s)", "steps/s");
    println!("{:>12} {:>10.3} {:>10.2}", "sequential", seq_secs, SMOKE_STEPS as f64 / seq_secs);
    println!("{:>12} {:>10.3} {:>10.2}", "pipelined", pipe_secs, SMOKE_STEPS as f64 / pipe_secs);
    println!("pipelined/sequential speedup: {speedup:.2}x, bit_identical: {bit_identical}");
    assert!(bit_identical, "engines must stay bit-identical (see tests/pipeline.rs)");

    let json = format!(
        "{{\"bench\":\"pipeline_smoke\",\"world\":{SMOKE_WORLD},\"steps\":{SMOKE_STEPS},\
         \"buckets\":{n_buckets},\"inflight\":{SMOKE_INFLIGHT},\
         \"sequential_secs\":{seq_secs:.6},\"pipelined_secs\":{pipe_secs:.6},\
         \"speedup\":{speedup:.4},\"bit_identical\":{bit_identical}}}",
    );
    if let Some(path) = json_path {
        std::fs::write(path, format!("{json}\n")).expect("write bench json");
        println!("wrote {path}");
    }
    println!("{json}");
}

// ---------------------------------------------------------------------
// Flat vs hierarchical A/B over loopback TCP (no artifacts needed)
// ---------------------------------------------------------------------

const TOPO_WORLD: usize = 8;
const TOPO: Topology = Topology { nodes: 2, ranks_per_node: 4 };

/// Run the smoke schedule on every rank of a fresh 8-rank loopback TCP
/// mesh under one collective algorithm; returns (wall seconds,
/// per-rank param hashes, total wire bytes across ranks).
fn topo_run(algo: Algo) -> (f64, Vec<u64>, u64) {
    let cc = CompressorConfig { density: SMOKE_DENSITY, ..Default::default() };
    let acc = smoke_acc();
    let transports = tcp_fabric(TOPO_WORLD);
    let stats: Vec<_> = transports.iter().map(|t| Arc::clone(&t.stats)).collect();
    let start = Instant::now();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let (rank, world) = (t.rank(), t.world());
                let mut buckets = build_buckets(&smoke_specs(), SMOKE_FUSION_CAP, acc);
                for b in &mut buckets {
                    b.set_algo(algo);
                }
                let mut engine = Sequential::with_topology(&t, TOPO, None, buckets, cc);
                smoke_steps(&mut engine, rank, world)
            })
        })
        .collect();
    let hashes: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let secs = start.elapsed().as_secs_f64();
    let bytes: u64 = stats.iter().map(|s| s.bytes()).sum();
    (secs, hashes, bytes)
}

/// The topology A/B: flat vs hierarchical schedules over loopback TCP
/// must stay bit-identical; report wall-clock, wire bytes, and the
/// extra intra-node union compression a value-merging reduce would buy.
fn topology_smoke(json_path: Option<&str>) {
    println!(
        "# topology A/B: {TOPO_WORLD} ranks as {} over loopback tcp, {} steps, density {}",
        TOPO.label(),
        SMOKE_STEPS,
        SMOKE_DENSITY
    );
    let _ = topo_run(Algo::Sparse); // warm-up
    let (flat_secs, flat_hashes, flat_bytes) = topo_run(Algo::Sparse);
    let (hier_secs, hier_hashes, hier_bytes) = topo_run(Algo::Hierarchical);

    let consistent = flat_hashes.iter().all(|&h| h == flat_hashes[0])
        && hier_hashes.iter().all(|&h| h == hier_hashes[0]);
    let bit_identical = consistent && flat_hashes[0] == hier_hashes[0];
    println!("{:>14} {:>10} {:>12}", "schedule", "wall(s)", "wire bytes");
    println!("{:>14} {:>10.3} {:>12}", "flat", flat_secs, flat_bytes);
    println!("{:>14} {:>10.3} {:>12}", "hierarchical", hier_secs, hier_bytes);
    println!("bit_identical: {bit_identical}");
    assert!(bit_identical, "schedules must stay bit-identical (see tests/topology.rs)");

    // what the value-merging intra-node union would shrink one node's
    // step-0 messages to (largest layer), vs the boundary-preserving
    // concatenation the bit-identical schedule ships
    let n0 = SMOKE_SIZES[0];
    let k = ((n0 as f64 * SMOKE_DENSITY).ceil() as usize).max(1);
    let sels: Vec<SparseTensor> = (0..TOPO.ranks_per_node)
        .map(|r| trimmed_topk(&smoke_grad(r, 0, 0, n0), k, 0.2, None).sparse)
        .collect();
    let concat_words: usize = sels.iter().map(|s| plain_words(s.len())).sum();
    let union_words = plain_words(merge_plain(&sels).len());
    println!(
        "node-0 union reduce would ship {union_words} of {concat_words} words \
         ({:.1}% of the concatenated blob)",
        100.0 * union_words as f64 / concat_words as f64
    );

    let json = format!(
        "{{\"bench\":\"topology_smoke\",\"world\":{TOPO_WORLD},\"topology\":\"{}\",\
         \"steps\":{SMOKE_STEPS},\"flat_secs\":{flat_secs:.6},\"hier_secs\":{hier_secs:.6},\
         \"flat_bytes\":{flat_bytes},\"hier_bytes\":{hier_bytes},\
         \"union_words\":{union_words},\"concat_words\":{concat_words},\
         \"bit_identical\":{bit_identical}}}",
        TOPO.label()
    );
    if let Some(path) = json_path {
        std::fs::write(path, format!("{json}\n")).expect("write bench json");
        println!("wrote {path}");
    }
    println!("{json}");
}

// ---------------------------------------------------------------------
// Zero-copy hot-path A/B: owned-decode vs view-based pack + apply
// ---------------------------------------------------------------------

const HOT_WORLD: usize = 8;
const HOT_DENSITY: f64 = 0.01;
const HOT_REPS: usize = 60;

/// The pre-zero-copy decompression walk, verbatim: every message decoded
/// into a freshly allocated tensor, then scattered.
fn hot_apply_owned(
    gathered: &[Vec<u32>],
    layers: &[(usize, bool)],
    params: &mut [Vec<f32>],
    scale: f32,
) {
    for rank_blob in gathered {
        let mut off = 0usize;
        for &(li, quantized) in layers {
            if quantized {
                let (q, used) = unpack_quant(&rank_blob[off..]).expect("well-formed blob");
                let add = q.mean * scale;
                for &i in &q.indices {
                    params[li][i as usize] += add;
                }
                off += used;
            } else {
                let (s, used) = unpack_plain(&rank_blob[off..]).expect("well-formed blob");
                s.scatter_add(&mut params[li], scale);
                off += used;
            }
        }
    }
}

/// One rank's per-layer selections for the hot-path A/B (deterministic).
fn hot_selections() -> Vec<Vec<(SparseTensor, bool)>> {
    (0..HOT_WORLD)
        .map(|rank| {
            SMOKE_SIZES
                .iter()
                .enumerate()
                .map(|(li, &n)| {
                    let k = ((n as f64 * HOT_DENSITY).ceil() as usize).max(1);
                    let quantized = li % 2 == 1;
                    let grad = smoke_grad(rank, 0, li, n);
                    let sign = if quantized { Some(1.0) } else { None };
                    (trimmed_topk(&grad, k, 0.2, sign).sparse, quantized)
                })
                .collect()
        })
        .collect()
}

/// The acceptance A/B for the zero-copy refactor: pack + apply through
/// the owned-decode path vs the view/pack-in-place path, p=8 ranks,
/// density 0.01 — bit-identical results, wall-clock ratio reported.
fn hotpath_smoke(json_path: Option<&str>) {
    let sels = hot_selections();
    let layers: Vec<(usize, bool)> = (0..SMOKE_SIZES.len()).map(|li| (li, li % 2 == 1)).collect();
    let scale = -0.05 / HOT_WORLD as f32;
    println!(
        "# hot-path A/B: {HOT_WORLD} ranks x {} layers, density {HOT_DENSITY}, {HOT_REPS} reps",
        SMOKE_SIZES.len()
    );

    let fresh_params = || -> Vec<Vec<f32>> { SMOKE_SIZES.iter().map(|&n| vec![0f32; n]).collect() };
    let quant_mean = |s: &SparseTensor| -> f32 {
        if s.is_empty() {
            0.0
        } else {
            s.values.iter().sum::<f32>() / s.len() as f32
        }
    };

    // owned-decode baseline: fresh blob Vecs per rank per step, owned
    // unpack per message per rank
    let mut owned_params = fresh_params();
    let owned = redsync::util::timer::bench(HOT_REPS, || {
        let gathered: Vec<Vec<u32>> = sels
            .iter()
            .map(|rank_sels| {
                let mut blob = Vec::new();
                for (s, quantized) in rank_sels {
                    if *quantized {
                        blob.extend(pack_quant(&QuantizedSet {
                            indices: s.indices.clone(),
                            mean: quant_mean(s),
                        }));
                    } else {
                        blob.extend(pack_plain(s));
                    }
                }
                blob
            })
            .collect();
        hot_apply_owned(&gathered, &layers, &mut owned_params, scale);
    });

    // zero-copy path: per-rank persistent blobs packed in place, views
    // applied straight off one gather buffer
    let mut view_params = fresh_params();
    let mut blobs: Vec<Vec<u32>> = (0..HOT_WORLD).map(|_| Vec::new()).collect();
    let view = redsync::util::timer::bench(HOT_REPS, || {
        for (blob, rank_sels) in blobs.iter_mut().zip(&sels) {
            blob.clear();
            for (s, quantized) in rank_sels {
                if *quantized {
                    pack_quant_into(&s.indices, quant_mean(s), blob);
                } else {
                    pack_plain_into(s, blob);
                }
            }
        }
        // one owned gather buffer, rank blocks addressed by span — the
        // shape the collectives hand to BucketDone
        let gathered = Gathered::from_parts(&blobs);
        redsync::pipeline::BucketDone {
            bucket: 0,
            layers: layers.clone(),
            gathered,
            selected: 0,
            elems: 0,
            msg_words: 0,
            comm_secs: 0.0,
        }
        .apply_to(&mut view_params, scale)
        .expect("well-formed blob");
    });

    let bit_identical = owned_params
        .iter()
        .zip(&view_params)
        .all(|(a, b)| a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
    assert!(bit_identical, "view apply diverged from owned-decode apply");

    let speedup = owned.median / view.median;
    println!("{:>14} {:>12} {:>12}", "path", "median", "min");
    println!(
        "{:>14} {:>12} {:>12}",
        "owned-decode",
        redsync::util::timer::fmt_secs(owned.median),
        redsync::util::timer::fmt_secs(owned.min)
    );
    println!(
        "{:>14} {:>12} {:>12}",
        "zero-copy",
        redsync::util::timer::fmt_secs(view.median),
        redsync::util::timer::fmt_secs(view.min)
    );
    println!("zero-copy speedup on pack+apply: {speedup:.2}x, bit_identical: {bit_identical}");

    // ---- scalar vs SIMD kernel A/B: select -> pack -> apply per backend
    let n = 1 << 18;
    let x = {
        let mut rng = redsync::util::rng::Pcg32::seeded(0x51AD);
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    };
    let k = ((n as f64 * HOT_DENSITY).ceil() as usize).max(1);
    let thr = trimmed_topk(&x, k, 0.2, None).threshold;
    let backends = simd::available();
    println!(
        "# kernel A/B: select+pack+apply, n={n}, thr={thr:.4}, active backend: {}",
        simd::active().name()
    );

    // untimed parity pass: every backend's full chain, bit-for-bit
    // against the scalar oracle
    let chain = |b: simd::Backend| -> (SparseTensor, Vec<u32>, Vec<f32>) {
        let mut sel = SparseTensor::default();
        simd::compact_gt_abs(b, &x, thr, &mut sel);
        let mut blob = Vec::new();
        simd::extend_value_bits(b, &sel.values, &mut blob);
        let mut dense = vec![0f32; n];
        simd::scatter_add_bits(b, &sel.indices, &blob, &mut dense, scale);
        (sel, blob, dense)
    };
    let (oracle_sel, oracle_blob, oracle_dense) = chain(simd::Backend::Scalar);
    for &b in &backends {
        let (sel, blob, dense) = chain(b);
        assert_eq!(sel.indices, oracle_sel.indices, "{b:?} select diverged");
        assert_eq!(blob, oracle_blob, "{b:?} pack diverged");
        assert!(
            dense.iter().zip(&oracle_dense).all(|(a, c)| a.to_bits() == c.to_bits()),
            "{b:?} apply diverged from scalar oracle"
        );
    }

    println!("{:>14} {:>12} {:>12} {:>10}", "backend", "median", "min", "vs scalar");
    let mut backend_rows = Vec::new();
    let mut scalar_median = 0.0f64;
    for &b in &backends {
        let mut sel = SparseTensor::default();
        let mut blob: Vec<u32> = Vec::new();
        let mut dense = vec![0f32; n];
        let t = redsync::util::timer::bench(HOT_REPS, || {
            let c = simd::count_gt_abs(b, &x, thr);
            sel.clear();
            simd::compact_gt_abs(b, &x, thr, &mut sel);
            assert_eq!(c, sel.len(), "count/compact disagree on {b:?}");
            blob.clear();
            simd::extend_value_bits(b, &sel.values, &mut blob);
            simd::scatter_add_bits(b, &sel.indices, &blob, &mut dense, scale);
        });
        if b == simd::Backend::Scalar {
            scalar_median = t.median;
        }
        let vs_scalar = scalar_median / t.median;
        println!(
            "{:>14} {:>12} {:>12} {:>9.2}x",
            b.name(),
            redsync::util::timer::fmt_secs(t.median),
            redsync::util::timer::fmt_secs(t.min),
            vs_scalar
        );
        // acceptance: SIMD must never lose to scalar (5% jitter allowance)
        assert!(
            vs_scalar >= 0.95,
            "{b:?} kernels slower than scalar ({vs_scalar:.2}x); \
             set REDSYNC_NO_SIMD=1 to force scalar while triaging"
        );
        backend_rows.push(format!(
            "{{\"backend\":\"{}\",\"median_secs\":{:.9},\"min_secs\":{:.9},\
             \"speedup_vs_scalar\":{vs_scalar:.4}}}",
            b.name(),
            t.median,
            t.min
        ));
    }

    let json = format!(
        "{{\"bench\":\"hotpath_smoke\",\"world\":{HOT_WORLD},\"density\":{HOT_DENSITY},\
         \"reps\":{HOT_REPS},\"owned_secs\":{:.9},\"view_secs\":{:.9},\
         \"speedup\":{speedup:.4},\"bit_identical\":{bit_identical},\
         \"simd_active\":\"{}\",\"backends\":[{}]}}",
        owned.median,
        view.median,
        simd::active().name(),
        backend_rows.join(",")
    );
    if let Some(path) = json_path {
        std::fs::write(path, format!("{json}\n")).expect("write bench json");
        println!("wrote {path}");
    }
    println!("{json}");
}

// ---------------------------------------------------------------------
// Elastic recovery smoke: detect -> reshape -> resume over loopback TCP
// ---------------------------------------------------------------------

/// 4 ranks over loopback TCP, rank 2 killed at step 8 of 16: measure
/// the survivors' recovery timeline and assert the shrunken world ends
/// replica-consistent.
fn elastic_smoke(json_path: Option<&str>) {
    use redsync::elastic::synthetic::{self, SyntheticWorkload};
    use redsync::elastic::{
        fresh_checkpoint, run_elastic_worker, ElasticOpts, ElasticStatus, FaultSpec,
    };
    use std::time::Duration;

    const WORLD: usize = 4;
    const STEPS: usize = 16;
    const KILL_AT: usize = 8;
    let seed = 0xE1A5u64;
    let opts = ElasticOpts {
        steps: STEPS,
        fusion_cap_elems: 3000,
        heartbeat: Duration::from_millis(50),
        log_every: STEPS,
        kill: vec![FaultSpec { rank: 2, step: KILL_AT }],
        ..ElasticOpts::default()
    };
    println!(
        "# elastic smoke: {WORLD} ranks over loopback tcp, {STEPS} steps, \
         kill rank 2 @ step {KILL_AT}, heartbeat {}ms",
        opts.heartbeat.as_millis()
    );

    let transports = tcp_fabric(WORLD);
    let start = Instant::now();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            let opts = opts.clone();
            thread::spawn(move || {
                let specs = synthetic::specs();
                let init =
                    fresh_checkpoint(synthetic::init_params(seed), &specs, opts.optimizer, seed);
                let mut w = SyntheticWorkload { seed };
                run_elastic_worker(&t, &specs, init, None, &opts, &mut w).expect("elastic rank")
            })
        })
        .collect();
    let outs: Vec<_> = handles.into_iter().map(|h| h.join().expect("rank")).collect();
    let total_secs = start.elapsed().as_secs_f64();

    assert_eq!(outs[2].status, ElasticStatus::Killed);
    let survivors = [0usize, 1, 3];
    let consistent = survivors.iter().all(|&r| {
        outs[r].status == ElasticStatus::Finished && outs[r].replicas_consistent
    });
    assert!(consistent, "survivors must finish replica-consistent");
    let event = outs[0].events.first().expect("membership event");
    println!("{:>14} {:>12}", "phase", "seconds");
    println!("{:>14} {:>12.4}", "detect", event.detect_secs);
    println!("{:>14} {:>12.4}", "reshape", event.reshape_secs);
    println!("{:>14} {:>12.4}", "run total", total_secs);
    println!(
        "lost {:?} -> {} ranks at epoch {}, resumed from step {}",
        event.lost, event.world_after, event.epoch, event.resume_step
    );

    let json = format!(
        "{{\"bench\":\"elastic_smoke\",\"world\":{WORLD},\"steps\":{STEPS},\
         \"kill_step\":{KILL_AT},\"detect_secs\":{:.6},\"reshape_secs\":{:.6},\
         \"total_secs\":{total_secs:.6},\"resume_step\":{},\"world_after\":{},\
         \"consistent\":{consistent}}}",
        event.detect_secs, event.reshape_secs, event.resume_step, event.world_after
    );
    if let Some(path) = json_path {
        std::fs::write(path, format!("{json}\n")).expect("write bench json");
        println!("wrote {path}");
    }
    println!("{json}");
}

// ---------------------------------------------------------------------
// Checkpoint-repository smoke: delta rejoin vs full-image A/B
// ---------------------------------------------------------------------

/// 4 ranks over the in-process fleet, rank 2 killed at step 6 and
/// rejoined at step 12 of 18: restore the rejoiner once by the
/// full-image donor stream and once by the content-addressed delta
/// rejoin, assert bit-identical final state, and report the wire words
/// each join moved plus the repo's chunk accounting.
fn ckpt_smoke(json_path: Option<&str>) {
    use redsync::elastic::synthetic::{self, FrozenWorkload};
    use redsync::elastic::{
        fresh_checkpoint, run_local_fleet, ElasticOpts, ElasticStatus, FaultSpec, FleetOutcome,
    };
    use std::time::Duration;

    const WORLD: usize = 4;
    const STEPS: usize = 18;
    const KILL_AT: usize = 6;
    const REJOIN_AT: usize = 12;
    let seed = 0xE1A5u64;
    // layers 0/3/4 frozen: their chunks survive the kill untouched, so
    // the delta rejoin has real content to dedup (the Gaussian workload
    // would dirty every chunk and degenerate to a full image)
    let frozen = vec![0usize, 3, 4];
    let dir = std::env::temp_dir().join(format!("redsync_ckpt_smoke_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("tmp dir");

    let run = |tag: &str, full_image: bool| -> FleetOutcome {
        let prefix = dir.join(tag).to_string_lossy().into_owned();
        let opts = ElasticOpts {
            steps: STEPS,
            fusion_cap_elems: 3000,
            heartbeat: Duration::from_millis(50),
            log_every: STEPS,
            kill: vec![FaultSpec { rank: 2, step: KILL_AT }],
            rejoin: vec![FaultSpec { rank: 2, step: REJOIN_AT }],
            ckpt_prefix: Some(prefix.clone()),
            ckpt_every: KILL_AT,
            ckpt_repo: Some(format!("{prefix}_repo")),
            rejoin_full_image: full_image,
            ..ElasticOpts::default()
        };
        let specs = synthetic::specs();
        let frozen = frozen.clone();
        run_local_fleet(
            WORLD,
            &specs,
            &opts,
            |_r| {
                Ok(fresh_checkpoint(
                    synthetic::init_params(seed),
                    &synthetic::specs(),
                    opts.optimizer,
                    seed,
                ))
            },
            move |_r| Ok(FrozenWorkload { seed, frozen: frozen.clone() }),
        )
        .expect("fleet")
    };

    println!(
        "# ckpt smoke: {WORLD} ranks in-process, {STEPS} steps, kill rank 2 @ {KILL_AT}, \
         rejoin @ {REJOIN_AT}; full-image vs chunk-delta rejoin"
    );
    let start = Instant::now();
    let full = run("full", true);
    let full_secs = start.elapsed().as_secs_f64();
    let start = Instant::now();
    let delta = run("delta", false);
    let delta_secs = start.elapsed().as_secs_f64();

    for (label, fleet) in [("full", &full), ("delta", &delta)] {
        for (rank, out) in fleet.ranks.iter().enumerate() {
            assert_eq!(out.status, ElasticStatus::Finished, "{label} rank {rank}");
            assert!(out.replicas_consistent, "{label} rank {rank}");
        }
    }
    let bit_identical = full.ranks[0].param_hash == delta.ranks[0].param_hash;
    assert!(bit_identical, "both rejoin flavors must restore the same bytes");

    let join_sum = |f: &FleetOutcome| -> u64 { f.ranks.iter().map(|o| o.rejoin.join_words).sum() };
    let full_words = join_sum(&full);
    let delta_words = join_sum(&delta);
    assert!(
        delta_words < full_words,
        "delta rejoin must move fewer words ({delta_words} vs {full_words})"
    );
    let rj = &delta.ranks[2].rejoin;
    let repo_sum = |pick: fn(&redsync::coordinator::metrics::RepoStats) -> u64| -> u64 {
        delta.ranks.iter().map(|o| pick(&o.repo)).sum()
    };

    println!("{:>12} {:>12} {:>10}", "rejoin", "join words", "wall(s)");
    println!("{:>12} {:>12} {:>10.3}", "full-image", full_words, full_secs);
    println!("{:>12} {:>12} {:>10.3}", "delta", delta_words, delta_secs);
    println!(
        "delta moved {:.1}% of the full image: {} fetched / {} reused / {} verified chunks",
        100.0 * delta_words as f64 / full_words as f64,
        rj.fetched_chunks,
        rj.reused_chunks,
        rj.verified_chunks
    );
    println!(
        "repo: {} manifests, {} chunks written / {} deduped / {} collected",
        repo_sum(|r| r.manifests_written),
        repo_sum(|r| r.chunks_written),
        repo_sum(|r| r.chunks_deduped),
        repo_sum(|r| r.chunks_collected)
    );

    let json = format!(
        "{{\"bench\":\"ckpt_smoke\",\"world\":{WORLD},\"steps\":{STEPS},\
         \"kill_step\":{KILL_AT},\"rejoin_step\":{REJOIN_AT},\
         \"full_image_words\":{full_words},\"delta_words\":{delta_words},\
         \"delta_fraction\":{:.6},\"fetched_chunks\":{},\"reused_chunks\":{},\
         \"verified_chunks\":{},\"retries\":{},\"failovers\":{},\
         \"chunks_written\":{},\"chunks_deduped\":{},\"chunks_collected\":{},\
         \"manifests_written\":{},\"full_secs\":{full_secs:.6},\
         \"delta_secs\":{delta_secs:.6},\"bit_identical\":{bit_identical}}}",
        delta_words as f64 / full_words as f64,
        rj.fetched_chunks,
        rj.reused_chunks,
        rj.verified_chunks,
        rj.retries,
        rj.failovers,
        repo_sum(|r| r.chunks_written),
        repo_sum(|r| r.chunks_deduped),
        repo_sum(|r| r.chunks_collected),
        repo_sum(|r| r.manifests_written)
    );
    if let Some(path) = json_path {
        std::fs::write(path, format!("{json}\n")).expect("write bench json");
        println!("wrote {path}");
    }
    println!("{json}");
}

// ---------------------------------------------------------------------
// Observability smoke: tracing overhead + cross-lane overlap
// ---------------------------------------------------------------------

const OBS_REPS: usize = 3;

/// True iff some comm lane's allgather span overlaps a *different*
/// lane's select or pack span on the same rank — the visible proof the
/// pipelined engine actually overlaps communication with selection.
fn has_cross_lane_overlap(dumps: &[redsync::obs::RankDump]) -> bool {
    use redsync::obs::{SPAN_COMM_SPARSE, SPAN_PACK, SPAN_SELECT};
    dumps.iter().any(|d| {
        d.lanes.iter().any(|a| {
            a.spans.iter().filter(|s| s.phase == SPAN_COMM_SPARSE).any(|s| {
                d.lanes.iter().filter(|b| b.lane != a.lane).any(|b| {
                    b.spans.iter().any(|o| {
                        (o.phase == SPAN_SELECT || o.phase == SPAN_PACK)
                            && o.t0_us < s.t1_us
                            && s.t0_us < o.t1_us
                    })
                })
            })
        })
    })
}

/// Unique Unix namespace per obs leg (the A/B reruns the same fabric
/// several times in one process).
static OBS_NS: AtomicU32 = AtomicU32::new(0);

/// Mixed link-class mesh on this host: Unix sockets inside each modeled
/// node, TCP across nodes (the `--transport auto` wire; see
/// tests/fabric.rs).
fn mixed_fabric(world: usize, topo: Topology) -> Vec<MixedFabric> {
    let addr = free_loopback_addr();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let addr = addr.clone();
            thread::spawn(move || {
                MixedFabric::connect(&MixedOptions::new(world, rank, addr, topo))
                    .expect("mixed bootstrap")
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Run the pipelined smoke schedule on every rank of `transports`.
/// When `calib_link` is set, rank 0 also runs the telemetry calibrator
/// over that link class — per-bucket observe plus a periodic re-plan —
/// so the traced leg prices exactly what a calibrated trainer pays.
fn pipelined_run_on<T: Transport + Send + 'static>(
    transports: Vec<T>,
    calib_link: Option<IntraLink>,
) -> (f64, Vec<u64>) {
    let cc = CompressorConfig { density: SMOKE_DENSITY, ..Default::default() };
    let acc = smoke_acc();
    let start = Instant::now();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let (rank, world) = (t.rank(), t.world());
                let buckets = build_buckets(&smoke_specs(), SMOKE_FUSION_CAP, acc);
                let link = if rank == 0 { calib_link } else { None };
                let calib = link.map(|l| {
                    let costs: Vec<BucketCost> = buckets
                        .iter()
                        .map(|b| BucketCost {
                            m_elems: b.specs().map(|s| s.n as f64).sum(),
                            t_select: 0.0,
                            wire_bytes: PLAIN_WIRE_BYTES,
                        })
                        .collect();
                    let c = Calibrator::new(Machine::fatnode(), Some(l), 1, world, buckets.len());
                    (c, costs)
                });
                let n = buckets.len() as u32;
                let mux = Arc::new(TagMux::new(t, BUCKET_TAG_BASE + n));
                let mut engine = Pipelined::new(mux, buckets, SMOKE_INFLIGHT, cc);
                smoke_steps_plan(&mut engine, rank, world, None, calib)
            })
        })
        .collect();
    let hashes: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (start.elapsed().as_secs_f64(), hashes)
}

/// One obs A/B leg on the named fabric; `calibrate` adds the rank-0
/// telemetry calibrator (the instrumented configuration under test).
fn obs_fabric_run(fabric: &str, calibrate: bool) -> (f64, Vec<u64>) {
    let link = |l: IntraLink| if calibrate { Some(l) } else { None };
    match fabric {
        "local" => {
            let mut f = LocalFabric::new(SMOKE_WORLD);
            pipelined_run_on(f.take_all(), link(IntraLink::Smp))
        }
        "tcp" => pipelined_run_on(tcp_fabric(SMOKE_WORLD), link(IntraLink::Loopback)),
        "unix" => {
            let ns = bench_ns(&format!("obs{}", OBS_NS.fetch_add(1, Ordering::Relaxed)));
            pipelined_run_on(unix_fabric(SMOKE_WORLD, &ns), link(IntraLink::Unix))
        }
        "mixed" => {
            let topo = Topology { nodes: 2, ranks_per_node: SMOKE_WORLD / 2 };
            pipelined_run_on(mixed_fabric(SMOKE_WORLD, topo), link(IntraLink::Unix))
        }
        other => panic!("unknown obs fabric '{other}' (local|tcp|unix|mixed)"),
    }
}

/// The observability A/B: span tracing plus the telemetry calibrator
/// must cost < 2% wall-clock on the pipelined engine, the drained
/// timeline must show cross-lane overlap, and an elastic kill must land
/// detect/reshape spans.
fn obs_smoke(fabric: &str, json_path: Option<&str>) {
    use redsync::obs::{self, RankDump};

    println!(
        "# obs A/B: {SMOKE_WORLD} ranks x {SMOKE_STEPS} steps, pipelined over {fabric}, \
         spans+calibrator off vs on, min of {OBS_REPS}"
    );
    let _ = obs_fabric_run(fabric, false); // warm-up
    let mut base = f64::MAX;
    for _ in 0..OBS_REPS {
        base = base.min(obs_fabric_run(fabric, false).0);
    }

    obs::set_enabled(true);
    let mut traced = f64::MAX;
    let mut dumps: Vec<RankDump> = Vec::new();
    for _ in 0..OBS_REPS {
        traced = traced.min(obs_fabric_run(fabric, true).0);
        // keep the last rep's timeline; draining every rep also keeps
        // the global registry from accumulating one ring set per engine
        dumps = (0..SMOKE_WORLD)
            .map(|r| RankDump { rank: r as u32, lanes: obs::drain_rank(r) })
            .collect();
    }
    obs::set_enabled(false);

    let spans = obs::span_count(&dumps);
    let overlap = has_cross_lane_overlap(&dumps);
    let overhead = traced / base - 1.0;
    println!("{:>10} {:>10} {:>10}", "tracing", "wall(s)", "steps/s");
    println!("{:>10} {:>10.3} {:>10.2}", "off", base, SMOKE_STEPS as f64 / base);
    println!("{:>10} {:>10.3} {:>10.2}", "on", traced, SMOKE_STEPS as f64 / traced);
    println!(
        "tracing overhead: {:.2}%, {spans} spans, cross-lane overlap: {overlap}",
        100.0 * overhead
    );
    assert!(spans > 0, "the traced run must record spans");
    assert!(overlap, "comm must overlap another lane's select/pack (pipelined engine)");
    assert!(
        overhead < 0.02,
        "tracing+calibration costs {:.2}% (> 2%) over {fabric}: \
         {base:.3}s off vs {traced:.3}s on",
        100.0 * overhead
    );

    let trace_path = "trace_obs.json";
    obs::write_chrome_trace(trace_path, &dumps).expect("write trace");
    println!("wrote {trace_path} ({spans} spans)");

    // short elastic kill leg: the recovery machinery must land its own
    // spans (retrospective detect + the reshape guard on the driver lane)
    use redsync::elastic::synthetic::{self, SyntheticWorkload};
    use redsync::elastic::{
        fresh_checkpoint, run_elastic_worker, ElasticOpts, ElasticStatus, FaultSpec,
    };
    use std::time::Duration;
    const EWORLD: usize = 4;
    let seed = 0xB0B5u64;
    let opts = ElasticOpts {
        steps: 12,
        fusion_cap_elems: 3000,
        heartbeat: Duration::from_millis(50),
        log_every: 12,
        kill: vec![FaultSpec { rank: 2, step: 6 }],
        ..ElasticOpts::default()
    };
    obs::set_enabled(true);
    let transports = tcp_fabric(EWORLD);
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            let opts = opts.clone();
            thread::spawn(move || {
                let specs = synthetic::specs();
                let init =
                    fresh_checkpoint(synthetic::init_params(seed), &specs, opts.optimizer, seed);
                let mut w = SyntheticWorkload { seed };
                run_elastic_worker(&t, &specs, init, None, &opts, &mut w).expect("elastic rank")
            })
        })
        .collect();
    let outs: Vec<_> = handles.into_iter().map(|h| h.join().expect("rank")).collect();
    obs::set_enabled(false);
    assert_eq!(outs[2].status, ElasticStatus::Killed);
    let elastic_lanes: Vec<_> = (0..EWORLD).flat_map(obs::drain_rank).collect();
    let phase_count = |p: u32| {
        elastic_lanes.iter().flat_map(|l| &l.spans).filter(|s| s.phase == p).count()
    };
    let detects = phase_count(obs::SPAN_DETECT);
    let reshapes = phase_count(obs::SPAN_RESHAPE);
    println!("elastic leg: {detects} detect spans, {reshapes} reshape spans");
    assert!(reshapes > 0, "the kill must land at least one reshape span");

    let json = format!(
        "{{\"bench\":\"obs_smoke\",\"fabric\":\"{fabric}\",\"world\":{SMOKE_WORLD},\
         \"steps\":{SMOKE_STEPS},\
         \"reps\":{OBS_REPS},\"base_secs\":{base:.6},\"traced_secs\":{traced:.6},\
         \"overhead_pct\":{:.4},\"spans\":{spans},\"cross_lane_overlap\":{overlap},\
         \"detect_spans\":{detects},\"reshape_spans\":{reshapes}}}",
        100.0 * overhead
    );
    if let Some(path) = json_path {
        std::fs::write(path, format!("{json}\n")).expect("write bench json");
        println!("wrote {path}");
    }
    println!("{json}");
}

// ---------------------------------------------------------------------
// Calibration smoke: straggler flip + one-window recovery + live switch
// ---------------------------------------------------------------------

/// Run the smoke schedule over a fresh 8-rank loopback TCP mesh with
/// the 2x4 topology, starting every bucket on `start` and optionally
/// switching all buckets live at a step barrier; returns (wall secs,
/// per-rank param hashes).
fn topo_run_plan(start: Algo, switch: Option<(usize, Algo)>) -> (f64, Vec<u64>) {
    let cc = CompressorConfig { density: SMOKE_DENSITY, ..Default::default() };
    let acc = smoke_acc();
    let transports = tcp_fabric(TOPO_WORLD);
    let started = Instant::now();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let (rank, world) = (t.rank(), t.world());
                let mut buckets = build_buckets(&smoke_specs(), SMOKE_FUSION_CAP, acc);
                for b in &mut buckets {
                    b.set_algo(start);
                }
                let mut engine = Sequential::with_topology(&t, TOPO, None, buckets, cc);
                smoke_steps_plan(&mut engine, rank, world, switch, None)
            })
        })
        .collect();
    let hashes: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (started.elapsed().as_secs_f64(), hashes)
}

/// The calibration A/B (acceptance for `--recalib-every`): the §5.5
/// picker must flip between the `fatnode` datasheet and the
/// `fatnode-straggler` truth, a [`Calibrator`] fed one recalibration
/// window of straggler-truth observations must re-plan to the truth
/// machine's choice with a predicted step-time improvement, and a live
/// mid-run switch must stay bit-identical to the static target plan
/// over real loopback TCP.
fn calib_smoke(json_path: Option<&str>) {
    const CAL_NODES: usize = 2;
    const CAL_RPN: usize = 4;
    const CAL_DENSITY: f64 = 1e-3;
    const CAL_WINDOW: usize = 16;
    let datasheet = Machine::fatnode();
    let truth = Machine::fatnode_straggler();
    println!(
        "# calib A/B: {CAL_NODES}x{CAL_RPN} picker flip + one-window recovery, \
         then live switch over {TOPO_WORLD}-rank loopback tcp"
    );

    // 1. the static datasheet plan is provably wrong on the straggler
    let grid = [4e6, 16e6, 64e6];
    for m_elems in grid {
        let cost = BucketCost { m_elems, t_select: 0.0, wire_bytes: PLAIN_WIRE_BYTES };
        let (h, _) = costmodel::pick_algo(&datasheet, CAL_NODES, CAL_RPN, &cost, CAL_DENSITY);
        let (s, _) = costmodel::pick_algo(&truth, CAL_NODES, CAL_RPN, &cost, CAL_DENSITY);
        println!("  {m_elems:>9.1e} elems: datasheet {h:?}, straggler truth {s:?}");
        assert_eq!(h, Algo::Hierarchical, "datasheet pick for {m_elems:e} elems");
        assert_eq!(s, Algo::Sparse, "straggler pick for {m_elems:e} elems");
    }

    // 2. one recalibration window of straggler-truth observations flips
    // the calibrated re-plan to the truth machine's choice
    let costs: Vec<BucketCost> = grid[..2]
        .iter()
        .map(|&m| BucketCost { m_elems: m, t_select: 0.0, wire_bytes: PLAIN_WIRE_BYTES })
        .collect();
    let current = vec![Algo::Hierarchical; costs.len()];
    let mut calib = Calibrator::new(datasheet.clone(), None, CAL_NODES, CAL_RPN, costs.len());
    let coeffs = costmodel::comm_coeffs(Algo::Hierarchical, CAL_NODES, CAL_RPN);
    for _ in 0..CAL_WINDOW {
        for (b, cost) in costs.iter().enumerate() {
            // the packed blob: D·m index/value pairs, two words each
            let words = (cost.m_elems * CAL_DENSITY * 2.0) as usize;
            let bytes = 4.0 * words as f64;
            let secs = coeffs.inter_rounds * truth.alpha
                + coeffs.inter_bytes * bytes * truth.beta
                + coeffs.intra_rounds * truth.intra_alpha
                + coeffs.intra_bytes * bytes * truth.intra_beta;
            calib.observe_bucket(b, Algo::Hierarchical, words, secs);
        }
    }
    let (next, switches) = calib.replan(&costs, CAL_DENSITY, &current);
    assert_eq!(next, vec![Algo::Sparse; costs.len()], "calibrated re-plan must flip to sparse");
    assert_eq!(switches, costs.len() as u64);
    let s = calib.summary();
    // the improvement the switch buys, priced on the truth machine:
    // modeled hierarchical vs flat-sparse step time ([dense, sparse, hier])
    let (mut t_old, mut t_new) = (0.0f64, 0.0f64);
    for cost in &costs {
        let (_, t) = costmodel::pick_algo(&truth, CAL_NODES, CAL_RPN, cost, CAL_DENSITY);
        t_old += t[2];
        t_new += t[1];
    }
    let improvement = t_old / t_new;
    println!(
        "calibrated re-plan: {switches} switches, link α {:.1}µs β {:.2} GB/s, \
         plan error x{:.2}, predicted step-time improvement {improvement:.2}x",
        s.alpha_us,
        s.beta_gbps,
        s.error_ratio()
    );
    assert!(s.error_ratio() > 1.5, "datasheet plan must under-predict: {}", s.error_ratio());
    assert!(improvement > 1.0, "the flip must be predicted to improve step time");

    // 3. live switch over real wire: static hier, static sparse, and a
    // mid-run hier->sparse switch must all end bit-identical
    let _ = topo_run_plan(Algo::Sparse, None); // warm-up
    let (hier_secs, hier_hashes) = topo_run_plan(Algo::Hierarchical, None);
    let (sparse_secs, sparse_hashes) = topo_run_plan(Algo::Sparse, None);
    let (switch_secs, switch_hashes) =
        topo_run_plan(Algo::Hierarchical, Some((SMOKE_STEPS / 2, Algo::Sparse)));
    let consistent = [&hier_hashes, &sparse_hashes, &switch_hashes]
        .iter()
        .all(|h| h.iter().all(|&x| x == h[0]));
    let bit_identical =
        consistent && hier_hashes[0] == sparse_hashes[0] && sparse_hashes[0] == switch_hashes[0];
    println!("{:>16} {:>10}", "plan", "wall(s)");
    println!("{:>16} {:>10.3}", "static hier", hier_secs);
    println!("{:>16} {:>10.3}", "static sparse", sparse_secs);
    println!("{:>16} {:>10.3}", "hier->sparse", switch_secs);
    println!("bit_identical: {bit_identical}");
    assert!(bit_identical, "a live mid-run switch must not perturb the parameters");

    let json = format!(
        "{{\"bench\":\"calib_smoke\",\"nodes\":{CAL_NODES},\"ranks_per_node\":{CAL_RPN},\
         \"window\":{CAL_WINDOW},\"switches\":{switches},\"alpha_us\":{:.3},\
         \"beta_gbps\":{:.3},\"plan_error_ratio\":{:.4},\
         \"predicted_improvement\":{improvement:.4},\"hier_secs\":{hier_secs:.6},\
         \"sparse_secs\":{sparse_secs:.6},\"switched_secs\":{switch_secs:.6},\
         \"bit_identical\":{bit_identical}}}",
        s.alpha_us,
        s.beta_gbps,
        s.error_ratio()
    );
    if let Some(path) = json_path {
        std::fs::write(path, format!("{json}\n")).expect("write bench json");
        println!("wrote {path}");
    }
    println!("{json}");
}

// ---------------------------------------------------------------------
// Fabric A/B: frame-per-write vs batched writev, loopback TCP vs Unix
// ---------------------------------------------------------------------

const BULK_FRAME_WORDS: usize = 1 << 18; // 1 MiB of payload per frame
const BULK_FRAMES: usize = 48;

/// Unique Unix-socket namespace per leg, so a leg never trips over the
/// previous one's rendezvous file.
fn bench_ns(tag: &str) -> String {
    format!("/tmp/rs-bench-fab-{}-{tag}", std::process::id())
}

/// Loopback TCP mesh with an explicit write-batching setting.
fn tcp_fabric_batched(world: usize, batch: bool) -> Vec<TcpTransport> {
    let addr = free_loopback_addr();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut opts = TcpOptions::new(world, rank, addr);
                opts.batch = batch;
                TcpTransport::connect(&opts).expect("tcp bootstrap")
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Same-host Unix-socket mesh, batched writes on.
fn unix_fabric(world: usize, ns: &str) -> Vec<UnixTransport> {
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let ns = ns.to_string();
            thread::spawn(move || {
                let mut opts = UnixOptions::new(world, rank, ns);
                opts.batch = true;
                UnixTransport::connect(&opts).expect("unix bootstrap")
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Run the pipelined smoke schedule on every rank of `transports`;
/// returns (wall secs, per-rank hashes, socket frames, write syscalls).
/// The transports drop inside the rank threads (writers joined), so the
/// syscall counts read from `link_stats` afterwards are final.
fn fabric_engine_run<T: Transport + Send + 'static>(
    transports: Vec<T>,
    link_stats: Vec<Arc<LinkClassStats>>,
) -> (f64, Vec<u64>, u64, u64) {
    let cc = CompressorConfig { density: SMOKE_DENSITY, ..Default::default() };
    let acc = smoke_acc();
    let start = Instant::now();
    let handles: Vec<_> = transports
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let (rank, world) = (t.rank(), t.world());
                let buckets = build_buckets(&smoke_specs(), SMOKE_FUSION_CAP, acc);
                let n = buckets.len() as u32;
                let mux = Arc::new(TagMux::new(t, BUCKET_TAG_BASE + n));
                let mut engine = Pipelined::new(mux, buckets, SMOKE_INFLIGHT, cc);
                smoke_steps(&mut engine, rank, world)
            })
        })
        .collect();
    let hashes: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let secs = start.elapsed().as_secs_f64();
    let socket: Vec<_> = link_stats
        .iter()
        .flat_map(|s| s.snapshot())
        .filter(|l| l.class != LinkClass::Mem)
        .collect();
    let frames: u64 = socket.iter().map(|l| l.frames).sum();
    let writes: u64 = socket.iter().map(|l| l.writes).sum();
    (secs, hashes, frames, writes)
}

/// Push [`BULK_FRAMES`] 1-MiB frames rank 0 -> rank 1 and wait for the
/// ack; returns elapsed seconds.
fn bulk_push_secs<T: Transport + Send + 'static>(pair: Vec<T>) -> f64 {
    let mut it = pair.into_iter();
    let t0 = it.next().expect("rank 0");
    let t1 = it.next().expect("rank 1");
    let start = Instant::now();
    let h = thread::spawn(move || {
        let msg = Arc::new((0..BULK_FRAME_WORDS as u32).collect::<Vec<u32>>());
        for _ in 0..BULK_FRAMES {
            t0.send_shared(1, &msg);
        }
        t0.recv(1)
    });
    for i in 0..BULK_FRAMES {
        assert_eq!(t1.recv(0).len(), BULK_FRAME_WORDS, "bulk frame {i} truncated");
    }
    t1.send(0, vec![1]);
    assert_eq!(h.join().unwrap(), vec![1]);
    start.elapsed().as_secs_f64()
}

/// The fabric A/B (acceptance for the link-class fabrics): same
/// pipelined schedule, three wire setups, bit-identical parameters and
/// identical socket frames — only the syscall count and the wall clock
/// may move.
fn fabric_smoke(json_path: Option<&str>) {
    println!(
        "# fabric A/B: {SMOKE_WORLD} ranks x {SMOKE_STEPS} steps pipelined, \
         tcp frame-per-write vs tcp batched vs unix batched"
    );
    let run_tcp = |batch: bool| {
        let ts = tcp_fabric_batched(SMOKE_WORLD, batch);
        let ls: Vec<_> = ts.iter().map(|t| t.link_stats()).collect();
        fabric_engine_run(ts, ls)
    };
    let run_unix = |tag: &str| {
        let ts = unix_fabric(SMOKE_WORLD, &bench_ns(tag));
        let ls: Vec<_> = ts.iter().map(|t| t.link_stats()).collect();
        fabric_engine_run(ts, ls)
    };
    let _ = run_tcp(true); // warm-up
    let (plain_secs, plain_hashes, plain_frames, plain_writes) = run_tcp(false);
    let (batch_secs, batch_hashes, batch_frames, batch_writes) = run_tcp(true);
    let (unix_secs, unix_hashes, unix_frames, unix_writes) = run_unix("engine");

    let consistent = [&plain_hashes, &batch_hashes, &unix_hashes]
        .iter()
        .all(|h| h.iter().all(|&x| x == h[0]));
    let bit_identical =
        consistent && plain_hashes[0] == batch_hashes[0] && batch_hashes[0] == unix_hashes[0];
    assert!(bit_identical, "fabrics must stay bit-identical (see tests/fabric.rs)");
    assert_eq!(plain_frames, batch_frames, "batching must never move frame boundaries");
    assert_eq!(plain_frames, unix_frames, "the unix fabric must ship the same frames");
    assert_eq!(plain_writes, plain_frames, "frame-per-write is exactly one syscall per frame");
    assert!(
        batch_writes < plain_writes,
        "batched writev must take strictly fewer syscalls ({batch_writes} vs {plain_writes})"
    );
    assert!(
        unix_writes < plain_writes,
        "unix batched writes must take strictly fewer syscalls ({unix_writes} vs {plain_writes})"
    );

    let fpw = |frames: u64, writes: u64| frames as f64 / writes.max(1) as f64;
    println!(
        "{:>16} {:>10} {:>10} {:>10} {:>13}",
        "fabric", "wall(s)", "frames", "writes", "frames/write"
    );
    for (label, secs, frames, writes) in [
        ("tcp frame/write", plain_secs, plain_frames, plain_writes),
        ("tcp batched", batch_secs, batch_frames, batch_writes),
        ("unix batched", unix_secs, unix_frames, unix_writes),
    ] {
        println!(
            "{label:>16} {secs:>10.3} {frames:>10} {writes:>10} {:>13.2}",
            fpw(frames, writes)
        );
    }

    // bulk push: the raw bandwidth question, min of 3 to damp scheduler
    // noise on shared CI hosts
    let mut tcp_bulk = f64::MAX;
    let mut unix_bulk = f64::MAX;
    for rep in 0..3 {
        tcp_bulk = tcp_bulk.min(bulk_push_secs(tcp_fabric_batched(2, true)));
        let ns = bench_ns(&format!("bulk{rep}"));
        unix_bulk = unix_bulk.min(bulk_push_secs(unix_fabric(2, &ns)));
    }
    let mb = (BULK_FRAMES * BULK_FRAME_WORDS * 4) as f64 / 1e6;
    let tcp_mbps = mb / tcp_bulk;
    let unix_mbps = mb / unix_bulk;
    println!("bulk push ({mb:.0} MB): tcp {tcp_mbps:.0} MB/s, unix {unix_mbps:.0} MB/s");
    assert!(
        unix_mbps >= 0.9 * tcp_mbps,
        "unix intra-node throughput regressed below loopback tcp: \
         {unix_mbps:.0} vs {tcp_mbps:.0} MB/s"
    );

    let json = format!(
        "{{\"bench\":\"fabric_smoke\",\"world\":{SMOKE_WORLD},\"steps\":{SMOKE_STEPS},\
         \"tcp_unbatched_secs\":{plain_secs:.6},\"tcp_batched_secs\":{batch_secs:.6},\
         \"unix_secs\":{unix_secs:.6},\"socket_frames\":{plain_frames},\
         \"tcp_unbatched_writes\":{plain_writes},\"tcp_batched_writes\":{batch_writes},\
         \"unix_writes\":{unix_writes},\"tcp_bulk_mbps\":{tcp_mbps:.1},\
         \"unix_bulk_mbps\":{unix_mbps:.1},\"bit_identical\":{bit_identical}}}"
    );
    if let Some(path) = json_path {
        std::fs::write(path, format!("{json}\n")).expect("write bench json");
        println!("wrote {path}");
    }
    println!("{json}");
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if let Some(pos) = args.iter().position(|a| a == "--pipeline-smoke") {
        pipeline_smoke(args.get(pos + 1).map(String::as_str));
        return;
    }
    if let Some(pos) = args.iter().position(|a| a == "--elastic-smoke") {
        elastic_smoke(args.get(pos + 1).map(String::as_str));
        return;
    }
    if let Some(pos) = args.iter().position(|a| a == "--topology-smoke") {
        topology_smoke(args.get(pos + 1).map(String::as_str));
        return;
    }
    if let Some(pos) = args.iter().position(|a| a == "--hotpath-smoke") {
        hotpath_smoke(args.get(pos + 1).map(String::as_str));
        return;
    }
    if let Some(pos) = args.iter().position(|a| a == "--obs-smoke") {
        let mut fabric = "tcp";
        let mut json = None;
        for a in args.iter().skip(pos + 1).take(2) {
            if a.starts_with("--") {
                break;
            } else if a.ends_with(".json") {
                json = Some(a.as_str());
            } else {
                fabric = a.as_str();
            }
        }
        obs_smoke(fabric, json);
        return;
    }
    if let Some(pos) = args.iter().position(|a| a == "--calib-smoke") {
        calib_smoke(args.get(pos + 1).map(String::as_str));
        return;
    }
    if let Some(pos) = args.iter().position(|a| a == "--fabric-smoke") {
        fabric_smoke(args.get(pos + 1).map(String::as_str));
        return;
    }
    if let Some(pos) = args.iter().position(|a| a == "--ckpt-smoke") {
        ckpt_smoke(args.get(pos + 1).map(String::as_str));
        return;
    }
    if redsync::models::schema::Manifest::load(
        redsync::models::schema::Manifest::default_dir(),
    )
    .is_err()
    {
        eprintln!("artifacts not built; run `make artifacts` first");
        eprintln!("(the artifact-free engine A/B is available via --pipeline-smoke)");
        std::process::exit(1);
    }
    bench_model("lm_tiny", 2, 40);
    bench_model("lm_small", 4, 20);
    bench_model("mlp_wide", 4, 30);
}
