//! Collective communication substrate: the paper's synchronization layer.
//!
//! * [`transport`] — the point-to-point [`Transport`] trait and the
//!   in-process [`LocalFabric`]
//! * [`group`]     — [`Topology`], [`ProcessGroup`] (ordered rank subset
//!   with local-rank translation; itself a `Transport`) and the
//!   [`Communicator`] that derives intra-node / leader / world groups
//! * [`allreduce`] — Rabenseifner + ring (dense baseline, Eq. 2 schedule)
//! * [`allgather`] — recursive doubling + ring, variable-length blocks
//!   (sparse synchronization, Eq. 1 schedule)
//! * [`hierarchical`] — topology-aware sparse allgather (§5.3):
//!   intra-node gather at the leader, inter-node allgather among
//!   leaders, intra-node broadcast — bit-identical to the flat schedule
//! * [`fusion`]    — tensor fusion for small layers (§5.3)
//! * [`mux`]       — tag-multiplexed logical channels over one endpoint,
//!   so the pipelined sync engine can run bucket collectives concurrently
//!
//! Collectives are generic over [`Transport`] and therefore run over a
//! [`ProcessGroup`] unchanged — groups are how the sync engines address
//! subsets of the world (DESIGN.md §Topology-Aware-Communication).
//!
//! ## Transport hierarchy
//!
//! Every collective is generic over [`Transport`]; three fabrics sit
//! underneath (DESIGN.md §Transports):
//!
//! | fabric | ranks are | wire | used for |
//! |---|---|---|---|
//! | [`LocalFabric`] (here) | threads | in-process mpsc channels | unit/integration tests, single-host `redsync train`, benches |
//! | `net::TcpTransport` | processes | length-prefixed frames over TCP | `redsync launch` / multi-host jobs; the Eq. 1/2 terms against a real network stack |
//! | `simnet` | virtual | none (cost model replay) | 128-GPU scalability figures no testbed could host |
//!
//! `LocalFabric` and `TcpTransport` carry real bits and must agree
//! bit-for-bit (held by `tests/tcp_loopback.rs`); `simnet` never moves
//! data and sits outside the trait on purpose — it charges virtual time
//! from layer profiles instead.  Both real fabrics buffer sends
//! (non-blocking `send`, blocking `recv`), which is what makes the
//! symmetric `exchange` in the collectives deadlock-free.

pub mod allgather;
pub mod allreduce;
pub mod fusion;
pub mod group;
pub mod hierarchical;
pub mod mux;
pub mod transport;

pub use allgather::{allgather, allgather_ref, concat, Gathered};
pub use allreduce::{allreduce_mean, allreduce_sum};
pub use fusion::FusionPlan;
pub use group::{Algo, Communicator, ProcessGroup, Topology};
pub use hierarchical::{
    hierarchical_allgather, hierarchical_allgather_ref, hierarchical_traffic_words,
};
pub use mux::{TagChannel, TagMux, OOB_TAG};
pub use transport::{
    LinkClass, LinkTraffic, LocalFabric, LocalTransport, PeerLostCause, Transport, TransportError,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::message::{apply_gathered_plain, pack_plain};
    use crate::tensor::SparseTensor;
    use std::thread;

    /// End-to-end sparse synchronization: every rank compresses a distinct
    /// residual, allgathers the §5.3 messages, and applies the average —
    /// all ranks must agree bit-for-bit with the serial reference.
    #[test]
    fn sparse_sync_equals_serial_reference() {
        let world = 4;
        let n = 64;
        // rank r's sparse contribution: index 2r and 2r+1 overlap with none
        let contribution = |r: usize| {
            SparseTensor::new(vec![2 * r as u32, (2 * r + 32) as u32], vec![r as f32 + 1.0, -1.0])
        };
        // serial reference
        let mut expect = vec![0f32; n];
        for r in 0..world {
            contribution(r).scatter_add(&mut expect, 1.0 / world as f32);
        }

        let mut fabric = LocalFabric::new(world);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let msg = pack_plain(&contribution(t.rank()));
                    let gathered = concat(allgather(&t, msg));
                    let mut dense = vec![0f32; n];
                    apply_gathered_plain(&gathered, t.world(), &mut dense, 1.0 / t.world() as f32)
                        .unwrap();
                    dense
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), expect);
        }
    }

    /// Sparse allgather traffic is (p-1) * message bytes per rank
    /// (recursive doubling) — the bandwidth term of Eq. 1.
    #[test]
    fn allgather_traffic_matches_eq1_bandwidth_term() {
        let world = 8;
        let msg_words = 100usize;
        let mut fabric = LocalFabric::new(world);
        let stats = std::sync::Arc::clone(&fabric.stats);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    allgather(&t, vec![0u32; msg_words]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Exact accounting.  Payload per rank is (p-1)·m — the Eq. 1
        // bandwidth term.  Recursive doubling is deterministic, so the
        // block-header overhead is too: at step s a rank packs 2^s
        // blocks into one message (1 count word + 2 header words per
        // block), giving lg(p) + 2(p-1) header words per rank.
        let payload = (world * (world - 1) * msg_words) as u64;
        let lg = world.trailing_zeros() as u64;
        let headers = world as u64 * (lg + 2 * (world as u64 - 1));
        let total = stats.words.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(
            total,
            payload + headers,
            "traffic must be exactly payload {payload} + headers {headers}"
        );
        // the Eq. 1 model charges only the payload; headers are noise
        assert!(headers < payload / 10, "header overhead is not negligible");
    }
}
