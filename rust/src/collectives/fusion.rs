//! Tensor fusion (§5.3): batch many small per-layer messages into few
//! large ones to amortize the per-message latency α and raise effective
//! bandwidth.  Used for the dense-allreduce layers (below `thsd1`) and for
//! batching small allgathers.

/// A fusion plan: which layer indices go into which bucket, preserving
/// layer order inside a bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct FusionPlan {
    pub buckets: Vec<Bucket>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Bucket {
    /// (layer index, element count) in order.
    pub layers: Vec<(usize, usize)>,
    pub total_elems: usize,
}

impl FusionPlan {
    /// Greedy first-fit in layer order: close a bucket when adding the
    /// next layer would exceed `cap_elems` (a single layer larger than the
    /// cap gets its own bucket).
    pub fn greedy(layer_sizes: &[usize], cap_elems: usize) -> FusionPlan {
        assert!(cap_elems > 0);
        let mut buckets = Vec::new();
        let mut cur = Bucket { layers: Vec::new(), total_elems: 0 };
        for (i, &n) in layer_sizes.iter().enumerate() {
            if !cur.layers.is_empty() && cur.total_elems + n > cap_elems {
                buckets.push(std::mem::replace(
                    &mut cur,
                    Bucket { layers: Vec::new(), total_elems: 0 },
                ));
            }
            cur.layers.push((i, n));
            cur.total_elems += n;
        }
        if !cur.layers.is_empty() {
            buckets.push(cur);
        }
        FusionPlan { buckets }
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }
}

impl Bucket {
    /// Flatten the bucket's layers (slices indexed by layer id) into one
    /// contiguous buffer.
    pub fn gather<'a>(&self, layers: impl Fn(usize) -> &'a [f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_elems);
        for &(idx, n) in &self.layers {
            let src = layers(idx);
            assert_eq!(src.len(), n, "layer {idx} size changed");
            out.extend_from_slice(src);
        }
        out
    }

    /// Scatter a fused buffer back out to the per-layer slices.
    pub fn scatter(&self, fused: &[f32], mut layer_mut: impl FnMut(usize) -> *mut f32) {
        assert_eq!(fused.len(), self.total_elems);
        let mut off = 0;
        for &(idx, n) in &self.layers {
            let dst = layer_mut(idx);
            // SAFETY: callers hand out disjoint per-layer buffers of length n.
            unsafe {
                std::ptr::copy_nonoverlapping(fused[off..].as_ptr(), dst, n);
            }
            off += n;
        }
    }

    /// Safe scatter into a Vec-of-Vecs layer store.
    pub fn scatter_into(&self, fused: &[f32], layers: &mut [Vec<f32>]) {
        assert_eq!(fused.len(), self.total_elems);
        let mut off = 0;
        for &(idx, n) in &self.layers {
            layers[idx].copy_from_slice(&fused[off..off + n]);
            off += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_respects_cap() {
        let plan = FusionPlan::greedy(&[10, 20, 30, 40], 50);
        // [10,20] -> 30; +30 would be 60 > 50 -> new bucket [30]; +40 > 50 -> [40]
        assert_eq!(plan.n_buckets(), 3);
        assert_eq!(plan.buckets[0].layers, vec![(0, 10), (1, 20)]);
        assert_eq!(plan.buckets[1].layers, vec![(2, 30)]);
        assert_eq!(plan.buckets[2].layers, vec![(3, 40)]);
    }

    #[test]
    fn oversized_layer_gets_own_bucket() {
        let plan = FusionPlan::greedy(&[100, 5], 10);
        assert_eq!(plan.n_buckets(), 2);
        assert_eq!(plan.buckets[0].total_elems, 100);
    }

    #[test]
    fn empty_input() {
        assert_eq!(FusionPlan::greedy(&[], 10).n_buckets(), 0);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let layers = vec![vec![1.0f32, 2.0], vec![3.0], vec![4.0, 5.0, 6.0]];
        let plan = FusionPlan::greedy(&[2, 1, 3], 100);
        assert_eq!(plan.n_buckets(), 1);
        let b = &plan.buckets[0];
        let fused = b.gather(|i| layers[i].as_slice());
        assert_eq!(fused, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut out = vec![vec![0.0f32; 2], vec![0.0], vec![0.0; 3]];
        b.scatter_into(&fused, &mut out);
        assert_eq!(out, layers);
    }

    #[test]
    fn all_layers_covered_exactly_once() {
        let sizes = [3usize, 7, 1, 9, 2, 8];
        let plan = FusionPlan::greedy(&sizes, 10);
        let mut seen = vec![false; sizes.len()];
        for b in &plan.buckets {
            let mut sum = 0;
            for &(i, n) in &b.layers {
                assert!(!seen[i]);
                seen[i] = true;
                assert_eq!(n, sizes[i]);
                sum += n;
            }
            assert_eq!(sum, b.total_elems);
        }
        assert!(seen.iter().all(|&s| s));
    }
}
