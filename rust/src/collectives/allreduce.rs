//! Allreduce — the dense-baseline synchronization (§2.2, Appendix B).
//!
//! Rabenseifner's algorithm (reduce-scatter by recursive halving, then
//! allgather by recursive doubling): 2·lg(p) latency terms and
//! 2·((p-1)/p)·M bandwidth — exactly the schedule Eq. 2 charges.  A ring
//! allreduce covers non-power-of-two worlds and serves as an ablation
//! comparator.

use super::transport::{f32s_to_words, words_to_f32s, Transport};

/// Sum-allreduce of `x` across all ranks (in place).  Dispatches to
/// Rabenseifner for power-of-two worlds, ring otherwise.
///
/// Like every collective here, `t` may be a
/// [`ProcessGroup`](super::group::ProcessGroup): the reduction then
/// runs among the group's members only (`world()` is the group size),
/// which is how topology-aware schedules scope dense reductions to a
/// node or to the leader set.
pub fn allreduce_sum<T: Transport>(t: &T, x: &mut [f32]) {
    if t.world() == 1 {
        return;
    }
    if t.world().is_power_of_two() {
        allreduce_rabenseifner(t, x)
    } else {
        allreduce_ring(t, x)
    }
}

/// Average-allreduce: sum then scale by 1/p.
pub fn allreduce_mean<T: Transport>(t: &T, x: &mut [f32]) {
    allreduce_sum(t, x);
    let inv = 1.0 / t.world() as f32;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// Contiguous chunk boundaries splitting `n` into `p` near-equal parts.
fn chunk_bounds(n: usize, p: usize) -> Vec<(usize, usize)> {
    let base = n / p;
    let rem = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Rabenseifner: recursive-halving reduce-scatter + recursive-doubling
/// allgather over contiguous chunks.
///
/// Chunk-space invariants (chunks indexed 0..world):
/// * reduce-scatter, step `dist` (world/2 → 1): rank's live group is the
///   2·dist-aligned block containing it; it keeps the half containing
///   itself and gives the other half to `rank ^ dist`.  After the loop it
///   owns exactly chunk `rank`, fully reduced.
/// * allgather, step `dist` (1 → world/2): rank owns the dist-aligned
///   block `[rank & !(dist-1), +dist)`; peer `rank ^ dist` owns the
///   mirrored block; after exchange both own the 2·dist block.
pub fn allreduce_rabenseifner<T: Transport>(t: &T, x: &mut [f32]) {
    let (rank, world) = (t.rank(), t.world());
    assert!(world.is_power_of_two());
    let bounds = chunk_bounds(x.len(), world);
    let range = |clo: usize, chi: usize| bounds[clo].0..bounds[chi - 1].1;

    // --- reduce-scatter (recursive halving) ---
    let mut dist = world / 2;
    while dist >= 1 {
        let peer = rank ^ dist;
        let lo = rank & !(2 * dist - 1); // group base (chunk index)
        let (keep_lo, give_lo) =
            if rank & dist == 0 { (lo, lo + dist) } else { (lo + dist, lo) };
        t.send(peer, f32s_to_words(&x[range(give_lo, give_lo + dist)]));
        let received = words_to_f32s(&t.recv(peer));
        let recv_range = range(keep_lo, keep_lo + dist);
        assert_eq!(received.len(), recv_range.len());
        for (xi, ri) in x[recv_range].iter_mut().zip(&received) {
            *xi += ri;
        }
        dist /= 2;
    }

    // --- allgather (recursive doubling) over owned chunks ---
    let mut dist = 1;
    while dist < world {
        let peer = rank ^ dist;
        let base = rank & !(dist - 1);
        let peer_base = base ^ dist;
        t.send(peer, f32s_to_words(&x[range(base, base + dist)]));
        let received = words_to_f32s(&t.recv(peer));
        let recv_range = range(peer_base, peer_base + dist);
        assert_eq!(received.len(), recv_range.len());
        x[recv_range].copy_from_slice(&received);
        dist <<= 1;
    }
}

/// Ring allreduce: reduce-scatter ring then allgather ring (2(p-1) steps,
/// 2·((p-1)/p)·M bytes — same bandwidth as Rabenseifner, more latency).
pub fn allreduce_ring<T: Transport>(t: &T, x: &mut [f32]) {
    let (rank, world) = (t.rank(), t.world());
    if world == 1 {
        return;
    }
    let bounds = chunk_bounds(x.len(), world);
    let next = (rank + 1) % world;
    let prev = (rank + world - 1) % world;

    // reduce-scatter: after p-1 steps, rank owns chunk (rank+1) % p
    for step in 0..world - 1 {
        let send_chunk = (rank + world - step) % world;
        let recv_chunk = (rank + world - step - 1) % world;
        let (s0, s1) = bounds[send_chunk];
        t.send(next, f32s_to_words(&x[s0..s1]));
        let received = words_to_f32s(&t.recv(prev));
        let (r0, r1) = bounds[recv_chunk];
        for (xi, ri) in x[r0..r1].iter_mut().zip(&received) {
            *xi += ri;
        }
    }
    // allgather ring
    for step in 0..world - 1 {
        let send_chunk = (rank + 1 + world - step) % world;
        let recv_chunk = (rank + world - step) % world;
        let (s0, s1) = bounds[send_chunk];
        t.send(next, f32s_to_words(&x[s0..s1]));
        let received = words_to_f32s(&t.recv(prev));
        let (r0, r1) = bounds[recv_chunk];
        x[r0..r1].copy_from_slice(&received);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::LocalFabric;
    use std::thread;

    /// Run `world` ranks, each contributing vec = rank-dependent data, and
    /// check every rank ends with the elementwise sum.
    fn check_allreduce(world: usize, n: usize, ring: bool) {
        let mut fabric = LocalFabric::new(world);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let mut x: Vec<f32> =
                        (0..n).map(|i| (t.rank() + 1) as f32 * (i as f32 + 1.0)).collect();
                    if ring {
                        allreduce_ring(&t, &mut x);
                    } else {
                        allreduce_sum(&t, &mut x);
                    }
                    x
                })
            })
            .collect();
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let rank_sum: f32 = (1..=world).map(|r| r as f32).sum();
        for got in &results {
            for (i, &v) in got.iter().enumerate() {
                let expect = rank_sum * (i as f32 + 1.0);
                assert!(
                    (v - expect).abs() < 1e-3 * expect.abs().max(1.0),
                    "world={world} n={n} i={i}: {v} != {expect}"
                );
            }
        }
    }

    #[test]
    fn rabenseifner_pow2_worlds() {
        for world in [2usize, 4, 8] {
            for n in [8usize, 17, 64, 1000] {
                check_allreduce(world, n, false);
            }
        }
    }

    #[test]
    fn ring_all_worlds() {
        for world in [2usize, 3, 4, 5, 7, 8] {
            for n in [16usize, 33, 256] {
                check_allreduce(world, n, true);
            }
        }
    }

    #[test]
    fn dispatch_handles_non_pow2() {
        check_allreduce(6, 100, false);
    }

    #[test]
    fn world_one_is_identity() {
        let mut fabric = LocalFabric::new(1);
        let t = fabric.take(0);
        let mut x = vec![1.0, 2.0];
        allreduce_sum(&t, &mut x);
        assert_eq!(x, vec![1.0, 2.0]);
    }

    #[test]
    fn mean_divides_by_world() {
        let mut fabric = LocalFabric::new(4);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let mut x = vec![4.0f32; 8];
                    allreduce_mean(&t, &mut x);
                    x
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![4.0f32; 8]);
        }
    }

    #[test]
    fn allreduce_over_a_process_group_scopes_to_members() {
        // an 8-rank fabric, reduced only within each 4-rank "node"
        use crate::collectives::group::{ProcessGroup, Topology};
        let topo = Topology::new(2, 4);
        let mut fabric = LocalFabric::new(topo.world());
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let rank = t.rank();
                    let members = topo.node_members(topo.node_of(rank));
                    let g = ProcessGroup::new(&t, members.clone());
                    let mut x = vec![rank as f32];
                    allreduce_sum(&g, &mut x);
                    let want: f32 = members.iter().map(|&m| m as f32).sum();
                    assert_eq!(x[0], want, "rank {rank} node sum");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn chunk_bounds_cover_everything() {
        for n in [0usize, 1, 7, 8, 100] {
            for p in [1usize, 2, 4, 8] {
                let b = chunk_bounds(n, p);
                assert_eq!(b.len(), p);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[p - 1].1, n);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }

    #[test]
    fn rabenseifner_small_vectors() {
        // n < world: some chunks empty — must still work
        check_allreduce(8, 3, false);
    }
}
