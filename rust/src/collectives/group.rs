//! Process groups and physical topology: the communicator layer under
//! the collectives.
//!
//! The paper's 128-GPU scalability rests on *hierarchical* communication
//! (§5.3): sparse messages are aggregated inside a node before a much
//! smaller inter-node exchange among node leaders.  That requires
//! collectives that run over an ordered *subset* of the world — a
//! [`ProcessGroup`] — rather than the raw fabric.
//!
//! A group is itself a [`Transport`]: `rank()`/`world()` are
//! group-local, and sends/receives translate local ranks to world ranks
//! on the underlying endpoint.  Every collective in this crate is
//! generic over `Transport`, so `allgather(&group, msg)` just works —
//! over any fabric (`LocalFabric`, `net::TcpTransport`) and through any
//! wrapper (`mux::TagChannel`), which is how the pipelined engine runs
//! hierarchical bucket collectives concurrently.
//!
//! [`Topology`] describes the machine as `nodes × ranks-per-node`
//! (contiguous rank placement: world rank `r` lives on node `r / s`),
//! and [`Communicator`] derives the standard groups from it: the node's
//! intra-node group, the inter-node leader group, and the world group.
//!
//! ## Why plain rank translation is safe
//!
//! Between any pair of world ranks the fabric preserves FIFO order, and
//! a rank participates in the hierarchical phases sequentially, so two
//! groups over the same endpoint never race for each other's messages
//! as long as every rank drives its collectives in the same global
//! order — the same discipline the flat collectives already require.
//! Concurrent collectives (the pipelined engine) isolate themselves
//! with per-bucket [`crate::collectives::mux::TagChannel`]s *under* the
//! group, not beside it.

use super::allgather::{allgather_ref, Gathered};
use super::hierarchical::hierarchical_allgather_ref;
use super::transport::{Transport, TransportError};
use std::sync::Arc;

/// Which collective algorithm synchronizes a fusion bucket (§5.5 + the
/// hierarchical scheme).  Picked per bucket at plan time — statically
/// (`--algo sparse|hierarchical`) or by the cost-model argmin
/// (`--algo auto`, `crate::costmodel::pick_algo`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Dense allreduce of the raw gradient (Eq. 2): the bucket's layers
    /// are demoted to the dense path and never compress.
    Dense,
    /// Flat sparse allgather over the full world (Eq. 1).
    Sparse,
    /// Intra-node aggregation at the leader, inter-node allgather among
    /// leaders, intra-node broadcast (the §5.3 hierarchical scheme).
    Hierarchical,
}

impl Algo {
    pub fn label(&self) -> &'static str {
        match self {
            Algo::Dense => "dense",
            Algo::Sparse => "sparse",
            Algo::Hierarchical => "hierarchical",
        }
    }
}

/// Physical machine shape: `nodes` × `ranks_per_node`, with contiguous
/// placement (world rank `r` is local rank `r % ranks_per_node` on node
/// `r / ranks_per_node`; each node's leader is its local rank 0).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub ranks_per_node: usize,
}

impl Topology {
    pub fn new(nodes: usize, ranks_per_node: usize) -> Topology {
        assert!(nodes >= 1 && ranks_per_node >= 1, "topology axes must be >= 1");
        Topology { nodes, ranks_per_node }
    }

    /// The degenerate one-node topology: hierarchical collectives over
    /// it collapse to a leader gather + broadcast with no inter-node
    /// exchange.
    pub fn flat(world: usize) -> Topology {
        Topology::new(1, world.max(1))
    }

    /// Parse `"NxM"` (nodes x ranks-per-node), e.g. `"2x4"`.
    pub fn parse(s: &str) -> Result<Topology, String> {
        let (n, r) = s
            .split_once('x')
            .ok_or_else(|| format!("topology '{s}': expected NODESxRANKS_PER_NODE, e.g. 2x4"))?;
        let nodes: usize =
            n.trim().parse().map_err(|_| format!("topology '{s}': bad node count '{n}'"))?;
        let rpn: usize = r
            .trim()
            .parse()
            .map_err(|_| format!("topology '{s}': bad ranks-per-node '{r}'"))?;
        if nodes == 0 || rpn == 0 {
            return Err(format!("topology '{s}': axes must be >= 1"));
        }
        Ok(Topology::new(nodes, rpn))
    }

    pub fn label(&self) -> String {
        format!("{}x{}", self.nodes, self.ranks_per_node)
    }

    pub fn world(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_node
    }

    /// Whether two world ranks share a physical node — the link-class
    /// predicate the mixed fabric uses to pick Unix sockets over TCP
    /// (`net::mixed`).
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    pub fn local_of(&self, rank: usize) -> usize {
        rank % self.ranks_per_node
    }

    /// World rank of `node`'s local rank `local` — the inverse of
    /// ([`node_of`](Self::node_of), [`local_of`](Self::local_of)).
    pub fn world_rank(&self, node: usize, local: usize) -> usize {
        debug_assert!(node < self.nodes && local < self.ranks_per_node);
        node * self.ranks_per_node + local
    }

    /// The node leader `rank` reports to (local rank 0 of its node).
    pub fn leader_of(&self, rank: usize) -> usize {
        self.node_of(rank) * self.ranks_per_node
    }

    pub fn is_leader(&self, rank: usize) -> bool {
        self.local_of(rank) == 0
    }

    /// World ranks of one node, ascending (leader first).
    pub fn node_members(&self, node: usize) -> Vec<usize> {
        assert!(node < self.nodes, "node {node} out of {}", self.nodes);
        let base = node * self.ranks_per_node;
        (base..base + self.ranks_per_node).collect()
    }

    /// World ranks of every node leader, ascending (one per node).
    pub fn leaders(&self) -> Vec<usize> {
        (0..self.nodes).map(|n| n * self.ranks_per_node).collect()
    }
}

/// An ordered subset of world ranks with local-rank translation — what
/// collectives run over instead of a raw endpoint.  The group is a full
/// [`Transport`]: `rank()`/`world()` are the *group-local* view, and
/// every send/receive maps local peer ids onto the member list.
pub struct ProcessGroup<T: Transport> {
    inner: T,
    members: Vec<usize>,
    /// This rank's position in `members` (its group-local rank).
    pos: usize,
}

impl<T: Transport> ProcessGroup<T> {
    /// Build the group view for the calling rank.  `members` is the
    /// ordered world-rank list; the caller's world rank must be one of
    /// them (a rank outside a group never constructs its view), and
    /// duplicates are rejected.
    pub fn new(inner: T, members: Vec<usize>) -> ProcessGroup<T> {
        assert!(!members.is_empty(), "a process group needs at least one member");
        let world = inner.world();
        let mut seen = vec![false; world];
        for &m in &members {
            assert!(m < world, "member {m} outside world {world}");
            assert!(!seen[m], "duplicate member {m}");
            seen[m] = true;
        }
        let me = inner.rank();
        let pos = members
            .iter()
            .position(|&m| m == me)
            .unwrap_or_else(|| panic!("rank {me} is not a member of the group {members:?}"));
        ProcessGroup { inner, members, pos }
    }

    /// The ordered world-rank membership.
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// World rank of group-local rank `local`.
    pub fn world_rank(&self, local: usize) -> usize {
        self.members[local]
    }

    /// Group-local rank of world rank `world`, if it is a member.
    pub fn local_rank(&self, world: usize) -> Option<usize> {
        self.members.iter().position(|&m| m == world)
    }
}

impl<T: Transport> Transport for ProcessGroup<T> {
    /// Group-local rank.
    fn rank(&self) -> usize {
        self.pos
    }

    /// Group size (not the world size of the underlying fabric).
    fn world(&self) -> usize {
        self.members.len()
    }

    fn send(&self, to: usize, msg: Vec<u32>) {
        self.inner.send(self.members[to], msg)
    }

    fn send_shared(&self, to: usize, msg: &Arc<Vec<u32>>) {
        self.inner.send_shared(self.members[to], msg)
    }

    fn recv_checked(&self, from: usize) -> Result<Vec<u32>, TransportError> {
        self.inner.recv_checked(self.members[from]).map_err(|e| self.relabel(from, e))
    }

    fn try_recv(&self, from: usize) -> Result<Option<Vec<u32>>, TransportError> {
        self.inner.try_recv(self.members[from]).map_err(|e| self.relabel(from, e))
    }

    fn send_checked(&self, to: usize, msg: Vec<u32>) -> Result<(), TransportError> {
        self.inner.send_checked(self.members[to], msg).map_err(|e| self.relabel(to, e))
    }

    fn sever(&self, peer: usize) {
        self.inner.sever(self.members[peer])
    }
}

impl<T: Transport> ProcessGroup<T> {
    /// Report the *group-local* peer the caller addressed, keeping the
    /// structured cause.
    fn relabel(&self, local: usize, e: TransportError) -> TransportError {
        TransportError::with_cause(
            local,
            format!("world rank {}: {}", self.members[local], e.reason),
            e.cause,
        )
    }
}

/// One rank's communicator: an endpoint plus the [`Topology`] it lives
/// in, from which the standard groups (intra-node, inter-node leaders,
/// world) are derived.  The sync engines hold one per collective
/// context and dispatch each bucket's [`Algo`] through it.
pub struct Communicator<T: Transport> {
    inner: T,
    topo: Topology,
}

impl<T: Transport> Communicator<T> {
    pub fn new(inner: T, topo: Topology) -> Communicator<T> {
        assert_eq!(
            topo.world(),
            inner.world(),
            "topology {} does not cover world {}",
            topo.label(),
            inner.world()
        );
        Communicator { inner, topo }
    }

    /// A communicator over the degenerate one-node topology — the flat
    /// world every pre-topology call site assumed.
    pub fn flat(inner: T) -> Communicator<T> {
        let world = inner.world();
        Communicator::new(inner, Topology::flat(world))
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// This rank's intra-node group (leader first).
    pub fn intra_group(&self) -> ProcessGroup<&T> {
        let node = self.topo.node_of(self.inner.rank());
        ProcessGroup::new(&self.inner, self.topo.node_members(node))
    }

    /// The inter-node leader group — only leaders may build their view.
    pub fn leaders_group(&self) -> Option<ProcessGroup<&T>> {
        if self.topo.is_leader(self.inner.rank()) {
            Some(ProcessGroup::new(&self.inner, self.topo.leaders()))
        } else {
            None
        }
    }

    /// The full-world group (identity translation).
    pub fn world_group(&self) -> ProcessGroup<&T> {
        ProcessGroup::new(&self.inner, (0..self.inner.world()).collect())
    }

    /// Dispatch one sparse collective for a bucket: gather every world
    /// rank's `msg` (borrowed — the bucket's persistent pack blob is
    /// read, never consumed) into one owned [`Gathered`] buffer indexed
    /// by world rank, over the algorithm the plan chose.  Both paths
    /// return bit-identical results (pinned in `tests/topology.rs`);
    /// they differ only in schedule and traffic.
    pub fn allgather(&self, algo: Algo, msg: &[u32]) -> Gathered {
        match algo {
            Algo::Sparse => allgather_ref(&self.inner, msg),
            Algo::Hierarchical => hierarchical_allgather_ref(&self.inner, self.topo, msg),
            Algo::Dense => unreachable!("dense buckets never reach the sparse collective"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::LocalFabric;
    use crate::collectives::{allgather, allreduce_mean};
    use std::thread;

    #[test]
    fn topology_translation() {
        let t = Topology::new(2, 4);
        assert_eq!(t.world(), 8);
        assert_eq!(t.node_of(5), 1);
        assert_eq!(t.local_of(5), 1);
        assert_eq!(t.world_rank(1, 1), 5);
        assert_eq!(t.leader_of(6), 4);
        assert!(t.same_node(4, 7) && !t.same_node(3, 4));
        assert!(t.is_leader(4) && !t.is_leader(7));
        assert_eq!(t.node_members(1), vec![4, 5, 6, 7]);
        assert_eq!(t.leaders(), vec![0, 4]);
        assert_eq!(t.label(), "2x4");
    }

    #[test]
    fn topology_parse_roundtrip() {
        let t = Topology::parse("2x4").unwrap();
        assert_eq!(t, Topology::new(2, 4));
        assert_eq!(Topology::parse(&t.label()).unwrap(), t);
        assert!(Topology::parse("2x0").is_err());
        assert!(Topology::parse("nope").is_err());
        assert!(Topology::parse("x4").is_err());
    }

    #[test]
    fn flat_topology_is_one_node() {
        let t = Topology::flat(4);
        assert_eq!((t.nodes, t.ranks_per_node), (1, 4));
        assert!(t.is_leader(0) && !t.is_leader(3));
        assert_eq!(t.leaders(), vec![0]);
    }

    #[test]
    fn group_translates_ranks() {
        // rank 2's view of the group {1, 2, 5} over an 8-rank fabric
        let mut fabric = LocalFabric::new(8);
        let t = fabric.take(2);
        let g = ProcessGroup::new(&t, vec![1, 2, 5]);
        assert_eq!(g.rank(), 1, "group-local rank");
        assert_eq!(g.world(), 3, "group size");
        assert_eq!(g.world_rank(2), 5);
        assert_eq!(g.local_rank(5), Some(2));
        assert_eq!(g.local_rank(3), None);
        assert_eq!(g.members(), &[1, 2, 5]);
    }

    #[test]
    #[should_panic(expected = "not a member")]
    fn group_rejects_non_member_builder() {
        let mut fabric = LocalFabric::new(4);
        let t = fabric.take(0);
        let _ = ProcessGroup::new(&t, vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "duplicate member")]
    fn group_rejects_duplicates() {
        let mut fabric = LocalFabric::new(4);
        let t = fabric.take(1);
        let _ = ProcessGroup::new(&t, vec![1, 1]);
    }

    /// Two disjoint groups run independent collectives over one fabric:
    /// evens allgather while odds allreduce, no cross-talk.
    #[test]
    fn disjoint_subgroups_run_independent_collectives() {
        let world = 4;
        let mut fabric = LocalFabric::new(world);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let rank = t.rank();
                    if rank % 2 == 0 {
                        let g = ProcessGroup::new(&t, vec![0, 2]);
                        let got = allgather(&g, vec![rank as u32]);
                        assert_eq!(got, vec![vec![0], vec![2]]);
                    } else {
                        let g = ProcessGroup::new(&t, vec![1, 3]);
                        let mut x = vec![rank as f32];
                        allreduce_mean(&g, &mut x);
                        assert_eq!(x, vec![2.0], "mean of ranks 1 and 3");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn communicator_derives_standard_groups() {
        let mut fabric = LocalFabric::new(8);
        let t = fabric.take(5);
        let comm = Communicator::new(&t, Topology::new(2, 4));
        assert_eq!(comm.intra_group().members(), &[4, 5, 6, 7]);
        assert!(comm.leaders_group().is_none(), "rank 5 is not a leader");
        assert_eq!(comm.world_group().members().len(), 8);

        let t4 = fabric.take(4);
        let comm4 = Communicator::new(&t4, Topology::new(2, 4));
        let leaders = comm4.leaders_group().expect("rank 4 leads node 1");
        assert_eq!(leaders.members(), &[0, 4]);
        assert_eq!(leaders.rank(), 1, "leader-group-local rank");
    }

    #[test]
    #[should_panic(expected = "does not cover world")]
    fn communicator_rejects_mismatched_topology() {
        let mut fabric = LocalFabric::new(4);
        let t = fabric.take(0);
        let _ = Communicator::new(&t, Topology::new(2, 4));
    }
}
