//! Tag-multiplexed logical channels over one [`Transport`] endpoint.
//!
//! The pipelined sync engine (`crate::pipeline`) runs several bucket
//! collectives *concurrently* over a single fabric endpoint.  The base
//! `Transport` demultiplexes inbound traffic by peer only, so two
//! in-flight collectives would steal each other's messages.  [`TagMux`]
//! fixes that with the MPI tag-matching discipline over ordered streams:
//! every outbound message gets a trailing *tag* word naming its logical
//! channel (trailing, not leading, so tagging is an amortized-O(1)
//! `push` and untagging an O(1) `pop` instead of a whole-message copy),
//! and inbound messages are routed into per-(peer, tag) FIFO queues.
//! Each [`TagChannel`] then behaves exactly like a private `Transport`,
//! so the collectives run over it unchanged.
//!
//! ## Why frames never interleave
//!
//! Every real fabric sends each message atomically — one mpsc element
//! in-process, one length-prefixed frame written by the peer's single
//! writer thread on the socket fabrics (`net::fabric`, TCP and Unix
//! alike) — so concurrent tagged senders interleave whole messages,
//! never words inside one.  The writer's batched vectored writes
//! coalesce whole frames into fewer syscalls without ever moving a
//! frame boundary, so this invariant survives batching.  The tag word
//! is all the demux needs.
//!
//! ## Why tags may be reused across steps
//!
//! Per-(src, dst, tag) order is preserved end-to-end: the underlying
//! stream is ordered per peer, and routing appends to FIFO queues.  A
//! bucket that reuses its tag next step enqueues strictly *behind* any
//! of its still-undrained messages from this step, so cross-step
//! confusion is impossible — the argument that makes the engine's
//! bounded in-flight window safe without a per-step epoch in the wire
//! format.
//!
//! ## Blocking discipline
//!
//! `recv` on a channel locks that peer's router and drains the underlying
//! stream, parking other tags' messages in their queues.  Another thread
//! waiting on a different tag of the same peer blocks on the router lock
//! until the first receiver gets its message; progress is guaranteed
//! because every parked message was already sent (sends never block) and
//! collectives consume exactly what they are sent.

use super::transport::{lock_ok, PeerLostCause, TrafficStats, Transport, TransportError};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Reserved out-of-band tag value: a frame whose trailing word is
/// `OOB_TAG` is not epoch traffic at all but an elastic reshape-protocol
/// frame (`crate::elastic::reshape`).  The mux parks it per peer and
/// surfaces a [`PeerLostCause::OutOfBand`] error, which aborts the
/// in-flight collective and hands control to the reshape driver —
/// without losing the frame.  `u32::MAX` can never collide with a real
/// tag (muxes reserve `0..n_tags` with `n_tags` small).
pub const OOB_TAG: u32 = u32::MAX;

/// Demultiplexer wrapping one fabric endpoint into `n_tags` logical
/// channels.  Build once per endpoint, share via `Arc`, and mint
/// channels with [`TagChannel::new`].
///
/// While a mux is live, *all* traffic on the endpoint must flow through
/// its channels: a raw `recv` on the inner transport could steal a tagged
/// message, and a raw `send` would arrive without a tag (a clean error on
/// the receiving mux, but an error nonetheless).  The one exception is
/// the reserved [`OOB_TAG`]: out-of-band frames are parked per peer for
/// the elastic reshape driver instead of being routed to a channel.
pub struct TagMux<T: Transport> {
    inner: T,
    n_tags: u32,
    /// pending[peer][tag]: messages received for a tag no channel was
    /// draining at the time.
    pending: Vec<Mutex<Vec<VecDeque<Vec<u32>>>>>,
    /// Out-of-band reshape frames per peer (tag word already stripped),
    /// in arrival order.
    oob: Vec<Mutex<VecDeque<Vec<u32>>>>,
    /// The side-channel tag, if one is reserved: its inbound messages
    /// are parked in [`side`](Self::side) — *outside* the per-peer
    /// router lock — so a poller (the heartbeat monitor) can observe
    /// them even while a blocking receive holds the router.  Without
    /// this, a peer's liveness evidence would be invisible exactly when
    /// a collective is waiting on that peer.
    side_tag: Option<u32>,
    side: Vec<Mutex<VecDeque<Vec<u32>>>>,
    /// Per-tag outbound counters (words include the tag word, matching
    /// what the underlying fabric charges), so per-fabric totals can be
    /// split into control vs bucket streams.
    stats: Vec<TrafficStats>,
}

impl<T: Transport> TagMux<T> {
    /// Wrap `inner`, reserving tags `0..n_tags`.
    pub fn new(inner: T, n_tags: u32) -> TagMux<T> {
        assert!(n_tags >= 1, "a mux needs at least one channel");
        let world = inner.world();
        let pending = (0..world)
            .map(|_| Mutex::new((0..n_tags as usize).map(|_| VecDeque::new()).collect()))
            .collect();
        let oob = (0..world).map(|_| Mutex::new(VecDeque::new())).collect();
        let side = (0..world).map(|_| Mutex::new(VecDeque::new())).collect();
        let stats = (0..n_tags).map(|_| TrafficStats::default()).collect();
        TagMux { inner, n_tags, pending, oob, side_tag: None, side, stats }
    }

    /// [`new`](Self::new), additionally reserving `side_tag` as the
    /// lock-independent side channel (the elastic heartbeat stream).
    pub fn with_side_channel(inner: T, n_tags: u32, side_tag: u32) -> TagMux<T> {
        assert!(side_tag < n_tags, "side tag {side_tag} outside {n_tags} channels");
        let mut mux = Self::new(inner, n_tags);
        mux.side_tag = Some(side_tag);
        mux
    }

    /// Outbound traffic of one logical channel (words include the tag
    /// word each message carries on the wire).
    pub fn tag_stats(&self, tag: u32) -> &TrafficStats {
        &self.stats[tag as usize]
    }

    /// Outbound bytes per logical channel, indexed by tag — the
    /// observability view of the same counters `tag_stats` exposes
    /// (reads only; accounting is untouched).
    pub fn per_tag_bytes(&self) -> Vec<u64> {
        (0..self.n_tags).map(|t| self.tag_stats(t).bytes()).collect()
    }

    /// Aggregate outbound `(messages, words)` across every channel of
    /// this mux — by construction exactly what the muxed streams added
    /// to the underlying fabric's counters.
    pub fn aggregate(&self) -> (u64, u64) {
        use std::sync::atomic::Ordering;
        self.stats.iter().fold((0, 0), |(m, w), s| {
            (m + s.messages.load(Ordering::Relaxed), w + s.words.load(Ordering::Relaxed))
        })
    }

    pub fn rank(&self) -> usize {
        self.inner.rank()
    }

    pub fn world(&self) -> usize {
        self.inner.world()
    }

    pub fn n_tags(&self) -> u32 {
        self.n_tags
    }

    fn send_tagged(&self, to: usize, tag: u32, mut msg: Vec<u32>) {
        use std::sync::atomic::Ordering;
        debug_assert!(tag < self.n_tags);
        msg.push(tag);
        let s = &self.stats[tag as usize];
        s.messages.fetch_add(1, Ordering::Relaxed);
        s.words.fetch_add(msg.len() as u64, Ordering::Relaxed);
        self.inner.send(to, msg);
    }

    /// Route one raw inbound message: strip the tag and either return it
    /// (`Some` when it matches `want`), park it for its channel, or park
    /// an out-of-band frame and surface the [`PeerLostCause::OutOfBand`]
    /// error that aborts the caller's collective.
    fn route(
        &self,
        from: usize,
        want: u32,
        mut raw: Vec<u32>,
        router: &mut [VecDeque<Vec<u32>>],
    ) -> Result<Option<Vec<u32>>, TransportError> {
        let Some(t) = raw.pop() else {
            return Err(TransportError::with_cause(
                from,
                "untagged (empty) message on a multiplexed fabric",
                PeerLostCause::Corrupt,
            ));
        };
        if t == OOB_TAG {
            lock_ok(&self.oob[from]).push_back(raw);
            return Err(TransportError::with_cause(
                from,
                "out-of-band reshape frame (peer left the epoch)",
                PeerLostCause::OutOfBand,
            ));
        }
        if t >= self.n_tags {
            return Err(TransportError::with_cause(
                from,
                format!("message tagged {t} outside the fabric's {} channels", self.n_tags),
                PeerLostCause::Corrupt,
            ));
        }
        if t == want {
            return Ok(Some(raw));
        }
        if Some(t) == self.side_tag {
            // park outside the router lock so a concurrent poller sees it
            lock_ok(&self.side[from]).push_back(raw);
            return Ok(None);
        }
        router[t as usize].push_back(raw);
        Ok(None)
    }

    /// Pop a parked side-channel message from `from`, if any.
    fn pop_side(&self, from: usize) -> Option<Vec<u32>> {
        lock_ok(&self.side[from]).pop_front()
    }

    /// Blocking receive on one (peer, tag) channel.  The calling thread
    /// drains the underlying stream while it waits, parking messages for
    /// other tags in their FIFO queues.
    fn recv_tagged(&self, from: usize, tag: u32) -> Result<Vec<u32>, TransportError> {
        debug_assert!(tag < self.n_tags);
        if Some(tag) == self.side_tag {
            if let Some(msg) = self.pop_side(from) {
                return Ok(msg);
            }
        }
        let mut router = self.pending[from].lock().unwrap();
        if let Some(msg) = router[tag as usize].pop_front() {
            return Ok(msg);
        }
        loop {
            let raw = self.inner.recv_checked(from)?;
            if let Some(msg) = self.route(from, tag, raw, &mut router[..])? {
                return Ok(msg);
            }
        }
    }

    /// Non-blocking receive on one (peer, tag) channel: polls parked
    /// messages and whatever the fabric already buffered, without ever
    /// waiting — the heartbeat monitor's primitive.  A router busy in
    /// another thread's blocking receive reports `Ok(None)` (that thread
    /// will park our messages for the next poll).
    fn try_recv_tagged(&self, from: usize, tag: u32) -> Result<Option<Vec<u32>>, TransportError> {
        debug_assert!(tag < self.n_tags);
        if Some(tag) == self.side_tag {
            if let Some(msg) = self.pop_side(from) {
                return Ok(Some(msg));
            }
        }
        let Ok(mut router) = self.pending[from].try_lock() else {
            // a blocking receiver is draining this peer; side-channel
            // messages still surface above, everything else next poll
            return Ok(None);
        };
        if let Some(msg) = router[tag as usize].pop_front() {
            return Ok(Some(msg));
        }
        loop {
            let Some(raw) = self.inner.try_recv(from)? else {
                return Ok(None);
            };
            if let Some(msg) = self.route(from, tag, raw, &mut router[..])? {
                return Ok(Some(msg));
            }
        }
    }

    /// Fallible tagged send (heartbeats outlive dead peers).  Counts
    /// traffic only on success.
    fn send_tagged_checked(&self, to: usize, tag: u32, mut msg: Vec<u32>) -> Result<(), TransportError> {
        use std::sync::atomic::Ordering;
        debug_assert!(tag < self.n_tags);
        msg.push(tag);
        let words = msg.len() as u64;
        self.inner.send_checked(to, msg)?;
        let s = &self.stats[tag as usize];
        s.messages.fetch_add(1, Ordering::Relaxed);
        s.words.fetch_add(words, Ordering::Relaxed);
        Ok(())
    }

    /// Any out-of-band reshape frames parked (from any peer)?
    pub fn has_oob(&self) -> bool {
        self.oob.iter().any(|q| !lock_ok(q).is_empty())
    }

    /// Hand the parked out-of-band frames (tag stripped, arrival order,
    /// indexed by this mux's peer id) to the reshape driver, clearing
    /// the queues.
    pub fn drain_oob(&self) -> Vec<VecDeque<Vec<u32>>> {
        self.oob
            .iter()
            .map(|q| std::mem::take(&mut *lock_ok(q)))
            .collect()
    }

    /// Force-close the underlying link to `peer` (see
    /// [`Transport::sever`]).
    pub fn sever(&self, peer: usize) {
        self.inner.sever(peer);
    }
}

/// One logical channel of a [`TagMux`] — a full [`Transport`], safe to
/// move to (or clone into) any thread.
pub struct TagChannel<T: Transport> {
    mux: Arc<TagMux<T>>,
    tag: u32,
}

impl<T: Transport> TagChannel<T> {
    pub fn new(mux: Arc<TagMux<T>>, tag: u32) -> TagChannel<T> {
        assert!(tag < mux.n_tags, "tag {tag} outside the mux's {} channels", mux.n_tags);
        TagChannel { mux, tag }
    }

    pub fn tag(&self) -> u32 {
        self.tag
    }
}

impl<T: Transport> Clone for TagChannel<T> {
    fn clone(&self) -> Self {
        TagChannel { mux: Arc::clone(&self.mux), tag: self.tag }
    }
}

impl<T: Transport> Transport for TagChannel<T> {
    fn rank(&self) -> usize {
        self.mux.inner.rank()
    }

    fn world(&self) -> usize {
        self.mux.inner.world()
    }

    fn send(&self, to: usize, msg: Vec<u32>) {
        self.mux.send_tagged(to, self.tag, msg)
    }

    fn recv_checked(&self, from: usize) -> Result<Vec<u32>, TransportError> {
        self.mux.recv_tagged(from, self.tag)
    }

    fn try_recv(&self, from: usize) -> Result<Option<Vec<u32>>, TransportError> {
        self.mux.try_recv_tagged(from, self.tag)
    }

    fn send_checked(&self, to: usize, msg: Vec<u32>) -> Result<(), TransportError> {
        self.mux.send_tagged_checked(to, self.tag, msg)
    }

    fn sever(&self, peer: usize) {
        self.mux.sever(peer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::allgather;
    use crate::collectives::transport::LocalFabric;
    use std::thread;

    type LocalMux = Arc<TagMux<crate::collectives::LocalTransport>>;

    fn mux_pair(n_tags: u32) -> (LocalMux, LocalMux) {
        let mut fabric = LocalFabric::new(2);
        let a = Arc::new(TagMux::new(fabric.take(0), n_tags));
        let b = Arc::new(TagMux::new(fabric.take(1), n_tags));
        (a, b)
    }

    #[test]
    fn tags_route_to_their_channels() {
        let (a, b) = mux_pair(3);
        let a0 = TagChannel::new(Arc::clone(&a), 0);
        let a2 = TagChannel::new(Arc::clone(&a), 2);
        let b0 = TagChannel::new(Arc::clone(&b), 0);
        let b2 = TagChannel::new(Arc::clone(&b), 2);
        // rank 1 sends tag 2 first, then tag 0: the tag-0 receiver must
        // still get its own message while parking the tag-2 one
        b2.send(0, vec![22]);
        b0.send(0, vec![10]);
        assert_eq!(a0.recv(1), vec![10]);
        assert_eq!(a2.recv(1), vec![22]);
        // and the reverse direction
        a0.send(1, vec![7]);
        assert_eq!(b0.recv(0), vec![7]);
        drop((b2, a2));
    }

    #[test]
    fn per_tag_order_is_fifo() {
        let (a, b) = mux_pair(2);
        let a1 = TagChannel::new(Arc::clone(&a), 1);
        let b1 = TagChannel::new(Arc::clone(&b), 1);
        let b0 = TagChannel::new(Arc::clone(&b), 0);
        for i in 0..50u32 {
            b1.send(0, vec![i]);
            b0.send(0, vec![1000 + i]); // interleaved noise on tag 0
        }
        for i in 0..50u32 {
            assert_eq!(a1.recv(1), vec![i]);
        }
        // the parked tag-0 messages are intact and ordered
        let a0 = TagChannel::new(Arc::clone(&a), 0);
        for i in 0..50u32 {
            assert_eq!(a0.recv(1), vec![1000 + i]);
        }
    }

    #[test]
    fn untagged_and_out_of_range_messages_are_clean_errors() {
        let mut fabric = LocalFabric::new(2);
        let a = Arc::new(TagMux::new(fabric.take(0), 2));
        let raw_b = fabric.take(1);
        let chan = TagChannel::new(Arc::clone(&a), 0);
        raw_b.send(0, vec![]); // no tag word at all
        let err = chan.recv_checked(1).unwrap_err();
        assert!(err.reason.contains("untagged"), "{err}");
        raw_b.send(0, vec![1, 2, 9]); // trailing tag 9 with only 2 channels
        let err = chan.recv_checked(1).unwrap_err();
        assert!(err.reason.contains("outside"), "{err}");
    }

    #[test]
    fn self_channel_roundtrips_through_the_mux() {
        let mut fabric = LocalFabric::new(1);
        let m = Arc::new(TagMux::new(fabric.take(0), 2));
        let c1 = TagChannel::new(Arc::clone(&m), 1);
        c1.send(0, vec![5, 6]);
        assert_eq!(c1.recv(0), vec![5, 6]);
    }

    #[test]
    fn concurrent_allgathers_on_different_tags_do_not_cross() {
        // 4 ranks, each running two allgathers at once from two threads —
        // the exact sharing pattern of the pipelined engine's comm pool
        let world = 4;
        let mut fabric = LocalFabric::new(world);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let rank = t.rank();
                    let mux = Arc::new(TagMux::new(t, 2));
                    let c0 = TagChannel::new(Arc::clone(&mux), 0);
                    let c1 = TagChannel::new(Arc::clone(&mux), 1);
                    let h = thread::spawn(move || allgather(&c1, vec![rank as u32; 3]));
                    let got0 = allgather(&c0, vec![100 + rank as u32]);
                    (got0, h.join().unwrap())
                })
            })
            .collect();
        for h in handles {
            let (got0, got1) = h.join().unwrap();
            for r in 0..world {
                assert_eq!(got0[r], vec![100 + r as u32]);
                assert_eq!(got1[r], vec![r as u32; 3]);
            }
        }
    }

    #[test]
    fn tag_word_is_counted_as_traffic() {
        // the mux's 1-word tag is real wire overhead and must show up in
        // the fabric's byte accounting (the Eq. 1 audit relies on this)
        let mut fabric = LocalFabric::new(2);
        let stats = Arc::clone(&fabric.stats);
        let a = Arc::new(TagMux::new(fabric.take(0), 1));
        let b = fabric.take(1);
        let c = TagChannel::new(Arc::clone(&a), 0);
        c.send(1, vec![1, 2, 3]);
        assert_eq!(b.recv(0).len(), 4, "tag word + 3 payload words");
        assert_eq!(stats.words.load(std::sync::atomic::Ordering::Relaxed), 4);
    }

    #[test]
    fn per_tag_stats_split_the_fabric_counters() {
        // the mux's per-tag counters must sum to exactly what its
        // channels added to the fabric totals (tag words included), so
        // worker metrics can split control from bucket traffic
        let mut fabric = LocalFabric::new(2);
        let fabric_stats = Arc::clone(&fabric.stats);
        let a = Arc::new(TagMux::new(fabric.take(0), 3));
        let _b = fabric.take(1);
        let c0 = TagChannel::new(Arc::clone(&a), 0);
        let c2 = TagChannel::new(Arc::clone(&a), 2);
        c0.send(1, vec![1, 2, 3]); // 4 words on the wire
        c2.send(1, vec![9]); // 2 words
        c2.send(1, vec![]); // 1 word (tag only)
        assert_eq!(a.tag_stats(0).message_count(), 1);
        assert_eq!(a.tag_stats(0).bytes(), 16);
        assert_eq!(a.tag_stats(1).message_count(), 0);
        assert_eq!(a.tag_stats(2).message_count(), 2);
        assert_eq!(a.tag_stats(2).bytes(), 12);
        let (msgs, words) = a.aggregate();
        assert_eq!(msgs, 3);
        assert_eq!(words, 7);
        assert_eq!(
            words,
            fabric_stats.words.load(std::sync::atomic::Ordering::Relaxed),
            "mux aggregate must equal what the fabric was charged"
        );
    }

    #[test]
    #[should_panic(expected = "outside the mux's")]
    fn channel_tag_must_be_in_range() {
        let mut fabric = LocalFabric::new(1);
        let m = Arc::new(TagMux::new(fabric.take(0), 2));
        let _ = TagChannel::new(m, 2);
    }

    #[test]
    fn oob_frames_are_parked_and_surface_a_clean_error() {
        use crate::collectives::transport::PeerLostCause;
        let mut fabric = LocalFabric::new(2);
        let a = Arc::new(TagMux::new(fabric.take(0), 2));
        let raw_b = fabric.take(1);
        let chan = TagChannel::new(Arc::clone(&a), 0);
        // a reshape frame: payload + the reserved OOB tag word
        raw_b.send(0, vec![7, 8, OOB_TAG]);
        let err = chan.recv_checked(1).unwrap_err();
        assert_eq!(err.cause, PeerLostCause::OutOfBand, "{err}");
        assert!(a.has_oob());
        let mut parked = a.drain_oob();
        assert_eq!(parked[1].pop_front().unwrap(), vec![7, 8], "tag stripped, frame kept");
        assert!(!a.has_oob(), "drained");
    }

    #[test]
    fn try_recv_on_a_channel_polls_and_parks() {
        let (a, b) = mux_pair(2);
        let a0 = TagChannel::new(Arc::clone(&a), 0);
        let a1 = TagChannel::new(Arc::clone(&a), 1);
        let b0 = TagChannel::new(Arc::clone(&b), 0);
        let b1 = TagChannel::new(Arc::clone(&b), 1);
        assert!(a1.try_recv(1).unwrap().is_none(), "idle");
        b0.send(0, vec![10]); // noise for tag 0
        b1.send(0, vec![11]);
        // polling tag 1 must deliver its message and park the tag-0 one
        assert_eq!(a1.try_recv(1).unwrap(), Some(vec![11]));
        assert_eq!(a0.try_recv(1).unwrap(), Some(vec![10]));
        assert!(a0.try_recv(1).unwrap().is_none());
        drop((b0, b1));
    }

    #[test]
    fn side_channel_messages_survive_a_blocked_router() {
        // the elastic liveness property: peer beats stay observable by a
        // poller even while another thread's blocking receive holds the
        // peer's router (a collective waiting on a slow peer)
        let mut fabric = LocalFabric::new(2);
        let a = Arc::new(TagMux::with_side_channel(fabric.take(0), 2, 1));
        let b = Arc::new(TagMux::with_side_channel(fabric.take(1), 2, 1));
        let a_ctrl = TagChannel::new(Arc::clone(&a), 0);
        let a_side = TagChannel::new(Arc::clone(&a), 1);
        let b_ctrl = TagChannel::new(Arc::clone(&b), 0);
        let b_side = TagChannel::new(Arc::clone(&b), 1);
        // a blocking ctrl receive on rank 0 drains rank 1's stream
        let blocker = thread::spawn(move || a_ctrl.recv(1));
        // give the blocker time to take the router lock
        thread::sleep(std::time::Duration::from_millis(30));
        b_side.send(0, vec![0x4842]);
        // the poller must see the beat while the router stays locked
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            match a_side.try_recv(1).unwrap() {
                Some(msg) => {
                    assert_eq!(msg, vec![0x4842]);
                    break;
                }
                None if std::time::Instant::now() > deadline => {
                    panic!("beat invisible behind the blocked router")
                }
                None => thread::sleep(std::time::Duration::from_millis(1)),
            }
        }
        // release the blocker and check ctrl traffic was untouched
        b_ctrl.send(0, vec![7]);
        assert_eq!(blocker.join().unwrap(), vec![7]);
        drop(b_side);
    }

    #[test]
    fn send_checked_on_a_channel_counts_only_successes() {
        let mut fabric = LocalFabric::new(2);
        let a = Arc::new(TagMux::new(fabric.take(0), 1));
        let b = fabric.take(1);
        let c = TagChannel::new(Arc::clone(&a), 0);
        c.send_checked(1, vec![1, 2]).unwrap();
        assert_eq!(b.recv(0), vec![1, 2, 0], "payload + tag word");
        assert_eq!(a.tag_stats(0).message_count(), 1);
        drop(b);
        assert!(c.send_checked(1, vec![3]).is_err());
        assert_eq!(a.tag_stats(0).message_count(), 1, "failed send not counted");
    }
}
