//! Tag-multiplexed logical channels over one [`Transport`] endpoint.
//!
//! The pipelined sync engine (`crate::pipeline`) runs several bucket
//! collectives *concurrently* over a single fabric endpoint.  The base
//! `Transport` demultiplexes inbound traffic by peer only, so two
//! in-flight collectives would steal each other's messages.  [`TagMux`]
//! fixes that with the MPI tag-matching discipline over ordered streams:
//! every outbound message gets a trailing *tag* word naming its logical
//! channel (trailing, not leading, so tagging is an amortized-O(1)
//! `push` and untagging an O(1) `pop` instead of a whole-message copy),
//! and inbound messages are routed into per-(peer, tag) FIFO queues.
//! Each [`TagChannel`] then behaves exactly like a private `Transport`,
//! so the collectives run over it unchanged.
//!
//! ## Why frames never interleave
//!
//! Both real fabrics send each message atomically — one mpsc element
//! in-process, one length-prefixed frame written by the peer's single
//! writer thread over TCP (`net::tcp`) — so concurrent tagged senders
//! interleave whole messages, never words inside one.  The tag word is
//! all the demux needs.
//!
//! ## Why tags may be reused across steps
//!
//! Per-(src, dst, tag) order is preserved end-to-end: the underlying
//! stream is ordered per peer, and routing appends to FIFO queues.  A
//! bucket that reuses its tag next step enqueues strictly *behind* any
//! of its still-undrained messages from this step, so cross-step
//! confusion is impossible — the argument that makes the engine's
//! bounded in-flight window safe without a per-step epoch in the wire
//! format.
//!
//! ## Blocking discipline
//!
//! `recv` on a channel locks that peer's router and drains the underlying
//! stream, parking other tags' messages in their queues.  Another thread
//! waiting on a different tag of the same peer blocks on the router lock
//! until the first receiver gets its message; progress is guaranteed
//! because every parked message was already sent (sends never block) and
//! collectives consume exactly what they are sent.

use super::transport::{TrafficStats, Transport, TransportError};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Demultiplexer wrapping one fabric endpoint into `n_tags` logical
/// channels.  Build once per endpoint, share via `Arc`, and mint
/// channels with [`TagChannel::new`].
///
/// While a mux is live, *all* traffic on the endpoint must flow through
/// its channels: a raw `recv` on the inner transport could steal a tagged
/// message, and a raw `send` would arrive without a tag (a clean error on
/// the receiving mux, but an error nonetheless).
pub struct TagMux<T: Transport> {
    inner: T,
    n_tags: u32,
    /// pending[peer][tag]: messages received for a tag no channel was
    /// draining at the time.
    pending: Vec<Mutex<Vec<VecDeque<Vec<u32>>>>>,
    /// Per-tag outbound counters (words include the tag word, matching
    /// what the underlying fabric charges), so per-fabric totals can be
    /// split into control vs bucket streams.
    stats: Vec<TrafficStats>,
}

impl<T: Transport> TagMux<T> {
    /// Wrap `inner`, reserving tags `0..n_tags`.
    pub fn new(inner: T, n_tags: u32) -> TagMux<T> {
        assert!(n_tags >= 1, "a mux needs at least one channel");
        let world = inner.world();
        let pending = (0..world)
            .map(|_| Mutex::new((0..n_tags as usize).map(|_| VecDeque::new()).collect()))
            .collect();
        let stats = (0..n_tags).map(|_| TrafficStats::default()).collect();
        TagMux { inner, n_tags, pending, stats }
    }

    /// Outbound traffic of one logical channel (words include the tag
    /// word each message carries on the wire).
    pub fn tag_stats(&self, tag: u32) -> &TrafficStats {
        &self.stats[tag as usize]
    }

    /// Aggregate outbound `(messages, words)` across every channel of
    /// this mux — by construction exactly what the muxed streams added
    /// to the underlying fabric's counters.
    pub fn aggregate(&self) -> (u64, u64) {
        use std::sync::atomic::Ordering;
        self.stats.iter().fold((0, 0), |(m, w), s| {
            (m + s.messages.load(Ordering::Relaxed), w + s.words.load(Ordering::Relaxed))
        })
    }

    pub fn rank(&self) -> usize {
        self.inner.rank()
    }

    pub fn world(&self) -> usize {
        self.inner.world()
    }

    pub fn n_tags(&self) -> u32 {
        self.n_tags
    }

    fn send_tagged(&self, to: usize, tag: u32, mut msg: Vec<u32>) {
        use std::sync::atomic::Ordering;
        debug_assert!(tag < self.n_tags);
        msg.push(tag);
        let s = &self.stats[tag as usize];
        s.messages.fetch_add(1, Ordering::Relaxed);
        s.words.fetch_add(msg.len() as u64, Ordering::Relaxed);
        self.inner.send(to, msg);
    }

    /// Blocking receive on one (peer, tag) channel.  The calling thread
    /// drains the underlying stream while it waits, parking messages for
    /// other tags in their FIFO queues.
    fn recv_tagged(&self, from: usize, tag: u32) -> Result<Vec<u32>, TransportError> {
        debug_assert!(tag < self.n_tags);
        let mut router = self.pending[from].lock().unwrap();
        if let Some(msg) = router[tag as usize].pop_front() {
            return Ok(msg);
        }
        loop {
            let mut raw = self.inner.recv_checked(from)?;
            let Some(t) = raw.pop() else {
                return Err(TransportError {
                    peer: from,
                    reason: "untagged (empty) message on a multiplexed fabric".into(),
                });
            };
            if t >= self.n_tags {
                return Err(TransportError {
                    peer: from,
                    reason: format!(
                        "message tagged {t} outside the fabric's {} channels",
                        self.n_tags
                    ),
                });
            }
            if t == tag {
                return Ok(raw);
            }
            router[t as usize].push_back(raw);
        }
    }
}

/// One logical channel of a [`TagMux`] — a full [`Transport`], safe to
/// move to (or clone into) any thread.
pub struct TagChannel<T: Transport> {
    mux: Arc<TagMux<T>>,
    tag: u32,
}

impl<T: Transport> TagChannel<T> {
    pub fn new(mux: Arc<TagMux<T>>, tag: u32) -> TagChannel<T> {
        assert!(tag < mux.n_tags, "tag {tag} outside the mux's {} channels", mux.n_tags);
        TagChannel { mux, tag }
    }

    pub fn tag(&self) -> u32 {
        self.tag
    }
}

impl<T: Transport> Clone for TagChannel<T> {
    fn clone(&self) -> Self {
        TagChannel { mux: Arc::clone(&self.mux), tag: self.tag }
    }
}

impl<T: Transport> Transport for TagChannel<T> {
    fn rank(&self) -> usize {
        self.mux.inner.rank()
    }

    fn world(&self) -> usize {
        self.mux.inner.world()
    }

    fn send(&self, to: usize, msg: Vec<u32>) {
        self.mux.send_tagged(to, self.tag, msg)
    }

    fn recv_checked(&self, from: usize) -> Result<Vec<u32>, TransportError> {
        self.mux.recv_tagged(from, self.tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::allgather;
    use crate::collectives::transport::LocalFabric;
    use std::thread;

    type LocalMux = Arc<TagMux<crate::collectives::LocalTransport>>;

    fn mux_pair(n_tags: u32) -> (LocalMux, LocalMux) {
        let mut fabric = LocalFabric::new(2);
        let a = Arc::new(TagMux::new(fabric.take(0), n_tags));
        let b = Arc::new(TagMux::new(fabric.take(1), n_tags));
        (a, b)
    }

    #[test]
    fn tags_route_to_their_channels() {
        let (a, b) = mux_pair(3);
        let a0 = TagChannel::new(Arc::clone(&a), 0);
        let a2 = TagChannel::new(Arc::clone(&a), 2);
        let b0 = TagChannel::new(Arc::clone(&b), 0);
        let b2 = TagChannel::new(Arc::clone(&b), 2);
        // rank 1 sends tag 2 first, then tag 0: the tag-0 receiver must
        // still get its own message while parking the tag-2 one
        b2.send(0, vec![22]);
        b0.send(0, vec![10]);
        assert_eq!(a0.recv(1), vec![10]);
        assert_eq!(a2.recv(1), vec![22]);
        // and the reverse direction
        a0.send(1, vec![7]);
        assert_eq!(b0.recv(0), vec![7]);
        drop((b2, a2));
    }

    #[test]
    fn per_tag_order_is_fifo() {
        let (a, b) = mux_pair(2);
        let a1 = TagChannel::new(Arc::clone(&a), 1);
        let b1 = TagChannel::new(Arc::clone(&b), 1);
        let b0 = TagChannel::new(Arc::clone(&b), 0);
        for i in 0..50u32 {
            b1.send(0, vec![i]);
            b0.send(0, vec![1000 + i]); // interleaved noise on tag 0
        }
        for i in 0..50u32 {
            assert_eq!(a1.recv(1), vec![i]);
        }
        // the parked tag-0 messages are intact and ordered
        let a0 = TagChannel::new(Arc::clone(&a), 0);
        for i in 0..50u32 {
            assert_eq!(a0.recv(1), vec![1000 + i]);
        }
    }

    #[test]
    fn untagged_and_out_of_range_messages_are_clean_errors() {
        let mut fabric = LocalFabric::new(2);
        let a = Arc::new(TagMux::new(fabric.take(0), 2));
        let raw_b = fabric.take(1);
        let chan = TagChannel::new(Arc::clone(&a), 0);
        raw_b.send(0, vec![]); // no tag word at all
        let err = chan.recv_checked(1).unwrap_err();
        assert!(err.reason.contains("untagged"), "{err}");
        raw_b.send(0, vec![1, 2, 9]); // trailing tag 9 with only 2 channels
        let err = chan.recv_checked(1).unwrap_err();
        assert!(err.reason.contains("outside"), "{err}");
    }

    #[test]
    fn self_channel_roundtrips_through_the_mux() {
        let mut fabric = LocalFabric::new(1);
        let m = Arc::new(TagMux::new(fabric.take(0), 2));
        let c1 = TagChannel::new(Arc::clone(&m), 1);
        c1.send(0, vec![5, 6]);
        assert_eq!(c1.recv(0), vec![5, 6]);
    }

    #[test]
    fn concurrent_allgathers_on_different_tags_do_not_cross() {
        // 4 ranks, each running two allgathers at once from two threads —
        // the exact sharing pattern of the pipelined engine's comm pool
        let world = 4;
        let mut fabric = LocalFabric::new(world);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let rank = t.rank();
                    let mux = Arc::new(TagMux::new(t, 2));
                    let c0 = TagChannel::new(Arc::clone(&mux), 0);
                    let c1 = TagChannel::new(Arc::clone(&mux), 1);
                    let h = thread::spawn(move || allgather(&c1, vec![rank as u32; 3]));
                    let got0 = allgather(&c0, vec![100 + rank as u32]);
                    (got0, h.join().unwrap())
                })
            })
            .collect();
        for h in handles {
            let (got0, got1) = h.join().unwrap();
            for r in 0..world {
                assert_eq!(got0[r], vec![100 + r as u32]);
                assert_eq!(got1[r], vec![r as u32; 3]);
            }
        }
    }

    #[test]
    fn tag_word_is_counted_as_traffic() {
        // the mux's 1-word tag is real wire overhead and must show up in
        // the fabric's byte accounting (the Eq. 1 audit relies on this)
        let mut fabric = LocalFabric::new(2);
        let stats = Arc::clone(&fabric.stats);
        let a = Arc::new(TagMux::new(fabric.take(0), 1));
        let b = fabric.take(1);
        let c = TagChannel::new(Arc::clone(&a), 0);
        c.send(1, vec![1, 2, 3]);
        assert_eq!(b.recv(0).len(), 4, "tag word + 3 payload words");
        assert_eq!(stats.words.load(std::sync::atomic::Ordering::Relaxed), 4);
    }

    #[test]
    fn per_tag_stats_split_the_fabric_counters() {
        // the mux's per-tag counters must sum to exactly what its
        // channels added to the fabric totals (tag words included), so
        // worker metrics can split control from bucket traffic
        let mut fabric = LocalFabric::new(2);
        let fabric_stats = Arc::clone(&fabric.stats);
        let a = Arc::new(TagMux::new(fabric.take(0), 3));
        let _b = fabric.take(1);
        let c0 = TagChannel::new(Arc::clone(&a), 0);
        let c2 = TagChannel::new(Arc::clone(&a), 2);
        c0.send(1, vec![1, 2, 3]); // 4 words on the wire
        c2.send(1, vec![9]); // 2 words
        c2.send(1, vec![]); // 1 word (tag only)
        assert_eq!(a.tag_stats(0).message_count(), 1);
        assert_eq!(a.tag_stats(0).bytes(), 16);
        assert_eq!(a.tag_stats(1).message_count(), 0);
        assert_eq!(a.tag_stats(2).message_count(), 2);
        assert_eq!(a.tag_stats(2).bytes(), 12);
        let (msgs, words) = a.aggregate();
        assert_eq!(msgs, 3);
        assert_eq!(words, 7);
        assert_eq!(
            words,
            fabric_stats.words.load(std::sync::atomic::Ordering::Relaxed),
            "mux aggregate must equal what the fabric was charged"
        );
    }

    #[test]
    #[should_panic(expected = "outside the mux's")]
    fn channel_tag_must_be_in_range() {
        let mut fabric = LocalFabric::new(1);
        let m = Arc::new(TagMux::new(fabric.take(0), 2));
        let _ = TagChannel::new(m, 2);
    }
}
