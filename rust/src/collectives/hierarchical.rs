//! Hierarchical sparse allgather — the §5.3 topology-aware schedule.
//!
//! Flat sparse allgather moves `(p-1)·m` bytes per rank over whatever
//! link happens to connect each peer pair; at scale most of that
//! crosses the slow inter-node fabric, which is why DGC-style flat
//! schedules stop paying off as the world grows.  The hierarchical
//! schedule keeps the bulk of the traffic inside a node:
//!
//! 1. **Intra-node gather** — every non-leader sends its message to the
//!    node leader (local rank 0 of the intra-node group).
//! 2. **Inter-node allgather** — each leader packs its node's messages
//!    into one *node blob* (per-rank boundaries preserved) and runs the
//!    ordinary allgather over the leader group — only `nodes`
//!    participants, so the slow-link bytes drop from `Θ(p²·m)` to
//!    `Θ(nodes²·s·m)`.
//! 3. **Intra-node broadcast** — each leader sends the assembled world
//!    blob back to its node members; every rank unpacks it into the
//!    per-world-rank result.
//!
//! All addressing goes through the [`Communicator`]'s derived
//! [`super::group::ProcessGroup`]s (intra-node, leaders): the schedule
//! is written in group-local ranks and the groups do the world-rank
//! translation.
//!
//! ## Bit-identity with the flat schedule
//!
//! The node-level aggregation is a *structural* union: messages are
//! concatenated under `[rank, len]` block headers, never value-merged,
//! so every rank ends with exactly the per-rank blobs the flat
//! allgather would deliver, in world-rank order.  Decompression then
//! applies them in the same float order — parameters stay bit-identical
//! (pinned in `tests/topology.rs` on both fabrics).  The value-merging
//! union (`compression::message::merge_plain`) halves inter-node bytes
//! further but changes summation order; it is modeled by the cost
//! model, not used on the schedule.
//!
//! Traffic is exactly accountable ([`hierarchical_traffic_words`]);
//! `tests/topology.rs` pins the fabric counters to it word-for-word,
//! and its payload component to the cost-model bandwidth term
//! (`costmodel::hierarchical_payload_words`).

use super::allgather::{allgather_ref, pack_blocks, Gathered};
use super::group::{Communicator, Topology};
use super::transport::Transport;
use std::sync::Arc;

/// Gather each rank's `msg` over the hierarchical schedule; returns all
/// contributions indexed by world rank — the same contract (and the
/// same bits) as [`crate::collectives::allgather`], with a
/// topology-shaped schedule.  Compat shape; the hot path uses
/// [`hierarchical_allgather_ref`].
pub fn hierarchical_allgather<T: Transport>(t: &T, topo: Topology, msg: Vec<u32>) -> Vec<Vec<u32>> {
    hierarchical_allgather_ref(t, topo, &msg).into_parts()
}

/// [`hierarchical_allgather`] borrowing the caller's message and
/// returning the single-buffer [`Gathered`] form.  The wire schedule and
/// every byte on it are identical to the historical implementation; the
/// zero-copy wins are local: a non-leader parses the received world blob
/// *in place* (spans into the blob, no per-rank copies), and the leader
/// broadcast ships one shared buffer instead of `s - 2` clones.
pub fn hierarchical_allgather_ref<T: Transport>(t: &T, topo: Topology, msg: &[u32]) -> Gathered {
    assert_eq!(topo.world(), t.world(), "topology {} over world {}", topo.label(), t.world());
    if t.world() == 1 {
        return Gathered::single(msg.to_vec());
    }
    let rank = t.rank();
    let comm = Communicator::new(t, topo);
    let intra = comm.intra_group();

    if !topo.is_leader(rank) {
        // phase 1: hand the contribution to the node leader (local 0).
        // The to_vec is the one copy borrowing costs on this schedule:
        // the historical code moved an owned blob here but then had to
        // re-allocate and re-fill it next step — same words either way,
        // and the caller's persistent pack buffer keeps its capacity.
        intra.send(0, msg.to_vec());
        // ...phase 3: the assembled world blob comes back; address it in
        // place instead of copying every rank's payload out
        let blob = intra.recv(0);
        return parse_world_blob(blob, topo.world());
    }

    // leader: the node's messages in member (= world-rank) order, own
    // message first — the historical packing order, byte for byte
    let mut member_msgs: Vec<(u32, Vec<u32>)> = Vec::with_capacity(intra.world() - 1);
    for local in 1..intra.world() {
        member_msgs.push((intra.world_rank(local) as u32, intra.recv(local)));
    }
    let refs: Vec<(u32, &[u32])> = std::iter::once((rank as u32, msg))
        .chain(member_msgs.iter().map(|(r, p)| (*r, p.as_slice())))
        .collect();

    // phase 2: allgather node blobs among the per-node leaders
    let leaders = comm.leaders_group().expect("a leader can build the leader group");
    let node_blobs = allgather_ref(&leaders, &pack_blocks(&refs));
    let result = assemble_world(&node_blobs, topo.world());

    // phase 3: broadcast the world blob to the node — ONE shared buffer
    // enqueued s-1 times (`send_shared`), zero per-peer clones at the
    // leader
    let s = intra.world();
    if s > 1 {
        let world_blob = Arc::new(pack_world_blob(&result));
        for local in 1..s {
            intra.send_shared(local, &world_blob);
        }
    }
    result
}

/// [`pack_blocks`] framing over the finished world result (block `r` is
/// world rank `r`'s payload), borrowing the payloads straight out of the
/// gather buffer.
fn pack_world_blob(result: &Gathered) -> Vec<u32> {
    let p = result.n_ranks();
    let mut out = Vec::with_capacity(1 + 2 * p + result.payload_words());
    out.push(p as u32);
    for (r, b) in result.blocks().enumerate() {
        out.push(r as u32);
        out.push(b.len() as u32);
    }
    for b in result.blocks() {
        out.extend_from_slice(b);
    }
    out
}

/// Address a received world blob in place: spans point into the blob
/// past its `[count][rank, len]…` headers — the non-leader's whole
/// phase-3 cost is this header walk.
fn parse_world_blob(blob: Vec<u32>, world: usize) -> Gathered {
    assert!(!blob.is_empty(), "empty world blob");
    let count = blob[0] as usize;
    assert_eq!(count, world, "world blob carries {count} blocks for a {world}-rank world");
    let mut spans: Vec<Option<(usize, usize)>> = vec![None; world];
    let mut off = 1 + 2 * count;
    for i in 0..count {
        let r = blob[1 + 2 * i] as usize;
        let len = blob[2 + 2 * i] as usize;
        let slot = &mut spans[r];
        assert!(slot.is_none(), "duplicate block for rank {r}");
        *slot = Some((off, len));
        off += len;
    }
    assert!(off <= blob.len(), "world blob truncated");
    let spans = spans
        .into_iter()
        .enumerate()
        .map(|(r, s)| s.unwrap_or_else(|| panic!("missing block for rank {r}")))
        .collect();
    Gathered::from_spans(blob, spans)
}

/// Assemble the world result from the leaders' gathered node blobs:
/// every node blob's framed blocks are copied once into one buffer,
/// spans indexed by world rank.
fn assemble_world(node_blobs: &Gathered, world: usize) -> Gathered {
    let mut total = 0usize;
    for nb in node_blobs.blocks() {
        let count = nb[0] as usize;
        for i in 0..count {
            total += nb[2 + 2 * i] as usize;
        }
    }
    let mut buf = Vec::with_capacity(total);
    let mut spans: Vec<Option<(usize, usize)>> = vec![None; world];
    for nb in node_blobs.blocks() {
        let count = nb[0] as usize;
        let mut off = 1 + 2 * count;
        for i in 0..count {
            let r = nb[1 + 2 * i] as usize;
            let len = nb[2 + 2 * i] as usize;
            let slot = &mut spans[r];
            assert!(slot.is_none(), "duplicate block for rank {r}");
            *slot = Some((buf.len(), len));
            buf.extend_from_slice(&nb[off..off + len]);
            off += len;
        }
    }
    let spans = spans
        .into_iter()
        .enumerate()
        .map(|(r, s)| s.unwrap_or_else(|| panic!("missing block for rank {r}")))
        .collect();
    Gathered::from_spans(buf, spans)
}

/// Exact fabric traffic of one [`hierarchical_allgather`] where every
/// rank contributes `msg_words` payload words: `(payload, headers)` in
/// words, summed over all ranks.  The payload component is the
/// bandwidth term the hierarchical cost model charges
/// (`costmodel::hierarchical_payload_words`); the headers are the
/// `[count]`/`[rank, len]` block framing, deterministic because the
/// schedule is.  `tests/topology.rs` asserts the fabric counters equal
/// `payload + headers` word-for-word.
pub fn hierarchical_traffic_words(
    nodes: usize,
    ranks_per_node: usize,
    msg_words: usize,
) -> (u64, u64) {
    let (n, s) = (nodes as u64, ranks_per_node as u64);
    let p = n * s;
    let m = msg_words as u64;
    if p <= 1 {
        return (0, 0);
    }

    // phase 1: per node, s-1 raw (unframed) messages of m words
    let payload1 = n * (s - 1) * m;

    // phase 2: leaders allgather node blobs B = 1 + s·(2 + m) words;
    // recursive doubling when the node count is a power of two (step j
    // sends 2^j blobs under one [count] word + [rank, len] each), ring
    // otherwise (n-1 single-blob messages per leader)
    let blob_headers = 1 + 2 * s; // [count] + s × [rank, len]
    let (payload2, headers2) = if n == 1 {
        (0, 0)
    } else if n.is_power_of_two() {
        let lg = n.trailing_zeros() as u64;
        (n * (n - 1) * s * m, n * (lg + (n - 1) * (2 + blob_headers)))
    } else {
        (n * (n - 1) * s * m, n * (n - 1) * (3 + blob_headers))
    };

    // phase 3: per node, s-1 copies of the world blob W = 1 + p·(2 + m)
    let payload3 = n * (s - 1) * p * m;
    let headers3 = n * (s - 1) * (1 + 2 * p);

    (payload1 + payload2 + payload3, headers2 + headers3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::LocalFabric;
    use std::sync::Arc;
    use std::thread;

    fn rank_msg(rank: usize, len: usize) -> Vec<u32> {
        (0..len).map(|i| (rank * 1000 + i) as u32).collect()
    }

    fn run_hier(
        topo: Topology,
        len_of: impl Fn(usize) -> usize + Copy + Send + 'static,
    ) -> Vec<Vec<Vec<u32>>> {
        let world = topo.world();
        let mut fabric = LocalFabric::new(world);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let msg = rank_msg(t.rank(), len_of(t.rank()));
                    hierarchical_allgather(&t, topo, msg)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn matches_flat_allgather_across_shapes() {
        for (nodes, rpn) in [(2usize, 4usize), (4, 2), (8, 1), (1, 8), (3, 2), (2, 3)] {
            let topo = Topology::new(nodes, rpn);
            let results = run_hier(topo, |r| r + 1);
            for got in &results {
                assert_eq!(got.len(), topo.world());
                for (r, part) in got.iter().enumerate() {
                    assert_eq!(part, &rank_msg(r, r + 1), "topology {}", topo.label());
                }
            }
        }
    }

    #[test]
    fn empty_contributions_survive_the_hierarchy() {
        let topo = Topology::new(2, 2);
        let results = run_hier(topo, |r| if r % 2 == 0 { 0 } else { 2 });
        for got in &results {
            assert!(got[0].is_empty() && got[2].is_empty());
            assert_eq!(got[1].len(), 2);
            assert_eq!(got[3].len(), 2);
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let topo = Topology::new(1, 1);
        let results = run_hier(topo, |_| 3);
        assert_eq!(results[0], vec![rank_msg(0, 3)]);
    }

    #[test]
    fn traffic_matches_exact_accounting() {
        for (nodes, rpn) in [(2usize, 4usize), (4, 2), (1, 4), (4, 1), (3, 2)] {
            let topo = Topology::new(nodes, rpn);
            let world = topo.world();
            let m = 64usize;
            let mut fabric = LocalFabric::new(world);
            let stats = Arc::clone(&fabric.stats);
            let handles: Vec<_> = fabric
                .take_all()
                .into_iter()
                .map(|t| {
                    thread::spawn(move || {
                        hierarchical_allgather(&t, topo, vec![7u32; m]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let (payload, headers) = hierarchical_traffic_words(nodes, rpn, m);
            let total = stats.words.load(std::sync::atomic::Ordering::Relaxed);
            assert_eq!(
                total,
                payload + headers,
                "topology {}: fabric moved {total} words, accounting says {payload} + {headers}",
                topo.label()
            );
        }
    }

    #[test]
    fn hierarchy_shrinks_leader_link_traffic() {
        // the point of the scheme: inter-node (phase 2) payload is
        // n·(n-1)·s·m vs the flat schedule's p·(p-1)·m total
        let (n, s, m) = (2u64, 4u64, 100u64);
        let p = n * s;
        let inter = n * (n - 1) * s * m;
        let flat_total = p * (p - 1) * m;
        assert!(inter * 4 <= flat_total, "inter-node {inter} vs flat {flat_total}");
    }
}
