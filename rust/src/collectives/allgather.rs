//! Allgather — the sparse-synchronization primitive (§5.3, Appendix B).
//!
//! Recursive doubling for power-of-two worlds (lg p steps, (p-1)·m bytes
//! per rank — the schedule Eq. 1 charges), ring allgather as the general
//! fallback.  Both support *variable-length* contributions: with threshold
//! binary search each rank's compressed residual differs in length, so
//! blocks travel with `[rank, len]` headers and are reassembled in rank
//! order at the end.
//!
//! The primary result shape is [`Gathered`]: every rank's block inside
//! ONE owned buffer addressed by `(start, len)` spans, so the §5.4
//! decompression walk reads straight from the gather buffer instead of
//! p freshly allocated per-rank `Vec`s (DESIGN.md §Zero-Copy-Hot-Path).
//! The `Vec<Vec<u32>>` shape survives as a compat wrapper for tests and
//! non-hot callers.

use super::transport::Transport;

/// An allgather result: every rank's contribution inside one owned
/// buffer, addressed by per-rank `(start, len)` spans.  `buf` may hold
/// framing words outside the spans (the hierarchical broadcast parses
/// the leader's world blob in place, headers and all), so consumers go
/// through [`block`](Gathered::block) / [`blocks`](Gathered::blocks).
pub struct Gathered {
    buf: Vec<u32>,
    spans: Vec<(usize, usize)>,
}

impl Gathered {
    /// Single-rank result: the whole buffer is rank 0's block.
    pub fn single(buf: Vec<u32>) -> Gathered {
        let n = buf.len();
        Gathered { buf, spans: vec![(0, n)] }
    }

    /// Wrap an already-framed buffer with externally computed spans.
    pub(crate) fn from_spans(buf: Vec<u32>, spans: Vec<(usize, usize)>) -> Gathered {
        debug_assert!(spans.iter().all(|&(s, l)| s + l <= buf.len()));
        Gathered { buf, spans }
    }

    pub fn n_ranks(&self) -> usize {
        self.spans.len()
    }

    /// Rank `r`'s contribution.
    pub fn block(&self, r: usize) -> &[u32] {
        let (start, len) = self.spans[r];
        &self.buf[start..start + len]
    }

    /// All blocks in rank order.
    pub fn blocks(&self) -> impl Iterator<Item = &[u32]> {
        (0..self.spans.len()).map(move |r| self.block(r))
    }

    /// Total payload words across ranks (framing excluded).
    pub fn payload_words(&self) -> usize {
        self.spans.iter().map(|&(_, l)| l).sum()
    }

    /// Assemble from borrowed per-rank parts — one copy into the single
    /// buffer (tests, benches, compat).
    pub fn from_parts(parts: &[Vec<u32>]) -> Gathered {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let mut buf = Vec::with_capacity(total);
        let mut spans = Vec::with_capacity(parts.len());
        for p in parts {
            spans.push((buf.len(), p.len()));
            buf.extend_from_slice(p);
        }
        Gathered { buf, spans }
    }

    /// Copy out per-rank parts — the historical result shape.
    pub fn into_parts(self) -> Vec<Vec<u32>> {
        (0..self.n_ranks()).map(|r| self.block(r).to_vec()).collect()
    }
}

/// Gather each rank's `msg`; returns all contributions indexed by rank.
/// Dispatches to recursive doubling when `world` is a power of two.
///
/// `t` is any [`Transport`], including a
/// [`ProcessGroup`](super::group::ProcessGroup): over a group the
/// collective runs among the members only and the result is indexed by
/// *group-local* rank — how the hierarchical schedule runs its
/// inter-node leader allgather.
///
/// Compat shape; the hot path uses [`allgather_ref`].
pub fn allgather<T: Transport>(t: &T, msg: Vec<u32>) -> Vec<Vec<u32>> {
    allgather_ref(t, &msg).into_parts()
}

/// [`allgather`] borrowing the caller's message (the bucket's persistent
/// pack blob is read, never consumed) and returning the single-buffer
/// [`Gathered`] form.  Wire schedule and bytes are identical to the
/// historical implementation; only the result representation differs.
pub fn allgather_ref<T: Transport>(t: &T, msg: &[u32]) -> Gathered {
    if t.world().is_power_of_two() {
        allgather_rd_ref(t, msg)
    } else {
        allgather_ring_ref(t, msg)
    }
}

/// Serialize a set of (rank, payload) blocks:
/// `[count][rank_0, len_0]...[rank_{c-1}, len_{c-1}][payload_0 ...]`.
/// Shared with the hierarchical schedule, which uses the same framing
/// for node blobs and the leader broadcast.  Generic over the payload
/// holder so owned blocks and borrowed slices pack the same bytes.
pub(crate) fn pack_blocks<B: AsRef<[u32]>>(blocks: &[(u32, B)]) -> Vec<u32> {
    let payload: usize = blocks.iter().map(|(_, p)| p.as_ref().len()).sum();
    let mut out = Vec::with_capacity(1 + 2 * blocks.len() + payload);
    out.push(blocks.len() as u32);
    for (r, p) in blocks {
        out.push(*r);
        out.push(p.as_ref().len() as u32);
    }
    for (_, p) in blocks {
        out.extend_from_slice(p.as_ref());
    }
    out
}

pub(crate) fn unpack_blocks(buf: &[u32]) -> Vec<(u32, Vec<u32>)> {
    let count = buf[0] as usize;
    let mut headers = Vec::with_capacity(count);
    for i in 0..count {
        headers.push((buf[1 + 2 * i], buf[2 + 2 * i] as usize));
    }
    let mut off = 1 + 2 * count;
    let mut out = Vec::with_capacity(count);
    for (rank, len) in headers {
        out.push((rank, buf[off..off + len].to_vec()));
        off += len;
    }
    out
}

/// Recursive doubling: at step s, exchange all accumulated blocks with the
/// partner at distance 2^s.  Exactly lg(p) rounds.  Compat shape.
pub fn allgather_recursive_doubling<T: Transport>(t: &T, msg: Vec<u32>) -> Vec<Vec<u32>> {
    allgather_rd_ref(t, &msg).into_parts()
}

fn allgather_rd_ref<T: Transport>(t: &T, msg: &[u32]) -> Gathered {
    let (rank, world) = (t.rank(), t.world());
    assert!(world.is_power_of_two(), "recursive doubling needs 2^k ranks");
    // own message first, received blocks in arrival order — the exact
    // packing order of the historical schedule, so wire bytes match
    let mut blocks: Vec<(u32, Vec<u32>)> = Vec::with_capacity(world - 1);
    let mut dist = 1;
    while dist < world {
        let peer = rank ^ dist;
        let refs: Vec<(u32, &[u32])> = std::iter::once((rank as u32, msg))
            .chain(blocks.iter().map(|(r, p)| (*r, p.as_slice())))
            .collect();
        let received = t.exchange(peer, pack_blocks(&refs));
        blocks.extend(unpack_blocks(&received));
        dist <<= 1;
    }
    finish_ref(rank, msg, blocks, world)
}

/// Ring allgather: p-1 steps, each forwarding the block received last
/// round.  Works for any world size.  Compat shape.
pub fn allgather_ring<T: Transport>(t: &T, msg: Vec<u32>) -> Vec<Vec<u32>> {
    allgather_ring_ref(t, &msg).into_parts()
}

fn allgather_ring_ref<T: Transport>(t: &T, msg: &[u32]) -> Gathered {
    let (rank, world) = (t.rank(), t.world());
    let next = (rank + 1) % world;
    let prev = (rank + world - 1) % world;
    let mut blocks: Vec<(u32, Vec<u32>)> = Vec::with_capacity(world - 1);
    let mut forward = pack_blocks(&[(rank as u32, msg)]);
    for _ in 0..world.saturating_sub(1) {
        t.send(next, forward);
        let received = t.recv(prev);
        let got = unpack_blocks(&received);
        forward = pack_blocks(&got);
        blocks.extend(got);
    }
    finish_ref(rank, msg, blocks, world)
}

/// Assemble own + received blocks into the single-buffer result,
/// asserting exactly one block per rank.
fn finish_ref(rank: usize, own: &[u32], blocks: Vec<(u32, Vec<u32>)>, world: usize) -> Gathered {
    let total = own.len() + blocks.iter().map(|(_, p)| p.len()).sum::<usize>();
    let mut buf = Vec::with_capacity(total);
    let mut spans: Vec<Option<(usize, usize)>> = vec![None; world];
    spans[rank] = Some((0, own.len()));
    buf.extend_from_slice(own);
    for (r, p) in &blocks {
        let slot = &mut spans[*r as usize];
        assert!(slot.is_none(), "duplicate block for rank {r}");
        *slot = Some((buf.len(), p.len()));
        buf.extend_from_slice(p);
    }
    let spans = spans
        .into_iter()
        .enumerate()
        .map(|(r, s)| s.unwrap_or_else(|| panic!("missing block for rank {r}")))
        .collect();
    Gathered { buf, spans }
}

/// Flatten an allgather result into one buffer (rank order) — the §5.4
/// decompression input.
pub fn concat(parts: Vec<Vec<u32>>) -> Vec<u32> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for p in parts {
        out.extend(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::LocalFabric;
    use std::thread;

    fn run_world(
        world: usize,
        f: impl Fn(crate::collectives::transport::LocalTransport) -> Vec<Vec<u32>>
            + Send
            + Sync
            + 'static,
    ) -> Vec<Vec<Vec<u32>>> {
        let mut fabric = LocalFabric::new(world);
        let f = std::sync::Arc::new(f);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                let f = std::sync::Arc::clone(&f);
                thread::spawn(move || f(t))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn rank_msg(rank: usize, len: usize) -> Vec<u32> {
        (0..len).map(|i| (rank * 1000 + i) as u32).collect()
    }

    #[test]
    fn recursive_doubling_pow2_worlds() {
        for world in [1usize, 2, 4, 8] {
            let results = run_world(world, move |t| {
                let msg = rank_msg(t.rank(), 3);
                allgather_recursive_doubling(&t, msg)
            });
            for got in &results {
                assert_eq!(got.len(), world);
                for (r, part) in got.iter().enumerate() {
                    assert_eq!(part, &rank_msg(r, 3), "world={world}");
                }
            }
        }
    }

    #[test]
    fn ring_any_world() {
        for world in [1usize, 2, 3, 5, 6, 8] {
            let results = run_world(world, move |t| {
                let msg = rank_msg(t.rank(), 2);
                allgather_ring(&t, msg)
            });
            for got in &results {
                for (r, part) in got.iter().enumerate() {
                    assert_eq!(part, &rank_msg(r, 2), "world={world}");
                }
            }
        }
    }

    #[test]
    fn variable_length_contributions() {
        let results = run_world(4, |t| {
            // rank r contributes r+1 words
            let msg = rank_msg(t.rank(), t.rank() + 1);
            allgather(&t, msg)
        });
        for got in &results {
            for (r, part) in got.iter().enumerate() {
                assert_eq!(part.len(), r + 1);
                assert_eq!(part, &rank_msg(r, r + 1));
            }
        }
    }

    #[test]
    fn gathered_form_matches_compat_form() {
        // the zero-copy result addresses the same bytes the Vec-of-Vec
        // shape copies out
        let results = run_world(4, |t| {
            let msg = rank_msg(t.rank(), t.rank() + 1);
            let g = allgather_ref(&t, &msg);
            assert_eq!(g.n_ranks(), 4);
            assert_eq!(g.payload_words(), 1 + 2 + 3 + 4);
            for (r, b) in g.blocks().enumerate() {
                assert_eq!(b, g.block(r));
            }
            g.into_parts()
        });
        for got in &results {
            for (r, part) in got.iter().enumerate() {
                assert_eq!(part, &rank_msg(r, r + 1));
            }
        }
    }

    #[test]
    fn empty_contributions_ok() {
        let results = run_world(4, |t| {
            let msg = if t.rank() % 2 == 0 { vec![] } else { vec![t.rank() as u32] };
            allgather(&t, msg)
        });
        for got in &results {
            assert!(got[0].is_empty() && got[2].is_empty());
            assert_eq!(got[1], vec![1]);
            assert_eq!(got[3], vec![3]);
        }
    }

    #[test]
    fn dispatch_picks_rd_for_pow2() {
        // indirect: non-pow2 world must still work through dispatch
        let results = run_world(3, |t| allgather(&t, vec![t.rank() as u32]));
        for got in &results {
            assert_eq!(got.len(), 3);
        }
    }

    #[test]
    fn concat_flattens_in_rank_order() {
        let parts = vec![vec![1, 2], vec![], vec![3]];
        assert_eq!(concat(parts), vec![1, 2, 3]);
    }

    #[test]
    fn block_pack_roundtrip() {
        let blocks = vec![(0u32, vec![1, 2]), (3u32, vec![]), (2u32, vec![9, 9, 9])];
        assert_eq!(unpack_blocks(&pack_blocks(&blocks)), blocks);
    }

    #[test]
    fn gathered_from_parts_roundtrip() {
        let parts = vec![vec![1, 2], vec![], vec![3]];
        let g = Gathered::from_parts(&parts);
        assert_eq!(g.block(0), &[1, 2]);
        assert!(g.block(1).is_empty());
        assert_eq!(g.into_parts(), parts);
        let s = Gathered::single(vec![5, 6]);
        assert_eq!(s.n_ranks(), 1);
        assert_eq!(s.block(0), &[5, 6]);
    }
}
