//! Allgather — the sparse-synchronization primitive (§5.3, Appendix B).
//!
//! Recursive doubling for power-of-two worlds (lg p steps, (p-1)·m bytes
//! per rank — the schedule Eq. 1 charges), ring allgather as the general
//! fallback.  Both support *variable-length* contributions: with threshold
//! binary search each rank's compressed residual differs in length, so
//! blocks travel with `[rank, len]` headers and are reassembled in rank
//! order at the end.

use super::transport::Transport;

/// Gather each rank's `msg`; returns all contributions indexed by rank.
/// Dispatches to recursive doubling when `world` is a power of two.
///
/// `t` is any [`Transport`], including a
/// [`ProcessGroup`](super::group::ProcessGroup): over a group the
/// collective runs among the members only and the result is indexed by
/// *group-local* rank — how the hierarchical schedule runs its
/// inter-node leader allgather.
pub fn allgather<T: Transport>(t: &T, msg: Vec<u32>) -> Vec<Vec<u32>> {
    if t.world().is_power_of_two() {
        allgather_recursive_doubling(t, msg)
    } else {
        allgather_ring(t, msg)
    }
}

/// Serialize a set of (rank, payload) blocks:
/// `[count][rank_0, len_0]...[rank_{c-1}, len_{c-1}][payload_0 ...]`.
/// Shared with the hierarchical schedule, which uses the same framing
/// for node blobs and the leader broadcast.
pub(crate) fn pack_blocks(blocks: &[(u32, Vec<u32>)]) -> Vec<u32> {
    let payload: usize = blocks.iter().map(|(_, p)| p.len()).sum();
    let mut out = Vec::with_capacity(1 + 2 * blocks.len() + payload);
    out.push(blocks.len() as u32);
    for (r, p) in blocks {
        out.push(*r);
        out.push(p.len() as u32);
    }
    for (_, p) in blocks {
        out.extend_from_slice(p);
    }
    out
}

pub(crate) fn unpack_blocks(buf: &[u32]) -> Vec<(u32, Vec<u32>)> {
    let count = buf[0] as usize;
    let mut headers = Vec::with_capacity(count);
    for i in 0..count {
        headers.push((buf[1 + 2 * i], buf[2 + 2 * i] as usize));
    }
    let mut off = 1 + 2 * count;
    let mut out = Vec::with_capacity(count);
    for (rank, len) in headers {
        out.push((rank, buf[off..off + len].to_vec()));
        off += len;
    }
    out
}

/// Recursive doubling: at step s, exchange all accumulated blocks with the
/// partner at distance 2^s.  Exactly lg(p) rounds.
pub fn allgather_recursive_doubling<T: Transport>(t: &T, msg: Vec<u32>) -> Vec<Vec<u32>> {
    let (rank, world) = (t.rank(), t.world());
    assert!(world.is_power_of_two(), "recursive doubling needs 2^k ranks");
    let mut blocks: Vec<(u32, Vec<u32>)> = vec![(rank as u32, msg)];
    let mut dist = 1;
    while dist < world {
        let peer = rank ^ dist;
        let received = t.exchange(peer, pack_blocks(&blocks));
        blocks.extend(unpack_blocks(&received));
        dist <<= 1;
    }
    finish(blocks, world)
}

/// Ring allgather: p-1 steps, each forwarding the block received last
/// round.  Works for any world size.
pub fn allgather_ring<T: Transport>(t: &T, msg: Vec<u32>) -> Vec<Vec<u32>> {
    let (rank, world) = (t.rank(), t.world());
    let next = (rank + 1) % world;
    let prev = (rank + world - 1) % world;
    let mut blocks: Vec<(u32, Vec<u32>)> = vec![(rank as u32, msg)];
    let mut forward = pack_blocks(&blocks);
    for _ in 0..world.saturating_sub(1) {
        t.send(next, forward);
        let received = t.recv(prev);
        let got = unpack_blocks(&received);
        blocks.extend(got.clone());
        forward = pack_blocks(&got);
    }
    finish(blocks, world)
}

pub(crate) fn finish(blocks: Vec<(u32, Vec<u32>)>, world: usize) -> Vec<Vec<u32>> {
    let mut out: Vec<Option<Vec<u32>>> = vec![None; world];
    for (r, p) in blocks {
        let slot = &mut out[r as usize];
        assert!(slot.is_none(), "duplicate block for rank {r}");
        *slot = Some(p);
    }
    out.into_iter()
        .enumerate()
        .map(|(r, p)| p.unwrap_or_else(|| panic!("missing block for rank {r}")))
        .collect()
}

/// Flatten an allgather result into one buffer (rank order) — the §5.4
/// decompression input.
pub fn concat(parts: Vec<Vec<u32>>) -> Vec<u32> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for p in parts {
        out.extend(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::LocalFabric;
    use std::thread;

    fn run_world(
        world: usize,
        f: impl Fn(crate::collectives::transport::LocalTransport) -> Vec<Vec<u32>>
            + Send
            + Sync
            + 'static,
    ) -> Vec<Vec<Vec<u32>>> {
        let mut fabric = LocalFabric::new(world);
        let f = std::sync::Arc::new(f);
        let handles: Vec<_> = fabric
            .take_all()
            .into_iter()
            .map(|t| {
                let f = std::sync::Arc::clone(&f);
                thread::spawn(move || f(t))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn rank_msg(rank: usize, len: usize) -> Vec<u32> {
        (0..len).map(|i| (rank * 1000 + i) as u32).collect()
    }

    #[test]
    fn recursive_doubling_pow2_worlds() {
        for world in [1usize, 2, 4, 8] {
            let results = run_world(world, move |t| {
                let msg = rank_msg(t.rank(), 3);
                allgather_recursive_doubling(&t, msg)
            });
            for got in &results {
                assert_eq!(got.len(), world);
                for (r, part) in got.iter().enumerate() {
                    assert_eq!(part, &rank_msg(r, 3), "world={world}");
                }
            }
        }
    }

    #[test]
    fn ring_any_world() {
        for world in [1usize, 2, 3, 5, 6, 8] {
            let results = run_world(world, move |t| {
                let msg = rank_msg(t.rank(), 2);
                allgather_ring(&t, msg)
            });
            for got in &results {
                for (r, part) in got.iter().enumerate() {
                    assert_eq!(part, &rank_msg(r, 2), "world={world}");
                }
            }
        }
    }

    #[test]
    fn variable_length_contributions() {
        let results = run_world(4, |t| {
            // rank r contributes r+1 words
            let msg = rank_msg(t.rank(), t.rank() + 1);
            allgather(&t, msg)
        });
        for got in &results {
            for (r, part) in got.iter().enumerate() {
                assert_eq!(part.len(), r + 1);
                assert_eq!(part, &rank_msg(r, r + 1));
            }
        }
    }

    #[test]
    fn empty_contributions_ok() {
        let results = run_world(4, |t| {
            let msg = if t.rank() % 2 == 0 { vec![] } else { vec![t.rank() as u32] };
            allgather(&t, msg)
        });
        for got in &results {
            assert!(got[0].is_empty() && got[2].is_empty());
            assert_eq!(got[1], vec![1]);
            assert_eq!(got[3], vec![3]);
        }
    }

    #[test]
    fn dispatch_picks_rd_for_pow2() {
        // indirect: non-pow2 world must still work through dispatch
        let results = run_world(3, |t| allgather(&t, vec![t.rank() as u32]));
        for got in &results {
            assert_eq!(got.len(), 3);
        }
    }

    #[test]
    fn concat_flattens_in_rank_order() {
        let parts = vec![vec![1, 2], vec![], vec![3]];
        assert_eq!(concat(parts), vec![1, 2, 3]);
    }

    #[test]
    fn block_pack_roundtrip() {
        let blocks = vec![(0u32, vec![1, 2]), (3u32, vec![]), (2u32, vec![9, 9, 9])];
        assert_eq!(unpack_blocks(&pack_blocks(&blocks)), blocks);
    }
}
