//! Point-to-point transport abstraction under the collectives.
//!
//! [`LocalTransport`] is the in-process fabric: one unbounded channel per
//! ordered rank pair, real data movement, real numerics — the substitute
//! for the paper's CUDA-aware MPI (DESIGN.md §Substitutions).  Worker
//! threads each own one endpoint.
//!
//! The message unit is `Vec<u32>` words: gradients travel as bit-cast f32,
//! compressed residuals in their §5.3 wire format.  Byte accounting for
//! the cost model is `4 * words`.
//!
//! Endpoints are `Sync`: the pipelined sync engine (`crate::pipeline`)
//! shares one endpoint between the training thread and a communication
//! thread pool through `crate::collectives::mux::TagMux`, so the per-peer
//! channel ends sit behind mutexes.  The locks are uncontended on the
//! sequential path (one thread per endpoint, the historical contract).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Why a peer link died, as specifically as the fabric can classify it.
/// The elastic membership layer (`crate::elastic`) keys its detection
/// and eviction decisions on this instead of grepping error strings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerLostCause {
    /// Orderly shutdown: a clean FIN between frames, or the in-process
    /// peer endpoint dropped (its worker thread exited).
    CleanFin,
    /// The stream ended in the middle of a frame — the peer vanished
    /// with data in flight (crash / hard kill).
    MidStream,
    /// The OS reported a reset (`ECONNRESET` / `EPIPE` / aborted
    /// connection).
    Reset,
    /// A heartbeat lease expired, or a read deadline fired; for TCP the
    /// monitor severs such links, converting a stall into a hard loss.
    Timeout,
    /// The stream carried garbage: oversized length prefix, malformed
    /// frame, untagged or out-of-range mux tag.
    Corrupt,
    /// Not a loss at all: an out-of-band reshape frame arrived on a
    /// multiplexed channel (the peer entered the elastic reshape
    /// protocol).  The frame is parked for the reshape driver.
    OutOfBand,
    /// The fabric could not classify the failure.
    Unknown,
}

impl PeerLostCause {
    pub fn label(&self) -> &'static str {
        match self {
            PeerLostCause::CleanFin => "clean-fin",
            PeerLostCause::MidStream => "mid-stream-eof",
            PeerLostCause::Reset => "reset",
            PeerLostCause::Timeout => "timeout",
            PeerLostCause::Corrupt => "corrupt",
            PeerLostCause::OutOfBand => "out-of-band",
            PeerLostCause::Unknown => "unknown",
        }
    }
}

/// A fabric link failure: the peer endpoint is gone (dropped thread,
/// closed socket, corrupt stream).  Collectives treat this as fatal via
/// [`Transport::recv`]'s panic; supervisors and fault tests observe it
/// cleanly through [`Transport::recv_checked`], and the elastic layer
/// dispatches on the structured [`PeerLostCause`].
#[derive(Debug)]
pub struct TransportError {
    /// Peer rank the failed operation addressed.
    pub peer: usize,
    /// Human-readable cause (as specific as the fabric can make it).
    pub reason: String,
    /// Structured classification of the failure.
    pub cause: PeerLostCause,
}

impl TransportError {
    pub fn new(peer: usize, reason: impl Into<String>) -> TransportError {
        TransportError { peer, reason: reason.into(), cause: PeerLostCause::Unknown }
    }

    pub fn with_cause(
        peer: usize,
        reason: impl Into<String>,
        cause: PeerLostCause,
    ) -> TransportError {
        TransportError { peer, reason: reason.into(), cause }
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.cause == PeerLostCause::Unknown {
            write!(f, "link to rank {}: {}", self.peer, self.reason)
        } else {
            write!(f, "link to rank {}: {} [{}]", self.peer, self.reason, self.cause.label())
        }
    }
}

impl std::error::Error for TransportError {}

/// Physical link class a peer connection rides on.  The socket fabrics
/// (`net`) classify every peer link so reports can show where the bytes
/// actually went, and the cost model prices intra-host unix-socket hops
/// differently from loopback TCP (`simnet::IntraLink` is the pricing
/// counterpart of this wire-level vocabulary).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LinkClass {
    /// In-memory channel: the self-link of a socket fabric (and all of
    /// `LocalFabric`).  No wire, no syscalls.
    Mem,
    /// Unix-domain socket between processes on one host.
    Unix,
    /// TCP socket — loopback or cross-node.
    Tcp,
}

impl LinkClass {
    pub fn label(&self) -> &'static str {
        match self {
            LinkClass::Mem => "mem",
            LinkClass::Unix => "unix",
            LinkClass::Tcp => "tcp",
        }
    }
}

/// Traffic summary for one link class of a fabric: what crossed links of
/// that class and in how many write syscalls — the visible record of the
/// writer threads' frame coalescing (`frames / writes` is the mean
/// syscall batch size).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkTraffic {
    pub class: LinkClass,
    /// Frames sent over links of this class (one per `send`).
    pub frames: u64,
    /// Payload bytes sent (`4 * words`, the `TrafficStats` convention;
    /// framing adds 4 bytes per frame on the wire).
    pub bytes: u64,
    /// Write syscalls the writer threads issued (0 for [`LinkClass::Mem`]
    /// — in-memory links never enter the kernel).
    pub writes: u64,
}

impl LinkTraffic {
    /// Mean frames coalesced per write syscall (0.0 when nothing was
    /// written through the kernel).
    pub fn frames_per_write(&self) -> f64 {
        if self.writes == 0 {
            0.0
        } else {
            self.frames as f64 / self.writes as f64
        }
    }
}

/// One queued fabric message: owned, or shared for broadcast fan-out
/// (the hierarchical intra-node broadcast ships one buffer to s-1 peers
/// without cloning it per peer).
pub(crate) enum Payload {
    Owned(Vec<u32>),
    Shared(Arc<Vec<u32>>),
}

impl Payload {
    pub(crate) fn as_slice(&self) -> &[u32] {
        match self {
            Payload::Owned(v) => v.as_slice(),
            Payload::Shared(a) => a.as_slice(),
        }
    }

    /// Take ownership: free for owned payloads and the last holder of a
    /// shared one; one receiver-side copy otherwise (cost the sender no
    /// longer pays serially).
    pub(crate) fn into_vec(self) -> Vec<u32> {
        match self {
            Payload::Owned(v) => v,
            Payload::Shared(a) => Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone()),
        }
    }
}

/// Point-to-point message transport between ranks.
pub trait Transport {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;
    /// Send `msg` to rank `to`.  Non-blocking (buffered fabric).
    fn send(&self, to: usize, msg: Vec<u32>);
    /// Blocking receive of the next message from rank `from`, surfacing a
    /// broken link as a clean error instead of a panic or a hang.
    fn recv_checked(&self, from: usize) -> Result<Vec<u32>, TransportError>;

    /// Non-blocking receive: `Ok(Some(msg))` if a message from `from` is
    /// already queued, `Ok(None)` if the link is healthy but idle,
    /// `Err` if it broke.  Polling fabrics (heartbeat monitors, the
    /// reshape protocol) require this; the default `Ok(None)` suits
    /// fabrics that are never polled.
    fn try_recv(&self, _from: usize) -> Result<Option<Vec<u32>>, TransportError> {
        Ok(None)
    }

    /// Fallible send: a closed link is an error instead of the panic
    /// [`send`](Transport::send) raises — for supervisors (heartbeats,
    /// reshape frames) that must outlive dead peers.
    fn send_checked(&self, to: usize, msg: Vec<u32>) -> Result<(), TransportError> {
        self.send(to, msg);
        Ok(())
    }

    /// Force-close the link to `peer`, if the fabric can: subsequent
    /// receives on it fail instead of blocking.  The elastic monitor
    /// severs a stalled peer's TCP link after its lease expires,
    /// converting a silent stall into a detectable loss.  Default no-op
    /// (the in-process fabric cannot interrupt a blocked channel; its
    /// failures are always immediate).
    fn sever(&self, _peer: usize) {}

    /// Broadcast-friendly send: ship a shared buffer without a per-peer
    /// clone at the sender.  Defaults to clone + [`send`](Transport::send);
    /// the real fabrics forward the `Arc` to their queues so the leader's
    /// intra-node broadcast enqueues s-1 sends of one buffer.  Byte
    /// accounting and receiver-observable behavior are identical to
    /// `send`.
    fn send_shared(&self, to: usize, msg: &Arc<Vec<u32>>) {
        self.send(to, msg.as_ref().clone());
    }

    /// Blocking receive of the next message from rank `from`.  Panics if
    /// the link broke — a dead peer mid-collective is unrecoverable.
    fn recv(&self, from: usize) -> Vec<u32> {
        match self.recv_checked(from) {
            Ok(msg) => msg,
            Err(e) => panic!("rank {}: {e}", self.rank()),
        }
    }

    /// Symmetric exchange (both sides call with each other's rank).
    fn exchange(&self, peer: usize, msg: Vec<u32>) -> Vec<u32> {
        self.send(peer, msg);
        self.recv(peer)
    }

    /// Per-link-class traffic snapshot (frames / bytes / write syscalls
    /// per [`LinkClass`]).  The socket fabrics report what each class
    /// carried; the default empty vec suits in-process fabrics whose
    /// links never touch the kernel.
    fn link_traffic(&self) -> Vec<LinkTraffic> {
        Vec::new()
    }
}

/// References forward to the underlying transport, so generic code can
/// take either an owned endpoint or a borrow.
impl<T: Transport + ?Sized> Transport for &T {
    fn rank(&self) -> usize {
        (**self).rank()
    }

    fn world(&self) -> usize {
        (**self).world()
    }

    fn send(&self, to: usize, msg: Vec<u32>) {
        (**self).send(to, msg)
    }

    fn send_shared(&self, to: usize, msg: &Arc<Vec<u32>>) {
        (**self).send_shared(to, msg)
    }

    fn recv_checked(&self, from: usize) -> Result<Vec<u32>, TransportError> {
        (**self).recv_checked(from)
    }

    fn try_recv(&self, from: usize) -> Result<Option<Vec<u32>>, TransportError> {
        (**self).try_recv(from)
    }

    fn send_checked(&self, to: usize, msg: Vec<u32>) -> Result<(), TransportError> {
        (**self).send_checked(to, msg)
    }

    fn sever(&self, peer: usize) {
        (**self).sever(peer)
    }

    fn recv(&self, from: usize) -> Vec<u32> {
        (**self).recv(from)
    }

    fn exchange(&self, peer: usize, msg: Vec<u32>) -> Vec<u32> {
        (**self).exchange(peer, msg)
    }

    fn link_traffic(&self) -> Vec<LinkTraffic> {
        (**self).link_traffic()
    }
}

/// Traffic counters shared by all endpoints of a fabric (for tests and
/// the bandwidth bench).
#[derive(Default, Debug)]
pub struct TrafficStats {
    pub messages: AtomicU64,
    pub words: AtomicU64,
}

impl TrafficStats {
    pub fn bytes(&self) -> u64 {
        self.words.load(Ordering::Relaxed) * 4
    }

    pub fn message_count(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.messages.store(0, Ordering::Relaxed);
        self.words.store(0, Ordering::Relaxed);
    }
}

/// In-process fabric: build once, split into per-rank endpoints.
pub struct LocalFabric {
    endpoints: Vec<Option<LocalTransport>>,
    pub stats: Arc<TrafficStats>,
}

impl LocalFabric {
    pub fn new(world: usize) -> Self {
        assert!(world >= 1);
        let stats = Arc::new(TrafficStats::default());
        // txs[from][to], rxs[to][from]
        let mut txs: Vec<Vec<Option<Sender<Payload>>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Payload>>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        for from in 0..world {
            for to in 0..world {
                let (tx, rx) = channel();
                txs[from][to] = Some(tx);
                rxs[to][from] = Some(rx);
            }
        }
        let mut endpoints = Vec::with_capacity(world);
        for (rank, rx_row) in rxs.into_iter().enumerate() {
            let senders: Vec<Mutex<Sender<Payload>>> = (0..world)
                .map(|to| Mutex::new(txs[rank][to].take().expect("sender taken twice")))
                .collect();
            let receivers: Vec<Mutex<Receiver<Payload>>> = rx_row
                .into_iter()
                .map(|r| Mutex::new(r.expect("receiver missing")))
                .collect();
            endpoints.push(Some(LocalTransport {
                rank,
                world,
                senders,
                receivers,
                stats: Arc::clone(&stats),
            }));
        }
        LocalFabric { endpoints, stats }
    }

    /// Take the endpoint for `rank` (each may be taken once, then moved
    /// into its worker thread).
    pub fn take(&mut self, rank: usize) -> LocalTransport {
        self.endpoints[rank].take().expect("endpoint already taken")
    }

    /// Take all endpoints in rank order.
    pub fn take_all(&mut self) -> Vec<LocalTransport> {
        (0..self.endpoints.len()).map(|r| self.take(r)).collect()
    }
}

/// One rank's view of the [`LocalFabric`].
pub struct LocalTransport {
    rank: usize,
    world: usize,
    senders: Vec<Mutex<Sender<Payload>>>,
    receivers: Vec<Mutex<Receiver<Payload>>>,
    stats: Arc<TrafficStats>,
}

/// Lock that tolerates poisoning: a peer-death panic in one thread's
/// `send` must not take the supervisor's `send_checked` down with it —
/// the channel ends themselves stay consistent (mpsc operations never
/// leave partial state under panic).
pub(crate) fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poison| poison.into_inner())
}

impl Transport for LocalTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, to: usize, msg: Vec<u32>) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.words.fetch_add(msg.len() as u64, Ordering::Relaxed);
        self.senders[to]
            .lock()
            .unwrap()
            .send(Payload::Owned(msg))
            .expect("peer endpoint dropped");
    }

    fn send_shared(&self, to: usize, msg: &Arc<Vec<u32>>) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.words.fetch_add(msg.len() as u64, Ordering::Relaxed);
        self.senders[to]
            .lock()
            .unwrap()
            .send(Payload::Shared(Arc::clone(msg)))
            .expect("peer endpoint dropped");
    }

    fn recv_checked(&self, from: usize) -> Result<Vec<u32>, TransportError> {
        lock_ok(&self.receivers[from]).recv().map(Payload::into_vec).map_err(|_| {
            TransportError::with_cause(from, "peer endpoint dropped", PeerLostCause::CleanFin)
        })
    }

    fn try_recv(&self, from: usize) -> Result<Option<Vec<u32>>, TransportError> {
        use std::sync::mpsc::TryRecvError;
        match lock_ok(&self.receivers[from]).try_recv() {
            Ok(p) => Ok(Some(p.into_vec())),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(TransportError::with_cause(
                from,
                "peer endpoint dropped",
                PeerLostCause::CleanFin,
            )),
        }
    }

    fn send_checked(&self, to: usize, msg: Vec<u32>) -> Result<(), TransportError> {
        let words = msg.len() as u64;
        match lock_ok(&self.senders[to]).send(Payload::Owned(msg)) {
            Ok(()) => {
                self.stats.messages.fetch_add(1, Ordering::Relaxed);
                self.stats.words.fetch_add(words, Ordering::Relaxed);
                Ok(())
            }
            // a failed send moved no bytes, so it is never counted
            Err(_) => Err(TransportError::with_cause(
                to,
                "peer endpoint dropped",
                PeerLostCause::CleanFin,
            )),
        }
    }
}

/// Bit-cast helpers between the f32 world and the u32 wire.
pub fn f32s_to_words(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

pub fn words_to_f32s(ws: &[u32]) -> Vec<f32> {
    ws.iter().map(|&w| f32::from_bits(w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_pair() {
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        let b = fabric.take(1);
        let h = thread::spawn(move || {
            b.send(0, vec![1, 2, 3]);
            b.recv(0)
        });
        assert_eq!(a.recv(1), vec![1, 2, 3]);
        a.send(1, vec![9]);
        assert_eq!(h.join().unwrap(), vec![9]);
    }

    #[test]
    fn exchange_is_symmetric() {
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        let b = fabric.take(1);
        let h = thread::spawn(move || b.exchange(0, vec![20]));
        let got_a = a.exchange(1, vec![10]);
        assert_eq!(got_a, vec![20]);
        assert_eq!(h.join().unwrap(), vec![10]);
    }

    #[test]
    fn self_send_works() {
        let mut fabric = LocalFabric::new(1);
        let a = fabric.take(0);
        a.send(0, vec![7]);
        assert_eq!(a.recv(0), vec![7]);
    }

    #[test]
    fn exchange_with_self_returns_own_message() {
        // collectives never self-exchange, but the Transport contract
        // (buffered send) makes it well-defined: you get your bits back
        let mut fabric = LocalFabric::new(3);
        let t = fabric.take(1);
        assert_eq!(t.exchange(1, vec![42, 7]), vec![42, 7]);
    }

    #[test]
    fn multi_megabyte_message_intact() {
        // 2M words = 8 MB: the seed's wire unit never exceeded a few KB,
        // so guard the fabric against large-payload truncation
        let n = 2 * 1024 * 1024usize;
        let msg: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
        let expect = msg.clone();
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        let b = fabric.take(1);
        let h = thread::spawn(move || b.recv(0));
        a.send(1, msg);
        assert_eq!(h.join().unwrap(), expect);
    }

    #[test]
    fn borrowed_transport_is_a_transport() {
        // generic code takes &T via the blanket impl
        fn world_of<T: Transport>(t: T) -> usize {
            t.world()
        }
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        assert_eq!(world_of(&a), 2);
        assert_eq!(world_of(&&a), 2);
    }

    #[test]
    fn endpoints_are_sync_and_send() {
        // the pipelined engine shares one endpoint across its comm pool
        fn assert_share<T: Send + Sync>() {}
        assert_share::<LocalTransport>();
        assert_share::<TransportError>();
    }

    #[test]
    fn recv_checked_surfaces_dropped_peer() {
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        let b = fabric.take(1);
        drop(b);
        let err = a.recv_checked(1).unwrap_err();
        assert_eq!(err.peer, 1);
        assert!(err.reason.contains("dropped"), "{err}");
        assert_eq!(err.cause, PeerLostCause::CleanFin);
    }

    #[test]
    fn try_recv_polls_without_blocking() {
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        let b = fabric.take(1);
        assert!(a.try_recv(1).unwrap().is_none(), "idle link");
        b.send(0, vec![5]);
        assert_eq!(a.try_recv(1).unwrap(), Some(vec![5]));
        assert!(a.try_recv(1).unwrap().is_none(), "drained");
        drop(b);
        let err = a.try_recv(1).unwrap_err();
        assert_eq!(err.cause, PeerLostCause::CleanFin);
    }

    #[test]
    fn send_checked_errors_instead_of_panicking() {
        let mut fabric = LocalFabric::new(2);
        let stats = Arc::clone(&fabric.stats);
        let a = fabric.take(0);
        let b = fabric.take(1);
        a.send_checked(1, vec![1, 2]).unwrap();
        assert_eq!(b.recv(0), vec![1, 2]);
        assert_eq!(stats.bytes(), 8, "successful send_checked counts like send");
        drop(b);
        let err = a.send_checked(1, vec![3]).unwrap_err();
        assert_eq!(err.peer, 1);
        assert_eq!(err.cause, PeerLostCause::CleanFin);
        assert_eq!(stats.bytes(), 8, "failed send moves no bytes");
    }

    #[test]
    fn sever_is_a_noop_on_the_local_fabric() {
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        let b = fabric.take(1);
        a.sever(1);
        b.send(0, vec![9]);
        assert_eq!(a.recv(1), vec![9], "local links cannot be severed");
    }

    #[test]
    fn messages_ordered_per_pair() {
        let mut fabric = LocalFabric::new(2);
        let a = fabric.take(0);
        let b = fabric.take(1);
        for i in 0..100u32 {
            a.send(1, vec![i]);
        }
        for i in 0..100u32 {
            assert_eq!(b.recv(0), vec![i]);
        }
    }

    #[test]
    fn send_shared_delivers_and_counts_like_send() {
        let mut fabric = LocalFabric::new(3);
        let stats = Arc::clone(&fabric.stats);
        let a = fabric.take(0);
        let b = fabric.take(1);
        let c = fabric.take(2);
        let blob = Arc::new(vec![7u32, 8, 9]);
        a.send_shared(1, &blob);
        a.send_shared(2, &blob);
        assert_eq!(b.recv(0), vec![7, 8, 9]);
        assert_eq!(c.recv(0), vec![7, 8, 9]);
        // identical accounting to two owned sends
        assert_eq!(stats.message_count(), 2);
        assert_eq!(stats.bytes(), 2 * 3 * 4);
        // the sender still holds its copy untouched
        assert_eq!(*blob, vec![7, 8, 9]);
    }

    #[test]
    fn stats_count_traffic() {
        let mut fabric = LocalFabric::new(2);
        let stats = Arc::clone(&fabric.stats);
        let a = fabric.take(0);
        let b = fabric.take(1);
        a.send(1, vec![0; 10]);
        b.recv(0);
        assert_eq!(stats.message_count(), 1);
        assert_eq!(stats.bytes(), 40);
        stats.reset();
        assert_eq!(stats.bytes(), 0);
    }

    #[test]
    #[should_panic(expected = "endpoint already taken")]
    fn endpoint_single_ownership() {
        let mut fabric = LocalFabric::new(2);
        let _a = fabric.take(0);
        let _again = fabric.take(0);
    }

    #[test]
    fn word_casts_roundtrip() {
        let xs = vec![1.5f32, -0.0, f32::MIN_POSITIVE];
        let back = words_to_f32s(&f32s_to_words(&xs));
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
