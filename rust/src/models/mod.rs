//! Model descriptions: paper DNN layer profiles for simulation ([`zoo`])
//! and manifest-driven schemas for the real AOT-compiled models
//! ([`schema`]).

pub mod schema;
pub mod zoo;

pub use schema::{InitSpec, ModelSchema, ParamSpec};
pub use zoo::{ModelProfile, LayerSpec};
