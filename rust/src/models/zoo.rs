//! Paper DNN layer profiles — the workloads of Figs. 7-10 and Table 1.
//!
//! Each profile lists per-layer parameter counts (elements) and the
//! forward GFlops per sample from Table 1.  The scalability simulations
//! are functions of exactly this data (per-layer bytes × network model ×
//! compression policy), so published architecture shapes + Table 1 model
//! sizes are sufficient to reproduce the figures' shapes — see DESIGN.md
//! §Substitutions.

/// One weight tensor (fused with its bias for profile purposes).
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub name: String,
    /// Parameter elements (f32).
    pub elems: usize,
    /// True for the model's output/classifier layer — never quantized
    /// (§5.2.3).
    pub is_output: bool,
}

/// A model profile for simulation.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: String,
    pub layers: Vec<LayerSpec>,
    /// Forward GFlops for a single sample (Table 1 "Compt. Amount").
    pub fwd_gflops_per_sample: f64,
    /// RNNs synchronize only after full BPTT (§5.6 scheme B).
    pub is_rnn: bool,
}

impl ModelProfile {
    pub fn total_elems(&self) -> usize {
        self.layers.iter().map(|l| l.elems).sum()
    }

    pub fn model_bytes(&self) -> usize {
        self.total_elems() * 4
    }

    fn layer(name: &str, elems: usize) -> LayerSpec {
        LayerSpec { name: name.to_string(), elems, is_output: false }
    }

    fn output(name: &str, elems: usize) -> LayerSpec {
        LayerSpec { name: name.to_string(), elems, is_output: true }
    }
}

/// AlexNet on ImageNet: 61M params (233 MB), fwd 0.72 GFlop.  fc6/fc7
/// dominate the byte mix — the communication-bound case of Fig. 7/8.
pub fn alexnet() -> ModelProfile {
    let l = ModelProfile::layer;
    ModelProfile {
        name: "alexnet".into(),
        layers: vec![
            l("conv1", 34_944),
            l("conv2", 307_456),
            l("conv3", 885_120),
            l("conv4", 663_936),
            l("conv5", 442_624),
            l("fc6", 37_752_832),
            l("fc7", 16_781_312),
            ModelProfile::output("fc8", 4_097_000),
        ],
        fwd_gflops_per_sample: 0.72,
        is_rnn: false,
    }
}

/// VGG16 on ImageNet: 138M params (528 MB), fwd 15.5 GFlop.
pub fn vgg16() -> ModelProfile {
    let l = ModelProfile::layer;
    let conv_sizes = [
        1_792usize, 36_928, 73_856, 147_584, 295_168, 590_080, 590_080, 1_180_160,
        2_359_808, 2_359_808, 2_359_808, 2_359_808, 2_359_808,
    ];
    let mut layers: Vec<LayerSpec> = conv_sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| l(&format!("conv{}", i + 1), n))
        .collect();
    layers.push(l("fc6", 102_764_544));
    layers.push(l("fc7", 16_781_312));
    layers.push(ModelProfile::output("fc8", 4_097_000));
    ModelProfile {
        name: "vgg16".into(),
        layers,
        fwd_gflops_per_sample: 15.5,
        is_rnn: false,
    }
}

/// VGG16 adapted to Cifar10: 14.7M params (58.9 MB), fwd 0.31 GFlop.
pub fn vgg16_cifar() -> ModelProfile {
    let l = ModelProfile::layer;
    let conv_sizes = [
        1_792usize, 36_928, 73_856, 147_584, 295_168, 590_080, 590_080, 1_180_160,
        2_359_808, 2_359_808, 2_359_808, 2_359_808, 2_359_808,
    ];
    let mut layers: Vec<LayerSpec> = conv_sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| l(&format!("conv{}", i + 1), n))
        .collect();
    layers.push(l("fc1", 262_656));
    layers.push(ModelProfile::output("fc2", 5_130));
    ModelProfile {
        name: "vgg16-cifar".into(),
        layers,
        fwd_gflops_per_sample: 0.31,
        is_rnn: false,
    }
}

/// ResNet-50 on ImageNet: 25.6M params (103 MB), fwd 8.22 GFlop.  Many
/// small layers + high compute/communication ratio: the case where
/// RedSync shows *no* gain (Fig. 7/8, Fig. 10 unpack-dominance).
pub fn resnet50() -> ModelProfile {
    let mut layers =
        vec![ModelProfile::layer("conv1", 9_408), ModelProfile::layer("bn1", 128)];
    // (mid, out, blocks); in = previous out
    let stages: [(usize, usize, usize); 4] =
        [(64, 256, 3), (128, 512, 4), (256, 1024, 6), (512, 2048, 3)];
    let mut input = 64usize;
    for (s, &(mid, out, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let pre = format!("s{}b{}", s + 1, b);
            layers.push(ModelProfile::layer(&format!("{pre}.conv1"), input * mid));
            layers.push(ModelProfile::layer(&format!("{pre}.bn1"), 2 * mid));
            layers.push(ModelProfile::layer(&format!("{pre}.conv2"), mid * mid * 9));
            layers.push(ModelProfile::layer(&format!("{pre}.bn2"), 2 * mid));
            layers.push(ModelProfile::layer(&format!("{pre}.conv3"), mid * out));
            layers.push(ModelProfile::layer(&format!("{pre}.bn3"), 2 * out));
            if b == 0 {
                layers.push(ModelProfile::layer(&format!("{pre}.down"), input * out));
                layers.push(ModelProfile::layer(&format!("{pre}.bn_down"), 2 * out));
            }
            input = out;
        }
    }
    layers.push(ModelProfile::output("fc", 2_048 * 1_000 + 1_000));
    ModelProfile {
        name: "resnet50".into(),
        layers,
        fwd_gflops_per_sample: 8.22,
        is_rnn: false,
    }
}

/// ResNet-44 on Cifar10: 0.66M params (2.65 MB), fwd 0.20 GFlop.
pub fn resnet44() -> ModelProfile {
    let mut layers = vec![ModelProfile::layer("conv1", 16 * 9 * 3)];
    let stages: [(usize, usize); 3] = [(16, 7), (32, 7), (64, 7)];
    let mut input = 16usize;
    for (s, &(ch, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let pre = format!("s{}b{}", s + 1, b);
            layers.push(ModelProfile::layer(&format!("{pre}.conv1"), input * ch * 9));
            layers.push(ModelProfile::layer(&format!("{pre}.conv2"), ch * ch * 9));
            input = ch;
        }
    }
    layers.push(ModelProfile::output("fc", 64 * 10 + 10));
    ModelProfile {
        name: "resnet44".into(),
        layers,
        fwd_gflops_per_sample: 0.20,
        is_rnn: false,
    }
}

/// 2-layer LSTM LM, 1500 hidden, PTB vocab (10k): 66M params (264 MB),
/// fwd 2.52 GFlop.  Giant embedding/softmax layers + BPTT scheme: the
/// RNN case of Fig. 7/9.
pub fn lstm_ptb() -> ModelProfile {
    lstm_lm("lstm-ptb", 10_000, 1_500)
}

/// Same LSTM on WikiText-2 (33k vocab): 136M params (543 MB).
pub fn lstm_wiki2() -> ModelProfile {
    lstm_lm("lstm-wiki2", 33_278, 1_500)
}

fn lstm_lm(name: &str, vocab: usize, hidden: usize) -> ModelProfile {
    ModelProfile {
        name: name.into(),
        layers: vec![
            ModelProfile::layer("embed", vocab * hidden),
            ModelProfile::layer("lstm1", 4 * hidden * (2 * hidden)),
            ModelProfile::layer("lstm2", 4 * hidden * (2 * hidden)),
            ModelProfile::output("softmax", hidden * vocab + vocab),
        ],
        fwd_gflops_per_sample: 2.52,
        is_rnn: true,
    }
}

/// Every profile used in the evaluation section.
pub fn all_profiles() -> Vec<ModelProfile> {
    vec![
        alexnet(),
        vgg16(),
        vgg16_cifar(),
        resnet50(),
        resnet44(),
        lstm_ptb(),
        lstm_wiki2(),
    ]
}

pub fn by_name(name: &str) -> Option<ModelProfile> {
    all_profiles().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1 model sizes (MB) within ~6% of the paper's numbers.
    #[test]
    fn model_sizes_match_table1() {
        let cases = [
            ("alexnet", 233.0),
            ("vgg16", 528.0),
            ("vgg16-cifar", 58.91),
            ("resnet50", 103.0),
            ("resnet44", 2.65),
            ("lstm-ptb", 264.0),
            ("lstm-wiki2", 543.0),
        ];
        for (name, mb) in cases {
            let m = by_name(name).unwrap();
            let got = m.model_bytes() as f64 / 1e6;
            let got_mib = m.model_bytes() as f64 / (1024.0 * 1024.0);
            // accept either MB or MiB convention within 8%
            let ok = (got - mb).abs() / mb < 0.08 || (got_mib - mb).abs() / mb < 0.08;
            assert!(ok, "{name}: paper {mb} MB, profile {got:.1} MB / {got_mib:.1} MiB");
        }
    }

    #[test]
    fn resnet50_has_many_small_layers() {
        let m = resnet50();
        assert!(m.layers.len() > 50);
        let small = m.layers.iter().filter(|l| l.elems * 4 < 128 * 1024).count();
        assert!(small > 10, "resnet50 should have many sub-128KB layers");
    }

    #[test]
    fn alexnet_fc_dominates() {
        let m = alexnet();
        let fc: usize = m
            .layers
            .iter()
            .filter(|l| l.name.starts_with("fc"))
            .map(|l| l.elems)
            .sum();
        assert!(fc as f64 / m.total_elems() as f64 > 0.9);
    }

    #[test]
    fn output_layers_marked() {
        for m in all_profiles() {
            assert_eq!(m.layers.iter().filter(|l| l.is_output).count(), 1, "{}", m.name);
        }
    }

    #[test]
    fn rnn_flag() {
        assert!(lstm_ptb().is_rnn);
        assert!(!vgg16().is_rnn);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("alexnet").is_some());
        assert!(by_name("nope").is_none());
    }
}
