//! Manifest-driven model schemas: the contract between `aot.py` and the
//! Rust coordinator.  Parses `artifacts/manifest.json` into typed specs
//! the trainer uses to allocate, initialize and shard parameters.

use crate::util::json::Value;
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum SchemaError {
    Io(std::io::Error),
    Parse(crate::util::json::ParseError),
    Malformed(String),
}

impl std::fmt::Display for SchemaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchemaError::Io(e) => write!(f, "manifest io: {e}"),
            SchemaError::Parse(e) => write!(f, "manifest parse: {e}"),
            SchemaError::Malformed(msg) => write!(f, "manifest malformed: {msg}"),
        }
    }
}

impl std::error::Error for SchemaError {}

impl From<std::io::Error> for SchemaError {
    fn from(e: std::io::Error) -> Self {
        SchemaError::Io(e)
    }
}

impl From<crate::util::json::ParseError> for SchemaError {
    fn from(e: crate::util::json::ParseError) -> Self {
        SchemaError::Parse(e)
    }
}

/// Parameter initialization recipe (mirrors model.py's init specs).
#[derive(Clone, Debug, PartialEq)]
pub enum InitSpec {
    Zeros,
    Ones,
    Normal { std: f32 },
    He { fan_in: usize },
    Residual { std: f32, layers: usize },
}

impl InitSpec {
    fn from_json(v: &Value) -> Result<InitSpec, SchemaError> {
        let kind = v
            .at(&["kind"])
            .and_then(Value::as_str)
            .ok_or_else(|| SchemaError::Malformed("init.kind missing".into()))?;
        Ok(match kind {
            "zeros" => InitSpec::Zeros,
            "ones" => InitSpec::Ones,
            "normal" => InitSpec::Normal {
                std: v.at(&["std"]).and_then(Value::as_f64).unwrap_or(0.02) as f32,
            },
            "he" => InitSpec::He {
                fan_in: v
                    .at(&["fan_in"])
                    .and_then(Value::as_usize)
                    .ok_or_else(|| SchemaError::Malformed("he init needs fan_in".into()))?,
            },
            "residual" => InitSpec::Residual {
                std: v.at(&["std"]).and_then(Value::as_f64).unwrap_or(0.02) as f32,
                layers: v.at(&["layers"]).and_then(Value::as_usize).unwrap_or(1),
            },
            other => return Err(SchemaError::Malformed(format!("unknown init '{other}'"))),
        })
    }

    /// Materialize an initialized buffer of `n` elements.
    pub fn init(&self, n: usize, rng: &mut Pcg32) -> Vec<f32> {
        let mut out = vec![0f32; n];
        match self {
            InitSpec::Zeros => {}
            InitSpec::Ones => out.iter_mut().for_each(|v| *v = 1.0),
            InitSpec::Normal { std } => rng.fill_normal(&mut out, *std),
            InitSpec::He { fan_in } => {
                rng.fill_normal(&mut out, (2.0 / *fan_in as f32).sqrt())
            }
            InitSpec::Residual { std, layers } => {
                rng.fill_normal(&mut out, std / (2.0 * *layers as f32).sqrt())
            }
        }
        out
    }
}

/// One parameter tensor of a model.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitSpec,
}

impl ParamSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.size() * 4
    }
}

/// One model input (data batch tensor).
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// A train-step model loaded from the manifest.
#[derive(Clone, Debug)]
pub struct ModelSchema {
    pub name: String,
    pub kind: String,
    pub file: PathBuf,
    pub eval_file: PathBuf,
    pub params: Vec<ParamSpec>,
    pub inputs: Vec<InputSpec>,
    pub param_count: usize,
    /// raw config numbers (batch, seq, vocab, ...)
    pub config: BTreeMap<String, f64>,
}

impl ModelSchema {
    pub fn cfg(&self, key: &str) -> Option<usize> {
        self.config.get(key).map(|&v| v as usize)
    }

    /// Initialize all parameters deterministically from `seed`.
    pub fn init_params(&self, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::new(seed, 0x1217);
        self.params.iter().map(|p| p.init.init(p.size(), &mut rng)).collect()
    }

    /// Output/classifier parameters are never quantized (§5.2.3): the LM
    /// head, or the MLP's final fc weight+bias.
    pub fn is_output_param(&self, idx: usize) -> bool {
        if self.kind == "lm" {
            self.params[idx].name == "head"
        } else {
            idx + 2 >= self.params.len()
        }
    }
}

/// Parsed artifact manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSchema>,
    /// op name -> bucket size -> artifact file
    pub compress_ops: BTreeMap<String, BTreeMap<usize, PathBuf>>,
    pub buckets: Vec<usize>,
    pub num_thresholds: usize,
    pub source_hash: String,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, SchemaError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let v = Value::parse(&text)?;

        let mut models = BTreeMap::new();
        let obj = v
            .at(&["models"])
            .and_then(Value::as_obj)
            .ok_or_else(|| SchemaError::Malformed("models missing".into()))?;
        for (name, entry) in obj.iter() {
            models.insert(name.clone(), parse_model(&dir, name, entry)?);
        }

        let mut compress_ops = BTreeMap::new();
        let ops = v
            .at(&["compress_ops"])
            .and_then(Value::as_obj)
            .ok_or_else(|| SchemaError::Malformed("compress_ops missing".into()))?;
        for (op, entry) in ops.iter() {
            let mut buckets = BTreeMap::new();
            let bm = entry
                .at(&["buckets"])
                .and_then(Value::as_obj)
                .ok_or_else(|| SchemaError::Malformed(format!("{op}.buckets missing")))?;
            for (size, file) in bm.iter() {
                let n: usize = size
                    .parse()
                    .map_err(|_| SchemaError::Malformed(format!("bad bucket '{size}'")))?;
                let f = file
                    .as_str()
                    .ok_or_else(|| SchemaError::Malformed("bucket file not str".into()))?;
                buckets.insert(n, dir.join(f));
            }
            compress_ops.insert(op.clone(), buckets);
        }

        let buckets = v
            .at(&["buckets"])
            .and_then(Value::as_arr)
            .map(|a| a.iter().filter_map(Value::as_usize).collect())
            .unwrap_or_default();
        let num_thresholds = v
            .at(&["compress_ops", "threshold_count", "num_thresholds"])
            .and_then(Value::as_usize)
            .unwrap_or(16);
        let source_hash = v
            .at(&["source_hash"])
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string();

        Ok(Manifest { dir, models, compress_ops, buckets, num_thresholds, source_hash })
    }

    /// Default artifact location: `$REDSYNC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("REDSYNC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest bucket >= n, if any.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().filter(|&b| b >= n).min()
    }
}

fn parse_model(dir: &Path, name: &str, entry: &Value) -> Result<ModelSchema, SchemaError> {
    let get_str = |key: &str| {
        entry
            .at(&[key])
            .and_then(Value::as_str)
            .map(str::to_string)
            .ok_or_else(|| SchemaError::Malformed(format!("{name}.{key} missing")))
    };
    let mut params = Vec::new();
    for p in entry
        .at(&["params"])
        .and_then(Value::as_arr)
        .ok_or_else(|| SchemaError::Malformed(format!("{name}.params missing")))?
    {
        let pname = p
            .at(&["name"])
            .and_then(Value::as_str)
            .ok_or_else(|| SchemaError::Malformed("param.name".into()))?;
        let shape = p
            .at(&["shape"])
            .and_then(Value::as_arr)
            .ok_or_else(|| SchemaError::Malformed("param.shape".into()))?
            .iter()
            .filter_map(Value::as_usize)
            .collect();
        let init = InitSpec::from_json(
            p.at(&["init"]).ok_or_else(|| SchemaError::Malformed("param.init".into()))?,
        )?;
        params.push(ParamSpec { name: pname.to_string(), shape, init });
    }
    let mut inputs = Vec::new();
    for i in entry
        .at(&["inputs"])
        .and_then(Value::as_arr)
        .ok_or_else(|| SchemaError::Malformed(format!("{name}.inputs missing")))?
    {
        inputs.push(InputSpec {
            name: i.at(&["name"]).and_then(Value::as_str).unwrap_or("").to_string(),
            shape: i
                .at(&["shape"])
                .and_then(Value::as_arr)
                .map(|a| a.iter().filter_map(Value::as_usize).collect())
                .unwrap_or_default(),
            dtype: i.at(&["dtype"]).and_then(Value::as_str).unwrap_or("f32").to_string(),
        });
    }
    let mut config = BTreeMap::new();
    if let Some(cfg) = entry.at(&["config"]).and_then(Value::as_obj) {
        for (k, v) in cfg.iter() {
            if let Some(n) = v.as_f64() {
                config.insert(k.clone(), n);
            }
        }
    }
    Ok(ModelSchema {
        name: name.to_string(),
        kind: get_str("kind")?,
        file: dir.join(get_str("file")?),
        eval_file: dir.join(get_str("eval_file")?),
        param_count: entry.at(&["param_count"]).and_then(Value::as_usize).unwrap_or(0),
        params,
        inputs,
        config,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        // repo root relative to CARGO_MANIFEST_DIR
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        manifest_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(manifest_dir()).unwrap();
        assert!(m.models.contains_key("lm_tiny"));
        assert!(m.compress_ops.contains_key("abs_stats"));
        assert!(!m.buckets.is_empty());
        let lm = &m.models["lm_tiny"];
        assert_eq!(lm.kind, "lm");
        assert!(lm.file.exists());
        assert_eq!(
            lm.param_count,
            lm.params.iter().map(ParamSpec::size).sum::<usize>()
        );
    }

    #[test]
    fn init_params_deterministic_and_shaped() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(manifest_dir()).unwrap();
        let lm = &m.models["lm_tiny"];
        let a = lm.init_params(42);
        let b = lm.init_params(42);
        assert_eq!(a, b);
        for (p, buf) in lm.params.iter().zip(&a) {
            assert_eq!(buf.len(), p.size(), "{}", p.name);
        }
        // ln scales init to ones
        let ln = lm.params.iter().position(|p| p.name.contains("ln1.scale")).unwrap();
        assert!(a[ln].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn bucket_for_rounds_up() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(manifest_dir()).unwrap();
        assert_eq!(m.bucket_for(1), Some(1024));
        assert_eq!(m.bucket_for(1024), Some(1024));
        assert_eq!(m.bucket_for(1025), Some(16384));
        assert_eq!(m.bucket_for(usize::MAX), None);
    }

    #[test]
    fn init_specs_behave() {
        let mut rng = Pcg32::seeded(1);
        assert!(InitSpec::Zeros.init(4, &mut rng).iter().all(|&v| v == 0.0));
        assert!(InitSpec::Ones.init(4, &mut rng).iter().all(|&v| v == 1.0));
        let h = InitSpec::He { fan_in: 100 }.init(10_000, &mut rng);
        let var: f32 = h.iter().map(|v| v * v).sum::<f32>() / 10_000.0;
        assert!((var - 0.02).abs() < 0.005, "he var {var}");
    }

    #[test]
    fn malformed_manifest_rejected() {
        let dir = std::env::temp_dir().join(format!("redsync_schema_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"models\": 3}").unwrap();
        assert!(matches!(Manifest::load(&dir), Err(SchemaError::Malformed(_))));
        std::fs::write(dir.join("manifest.json"), "not json").unwrap();
        assert!(matches!(Manifest::load(&dir), Err(SchemaError::Parse(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
