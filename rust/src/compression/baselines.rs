//! Baseline compression schemes from the paper's related work (§3),
//! implemented for comparison benches and ablations:
//!
//! * [`strom_threshold`] — Strom (2015): fixed constant threshold, 1-bit
//!   sign quantization of the sent values (the paper's §5.2.3 notes
//!   RedSync's same-sign scheme saves that sign bit).
//! * [`AdaCompressor`] — AdaComp (Chen et al. 2017): bin-based selection
//!   with a locally adaptive threshold per bin.
//! * [`delta_encode_indices`] / [`delta_decode_indices`] — DGC's
//!   index-distance encoding (Lin et al. 2017 §5.3 discussion): RedSync
//!   deliberately does *not* use it (hard to parallelize on GPU); here it
//!   quantifies the wire-size trade-off as an ablation.

use crate::tensor::SparseTensor;

/// Strom (2015): transmit every element with |x| above a fixed constant
/// threshold, quantized to ±τ (1 sign bit + shared magnitude).  Returns
/// the selected set with ±τ values and leaves the residual handling to
/// the caller (same masking flow as RedSync).
pub fn strom_threshold(x: &[f32], tau: f32) -> SparseTensor {
    let mut s = SparseTensor::default();
    for (i, &v) in x.iter().enumerate() {
        if v > tau {
            s.push(i as u32, tau);
        } else if v < -tau {
            s.push(i as u32, -tau);
        }
    }
    s
}

/// Wire size (u32 words) of a Strom message: len + indices + packed sign
/// bits + one magnitude.  (Sign bits packed 32/word.)
pub fn strom_words(k: usize) -> usize {
    1 + k + k.div_ceil(32) + 1
}

/// AdaComp (Chen et al. 2017): split the residual into fixed-size bins;
/// within each bin select every element whose |value| exceeds the bin's
/// local maximum scaled by `ratio` — a locally-adaptive threshold that
/// self-adjusts across layers and minibatches.
pub struct AdaCompressor {
    pub bin_size: usize,
    /// Fraction of the bin maximum above which elements are sent
    /// (AdaComp's g·max heuristic; their default keeps |bin| ≈ 1 extra).
    pub ratio: f32,
}

impl Default for AdaCompressor {
    fn default() -> Self {
        AdaCompressor { bin_size: 512, ratio: 0.999 }
    }
}

impl AdaCompressor {
    /// Select the communication-set.  Every bin contributes at least its
    /// maximum element (AdaComp always sends the bin max).
    pub fn select(&self, x: &[f32]) -> SparseTensor {
        let mut out = SparseTensor::default();
        for (b, bin) in x.chunks(self.bin_size).enumerate() {
            let base = b * self.bin_size;
            let mut max = 0f32;
            for &v in bin {
                let a = v.abs();
                if a > max {
                    max = a;
                }
            }
            if max == 0.0 {
                continue;
            }
            let thr = max * self.ratio;
            for (i, &v) in bin.iter().enumerate() {
                if v.abs() >= thr {
                    out.push((base + i) as u32, v);
                }
            }
        }
        out
    }

    /// Mean selected density over a buffer (for comparison tables).
    pub fn density(&self, x: &[f32]) -> f64 {
        if x.is_empty() {
            return 0.0;
        }
        self.select(x).len() as f64 / x.len() as f64
    }
}

/// DGC-style index compression: ascending indices → gap-1 deltas,
/// varint-encoded into bytes (LEB128), then packed into u32 words.
/// Returns the encoded words.
pub fn delta_encode_indices(indices: &[u32]) -> Vec<u32> {
    let mut bytes: Vec<u8> = Vec::with_capacity(indices.len());
    let mut prev = 0u32;
    for (pos, &i) in indices.iter().enumerate() {
        debug_assert!(pos == 0 || i > prev, "indices must ascend");
        let mut gap = if pos == 0 { i } else { i - prev - 1 };
        prev = i;
        loop {
            let b = (gap & 0x7f) as u8;
            gap >>= 7;
            if gap == 0 {
                bytes.push(b);
                break;
            }
            bytes.push(b | 0x80);
        }
    }
    // prefix with the byte count, pack LE into words
    let mut words = Vec::with_capacity(2 + bytes.len() / 4);
    words.push(indices.len() as u32);
    words.push(bytes.len() as u32);
    for chunk in bytes.chunks(4) {
        let mut w = 0u32;
        for (j, &b) in chunk.iter().enumerate() {
            w |= (b as u32) << (8 * j);
        }
        words.push(w);
    }
    words
}

/// Inverse of [`delta_encode_indices`].
pub fn delta_decode_indices(words: &[u32]) -> Option<Vec<u32>> {
    let n = *words.first()? as usize;
    let n_bytes = *words.get(1)? as usize;
    let payload = &words[2..];
    if payload.len() * 4 < n_bytes {
        return None;
    }
    let byte_at = |i: usize| ((payload[i / 4] >> (8 * (i % 4))) & 0xff) as u8;
    let mut out = Vec::with_capacity(n);
    let mut pos = 0usize;
    let mut prev = 0u32;
    for count in 0..n {
        let mut gap = 0u32;
        let mut shift = 0;
        loop {
            if pos >= n_bytes {
                return None;
            }
            let b = byte_at(pos);
            pos += 1;
            gap |= ((b & 0x7f) as u32) << shift;
            if b & 0x80 == 0 {
                break;
            }
            shift += 7;
        }
        let i = if count == 0 { gap } else { prev + 1 + gap };
        out.push(i);
        prev = i;
    }
    Some(out)
}

/// Encoded index words under delta-varint (for wire-size comparisons).
pub fn delta_index_words(indices: &[u32]) -> usize {
    delta_encode_indices(indices).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn strom_selects_both_signs_at_tau() {
        let x = vec![0.5, -2.0, 1.5, 0.0, -0.4];
        let s = strom_threshold(&x, 1.0);
        assert_eq!(s.indices, vec![1, 2]);
        assert_eq!(s.values, vec![-1.0, 1.0]);
    }

    #[test]
    fn strom_wire_smaller_than_plain_but_larger_than_redsync_quant() {
        // k indices + k sign bits + 1 magnitude vs RedSync's k indices + 1
        // mean — the §5.2.3 "we save the sign bit" comparison
        let k = 1024;
        let strom = strom_words(k);
        let plain = crate::compression::message::plain_words(k);
        let quant = crate::compression::message::quant_words(k);
        assert!(strom < plain);
        assert!(strom > quant);
        assert_eq!(strom - quant, k / 32); // exactly the packed sign bits
    }

    #[test]
    fn adacomp_every_nonzero_bin_contributes() {
        let mut x = vec![0f32; 2048];
        x[10] = 1.0;
        x[600] = -3.0;
        x[1999] = 0.25;
        let c = AdaCompressor { bin_size: 512, ratio: 0.999 };
        let s = c.select(&x);
        assert_eq!(s.indices, vec![10, 600, 1999]);
    }

    #[test]
    fn adacomp_ratio_controls_density() {
        let mut g = crate::util::proptest::Gen::new(5);
        let x = g.vec_normal(8192, 1.0);
        let tight = AdaCompressor { bin_size: 256, ratio: 0.999 };
        let loose = AdaCompressor { bin_size: 256, ratio: 0.5 };
        assert!(loose.density(&x) > tight.density(&x));
        // tight keeps ~1 per bin
        let d = tight.density(&x);
        assert!((d - 1.0 / 256.0).abs() < 1.0 / 256.0, "density {d}");
    }

    #[test]
    fn adacomp_misses_global_topk_sometimes() {
        // the paper's §5.2.2 criticism: bin-local thresholds can miss
        // globally important elements.  Construct a bin holding the 2nd
        // and 3rd largest elements: only its max survives.
        let mut x = vec![0.01f32; 1024];
        x[0] = 10.0; // bin 0 max
        x[600] = 9.0; // bin 1 max
        x[601] = 8.9; // bin 1 runner-up: globally 3rd, locally cut
        let c = AdaCompressor { bin_size: 512, ratio: 0.999 };
        let s = c.select(&x);
        assert!(s.indices.contains(&0) && s.indices.contains(&600));
        assert!(!s.indices.contains(&601), "bin-local threshold should cut it");
        // while global top-3 keeps it
        let g = crate::compression::exact_topk(&x, 3, None);
        assert!(g.sparse.indices.contains(&601));
    }

    #[test]
    fn delta_roundtrip() {
        let idx = vec![0u32, 1, 5, 130, 131, 1_000_000];
        let enc = delta_encode_indices(&idx);
        assert_eq!(delta_decode_indices(&enc).unwrap(), idx);
    }

    #[test]
    fn prop_delta_roundtrip_and_compression() {
        check(40, |g| {
            let n = g.size(1..4000);
            let mut idx: Vec<u32> = (0..(n * 8) as u32).collect();
            g.rng().shuffle(&mut idx);
            idx.truncate(n);
            idx.sort_unstable();
            let enc = delta_encode_indices(&idx);
            let dec = delta_decode_indices(&enc).ok_or("decode failed")?;
            ensure(dec == idx, "roundtrip")?;
            // dense index sets compress below 1 word/index
            ensure(enc.len() <= idx.len() + 2, "never expands past raw")?;
            Ok(())
        });
    }

    #[test]
    fn delta_compresses_dense_top1pct_indices() {
        // density 1% -> mean gap 100 -> 1 varint byte each -> ~4x smaller
        let mut g = crate::util::proptest::Gen::new(9);
        let n = 100_000;
        let x = g.vec_normal(n, 1.0);
        let sel = crate::compression::exact_topk(&x, n / 100, None);
        let raw_words = sel.sparse.len();
        let enc_words = delta_index_words(&sel.sparse.indices) - 2;
        assert!(
            (enc_words as f64) < 0.33 * raw_words as f64,
            "delta {enc_words} vs raw {raw_words}"
        );
    }

    #[test]
    fn delta_decode_rejects_truncation() {
        let idx: Vec<u32> = (0..100).map(|i| i * 1000).collect();
        let enc = delta_encode_indices(&idx);
        assert!(delta_decode_indices(&enc[..enc.len() - 1]).is_none());
        assert!(delta_decode_indices(&[]).is_none());
    }
}
