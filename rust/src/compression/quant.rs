//! Quantization of compressed residuals (§5.2.3).
//!
//! All elements of the communication-set share one sign (the selector runs
//! in signed mode, alternating top-k / bottom-k per iteration), so the
//! message carries only the indices plus a *single* f32 — the mean of the
//! selected values — halving bandwidth vs (index, value) pairs.
//!
//! The paper never quantizes the model's output/softmax layer; that policy
//! lives in `coordinator::policy`.

use crate::tensor::SparseTensor;

/// Per-layer alternation state: top-k on even calls, bottom-k on odd.
#[derive(Clone, Debug, Default)]
pub struct SignAlternator {
    flip: bool,
}

impl SignAlternator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sign for the *next* selection (+1 = top-k, -1 = bottom-k), advancing
    /// the state.
    pub fn next_sign(&mut self) -> f32 {
        let s = if self.flip { -1.0 } else { 1.0 };
        self.flip = !self.flip;
        s
    }

    /// Peek without advancing.
    pub fn peek_sign(&self) -> f32 {
        if self.flip {
            -1.0
        } else {
            1.0
        }
    }
}

/// A quantized communication-set: indices + one mean value.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedSet {
    pub indices: Vec<u32>,
    pub mean: f32,
}

impl QuantizedSet {
    /// Quantize a (single-signed) selection: mean of its values.
    ///
    /// The sum deliberately stays scalar (`value_sum` is a sequential
    /// fold): a lane-parallel reduction would reorder float accumulation
    /// and break the cross-engine bit-identity pins on the wire mean.
    /// The selection walk that *feeds* this (signed compaction) is the
    /// SIMD-dispatched part (DESIGN.md §SIMD-Kernels).
    pub fn from_sparse(s: &SparseTensor) -> Self {
        let mean = if s.is_empty() { 0.0 } else { s.value_sum() / s.len() as f32 };
        QuantizedSet { indices: s.indices.clone(), mean }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Reconstruct the sparse tensor the receivers apply.
    pub fn dequantize(&self) -> SparseTensor {
        SparseTensor::with_constant_values(self.indices.clone(), self.mean)
    }

    /// Quantization error vs the original selection (L2 of value - mean).
    pub fn error(&self, original: &SparseTensor) -> f32 {
        original
            .values
            .iter()
            .map(|&v| {
                let d = (v - self.mean) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::select::exact_topk;
    use crate::util::rng::Pcg32;

    #[test]
    fn alternator_flips() {
        let mut a = SignAlternator::new();
        assert_eq!(a.next_sign(), 1.0);
        assert_eq!(a.next_sign(), -1.0);
        assert_eq!(a.next_sign(), 1.0);
        assert_eq!(a.peek_sign(), -1.0);
        assert_eq!(a.peek_sign(), -1.0); // peek does not advance
    }

    #[test]
    fn quantize_mean_of_values() {
        let s = SparseTensor::new(vec![1, 5, 9], vec![2.0, 4.0, 6.0]);
        let q = QuantizedSet::from_sparse(&s);
        assert_eq!(q.mean, 4.0);
        assert_eq!(q.dequantize().values, vec![4.0, 4.0, 4.0]);
    }

    #[test]
    fn quantize_empty() {
        let q = QuantizedSet::from_sparse(&SparseTensor::default());
        assert_eq!(q.mean, 0.0);
        assert!(q.is_empty());
    }

    #[test]
    fn quantized_mass_preserved() {
        // sum(dequantized) == sum(original): mean * n == sum
        let mut r = Pcg32::seeded(3);
        let mut x = vec![0f32; 4096];
        r.fill_normal(&mut x, 1.0);
        let sel = exact_topk(&x, 64, Some(1.0));
        let q = QuantizedSet::from_sparse(&sel.sparse);
        let sum_q: f32 = q.dequantize().values.iter().sum();
        assert!((sum_q - sel.sparse.value_sum()).abs() < 1e-3);
    }

    #[test]
    fn single_sign_selection_quantizes_with_right_sign() {
        let mut r = Pcg32::seeded(7);
        let mut x = vec![0f32; 2048];
        r.fill_normal(&mut x, 1.0);
        let pos = exact_topk(&x, 32, Some(1.0));
        assert!(QuantizedSet::from_sparse(&pos.sparse).mean > 0.0);
        let neg = exact_topk(&x, 32, Some(-1.0));
        assert!(QuantizedSet::from_sparse(&neg.sparse).mean < 0.0);
    }

    #[test]
    fn error_zero_for_constant_values() {
        let s = SparseTensor::new(vec![0, 1], vec![3.0, 3.0]);
        let q = QuantizedSet::from_sparse(&s);
        assert_eq!(q.error(&s), 0.0);
    }
}
