//! Wire format for compressed residuals (§5.3).
//!
//! Indices and values are packaged into a *single* message (one allgather,
//! not two) with a leading length word, exactly as the paper describes:
//!
//! ```text
//! plain RGC:      [len][idx_0 .. idx_{len-1}][bits(val_0) .. bits(val_{len-1})]
//! quantized RGC:  [len][idx_0 .. idx_{len-1}][bits(mean)]
//! ```
//!
//! Everything is a `u32` word; values are bit-cast f32 (no precision loss,
//! no endianness games inside one process).  The leading length makes
//! variable-length messages (threshold binary search) self-describing when
//! ranks' messages are concatenated by the allgather.

use super::quant::QuantizedSet;
use crate::tensor::SparseTensor;

#[derive(Debug, PartialEq)]
pub enum WireError {
    Truncated { need: usize, have: usize },
    Empty,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "message truncated: need {need} words, have {have}")
            }
            WireError::Empty => write!(f, "empty buffer"),
        }
    }
}

impl std::error::Error for WireError {}

/// Words required to encode a plain message of k elements.
pub fn plain_words(k: usize) -> usize {
    1 + 2 * k
}

/// Words required to encode a quantized message of k elements.
pub fn quant_words(k: usize) -> usize {
    1 + k + 1
}

/// Encode a plain (index, value) message.
pub fn pack_plain(s: &SparseTensor) -> Vec<u32> {
    let mut out = Vec::with_capacity(plain_words(s.len()));
    out.push(s.len() as u32);
    out.extend_from_slice(&s.indices);
    out.extend(s.values.iter().map(|v| v.to_bits()));
    out
}

/// Encode a quantized (indices + mean) message.
pub fn pack_quant(q: &QuantizedSet) -> Vec<u32> {
    let mut out = Vec::with_capacity(quant_words(q.len()));
    out.push(q.indices.len() as u32);
    out.extend_from_slice(&q.indices);
    out.push(q.mean.to_bits());
    out
}

/// Decode one plain message from the front of `buf`; returns (tensor,
/// words consumed).
pub fn unpack_plain(buf: &[u32]) -> Result<(SparseTensor, usize), WireError> {
    let &len = buf.first().ok_or(WireError::Empty)?;
    let len = len as usize;
    let need = plain_words(len);
    if buf.len() < need {
        return Err(WireError::Truncated { need, have: buf.len() });
    }
    let indices = buf[1..1 + len].to_vec();
    let values = buf[1 + len..need].iter().map(|&b| f32::from_bits(b)).collect();
    Ok((SparseTensor::new(indices, values), need))
}

/// Decode one quantized message from the front of `buf`.
pub fn unpack_quant(buf: &[u32]) -> Result<(QuantizedSet, usize), WireError> {
    let &len = buf.first().ok_or(WireError::Empty)?;
    let len = len as usize;
    let need = quant_words(len);
    if buf.len() < need {
        return Err(WireError::Truncated { need, have: buf.len() });
    }
    let indices = buf[1..1 + len].to_vec();
    let mean = f32::from_bits(buf[need - 1]);
    Ok((QuantizedSet { indices, mean }, need))
}

/// Decode a concatenation of `n_ranks` plain messages (an allgather
/// result), scatter-adding each into `dense` with `scale` — the §5.4
/// decompression loop.  Returns the number of (index, value) pairs applied.
pub fn apply_gathered_plain(
    buf: &[u32],
    n_ranks: usize,
    dense: &mut [f32],
    scale: f32,
) -> Result<usize, WireError> {
    let mut off = 0;
    let mut applied = 0;
    for _ in 0..n_ranks {
        let (s, used) = unpack_plain(&buf[off..])?;
        s.scatter_add(dense, scale);
        applied += s.len();
        off += used;
    }
    Ok(applied)
}

/// Quantized variant of [`apply_gathered_plain`]: each rank contributes
/// indices + one mean.
pub fn apply_gathered_quant(
    buf: &[u32],
    n_ranks: usize,
    dense: &mut [f32],
    scale: f32,
) -> Result<usize, WireError> {
    let mut off = 0;
    let mut applied = 0;
    for _ in 0..n_ranks {
        let (q, used) = unpack_quant(&buf[off..])?;
        let add = q.mean * scale;
        for &i in &q.indices {
            dense[i as usize] += add;
        }
        applied += q.len();
        off += used;
    }
    Ok(applied)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    fn sample() -> SparseTensor {
        SparseTensor::new(vec![3, 17, 42], vec![-1.5, 2.25, 1e-20])
    }

    #[test]
    fn plain_roundtrip() {
        let s = sample();
        let buf = pack_plain(&s);
        assert_eq!(buf.len(), plain_words(3));
        let (t, used) = unpack_plain(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(t, s);
    }

    #[test]
    fn plain_roundtrip_bitexact_specials() {
        let s = SparseTensor::new(vec![0, 1, 2], vec![f32::MIN_POSITIVE, -0.0, 1e38]);
        let (t, _) = unpack_plain(&pack_plain(&s)).unwrap();
        assert_eq!(t.values[0].to_bits(), f32::MIN_POSITIVE.to_bits());
        assert_eq!(t.values[1].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn quant_roundtrip() {
        let q = QuantizedSet { indices: vec![1, 9], mean: 0.125 };
        let buf = pack_quant(&q);
        assert_eq!(buf.len(), quant_words(2));
        let (r, used) = unpack_quant(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(r, q);
    }

    #[test]
    fn empty_messages() {
        let s = SparseTensor::default();
        let (t, used) = unpack_plain(&pack_plain(&s)).unwrap();
        assert_eq!(used, 1);
        assert!(t.is_empty());
        let q = QuantizedSet { indices: vec![], mean: 0.5 };
        let (r, used) = unpack_quant(&pack_quant(&q)).unwrap();
        assert_eq!(used, 2);
        assert!(r.is_empty());
        assert_eq!(r.mean, 0.5);
    }

    #[test]
    fn truncated_detected() {
        let mut buf = pack_plain(&sample());
        buf.pop();
        assert!(matches!(unpack_plain(&buf), Err(WireError::Truncated { .. })));
        assert_eq!(unpack_plain(&[]), Err(WireError::Empty));
    }

    #[test]
    fn quantized_message_halves_bandwidth() {
        // the paper's bandwidth claim: quant message ~ half of plain for
        // the same k (k idx + 1 val vs k idx + k val)
        let k = 1000;
        assert!(quant_words(k) * 2 <= plain_words(k) + 3);
    }

    #[test]
    fn gathered_apply_averages_ranks() {
        // two ranks contribute overlapping indices; scale = 1/2 averages
        let a = SparseTensor::new(vec![0, 2], vec![2.0, 4.0]);
        let b = SparseTensor::new(vec![2, 3], vec![6.0, 8.0]);
        let mut buf = pack_plain(&a);
        buf.extend(pack_plain(&b));
        let mut dense = vec![0f32; 4];
        let n = apply_gathered_plain(&buf, 2, &mut dense, 0.5).unwrap();
        assert_eq!(n, 4);
        assert_eq!(dense, vec![1.0, 0.0, 5.0, 4.0]);
    }

    #[test]
    fn gathered_apply_quant() {
        let qa = QuantizedSet { indices: vec![0, 1], mean: 2.0 };
        let qb = QuantizedSet { indices: vec![1], mean: -4.0 };
        let mut buf = pack_quant(&qa);
        buf.extend(pack_quant(&qb));
        let mut dense = vec![0f32; 2];
        apply_gathered_quant(&buf, 2, &mut dense, 0.5).unwrap();
        assert_eq!(dense, vec![1.0, -1.0]);
    }

    #[test]
    fn prop_roundtrip_any_message() {
        check(60, |g| {
            let n = g.size(0..500);
            let mut s = SparseTensor::default();
            for i in 0..n {
                s.push(i as u32 * 3, g.f32(-100.0..100.0));
            }
            let (t, used) = unpack_plain(&pack_plain(&s)).map_err(|e| e.to_string())?;
            ensure(used == plain_words(n), "length")?;
            ensure(t == s, "roundtrip mismatch")
        });
    }
}
