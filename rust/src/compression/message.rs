//! Wire format for compressed residuals (§5.3).
//!
//! Indices and values are packaged into a *single* message (one allgather,
//! not two) with a leading length word, exactly as the paper describes:
//!
//! ```text
//! plain RGC:      [len][idx_0 .. idx_{len-1}][bits(val_0) .. bits(val_{len-1})]
//! quantized RGC:  [len][idx_0 .. idx_{len-1}][bits(mean)]
//! ```
//!
//! Everything is a `u32` word; values are bit-cast f32 (no precision loss,
//! no endianness games inside one process).  The leading length makes
//! variable-length messages (threshold binary search) self-describing when
//! ranks' messages are concatenated by the allgather.

use super::quant::QuantizedSet;
use crate::tensor::{SparseTensor, SparseView};

#[derive(Debug, PartialEq)]
pub enum WireError {
    Truncated { need: usize, have: usize },
    Empty,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "message truncated: need {need} words, have {have}")
            }
            WireError::Empty => write!(f, "empty buffer"),
        }
    }
}

impl std::error::Error for WireError {}

/// Words required to encode a plain message of k elements.
pub fn plain_words(k: usize) -> usize {
    1 + 2 * k
}

/// Words required to encode a quantized message of k elements.
pub fn quant_words(k: usize) -> usize {
    1 + k + 1
}

/// Encode a plain (index, value) message.
pub fn pack_plain(s: &SparseTensor) -> Vec<u32> {
    let mut out = Vec::with_capacity(plain_words(s.len()));
    pack_plain_into(s, &mut out);
    out
}

/// Append a plain message to a reused wire buffer — the pack-in-place
/// form `BucketState::produce` drives: the bucket's persistent blob is
/// cleared once per step and every layer appends, so steady-state
/// packing allocates nothing.
pub fn pack_plain_into(s: &SparseTensor, out: &mut Vec<u32>) {
    out.reserve(plain_words(s.len()));
    out.push(s.len() as u32);
    out.extend_from_slice(&s.indices);
    // value section: `to_bits` per element == one bulk bit copy on the
    // SIMD backends (bit-identical, NaN payloads and -0.0 included)
    super::simd::extend_value_bits(super::simd::active(), &s.values, out);
}

/// Encode a quantized (indices + mean) message.
pub fn pack_quant(q: &QuantizedSet) -> Vec<u32> {
    let mut out = Vec::with_capacity(quant_words(q.len()));
    pack_quant_into(&q.indices, q.mean, &mut out);
    out
}

/// Append a quantized message to a reused wire buffer.  Takes the raw
/// (indices, mean) pair so the packer never materializes a
/// [`QuantizedSet`] on the hot path.
pub fn pack_quant_into(indices: &[u32], mean: f32, out: &mut Vec<u32>) {
    out.reserve(quant_words(indices.len()));
    out.push(indices.len() as u32);
    out.extend_from_slice(indices);
    out.push(mean.to_bits());
}

/// A quantized message parsed in place: borrowed indices + the mean.
#[derive(Clone, Copy, Debug)]
pub struct QuantView<'a> {
    pub indices: &'a [u32],
    pub mean: f32,
}

impl QuantView<'_> {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

/// One message of either flavor, parsed in place — what a layer walk
/// over a gathered blob yields without touching the heap.
#[derive(Clone, Copy, Debug)]
pub enum MessageView<'a> {
    Plain(SparseView<'a>),
    Quantized(QuantView<'a>),
}

/// Parse one message of the given flavor from the front of `buf`;
/// returns (view, words consumed).
pub fn view_message(buf: &[u32], quantized: bool) -> Result<(MessageView<'_>, usize), WireError> {
    if quantized {
        view_quant(buf).map(|(q, used)| (MessageView::Quantized(q), used))
    } else {
        view_plain(buf).map(|(s, used)| (MessageView::Plain(s), used))
    }
}

/// Parse one plain message in place from the front of `buf`; returns
/// (view, words consumed).  Same framing checks as [`unpack_plain`],
/// zero copies.
pub fn view_plain(buf: &[u32]) -> Result<(SparseView<'_>, usize), WireError> {
    let &len = buf.first().ok_or(WireError::Empty)?;
    let len = len as usize;
    let need = plain_words(len);
    if buf.len() < need {
        return Err(WireError::Truncated { need, have: buf.len() });
    }
    Ok((SparseView::new(&buf[1..1 + len], &buf[1 + len..need]), need))
}

/// Parse one quantized message in place from the front of `buf`.
pub fn view_quant(buf: &[u32]) -> Result<(QuantView<'_>, usize), WireError> {
    let &len = buf.first().ok_or(WireError::Empty)?;
    let len = len as usize;
    let need = quant_words(len);
    if buf.len() < need {
        return Err(WireError::Truncated { need, have: buf.len() });
    }
    Ok((QuantView { indices: &buf[1..1 + len], mean: f32::from_bits(buf[need - 1]) }, need))
}

/// Decode one plain message from the front of `buf`; returns (tensor,
/// words consumed).  Owned-decode compat shape — the hot path uses
/// [`view_plain`].
pub fn unpack_plain(buf: &[u32]) -> Result<(SparseTensor, usize), WireError> {
    let (v, used) = view_plain(buf)?;
    Ok((v.to_tensor(), used))
}

/// Decode one quantized message from the front of `buf`.
pub fn unpack_quant(buf: &[u32]) -> Result<(QuantizedSet, usize), WireError> {
    let (q, used) = view_quant(buf)?;
    Ok((QuantizedSet { indices: q.indices.to_vec(), mean: q.mean }, used))
}

/// Decode a concatenation of `n_ranks` plain messages (an allgather
/// result), scatter-adding each into `dense` with `scale` — the §5.4
/// decompression loop.  Returns the number of (index, value) pairs applied.
pub fn apply_gathered_plain(
    buf: &[u32],
    n_ranks: usize,
    dense: &mut [f32],
    scale: f32,
) -> Result<usize, WireError> {
    let mut off = 0;
    let mut applied = 0;
    for _ in 0..n_ranks {
        let (s, used) = view_plain(&buf[off..])?;
        s.scatter_add(dense, scale);
        applied += s.len();
        off += used;
    }
    Ok(applied)
}

/// Merge plain sparse messages into one index-union message: indices
/// selected by several ranks appear once with their values summed —
/// the node-level *reduce* of the hierarchical scheme in its
/// bandwidth-optimal form (inter-node bytes bounded by the union, not
/// the sum, of the node's selections).  Indices come back sorted;
/// values are f32 sums accumulated in `msgs` order, so callers must
/// present messages in a rank-deterministic order to get identical
/// bits everywhere (float addition does not commute bitwise).
///
/// Implemented as a k-way sort-merge over the inputs, which the wire
/// format guarantees are index-ascending (every selector emits sorted
/// indices) — O(union · k) cursor scans and one output buffer, no
/// tree-map churn.  Debug builds assert the ascending precondition.
///
/// The wire schedule (`collectives::hierarchical`) deliberately does
/// *not* apply this merge — value-merging changes float summation order
/// and would break the bit-identity pin against the flat schedule — but
/// the cost model prices it and the topology bench reports the union
/// size it would achieve.
pub fn merge_plain(msgs: &[SparseTensor]) -> SparseTensor {
    debug_assert!(
        msgs.iter().all(|m| m.indices.windows(2).all(|w| w[0] <= w[1])),
        "merge_plain needs index-ascending messages (the wire invariant)"
    );
    let mut cursors = vec![0usize; msgs.len()];
    let mut out = SparseTensor::default();
    loop {
        // the smallest index any cursor still points at
        let mut next: Option<u32> = None;
        for (m, &c) in msgs.iter().zip(&cursors) {
            if c < m.len() {
                let i = m.indices[c];
                if next.map_or(true, |n| i < n) {
                    next = Some(i);
                }
            }
        }
        let Some(i) = next else { break };
        // sum every message's run of `i` entries, in message order — the
        // same accumulation order the receivers' scatter walk uses
        let mut v = 0.0f32;
        for (m, c) in msgs.iter().zip(&mut cursors) {
            while *c < m.len() && m.indices[*c] == i {
                v += m.values[*c];
                *c += 1;
            }
        }
        out.push(i, v);
    }
    out
}

/// Quantized variant of [`apply_gathered_plain`]: each rank contributes
/// indices + one mean.
pub fn apply_gathered_quant(
    buf: &[u32],
    n_ranks: usize,
    dense: &mut [f32],
    scale: f32,
) -> Result<usize, WireError> {
    let mut off = 0;
    let mut applied = 0;
    for _ in 0..n_ranks {
        let (q, used) = view_quant(&buf[off..])?;
        let add = q.mean * scale;
        for &i in q.indices {
            dense[i as usize] += add;
        }
        applied += q.len();
        off += used;
    }
    Ok(applied)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    fn sample() -> SparseTensor {
        SparseTensor::new(vec![3, 17, 42], vec![-1.5, 2.25, 1e-20])
    }

    #[test]
    fn plain_roundtrip() {
        let s = sample();
        let buf = pack_plain(&s);
        assert_eq!(buf.len(), plain_words(3));
        let (t, used) = unpack_plain(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(t, s);
    }

    #[test]
    fn plain_roundtrip_bitexact_specials() {
        let s = SparseTensor::new(vec![0, 1, 2], vec![f32::MIN_POSITIVE, -0.0, 1e38]);
        let (t, _) = unpack_plain(&pack_plain(&s)).unwrap();
        assert_eq!(t.values[0].to_bits(), f32::MIN_POSITIVE.to_bits());
        assert_eq!(t.values[1].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn quant_roundtrip() {
        let q = QuantizedSet { indices: vec![1, 9], mean: 0.125 };
        let buf = pack_quant(&q);
        assert_eq!(buf.len(), quant_words(2));
        let (r, used) = unpack_quant(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(r, q);
    }

    #[test]
    fn empty_messages() {
        let s = SparseTensor::default();
        let (t, used) = unpack_plain(&pack_plain(&s)).unwrap();
        assert_eq!(used, 1);
        assert!(t.is_empty());
        let q = QuantizedSet { indices: vec![], mean: 0.5 };
        let (r, used) = unpack_quant(&pack_quant(&q)).unwrap();
        assert_eq!(used, 2);
        assert!(r.is_empty());
        assert_eq!(r.mean, 0.5);
    }

    #[test]
    fn truncated_detected() {
        let mut buf = pack_plain(&sample());
        buf.pop();
        assert!(matches!(unpack_plain(&buf), Err(WireError::Truncated { .. })));
        assert_eq!(unpack_plain(&[]), Err(WireError::Empty));
        // the in-place views apply the same framing checks
        assert!(matches!(view_plain(&buf), Err(WireError::Truncated { .. })));
        assert!(matches!(view_quant(&[]), Err(WireError::Empty)));
    }

    #[test]
    fn views_parse_in_place() {
        let s = sample();
        let mut buf = pack_plain(&s);
        buf.extend(pack_quant(&QuantizedSet { indices: vec![2, 5], mean: -0.75 }));
        let (v, used) = view_plain(&buf).unwrap();
        assert_eq!(v.indices, &s.indices[..]);
        assert_eq!(v.to_tensor(), s);
        let (q, used2) = view_quant(&buf[used..]).unwrap();
        assert_eq!(q.indices, &[2, 5]);
        assert_eq!(q.mean, -0.75);
        assert_eq!(used + used2, buf.len());
        match view_message(&buf, false).unwrap() {
            (MessageView::Plain(p), u) => assert_eq!((p.len(), u), (3, used)),
            _ => panic!("expected plain"),
        }
    }

    #[test]
    fn pack_into_appends_to_a_shared_blob() {
        let s = sample();
        let mut blob = vec![0xFEEDu32]; // pre-existing contents survive
        pack_plain_into(&s, &mut blob);
        pack_quant_into(&[1, 2], 0.5, &mut blob);
        assert_eq!(blob[0], 0xFEED);
        assert_eq!(&blob[1..1 + plain_words(3)], &pack_plain(&s)[..]);
        assert_eq!(
            &blob[1 + plain_words(3)..],
            &pack_quant(&QuantizedSet { indices: vec![1, 2], mean: 0.5 })[..]
        );
    }

    #[test]
    fn quantized_message_halves_bandwidth() {
        // the paper's bandwidth claim: quant message ~ half of plain for
        // the same k (k idx + 1 val vs k idx + k val)
        let k = 1000;
        assert!(quant_words(k) * 2 <= plain_words(k) + 3);
    }

    #[test]
    fn gathered_apply_averages_ranks() {
        // two ranks contribute overlapping indices; scale = 1/2 averages
        let a = SparseTensor::new(vec![0, 2], vec![2.0, 4.0]);
        let b = SparseTensor::new(vec![2, 3], vec![6.0, 8.0]);
        let mut buf = pack_plain(&a);
        buf.extend(pack_plain(&b));
        let mut dense = vec![0f32; 4];
        let n = apply_gathered_plain(&buf, 2, &mut dense, 0.5).unwrap();
        assert_eq!(n, 4);
        assert_eq!(dense, vec![1.0, 0.0, 5.0, 4.0]);
    }

    #[test]
    fn gathered_apply_quant() {
        let qa = QuantizedSet { indices: vec![0, 1], mean: 2.0 };
        let qb = QuantizedSet { indices: vec![1], mean: -4.0 };
        let mut buf = pack_quant(&qa);
        buf.extend(pack_quant(&qb));
        let mut dense = vec![0f32; 2];
        apply_gathered_quant(&buf, 2, &mut dense, 0.5).unwrap();
        assert_eq!(dense, vec![1.0, -1.0]);
    }

    #[test]
    fn merge_sums_overlapping_indices() {
        let a = SparseTensor::new(vec![0, 2, 5], vec![1.0, 2.0, 3.0]);
        let b = SparseTensor::new(vec![2, 7], vec![10.0, 4.0]);
        let m = merge_plain(&[a, b]);
        assert_eq!(m.indices, vec![0, 2, 5, 7]);
        assert_eq!(m.values, vec![1.0, 12.0, 3.0, 4.0]);
    }

    #[test]
    fn merge_of_disjoint_messages_is_the_sorted_union() {
        let a = SparseTensor::new(vec![1, 9], vec![2.0, 1.0]);
        let b = SparseTensor::new(vec![4], vec![3.0]);
        let m = merge_plain(&[a, b]);
        assert_eq!(m.indices, vec![1, 4, 9]);
        assert_eq!(m.values, vec![2.0, 1.0, 3.0]);
        assert!(merge_plain(&[]).is_empty());
    }

    #[test]
    fn prop_merged_size_is_the_distinct_index_count() {
        // the hierarchical cost model's union bound: |merge| == number
        // of distinct indices across the node's messages, and the merged
        // scatter equals the sequential scatter of the parts
        check(40, |g| {
            let n_msgs = g.size(1..5);
            let dim = g.size(8..200);
            let msgs: Vec<SparseTensor> = (0..n_msgs)
                .map(|_| {
                    let k = g.size(0..dim.min(40));
                    let mut used = vec![false; dim];
                    for _ in 0..k {
                        used[g.size(0..dim)] = true;
                    }
                    // wire invariant: message indices ascend
                    let mut s = SparseTensor::default();
                    for (i, &u) in used.iter().enumerate() {
                        if u {
                            s.push(i as u32, g.f32(-2.0..2.0));
                        }
                    }
                    s
                })
                .collect();
            let mut distinct = vec![false; dim];
            for m in &msgs {
                for &i in &m.indices {
                    distinct[i as usize] = true;
                }
            }
            let want = distinct.iter().filter(|&&d| d).count();
            let merged = merge_plain(&msgs);
            ensure(merged.len() == want, format!("union {} != {}", merged.len(), want))?;
            let mut direct = vec![0f64; dim];
            for m in &msgs {
                for (&i, &v) in m.indices.iter().zip(&m.values) {
                    direct[i as usize] += v as f64;
                }
            }
            for (&i, &v) in merged.indices.iter().zip(&merged.values) {
                let d = direct[i as usize];
                ensure((v as f64 - d).abs() <= 1e-4 * d.abs().max(1.0), "merged value")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_any_message() {
        check(60, |g| {
            let n = g.size(0..500);
            let mut s = SparseTensor::default();
            for i in 0..n {
                s.push(i as u32 * 3, g.f32(-100.0..100.0));
            }
            let (t, used) = unpack_plain(&pack_plain(&s)).map_err(|e| e.to_string())?;
            ensure(used == plain_words(n), "length")?;
            ensure(t == s, "roundtrip mismatch")
        });
    }
}
