//! Residual Gradient Compression — the paper's core machinery.
//!
//! * [`select`]   — communication-set selection (Alg. 2/3 + exact baseline)
//! * [`quant`]    — same-sign mean quantization (§5.2.3)
//! * [`message`]  — single-message wire format `(len, idx…, val…)` (§5.3)
//! * [`residual`] — residual store + momentum correction/masking (Alg. 4)
//! * [`simd`]     — SSE2/AVX2 kernels for the select/pack/apply walks,
//!   runtime-dispatched, scalar path as bit-identity oracle
//!
//! [`LayerCompressor`] ties them together as the per-layer pipeline the
//! coordinator drives: accumulate → select → (quantize) → pack, plus the
//! §5.5 size-based method policy in [`Method::for_size`].

pub mod baselines;
pub mod message;
pub mod quant;
pub mod residual;
pub mod select;
pub mod simd;

pub use quant::{QuantizedSet, SignAlternator};
pub use residual::{Accumulation, ResidualState};
pub use select::{
    exact_topk, exact_topk_into, threshold_binary_search, threshold_binary_search_into,
    trimmed_topk, trimmed_topk_into, BinarySearchParams, CachedThresholdSelector, SelectScratch,
    Selection,
};

use crate::tensor::SparseTensor;

/// Selection method per layer (Alg. 5 dispatch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Parameter too small to be worth compressing: dense allreduce.
    Dense,
    /// Exact top-k (the radixSelect-baseline; not chosen by the policy but
    /// selectable for ablations).
    ExactTopk,
    /// Algorithm 2 — sizes in [thsd1, thsd2).
    TrimmedTopk,
    /// Algorithm 3 with threshold caching — sizes >= thsd2.
    SampledBinarySearch,
}

/// §5.5 policy thresholds, in *bytes* of layer parameters.
#[derive(Clone, Copy, Debug)]
pub struct PolicyThresholds {
    /// Below this: dense allreduce (default 128 KB).
    pub thsd1: usize,
    /// Above this: sampled threshold binary search (default 4 MB).
    pub thsd2: usize,
}

impl Default for PolicyThresholds {
    fn default() -> Self {
        PolicyThresholds { thsd1: 128 * 1024, thsd2: 4 * 1024 * 1024 }
    }
}

impl Method {
    /// The paper's rule: dense < 128 KB <= trimmed < 4 MB <= binary search.
    pub fn for_size(param_bytes: usize, t: PolicyThresholds) -> Method {
        if param_bytes < t.thsd1 {
            Method::Dense
        } else if param_bytes < t.thsd2 {
            Method::TrimmedTopk
        } else {
            Method::SampledBinarySearch
        }
    }
}

/// Tunables for one compression pipeline instance.
#[derive(Clone, Copy, Debug)]
pub struct CompressorConfig {
    /// Density D: fraction of elements selected (paper default 1e-3).
    pub density: f64,
    /// Trim ratio decrement ε for Algorithm 2.
    pub trim_eps: f32,
    /// Binary-search parameters for Algorithm 3.
    pub bs: BinarySearchParams,
    /// Threshold-reuse interval for the sampled variant (paper: 5).
    pub interval: usize,
    /// Quantize the communication-set (§5.2.3).  Incompatible with
    /// threshold caching — quantized layers re-search every iteration, as
    /// the paper notes.
    pub quantize: bool,
    /// Record per-phase produce timings (the Fig. 10 mask/select/pack
    /// split).  Disabling skips every clock read on the produce hot path
    /// — for micro-layer workloads and benches where `Instant::now`
    /// would dominate the phase being measured.
    pub timing: bool,
}

impl Default for CompressorConfig {
    fn default() -> Self {
        CompressorConfig {
            density: 1e-3,
            trim_eps: 0.2,
            bs: BinarySearchParams::default(),
            interval: 5,
            quantize: false,
            timing: true,
        }
    }
}

impl CompressorConfig {
    /// Communication-set size for a layer of n elements (>= 1).
    pub fn k_for(&self, n: usize) -> usize {
        ((n as f64 * self.density).ceil() as usize).clamp(1, n)
    }
}

/// The compressed product of one layer-iteration, ready for allgather.
#[derive(Clone, Debug)]
pub enum CompressedMessage {
    Plain(SparseTensor),
    Quantized(QuantizedSet),
}

impl CompressedMessage {
    pub fn n_selected(&self) -> usize {
        match self {
            CompressedMessage::Plain(s) => s.len(),
            CompressedMessage::Quantized(q) => q.len(),
        }
    }

    /// Encoded size in u32 words.
    pub fn wire_words(&self) -> usize {
        match self {
            CompressedMessage::Plain(s) => message::plain_words(s.len()),
            CompressedMessage::Quantized(q) => message::quant_words(q.len()),
        }
    }

    pub fn pack(&self) -> Vec<u32> {
        match self {
            CompressedMessage::Plain(s) => message::pack_plain(s),
            CompressedMessage::Quantized(q) => message::pack_quant(q),
        }
    }
}

/// Per-layer compression pipeline: residual state + selection method +
/// quantization alternator + threshold cache.
#[derive(Clone, Debug)]
pub struct LayerCompressor {
    pub method: Method,
    pub cfg: CompressorConfig,
    pub residual: ResidualState,
    alternator: SignAlternator,
    cached: CachedThresholdSelector,
}

impl LayerCompressor {
    pub fn new(n: usize, method: Method, accumulation: Accumulation, cfg: CompressorConfig) -> Self {
        LayerCompressor {
            method,
            cfg,
            residual: ResidualState::new(n, accumulation),
            alternator: SignAlternator::new(),
            cached: CachedThresholdSelector::new(cfg.interval, cfg.bs),
        }
    }

    /// Accumulate this iteration's gradient into the residual.
    pub fn accumulate(&mut self, grad: &[f32]) {
        self.residual.accumulate(grad);
    }

    /// Select + (quantize) + mask.  Returns the message to allgather.
    pub fn compress(&mut self) -> CompressedMessage {
        let n = self.residual.len();
        let k = self.cfg.k_for(n);
        let sign = if self.cfg.quantize { Some(self.alternator.next_sign()) } else { None };

        let sel = match self.method {
            Method::Dense => {
                // callers shouldn't compress Dense layers; degrade gracefully
                exact_topk(self.residual.residual(), k, sign)
            }
            Method::ExactTopk => exact_topk(self.residual.residual(), k, sign),
            Method::TrimmedTopk => {
                trimmed_topk(self.residual.residual(), k, self.cfg.trim_eps, sign)
            }
            Method::SampledBinarySearch => {
                if self.cfg.quantize {
                    // §6.4: threshold sharing is incompatible with
                    // quantization (sign alternates) — search every time.
                    threshold_binary_search(self.residual.residual(), k, self.cfg.bs, sign)
                } else {
                    self.cached.select(self.residual.residual(), k, sign)
                }
            }
        };

        self.residual.mask(&sel.sparse);
        if self.cfg.quantize {
            CompressedMessage::Quantized(QuantizedSet::from_sparse(&sel.sparse))
        } else {
            CompressedMessage::Plain(sel.sparse)
        }
    }

    pub fn reset(&mut self) {
        let n = self.residual.len();
        self.residual = ResidualState::new(n, self.residual.accumulation);
        self.alternator = SignAlternator::new();
        self.cached.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    #[test]
    fn policy_matches_paper_rules() {
        let t = PolicyThresholds::default();
        assert_eq!(Method::for_size(64 * 1024, t), Method::Dense);
        assert_eq!(Method::for_size(128 * 1024, t), Method::TrimmedTopk);
        assert_eq!(Method::for_size(1024 * 1024, t), Method::TrimmedTopk);
        assert_eq!(Method::for_size(4 * 1024 * 1024, t), Method::SampledBinarySearch);
        assert_eq!(Method::for_size(64 << 20, t), Method::SampledBinarySearch);
    }

    #[test]
    fn k_for_density() {
        let cfg = CompressorConfig { density: 1e-3, ..Default::default() };
        assert_eq!(cfg.k_for(1_000_000), 1000);
        assert_eq!(cfg.k_for(10), 1); // clamped to >= 1
        assert_eq!(cfg.k_for(500), 1);
    }

    #[test]
    fn compress_trimmed_returns_exactly_k() {
        let cfg = CompressorConfig { density: 0.01, ..Default::default() };
        let mut lc = LayerCompressor::new(10_000, Method::TrimmedTopk, Accumulation::Sgd, cfg);
        let mut g = crate::util::proptest::Gen::new(1);
        lc.accumulate(&g.vec_normal(10_000, 1.0));
        let msg = lc.compress();
        assert_eq!(msg.n_selected(), 100);
    }

    #[test]
    fn compress_masks_residual() {
        let cfg = CompressorConfig { density: 0.1, ..Default::default() };
        let mut lc = LayerCompressor::new(1000, Method::ExactTopk, Accumulation::Sgd, cfg);
        let mut g = crate::util::proptest::Gen::new(2);
        lc.accumulate(&g.vec_normal(1000, 1.0));
        let msg = lc.compress();
        if let CompressedMessage::Plain(s) = &msg {
            for &i in &s.indices {
                assert_eq!(lc.residual.residual()[i as usize], 0.0);
            }
        } else {
            panic!("expected plain");
        }
    }

    #[test]
    fn quantized_alternates_sign() {
        let cfg = CompressorConfig { density: 0.01, quantize: true, ..Default::default() };
        let mut lc = LayerCompressor::new(5000, Method::TrimmedTopk, Accumulation::Sgd, cfg);
        let mut g = crate::util::proptest::Gen::new(3);
        let grad = g.vec_normal(5000, 1.0);
        lc.accumulate(&grad);
        let m1 = lc.compress();
        lc.accumulate(&grad);
        let m2 = lc.compress();
        match (m1, m2) {
            (CompressedMessage::Quantized(a), CompressedMessage::Quantized(b)) => {
                assert!(a.mean > 0.0, "first = top-k (positive)");
                assert!(b.mean < 0.0, "second = bottom-k (negative)");
            }
            _ => panic!("expected quantized"),
        }
    }

    #[test]
    fn wire_words_accounting() {
        let s = SparseTensor::new(vec![1, 2], vec![1.0, 2.0]);
        assert_eq!(CompressedMessage::Plain(s.clone()).wire_words(), 5);
        let q = QuantizedSet::from_sparse(&s);
        assert_eq!(CompressedMessage::Quantized(q).wire_words(), 4);
    }

    #[test]
    fn prop_compress_never_selects_more_than_2k_bs() {
        check(25, |g| {
            let n = g.usize_pow2(10, 15);
            let cfg = CompressorConfig { density: 0.01, ..Default::default() };
            let mut lc =
                LayerCompressor::new(n, Method::SampledBinarySearch, Accumulation::Sgd, cfg);
            for _ in 0..3 {
                lc.accumulate(&g.vec_normal(n, 1.0));
                let k = cfg.k_for(n);
                let msg = lc.compress();
                // binary search may exceed 2k slightly in cached iterations
                // (threshold drift) but must stay near the target
                ensure(
                    msg.n_selected() >= 1 && msg.n_selected() <= 8 * k.max(1),
                    format!("selected {} for k={k}", msg.n_selected()),
                )?;
            }
            Ok(())
        });
    }
}
