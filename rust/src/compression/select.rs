//! Communication-set selection — the paper's §5.2, the compute hot-spot.
//!
//! Three selectors, exactly mirroring the paper:
//!
//! * [`exact_topk`] — exact top-k via quickselect.  This is the repo's
//!   stand-in for the paper's GPU radixSelect *baseline* (both are exact
//!   selectors whose cost grows with the full array size; Fig. 3 compares
//!   everything against it).
//! * [`trimmed_topk`] — Algorithm 2: use (mean, max) of |x| to trim the
//!   candidate set with a descending-ratio threshold, then run the exact
//!   selector on the (tiny) remainder.  Always returns exactly `k`.
//! * [`threshold_binary_search`] — Algorithm 3: bisect a threshold whose
//!   count lands in [k, 2k]; returns *at least* k elements and never
//!   touches an exact selector.  [`CachedThresholdSelector`] adds the
//!   paper's "reuse the threshold for the next `interval` iterations"
//!   optimization (§5.2.2, interval = 5).
//!
//! All selectors come in magnitude (`sign = None`) and signed
//! (`sign = Some(±1.0)`) flavors; the signed ones power quantized RGC
//! (§5.2.3) where the communication-set must be single-signed.
//!
//! # NaN policy
//!
//! A non-finite gradient must never abort the rank.  Selection orders
//! keys with a *total* order in which **NaN sorts last and is never
//! selected while finite candidates remain** (see [`cmp_keys_desc`]):
//! rank-based selectors treat a NaN key as below every real key, and
//! threshold compares are IEEE *ordered* `>` — a NaN key fails them.
//! The SIMD kernels in [`crate::compression::simd`] use ordered vector
//! compares (`_CMP_GT_OQ`) and therefore implement the identical
//! semantics; the scalar path stays the bit-identity oracle.  The only
//! NaN-selected case is the deliberate `k >= n` pass-through, which
//! returns the whole layer verbatim.  Non-finite values also poison the
//! `(mean, max)` statistics that Alg. 3's threshold interpolation needs,
//! so degenerate stats (NaN/Inf mean or max, or an all-zero layer) fall
//! back to the exact selector, which is well-defined for every input.

use crate::tensor::{abs_mean_max, SparseTensor};

/// Result of a selection pass.
#[derive(Clone, Debug)]
pub struct Selection {
    pub sparse: SparseTensor,
    /// The threshold that produced the set (for threshold reuse).
    pub threshold: f32,
}

/// Reusable selection scratch: every buffer a selector touches, kept
/// alive across steps so steady-state selection performs zero heap
/// allocation (DESIGN.md §Zero-Copy-Hot-Path).  One per fusion bucket —
/// the bucket's layers select serially, so they share it; capacities
/// grow to the largest layer once and stay.
///
/// The `*_into` selectors leave their result in the
/// [`selected`](SelectScratch::selected) slot and return the threshold;
/// the owned wrappers ([`exact_topk`], [`trimmed_topk`],
/// [`threshold_binary_search`]) keep the historical `Selection` shape
/// for everything that is not the per-step hot path.
#[derive(Default)]
pub struct SelectScratch {
    /// Index permutation buffer for exact top-k's quickselect.
    idx: Vec<u32>,
    /// Strided sample keys (trim / sample-guided estimation).
    keys: Vec<f32>,
    /// Bisection threshold ladder.
    ladder: Vec<f32>,
    /// Counting-pass output.
    counts: Vec<usize>,
    /// Trim-pass candidate set.
    cand: SparseTensor,
    /// The selection result slot.
    out: SparseTensor,
}

impl SelectScratch {
    pub fn new() -> SelectScratch {
        SelectScratch::default()
    }

    /// The last selection written by an `*_into` selector.
    pub fn selected(&self) -> &SparseTensor {
        &self.out
    }

    /// Take the result slot (owned-wrapper use).
    pub fn take_selected(&mut self) -> SparseTensor {
        std::mem::take(&mut self.out)
    }

    /// Replace the result slot with an externally produced selection
    /// (the device-selector path hands back owned tensors).
    pub fn put(&mut self, s: SparseTensor) {
        self.out = s;
    }

    /// Compact `x` above a cached threshold into the result slot — the
    /// §5.2.2 threshold-reuse fast path, allocation-free.
    pub fn compact_above(&mut self, x: &[f32], thr: f32) {
        SparseTensor::compact_above_into(x, thr, &mut self.out);
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BinarySearchParams {
    /// Termination width on the ratio interval (paper's ε).
    pub eps: f32,
    /// Hard cap on total probe evaluations.
    pub max_iters: usize,
    /// Probes per counting pass (J-way bisection, §Perf).  1 = the
    /// paper's scalar bisection; 15 shrinks the bracket 16x per pass.
    pub probes: usize,
}

impl Default for BinarySearchParams {
    fn default() -> Self {
        BinarySearchParams { eps: 1e-3, max_iters: 64, probes: 15 }
    }
}

#[inline]
fn key_of(v: f32, sign: Option<f32>) -> f32 {
    match sign {
        None => v.abs(),
        Some(s) => s * v,
    }
}

/// Map a selection key into the total order used for ranking: NaN sorts
/// below every real key (including -∞), so it is never selected while a
/// finite candidate remains — the module-level NaN policy.
#[inline]
fn nan_last_key(v: f32) -> f32 {
    if v.is_nan() {
        f32::NEG_INFINITY
    } else {
        v
    }
}

/// Descending total-order comparator on selection keys, NaN last.
/// Every rank-based pass in this module sorts with this — a single
/// NaN/Inf gradient element must never panic a `partial_cmp` unwrap.
#[inline]
fn cmp_keys_desc(a: &f32, b: &f32) -> std::cmp::Ordering {
    nan_last_key(*b).total_cmp(&nan_last_key(*a))
}

fn compact(x: &[f32], thr: f32, sign: Option<f32>) -> SparseTensor {
    match sign {
        None => SparseTensor::compact_above(x, thr),
        Some(s) => SparseTensor::compact_above_signed(x, thr, s),
    }
}

fn count(x: &[f32], thr: f32, sign: Option<f32>) -> usize {
    match sign {
        None => crate::tensor::count_above(x, thr),
        Some(s) => crate::tensor::count_above_signed(x, thr, s),
    }
}

/// Signed-aware (mean, max) of selection keys.  For magnitude mode this is
/// (mean|x|, max|x|); for signed mode, stats of max(s*x, 0) so the
/// threshold interpolation stays in the meaningful range.
fn key_stats(x: &[f32], sign: Option<f32>) -> (f32, f32) {
    match sign {
        None => abs_mean_max(x),
        Some(s) => {
            if x.is_empty() {
                return (0.0, 0.0);
            }
            let mut sum = 0f64;
            let mut max = 0f32;
            for &v in x {
                let k = (s * v).max(0.0);
                sum += k as f64;
                if k > max {
                    max = k;
                }
            }
            ((sum / x.len() as f64) as f32, max)
        }
    }
}

/// Strided sample of selection keys (§Perf) into a reused buffer.
fn sample_keys_into(x: &[f32], stride: usize, sign: Option<f32>, keys: &mut Vec<f32>) {
    if stride == 1 {
        // dense sample: the abs/scaled key materialization vectorizes
        // (resize on the warm scratch Vec allocates nothing steady-state)
        let b = super::simd::active();
        keys.resize(x.len(), 0.0);
        match sign {
            None => super::simd::abs_keys(b, x, keys),
            Some(s) => super::simd::scaled_keys(b, x, s, keys),
        }
        return;
    }
    keys.clear();
    match sign {
        None => keys.extend(x.iter().step_by(stride).map(|v| v.abs())),
        Some(s) => keys.extend(x.iter().step_by(stride).map(|v| v * s)),
    }
}

/// Sampling stride for a top-k estimate: keep the target rank's sample
/// count >= ~32 so the quantile noise (~rank^-1/2) stays well inside the
/// 2x safety margin.
fn sample_stride(n: usize, k: usize) -> usize {
    (n / 65_536).min(k / 32).max(1)
}

/// Trim threshold from a strided-sample quantile at twice the target
/// rank: ≥ k survivors w.h.p., ~2k expected.  `None` when the sample's
/// quantile is non-positive (degenerate distribution) — callers fall back
/// to the exact selector.  `keys` is reused scratch.
fn sample_trim_threshold(
    x: &[f32],
    k: usize,
    sign: Option<f32>,
    keys: &mut Vec<f32>,
) -> Option<f32> {
    let stride = sample_stride(x.len(), k);
    sample_keys_into(x, stride, sign, keys);
    if keys.is_empty() {
        return None;
    }
    let rank = (2usize.saturating_mul(k) / stride).min(keys.len() - 1);
    let (_, kth, _) = keys.select_nth_unstable_by(rank, cmp_keys_desc);
    let thr = *kth;
    // ordered compare: a NaN or non-positive quantile means the sample is
    // degenerate — caller falls back to the exact selector
    (thr > 0.0).then_some(thr)
}

/// Exact top-k selection by quickselect (`select_nth_unstable_by`), the
/// radixSelect-baseline of Fig. 3.  Returns exactly `min(k, n)` elements
/// with ascending indices.
pub fn exact_topk(x: &[f32], k: usize, sign: Option<f32>) -> Selection {
    let mut idx = Vec::new();
    let mut out = SparseTensor::default();
    let threshold = exact_topk_core(x, k, sign, &mut idx, &mut out);
    Selection { sparse: out, threshold }
}

/// [`exact_topk`] over reusable scratch.
pub fn exact_topk_into(x: &[f32], k: usize, sign: Option<f32>, s: &mut SelectScratch) -> f32 {
    exact_topk_core(x, k, sign, &mut s.idx, &mut s.out)
}

/// The quickselect core: result in `out` (cleared first), `idx` is the
/// reused permutation buffer; returns the selection threshold.
fn exact_topk_core(
    x: &[f32],
    k: usize,
    sign: Option<f32>,
    idx: &mut Vec<u32>,
    out: &mut SparseTensor,
) -> f32 {
    out.clear();
    let n = x.len();
    if k == 0 || n == 0 {
        return f32::INFINITY;
    }
    if k >= n {
        for (i, &v) in x.iter().enumerate() {
            out.push(i as u32, v);
        }
        return f32::NEG_INFINITY;
    }
    idx.clear();
    idx.extend(0..n as u32);
    // descending by key, NaN last: element k-1 is the kth largest real
    // key after the call
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        cmp_keys_desc(&key_of(x[a as usize], sign), &key_of(x[b as usize], sign))
    });
    let threshold = key_of(x[idx[k - 1] as usize], sign);
    idx[..k].sort_unstable();
    for &i in &idx[..k] {
        out.push(i, x[i as usize]);
    }
    threshold
}

/// Algorithm 2: trimmed top-k.  One stats pass, a descending-ratio scan to
/// find a trim threshold with >= k survivors, then exact top-k on the
/// survivors only.  `eps` is the paper's ratio decrement (0.2).
pub fn trimmed_topk(x: &[f32], k: usize, eps: f32, sign: Option<f32>) -> Selection {
    let mut s = SelectScratch::default();
    let threshold = trimmed_topk_into(x, k, eps, sign, &mut s);
    Selection { sparse: s.take_selected(), threshold }
}

/// [`trimmed_topk`] over reusable scratch: result in
/// [`SelectScratch::selected`], returns the threshold.
pub fn trimmed_topk_into(
    x: &[f32],
    k: usize,
    eps: f32,
    sign: Option<f32>,
    s: &mut SelectScratch,
) -> f32 {
    let n = x.len();
    if k == 0 || n == 0 {
        s.out.clear();
        return f32::INFINITY;
    }
    let SelectScratch { idx, keys, cand, out, .. } = s;
    if k >= n {
        return exact_topk_core(x, k, sign, idx, out);
    }
    let _ = eps; // ratio decrement of the paper's GPU ladder; the host
                 // trim statistic is a sample quantile instead (§Perf)
    // Statistical trim (Alg. 2's essence — a cheap statistic removes the
    // mass of small elements before the exact selector).  The paper's GPU
    // statistic is a mean/max ratio ladder (each rung = one counting
    // kernel); on the host every extra full pass costs as much as the
    // exact selector on ~1M elements, so the trim threshold comes from a
    // strided-sample quantile at twice the target rank: ≥ k survivors
    // w.h.p., ~2k in expectation, verified by the compaction pass.
    let Some(thr) = sample_trim_threshold(x, k, sign, keys) else {
        // degenerate (constant / all-zero / wrong-signed) distribution
        return exact_topk_core(x, k, sign, idx, out);
    };
    // Trim: gather candidate (index, value) pairs, then exact top-k on
    // the candidates (the paper's "radixSelect on the remaining").
    compact_into(x, thr, sign, cand);
    if cand.len() < k {
        // sampling undershot (rare; heavy ties or tiny k): fall back to a
        // trim at the sample's low quantile, then to the full array
        compact_into(x, 0.0, sign, cand);
        if cand.len() < k {
            return exact_topk_core(x, k, sign, idx, out);
        }
    }
    let threshold = exact_topk_core(&cand.values, k, sign, idx, out);
    // candidate positions -> original indices, in place
    for i in out.indices.iter_mut() {
        *i = cand.indices[*i as usize];
    }
    // indices of candidates are ascending, and exact_topk returns ascending
    // positions within candidates, so this is already ascending; keep it
    // defensive anyway.
    if !out.indices.windows(2).all(|w| w[0] < w[1]) {
        let mut pairs: Vec<(u32, f32)> =
            out.indices.iter().copied().zip(out.values.iter().copied()).collect();
        pairs.sort_unstable_by_key(|p| p.0);
        out.clear();
        for (i, v) in pairs {
            out.push(i, v);
        }
    }
    threshold
}

/// `compact` into a reused buffer (sign-dispatched).
fn compact_into(x: &[f32], thr: f32, sign: Option<f32>, out: &mut SparseTensor) {
    match sign {
        None => SparseTensor::compact_above_into(x, thr, out),
        Some(s) => SparseTensor::compact_above_signed_into(x, thr, s, out),
    }
}

/// Algorithm 3: threshold binary search.  Bisects `ratio ∈ [0, 1]` over
/// `thr = mean + ratio (max - mean)` until `k <= nnz <= 2k` (or the
/// interval collapses to `eps`), then compacts everything above the
/// threshold.  Returns between k and 2k elements in the regular case —
/// never exactly-k, by design (the paper trades set-size slack for never
/// running an exact selector).
pub fn threshold_binary_search(
    x: &[f32],
    k: usize,
    p: BinarySearchParams,
    sign: Option<f32>,
) -> Selection {
    let mut s = SelectScratch::default();
    let threshold = threshold_binary_search_into(x, k, p, sign, &mut s);
    Selection { sparse: s.take_selected(), threshold }
}

/// [`threshold_binary_search`] over reusable scratch: result in
/// [`SelectScratch::selected`], returns the threshold.
pub fn threshold_binary_search_into(
    x: &[f32],
    k: usize,
    p: BinarySearchParams,
    sign: Option<f32>,
    s: &mut SelectScratch,
) -> f32 {
    let n = x.len();
    if k == 0 || n == 0 {
        s.out.clear();
        return f32::INFINITY;
    }
    let SelectScratch { idx, keys, ladder, counts, out, .. } = s;
    if k >= n {
        return exact_topk_core(x, k, sign, idx, out);
    }
    // Fast path (§Perf): sample-guided threshold estimation — candidate
    // thresholds from the strided sample at ranks spanning (k, 2k), all
    // verified with ONE sparse counting pass; take the highest whose
    // exact count lands in [k, 2k].
    if let Some((thr, cnt)) = sample_guided_threshold(x, k, sign, keys, counts) {
        compact_into(x, thr, sign, out);
        debug_assert_eq!(out.len(), cnt);
        return thr;
    }
    let (mean, max) = key_stats(x, sign);
    if max <= 0.0 || !mean.is_finite() || !max.is_finite() {
        // degenerate stats: an all-zero / wrong-signed layer (max == 0),
        // or a non-finite gradient poisoning mean/max — the mean..max
        // threshold interpolation is meaningless, so fall back to the
        // exact selector, which is well-defined for every input (NaN
        // keys last, see module docs)
        return exact_topk_core(x, k, sign, idx, out);
    }
    // Fallback: J-way bisection — each counting pass probes `p.probes`
    // interior ratios at once, shrinking the bracket by (probes+1)x per
    // pass — log_{J+1}(1/eps) passes instead of log_2(1/eps).  This is
    // the host-side mirror of the vectorized `threshold_count` kernel,
    // and handles the heavy-tie distributions sampling cannot.
    let probes = p.probes.max(1);
    let (mut l, mut r) = (0.0f32, 1.0f32);
    let mut thr = mean; // ratio-0 fallback: guaranteed >= k survivors for
                        // any non-degenerate distribution (checked below)
    let mut passes = 0;
    'outer: while r - l > p.eps && passes * probes < p.max_iters {
        passes += 1;
        // descending thresholds = ascending ratios reversed
        ladder.clear();
        ladder.extend((0..probes).map(|i| {
            let ratio = r - (r - l) * (i + 1) as f32 / (probes + 1) as f32;
            mean + ratio * (max - mean)
        }));
        crate::tensor::count_above_multi_into(x, ladder, sign, counts);
        for (i, &c) in counts.iter().enumerate() {
            if c >= k && c <= 2 * k {
                thr = ladder[i];
                break 'outer;
            }
        }
        // no direct hit: bracket between the last undershoot (< k) and the
        // first overshoot (> 2k)
        let mut new_r = r;
        let mut new_l = l;
        for (i, &c) in counts.iter().enumerate() {
            let ratio = r - (r - l) * (i + 1) as f32 / (probes + 1) as f32;
            if c < k {
                new_r = ratio;
            } else {
                // c > 2k (c in [k,2k] already returned)
                new_l = ratio;
                break;
            }
        }
        if new_r <= new_l {
            thr = mean + new_l * (max - mean);
            break;
        }
        r = new_r;
        l = new_l;
        thr = mean + l * (max - mean);
    }
    if count(x, thr, sign) < k {
        // interval collapsed on the high side: take the low bound
        let thr_low = mean + l * (max - mean);
        thr = if count(x, thr_low, sign) >= k { thr_low } else { mean };
    }
    compact_into(x, thr, sign, out);
    if out.is_empty() {
        // pathological (e.g. all values equal mean=max): fall back
        return exact_topk_core(x, k, sign, idx, out);
    }
    thr
}

/// Sample-guided Alg. 3 fast path: estimate J candidate thresholds at
/// sample ranks spanning (k, 2k), verify all of them exactly in one
/// sparse counting pass, return the best `(threshold, exact count)`.
/// `None` when k is too small for reliable sampling or no candidate
/// lands in [k, 2k] (heavy ties) — the caller bisects instead.  `keys`
/// and `counts` are reused scratch.
fn sample_guided_threshold(
    x: &[f32],
    k: usize,
    sign: Option<f32>,
    keys: &mut Vec<f32>,
    counts: &mut Vec<usize>,
) -> Option<(f32, usize)> {
    let n = x.len();
    if k < 64 || n < 8_192 {
        return None;
    }
    let stride = sample_stride(n, k);
    sample_keys_into(x, stride, sign, keys);
    let m = keys.len();
    // top (2.4k/stride) sample keys, sorted descending: rank r in this
    // prefix estimates a threshold with ~r·stride true survivors
    let prefix = ((24 * k / stride) / 10 + 1).min(m - 1);
    keys.select_nth_unstable_by(prefix, cmp_keys_desc);
    keys.truncate(prefix + 1);
    keys.sort_unstable_by(cmp_keys_desc);
    const J: usize = 8;
    let mut thrs = [0f32; J];
    let mut nt = 0;
    for i in 0..J {
        // expected counts from ~1.1k up to ~1.9k
        let target = (1.1 + 0.8 * i as f64 / (J - 1) as f64) * k as f64;
        let r = ((target / stride as f64) as usize).min(keys.len() - 1);
        let t = keys[r];
        // the quantile can be NaN (NaN keys sort last, so a deep rank can
        // reach them) — a NaN candidate threshold must stop the ladder
        // exactly like a non-positive one
        if t.is_nan() || t <= 0.0 {
            break;
        }
        if nt == 0 || thrs[nt - 1] != t {
            thrs[nt] = t;
            nt += 1;
        }
    }
    if nt == 0 {
        return None;
    }
    crate::tensor::count_above_multi_sparse_into(x, &thrs[..nt], sign, counts);
    let pick = counts.iter().position(|&c| c >= k && c <= 2 * k)?;
    Some((thrs[pick], counts[pick]))
}

/// §5.2.2 sampled-threshold optimization: run the binary search only every
/// `interval` calls and reuse the cached threshold in between (one
/// compaction pass, zero count_nonzero passes).  Per-layer state.
#[derive(Clone, Debug)]
pub struct CachedThresholdSelector {
    pub interval: usize,
    pub params: BinarySearchParams,
    counter: usize,
    cached_thr: Option<f32>,
}

impl CachedThresholdSelector {
    pub fn new(interval: usize, params: BinarySearchParams) -> Self {
        assert!(interval >= 1);
        CachedThresholdSelector { interval, params, counter: 0, cached_thr: None }
    }

    /// True if the next call will run a full binary search.  The cache
    /// counts as cold when it holds no threshold *or* a non-finite one
    /// (an exact-fallback sentinel such as ±∞, or NaN after a degenerate
    /// step) — reusing those could never produce a k-sized set.
    pub fn will_search(&self) -> bool {
        self.counter == 0 || !self.cached_thr.is_some_and(f32::is_finite)
    }

    pub fn select(&mut self, x: &[f32], k: usize, sign: Option<f32>) -> Selection {
        // no unwrap: a cold cache (None / non-finite, e.g. right after an
        // elastic reshape reset) takes the search arm structurally
        let reusable = if self.will_search() { None } else { self.cached_thr };
        let out = match reusable {
            Some(thr) => {
                let sparse = compact(x, thr, sign);
                if sparse.is_empty() || sparse.len() > 4 * k {
                    // distribution drifted under the cached threshold (the
                    // paper's "far more than expected" case): re-search
                    let sel = threshold_binary_search(x, k, self.params, sign);
                    self.cached_thr = Some(sel.threshold);
                    self.counter = 0;
                    sel
                } else {
                    Selection { sparse, threshold: thr }
                }
            }
            None => {
                let sel = threshold_binary_search(x, k, self.params, sign);
                self.cached_thr = Some(sel.threshold);
                sel
            }
        };
        self.counter = (self.counter + 1) % self.interval;
        out
    }

    pub fn reset(&mut self) {
        self.counter = 0;
        self.cached_thr = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};
    use crate::util::rng::Pcg32;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        let mut v = vec![0f32; n];
        r.fill_normal(&mut v, 1.0);
        v
    }

    fn brute_topk_keys(x: &[f32], k: usize) -> Vec<f32> {
        let mut keys: Vec<f32> = x.iter().map(|v| v.abs()).collect();
        keys.sort_by(cmp_keys_desc);
        keys[..k.min(keys.len())].to_vec()
    }

    #[test]
    fn exact_topk_matches_brute_force() {
        let x = randn(1000, 1);
        let k = 10;
        let sel = exact_topk(&x, k, None);
        let mut got: Vec<f32> = sel.sparse.values.iter().map(|v| v.abs()).collect();
        got.sort_by(cmp_keys_desc);
        assert_eq!(got, brute_topk_keys(&x, k));
    }

    #[test]
    fn exact_topk_k_geq_n_returns_all() {
        let x = [1.0, -2.0];
        let sel = exact_topk(&x, 5, None);
        assert_eq!(sel.sparse.len(), 2);
    }

    #[test]
    fn exact_topk_k_zero() {
        assert_eq!(exact_topk(&[1.0], 0, None).sparse.len(), 0);
    }

    #[test]
    fn exact_topk_indices_ascending() {
        let x = randn(512, 2);
        let sel = exact_topk(&x, 32, None);
        assert!(sel.sparse.indices.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn exact_topk_signed_selects_one_sign() {
        let x = randn(1024, 3);
        let pos = exact_topk(&x, 16, Some(1.0));
        assert!(pos.sparse.values.iter().all(|&v| v > 0.0));
        let neg = exact_topk(&x, 16, Some(-1.0));
        assert!(neg.sparse.values.iter().all(|&v| v < 0.0));
    }

    #[test]
    fn trimmed_topk_equals_exact_topk_as_set() {
        let x = randn(4096, 4);
        let k = 40;
        let a = exact_topk(&x, k, None);
        let b = trimmed_topk(&x, k, 0.2, None);
        assert_eq!(b.sparse.len(), k);
        // same multiset of |values| (ties may swap indices)
        let mut ka: Vec<f32> = a.sparse.values.iter().map(|v| v.abs()).collect();
        let mut kb: Vec<f32> = b.sparse.values.iter().map(|v| v.abs()).collect();
        ka.sort_by(f32::total_cmp);
        kb.sort_by(f32::total_cmp);
        assert_eq!(ka, kb);
    }

    #[test]
    fn trimmed_topk_constant_array_falls_back() {
        let x = vec![0.5f32; 256];
        let sel = trimmed_topk(&x, 16, 0.2, None);
        assert_eq!(sel.sparse.len(), 16);
    }

    #[test]
    fn trimmed_topk_zeros() {
        let x = vec![0f32; 256];
        let sel = trimmed_topk(&x, 16, 0.2, None);
        assert_eq!(sel.sparse.len(), 16); // exact fallback picks zeros
    }

    #[test]
    fn binary_search_returns_between_k_and_2k_typically() {
        let x = randn(65536, 5);
        let k = 64;
        let sel = threshold_binary_search(&x, k, BinarySearchParams::default(), None);
        assert!(
            sel.sparse.len() >= k && sel.sparse.len() <= 2 * k,
            "got {}",
            sel.sparse.len()
        );
    }

    #[test]
    fn binary_search_never_returns_empty_on_nonzero_input() {
        let x = randn(1024, 6);
        for k in [1usize, 3, 17, 100] {
            let sel = threshold_binary_search(&x, k, BinarySearchParams::default(), None);
            assert!(sel.sparse.len() >= k.min(x.len()), "k={k} got {}", sel.sparse.len());
        }
    }

    #[test]
    fn binary_search_signed_mode() {
        let x = randn(8192, 7);
        let sel =
            threshold_binary_search(&x, 32, BinarySearchParams::default(), Some(-1.0));
        assert!(sel.sparse.len() >= 32);
        assert!(sel.sparse.values.iter().all(|&v| v < 0.0));
    }

    #[test]
    fn cached_selector_reuses_threshold() {
        let mut sel = CachedThresholdSelector::new(5, BinarySearchParams::default());
        let x = randn(4096, 8);
        assert!(sel.will_search());
        let a = sel.select(&x, 16, None);
        assert!(!sel.will_search());
        let b = sel.select(&x, 16, None);
        assert_eq!(a.threshold, b.threshold);
        // after interval calls, searches again
        for _ in 0..3 {
            sel.select(&x, 16, None);
        }
        assert!(sel.will_search());
    }

    #[test]
    fn cached_selector_recovers_from_drift() {
        let mut sel = CachedThresholdSelector::new(5, BinarySearchParams::default());
        let x = randn(1024, 9);
        sel.select(&x, 16, None);
        // residual collapses to tiny values: cached threshold selects none
        let y = vec![1e-12f32; 1024];
        let out = sel.select(&y, 16, None);
        assert!(out.sparse.len() >= 16);
    }

    // ---------------------------------------------------------- properties

    #[test]
    fn prop_exact_topk_is_exact() {
        check(60, |g| {
            let n = g.size(1..4000);
            let k = g.size(1..n.max(2));
            let x = g.vec_normal(n, 1.0);
            let sel = exact_topk(&x, k, None);
            ensure(sel.sparse.len() == k.min(n), "wrong size")?;
            // every selected key >= every unselected key
            let min_sel = sel
                .sparse
                .values
                .iter()
                .map(|v| v.abs())
                .fold(f32::INFINITY, f32::min);
            let selset: std::collections::HashSet<u32> =
                sel.sparse.indices.iter().copied().collect();
            for (i, v) in x.iter().enumerate() {
                if !selset.contains(&(i as u32)) {
                    ensure(
                        v.abs() <= min_sel + 1e-6,
                        format!("unselected {} > min selected {}", v.abs(), min_sel),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_trimmed_matches_exact_keys() {
        check(40, |g| {
            let n = g.size(16..20000);
            let k = g.size(1..(n / 4).max(2));
            let x = g.vec_normal(n, 2.0);
            let a = exact_topk(&x, k, None);
            let b = trimmed_topk(&x, k, 0.2, None);
            ensure(b.sparse.len() == k, format!("trimmed len {} != {k}", b.sparse.len()))?;
            let sum_a: f64 = a.sparse.values.iter().map(|v| v.abs() as f64).sum();
            let sum_b: f64 = b.sparse.values.iter().map(|v| v.abs() as f64).sum();
            crate::util::proptest::ensure_close(sum_a, sum_b, 1e-5, "topk key mass")
        });
    }

    #[test]
    fn prop_binary_search_superset_of_topk_threshold() {
        check(40, |g| {
            let n = g.size(64..30000);
            let k = g.size(1..(n / 8).max(2));
            let x = g.vec_normal(n, 1.0);
            let sel = threshold_binary_search(&x, k, BinarySearchParams::default(), None);
            // all returned satisfy |v| > thr, and count >= k
            ensure(sel.sparse.len() >= k, format!("{} < k={k}", sel.sparse.len()))?;
            for &v in &sel.sparse.values {
                ensure(v.abs() > sel.threshold, "value below threshold")?;
            }
            Ok(())
        });
    }
}
