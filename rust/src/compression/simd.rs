//! SIMD selection/quantization kernels — the compute half of the paper's
//! Fig. 3 argument, vectorized.
//!
//! Top-k threshold selection is RedSync's compute hot spot: after the
//! zero-copy PR the remaining per-step cost is the scalar walks over the
//! residual (abs-key + threshold compare + compress-store), the
//! `[len][idx…][bits…]` value packing, and the §5.4 scatter-add apply.
//! This module owns `std::arch` SSE2/AVX2 implementations of exactly
//! those walks behind runtime feature detection — zero new dependencies,
//! `unsafe` confined to this file.
//!
//! **The scalar path is the bit-identity oracle.**  Every kernel exists
//! in a scalar form and the SIMD forms are constructed to be
//! bit-identical to it:
//!
//! * threshold compares are IEEE *ordered* `>` in both worlds (`v.abs() >
//!   thr` scalar, `_CMP_GT_OQ` / `cmpgt` vector) — a NaN key never
//!   qualifies on either path, which is also the selection NaN policy
//!   (see `select.rs`);
//! * `|x|` is a sign-bit mask on both paths (`f32::abs` is defined as
//!   exactly that), and the signed key `x * sign` with `sign = ±1.0` is
//!   the same single IEEE multiply;
//! * survivors are copied verbatim (no arithmetic on the values), in
//!   ascending index order on both paths;
//! * the apply walk computes the per-element product `scale * v` lanewise
//!   (IEEE multiply is lanewise-identical to scalar) and performs the
//!   `dense[i] += …` additions strictly in message order, so float
//!   summation order never changes;
//! * value packing is a bit copy (`f32::to_bits` *is* the transmute).
//!
//! Quantization's mean (`Σ values / k`) deliberately stays scalar:
//! a lane-parallel sum would change float accumulation order and break
//! the cross-engine bit-identity pins.
//!
//! **Dispatch.**  [`Backend::detect`] picks the widest instruction set
//! the host supports (`is_x86_feature_detected!`), demotable to scalar
//! with the `REDSYNC_NO_SIMD=1` env knob (CI runs the suite both ways).
//! [`active`] caches the decision process-wide; selectors and packers
//! read it once at plan time and the worker records it in
//! `TrainReport::simd_backend`.  Every kernel also takes an explicit
//! [`Backend`] so tests and the `--hotpath-smoke` A/B can pin
//! scalar-vs-SIMD parity and throughput side by side.

use crate::tensor::SparseTensor;
use std::sync::OnceLock;

/// Instruction-set backend for the selection/pack/apply kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable scalar loops — the bit-identity oracle.
    Scalar,
    /// 4-lane `std::arch` x86-64 SSE2 (baseline on every x86-64).
    Sse2,
    /// 8-lane `std::arch` x86-64 AVX2.
    Avx2,
}

static ACTIVE: OnceLock<Backend> = OnceLock::new();

/// The process-wide backend, detected once on first use ("plan time"):
/// the widest supported instruction set, unless `REDSYNC_NO_SIMD` is
/// set to anything but `0`/empty.
pub fn active() -> Backend {
    *ACTIVE.get_or_init(Backend::detect)
}

impl Backend {
    /// Runtime detection: scalar when `REDSYNC_NO_SIMD` forces it,
    /// otherwise the widest feature set the CPU reports.
    pub fn detect() -> Backend {
        if scalar_forced() {
            return Backend::Scalar;
        }
        Backend::widest_hardware()
    }

    /// The widest backend this CPU supports, ignoring the env knob.
    pub fn widest_hardware() -> Backend {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Backend::Avx2;
            }
            if std::arch::is_x86_feature_detected!("sse2") {
                return Backend::Sse2;
            }
        }
        Backend::Scalar
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Sse2 => "sse2",
            Backend::Avx2 => "avx2",
        }
    }
}

fn scalar_forced() -> bool {
    std::env::var("REDSYNC_NO_SIMD").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Every backend this host can run, scalar first — what the parity
/// tests and the `--hotpath-smoke` per-backend rows iterate over
/// (independent of the env knob, so a scalar-forced run still *tests*
/// the vector kernels it refuses to *use*).
pub fn available() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("sse2") {
            v.push(Backend::Sse2);
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(Backend::Avx2);
        }
    }
    v
}

// ---------------------------------------------------------------------
// Compress-store: threshold partition of a dense residual
// ---------------------------------------------------------------------

/// Append `(i, x[i])` for every `|x[i]| > thr` to `out`, ascending — the
/// trimmed-threshold partition pass.  NaN keys never qualify (ordered
/// compare) on any backend.
pub fn compact_gt_abs(b: Backend, x: &[f32], thr: f32, out: &mut SparseTensor) {
    match b {
        Backend::Scalar => compact_gt_abs_scalar(x, thr, out),
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe { x86::compact_gt_abs_sse2(x, thr, out) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { x86::compact_gt_abs_avx2(x, thr, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => compact_gt_abs_scalar(x, thr, out),
    }
}

/// Signed flavor for quantized RGC: keeps `x[i] * sign > thr`
/// (`sign = ±1.0`), ascending.
pub fn compact_gt_signed(b: Backend, x: &[f32], thr: f32, sign: f32, out: &mut SparseTensor) {
    match b {
        Backend::Scalar => compact_gt_signed_scalar(x, thr, sign, out),
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe { x86::compact_gt_signed_sse2(x, thr, sign, out) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { x86::compact_gt_signed_avx2(x, thr, sign, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => compact_gt_signed_scalar(x, thr, sign, out),
    }
}

fn compact_gt_abs_scalar(x: &[f32], thr: f32, out: &mut SparseTensor) {
    for (i, &v) in x.iter().enumerate() {
        if v.abs() > thr {
            out.push(i as u32, v);
        }
    }
}

fn compact_gt_signed_scalar(x: &[f32], thr: f32, sign: f32, out: &mut SparseTensor) {
    for (i, &v) in x.iter().enumerate() {
        if v * sign > thr {
            out.push(i as u32, v);
        }
    }
}

// ---------------------------------------------------------------------
// Threshold counting (the Alg. 3 probe passes)
// ---------------------------------------------------------------------

/// `#{ i : |x[i]| > thr }` — exact on every backend (popcount of the
/// compare mask).
pub fn count_gt_abs(b: Backend, x: &[f32], thr: f32) -> usize {
    match b {
        Backend::Scalar => x.iter().filter(|v| v.abs() > thr).count(),
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe { x86::count_gt_abs_sse2(x, thr) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { x86::count_gt_abs_avx2(x, thr) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => x.iter().filter(|v| v.abs() > thr).count(),
    }
}

/// `#{ i : x[i] * sign > thr }` for `sign = ±1.0`.
pub fn count_gt_signed(b: Backend, x: &[f32], thr: f32, sign: f32) -> usize {
    match b {
        Backend::Scalar => x.iter().filter(|&&v| v * sign > thr).count(),
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe { x86::count_gt_signed_sse2(x, thr, sign) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { x86::count_gt_signed_avx2(x, thr, sign) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => x.iter().filter(|&&v| v * sign > thr).count(),
    }
}

/// `#{ i : keys[i] > thr }` over pre-materialized keys (the blocked
/// multi-threshold counting pass reuses one key tile for J thresholds).
pub fn count_gt(b: Backend, keys: &[f32], thr: f32) -> usize {
    match b {
        Backend::Scalar => keys.iter().filter(|&&a| a > thr).count(),
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe { x86::count_gt_sse2(keys, thr) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { x86::count_gt_avx2(keys, thr) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => keys.iter().filter(|&&a| a > thr).count(),
    }
}

// ---------------------------------------------------------------------
// Key materialization (sampling / blocked counting tiles)
// ---------------------------------------------------------------------

/// `out[i] = |x[i]|` (slices must have equal length).
pub fn abs_keys(b: Backend, x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    match b {
        Backend::Scalar => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = v.abs();
            }
        }
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe { x86::abs_keys_sse2(x, out) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { x86::abs_keys_avx2(x, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = v.abs();
            }
        }
    }
}

/// `out[i] = x[i] * sign` (slices must have equal length).
pub fn scaled_keys(b: Backend, x: &[f32], sign: f32, out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    match b {
        Backend::Scalar => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = v * sign;
            }
        }
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe { x86::scaled_keys_sse2(x, sign, out) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { x86::scaled_keys_avx2(x, sign, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = v * sign;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Wire packing: the [len][idx…][bits…] value section
// ---------------------------------------------------------------------

/// Append `v.to_bits()` for every value — the value section of a plain
/// message.  `to_bits` is a transmute, so the vector form is one bulk
/// bit copy; NaN payloads, -0.0 and denormals survive exactly on every
/// backend.
pub fn extend_value_bits(b: Backend, values: &[f32], out: &mut Vec<u32>) {
    match b {
        Backend::Scalar => out.extend(values.iter().map(|v| v.to_bits())),
        // f32 and u32 share size and alignment; a bulk copy of the raw
        // words is exactly per-element `to_bits`.
        _ => out.extend_from_slice(f32_words(values)),
    }
}

// ---------------------------------------------------------------------
// Apply: the §5.4 scatter-add decompression walk
// ---------------------------------------------------------------------

/// `dense[idx[i]] += scale * from_bits(bits[i])` in message order — the
/// borrowed-view apply walk.  The products are computed lanewise (IEEE
/// multiply is per-lane identical to scalar) and added strictly in
/// ascending message order, so the result is bit-identical to the
/// scalar walk.  Out-of-range indices panic on every backend (bounds
/// checks are kept — malformed blobs must not scribble).
pub fn scatter_add_bits(b: Backend, indices: &[u32], bits: &[u32], dense: &mut [f32], scale: f32) {
    assert_eq!(indices.len(), bits.len());
    match b {
        Backend::Scalar => {
            for (&i, &w) in indices.iter().zip(bits) {
                dense[i as usize] += scale * f32::from_bits(w);
            }
        }
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe { x86::scatter_add_bits_sse2(indices, bits, dense, scale) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { x86::scatter_add_bits_avx2(indices, bits, dense, scale) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => {
            for (&i, &w) in indices.iter().zip(bits) {
                dense[i as usize] += scale * f32::from_bits(w);
            }
        }
    }
}

/// Owned-tensor flavor of [`scatter_add_bits`]: `dense[idx[i]] +=
/// scale * values[i]`, same ordering and bounds-check guarantees.
pub fn scatter_add_values(
    b: Backend,
    indices: &[u32],
    values: &[f32],
    dense: &mut [f32],
    scale: f32,
) {
    assert_eq!(indices.len(), values.len());
    match b {
        Backend::Scalar => {
            for (&i, &v) in indices.iter().zip(values) {
                dense[i as usize] += scale * v;
            }
        }
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => unsafe {
            x86::scatter_add_bits_sse2(indices, f32_words(values), dense, scale)
        },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe {
            x86::scatter_add_bits_avx2(indices, f32_words(values), dense, scale)
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => {
            for (&i, &v) in indices.iter().zip(values) {
                dense[i as usize] += scale * v;
            }
        }
    }
}

/// View an f32 slice as its raw u32 words (same size and alignment;
/// the inverse of the wire's `from_bits` decode).
fn f32_words(values: &[f32]) -> &[u32] {
    unsafe { std::slice::from_raw_parts(values.as_ptr().cast::<u32>(), values.len()) }
}

// ---------------------------------------------------------------------
// x86-64 kernels
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::SparseTensor;
    use std::arch::x86_64::*;

    /// # Safety
    /// Requires AVX2 (the dispatcher checks at detection time).
    #[target_feature(enable = "avx2")]
    pub unsafe fn compact_gt_abs_avx2(x: &[f32], thr: f32, out: &mut SparseTensor) {
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let t = _mm256_set1_ps(thr);
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            let m = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_GT_OQ>(_mm256_and_ps(v, absmask), t));
            push_lanes(x, i, m as u32, out);
            i += 8;
        }
        for (j, &v) in x.iter().enumerate().skip(i) {
            if v.abs() > thr {
                out.push(j as u32, v);
            }
        }
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn compact_gt_signed_avx2(x: &[f32], thr: f32, sign: f32, out: &mut SparseTensor) {
        let s = _mm256_set1_ps(sign);
        let t = _mm256_set1_ps(thr);
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            let m = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_GT_OQ>(_mm256_mul_ps(v, s), t));
            push_lanes(x, i, m as u32, out);
            i += 8;
        }
        for (j, &v) in x.iter().enumerate().skip(i) {
            if v * sign > thr {
                out.push(j as u32, v);
            }
        }
    }

    /// # Safety
    /// Requires SSE2.
    #[target_feature(enable = "sse2")]
    pub unsafe fn compact_gt_abs_sse2(x: &[f32], thr: f32, out: &mut SparseTensor) {
        let absmask = _mm_castsi128_ps(_mm_set1_epi32(0x7FFF_FFFF));
        let t = _mm_set1_ps(thr);
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            let m = _mm_movemask_ps(_mm_cmpgt_ps(_mm_and_ps(v, absmask), t));
            push_lanes(x, i, m as u32, out);
            i += 4;
        }
        for (j, &v) in x.iter().enumerate().skip(i) {
            if v.abs() > thr {
                out.push(j as u32, v);
            }
        }
    }

    /// # Safety
    /// Requires SSE2.
    #[target_feature(enable = "sse2")]
    pub unsafe fn compact_gt_signed_sse2(x: &[f32], thr: f32, sign: f32, out: &mut SparseTensor) {
        let s = _mm_set1_ps(sign);
        let t = _mm_set1_ps(thr);
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            let m = _mm_movemask_ps(_mm_cmpgt_ps(_mm_mul_ps(v, s), t));
            push_lanes(x, i, m as u32, out);
            i += 4;
        }
        for (j, &v) in x.iter().enumerate().skip(i) {
            if v * sign > thr {
                out.push(j as u32, v);
            }
        }
    }

    /// Compress-store the survivors of one compare mask: walk the set
    /// bits in lane order (= ascending index) and push verbatim values.
    #[inline(always)]
    fn push_lanes(x: &[f32], base: usize, mut mask: u32, out: &mut SparseTensor) {
        while mask != 0 {
            let l = mask.trailing_zeros() as usize;
            out.push((base + l) as u32, x[base + l]);
            mask &= mask - 1;
        }
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn count_gt_abs_avx2(x: &[f32], thr: f32) -> usize {
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let t = _mm256_set1_ps(thr);
        let n = x.len();
        let mut cnt = 0usize;
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            let m = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_GT_OQ>(_mm256_and_ps(v, absmask), t));
            cnt += (m as u32).count_ones() as usize;
            i += 8;
        }
        cnt + x[i..].iter().filter(|v| v.abs() > thr).count()
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn count_gt_signed_avx2(x: &[f32], thr: f32, sign: f32) -> usize {
        let s = _mm256_set1_ps(sign);
        let t = _mm256_set1_ps(thr);
        let n = x.len();
        let mut cnt = 0usize;
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            let m = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_GT_OQ>(_mm256_mul_ps(v, s), t));
            cnt += (m as u32).count_ones() as usize;
            i += 8;
        }
        cnt + x[i..].iter().filter(|&&v| v * sign > thr).count()
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn count_gt_avx2(keys: &[f32], thr: f32) -> usize {
        let t = _mm256_set1_ps(thr);
        let n = keys.len();
        let mut cnt = 0usize;
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(keys.as_ptr().add(i));
            let m = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_GT_OQ>(v, t));
            cnt += (m as u32).count_ones() as usize;
            i += 8;
        }
        cnt + keys[i..].iter().filter(|&&a| a > thr).count()
    }

    /// # Safety
    /// Requires SSE2.
    #[target_feature(enable = "sse2")]
    pub unsafe fn count_gt_abs_sse2(x: &[f32], thr: f32) -> usize {
        let absmask = _mm_castsi128_ps(_mm_set1_epi32(0x7FFF_FFFF));
        let t = _mm_set1_ps(thr);
        let n = x.len();
        let mut cnt = 0usize;
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            let m = _mm_movemask_ps(_mm_cmpgt_ps(_mm_and_ps(v, absmask), t));
            cnt += (m as u32).count_ones() as usize;
            i += 4;
        }
        cnt + x[i..].iter().filter(|v| v.abs() > thr).count()
    }

    /// # Safety
    /// Requires SSE2.
    #[target_feature(enable = "sse2")]
    pub unsafe fn count_gt_signed_sse2(x: &[f32], thr: f32, sign: f32) -> usize {
        let s = _mm_set1_ps(sign);
        let t = _mm_set1_ps(thr);
        let n = x.len();
        let mut cnt = 0usize;
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            let m = _mm_movemask_ps(_mm_cmpgt_ps(_mm_mul_ps(v, s), t));
            cnt += (m as u32).count_ones() as usize;
            i += 4;
        }
        cnt + x[i..].iter().filter(|&&v| v * sign > thr).count()
    }

    /// # Safety
    /// Requires SSE2.
    #[target_feature(enable = "sse2")]
    pub unsafe fn count_gt_sse2(keys: &[f32], thr: f32) -> usize {
        let t = _mm_set1_ps(thr);
        let n = keys.len();
        let mut cnt = 0usize;
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(keys.as_ptr().add(i));
            let m = _mm_movemask_ps(_mm_cmpgt_ps(v, t));
            cnt += (m as u32).count_ones() as usize;
            i += 4;
        }
        cnt + keys[i..].iter().filter(|&&a| a > thr).count()
    }

    /// # Safety
    /// Requires AVX2; `x.len() == out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn abs_keys_avx2(x: &[f32], out: &mut [f32]) {
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_and_ps(v, absmask));
            i += 8;
        }
        for (o, &v) in out[i..].iter_mut().zip(&x[i..]) {
            *o = v.abs();
        }
    }

    /// # Safety
    /// Requires AVX2; `x.len() == out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scaled_keys_avx2(x: &[f32], sign: f32, out: &mut [f32]) {
        let s = _mm256_set1_ps(sign);
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(v, s));
            i += 8;
        }
        for (o, &v) in out[i..].iter_mut().zip(&x[i..]) {
            *o = v * sign;
        }
    }

    /// # Safety
    /// Requires SSE2; `x.len() == out.len()`.
    #[target_feature(enable = "sse2")]
    pub unsafe fn abs_keys_sse2(x: &[f32], out: &mut [f32]) {
        let absmask = _mm_castsi128_ps(_mm_set1_epi32(0x7FFF_FFFF));
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_and_ps(v, absmask));
            i += 4;
        }
        for (o, &v) in out[i..].iter_mut().zip(&x[i..]) {
            *o = v.abs();
        }
    }

    /// # Safety
    /// Requires SSE2; `x.len() == out.len()`.
    #[target_feature(enable = "sse2")]
    pub unsafe fn scaled_keys_sse2(x: &[f32], sign: f32, out: &mut [f32]) {
        let s = _mm_set1_ps(sign);
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(x.as_ptr().add(i));
            _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(v, s));
            i += 4;
        }
        for (o, &v) in out[i..].iter_mut().zip(&x[i..]) {
            *o = v * sign;
        }
    }

    /// # Safety
    /// Requires AVX2; `indices.len() == bits.len()`.  Dense indexing
    /// stays bounds-checked (panics on out-of-range, like scalar).
    #[target_feature(enable = "avx2")]
    pub unsafe fn scatter_add_bits_avx2(
        indices: &[u32],
        bits: &[u32],
        dense: &mut [f32],
        scale: f32,
    ) {
        let s = _mm256_set1_ps(scale);
        let mut prod = [0f32; 8];
        let n = indices.len();
        let mut i = 0;
        while i + 8 <= n {
            // the wire words ARE f32 bit patterns: a vector load of the
            // u32 slice is `from_bits` on every lane
            let v = _mm256_loadu_ps(bits.as_ptr().add(i).cast::<f32>());
            _mm256_storeu_ps(prod.as_mut_ptr(), _mm256_mul_ps(v, s));
            for (l, &p) in prod.iter().enumerate() {
                dense[indices[i + l] as usize] += p;
            }
            i += 8;
        }
        for (&ix, &w) in indices[i..].iter().zip(&bits[i..]) {
            dense[ix as usize] += scale * f32::from_bits(w);
        }
    }

    /// # Safety
    /// Requires SSE2; `indices.len() == bits.len()`.
    #[target_feature(enable = "sse2")]
    pub unsafe fn scatter_add_bits_sse2(
        indices: &[u32],
        bits: &[u32],
        dense: &mut [f32],
        scale: f32,
    ) {
        let s = _mm_set1_ps(scale);
        let mut prod = [0f32; 4];
        let n = indices.len();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm_loadu_ps(bits.as_ptr().add(i).cast::<f32>());
            _mm_storeu_ps(prod.as_mut_ptr(), _mm_mul_ps(v, s));
            for (l, &p) in prod.iter().enumerate() {
                dense[indices[i + l] as usize] += p;
            }
            i += 4;
        }
        for (&ix, &w) in indices[i..].iter().zip(&bits[i..]) {
            dense[ix as usize] += scale * f32::from_bits(w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Random data salted with every special the wire can carry.
    fn specials(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        let mut v = vec![0f32; n];
        r.fill_normal(&mut v, 1.0);
        let salt = [
            f32::NAN,
            -f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0,
            0.0,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            1e-42, // denormal
            f32::MAX,
            f32::MIN,
        ];
        for (k, &s) in salt.iter().enumerate() {
            let at = (k * 37 + 5) % n.max(1);
            v[at] = s;
        }
        v
    }

    fn eq_bits(a: &SparseTensor, b: &SparseTensor) -> bool {
        a.indices == b.indices
            && a.values.len() == b.values.len()
            && a.values.iter().zip(&b.values).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn backend_detect_and_names() {
        let b = Backend::detect();
        assert!(!b.name().is_empty());
        let avail = available();
        assert_eq!(avail[0], Backend::Scalar);
        // the active backend is always runnable here
        assert!(available().contains(&active()) || active() == Backend::Scalar);
    }

    #[test]
    fn env_knob_forces_scalar() {
        // detect() (not active(): the cache must stay untouched) honors
        // the knob both ways
        std::env::set_var("REDSYNC_NO_SIMD", "1");
        assert_eq!(Backend::detect(), Backend::Scalar);
        std::env::set_var("REDSYNC_NO_SIMD", "0");
        assert_eq!(Backend::detect(), Backend::widest_hardware());
        std::env::remove_var("REDSYNC_NO_SIMD");
        assert_eq!(Backend::detect(), Backend::widest_hardware());
    }

    #[test]
    fn compact_parity_all_backends() {
        for seed in 0..6u64 {
            let x = specials(257 + seed as usize * 13, seed);
            for thr in [0.0f32, 0.5, -1.0, f32::NAN, f32::INFINITY] {
                let mut oracle = SparseTensor::default();
                compact_gt_abs(Backend::Scalar, &x, thr, &mut oracle);
                // NaN values never qualify under an ordered compare
                assert!(oracle.values.iter().all(|v| !v.is_nan()));
                for &b in &available() {
                    let mut got = SparseTensor::default();
                    compact_gt_abs(b, &x, thr, &mut got);
                    assert!(eq_bits(&oracle, &got), "abs backend {b:?} thr {thr}");
                }
                for sign in [1.0f32, -1.0] {
                    let mut oracle = SparseTensor::default();
                    compact_gt_signed(Backend::Scalar, &x, thr, sign, &mut oracle);
                    for &b in &available() {
                        let mut got = SparseTensor::default();
                        compact_gt_signed(b, &x, thr, sign, &mut got);
                        assert!(eq_bits(&oracle, &got), "signed backend {b:?} thr {thr}");
                    }
                }
            }
        }
    }

    #[test]
    fn count_parity_all_backends() {
        for seed in 0..6u64 {
            let x = specials(511 + seed as usize * 7, 100 + seed);
            for thr in [0.0f32, 0.3, 2.0, f32::NAN] {
                let want_abs = count_gt_abs(Backend::Scalar, &x, thr);
                let want_plain = count_gt(Backend::Scalar, &x, thr);
                for &b in &available() {
                    assert_eq!(count_gt_abs(b, &x, thr), want_abs, "{b:?} abs thr {thr}");
                    assert_eq!(count_gt(b, &x, thr), want_plain, "{b:?} plain thr {thr}");
                    for sign in [1.0f32, -1.0] {
                        assert_eq!(
                            count_gt_signed(b, &x, thr, sign),
                            count_gt_signed(Backend::Scalar, &x, thr, sign),
                            "{b:?} signed thr {thr}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn keys_parity_all_backends() {
        let x = specials(301, 7);
        let mut oracle = vec![0f32; x.len()];
        abs_keys(Backend::Scalar, &x, &mut oracle);
        for &b in &available() {
            let mut got = vec![0f32; x.len()];
            abs_keys(b, &x, &mut got);
            assert!(
                oracle.iter().zip(&got).all(|(a, c)| a.to_bits() == c.to_bits()),
                "abs keys {b:?}"
            );
            for sign in [1.0f32, -1.0] {
                let mut want = vec![0f32; x.len()];
                scaled_keys(Backend::Scalar, &x, sign, &mut want);
                let mut got = vec![0f32; x.len()];
                scaled_keys(b, &x, sign, &mut got);
                assert!(
                    want.iter().zip(&got).all(|(a, c)| a.to_bits() == c.to_bits()),
                    "scaled keys {b:?} sign {sign}"
                );
            }
        }
    }

    #[test]
    fn value_bits_parity_all_backends() {
        let x = specials(101, 9);
        let mut oracle = vec![0xFEEDu32];
        extend_value_bits(Backend::Scalar, &x, &mut oracle);
        assert_eq!(oracle.len(), 1 + x.len());
        for &b in &available() {
            let mut got = vec![0xFEEDu32];
            extend_value_bits(b, &x, &mut got);
            assert_eq!(oracle, got, "value bits {b:?}");
        }
    }

    #[test]
    fn scatter_parity_all_backends() {
        let mut r = Pcg32::seeded(11);
        let vals = specials(97, 13);
        let dim = 200usize;
        // ascending unique indices, like every wire message
        let mut indices: Vec<u32> = Vec::new();
        let mut at = 0u32;
        for _ in 0..vals.len() {
            at += 1 + (r.next_u32() % 2);
            indices.push(at % dim as u32);
        }
        indices.sort_unstable();
        indices.dedup();
        let vals = &vals[..indices.len()];
        let bits: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
        let mut init = vec![0f32; dim];
        r.fill_normal(&mut init, 0.5);
        for scale in [1.0f32, -0.125, 0.3] {
            let mut oracle = init.clone();
            scatter_add_bits(Backend::Scalar, &indices, &bits, &mut oracle, scale);
            for &b in &available() {
                let mut got = init.clone();
                scatter_add_bits(b, &indices, &bits, &mut got, scale);
                assert!(
                    oracle.iter().zip(&got).all(|(a, c)| a.to_bits() == c.to_bits()),
                    "scatter bits {b:?} scale {scale}"
                );
                let mut got = init.clone();
                scatter_add_values(b, &indices, vals, &mut got, scale);
                assert!(
                    oracle.iter().zip(&got).all(|(a, c)| a.to_bits() == c.to_bits()),
                    "scatter values {b:?} scale {scale}"
                );
            }
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        for &b in &available() {
            let mut out = SparseTensor::default();
            compact_gt_abs(b, &[], 0.0, &mut out);
            assert!(out.is_empty());
            compact_gt_abs(b, &[2.0], 1.0, &mut out);
            assert_eq!(out.indices, [0]);
            assert_eq!(count_gt_abs(b, &[], 1.0), 0);
            assert_eq!(count_gt_abs(b, &[1.5], 1.0), 1);
            let mut dense = [0f32; 1];
            scatter_add_bits(b, &[0], &[1.0f32.to_bits()], &mut dense, 2.0);
            assert_eq!(dense[0], 2.0);
            let mut packed = Vec::new();
            extend_value_bits(b, &[], &mut packed);
            assert!(packed.is_empty());
        }
    }
}
