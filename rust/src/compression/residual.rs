//! Per-layer residual state with momentum correction and momentum factor
//! masking (§5.7 / Algorithm 4, adopted from Deep Gradient Compression).
//!
//! Plain RGC accumulates raw gradients into the residual `V`.  Under
//! momentum SGD that is wrong — the paper integrates DGC's *momentum
//! correction*: the momentum buffer `U` is updated locally and `V`
//! accumulates `U` (velocity), so delayed elements carry their momentum
//! history.  *Momentum factor masking* zeroes both `V` and `U` at
//! transmitted positions to stop stale momentum from re-applying.

use crate::tensor::{axpy, SparseTensor};

/// Optimizer flavor driving the accumulation rule (Alg. 4 lines 11-19).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Accumulation {
    /// V += g
    Sgd,
    /// U = m U + g;  V += U
    Momentum { momentum: f32 },
    /// U = m U + g;  V += U + g
    Nesterov { momentum: f32 },
}

/// Residual + momentum buffers for one compressed layer.
#[derive(Clone, Debug)]
pub struct ResidualState {
    v: Vec<f32>,
    u: Vec<f32>,
    pub accumulation: Accumulation,
}

impl ResidualState {
    pub fn new(n: usize, accumulation: Accumulation) -> Self {
        ResidualState { v: vec![0.0; n], u: vec![0.0; n], accumulation }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }

    pub fn residual(&self) -> &[f32] {
        &self.v
    }

    pub fn residual_mut(&mut self) -> &mut [f32] {
        &mut self.v
    }

    pub fn momentum_buf(&self) -> &[f32] {
        &self.u
    }

    /// Accumulate a (possibly weight-decayed, possibly clipped) gradient.
    pub fn accumulate(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.v.len());
        match self.accumulation {
            Accumulation::Sgd => axpy(&mut self.v, 1.0, grad),
            Accumulation::Momentum { momentum } => {
                for i in 0..grad.len() {
                    self.u[i] = momentum * self.u[i] + grad[i];
                    self.v[i] += self.u[i];
                }
            }
            Accumulation::Nesterov { momentum } => {
                for i in 0..grad.len() {
                    self.u[i] = momentum * self.u[i] + grad[i];
                    self.v[i] += self.u[i] + grad[i];
                }
            }
        }
    }

    /// Momentum factor masking: zero V and U at the transmitted indices
    /// (Alg. 4 lines 21-23).
    pub fn mask(&mut self, sent: &SparseTensor) {
        sent.zero_at(&mut self.v);
        if !matches!(self.accumulation, Accumulation::Sgd) {
            sent.zero_at(&mut self.u);
        }
    }

    /// Overwrite the residual from a device-computed buffer (when the
    /// Pallas `compress_mask` kernel already produced V*(1-mask)).
    pub fn set_residual(&mut self, new_v: Vec<f32>) {
        assert_eq!(new_v.len(), self.v.len());
        self.v = new_v;
    }

    /// Replace both buffers with device-computed accumulation results
    /// (the fused `momentum_accum` kernel, Alg. 4 lines 11-19).
    pub fn set_buffers(&mut self, new_v: Vec<f32>, new_u: Vec<f32>) {
        assert_eq!(new_v.len(), self.v.len());
        assert_eq!(new_u.len(), self.u.len());
        self.v = new_v;
        self.u = new_u;
    }

    /// Total residual mass (diagnostics / conservation tests).
    pub fn mass(&self) -> f64 {
        self.v.iter().map(|&x| x as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::select::exact_topk;
    use crate::util::proptest::{check, ensure, ensure_close};

    #[test]
    fn sgd_accumulation_adds() {
        let mut r = ResidualState::new(3, Accumulation::Sgd);
        r.accumulate(&[1.0, 2.0, 3.0]);
        r.accumulate(&[1.0, 0.0, -1.0]);
        assert_eq!(r.residual(), &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn momentum_correction_matches_manual() {
        let m = 0.9f32;
        let mut r = ResidualState::new(1, Accumulation::Momentum { momentum: m });
        r.accumulate(&[1.0]); // u=1, v=1
        r.accumulate(&[1.0]); // u=1.9, v=2.9
        assert!((r.residual()[0] - 2.9).abs() < 1e-6);
        assert!((r.momentum_buf()[0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn nesterov_adds_extra_gradient() {
        let m = 0.5f32;
        let mut r = ResidualState::new(1, Accumulation::Nesterov { momentum: m });
        r.accumulate(&[2.0]); // u=2, v=u+g=4
        assert_eq!(r.residual()[0], 4.0);
    }

    #[test]
    fn masking_zeroes_both_buffers() {
        let mut r = ResidualState::new(4, Accumulation::Momentum { momentum: 0.9 });
        r.accumulate(&[1.0, 2.0, 3.0, 4.0]);
        let sel = exact_topk(r.residual(), 2, None);
        r.mask(&sel.sparse);
        assert_eq!(r.residual()[2], 0.0);
        assert_eq!(r.residual()[3], 0.0);
        assert_eq!(r.momentum_buf()[3], 0.0);
        assert!(r.residual()[0] != 0.0 && r.momentum_buf()[0] != 0.0);
    }

    #[test]
    fn sgd_mask_leaves_u_untouched() {
        let mut r = ResidualState::new(2, Accumulation::Sgd);
        r.accumulate(&[5.0, 1.0]);
        let sel = exact_topk(r.residual(), 1, None);
        r.mask(&sel.sparse);
        assert_eq!(r.residual(), &[0.0, 1.0]);
    }

    #[test]
    fn prop_sgd_mass_conservation() {
        // For plain-SGD accumulation: transmitted mass + remaining residual
        // mass == total injected gradient mass, every iteration.
        check(30, |g| {
            let n = g.size(8..2048);
            let mut r = ResidualState::new(n, Accumulation::Sgd);
            let mut injected = 0f64;
            let mut transmitted = 0f64;
            for _ in 0..5 {
                let grad = g.vec_normal(n, 1.0);
                injected += grad.iter().map(|&x| x as f64).sum::<f64>();
                r.accumulate(&grad);
                let k = (n / 10).max(1);
                let sel = exact_topk(r.residual(), k, None);
                transmitted += sel.sparse.values.iter().map(|&x| x as f64).sum::<f64>();
                r.mask(&sel.sparse);
            }
            ensure_close(injected, transmitted + r.mass(), 1e-4, "mass conservation")
        });
    }

    #[test]
    fn prop_masked_positions_are_zero() {
        check(30, |g| {
            let n = g.size(8..1024);
            let mut r = ResidualState::new(n, Accumulation::Momentum { momentum: 0.9 });
            r.accumulate(&g.vec_normal(n, 1.0));
            let k = g.size(1..n.max(2));
            let sel = exact_topk(r.residual(), k, None);
            r.mask(&sel.sparse);
            for &i in &sel.sparse.indices {
                ensure(r.residual()[i as usize] == 0.0, "v not zeroed")?;
                ensure(r.momentum_buf()[i as usize] == 0.0, "u not zeroed")?;
            }
            Ok(())
        });
    }
}
