//! Per-iteration timeline simulator: produces iteration time, speedup
//! curves (Figs. 7-9) and the phase decomposition (Fig. 10) for the three
//! strategies (dense baseline / RGC / quantized RGC) under the two §5.6
//! overlap schemes (per-layer pipelining for CNNs, post-BPTT for RNNs).
//!
//! The network is single-ported (one collective in flight, as the cost
//! model assumes): per-layer collectives queue on the link; GPU-side
//! compression work (select/mask/pack) serializes with backprop compute on
//! the device stream; decompression (unpack) happens after synchronization.

use super::{allgather_time, allreduce_time, hierarchical_allgather_time, Machine};
use crate::compression::{Method, PolicyThresholds};
use crate::models::zoo::ModelProfile;

/// Synchronization strategy for a simulated run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Dense allreduce every layer (the horovod baseline).
    Dense,
    /// Residual gradient compression, plain messages.
    Rgc,
    /// RGC + same-sign mean quantization (§5.2.3).
    QuantRgc,
}

impl Strategy {
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Dense => "baseline",
            Strategy::Rgc => "RGC",
            Strategy::QuantRgc => "quant-RGC",
        }
    }
}

/// Simulation tunables.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Compression density D (paper: 1e-3).
    pub density: f64,
    /// Per-GPU mini-batch (weak scaling, as the paper measures).
    pub batch_per_gpu: usize,
    /// §5.5 selection-method policy thresholds.
    pub thresholds: PolicyThresholds,
    /// Backward/forward flop ratio (standard 2x).
    pub bwd_flop_ratio: f64,
    /// Model the pipelined sync engine: collectives queue on the link
    /// while the device stream runs ahead — per-window exposed time is
    /// `max(comm, compute)`, not the sum.  `false` projects the
    /// sequential engine (compute blocks on every collective).
    pub pipeline: bool,
    /// Bounded in-flight window under `pipeline`: issuing collective `i`
    /// stalls the device stream until collective `i - inflight` left the
    /// link.  0 = unbounded (the idealized overlap of the paper's
    /// figures).
    pub inflight: usize,
    /// Physical topology `(nodes, ranks_per_node)` for the sparse
    /// collectives: when set (and it covers `p`), compressed layers run
    /// the hierarchical allgather schedule instead of the flat one —
    /// `redsync simulate --topology`.  Dense allreduces keep the flat
    /// Eq. 2 schedule either way.
    pub topology: Option<(usize, usize)>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            density: 1e-3,
            batch_per_gpu: 32,
            thresholds: PolicyThresholds::default(),
            bwd_flop_ratio: 2.0,
            pipeline: true,
            inflight: 0,
            topology: None,
        }
    }
}

/// Virtual-time phase totals for one iteration.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub compute: f64,
    pub select: f64,
    pub mask: f64,
    pub pack: f64,
    /// Total collective time on the link (not the exposed part).
    pub comm: f64,
    pub unpack: f64,
    /// End-to-end iteration time (with overlap).
    pub total: f64,
}

impl Breakdown {
    /// Sum of the device/network component costs (Fig. 10 columns are
    /// proportions of this).
    pub fn component_sum(&self) -> f64 {
        self.compute + self.select + self.mask + self.pack + self.comm + self.unpack
    }
}

fn compute_times(model: &ModelProfile, machine: &Machine, cfg: &SimConfig) -> (f64, f64) {
    let total_flops =
        model.fwd_gflops_per_sample * 1e9 * cfg.batch_per_gpu as f64 * (1.0 + cfg.bwd_flop_ratio);
    let fwd = model.fwd_gflops_per_sample * 1e9 * cfg.batch_per_gpu as f64
        / (machine.gpu_gflops * 1e9);
    let bwd = (total_flops / (machine.gpu_gflops * 1e9)) - fwd;
    (fwd, bwd)
}

/// Selected elements per layer under density D.
fn k_for(elems: usize, density: f64) -> usize {
    ((elems as f64 * density).ceil() as usize).clamp(1, elems)
}

/// Message bytes for one rank's compressed layer (§5.3 wire format).
fn message_bytes(k: usize, quantized: bool) -> f64 {
    if quantized {
        // len + k indices + 1 mean
        4.0 * (k as f64 + 2.0)
    } else {
        // len + k indices + k values
        4.0 * (2.0 * k as f64 + 1.0)
    }
}

/// Threshold-reuse interval of the sampled binary search (§5.2.2).
const BS_INTERVAL: f64 = 5.0;

/// Per-layer selection cost.  Sampled binary search amortizes the full
/// search over `BS_INTERVAL` iterations (cached-threshold iterations pay
/// only one compaction pass); quantized layers cannot reuse thresholds
/// (§6.4 — the sign alternates) and pay the full search every time.
fn select_time(machine: &Machine, method: Method, elems: usize, quantized: bool) -> f64 {
    let n = elems as f64;
    match method {
        Method::Dense => 0.0,
        Method::ExactTopk => machine.sel_launch + n * machine.sel_exact_per_elem,
        Method::TrimmedTopk => machine.sel_launch + n * machine.sel_trimmed_per_elem,
        Method::SampledBinarySearch => {
            let full = n * machine.sel_bs_per_elem;
            let compact = n * machine.sel_trimmed_per_elem;
            if quantized {
                machine.sel_launch + full
            } else {
                machine.sel_launch + (full + (BS_INTERVAL - 1.0) * compact) / BS_INTERVAL
            }
        }
    }
}

/// Simulate one training iteration; returns the phase breakdown.
pub fn simulate_iteration(
    model: &ModelProfile,
    machine: &Machine,
    p: usize,
    strategy: Strategy,
    cfg: &SimConfig,
) -> Breakdown {
    let (fwd, bwd_total) = compute_times(model, machine, cfg);
    let nl = model.layers.len() as f64;
    let bwd_per_layer = bwd_total / nl;

    let mut b = Breakdown { compute: fwd + bwd_total, ..Default::default() };

    // device-stream clock (backprop + compression) and link clock; the
    // link is single-ported (one collective at a time), and `ends`
    // records per-collective completion for the in-flight window
    let mut gpu = 0.0f64;
    let mut link = 0.0f64;
    let mut ends: Vec<f64> = Vec::new();

    let per_layer_overlap = !model.is_rnn;
    if !per_layer_overlap {
        // RNN: BPTT must finish before any compression/communication
        gpu = bwd_total;
        link = bwd_total;
    }

    // issue one collective: start when both the device stream has
    // produced it and the link is free; sequential engines (`!pipeline`)
    // block the device stream until it completes
    let issue = |gpu: &mut f64, link: &mut f64, ends: &mut Vec<f64>, dur: f64| {
        let start = gpu.max(*link);
        *link = start + dur;
        ends.push(*link);
        if !cfg.pipeline {
            *gpu = *link;
        }
    };

    // iterate layers in backprop order (last layer first)
    for layer in model.layers.iter().rev() {
        if per_layer_overlap {
            gpu += bwd_per_layer;
        }
        // bounded in-flight window: the producer stalls until collective
        // i - inflight retired (the pipelined engine's backpressure)
        if cfg.pipeline && cfg.inflight > 0 && ends.len() >= cfg.inflight {
            gpu = gpu.max(ends[ends.len() - cfg.inflight]);
        }
        let bytes = layer.elems as f64 * 4.0;
        match strategy {
            Strategy::Dense => {
                let dur = allreduce_time(machine, p, bytes);
                b.comm += dur;
                issue(&mut gpu, &mut link, &mut ends, dur);
            }
            Strategy::Rgc | Strategy::QuantRgc => {
                let method = Method::for_size(layer.elems * 4, cfg.thresholds);
                if method == Method::Dense {
                    let dur = allreduce_time(machine, p, bytes);
                    b.comm += dur;
                    issue(&mut gpu, &mut link, &mut ends, dur);
                } else {
                    // quantization is never applied to the output layer
                    let quantized = strategy == Strategy::QuantRgc && !layer.is_output;
                    let k = k_for(layer.elems, cfg.density);
                    let t_sel = select_time(machine, method, layer.elems, quantized);
                    let t_mask = layer.elems as f64 * machine.mask_per_elem;
                    let t_pack = k as f64 * machine.pack_per_elem;
                    b.select += t_sel;
                    b.mask += t_mask;
                    b.pack += t_pack;
                    gpu += t_sel + t_mask + t_pack;
                    let dur = match cfg.topology {
                        Some((nodes, rpn)) if nodes * rpn == p => {
                            hierarchical_allgather_time(
                                machine,
                                nodes,
                                rpn,
                                message_bytes(k, quantized),
                            )
                        }
                        _ => allgather_time(machine, p, message_bytes(k, quantized)),
                    };
                    b.comm += dur;
                    issue(&mut gpu, &mut link, &mut ends, dur);
                    // unpack: apply p compressed sets of size k, one
                    // (launch + scatter) per rank per layer — the p·γ₁
                    // term of Eq. 1
                    b.unpack += p as f64
                        * (machine.unpack_launch + k as f64 * machine.gamma_decompress);
                }
            }
        }
    }

    let sync_end = gpu.max(link);
    b.total = fwd + sync_end + b.unpack;
    b
}

/// Single-GPU iteration time of the *baseline* (compute only) — the
/// denominator of the paper's speedup curves.
pub fn single_gpu_time(model: &ModelProfile, machine: &Machine, cfg: &SimConfig) -> f64 {
    let (fwd, bwd) = compute_times(model, machine, cfg);
    fwd + bwd
}

/// Paper-style speedup: single-GPU baseline time / distributed per-
/// iteration time (weak scaling: same per-GPU batch).
pub fn speedup(
    model: &ModelProfile,
    machine: &Machine,
    p: usize,
    strategy: Strategy,
    cfg: &SimConfig,
) -> f64 {
    let t1 = single_gpu_time(model, machine, cfg);
    let tp = simulate_iteration(model, machine, p, strategy, cfg).total;
    // speedup of p GPUs = p × per-iteration throughput ratio
    p as f64 * t1 / tp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    #[test]
    fn single_gpu_equals_compute() {
        let m = zoo::alexnet();
        let mach = Machine::muradin();
        let b = simulate_iteration(&m, &mach, 1, Strategy::Dense, &cfg());
        let t1 = single_gpu_time(&m, &mach, &cfg());
        assert!((b.total - t1).abs() / t1 < 1e-9);
    }

    #[test]
    fn rgc_beats_dense_for_alexnet_at_scale() {
        // AlexNet = communication-bound: the paper's headline case
        let m = zoo::alexnet();
        let mach = Machine::piz_daint();
        for p in [16usize, 32, 64, 128] {
            let d = speedup(&m, &mach, p, Strategy::Dense, &cfg());
            let r = speedup(&m, &mach, p, Strategy::QuantRgc, &cfg());
            assert!(r > d, "p={p}: quant-RGC {r:.1} <= dense {d:.1}");
        }
    }

    #[test]
    fn quant_rgc_beats_rgc_for_comm_bound_cnns() {
        // AlexNet = the communication-bound CNN where the halved message
        // size is exposed (for VGG16 our overlap model hides comm almost
        // fully, so quant ≈ plain there — see EXPERIMENTS.md deviations)
        let m = zoo::alexnet();
        let mach = Machine::piz_daint();
        let r = speedup(&m, &mach, 64, Strategy::Rgc, &cfg());
        let q = speedup(&m, &mach, 64, Strategy::QuantRgc, &cfg());
        assert!(q > r, "quant {q:.2} <= plain {r:.2}");
        // and never *worse* for the other CNNs
        for name in ["vgg16", "resnet50"] {
            let m = zoo::by_name(name).unwrap();
            let r = speedup(&m, &mach, 64, Strategy::Rgc, &cfg());
            let q = speedup(&m, &mach, 64, Strategy::QuantRgc, &cfg());
            assert!(q >= 0.95 * r, "{name}: quant {q:.2} << plain {r:.2}");
        }
    }

    #[test]
    fn quant_rgc_slower_than_rgc_for_lstm_small_scale() {
        // §6.4: threshold sharing is incompatible with quantization, so
        // the LSTM's huge layers pay a full binary search every iteration
        // — at small scale that overhead beats the bandwidth saving
        let m = zoo::lstm_ptb();
        let mach = Machine::muradin();
        let r = speedup(&m, &mach, 2, Strategy::Rgc, &cfg());
        let q = speedup(&m, &mach, 2, Strategy::QuantRgc, &cfg());
        assert!(q < r, "quant {q:.2} should trail plain {r:.2} at p=2");
    }

    #[test]
    fn resnet50_gains_little_or_nothing() {
        // the paper's negative result: high compute/comm ratio
        let m = zoo::resnet50();
        let mach = Machine::piz_daint();
        let d = speedup(&m, &mach, 128, Strategy::Dense, &cfg());
        let q = speedup(&m, &mach, 128, Strategy::QuantRgc, &cfg());
        assert!(
            q < d * 1.15,
            "resnet50 should not meaningfully benefit: dense {d:.1} quant {q:.1}"
        );
    }

    #[test]
    fn unpack_grows_linearly_with_p() {
        let m = zoo::resnet50();
        let mach = Machine::piz_daint();
        let b32 = simulate_iteration(&m, &mach, 32, Strategy::Rgc, &cfg());
        let b128 = simulate_iteration(&m, &mach, 128, Strategy::Rgc, &cfg());
        let ratio = b128.unpack / b32.unpack;
        assert!((ratio - 4.0).abs() < 0.01, "unpack ratio {ratio}");
    }

    #[test]
    fn rnn_scheme_defers_comm() {
        // with the RNN scheme, link time starts after full BPTT: total
        // must be >= bwd + first comm
        let m = zoo::lstm_ptb();
        let mach = Machine::muradin();
        let b = simulate_iteration(&m, &mach, 4, Strategy::Rgc, &cfg());
        let t1 = single_gpu_time(&m, &mach, &cfg());
        assert!(b.total > t1, "comm cannot be fully hidden for RNN");
    }

    #[test]
    fn small_layers_fall_back_to_dense_in_rgc() {
        // resnet44: every layer except the thirteen 147KB s3 64x64
        // convs is below thsd1 -> dense allreduce inside the RGC strategy
        let m = zoo::resnet44();
        let compressed: Vec<_> = m
            .layers
            .iter()
            .filter(|l| Method::for_size(l.elems * 4, PolicyThresholds::default()) != Method::Dense)
            .collect();
        assert_eq!(compressed.len(), 13, "{compressed:?}");
        assert!(compressed.iter().all(|l| l.elems == 36_864));
        let mach = Machine::muradin();
        let rgc = simulate_iteration(&m, &mach, 4, Strategy::Rgc, &cfg());
        // select cost is exactly the 7 trimmed selections
        let expect = 13.0 * (mach.sel_launch + 36_864.0 * mach.sel_trimmed_per_elem);
        assert!((rgc.select - expect).abs() / expect < 1e-9, "{} vs {expect}", rgc.select);
        // and the rest of the traffic still goes through dense allreduce
        let dense = simulate_iteration(&m, &mach, 4, Strategy::Dense, &cfg());
        assert!(rgc.comm > 0.3 * dense.comm, "most of resnet44 stays dense");
    }

    #[test]
    fn breakdown_components_positive() {
        let m = zoo::vgg16();
        let mach = Machine::piz_daint();
        let b = simulate_iteration(&m, &mach, 16, Strategy::Rgc, &cfg());
        assert!(b.select > 0.0 && b.mask > 0.0 && b.pack > 0.0);
        assert!(b.comm > 0.0 && b.unpack > 0.0);
        assert!(b.total >= b.compute);
    }

    #[test]
    fn sequential_engine_never_beats_pipelined() {
        // removing overlap can only expose more time, for every model,
        // machine and strategy
        let mach = Machine::piz_daint();
        for name in ["alexnet", "vgg16", "resnet50", "lstm-ptb"] {
            let m = zoo::by_name(name).unwrap();
            for strat in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
                let piped = simulate_iteration(&m, &mach, 16, strat, &cfg());
                let seq_cfg = SimConfig { pipeline: false, ..cfg() };
                let seq = simulate_iteration(&m, &mach, 16, strat, &seq_cfg);
                assert!(
                    seq.total >= piped.total * (1.0 - 1e-9),
                    "{name}/{}: sequential {} < pipelined {}",
                    strat.label(),
                    seq.total,
                    piped.total
                );
            }
        }
    }

    #[test]
    fn no_overlap_total_is_the_sum_of_parts() {
        // with the sequential engine nothing hides: iteration time is
        // exactly compute + select + mask + pack + comm + unpack
        let m = zoo::vgg16();
        let mach = Machine::piz_daint();
        let seq_cfg = SimConfig { pipeline: false, ..cfg() };
        let b = simulate_iteration(&m, &mach, 32, Strategy::Rgc, &seq_cfg);
        let sum = b.component_sum();
        assert!((b.total - sum).abs() / sum < 1e-9, "total {} vs sum {}", b.total, sum);
    }

    #[test]
    fn inflight_window_is_monotone() {
        // a tighter window can only stall the producer more
        let m = zoo::alexnet();
        let mach = Machine::piz_daint();
        let t = |inflight: usize| {
            let c = SimConfig { inflight, ..cfg() };
            simulate_iteration(&m, &mach, 64, Strategy::Rgc, &c).total
        };
        let (w1, w4, unbounded) = (t(1), t(4), t(0));
        assert!(w1 >= w4 * (1.0 - 1e-9), "window 1 {w1} < window 4 {w4}");
        assert!(w4 >= unbounded * (1.0 - 1e-9), "window 4 {w4} < unbounded {unbounded}");
        // and the window sits between the two engine extremes
        let seq = simulate_iteration(
            &m,
            &mach,
            64,
            Strategy::Rgc,
            &SimConfig { pipeline: false, ..cfg() },
        )
        .total;
        assert!(seq >= w1 * (1.0 - 1e-9), "sequential {seq} < window-1 {w1}");
    }

    #[test]
    fn topology_model_helps_rgc_on_fat_nodes_only() {
        // hierarchical collectives cut comm link time on fat nodes; on
        // thin (1 GPU/node) topologies they degenerate to the flat
        // schedule and change nothing
        let m = zoo::alexnet();
        let flat = cfg();
        let fat = SimConfig { topology: Some((4, 4)), ..cfg() };
        let mach = Machine::fatnode();
        let b_flat = simulate_iteration(&m, &mach, 16, Strategy::Rgc, &flat);
        let b_fat = simulate_iteration(&m, &mach, 16, Strategy::Rgc, &fat);
        assert!(b_fat.comm < b_flat.comm, "fat {} !< flat {}", b_fat.comm, b_flat.comm);
        let thin = SimConfig { topology: Some((16, 1)), ..cfg() };
        let b_thin = simulate_iteration(&m, &mach, 16, Strategy::Rgc, &thin);
        assert!(
            (b_thin.comm - b_flat.comm).abs() <= 1e-12 * b_flat.comm,
            "1-rank nodes must match the flat schedule"
        );
        // a topology that does not cover p falls back to flat
        let bad = SimConfig { topology: Some((3, 5)), ..cfg() };
        let b_bad = simulate_iteration(&m, &mach, 16, Strategy::Rgc, &bad);
        assert_eq!(b_bad.comm, b_flat.comm);
    }

    #[test]
    fn speedup_concave_at_scale_for_rgc() {
        // the paper observes concave speedup curves (bandwidth + unpack
        // grow with p): marginal speedup per added GPU shrinks
        let m = zoo::vgg16();
        let mach = Machine::piz_daint();
        let s: Vec<f64> = [16usize, 32, 64, 128]
            .iter()
            .map(|&p| speedup(&m, &mach, p, Strategy::QuantRgc, &cfg()))
            .collect();
        let eff: Vec<f64> = s.iter().zip([16f64, 32.0, 64.0, 128.0]).map(|(s, p)| s / p).collect();
        assert!(eff[0] > eff[1] && eff[1] > eff[2] && eff[2] > eff[3], "{eff:?}");
    }
}
