//! Virtual-time network + device simulator.
//!
//! The paper's performance results come from an 8-GPU PCIe server
//! ("Muradin", 3.5 GB/s allreduce bandwidth) and a 5k-node Cray
//! ("Piz Daint", Aries, ~1.5 GB/s) — hardware this repo does not have.
//! Per DESIGN.md §Substitutions, the *scalability* experiments replay the
//! exact collective schedules (`collectives::`) in virtual time against an
//! α-β link model plus per-element device costs, with machine presets
//! calibrated to the paper's measured bandwidths and Fig. 3 selection
//! ratios.
//!
//! [`iteration`] builds on this: a per-layer timeline simulator producing
//! iteration time + the Fig. 10 phase decomposition for dense / RGC /
//! quantized-RGC strategies.

pub mod iteration;

/// Which physical link class carries *intra-host* traffic — the
/// transport-level counterpart of `net`'s fabric choice.  The α-β
/// parameters differ per class ([`Machine::link_params`]): shared-memory
/// channels (the in-process `LocalFabric` / NCCL-style SMP transfers)
/// are cheapest, Unix-domain sockets skip loopback-TCP's per-segment
/// protocol work, and loopback TCP pays the full stack.  `--algo auto`
/// prices single-host schedules against the class the configured
/// `--transport` actually uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntraLink {
    /// Shared-memory / PCIe-class transfers (in-process fabric).
    Smp,
    /// `AF_UNIX` stream sockets between same-host processes.
    Unix,
    /// TCP over the loopback interface.
    Loopback,
}

impl IntraLink {
    pub fn label(&self) -> &'static str {
        match self {
            IntraLink::Smp => "smp",
            IntraLink::Unix => "unix",
            IntraLink::Loopback => "loopback",
        }
    }
}

/// Device + network parameters of one simulated machine.
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: String,
    /// Per-message latency α (seconds) of the inter-node fabric.
    pub alpha: f64,
    /// Per-byte transfer time β (seconds/byte) of the inter-node fabric.
    pub beta: f64,
    /// Per-message latency of the intra-node link (PCIe/NVLink class) —
    /// what the hierarchical schedule's gather/broadcast phases pay.
    pub intra_alpha: f64,
    /// Per-byte transfer time of the intra-node link.
    pub intra_beta: f64,
    /// Per-message latency of a Unix-domain socket between same-host
    /// processes (`net::UnixTransport`): one kernel crossing per write,
    /// no loopback-TCP segmentation/ack work.
    pub uds_alpha: f64,
    /// Per-byte transfer time over a Unix-domain socket.
    pub uds_beta: f64,
    /// Per-message latency of loopback TCP between same-host processes
    /// (`net::TcpTransport` on 127.0.0.1).
    pub lo_alpha: f64,
    /// Per-byte transfer time over loopback TCP.
    pub lo_beta: f64,
    /// Reduction cost per element (dense allreduce γ₂ contribution).
    pub gamma_reduce: f64,
    /// Sparse decompression (scatter-add) cost per element (γ₁).
    pub gamma_decompress: f64,
    /// Fixed launch/setup cost of one selection pass on a layer (the
    /// handful of kernel launches behind Alg. 2/3) — why small layers
    /// prefer dense allreduce (§5.5).
    pub sel_launch: f64,
    /// Fixed cost of decompressing one rank's message for one layer
    /// (cuSparse axpyi launch + small-size inefficiency — the paper's
    /// "GPU memory bandwidth cannot be fully utilized when
    /// decompressing").  Charged p times per compressed layer; the
    /// linear-in-p term that makes unpack dominate Fig. 10 at scale.
    pub unpack_launch: f64,
    /// Exact top-k selection cost per scanned element (the radixSelect
    /// stand-in of Fig. 3).
    pub sel_exact_per_elem: f64,
    /// Trimmed top-k cost per scanned element (Alg. 2; ~38× cheaper at
    /// 16Mi elements per Fig. 3).
    pub sel_trimmed_per_elem: f64,
    /// Threshold binary search cost per scanned element (Alg. 3; ~16×).
    pub sel_bs_per_elem: f64,
    /// Momentum correction + masking cost per element (Fig. 10 "mask").
    pub mask_per_elem: f64,
    /// Message packing cost per *selected* element (Fig. 10 "pack").
    pub pack_per_elem: f64,
    /// Effective device throughput for fwd+bwd compute (GFlop/s).
    pub gpu_gflops: f64,
    /// Ranks available on this machine in the paper.
    pub max_ranks: usize,
}

impl Machine {
    /// The 8× Titan V PCIe server: 3.5 GB/s peak allreduce bandwidth
    /// (paper Fig. 5), NCCL within one node.
    pub fn muradin() -> Machine {
        Machine {
            name: "muradin".into(),
            alpha: 10e-6,
            beta: 1.0 / 3.5e9,
            // single-node PCIe server: the "intra" link is the same PCIe
            // complex NCCL already uses, slightly faster point-to-point
            intra_alpha: 5e-6,
            intra_beta: 1.0 / 12e9,
            // process-to-process on the same host: AF_UNIX clearly beats
            // loopback TCP (no segmentation, single kernel crossing)
            uds_alpha: 3e-6,
            uds_beta: 1.0 / 9e9,
            lo_alpha: 12e-6,
            lo_beta: 1.0 / 4e9,
            gamma_reduce: 2.0e-11,
            gamma_decompress: 1.0e-10,
            sel_launch: 30e-6,
            unpack_launch: 10e-6,
            sel_exact_per_elem: 1.2e-9,
            sel_trimmed_per_elem: 3.2e-11,
            sel_bs_per_elem: 7.4e-11,
            mask_per_elem: 4.0e-11,
            pack_per_elem: 4.0e-10,
            gpu_gflops: 7_000.0, // Titan V fp32, ~50% efficiency
            max_ranks: 8,
        }
    }

    /// Piz Daint: 1 P100/node, Aries dragonfly, ~1.5 GB/s sustained
    /// allreduce bandwidth (paper Fig. 5), higher launch latency.
    pub fn piz_daint() -> Machine {
        Machine {
            name: "piz-daint".into(),
            alpha: 25e-6,
            beta: 1.0 / 1.5e9,
            // hypothetical fat nodes (the paper's nodes host one P100,
            // so hierarchy degenerates there): NVLink-class local link
            intra_alpha: 5e-6,
            intra_beta: 1.0 / 10e9,
            uds_alpha: 3e-6,
            uds_beta: 1.0 / 8e9,
            lo_alpha: 15e-6,
            lo_beta: 1.0 / 3e9,
            gamma_reduce: 2.0e-11,
            gamma_decompress: 1.0e-10,
            sel_launch: 30e-6,
            unpack_launch: 25e-6,
            sel_exact_per_elem: 1.2e-9,
            sel_trimmed_per_elem: 3.2e-11,
            sel_bs_per_elem: 7.4e-11,
            mask_per_elem: 4.0e-11,
            pack_per_elem: 4.0e-10,
            gpu_gflops: 5_000.0, // P100 fp32, ~50% efficiency
            max_ranks: 128,
        }
    }

    /// A fat-node commodity cluster: NVLink-class links inside a node,
    /// a 10 GbE-class fabric between nodes.  The regime where the
    /// hierarchical schedule pays: the inter/intra bandwidth ratio
    /// (~40×) exceeds the world sizes we care about, so keeping traffic
    /// on-node beats the flat schedule (see `costmodel::t_hierarchical`).
    pub fn fatnode() -> Machine {
        Machine {
            name: "fatnode".into(),
            alpha: 20e-6,
            beta: 1.0 / 1.25e9,
            intra_alpha: 3e-6,
            intra_beta: 1.0 / 50e9,
            uds_alpha: 2e-6,
            uds_beta: 1.0 / 12e9,
            lo_alpha: 10e-6,
            lo_beta: 1.0 / 5e9,
            gamma_reduce: 2.0e-11,
            gamma_decompress: 1.0e-10,
            sel_launch: 30e-6,
            unpack_launch: 10e-6,
            sel_exact_per_elem: 1.2e-9,
            sel_trimmed_per_elem: 3.2e-11,
            sel_bs_per_elem: 7.4e-11,
            mask_per_elem: 4.0e-11,
            pack_per_elem: 4.0e-10,
            gpu_gflops: 7_000.0,
            max_ranks: 64,
        }
    }

    /// [`Machine::fatnode`] with one straggling worker per node: the
    /// intra-node collectives are synchronous, so they run at the
    /// slowest member's pace — the effective intra link degrades ~130×
    /// in latency and ~80× in bandwidth while the inter-node fabric is
    /// untouched.  The straggler-heterogeneity scenario where the
    /// datasheet plan (hierarchical, per
    /// `hierarchy_beats_flat_on_fat_nodes`) is provably wrong and the
    /// calibrated picker (`obs::calib`) must fall back to the flat
    /// sparse schedule.
    pub fn fatnode_straggler() -> Machine {
        Machine {
            name: "fatnode-straggler".into(),
            intra_alpha: 400e-6,
            intra_beta: 1.0 / 0.6e9,
            uds_alpha: 300e-6,
            uds_beta: 1.0 / 0.5e9,
            lo_alpha: 500e-6,
            lo_beta: 1.0 / 0.4e9,
            ..Machine::fatnode()
        }
    }

    pub fn by_name(name: &str) -> Option<Machine> {
        match name {
            "muradin" => Some(Machine::muradin()),
            "piz-daint" | "pizdaint" | "piz_daint" => Some(Machine::piz_daint()),
            "fatnode" | "fat-node" | "fat_node" => Some(Machine::fatnode()),
            "fatnode-straggler" | "fatnode_straggler" | "straggler" => {
                Some(Machine::fatnode_straggler())
            }
            _ => None,
        }
    }

    /// The α-β parameters of one intra-host link class.  `Smp` is the
    /// historical `intra_alpha`/`intra_beta` pair — the shared-memory
    /// link the hierarchical closed form has always priced.
    pub fn link_params(&self, link: IntraLink) -> (f64, f64) {
        match link {
            IntraLink::Smp => (self.intra_alpha, self.intra_beta),
            IntraLink::Unix => (self.uds_alpha, self.uds_beta),
            IntraLink::Loopback => (self.lo_alpha, self.lo_beta),
        }
    }
}

/// The recursive-doubling allgather walk over an explicit α-β link.
fn allgather_time_ab(alpha: f64, beta: f64, p: usize, bytes_per_rank: f64) -> f64 {
    assert!(p >= 1);
    if p == 1 {
        return 0.0;
    }
    let mut t = 0.0;
    let mut have = bytes_per_rank; // bytes accumulated so far
    let mut dist = 1;
    while dist < p {
        t += alpha + have * beta;
        have *= 2.0;
        dist <<= 1;
    }
    t
}

/// Virtual time of a recursive-doubling allgather where every rank
/// contributes `bytes_per_rank`.  Walks the actual schedule: step s moves
/// 2^s · m bytes, so Σ = lg(p)·α + (p-1)·m·β — Eq. 1's transfer term.
pub fn allgather_time(machine: &Machine, p: usize, bytes_per_rank: f64) -> f64 {
    allgather_time_ab(machine.alpha, machine.beta, p, bytes_per_rank)
}

/// [`allgather_time`] over one *intra-host* link class — what a flat
/// sparse allgather costs when the whole world lives on one host and
/// the fabric is Unix sockets or loopback TCP instead of the inter-node
/// network.
pub fn allgather_time_on(
    machine: &Machine,
    link: IntraLink,
    p: usize,
    bytes_per_rank: f64,
) -> f64 {
    let (alpha, beta) = machine.link_params(link);
    allgather_time_ab(alpha, beta, p, bytes_per_rank)
}

/// The Rabenseifner allreduce walk over an explicit α-β link.
fn allreduce_time_ab(machine: &Machine, alpha: f64, beta: f64, p: usize, bytes: f64) -> f64 {
    assert!(p >= 1);
    if p == 1 {
        return 0.0;
    }
    let mut t = 0.0;
    // reduce-scatter: step sizes M/2, M/4, ... M/p
    let mut part = bytes / 2.0;
    let mut dist = p / 2;
    while dist >= 1 {
        t += alpha + part * beta + (part / 4.0) * machine.gamma_reduce;
        part /= 2.0;
        dist /= 2;
    }
    // allgather: step sizes M/p, 2M/p, ... M/2
    let mut part = bytes / p as f64;
    let mut dist = 1;
    while dist < p {
        t += alpha + part * beta;
        part *= 2.0;
        dist <<= 1;
    }
    t
}

/// Virtual time of a Rabenseifner allreduce on `bytes` of gradient data:
/// reduce-scatter (recursive halving, with per-element reduction) +
/// allgather (recursive doubling) — Eq. 2's schedule.
pub fn allreduce_time(machine: &Machine, p: usize, bytes: f64) -> f64 {
    allreduce_time_ab(machine, machine.alpha, machine.beta, p, bytes)
}

/// [`allreduce_time`] over one intra-host link class (single-host dense
/// baseline over Unix sockets / loopback TCP).
pub fn allreduce_time_on(machine: &Machine, link: IntraLink, p: usize, bytes: f64) -> f64 {
    let (alpha, beta) = machine.link_params(link);
    allreduce_time_ab(machine, alpha, beta, p, bytes)
}

/// Virtual time of one hierarchical allgather (`nodes` ×
/// `ranks_per_node`, every rank contributing `bytes_per_rank`), walking
/// the actual three-phase schedule on the leader's critical path:
/// serial intra-node gather, recursive-doubling allgather of node blobs
/// among the leaders (inter-node link), serial intra-node broadcast of
/// the world blob.  `costmodel::t_hierarchical` is the closed form;
/// the proptests pin them equal.
pub fn hierarchical_allgather_time(
    machine: &Machine,
    nodes: usize,
    ranks_per_node: usize,
    bytes_per_rank: f64,
) -> f64 {
    hierarchical_allgather_time_on(machine, IntraLink::Smp, nodes, ranks_per_node, bytes_per_rank)
}

/// [`hierarchical_allgather_time`] with the intra-node phases priced on
/// an explicit link class: `Smp` reproduces the historical walk exactly;
/// `Unix`/`Loopback` price the gather/broadcast phases the way a
/// process-per-rank `--transport unix`/`tcp` run actually pays them.
/// The inter-node leader exchange always rides `alpha`/`beta`.
pub fn hierarchical_allgather_time_on(
    machine: &Machine,
    link: IntraLink,
    nodes: usize,
    ranks_per_node: usize,
    bytes_per_rank: f64,
) -> f64 {
    let (ia, ib) = machine.link_params(link);
    let p = nodes * ranks_per_node;
    assert!(p >= 1);
    if p == 1 {
        return 0.0;
    }
    let mut t = 0.0;
    // phase 1: the leader drains s-1 member messages one after another
    for _ in 1..ranks_per_node {
        t += ia + bytes_per_rank * ib;
    }
    // phase 2: the leader allgather dispatches like the real collective
    // — recursive doubling for power-of-two node counts (blobs double
    // per step), ring otherwise (n-1 single-blob forwards)
    let node_bytes = ranks_per_node as f64 * bytes_per_rank;
    if nodes.is_power_of_two() {
        let mut have = node_bytes;
        let mut dist = 1;
        while dist < nodes {
            t += machine.alpha + have * machine.beta;
            have *= 2.0;
            dist <<= 1;
        }
    } else {
        for _ in 1..nodes {
            t += machine.alpha + node_bytes * machine.beta;
        }
    }
    // phase 3: the leader pushes the world blob to each member in turn
    let world_bytes = p as f64 * bytes_per_rank;
    for _ in 1..ranks_per_node {
        t += ia + world_bytes * ib;
    }
    t
}

/// Effective allreduce *bandwidth* reported the way the paper's Fig. 5
/// measures it: S/t · 2(n-1)/n for per-rank data size S.
pub fn allreduce_bandwidth(machine: &Machine, p: usize, bytes: f64) -> f64 {
    if p == 1 {
        return f64::INFINITY;
    }
    let t = allreduce_time(machine, p, bytes);
    (bytes / t) * 2.0 * (p as f64 - 1.0) / p as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allgather_matches_closed_form() {
        let m = Machine::muradin();
        for p in [2usize, 4, 8, 32, 128] {
            for bytes in [1e3, 1e6, 64e6] {
                let walked = allgather_time(&m, p, bytes);
                let closed =
                    (p as f64).log2() * m.alpha + (p as f64 - 1.0) * bytes * m.beta;
                assert!(
                    (walked - closed).abs() / closed < 1e-9,
                    "p={p} bytes={bytes}: {walked} vs {closed}"
                );
            }
        }
    }

    #[test]
    fn allreduce_matches_closed_form() {
        let m = Machine::piz_daint();
        for p in [2usize, 8, 64, 128] {
            let bytes = 32e6;
            let walked = allreduce_time(&m, p, bytes);
            let pf = p as f64;
            let closed = 2.0 * pf.log2() * m.alpha
                + 2.0 * (pf - 1.0) / pf * bytes * m.beta
                + (pf - 1.0) / pf * (bytes / 4.0) * m.gamma_reduce;
            assert!(
                (walked - closed).abs() / closed < 1e-9,
                "p={p}: {walked} vs {closed}"
            );
        }
    }

    #[test]
    fn single_rank_costs_nothing() {
        let m = Machine::muradin();
        assert_eq!(allgather_time(&m, 1, 1e6), 0.0);
        assert_eq!(allreduce_time(&m, 1, 1e6), 0.0);
        assert_eq!(hierarchical_allgather_time(&m, 1, 1, 1e6), 0.0);
    }

    #[test]
    fn hierarchical_walk_matches_closed_form() {
        // pow2 node counts walk recursive doubling (lg n rounds),
        // non-pow2 walk the ring (n-1 rounds) — exactly what the real
        // leader allgather dispatches
        let m = Machine::piz_daint();
        for (n, s) in [(2usize, 4usize), (4, 4), (8, 2), (1, 8), (16, 1), (3, 2), (6, 4), (5, 1)] {
            for bytes in [1e3, 1e6] {
                let walked = hierarchical_allgather_time(&m, n, s, bytes);
                let (nf, sf) = (n as f64, s as f64);
                let p = nf * sf;
                let mut closed = (sf - 1.0) * (m.intra_alpha + bytes * m.intra_beta);
                if n > 1 {
                    let rounds = if n.is_power_of_two() { nf.log2() } else { nf - 1.0 };
                    closed += rounds * m.alpha + (nf - 1.0) * sf * bytes * m.beta;
                }
                closed += (sf - 1.0) * (m.intra_alpha + p * bytes * m.intra_beta);
                assert!(
                    (walked - closed).abs() <= 1e-9 * closed.max(1e-12),
                    "{n}x{s} bytes={bytes}: {walked} vs {closed}"
                );
            }
        }
    }

    #[test]
    fn hierarchy_beats_flat_on_fat_nodes() {
        // per-leader slow-link bytes drop from (p-1)·m to (n-1)·s·m; the
        // gather/broadcast phases move to the ~40x faster intra link, so
        // the schedule wins whenever β_inter/β_intra exceeds ~p
        let m = Machine::fatnode();
        let bytes = 1e6;
        for (n, s) in [(4usize, 4usize), (2, 8)] {
            let flat = allgather_time(&m, n * s, bytes);
            let hier = hierarchical_allgather_time(&m, n, s, bytes);
            assert!(hier < flat, "{n}x{s}: hierarchical {hier} !< flat {flat}");
        }
        // and on piz-daint (1 GPU/node in the paper, mild intra edge) the
        // serial broadcast makes flat the right call — the reason the
        // algorithm choice is a per-bucket cost-model decision, not a
        // global default
        let pd = Machine::piz_daint();
        let flat = allgather_time(&pd, 16, bytes);
        let hier = hierarchical_allgather_time(&pd, 4, 4, bytes);
        assert!(hier > flat, "piz-daint 4x4 should prefer flat: {hier} vs {flat}");
    }

    #[test]
    fn bandwidth_saturates_near_link_rate() {
        // large message, few ranks: effective bw approaches 1/beta
        let m = Machine::muradin();
        let bw = allreduce_bandwidth(&m, 8, 256e6);
        assert!(bw > 3.0e9 && bw < 3.6e9, "bw={bw:e}");
    }

    #[test]
    fn bandwidth_drops_for_small_messages() {
        // latency-dominated regime
        let m = Machine::piz_daint();
        let small = allreduce_bandwidth(&m, 8, 4e3);
        let large = allreduce_bandwidth(&m, 8, 64e6);
        assert!(small < large / 3.0, "small={small:e} large={large:e}");
    }

    #[test]
    fn link_classes_price_distinctly() {
        // Smp delegation is exact (same code path, same floats), and on
        // every preset AF_UNIX beats loopback TCP on both axes, so every
        // schedule walked over Unix is strictly cheaper than Loopback.
        for m in [Machine::muradin(), Machine::piz_daint(), Machine::fatnode()] {
            assert_eq!(
                hierarchical_allgather_time_on(&m, IntraLink::Smp, 4, 4, 1e6),
                hierarchical_allgather_time(&m, 4, 4, 1e6),
                "{}: Smp must reproduce the historical walk",
                m.name
            );
            let (ua, ub) = m.link_params(IntraLink::Unix);
            let (la, lb) = m.link_params(IntraLink::Loopback);
            assert!(ua < la && ub < lb, "{}: unix must beat loopback", m.name);
            for bytes in [4e3, 1e6, 64e6] {
                let uds = allgather_time_on(&m, IntraLink::Unix, 8, bytes);
                let lo = allgather_time_on(&m, IntraLink::Loopback, 8, bytes);
                assert!(uds < lo, "{} allgather bytes={bytes}: {uds} !< {lo}", m.name);
                let uds = allreduce_time_on(&m, IntraLink::Unix, 8, bytes);
                let lo = allreduce_time_on(&m, IntraLink::Loopback, 8, bytes);
                assert!(uds < lo, "{} allreduce bytes={bytes}: {uds} !< {lo}", m.name);
            }
        }
        assert_eq!(IntraLink::Unix.label(), "unix");
        assert_eq!(IntraLink::Smp.label(), "smp");
        assert_eq!(IntraLink::Loopback.label(), "loopback");
    }

    #[test]
    fn presets_resolve() {
        assert_eq!(Machine::by_name("muradin").unwrap().max_ranks, 8);
        assert_eq!(Machine::by_name("piz-daint").unwrap().max_ranks, 128);
        assert_eq!(Machine::by_name("fatnode-straggler").unwrap().name, "fatnode-straggler");
        assert!(Machine::by_name("x").is_none());
    }

    #[test]
    fn straggler_preset_flips_the_schedule_choice() {
        // the straggler degrades only the intra-host links; the inter
        // fabric is untouched, so the flat schedule's cost is unchanged
        // while the hierarchical schedule's intra phases blow up —
        // hierarchy wins on the datasheet fatnode and loses on the
        // straggler, at the same 2x4 topology and message size
        let m = Machine::fatnode();
        let s = Machine::fatnode_straggler();
        assert_eq!(s.alpha, m.alpha);
        assert_eq!(s.beta, m.beta);
        assert!(s.intra_alpha > m.intra_alpha && s.intra_beta > m.intra_beta);
        for bytes in [1e5, 1e6, 8e6] {
            assert_eq!(allgather_time(&s, 8, bytes), allgather_time(&m, 8, bytes));
            let (flat, hier) =
                (allgather_time(&m, 8, bytes), hierarchical_allgather_time(&m, 2, 4, bytes));
            assert!(hier < flat, "fatnode {bytes}: {hier} !< {flat}");
            let (flat, hier) =
                (allgather_time(&s, 8, bytes), hierarchical_allgather_time(&s, 2, 4, bytes));
            assert!(hier > flat, "straggler {bytes}: {hier} !> {flat}");
        }
    }
}
