//! RedSync leader binary.
//!
//! Subcommands:
//!   train       run a data-parallel training job (real execution)
//!   launch      spawn a multi-process job over a socket fabric
//!   simulate    virtual-time scalability simulation (Figs. 7-10)
//!   costmodel   evaluate the §5.5 analytic cost model (Eq. 1/2)
//!   select      micro-benchmark the selection algorithms (Fig. 3)
//!   info        list artifacts, models, machine presets

use redsync::collectives::{Topology, Transport};
use redsync::config::{preset, presets::preset_names, TrainConfig, TransportKind};
use redsync::coordinator::Trainer;
use redsync::models::schema::Manifest;
use redsync::models::zoo;
use redsync::net::{
    free_loopback_addr, MixedFabric, MixedOptions, TcpOptions, TcpTransport, UnixOptions,
    UnixTransport,
};
use redsync::simnet::iteration::{simulate_iteration, speedup, SimConfig, Strategy};
use redsync::simnet::Machine;
use redsync::util::argparse::Args;
use redsync::util::{fmt_bytes, logging};

fn main() {
    logging::init(None);
    let argv: Vec<String> = std::env::args().collect();
    let code = match argv.get(1).map(String::as_str) {
        Some("train") => cmd_train(&argv[2..]),
        Some("launch") => cmd_launch(&argv[2..]),
        Some("simulate") => cmd_simulate(&argv[2..]),
        Some("costmodel") => cmd_costmodel(&argv[2..]),
        Some("select") => cmd_select(&argv[2..]),
        Some("info") => cmd_info(),
        Some("-h") | Some("--help") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    eprintln!(
        "redsync — Residual Gradient Compression for data-parallel training

USAGE: redsync <subcommand> [flags]

SUBCOMMANDS:
  train      run a training job (in-process fabric, or one socket rank)
  launch     spawn a multi-process training job (tcp, unix or auto fabric)
  simulate   virtual-time scalability simulation (paper Figs. 7-10)
  costmodel  evaluate the Eq. 1/2 analytic model for a layer size
  select     micro-benchmark selection algorithms (paper Fig. 3)
  info       list models, artifacts and machine presets

OBSERVABILITY (train/launch):
  --trace-out PATH      write a Chrome trace-event JSON of every rank's spans
  --metrics-addr ADDR   rank 0 serves a Prometheus scrape endpoint here
  --obs-every N         gather cross-rank step-latency stats every N steps
  --recalib-every N     re-run the auto picker on telemetry-calibrated link
                        estimates every N steps, switching algorithms live
                        (requires --algo auto)
  REDSYNC_LOG           log verbosity for the lines these knobs emit

ENVIRONMENT:
  REDSYNC_LOG       log verbosity: error|warn|info|debug|trace (default info)
  REDSYNC_NO_SIMD   set to 1 to force the scalar select/pack/apply kernels
                    (bit-identical to SSE2/AVX2; for debugging and A/B runs)

Presets for train: {}",
        preset_names().join(", ")
    );
}

fn cmd_train(argv: &[String]) -> i32 {
    let args = Args::new("redsync train", "run a data-parallel RGC training job")
        .opt("preset", "smoke", "named preset (see `redsync info`)")
        .opt("config", "", "JSON config file applied over the preset")
        .opt("set", "", "comma-separated key=value overrides")
        .opt(
            "transport",
            "",
            "fabric: local (threads), or one rank per process over tcp, unix \
             (same-host AF_UNIX sockets) or auto (unix intra-node, tcp across nodes)",
        )
        .opt("rank", "", "this process's rank (tcp transport)")
        .opt("port", "", "loopback rendezvous port (shorthand for --rendezvous 127.0.0.1:PORT)")
        .opt("rendezvous", "", "rendezvous address rank 0 listens on (tcp transport)")
        .opt("inflight", "", "pipelined engine: max buckets in flight (default 2)")
        .opt("topology", "", "physical topology NODESxRANKS_PER_NODE, e.g. 2x4 (flat if unset)")
        .opt("algo", "", "bucket collective: sparse | hierarchical | auto (cost-model argmin)")
        .opt("machine", "", "machine preset the auto picker prices against (default muradin)")
        .opt("heartbeat-ms", "", "elastic: heartbeat interval in ms (lease = 4x; default 25)")
        .opt("min-ranks", "", "elastic: abort instead of reshaping below this many ranks")
        .opt("kill-rank", "", "fault injection: kill rank R at step S, as R@S (';'-separated)")
        .opt("stall-rank", "", "fault injection: stall rank R at step S for MS ms, as R@S:MS")
        .opt("rejoin-rank", "", "elastic: rejoin killed rank R at step S, as R@S (local fabric)")
        .opt("ckpt", "", "elastic: RSCK checkpoint path prefix")
        .opt("ckpt-every", "", "elastic: periodic checkpoint cadence in steps (0 = never)")
        .opt("resume", "", "elastic: resume every rank from PREFIX_rank{R}.rsck")
        .opt("ckpt-repo", "", "elastic: content-addressed chunk repo root (delta rejoin)")
        .opt("rejoin-donors", "", "elastic: donors serving a delta rejoin in parallel (default 2)")
        .opt("trace-out", "", "write a Chrome trace-event JSON of every rank's spans here")
        .opt("metrics-addr", "", "serve a Prometheus scrape endpoint on this address (rank 0)")
        .opt("obs-every", "", "gather cross-rank step-latency stats every N steps (0 = never)")
        .opt(
            "recalib-every",
            "",
            "re-run the auto picker on telemetry-calibrated link estimates every N steps \
             and switch bucket algorithms live (requires --algo auto; 0 = plan once)",
        )
        .flag("elastic", "survive worker loss: heartbeats, world reshape, rejoin")
        .flag("pipeline", "overlap bucket selection + collectives on a comm thread pool")
        .flag("csv", "print a CSV row instead of the summary");
    let parsed = match args.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    let mut cfg = match preset(parsed.get("preset")) {
        Some(c) => c,
        None => {
            eprintln!("unknown preset '{}' (have: {})", parsed.get("preset"), preset_names().join(", "));
            return 2;
        }
    };
    if !parsed.get("config").is_empty() {
        if let Err(e) = cfg.apply_file(parsed.get("config")) {
            eprintln!("{e}");
            return 2;
        }
    }
    let mut overrides: Vec<String> = Vec::new();
    if !parsed.get("set").is_empty() {
        overrides.extend(parsed.get("set").split(',').map(str::to_string));
    }
    // dedicated transport/engine flags win over --set
    for key in ["transport", "rank", "rendezvous", "inflight", "topology", "algo", "machine"] {
        if !parsed.get(key).is_empty() {
            overrides.push(format!("{key}={}", parsed.get(key)));
        }
    }
    // elastic knobs: CLI spelling -> config key
    for (flag, key) in [
        ("heartbeat-ms", "heartbeat_ms"),
        ("min-ranks", "min_ranks"),
        ("kill-rank", "kill_rank"),
        ("stall-rank", "stall_rank"),
        ("rejoin-rank", "rejoin_rank"),
        ("ckpt", "ckpt"),
        ("ckpt-every", "ckpt_every"),
        ("resume", "resume"),
        ("ckpt-repo", "ckpt_repo"),
        ("rejoin-donors", "rejoin_donors"),
        ("trace-out", "trace_out"),
        ("metrics-addr", "metrics_addr"),
        ("obs-every", "obs_every"),
        ("recalib-every", "recalib_every"),
    ] {
        if !parsed.get(flag).is_empty() {
            overrides.push(format!("{key}={}", parsed.get(flag)));
        }
    }
    if parsed.get_flag("elastic") {
        overrides.push("elastic=true".into());
    }
    if parsed.get_flag("pipeline") {
        overrides.push("pipeline=true".into());
    }
    if !parsed.get("port").is_empty() && parsed.get("rendezvous").is_empty() {
        overrides.push(format!("rendezvous=127.0.0.1:{}", parsed.get("port")));
    }
    if let Err(e) = cfg.apply_overrides(&overrides) {
        eprintln!("{e}");
        return 2;
    }

    let manifest = match Manifest::load(Manifest::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            return 1;
        }
    };
    match cfg.transport {
        TransportKind::Local => {
            println!("config: {}", cfg.to_json().to_json());
            let trainer = match Trainer::new(&manifest, cfg) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            match trainer.run() {
                Ok(report) => {
                    if parsed.get_flag("csv") {
                        println!("{}", redsync::coordinator::metrics::TrainReport::csv_header());
                        println!("{}", report.csv_row());
                    } else {
                        print!("{}", report.summary());
                    }
                    0
                }
                Err(e) => {
                    eprintln!("training failed: {e}");
                    1
                }
            }
        }
        TransportKind::Tcp | TransportKind::Unix | TransportKind::Auto => {
            train_socket_rank(&manifest, cfg, parsed.get_flag("csv"))
        }
    }
}

/// Run this process's single rank of a socket-fabric job: bootstrap the
/// transport kind the config picked, then hand the connected endpoint
/// to the generic per-rank trainer.
fn train_socket_rank(manifest: &Manifest, cfg: TrainConfig, csv: bool) -> i32 {
    let rank = cfg.rank;
    logging::set_rank(rank);
    if let Err(e) = cfg.validate() {
        eprintln!("{e}");
        return 2;
    }
    if rank == 0 {
        println!("config: {}", cfg.to_json().to_json());
    }
    let label = cfg.transport.label();
    match cfg.transport {
        TransportKind::Tcp => {
            let opts = TcpOptions::new(cfg.world, rank, cfg.rendezvous.clone());
            match TcpTransport::connect(&opts) {
                Ok(t) => {
                    let stats = std::sync::Arc::clone(&t.stats);
                    run_connected_rank(manifest, cfg, csv, &t, &stats, label)
                }
                Err(e) => bootstrap_failed(rank, label, &e),
            }
        }
        TransportKind::Unix => {
            let opts = UnixOptions::new(cfg.world, rank, cfg.rendezvous.clone());
            match UnixTransport::connect(&opts) {
                Ok(t) => {
                    let stats = std::sync::Arc::clone(&t.stats);
                    run_connected_rank(manifest, cfg, csv, &t, &stats, label)
                }
                Err(e) => bootstrap_failed(rank, label, &e),
            }
        }
        TransportKind::Auto => {
            let topo = cfg.topology.unwrap_or_else(|| Topology::flat(cfg.world));
            let opts = MixedOptions::new(cfg.world, rank, cfg.rendezvous.clone(), topo);
            match MixedFabric::connect(&opts) {
                Ok(t) => {
                    let stats = std::sync::Arc::clone(&t.stats);
                    run_connected_rank(manifest, cfg, csv, &t, &stats, label)
                }
                Err(e) => bootstrap_failed(rank, label, &e),
            }
        }
        TransportKind::Local => unreachable!("local transport dispatches to Trainer::run"),
    }
}

fn bootstrap_failed(rank: usize, label: &str, e: &std::io::Error) -> i32 {
    eprintln!("rank {rank}: {label} fabric bootstrap failed: {e}");
    1
}

/// The transport-generic tail of a socket rank: build the trainer, run
/// this rank, report.
fn run_connected_rank<T: Transport + Sync>(
    manifest: &Manifest,
    cfg: TrainConfig,
    csv: bool,
    transport: &T,
    stats: &redsync::collectives::transport::TrafficStats,
    label: &str,
) -> i32 {
    let rank = cfg.rank;
    let trainer = match Trainer::new(manifest, cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("rank {rank}: {e}");
            return 1;
        }
    };
    match trainer.run_rank(transport, Some(stats)) {
        Ok(report) => {
            if rank == 0 {
                if csv {
                    println!("{}", redsync::coordinator::metrics::TrainReport::csv_header());
                    println!("{}", report.csv_row());
                } else {
                    print!("{}", report.summary());
                }
            } else if let Some(note) = &report.status_note {
                eprintln!(
                    "rank {rank}: {note} ({} sent over {label})",
                    fmt_bytes(report.bytes as usize)
                );
            } else {
                eprintln!(
                    "rank {rank}: done ({} sent over {label}, replicas {})",
                    fmt_bytes(report.bytes as usize),
                    if report.replicas_consistent { "consistent" } else { "DRIFTED" }
                );
            }
            // a killed/evicted elastic rank is an expected clean exit;
            // an actually-finished rank must have consistent replicas
            if report.replicas_consistent || report.status_note.is_some() {
                0
            } else {
                eprintln!("rank {rank}: replica drift detected");
                1
            }
        }
        Err(e) => {
            eprintln!("rank {rank}: training failed: {e}");
            1
        }
    }
}

/// Spawn one `redsync train` process per rank over a socket fabric on
/// this host and wait for the fleet.
fn cmd_launch(argv: &[String]) -> i32 {
    let args = Args::new("redsync launch", "spawn a multi-process training job on this host")
        .opt("world", "2", "number of worker processes (one rank each)")
        .opt("transport", "tcp", "socket fabric: tcp | unix (AF_UNIX sockets) | auto (mixed)")
        .opt("port", "0", "rendezvous port on 127.0.0.1 (0 = pick a free one)")
        .opt("preset", "smoke", "named preset forwarded to every rank")
        .opt("config", "", "JSON config file forwarded to every rank")
        .opt("set", "", "comma-separated key=value overrides forwarded to every rank")
        .opt("inflight", "", "pipelined engine: max buckets in flight (default 2)")
        .opt("topology", "", "physical topology NODESxRANKS_PER_NODE forwarded to every rank")
        .opt("algo", "", "bucket collective forwarded to every rank: sparse | hierarchical | auto")
        .opt("machine", "", "machine preset the auto picker prices against, forwarded to every rank")
        .opt("heartbeat-ms", "", "elastic: heartbeat interval in ms, forwarded to every rank")
        .opt("min-ranks", "", "elastic: minimum surviving view size, forwarded to every rank")
        .opt("kill-rank", "", "fault injection: kill rank R at step S (R@S), forwarded")
        .opt("stall-rank", "", "fault injection: stall rank R at step S for MS ms (R@S:MS), forwarded")
        .opt("ckpt-repo", "", "elastic: content-addressed chunk repo root, forwarded to every rank")
        .opt("rejoin-donors", "", "elastic: parallel delta-rejoin donors, forwarded to every rank")
        .opt("trace-out", "", "Chrome trace-event JSON path, forwarded to every rank")
        .opt("metrics-addr", "", "Prometheus scrape address (rank 0 serves it), forwarded")
        .opt("obs-every", "", "cross-rank stats gather cadence in steps, forwarded")
        .opt("recalib-every", "", "calibrated re-planning cadence in steps, forwarded")
        .flag("elastic", "every rank survives worker loss (heartbeats + world reshape)")
        .flag("pipeline", "every rank runs the pipelined sync engine")
        .flag("csv", "rank 0 prints a CSV row instead of the summary");
    let parsed = match args.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let world = parsed.usize("world");
    if world == 0 {
        eprintln!("--world must be >= 1");
        return 2;
    }
    let transport = parsed.get("transport");
    if !matches!(transport, "tcp" | "unix" | "uds" | "auto" | "mixed") {
        eprintln!("--transport must be tcp, unix or auto (got '{transport}')");
        return 2;
    }
    let rendezvous = match parsed.get("port") {
        "" | "0" => free_loopback_addr(),
        port => format!("127.0.0.1:{port}"),
    };
    let exe = match std::env::current_exe() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot locate the redsync binary: {e}");
            return 1;
        }
    };

    eprintln!("launching {world} workers over {transport}, rendezvous {rendezvous}");
    let mut children = Vec::with_capacity(world);
    for rank in 0..world {
        let mut set =
            format!("world={world},transport={transport},rank={rank},rendezvous={rendezvous}");
        if parsed.get_flag("pipeline") {
            set.push_str(",pipeline=true");
        }
        if parsed.get_flag("elastic") {
            set.push_str(",elastic=true");
        }
        if !parsed.get("inflight").is_empty() {
            set.push_str(&format!(",inflight={}", parsed.get("inflight")));
        }
        for key in ["topology", "algo", "machine"] {
            if !parsed.get(key).is_empty() {
                set.push_str(&format!(",{key}={}", parsed.get(key)));
            }
        }
        for (flag, key) in [
            ("heartbeat-ms", "heartbeat_ms"),
            ("min-ranks", "min_ranks"),
            ("kill-rank", "kill_rank"),
            ("stall-rank", "stall_rank"),
            ("ckpt-repo", "ckpt_repo"),
            ("rejoin-donors", "rejoin_donors"),
            ("trace-out", "trace_out"),
            ("metrics-addr", "metrics_addr"),
            ("obs-every", "obs_every"),
            ("recalib-every", "recalib_every"),
        ] {
            if !parsed.get(flag).is_empty() {
                set.push_str(&format!(",{key}={}", parsed.get(flag)));
            }
        }
        if !parsed.get("set").is_empty() {
            set = format!("{},{set}", parsed.get("set"));
        }
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("train").arg("--preset").arg(parsed.get("preset")).arg("--set").arg(&set);
        if !parsed.get("config").is_empty() {
            cmd.arg("--config").arg(parsed.get("config"));
        }
        if parsed.get_flag("csv") {
            cmd.arg("--csv");
        }
        // rank 0 owns stdout (the report); the rest keep stderr for logs
        if rank != 0 {
            cmd.stdout(std::process::Stdio::null());
        }
        match cmd.spawn() {
            Ok(child) => children.push((rank, child)),
            Err(e) => {
                eprintln!("failed to spawn rank {rank}: {e}");
                for (_, mut c) in children {
                    let _ = c.kill();
                }
                return 1;
            }
        }
    }

    let mut code = 0;
    for (rank, mut child) in children {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                eprintln!("rank {rank} exited with {status}");
                code = 1;
            }
            Err(e) => {
                eprintln!("rank {rank}: wait failed: {e}");
                code = 1;
            }
        }
    }
    code
}

fn cmd_simulate(argv: &[String]) -> i32 {
    let args = Args::new("redsync simulate", "virtual-time scalability simulation")
        .opt("model", "vgg16", "profile: alexnet|vgg16|vgg16-cifar|resnet50|resnet44|lstm-ptb|lstm-wiki2")
        .opt("machine", "piz-daint", "machine preset: muradin|piz-daint|fatnode")
        .opt("gpus", "2,4,8,16,32,64,128", "comma-separated world sizes")
        .opt("density", "0.001", "compression density D")
        .opt("batch", "32", "per-GPU batch size")
        .opt("engine", "pipelined", "sync-engine schedule: pipelined|sequential")
        .opt("inflight", "0", "pipelined in-flight window (0 = unbounded)")
        .opt(
            "topology",
            "",
            "NODESxRANKS_PER_NODE; ranks-per-node is held as --gpus sweeps (hierarchical sparse collectives)",
        )
        .flag("breakdown", "print the Fig. 10 phase decomposition");
    let parsed = match args.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let Some(model) = zoo::by_name(parsed.get("model")) else {
        eprintln!("unknown model profile '{}'", parsed.get("model"));
        return 2;
    };
    let Some(machine) = Machine::by_name(parsed.get("machine")) else {
        eprintln!("unknown machine '{}'", parsed.get("machine"));
        return 2;
    };
    let pipeline = match parsed.get("engine") {
        "sequential" | "seq" => false,
        "pipelined" | "pipe" => true,
        other => {
            eprintln!("unknown engine '{other}' (pipelined|sequential)");
            return 2;
        }
    };
    let ranks_per_node = match parsed.get("topology") {
        "" => None,
        spec => match redsync::collectives::Topology::parse(spec) {
            Ok(t) => Some(t.ranks_per_node),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    let base_cfg = SimConfig {
        density: parsed.f64("density"),
        batch_per_gpu: parsed.usize("batch"),
        pipeline,
        inflight: parsed.usize("inflight"),
        ..SimConfig::default()
    };
    // per world size p: hold ranks-per-node, scale the node count
    let cfg_for = |p: usize| -> SimConfig {
        let topology = ranks_per_node
            .filter(|&rpn| p % rpn == 0 && p >= rpn)
            .map(|rpn| (p / rpn, rpn));
        SimConfig { topology, ..base_cfg }
    };
    let cfg = base_cfg;
    let gpus: Vec<usize> = parsed
        .get("gpus")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    if let Some(rpn) = ranks_per_node {
        for &p in &gpus {
            if p % rpn != 0 || p < rpn {
                eprintln!(
                    "# note: {rpn} ranks/node does not divide p={p} — that row uses the flat schedule"
                );
            }
        }
    }

    println!(
        "# {} on {} (density {}, batch/gpu {}, engine {}{}{})",
        model.name,
        machine.name,
        cfg.density,
        cfg.batch_per_gpu,
        if cfg.pipeline { "pipelined" } else { "sequential" },
        if cfg.pipeline && cfg.inflight > 0 {
            format!(" inflight {}", cfg.inflight)
        } else {
            String::new()
        },
        ranks_per_node
            .map(|rpn| format!(", hierarchical over {rpn} ranks/node"))
            .unwrap_or_default(),
    );
    if parsed.get_flag("breakdown") {
        println!("{:>5} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>10}",
            "gpus", "strategy", "compute", "select", "mask", "pack", "comm", "unpack", "iter(ms)");
        for &p in &gpus {
            for strat in [Strategy::Dense, Strategy::Rgc, Strategy::QuantRgc] {
                let b = simulate_iteration(&model, &machine, p, strat, &cfg_for(p));
                println!(
                    "{:>5} {:>10} {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>10.2}",
                    p,
                    strat.label(),
                    100.0 * b.compute / b.component_sum(),
                    100.0 * b.select / b.component_sum(),
                    100.0 * b.mask / b.component_sum(),
                    100.0 * b.pack / b.component_sum(),
                    100.0 * b.comm / b.component_sum(),
                    100.0 * b.unpack / b.component_sum(),
                    b.total * 1e3,
                );
            }
        }
    } else {
        println!("{:>5} {:>12} {:>12} {:>12}", "gpus", "baseline", "RGC", "quant-RGC");
        for &p in &gpus {
            let c = cfg_for(p);
            let d = speedup(&model, &machine, p, Strategy::Dense, &c);
            let r = speedup(&model, &machine, p, Strategy::Rgc, &c);
            let q = speedup(&model, &machine, p, Strategy::QuantRgc, &c);
            println!("{p:>5} {d:>12.2} {r:>12.2} {q:>12.2}");
        }
    }
    0
}

fn cmd_costmodel(argv: &[String]) -> i32 {
    let args = Args::new("redsync costmodel", "evaluate Eq. 1 / Eq. 2 for a layer")
        .opt("machine", "muradin", "machine preset")
        .opt("elems", "16777216", "layer size in elements (64 MB default)")
        .opt("density", "0.001", "density D")
        .opt("gpus", "2,4,8,16,32,64,128", "world sizes");
    let parsed = match args.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let Some(machine) = Machine::by_name(parsed.get("machine")) else {
        eprintln!("unknown machine");
        return 2;
    };
    let m = parsed.f64("elems");
    let d = parsed.f64("density");
    println!(
        "# Eq.1 vs Eq.2: layer {} ({}) density {} on {}",
        m,
        fmt_bytes((m as usize) * 4),
        d,
        machine.name
    );
    println!(
        "{:>5} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "p", "sparse(ms)", "quant(ms)", "dense(ms)", "bw-ratio", "crossover-D"
    );
    for p in parsed.get("gpus").split(',').filter_map(|s| s.trim().parse::<usize>().ok()) {
        use redsync::costmodel::*;
        let ts = t_sparse(&machine, p, m, d, 0.0, PLAIN_WIRE_BYTES);
        let tq = t_sparse(&machine, p, m, d, 0.0, QUANT_WIRE_BYTES);
        let td = t_dense(&machine, p, m);
        let bw = bandwidth_ratio(p, d, PLAIN_WIRE_BYTES);
        let cd = crossover_density(&machine, p, m, 0.0, PLAIN_WIRE_BYTES);
        println!(
            "{:>5} {:>12.3} {:>12.3} {:>12.3} {:>9.1}% {:>10}",
            p,
            ts * 1e3,
            tq * 1e3,
            td * 1e3,
            bw * 100.0,
            cd.map(|v| format!("{v:.2e}")).unwrap_or_else(|| "-".into())
        );
    }
    0
}

fn cmd_select(argv: &[String]) -> i32 {
    let args = Args::new("redsync select", "selection micro-benchmark (Fig. 3)")
        .opt("sizes", "16384,65536,262144,1048576,4194304,16777216", "element counts")
        .opt("density", "0.001", "density D")
        .opt("reps", "5", "repetitions per point");
    let parsed = match args.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let density = parsed.f64("density");
    let reps = parsed.usize("reps");
    use redsync::compression::{exact_topk, threshold_binary_search, trimmed_topk, BinarySearchParams};
    use redsync::util::rng::Pcg32;
    use redsync::util::timer::bench;

    println!("# selection time (ms), density {density}, median of {reps}");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "elems", "exact", "trimmed", "binsearch", "x-trim", "x-bs"
    );
    for size in parsed.get("sizes").split(',').filter_map(|s| s.trim().parse::<usize>().ok()) {
        let mut rng = Pcg32::seeded(size as u64);
        let mut x = vec![0f32; size];
        rng.fill_normal(&mut x, 1.0);
        let k = ((size as f64 * density).ceil() as usize).max(1);
        let te = bench(reps, || exact_topk(&x, k, None)).median;
        let tt = bench(reps, || trimmed_topk(&x, k, 0.2, None)).median;
        let tb =
            bench(reps, || threshold_binary_search(&x, k, BinarySearchParams::default(), None))
                .median;
        println!(
            "{:>10} {:>12.3} {:>12.3} {:>12.3} {:>9.1}x {:>9.1}x",
            size,
            te * 1e3,
            tt * 1e3,
            tb * 1e3,
            te / tt,
            te / tb
        );
    }
    0
}

fn cmd_info() -> i32 {
    println!("machine presets:");
    for m in [Machine::muradin(), Machine::piz_daint(), Machine::fatnode()] {
        println!(
            "  {:<10} alpha {:.0}us  bw {:.1} GB/s  intra bw {:.0} GB/s  max ranks {}",
            m.name,
            m.alpha * 1e6,
            1e-9 / m.beta,
            1e-9 / m.intra_beta,
            m.max_ranks
        );
    }
    println!("\nmodel profiles (simulation):");
    for p in zoo::all_profiles() {
        println!(
            "  {:<12} {:>8} params ({})  {:.2} GFlop/sample  {} layers{}",
            p.name,
            p.total_elems(),
            fmt_bytes(p.model_bytes()),
            p.fwd_gflops_per_sample,
            p.layers.len(),
            if p.is_rnn { "  [RNN]" } else { "" }
        );
    }
    println!("\ntrain presets: {}", preset_names().join(", "));
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => {
            println!("\nartifacts ({}):", m.dir.display());
            for (name, schema) in &m.models {
                println!(
                    "  {:<10} {:<4} {:>10} params  file {}",
                    name,
                    schema.kind,
                    schema.param_count,
                    schema.file.file_name().unwrap().to_string_lossy()
                );
            }
            println!("  compression-op buckets: {:?}", m.buckets);
        }
        Err(e) => println!("\nartifacts: not built ({e}); run `make artifacts`"),
    }
    0
}
