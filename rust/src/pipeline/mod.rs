//! Synchronization engines: how one step's compressed buckets reach the
//! fabric.
//!
//! The paper's end-to-end speedups (§5.3, Figs. 7-9) depend on *hiding*
//! communication behind computation, not just shrinking it.  This module
//! turns the transport subsystem into that wall-clock win by making the
//! per-step bucket synchronization a strategy:
//!
//! * [`Sequential`] — the historical schedule and the correctness
//!   *oracle*: produce (accumulate → select → mask → pack) and allgather
//!   each bucket inline on the training thread, one after another.
//! * [`Pipelined`] — hands each bucket, in backward order, to a
//!   communication thread pool of `inflight` workers.  While bucket *b*'s
//!   allgather waits on the wire, bucket *b+1* is selecting and packing —
//!   selection, encoding and the collective all overlap across buckets.
//!   Traffic is tag-multiplexed per bucket over one fabric endpoint
//!   (`collectives::mux`), so concurrent collectives never steal each
//!   other's messages.
//!
//! ## Determinism
//!
//! Both engines produce **bit-identical** parameters (pinned by
//! `tests/pipeline.rs`, on the in-process and the TCP fabric):
//!
//! 1. `BucketState::produce` is pure given (state, grads, density) — the
//!    thread that runs it cannot affect the packed bits.
//! 2. Each bucket's collective runs on a private tag channel whose
//!    per-(src, dst, tag) order is preserved end-to-end, so the gathered
//!    blobs match the sequential run's exactly.
//! 3. [`SyncEngine::sync_step`] delivers finished buckets to the apply
//!    callback in *bucket order*, whatever order they completed in — the
//!    barrier at the optimizer step.  Scatter-adds therefore run in the
//!    same float order as the sequential engine.
//!
//! The only observable difference is wall-clock and one tag word per
//! message of mux overhead (audited exactly in `tests/pipeline.rs`).
//!
//! ## Topology
//!
//! Both engines drive *group* collectives through a
//! [`crate::collectives::Communicator`]: each bucket carries a planned
//! algorithm ([`BucketState::algo`]) — flat sparse allgather or the
//! hierarchical (intra-node gather → leader allgather → intra-node
//! broadcast) schedule — chosen statically (`--algo`) or by the
//! cost-model argmin per bucket (`--algo auto`,
//! `costmodel::pick_algo`; dense-picked buckets are demoted to the
//! worker's dense allreduce path before the engine sees them).  Both
//! algorithms deliver bit-identical gathered blobs (`tests/topology.rs`).
//!
//! ## Constraints
//!
//! The engine choice must be uniform across ranks (tagged and untagged
//! wire formats don't mix), and the pipelined engine cannot drive device
//! selection — PJRT clients are thread-bound (`config::validate`
//! rejects the combination).

pub mod bucket;
mod pipelined;
mod sequential;

pub use bucket::{build_buckets, BucketState, LayerSpec, Produced};
pub use pipelined::Pipelined;
pub use sequential::Sequential;

use crate::collectives::group::Algo;
use crate::collectives::Gathered;
use crate::compression::message::{view_plain, view_quant};
use crate::util::timer::PhaseTimer;

/// Mux tag reserved for the training loop's own collectives (dense
/// allreduce, loss averaging, replica-hash checks).
pub const CTRL_TAG: u32 = 0;
/// Bucket `b` communicates on tag `BUCKET_TAG_BASE + b`.
pub const BUCKET_TAG_BASE: u32 = 1;

/// One synchronized bucket, delivered to the apply callback in bucket
/// order.
pub struct BucketDone {
    /// Bucket index (backward order, 0 = deepest layers).
    pub bucket: usize,
    /// (layer index, quantized) per layer, in packing order — everything
    /// the decompression walk needs.
    pub layers: Vec<(usize, bool)>,
    /// Gathered per-rank blobs in one owned buffer, indexed by rank.
    pub gathered: Gathered,
    /// Elements this rank selected across the bucket's layers.
    pub selected: usize,
    /// Total elements across the bucket's layers.
    pub elems: usize,
    /// Words in this rank's packed blob — the per-rank message size the
    /// cost model prices (`obs::calib` fits α/β against it).
    pub msg_words: usize,
    /// Measured wall seconds of this bucket's collective (the
    /// calibration observation paired with `msg_words`).
    pub comm_secs: f64,
}

impl BucketDone {
    /// The §5.4 decompression walk: scatter-add every rank's gathered
    /// messages for this bucket into the parameter buffers, scaled by
    /// `scale` (the worker passes `-lr / world`).  Parses each message
    /// *in place* (`view_plain`/`view_quant`) and scatters straight from
    /// the gather buffer — zero heap traffic, float-op identical to the
    /// historical owned-decode walk (pinned by the view-parity proptest
    /// in `tests/proptests.rs`).  The single shared implementation
    /// behind the worker, the determinism tests and the smoke bench —
    /// so the bit-identical pin always covers the production walk.
    pub fn apply_to(&self, params: &mut [Vec<f32>], scale: f32) -> Result<(), String> {
        for rank_blob in self.gathered.blocks() {
            let mut off = 0usize;
            for &(li, quantized) in &self.layers {
                if quantized {
                    let (q, used) = view_quant(&rank_blob[off..])
                        .map_err(|e| format!("layer {li}: {e}"))?;
                    let add = q.mean * scale;
                    for &i in q.indices {
                        params[li][i as usize] += add;
                    }
                    off += used;
                } else {
                    let (s, used) = view_plain(&rank_blob[off..])
                        .map_err(|e| format!("layer {li}: {e}"))?;
                    s.scatter_add(&mut params[li], scale);
                    off += used;
                }
            }
        }
        Ok(())
    }
}

/// Per-step compressed-bucket synchronization strategy.
///
/// The worker calls [`sync_step`](SyncEngine::sync_step) once per
/// non-warm-up step after the dense layers' allreduce; the engine owns
/// the compressed layers' residual state across steps.
pub trait SyncEngine {
    /// Engine label for logs and reports.
    fn name(&self) -> &'static str;

    fn n_buckets(&self) -> usize;

    /// Synchronize every bucket for one step.  `grads` is the full
    /// per-layer gradient set, indexed by schema layer id; engines read
    /// only their buckets' layers.  Calls `apply` exactly once per bucket
    /// **in bucket order** — the deterministic reduction point — and
    /// returns after all buckets are applied (the optimizer barrier).
    ///
    /// Phase seconds for mask/select/pack/comm are merged into `timer`
    /// as *component* times (the Fig. 10 convention): under the
    /// pipelined engine they overlap in wall-clock, so they sum to more
    /// than the elapsed time.
    fn sync_step(
        &mut self,
        grads: &[Vec<f32>],
        density: f64,
        timer: &mut PhaseTimer,
        apply: &mut dyn FnMut(BucketDone) -> Result<(), String>,
    ) -> Result<(), String>;

    /// Snapshot the engine-owned per-layer compressor state as
    /// `(layer id, residual V, momentum U)` clones — taken at step
    /// boundaries by the elastic driver, whose reshape rollback and
    /// `RSCK` checkpoints must carry the unsent gradient mass (DGC:
    /// residuals are part of the training trajectory).  Engines that
    /// own no residual state may return nothing.
    fn export_layer_states(&self) -> Vec<(usize, Vec<f32>, Vec<f32>)> {
        Vec::new()
    }

    /// Re-plan the per-bucket collective algorithms at a step barrier
    /// (`--recalib-every`): `algos[b]` becomes bucket `b`'s algorithm
    /// from the next `sync_step` on.  Sparse and hierarchical deliver
    /// bit-identical gathered blobs, so a live switch between them
    /// cannot perturb training; `Dense` is rejected by the bucket state
    /// (dense buckets are demoted at plan time, never switched to).
    /// Engines without per-bucket plans ignore the call.
    fn set_algos(&mut self, algos: &[Algo]) {
        let _ = algos;
    }
}
