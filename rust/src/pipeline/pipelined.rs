//! The pipelined sync engine: a bounded comm thread pool overlaps bucket
//! selection/encoding with the collectives.
//!
//! Each step, every bucket becomes a task owning its compressor state
//! and a snapshot of its layers' gradients.  `inflight` pool workers pop
//! tasks in backward (bucket) order — the bounded in-flight window — run
//! produce (select → encode) and the bucket's allgather on its private
//! tag channel, and report back.  The engine collects results, restores
//! each bucket's state, and applies them in bucket order regardless of
//! completion order: the optimizer-step barrier that keeps reductions
//! deterministic.
//!
//! ## Progress
//!
//! Workers pop buckets in order, so the globally lowest-numbered
//! incomplete bucket is in (or next into) every rank's window; sends are
//! buffered, so by induction on collective rounds that bucket always
//! completes — the window never deadlocks.  Tag reuse across steps is
//! safe because per-(src, dst, tag) FIFO order is end-to-end (see
//! `collectives::mux`).
//!
//! ## Failure
//!
//! A produce/apply error aborts the step; in-flight peers then observe a
//! dead fabric and panic out of their collectives (clean `Err` surfaces
//! are for `recv_checked` users — a dead peer mid-collective is fatal by
//! the transport contract).

use super::bucket::BucketState;
use super::{BucketDone, SyncEngine, BUCKET_TAG_BASE};
use crate::collectives::group::{Algo, Communicator, Topology};
use crate::collectives::mux::{TagChannel, TagMux};
use crate::collectives::{Gathered, Transport};
use crate::compression::CompressorConfig;
use crate::coordinator::metrics::phase;
use crate::obs::{self, SpanCtx, SpanRing};
use crate::util::timer::PhaseTimer;
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

/// One in-flight bucket: owned state + this step's gradient slices
/// (borrowed — the caller's gradient set outlives the step's scope, so
/// no copies cross the thread boundary).
struct Task<'g> {
    bucket: usize,
    state: BucketState,
    grads: Vec<&'g [f32]>,
}

/// What a pool worker hands back.
struct TaskOut {
    state: BucketState,
    gathered: Gathered,
    selected: usize,
    elems: usize,
    msg_words: usize,
    mask_secs: f64,
    select_secs: f64,
    pack_secs: f64,
    comm_secs: f64,
}

/// The pipelined engine.  `T` is the fabric endpoint the mux wraps —
/// `&TcpTransport`, `&LocalTransport`, or an owned endpoint in tests.
pub struct Pipelined<T: Transport + Send + Sync> {
    mux: Arc<TagMux<T>>,
    /// Topology each bucket's communicator is built over (flat by
    /// default); buckets planned `Hierarchical` run the three-phase
    /// schedule on their private tag channel.
    topo: Topology,
    /// Bucket states, parked here between steps (`None` while in flight).
    slots: Vec<Option<BucketState>>,
    /// (layer index, quantized) per bucket — the stable copy handed out
    /// in [`BucketDone`] while the state itself is on a pool thread.
    groups: Vec<Vec<(usize, bool)>>,
    inflight: usize,
    cc: CompressorConfig,
    /// One registered span ring per comm lane when tracing is on
    /// (created once at construction — the per-step thread scope only
    /// clones `Arc`s, keeping the traced steady state allocation-free).
    rings: Vec<SpanRing>,
    step: u32,
}

impl<T: Transport + Send + Sync> Pipelined<T> {
    /// `mux` must reserve tags `BUCKET_TAG_BASE .. BUCKET_TAG_BASE +
    /// buckets.len()` (plus the control tag below them).  Flat (one-node)
    /// topology: every bucket collective runs over the full world.
    pub fn new(
        mux: Arc<TagMux<T>>,
        buckets: Vec<BucketState>,
        inflight: usize,
        cc: CompressorConfig,
    ) -> Pipelined<T> {
        let topo = Topology::flat(mux.world());
        Pipelined::with_topology(mux, topo, buckets, inflight, cc)
    }

    /// A pool over a physical topology; per-bucket algorithms come from
    /// the buckets' plan ([`BucketState::algo`]).
    pub fn with_topology(
        mux: Arc<TagMux<T>>,
        topo: Topology,
        buckets: Vec<BucketState>,
        inflight: usize,
        cc: CompressorConfig,
    ) -> Pipelined<T> {
        assert!(inflight >= 1, "the in-flight window must admit at least one bucket");
        assert_eq!(
            topo.world(),
            mux.world(),
            "topology {} does not cover the fabric's {} ranks",
            topo.label(),
            mux.world()
        );
        assert!(
            mux.n_tags() >= BUCKET_TAG_BASE + buckets.len() as u32,
            "mux reserves too few tags for {} buckets",
            buckets.len()
        );
        let groups: Vec<Vec<(usize, bool)>> = buckets
            .iter()
            .map(|b| b.specs().map(|s| (s.li, s.quantize)).collect())
            .collect();
        let rings = if obs::enabled() {
            (0..inflight.min(buckets.len()))
                .map(|lane| {
                    obs::ring(mux.rank(), obs::LANE_COMM_BASE + lane as u32, obs::DEFAULT_CAP)
                })
                .collect()
        } else {
            Vec::new()
        };
        Pipelined {
            mux,
            topo,
            slots: buckets.into_iter().map(Some).collect(),
            groups,
            inflight,
            cc,
            rings,
            step: 0,
        }
    }
}

impl<T: Transport + Send + Sync> SyncEngine for Pipelined<T> {
    fn name(&self) -> &'static str {
        "pipelined"
    }

    fn n_buckets(&self) -> usize {
        self.slots.len()
    }

    fn export_layer_states(&self) -> Vec<(usize, Vec<f32>, Vec<f32>)> {
        // between steps every bucket state is parked; mid-step (a bucket
        // in flight on the pool) there is no consistent snapshot to take,
        // and the elastic driver only calls this at step boundaries
        self.slots
            .iter()
            .flat_map(|slot| {
                let b = slot.as_ref().expect("bucket state parked between steps");
                b.layer_states()
                    .map(|(li, v, u)| (li, v.to_vec(), u.to_vec()))
                    .collect::<Vec<_>>()
            })
            .collect()
    }

    fn set_algos(&mut self, algos: &[Algo]) {
        // only legal between steps, when every bucket state is parked
        assert_eq!(algos.len(), self.slots.len(), "re-plan must cover every bucket");
        for (slot, &a) in self.slots.iter_mut().zip(algos) {
            slot.as_mut().expect("bucket state parked between steps").set_algo(a);
        }
    }

    fn sync_step(
        &mut self,
        grads: &[Vec<f32>],
        density: f64,
        timer: &mut PhaseTimer,
        apply: &mut dyn FnMut(BucketDone) -> Result<(), String>,
    ) -> Result<(), String> {
        let n = self.slots.len();
        if n == 0 {
            return Ok(());
        }
        // Queue every bucket's task in backward order.  Each task borrows
        // its layers' gradient slices (the barrier below keeps `grads`
        // alive past every worker) and owns its bucket state outright —
        // the state moves (never copies) to whichever worker runs it.
        let mut tasks = VecDeque::with_capacity(n);
        for b in 0..n {
            let state = self.slots[b].take().expect("bucket state parked between steps");
            let g: Vec<&[f32]> = state.specs().map(|s| grads[s.li].as_slice()).collect();
            tasks.push_back(Task { bucket: b, state, grads: g });
        }
        let queue = Mutex::new(tasks);
        let (res_tx, res_rx) = channel::<(usize, Result<TaskOut, String>)>();
        let workers = self.inflight.min(n);
        let step = self.step;
        self.step = self.step.wrapping_add(1);

        thread::scope(|s| -> Result<(), String> {
            for lane in 0..workers {
                let mux = Arc::clone(&self.mux);
                let tx = res_tx.clone();
                let cc = self.cc;
                let topo = self.topo;
                let queue = &queue;
                let ring = self.rings.get(lane).cloned();
                s.spawn(move || loop {
                    let task = queue.lock().unwrap().pop_front();
                    let Some(mut task) = task else { return };
                    let ctx = ring
                        .as_ref()
                        .map(|r| SpanCtx { ring: r, step, tag: task.bucket as u32 });
                    let out = match task.state.produce_traced(&task.grads, density, &cc, None, ctx)
                    {
                        Ok(p) => {
                            let chan = TagChannel::new(
                                Arc::clone(&mux),
                                BUCKET_TAG_BASE + task.bucket as u32,
                            );
                            let comm = Communicator::new(chan, topo);
                            let t0 = Instant::now();
                            // borrows the bucket's persistent blob; the
                            // state (blob included) moves back afterwards
                            let guard = ring
                                .as_ref()
                                .map(|r| r.guard(obs::SPAN_COMM_SPARSE, step, task.bucket as u32));
                            let msg_words = task.state.blob().len();
                            let gathered = comm.allgather(task.state.algo(), task.state.blob());
                            drop(guard);
                            Ok(TaskOut {
                                state: task.state,
                                gathered,
                                selected: p.selected,
                                elems: p.elems,
                                msg_words,
                                mask_secs: p.mask_secs,
                                select_secs: p.select_secs,
                                pack_secs: p.pack_secs,
                                comm_secs: t0.elapsed().as_secs_f64(),
                            })
                        }
                        Err(e) => Err(e),
                    };
                    if tx.send((task.bucket, out)).is_err() {
                        return; // collector gone (step aborted)
                    }
                });
            }
            drop(res_tx);

            // Collect and apply in bucket order regardless of completion
            // order — the deterministic barrier at the optimizer step.
            let mut parked: BTreeMap<usize, Result<TaskOut, String>> = BTreeMap::new();
            for expect in 0..n {
                let out = loop {
                    if let Some(o) = parked.remove(&expect) {
                        break o;
                    }
                    match res_rx.recv() {
                        Ok((b, o)) if b == expect => break o,
                        Ok((b, o)) => {
                            parked.insert(b, o);
                        }
                        Err(_) => return Err("pipelined sync: comm pool hung up".into()),
                    }
                };
                let out = out.map_err(|e| format!("bucket {expect}: {e}"))?;
                timer.add(phase::MASK, out.mask_secs);
                timer.add(phase::SELECT, out.select_secs);
                timer.add(phase::PACK, out.pack_secs);
                timer.add(phase::COMM_SPARSE, out.comm_secs);
                self.slots[expect] = Some(out.state);
                apply(BucketDone {
                    bucket: expect,
                    layers: self.groups[expect].clone(),
                    gathered: out.gathered,
                    selected: out.selected,
                    elems: out.elems,
                    msg_words: out.msg_words,
                    comm_secs: out.comm_secs,
                })?;
            }
            Ok(())
        })
    }
}
