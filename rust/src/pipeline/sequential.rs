//! The sequential sync engine: the historical inline schedule, kept as
//! the correctness oracle the pipelined engine is pinned against.

use super::bucket::BucketState;
use super::{BucketDone, SyncEngine};
use crate::collectives::group::{Algo, Communicator, Topology};
use crate::collectives::Transport;
use crate::compression::CompressorConfig;
use crate::coordinator::metrics::phase;
use crate::obs::{self, SpanCtx, SpanRing};
use crate::runtime::DeviceSelector;
use crate::util::timer::PhaseTimer;
use std::time::Instant;

/// Produce + allgather every bucket inline on the calling thread, in
/// bucket order, dispatching each bucket's planned collective (flat or
/// hierarchical) through the [`Communicator`].  The only engine that
/// can drive device selection (the PJRT client is owned by this
/// thread).
pub struct Sequential<'a, T: Transport> {
    comm: Communicator<&'a T>,
    device: Option<DeviceSelector<'a>>,
    buckets: Vec<BucketState>,
    cc: CompressorConfig,
    /// Registered span ring (main lane) when tracing is on; `None` keeps
    /// the steady state identical to the pre-obs engine.
    ring: Option<SpanRing>,
    step: u32,
}

impl<'a, T: Transport> Sequential<'a, T> {
    /// The flat (one-node topology) engine — every bucket's collective
    /// runs over the full world, the pre-topology schedule.
    pub fn new(
        transport: &'a T,
        device: Option<DeviceSelector<'a>>,
        buckets: Vec<BucketState>,
        cc: CompressorConfig,
    ) -> Sequential<'a, T> {
        let topo = Topology::flat(transport.world());
        Sequential::with_topology(transport, topo, device, buckets, cc)
    }

    /// An engine over a physical topology: buckets planned
    /// `Hierarchical` run the three-phase schedule over the derived
    /// process groups.
    pub fn with_topology(
        transport: &'a T,
        topo: Topology,
        device: Option<DeviceSelector<'a>>,
        buckets: Vec<BucketState>,
        cc: CompressorConfig,
    ) -> Sequential<'a, T> {
        let ring =
            obs::enabled().then(|| obs::ring(transport.rank(), obs::LANE_MAIN, obs::DEFAULT_CAP));
        Sequential { comm: Communicator::new(transport, topo), device, buckets, cc, ring, step: 0 }
    }
}

impl<T: Transport> SyncEngine for Sequential<'_, T> {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    fn export_layer_states(&self) -> Vec<(usize, Vec<f32>, Vec<f32>)> {
        self.buckets
            .iter()
            .flat_map(|b| b.layer_states().map(|(li, v, u)| (li, v.to_vec(), u.to_vec())))
            .collect()
    }

    fn set_algos(&mut self, algos: &[Algo]) {
        assert_eq!(algos.len(), self.buckets.len(), "re-plan must cover every bucket");
        for (state, &a) in self.buckets.iter_mut().zip(algos) {
            state.set_algo(a);
        }
    }

    fn sync_step(
        &mut self,
        grads: &[Vec<f32>],
        density: f64,
        timer: &mut PhaseTimer,
        apply: &mut dyn FnMut(BucketDone) -> Result<(), String>,
    ) -> Result<(), String> {
        let step = self.step;
        self.step = self.step.wrapping_add(1);
        for (b, state) in self.buckets.iter_mut().enumerate() {
            let ctx = self.ring.as_ref().map(|r| SpanCtx { ring: r, step, tag: b as u32 });
            let grefs: Vec<&[f32]> = state.specs().map(|s| grads[s.li].as_slice()).collect();
            let produced = state
                .produce_traced(&grefs, density, &self.cc, self.device.as_ref(), ctx)
                .map_err(|e| format!("bucket {b}: {e}"))?;
            timer.add(phase::MASK, produced.mask_secs);
            timer.add(phase::SELECT, produced.select_secs);
            timer.add(phase::PACK, produced.pack_secs);
            let algo = state.algo();
            let msg_words = state.blob().len();
            // the collective borrows the bucket's persistent blob
            let _g = self.ring.as_ref().map(|r| r.guard(obs::SPAN_COMM_SPARSE, step, b as u32));
            let t0 = Instant::now();
            let gathered =
                timer.time(phase::COMM_SPARSE, || self.comm.allgather(algo, state.blob()));
            let comm_secs = t0.elapsed().as_secs_f64();
            drop(_g);
            apply(BucketDone {
                bucket: b,
                layers: state.specs().map(|s| (s.li, s.quantize)).collect(),
                gathered,
                selected: produced.selected,
                elems: produced.elems,
                msg_words,
                comm_secs,
            })?;
        }
        Ok(())
    }
}
