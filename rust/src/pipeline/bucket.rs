//! Per-bucket compressor state and the produce step shared by both sync
//! engines.
//!
//! A *bucket* is one §5.3 fusion group of compressed layers: the unit of
//! synchronization (one allgather per bucket per step) and, under the
//! pipelined engine, the unit of parallelism — each in-flight bucket owns
//! its layers' residual/alternator/threshold state outright, so the task
//! can run on any thread without sharing.  `produce` is the entire
//! GPU-side half of Algorithm 4 for one bucket: accumulate (momentum
//! correction) → select → mask → pack, identical math on either engine —
//! the root of the engines' bit-for-bit agreement.

use crate::collectives::group::Algo;
use crate::collectives::FusionPlan;
use crate::compression::message::{pack_plain_into, pack_quant_into};
use crate::compression::{
    exact_topk_into, threshold_binary_search_into, trimmed_topk_into, Accumulation,
    CompressorConfig, Method, ResidualState, SelectScratch, SignAlternator,
};
use crate::obs::{self, PhaseClock, SpanCtx};
use crate::runtime::DeviceSelector;

/// Static description of one compressed layer (everything `produce`
/// needs besides the evolving state).
#[derive(Clone, Debug)]
pub struct LayerSpec {
    /// Layer index in the model schema — names the parameter buffer the
    /// gathered result is applied to.
    pub li: usize,
    /// Element count.
    pub n: usize,
    /// Selection method (Alg. 5 dispatch, decided once).
    pub method: Method,
    /// Quantize this layer's messages (§5.2.3; never the output layer).
    pub quantize: bool,
}

/// Mutable compressor state for one layer of a bucket.
pub struct BucketLayer {
    pub spec: LayerSpec,
    /// Residual + momentum state (Alg. 4).
    residual: ResidualState,
    /// Sign alternation for quantized layers.
    alternator: SignAlternator,
    /// Cached binary-search threshold (+ age) for the sampled variant.
    cached_thr: Option<(f32, usize)>,
}

/// One fusion bucket's compressor state; owned by a sync engine and, in
/// the pipelined engine, moved into the in-flight task.  Carries the
/// bucket's persistent scratch: the wire blob `produce` packs in place
/// and the selection buffers its layers share — after warm-up a
/// steady-state `produce` allocates nothing (pinned by
/// `tests/alloc_steady.rs`).
pub struct BucketState {
    pub(crate) layers: Vec<BucketLayer>,
    /// Collective algorithm the plan chose for this bucket (flat sparse
    /// allgather by default; `Hierarchical` under a topology plan —
    /// never `Dense`, dense-picked buckets are demoted before the
    /// engine sees them).
    algo: Algo,
    /// Persistent wire blob: cleared and repacked in place each step;
    /// the collective borrows it (`Communicator::allgather` takes
    /// `&[u32]`), so its capacity survives across steps.
    blob: Vec<u32>,
    /// Reusable selection scratch, shared by the bucket's layers (they
    /// select serially inside `produce`).
    scratch: SelectScratch,
}

/// What one `produce` yields besides the packed blob (readable via
/// [`BucketState::blob`] afterwards): selection totals plus the
/// per-phase seconds the engines merge into the worker's timer.
pub struct Produced {
    /// Elements this rank selected across the bucket's layers.
    pub selected: usize,
    /// Total elements across the bucket's layers.
    pub elems: usize,
    pub mask_secs: f64,
    pub select_secs: f64,
    pub pack_secs: f64,
}

/// Group compressed-layer specs (already in backward order) into fusion
/// buckets under `fusion_cap_elems` (§5.3 greedy first-fit; 0 disables
/// fusion — one bucket per layer) and seed each layer's state.
pub fn build_buckets(
    specs: &[LayerSpec],
    fusion_cap_elems: usize,
    accumulation: Accumulation,
) -> Vec<BucketState> {
    let groups: Vec<Vec<usize>> = if fusion_cap_elems > 0 && !specs.is_empty() {
        let sizes: Vec<usize> = specs.iter().map(|s| s.n).collect();
        FusionPlan::greedy(&sizes, fusion_cap_elems)
            .buckets
            .into_iter()
            .map(|b| b.layers.into_iter().map(|(pos, _)| pos).collect())
            .collect()
    } else {
        (0..specs.len()).map(|i| vec![i]).collect()
    };
    groups
        .into_iter()
        .map(|group| BucketState {
            layers: group
                .into_iter()
                .map(|pos| {
                    let spec = specs[pos].clone();
                    BucketLayer {
                        residual: ResidualState::new(spec.n, accumulation),
                        alternator: SignAlternator::new(),
                        cached_thr: None,
                        spec,
                    }
                })
                .collect(),
            algo: Algo::Sparse,
            blob: Vec::new(),
            scratch: SelectScratch::new(),
        })
        .collect()
}

fn k_for(n: usize, density: f64) -> usize {
    ((n as f64 * density).ceil() as usize).clamp(1, n)
}

impl BucketState {
    /// Layer specs in packing order.
    pub fn specs(&self) -> impl Iterator<Item = &LayerSpec> {
        self.layers.iter().map(|l| &l.spec)
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// The collective algorithm planned for this bucket.
    pub fn algo(&self) -> Algo {
        self.algo
    }

    /// Assign the planned algorithm (`Sparse` or `Hierarchical`; a
    /// dense-picked bucket is demoted to the dense path instead of ever
    /// reaching an engine).
    pub fn set_algo(&mut self, algo: Algo) {
        assert_ne!(algo, Algo::Dense, "dense buckets are demoted, not synced");
        self.algo = algo;
    }

    /// The packed wire blob of the last [`produce`](Self::produce) —
    /// what the engines hand (borrowed) to the bucket's collective.
    pub fn blob(&self) -> &[u32] {
        &self.blob
    }

    /// Per-layer `(layer id, residual V, momentum U)` views — what the
    /// elastic snapshot/checkpoint captures each step boundary
    /// (DESIGN.md §Elastic-Membership).
    pub fn layer_states(&self) -> impl Iterator<Item = (usize, &[f32], &[f32])> {
        self.layers
            .iter()
            .map(|l| (l.spec.li, l.residual.residual(), l.residual.momentum_buf()))
    }

    /// Restore one layer's residual/momentum buffers (inverse of
    /// [`layer_states`](Self::layer_states)); the selection caches
    /// (threshold, sign alternator) restart cold — deterministically, so
    /// a rebuilt engine matches a fresh run resumed from the same
    /// checkpoint bit-for-bit.
    pub fn load_layer_state(&mut self, idx: usize, v: &[f32], u: &[f32]) {
        let layer = &mut self.layers[idx];
        assert_eq!(v.len(), layer.spec.n, "residual length for layer {}", layer.spec.li);
        assert_eq!(u.len(), layer.spec.n, "momentum length for layer {}", layer.spec.li);
        layer.residual.set_buffers(v.to_vec(), u.to_vec());
    }

    /// The GPU-side half of Alg. 4 for this bucket: accumulate → select
    /// → mask → pack each layer in order, into the bucket's persistent
    /// allgather blob ([`blob`](Self::blob)).  `grads[i]` is this step's
    /// gradient for `layers[i]` (same order).
    ///
    /// Pure given (state, grads, density): the produced blob is identical
    /// no matter which thread runs it — the pipelined engine's
    /// determinism rests here.  Selection and packing run entirely in
    /// the bucket's reusable scratch: zero heap allocation once the
    /// buffers are warm.
    pub fn produce(
        &mut self,
        grads: &[&[f32]],
        density: f64,
        cc: &CompressorConfig,
        device: Option<&DeviceSelector>,
    ) -> Result<Produced, String> {
        self.produce_traced(grads, density, cc, device, None)
    }

    /// [`produce`](Self::produce) with an optional trace context: when
    /// `ctx` is set, every phase lap is also recorded as a span on the
    /// caller's ring — the phase-seconds totals and the timeline come
    /// from the *same* clock reads (obs's `PhaseClock` is the one
    /// stopwatch; the old private copy here is gone).  Tracing implies
    /// timing: a span needs the interval whether or not
    /// `CompressorConfig::timing` asked for seconds.
    pub fn produce_traced(
        &mut self,
        grads: &[&[f32]],
        density: f64,
        cc: &CompressorConfig,
        device: Option<&DeviceSelector>,
        ctx: Option<SpanCtx<'_>>,
    ) -> Result<Produced, String> {
        assert_eq!(grads.len(), self.layers.len(), "one gradient per bucket layer");
        self.blob.clear();
        let mut out =
            Produced { selected: 0, elems: 0, mask_secs: 0.0, select_secs: 0.0, pack_secs: 0.0 };
        let ctx = ctx.as_ref();
        let mut clock = PhaseClock::start(cc.timing || ctx.is_some());
        for (layer, grad) in self.layers.iter_mut().zip(grads) {
            let n = layer.spec.n;
            debug_assert_eq!(grad.len(), n);

            // momentum correction (Alg. 4 lines 11-19): via the fused L1
            // kernel on the device path, host otherwise
            let dev_accum = device.filter(|d| d.ops.has_momentum_accum()).map(|d| &d.ops);
            if let Some(ops) = dev_accum {
                let (momentum, nesterov) = match layer.residual.accumulation {
                    Accumulation::Sgd => (0.0, false),
                    Accumulation::Momentum { momentum } => (momentum, false),
                    Accumulation::Nesterov { momentum } => (momentum, true),
                };
                let (v, u) = ops
                    .momentum_accum(
                        layer.residual.residual(),
                        layer.residual.momentum_buf(),
                        grad,
                        momentum,
                        nesterov,
                    )
                    .map_err(|e| format!("momentum_accum: {e}"))?;
                layer.residual.set_buffers(v, u);
            } else {
                layer.residual.accumulate(grad);
            }
            out.mask_secs += clock.lap_span(ctx, obs::SPAN_MASK);

            let k = k_for(n, density);
            let sign =
                if layer.spec.quantize { Some(layer.alternator.next_sign()) } else { None };
            layer.select_into(device, k, sign, cc, &mut self.scratch)?;
            out.select_secs += clock.lap_span(ctx, obs::SPAN_SELECT);

            let sel = self.scratch.selected();
            layer.residual.mask(sel);
            out.mask_secs += clock.lap_span(ctx, obs::SPAN_MASK);
            out.selected += sel.len();
            out.elems += n;

            if layer.spec.quantize {
                // same-sign mean quantization (§5.2.3), packed without
                // materializing a QuantizedSet
                let mean = if sel.is_empty() { 0.0 } else { sel.value_sum() / sel.len() as f32 };
                pack_quant_into(&sel.indices, mean, &mut self.blob);
            } else {
                pack_plain_into(sel, &mut self.blob);
            }
            out.pack_secs += clock.lap_span(ctx, obs::SPAN_PACK);
        }
        Ok(out)
    }
}

impl BucketLayer {
    /// Communication-set selection into the bucket's reusable scratch
    /// (result in [`SelectScratch::selected`]), host or device flavor
    /// (moved from the pre-engine `run_worker`, math unchanged).
    fn select_into(
        &mut self,
        device: Option<&DeviceSelector>,
        k: usize,
        sign: Option<f32>,
        cc: &CompressorConfig,
        scratch: &mut SelectScratch,
    ) -> Result<(), String> {
        let residual = &mut self.residual;

        if let Some(dev) = device {
            // L1-kernel path (device buffers are owned per call)
            let d = match self.spec.method {
                Method::TrimmedTopk | Method::ExactTopk => {
                    dev.trimmed_topk(residual.residual(), k, cc.trim_eps, sign)
                }
                Method::SampledBinarySearch => dev.threshold_binary_search(
                    residual.residual(),
                    k,
                    cc.bs.eps,
                    cc.bs.max_iters,
                    sign,
                ),
                Method::Dense => unreachable!("dense layers never select"),
            }
            .map_err(|e| format!("device select: {e}"))?;
            scratch.put(d.sparse);
            return Ok(());
        }

        // host path (per-step density, bucket-owned threshold cache)
        let v = residual.residual();
        match self.spec.method {
            Method::ExactTopk => {
                exact_topk_into(v, k, sign, scratch);
            }
            Method::TrimmedTopk => {
                trimmed_topk_into(v, k, cc.trim_eps, sign, scratch);
            }
            Method::SampledBinarySearch => {
                // §6.4: threshold reuse is incompatible with sign alternation
                if sign.is_none() {
                    if let Some((thr, age)) = self.cached_thr {
                        if age < cc.interval {
                            scratch.compact_above(v, thr);
                            // cache is valid unless the residual drifted far
                            // from the threshold (the paper's re-select rule)
                            let len = scratch.selected().len();
                            if len > 0 && len <= 4 * k {
                                self.cached_thr = Some((thr, age + 1));
                                return Ok(());
                            }
                            // fall through to a fresh search
                        }
                    }
                }
                let thr = threshold_binary_search_into(v, k, cc.bs, sign, scratch);
                if sign.is_none() {
                    self.cached_thr = Some((thr, 1));
                }
            }
            Method::Dense => unreachable!(),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::message::{unpack_plain, unpack_quant};
    use crate::util::proptest::Gen;

    fn spec(li: usize, n: usize, quantize: bool) -> LayerSpec {
        LayerSpec { li, n, method: Method::TrimmedTopk, quantize }
    }

    #[test]
    fn build_buckets_respects_fusion_cap() {
        let specs: Vec<LayerSpec> =
            [100usize, 200, 300, 400].iter().enumerate().map(|(i, &n)| spec(i, n, false)).collect();
        let buckets = build_buckets(&specs, 500, Accumulation::Sgd);
        // greedy: [100,200] -> 300; +300 = 600 > 500 -> [300]; [400]
        assert_eq!(buckets.len(), 3);
        assert_eq!(buckets[0].n_layers(), 2);
        let lis: Vec<usize> = buckets.iter().flat_map(|b| b.specs().map(|s| s.li)).collect();
        assert_eq!(lis, vec![0, 1, 2, 3]);
    }

    #[test]
    fn no_fusion_means_singleton_buckets() {
        let specs: Vec<LayerSpec> = (0..3).map(|i| spec(i, 50, false)).collect();
        let buckets = build_buckets(&specs, 0, Accumulation::Sgd);
        assert_eq!(buckets.len(), 3);
        assert!(buckets.iter().all(|b| b.n_layers() == 1));
    }

    #[test]
    fn produce_packs_every_layer_in_order() {
        let specs = vec![spec(0, 400, false), spec(1, 300, true)];
        let mut buckets = build_buckets(&specs, 1000, Accumulation::Sgd);
        assert_eq!(buckets.len(), 1);
        let mut g = Gen::new(7);
        let g0 = g.vec_normal(400, 1.0);
        let g1 = g.vec_normal(300, 1.0);
        let cc = CompressorConfig::default();
        let p = buckets[0]
            .produce(&[g0.as_slice(), g1.as_slice()], 0.05, &cc, None)
            .unwrap();
        assert_eq!(p.elems, 700);
        // blob = one plain message then one quantized message
        let blob = buckets[0].blob();
        let (s, used) = unpack_plain(blob).unwrap();
        assert_eq!(s.len(), 20, "ceil(400 * 0.05)");
        let (q, used2) = unpack_quant(&blob[used..]).unwrap();
        assert_eq!(q.len(), 15, "ceil(300 * 0.05)");
        assert_eq!(used + used2, blob.len());
        assert_eq!(p.selected, 35);
        // the persistent blob is repacked in place, not appended to
        let len1 = buckets[0].blob().len();
        buckets[0].produce(&[g0.as_slice(), g1.as_slice()], 0.05, &cc, None).unwrap();
        assert_eq!(buckets[0].blob().len(), len1, "second produce must clear the blob first");
    }

    #[test]
    fn produce_is_deterministic_across_calls_on_equal_state() {
        let specs = vec![spec(0, 600, false)];
        let cc = CompressorConfig::default();
        let mut g = Gen::new(3);
        let grad = g.vec_normal(600, 1.0);
        let mut a = build_buckets(&specs, 0, Accumulation::Momentum { momentum: 0.9 });
        let mut b = build_buckets(&specs, 0, Accumulation::Momentum { momentum: 0.9 });
        for _ in 0..3 {
            a[0].produce(&[grad.as_slice()], 0.01, &cc, None).unwrap();
            b[0].produce(&[grad.as_slice()], 0.01, &cc, None).unwrap();
            assert_eq!(a[0].blob(), b[0].blob(), "same state + grads must pack the same bits");
        }
    }

    #[test]
    fn timing_gate_zeroes_phase_seconds_without_changing_bits() {
        let specs = vec![spec(0, 800, false)];
        let mut g = Gen::new(5);
        let grad = g.vec_normal(800, 1.0);
        let timed = CompressorConfig::default();
        let silent = CompressorConfig { timing: false, ..Default::default() };
        let mut a = build_buckets(&specs, 0, Accumulation::Sgd);
        let mut b = build_buckets(&specs, 0, Accumulation::Sgd);
        let pa = a[0].produce(&[grad.as_slice()], 0.02, &timed, None).unwrap();
        let pb = b[0].produce(&[grad.as_slice()], 0.02, &silent, None).unwrap();
        assert_eq!(a[0].blob(), b[0].blob(), "the timing gate must not touch the math");
        assert_eq!(pb.mask_secs + pb.select_secs + pb.pack_secs, 0.0, "disabled clock reads");
        assert!(pa.select_secs >= 0.0);
        assert_eq!((pa.selected, pa.elems), (pb.selected, pb.elems));
    }

    #[test]
    fn quantized_layer_alternates_sign_across_steps() {
        let specs = vec![spec(0, 500, true)];
        let mut buckets = build_buckets(&specs, 0, Accumulation::Sgd);
        let mut g = Gen::new(11);
        let grad = g.vec_normal(500, 1.0);
        let cc = CompressorConfig::default();
        buckets[0].produce(&[grad.as_slice()], 0.02, &cc, None).unwrap();
        let blob1 = buckets[0].blob().to_vec();
        buckets[0].produce(&[grad.as_slice()], 0.02, &cc, None).unwrap();
        let (q1, _) = unpack_quant(&blob1).unwrap();
        let (q2, _) = unpack_quant(buckets[0].blob()).unwrap();
        assert!(q1.mean > 0.0, "first pass selects top-k");
        assert!(q2.mean < 0.0, "second pass selects bottom-k");
    }
}
