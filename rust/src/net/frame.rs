//! Length-prefixed framing for the TCP fabric.
//!
//! The collectives' message unit is `Vec<u32>` (see
//! `collectives/transport.rs`); on the wire each message becomes one
//! frame:
//!
//! ```text
//! [len u32 LE][word_0 u32 LE] .. [word_{len-1} u32 LE]
//! ```
//!
//! `len` counts payload *words*, so the wire overhead is exactly 4 bytes
//! per message — the per-message α term the Eq. 1/2 cost model already
//! charges.  Words travel little-endian regardless of host order, so a
//! heterogeneous cluster still bit-matches the in-process fabric.

use std::io::{self, IoSlice, Read, Write};

/// Hard cap on a single frame's payload (words): 1 GiB.  A peer that
/// announces more is corrupt (or hostile); failing fast beats a huge
/// allocation.
pub const MAX_FRAME_WORDS: usize = 1 << 28;

/// Serialize one message into a frame's wire bytes.
pub fn encode_frame(msg: &[u32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 + msg.len() * 4);
    buf.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    for &w in msg {
        buf.extend_from_slice(&w.to_le_bytes());
    }
    buf
}

/// The send-side counterpart of the read cap: an oversized message must
/// fail here, loudly, not as a peer-side reject — which for
/// > 2^32-word messages would also be a silent u32 length truncation
/// that desynchronizes the stream.
fn check_send_len(words: usize) -> io::Result<()> {
    if words > MAX_FRAME_WORDS {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("message of {words} words exceeds frame cap {MAX_FRAME_WORDS}"),
        ));
    }
    Ok(())
}

/// Write one frame (single `write_all`; callers wrap the stream in a
/// `BufWriter` and flush per message).  Enforces the same
/// [`MAX_FRAME_WORDS`] cap the read side does.
pub fn write_frame<W: Write>(w: &mut W, msg: &[u32]) -> io::Result<()> {
    check_send_len(msg.len())?;
    w.write_all(&encode_frame(msg))
}

/// [`write_frame`] staging through a reused encode buffer (cleared
/// first) — the steady-state form the fabric's writer threads drive so
/// framing stops allocating per message (buffers recycle through
/// [`super::pool::BytePool`]).
pub fn write_frame_with<W: Write>(w: &mut W, msg: &[u32], scratch: &mut Vec<u8>) -> io::Result<()> {
    check_send_len(msg.len())?;
    scratch.clear();
    scratch.reserve(4 + msg.len() * 4);
    scratch.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    for &word in msg {
        scratch.extend_from_slice(&word.to_le_bytes());
    }
    w.write_all(scratch)
}

/// Write a batch of frames through as few syscalls as possible: every
/// length prefix and payload is staged into `scratch` (cleared first)
/// and handed to the stream as separate `IoSlice`s of a single
/// `write_vectored` call — a pipelined step's many small `TagMux`
/// frames leave in one `writev` instead of one `write` each.
///
/// Byte-identical on the wire to calling [`write_frame_with`] once per
/// message.  Partial writes are honored: the vectored loop resumes
/// mid-slice until every byte is out.  Returns the number of
/// `write_vectored` calls issued — the syscall count a batching writer
/// thread reports to its link stats.
pub fn write_frames_vectored<W: Write>(
    w: &mut W,
    msgs: &[&[u32]],
    scratch: &mut Vec<u8>,
) -> io::Result<usize> {
    if msgs.is_empty() {
        return Ok(0);
    }
    let mut total = 0usize;
    for m in msgs {
        check_send_len(m.len())?;
        total += 4 + m.len() * 4;
    }
    scratch.clear();
    scratch.reserve(total);
    // (start, len) byte spans into `scratch`, alternating header /
    // payload (empty payloads contribute a header span only)
    let mut spans: Vec<(usize, usize)> = Vec::with_capacity(msgs.len() * 2);
    for m in msgs {
        let h = scratch.len();
        scratch.extend_from_slice(&(m.len() as u32).to_le_bytes());
        spans.push((h, 4));
        if !m.is_empty() {
            let p = scratch.len();
            for &word in *m {
                scratch.extend_from_slice(&word.to_le_bytes());
            }
            spans.push((p, scratch.len() - p));
        }
    }
    write_vectored_all(w, scratch, &spans)
}

/// Drive `write_vectored` until every span is fully written, resuming
/// mid-slice after partial writes (`IoSlice::advance_slices` is not
/// stable, so the cursor is tracked by hand).  Returns the number of
/// `write_vectored` calls made.
fn write_vectored_all<W: Write + ?Sized>(
    w: &mut W,
    buf: &[u8],
    spans: &[(usize, usize)],
) -> io::Result<usize> {
    let mut calls = 0usize;
    let mut idx = 0usize; // first span not yet fully written
    let mut off = 0usize; // bytes of span `idx` already written
    while idx < spans.len() {
        let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(spans.len() - idx);
        let (s, l) = spans[idx];
        slices.push(IoSlice::new(&buf[s + off..s + l]));
        for &(s, l) in &spans[idx + 1..] {
            slices.push(IoSlice::new(&buf[s..s + l]));
        }
        let n = match w.write_vectored(&slices) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    "failed to write whole frame batch",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        calls += 1;
        // advance the cursor over fully-written spans
        let mut done = off + n;
        while idx < spans.len() && done >= spans[idx].1 {
            done -= spans[idx].1;
            idx += 1;
        }
        off = done;
    }
    Ok(calls)
}

/// Read one frame.  Returns `Ok(None)` on a clean EOF *between* frames
/// (the peer shut down its write half); a mid-frame EOF is an error.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Vec<u32>>> {
    let mut scratch = Vec::new();
    read_frame_with(r, &mut scratch)
}

/// [`read_frame`] staging the payload bytes through a reused buffer —
/// only the decoded `Vec<u32>` handed to the inbox is allocated per
/// message.
pub fn read_frame_with<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> io::Result<Option<Vec<u32>>> {
    let mut header = [0u8; 4];
    // Distinguish "no more frames" from "truncated frame": only a zero-
    // byte first read counts as a clean close.
    let mut got = 0;
    while got < 4 {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside frame header",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let words = u32::from_le_bytes(header) as usize;
    if words > MAX_FRAME_WORDS {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {words} words exceeds cap {MAX_FRAME_WORDS}"),
        ));
    }
    scratch.clear();
    scratch.resize(words * 4, 0);
    r.read_exact(scratch)?;
    let msg = scratch
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Some(msg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_empty_and_data() {
        for msg in [vec![], vec![7u32], vec![0, u32::MAX, 0xDEAD_BEEF]] {
            let mut wire = Vec::new();
            write_frame(&mut wire, &msg).unwrap();
            assert_eq!(wire.len(), 4 + msg.len() * 4);
            let got = read_frame(&mut Cursor::new(&wire)).unwrap().unwrap();
            assert_eq!(got, msg);
        }
    }

    #[test]
    fn back_to_back_frames() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[1, 2]).unwrap();
        write_frame(&mut wire, &[3]).unwrap();
        let mut cur = Cursor::new(&wire);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), vec![1, 2]);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), vec![3]);
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn clean_eof_is_none() {
        let mut cur = Cursor::new(&[] as &[u8]);
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn truncated_header_is_error() {
        let mut cur = Cursor::new(&[1u8, 0][..]);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn truncated_payload_is_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[1, 2, 3]).unwrap();
        wire.truncate(wire.len() - 2);
        assert!(read_frame(&mut Cursor::new(&wire)).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let wire = (u32::MAX).to_le_bytes();
        let err = read_frame(&mut Cursor::new(&wire[..])).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn oversized_send_rejected_before_the_wire() {
        // (a MAX_FRAME_WORDS+1 buffer would need >1 GiB, so the length
        // check is probed directly)
        assert!(check_send_len(MAX_FRAME_WORDS).is_ok());
        let err = check_send_len(MAX_FRAME_WORDS + 1).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
    }

    #[test]
    fn scratch_variants_match_the_plain_ones() {
        let mut scratch = Vec::new();
        let mut wire_a = Vec::new();
        let mut wire_b = Vec::new();
        for msg in [vec![], vec![7u32], vec![0, u32::MAX, 0xDEAD_BEEF]] {
            wire_a.clear();
            wire_b.clear();
            write_frame(&mut wire_a, &msg).unwrap();
            write_frame_with(&mut wire_b, &msg, &mut scratch).unwrap();
            assert_eq!(wire_a, wire_b, "scratch encoding must be byte-identical");
            let got = read_frame_with(&mut Cursor::new(&wire_b), &mut scratch).unwrap().unwrap();
            assert_eq!(got, msg);
        }
    }

    #[test]
    fn wire_is_little_endian() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[0x0102_0304]).unwrap();
        assert_eq!(wire, vec![1, 0, 0, 0, 0x04, 0x03, 0x02, 0x01]);
    }

    /// A `Write` sink that accepts at most `cap` bytes per call and
    /// honors multi-slice vectored writes — the adversarial shim the
    /// partial-write resume logic is proved against.  The default
    /// `write_vectored` would silently use only the first slice, so it
    /// is implemented explicitly (as the real socket types do).
    struct ShortWriter {
        out: Vec<u8>,
        cap: usize,
        calls: usize,
    }

    impl Write for ShortWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.calls += 1;
            let take = buf.len().min(self.cap);
            self.out.extend_from_slice(&buf[..take]);
            Ok(take)
        }

        fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> io::Result<usize> {
            self.calls += 1;
            let mut left = self.cap;
            let mut wrote = 0;
            for b in bufs {
                let take = b.len().min(left);
                self.out.extend_from_slice(&b[..take]);
                wrote += take;
                left -= take;
                if left == 0 {
                    break;
                }
            }
            Ok(wrote)
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn vectored_batch_is_byte_identical_to_sequential_frames() {
        let msgs: Vec<Vec<u32>> =
            vec![vec![], vec![7], vec![1, 2, 3], vec![0xDEAD_BEEF; 1000], vec![]];
        let refs: Vec<&[u32]> = msgs.iter().map(|m| m.as_slice()).collect();
        let mut expect = Vec::new();
        for m in &msgs {
            write_frame(&mut expect, m).unwrap();
        }
        let mut scratch = Vec::new();
        let mut wire = Vec::new();
        let calls = write_frames_vectored(&mut wire, &refs, &mut scratch).unwrap();
        assert_eq!(wire, expect, "batched wire bytes must match frame-per-write");
        assert_eq!(calls, 1, "an unbounded sink takes the whole batch in one writev");
        // and the read side sees the individual frames unchanged
        let mut cur = Cursor::new(&wire);
        for m in &msgs {
            assert_eq!(read_frame(&mut cur).unwrap().unwrap(), *m);
        }
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn empty_batch_writes_nothing() {
        let mut scratch = Vec::new();
        let mut sink = ShortWriter { out: Vec::new(), cap: 8, calls: 0 };
        assert_eq!(write_frames_vectored(&mut sink, &[], &mut scratch).unwrap(), 0);
        assert_eq!(sink.calls, 0);
        assert!(sink.out.is_empty());
    }

    #[test]
    fn vectored_batch_survives_partial_writes() {
        // randomized message shapes × per-call write caps, including caps
        // that split length prefixes and payload words mid-slice
        crate::util::proptest::check(60, |g| {
            let n_msgs = g.size(1..8);
            let msgs: Vec<Vec<u32>> = (0..n_msgs)
                .map(|_| {
                    let words = g.size(0..40);
                    (0..words).map(|_| g.rng().next_u32()).collect()
                })
                .collect();
            let refs: Vec<&[u32]> = msgs.iter().map(|m| m.as_slice()).collect();
            let mut expect = Vec::new();
            for m in &msgs {
                write_frame(&mut expect, m).unwrap();
            }
            let cap = g.size(1..23); // deliberately not word-aligned
            let mut sink = ShortWriter { out: Vec::new(), cap, calls: 0 };
            let mut scratch = Vec::new();
            let calls = write_frames_vectored(&mut sink, &refs, &mut scratch)
                .map_err(|e| format!("vectored write failed: {e}"))?;
            crate::util::proptest::ensure(
                sink.out == expect,
                format!("cap {cap}: resumed wire bytes diverge"),
            )?;
            crate::util::proptest::ensure(
                calls == sink.calls,
                format!("reported {calls} calls, sink saw {}", sink.calls),
            )?;
            let want_calls = (expect.len() + cap - 1) / cap;
            crate::util::proptest::ensure(
                calls == want_calls,
                format!("cap {cap}: expected {want_calls} calls, got {calls}"),
            )
        });
    }

    #[test]
    fn zero_length_write_is_an_error_not_a_spin() {
        struct DeadWriter;
        impl Write for DeadWriter {
            fn write(&mut self, _: &[u8]) -> io::Result<usize> {
                Ok(0)
            }
            fn write_vectored(&mut self, _: &[IoSlice<'_>]) -> io::Result<usize> {
                Ok(0)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut scratch = Vec::new();
        let err = write_frames_vectored(&mut DeadWriter, &[&[1, 2, 3]], &mut scratch).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::WriteZero);
    }

}
