//! Tiny free-list buffer pool for the TCP fabric's framing scratch.
//!
//! Every frame crossing a socket needs a byte staging buffer — encode on
//! the writer side, payload read on the reader side.  Allocating those
//! per message made steady-state framing O(messages) heap churn; the
//! per-message reuse now lives in `frame::{read,write}_frame_with`,
//! which each writer/reader thread drives with ONE long-lived buffer.
//! The pool is the checkout desk for those buffers: a thread takes its
//! scratch here at spawn and returns it on exit, so buffer capacity
//! survives thread turnover (future reconnect/re-peer paths) instead of
//! dying with each thread (DESIGN.md §Zero-Copy-Hot-Path).

use std::sync::Mutex;

/// Capacity cap (bytes) above which a returned buffer is dropped instead
/// of pooled — one multi-GB gather must not pin its footprint forever.
const MAX_POOLED_BYTES: usize = 8 << 20;

/// A small LIFO free list of byte buffers, shared by a fabric's writer
/// and reader threads.
pub struct BytePool {
    free: Mutex<Vec<Vec<u8>>>,
    max_buffers: usize,
}

impl BytePool {
    /// Pool retaining at most `max_buffers` buffers.
    pub fn new(max_buffers: usize) -> BytePool {
        BytePool { free: Mutex::new(Vec::new()), max_buffers }
    }

    /// Take a cleared buffer (fresh if the pool is empty).
    pub fn get(&self) -> Vec<u8> {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a buffer for reuse; oversized or surplus buffers are freed.
    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() > MAX_POOLED_BYTES {
            return;
        }
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_buffers {
            free.push(buf);
        }
    }

    /// Buffers currently parked in the free list.
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_put_recycles_capacity() {
        let pool = BytePool::new(2);
        let mut b = pool.get();
        assert!(b.is_empty());
        b.extend_from_slice(&[1, 2, 3, 4]);
        let cap = b.capacity();
        pool.put(b);
        assert_eq!(pool.pooled(), 1);
        let b2 = pool.get();
        assert!(b2.is_empty(), "returned buffers come back cleared");
        assert_eq!(b2.capacity(), cap, "capacity survives the round trip");
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn pool_caps_buffer_count() {
        let pool = BytePool::new(1);
        pool.put(Vec::with_capacity(8));
        pool.put(Vec::with_capacity(8));
        assert_eq!(pool.pooled(), 1, "surplus buffers are dropped");
    }

    #[test]
    fn oversized_buffers_are_not_pooled() {
        let pool = BytePool::new(4);
        pool.put(Vec::with_capacity(MAX_POOLED_BYTES + 1));
        assert_eq!(pool.pooled(), 0);
    }
}
