//! Mixed fabric: link-class-aware transport selection.
//!
//! [`MixedFabric`] consults the job's [`Topology`] and builds each
//! peer link over the cheapest fabric that can reach it: same-node
//! peers get Unix-domain sockets (`net::unix`), cross-node peers get
//! TCP (`net::tcp`).  Both ride the same framing and the same
//! [`StreamTransport`] data plane, so the choice is invisible to the
//! collectives — bit-identical messages, different syscall cost — and
//! visible to accounting as per-class [`LinkClassStats`].
//!
//! ## Bootstrap
//!
//! The rendezvous advertises both endpoints of rank 0: the TCP address
//! (`--rendezvous`, dialable from every node) and a socket-path
//! namespace derived from the same string ([`socket_base`] — identical
//! on every host, and only same-host ranks ever dial each other's
//! paths, so one shared seed namespaces both planes).  The protocol is
//! the TCP fabric's `REG`/`DIR`/`MESH` with one twist: registration
//! always runs over TCP (it must cross nodes), but a registration
//! connection is *kept* as the `0 <-> i` data link only when ranks 0
//! and `i` are on different nodes — same-node peers of rank 0 drop it
//! after the directory and redial rank 0's Unix listener in the mesh
//! phase.  Every rank binds its Unix listener *before* registering, so
//! the directory go-signal implies every same-host path exists; mesh
//! dials then pick Unix vs TCP per pair from the topology, and accepts
//! poll both listeners under one deadline.

use super::fabric::{
    batching_enabled, delegate_transport, LinkClassStats, LinkStream, StreamTransport,
};
use super::frame::write_frame;
use super::tcp::{
    accept_deadline, bad_data, connect_retry, read_handshake, timed_out, DIR, MESH, REG,
};
use super::unix::{
    accept_deadline_unix, bind_unix, check_paths, connect_unix_retry, read_handshake_unix,
    socket_base, PathGuard,
};
use crate::collectives::transport::{LinkClass, PeerLostCause, TrafficStats};
use crate::collectives::Topology;
use std::io::{self, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddrV4, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Bootstrap parameters for one rank of a mixed fabric.
#[derive(Clone, Debug)]
pub struct MixedOptions {
    pub world: usize,
    pub rank: usize,
    /// Rank 0's TCP rendezvous address; also the socket-path namespace
    /// seed for the intra-node plane (see [`socket_base`]).
    pub rendezvous: String,
    /// Physical placement — the link-class oracle: `topo.same_node(a, b)`
    /// decides Unix vs TCP for every pair.
    pub topo: Topology,
    /// Bound on the whole bootstrap (connect retries, accepts, handshakes).
    pub timeout: Duration,
    /// Coalesce queued frames into vectored write batches (see
    /// `net::fabric`); `false` falls back to frame-per-write.
    pub batch: bool,
}

impl MixedOptions {
    pub fn new(
        world: usize,
        rank: usize,
        rendezvous: impl Into<String>,
        topo: Topology,
    ) -> MixedOptions {
        MixedOptions {
            world,
            rank,
            rendezvous: rendezvous.into(),
            topo,
            timeout: Duration::from_secs(30),
            batch: batching_enabled(),
        }
    }
}

/// One rank's endpoint of a link-class-aware fabric: Unix sockets to
/// same-node peers, TCP to cross-node peers, chosen per pair from the
/// [`Topology`].  Construct with [`MixedFabric::connect`]; under the
/// degenerate flat topology every link is Unix, which is what
/// `--transport auto` resolves to for a single-host fleet.
pub struct MixedFabric {
    inner: StreamTransport,
    topo: Topology,
    /// Per-process traffic counters — identical accounting to every
    /// other fabric (payload words at `send`).
    pub stats: Arc<TrafficStats>,
}

impl MixedFabric {
    /// Run the bootstrap protocol and return this rank's live endpoint.
    /// Blocks until the full mesh is up or `opts.timeout` expires.
    pub fn connect(opts: &MixedOptions) -> io::Result<MixedFabric> {
        if opts.world == 0 {
            return Err(bad_data("world must be >= 1".into()));
        }
        if opts.rank >= opts.world {
            return Err(bad_data(format!("rank {} out of world {}", opts.rank, opts.world)));
        }
        if opts.topo.world() != opts.world {
            return Err(bad_data(format!(
                "topology {} covers {} ranks, world is {}",
                opts.topo.label(),
                opts.topo.world(),
                opts.world
            )));
        }
        let base = socket_base(&opts.rendezvous);
        check_paths(&base, opts.world)?;
        let deadline = Instant::now() + opts.timeout;
        let streams = if opts.world == 1 {
            Vec::new()
        } else if opts.rank == 0 {
            bootstrap_rank0(opts, &base, deadline)?
        } else {
            bootstrap_peer(opts, &base, deadline)?
        };
        let inner = StreamTransport::from_streams(opts.rank, opts.world, streams, opts.batch);
        let stats = Arc::clone(&inner.stats);
        Ok(MixedFabric { inner, topo: opts.topo, stats })
    }

    /// The link class serving `peer`: `Mem` for self, `Unix` for
    /// same-node peers, `Tcp` across nodes.
    pub fn class_of(&self, peer: usize) -> LinkClass {
        self.inner.class_of(peer)
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Per-link-class counters (frames / words / write syscalls).
    pub fn link_stats(&self) -> Arc<LinkClassStats> {
        Arc::clone(&self.inner.link_stats)
    }

    /// The recorded loss cause for `peer`'s link, if its reader has
    /// already classified a failure.
    pub fn peer_lost(&self, peer: usize) -> Option<(PeerLostCause, String)> {
        self.inner.peer_lost(peer)
    }

    /// Every peer whose link has died so far, with the classified cause.
    pub fn lost_peers(&self) -> Vec<(usize, PeerLostCause)> {
        self.inner.lost_peers()
    }
}

delegate_transport!(MixedFabric);

/// Does `rank` need a Unix listener — i.e. will any *higher* rank on
/// the same node dial it in the mesh phase?  (Rank 0's same-node peers
/// all count, since they redial over Unix instead of keeping the
/// registration connection.)
fn needs_unix_listener(topo: &Topology, rank: usize, world: usize) -> bool {
    (rank + 1..world).any(|p| topo.same_node(rank, p))
}

/// Rank 0: TCP registration exactly as the TCP fabric, but same-node
/// registration connections are dropped after the directory and
/// replaced by Unix mesh accepts.
fn bootstrap_rank0(
    opts: &MixedOptions,
    base: &str,
    deadline: Instant,
) -> io::Result<Vec<Option<LinkStream>>> {
    let world = opts.world;
    let topo = &opts.topo;
    // bind the Unix listener before anyone can learn the directory, so
    // a same-node peer's mesh dial never races the bind
    let unix_listener = if needs_unix_listener(topo, 0, world) {
        Some(bind_unix(&format!("{base}.r0"))?)
    } else {
        None
    };
    let listener = TcpListener::bind(&opts.rendezvous[..])?;
    let mut regs: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
    let mut endpoints: Vec<Option<(Ipv4Addr, u32)>> = (0..world).map(|_| None).collect();

    for _ in 1..world {
        let mut s = accept_deadline(&listener, deadline)?;
        let frame = read_handshake(&mut s, deadline, "registration")?;
        if frame.len() != 4 || frame[0] != REG {
            return Err(bad_data(format!("bad registration frame {frame:?}")));
        }
        let (w, r, port) = (frame[1], frame[2], frame[3]);
        if w as usize != world {
            return Err(bad_data(format!("peer expects world {w}, rank 0 has {world}")));
        }
        let r = r as usize;
        if r == 0 || r >= world {
            return Err(bad_data(format!("registration from invalid rank {r}")));
        }
        if regs[r].is_some() {
            return Err(bad_data(format!("duplicate registration for rank {r}")));
        }
        let IpAddr::V4(ip) = s.peer_addr()?.ip() else {
            return Err(bad_data("mixed fabric directory is IPv4-only".into()));
        };
        endpoints[r] = Some((ip, port));
        regs[r] = Some(s);
    }

    let mut dir = Vec::with_capacity(2 + 2 * (world - 1));
    dir.push(DIR);
    dir.push(world as u32);
    for e in endpoints.into_iter().skip(1) {
        let (ip, port) = e.expect("all ranks registered");
        dir.push(u32::from(ip));
        dir.push(port);
    }
    for s in regs.iter_mut().skip(1) {
        let s = s.as_mut().expect("all ranks registered");
        write_frame(s, &dir)?;
        s.flush()?;
    }

    // cross-node registration connections become the 0 <-> i data
    // links; same-node ones are dropped — those peers redial over Unix
    let mut streams: Vec<Option<LinkStream>> = (0..world).map(|_| None).collect();
    for (r, reg) in regs.into_iter().enumerate().skip(1) {
        if !topo.same_node(0, r) {
            streams[r] = Some(LinkStream::Tcp(reg.expect("all ranks registered")));
        }
    }
    if let Some((listener, _guard)) = &unix_listener {
        let expected = (1..world).filter(|&p| topo.same_node(0, p)).count();
        for _ in 0..expected {
            let mut s = accept_deadline_unix(listener, deadline)?;
            let frame = read_handshake_unix(&mut s, deadline, "mesh")?;
            let peer = validate_mesh(&frame, world, 0)?;
            if !topo.same_node(0, peer) {
                return Err(bad_data(format!(
                    "rank {peer} dialed the unix plane but lives on another node"
                )));
            }
            if streams[peer].is_some() {
                return Err(bad_data(format!("duplicate mesh connection from rank {peer}")));
            }
            streams[peer] = Some(LinkStream::Unix(s));
        }
    }
    Ok(streams)
}

/// Nonzero rank: TCP-register with rank 0, then dial every lower rank
/// over the class the topology picks and accept every higher one on
/// both listeners under one deadline.
fn bootstrap_peer(
    opts: &MixedOptions,
    base: &str,
    deadline: Instant,
) -> io::Result<Vec<Option<LinkStream>>> {
    let (world, rank) = (opts.world, opts.rank);
    let topo = &opts.topo;
    let tcp_listener = TcpListener::bind((Ipv4Addr::UNSPECIFIED, 0))?;
    let my_port = tcp_listener.local_addr()?.port();
    let unix_listener = if needs_unix_listener(topo, rank, world) {
        Some(bind_unix(&format!("{base}.r{rank}"))?)
    } else {
        None
    };

    let mut to_zero = connect_retry(&opts.rendezvous[..], deadline)?;
    write_frame(&mut to_zero, &[REG, world as u32, rank as u32, my_port as u32])?;
    to_zero.flush()?;
    let dir = read_handshake(&mut to_zero, deadline, "directory")?;
    if dir.len() != 2 + 2 * (world - 1) || dir[0] != DIR || dir[1] as usize != world {
        return Err(bad_data(format!("bad directory frame (len {})", dir.len())));
    }

    let mut streams: Vec<Option<LinkStream>> = (0..world).map(|_| None).collect();
    // the registration connection survives as the 0-link only across
    // nodes; same-node ranks redial rank 0 over its Unix listener below
    if !topo.same_node(rank, 0) {
        streams[0] = Some(LinkStream::Tcp(to_zero));
    } else {
        drop(to_zero);
    }

    for peer in 0..rank {
        if topo.same_node(rank, peer) {
            let mut s = connect_unix_retry(&format!("{base}.r{peer}"), deadline)?;
            write_frame(&mut s, &[MESH, world as u32, rank as u32])?;
            s.flush()?;
            streams[peer] = Some(LinkStream::Unix(s));
        } else if peer > 0 {
            let ip = Ipv4Addr::from(dir[2 * peer]);
            let port = dir[2 * peer + 1] as u16;
            let mut s = connect_retry(SocketAddrV4::new(ip, port), deadline)?;
            write_frame(&mut s, &[MESH, world as u32, rank as u32])?;
            s.flush()?;
            streams[peer] = Some(LinkStream::Tcp(s));
        } // peer == 0 cross-node: registration connection already kept
    }

    let want_unix = (rank + 1..world).filter(|&p| topo.same_node(rank, p)).count();
    let want_tcp = (rank + 1..world).filter(|&p| !topo.same_node(rank, p)).count();
    accept_both(
        &tcp_listener,
        unix_listener.as_ref().map(|(l, _)| l),
        want_tcp,
        want_unix,
        deadline,
        topo,
        rank,
        world,
        &mut streams,
    )?;
    Ok(streams)
}

fn validate_mesh(frame: &[u32], world: usize, rank: usize) -> io::Result<usize> {
    if frame.len() != 3 || frame[0] != MESH {
        return Err(bad_data(format!("bad mesh frame {frame:?}")));
    }
    let (w, peer) = (frame[1], frame[2] as usize);
    if w as usize != world || peer <= rank || peer >= world {
        return Err(bad_data(format!("mesh handshake from invalid rank {peer}")));
    }
    Ok(peer)
}

/// Poll both listeners (nonblocking, 5ms) until every expected mesh
/// connection has arrived — higher ranks dial in arbitrary order and
/// class, so a single blocking accept on either listener could deadlock
/// the other plane.
#[allow(clippy::too_many_arguments)]
fn accept_both(
    tcp: &TcpListener,
    unix: Option<&UnixListener>,
    mut want_tcp: usize,
    mut want_unix: usize,
    deadline: Instant,
    topo: &Topology,
    rank: usize,
    world: usize,
    streams: &mut [Option<LinkStream>],
) -> io::Result<()> {
    tcp.set_nonblocking(true)?;
    if let Some(l) = unix {
        l.set_nonblocking(true)?;
    }
    while want_tcp > 0 || want_unix > 0 {
        let mut progressed = false;
        if want_tcp > 0 {
            match tcp.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false)?;
                    let frame = read_handshake(&mut s, deadline, "mesh")?;
                    let peer = validate_mesh(&frame, world, rank)?;
                    if topo.same_node(rank, peer) {
                        return Err(bad_data(format!(
                            "same-node rank {peer} dialed over tcp instead of unix"
                        )));
                    }
                    if streams[peer].is_some() {
                        return Err(bad_data(format!(
                            "duplicate mesh connection from rank {peer}"
                        )));
                    }
                    streams[peer] = Some(LinkStream::Tcp(s));
                    want_tcp -= 1;
                    progressed = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if want_unix > 0 {
            let l = unix.expect("unix accepts expected only with a bound listener");
            match l.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false)?;
                    let frame = read_handshake_unix(&mut s, deadline, "mesh")?;
                    let peer = validate_mesh(&frame, world, rank)?;
                    if !topo.same_node(rank, peer) {
                        return Err(bad_data(format!(
                            "rank {peer} dialed the unix plane but lives on another node"
                        )));
                    }
                    if streams[peer].is_some() {
                        return Err(bad_data(format!(
                            "duplicate mesh connection from rank {peer}"
                        )));
                    }
                    streams[peer] = Some(LinkStream::Unix(s));
                    want_unix -= 1;
                    progressed = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if !progressed {
            if Instant::now() >= deadline {
                return Err(timed_out("timed out waiting for mesh connections"));
            }
            thread::sleep(Duration::from_millis(5));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::Transport;
    use crate::net::free_loopback_addr;

    fn fabric(topo: Topology, addr: &str) -> Vec<MixedFabric> {
        let world = topo.world();
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let opts = MixedOptions::new(world, rank, addr, topo);
                thread::spawn(move || MixedFabric::connect(&opts).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn flat_topology_selects_unix_for_every_peer() {
        let addr = free_loopback_addr();
        let ts = fabric(Topology::flat(3), &addr);
        for (rank, t) in ts.iter().enumerate() {
            for peer in 0..3 {
                let want = if peer == rank { LinkClass::Mem } else { LinkClass::Unix };
                assert_eq!(t.class_of(peer), want, "rank {rank} -> {peer}");
            }
        }
        drop(ts);
    }

    #[test]
    fn two_by_two_topology_splits_classes_by_node() {
        // ranks 0,1 on "node 0"; ranks 2,3 on "node 1" — all in this
        // process, but the fabric must still route by declared placement
        let addr = free_loopback_addr();
        let ts = fabric(Topology::new(2, 2), &addr);
        assert_eq!(ts[0].class_of(1), LinkClass::Unix);
        assert_eq!(ts[0].class_of(2), LinkClass::Tcp);
        assert_eq!(ts[0].class_of(3), LinkClass::Tcp);
        assert_eq!(ts[3].class_of(2), LinkClass::Unix);
        assert_eq!(ts[3].class_of(0), LinkClass::Tcp);
        assert_eq!(ts[1].class_of(1), LinkClass::Mem);
        drop(ts);
    }

    #[test]
    fn all_pairs_exchange_across_mixed_classes() {
        let addr = free_loopback_addr();
        let ts = fabric(Topology::new(2, 2), &addr);
        let world = 4;
        let handles: Vec<_> = ts
            .into_iter()
            .enumerate()
            .map(|(rank, t)| {
                thread::spawn(move || {
                    for peer in 0..world {
                        t.send(peer, vec![(rank * 10 + peer) as u32; 5]);
                    }
                    for peer in 0..world {
                        assert_eq!(t.recv(peer), vec![(peer * 10 + rank) as u32; 5]);
                    }
                    t
                })
            })
            .collect();
        let ts: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // rank 0 sent one 5-word frame to each class: itself (mem), 1
        // (unix), 2 and 3 (tcp)
        let lt = ts[0].link_traffic();
        assert_eq!(lt.len(), 3, "all three classes active: {lt:?}");
        assert_eq!((lt[0].class, lt[0].frames, lt[0].bytes), (LinkClass::Mem, 1, 20));
        assert_eq!((lt[1].class, lt[1].frames, lt[1].bytes), (LinkClass::Unix, 1, 20));
        assert_eq!((lt[2].class, lt[2].frames, lt[2].bytes), (LinkClass::Tcp, 2, 40));
        assert_eq!(ts[0].stats.bytes(), 80, "class-blind totals agree");
    }

    #[test]
    fn world_one_needs_no_sockets() {
        let t =
            MixedFabric::connect(&MixedOptions::new(1, 0, "127.0.0.1:1", Topology::flat(1)))
                .unwrap();
        t.send(0, vec![7]);
        assert_eq!(t.recv(0), vec![7]);
    }

    #[test]
    fn topology_must_cover_world() {
        let err =
            MixedFabric::connect(&MixedOptions::new(4, 0, "127.0.0.1:1", Topology::new(2, 4)))
                .unwrap_err();
        assert!(err.to_string().contains("covers"), "{err}");
    }

    #[test]
    fn socket_files_cleaned_after_mixed_bootstrap() {
        let addr = free_loopback_addr();
        let base = socket_base(&addr);
        let ts = fabric(Topology::flat(3), &addr);
        for rank in 0..2 {
            assert!(
                !std::path::Path::new(&format!("{base}.r{rank}")).exists(),
                "unix listener path for rank {rank} must be unlinked"
            );
        }
        drop(ts);
    }
}
