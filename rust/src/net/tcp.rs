//! TCP fabric: the real-network implementation of [`Transport`].
//!
//! ## Bootstrap (rendezvous) protocol
//!
//! Rank 0 listens on the rendezvous address.  Every other rank binds its
//! own ephemeral listener, dials rank 0 and registers
//! `[REG, world, rank, listen_port]`.  Once all `world - 1` ranks are in,
//! rank 0 replies to each with the directory
//! `[DIR, world, ip_1, port_1, .., ip_{w-1}, port_{w-1}]` (IPv4, observed
//! from the registration connection).  The mesh then completes
//! decentralized: rank `i` dials every rank `j` with `1 <= j < i` and
//! introduces itself with `[MESH, world, i]`; the `0 <-> i` links reuse
//! the registration connections.  Every rank ends holding `world - 1`
//! sockets plus an in-memory self-channel.
//!
//! ## Data plane
//!
//! The data plane lives in [`super::fabric::StreamTransport`], shared
//! with the Unix-socket and mixed fabrics: one writer and one reader
//! thread per peer socket, batched vectored frame writes (flush once
//! per channel drain), per-link-class traffic accounting, loss-cause
//! classification, and flush+FIN graceful shutdown.  `send` enqueues to
//! the writer's unbounded channel and never blocks — the same
//! buffered-fabric contract as `LocalFabric`, which is what makes the
//! collectives' symmetric `exchange` deadlock-free.
//!
//! Every message crosses the wire as one atomic frame written by that
//! peer's single writer thread, so concurrent senders (the pipelined sync
//! engine's comm pool, multiplexed by `collectives::mux::TagMux` bucket
//! tags) never interleave words *inside* a frame — write batching
//! coalesces whole frames only, so the tag word at the end of each
//! message is still all the demux above needs.

use super::fabric::{batching_enabled, delegate_transport, LinkClassStats, LinkStream, StreamTransport};
use super::frame::{read_frame, write_frame};
use crate::collectives::transport::{PeerLostCause, TrafficStats};
use std::io::{self, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddrV4, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

pub(crate) const REG: u32 = 0x5244_5301; // "RDS" + frame kind
pub(crate) const DIR: u32 = 0x5244_5302;
pub(crate) const MESH: u32 = 0x5244_5303;

/// Bootstrap parameters for one rank of a TCP fabric.
#[derive(Clone, Debug)]
pub struct TcpOptions {
    pub world: usize,
    pub rank: usize,
    /// Rendezvous address rank 0 listens on (e.g. `127.0.0.1:29500`).
    pub rendezvous: String,
    /// Bound on the whole bootstrap (connect retries, accepts, handshakes).
    pub timeout: Duration,
    /// Coalesce queued frames into vectored write batches (default; see
    /// `net::fabric`).  `false` falls back to frame-per-write — the A/B
    /// lever of the fabric bench.
    pub batch: bool,
}

impl TcpOptions {
    pub fn new(world: usize, rank: usize, rendezvous: impl Into<String>) -> TcpOptions {
        TcpOptions {
            world,
            rank,
            rendezvous: rendezvous.into(),
            timeout: Duration::from_secs(30),
            batch: batching_enabled(),
        }
    }
}

pub(crate) fn bad_data(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

pub(crate) fn timed_out(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::TimedOut, msg.to_string())
}

/// First retry delay of [`connect_retry`]; doubles per refused attempt.
pub(crate) const CONNECT_BACKOFF_START: Duration = Duration::from_millis(10);
/// Backoff ceiling: late attempts poll at this period until the
/// deadline, so a rank that comes up seconds late is still caught
/// promptly without hammering the host with SYNs.
pub(crate) const CONNECT_BACKOFF_CAP: Duration = Duration::from_millis(400);

/// Dial with bounded exponential backoff until `deadline`: during
/// bootstrap the target's listener may simply not be bound yet (ranks
/// of a `launch` fleet start in arbitrary order), so refused/unreachable
/// connects are retried — 10ms, 20ms, ... capped at 400ms — rather than
/// failing on the first `ECONNREFUSED`.  On timeout the error reports
/// the attempt count and the last underlying cause.
pub(crate) fn connect_retry<A: ToSocketAddrs + Clone>(
    addr: A,
    deadline: Instant,
) -> io::Result<TcpStream> {
    let mut delay = CONNECT_BACKOFF_START;
    let mut attempts = 0u32;
    loop {
        attempts += 1;
        match TcpStream::connect(addr.clone()) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(io::Error::new(
                        e.kind(),
                        format!("giving up after {attempts} connect attempts: {e}"),
                    ));
                }
                // never sleep past the deadline — the caller's bootstrap
                // budget is shared across every handshake
                thread::sleep(delay.min(deadline.saturating_duration_since(now)));
                delay = (delay * 2).min(CONNECT_BACKOFF_CAP);
            }
        }
    }
}

/// Accept with a deadline (listener switched to non-blocking polling).
pub(crate) fn accept_deadline(listener: &TcpListener, deadline: Instant) -> io::Result<TcpStream> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)?;
                return Ok(s);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(timed_out("timed out waiting for a peer connection"));
                }
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Read one bootstrap frame, bounded by the *remaining* shared deadline
/// — `TcpOptions::timeout` caps the whole bootstrap, so a stalled (or
/// stray) peer must not get a fresh full timeout per socket.
pub(crate) fn read_handshake(
    s: &mut TcpStream,
    deadline: Instant,
    what: &str,
) -> io::Result<Vec<u32>> {
    let remaining = deadline.saturating_duration_since(Instant::now());
    if remaining.is_zero() {
        return Err(timed_out("bootstrap deadline expired"));
    }
    s.set_read_timeout(Some(remaining))?;
    let frame = read_frame(s)?
        .ok_or_else(|| bad_data(format!("peer closed during {what} handshake")))?;
    s.set_read_timeout(None)?;
    Ok(frame)
}

/// One rank's endpoint of a TCP fabric.  Construct with
/// [`TcpTransport::connect`]; every rank of the job calls it with the same
/// `world` and rendezvous address and its own `rank`.  A thin bootstrap
/// wrapper over [`StreamTransport`], which owns the data plane.
pub struct TcpTransport {
    inner: StreamTransport,
    /// Per-process traffic counters (same accounting as `LocalFabric`:
    /// payload words at `send`; the 4-byte frame header is `4 *
    /// message_count()` extra wire bytes).
    pub stats: Arc<TrafficStats>,
}

impl TcpTransport {
    /// Run the bootstrap protocol and return this rank's live endpoint.
    /// Blocks until the full mesh is up or `opts.timeout` expires.
    pub fn connect(opts: &TcpOptions) -> io::Result<TcpTransport> {
        if opts.world == 0 {
            return Err(bad_data("world must be >= 1".into()));
        }
        if opts.rank >= opts.world {
            return Err(bad_data(format!("rank {} out of world {}", opts.rank, opts.world)));
        }
        let deadline = Instant::now() + opts.timeout;
        let streams = if opts.world == 1 {
            Vec::new()
        } else if opts.rank == 0 {
            bootstrap_rank0(opts, deadline)?
        } else {
            bootstrap_peer(opts, deadline)?
        };
        Ok(Self::from_streams_batched(opts.rank, opts.world, streams, opts.batch))
    }

    /// Wire up the data plane over an established socket per peer
    /// (`streams[rank]` is ignored; all others must be `Some`).  Public
    /// for fault-injection tests that hand-craft one side of a link.
    pub fn from_streams(
        rank: usize,
        world: usize,
        streams: Vec<Option<TcpStream>>,
    ) -> TcpTransport {
        Self::from_streams_batched(rank, world, streams, batching_enabled())
    }

    fn from_streams_batched(
        rank: usize,
        world: usize,
        streams: Vec<Option<TcpStream>>,
        batch: bool,
    ) -> TcpTransport {
        let links = streams.into_iter().map(|s| s.map(LinkStream::Tcp)).collect();
        let inner = StreamTransport::from_streams(rank, world, links, batch);
        let stats = Arc::clone(&inner.stats);
        TcpTransport { inner, stats }
    }

    /// Per-link-class counters (frames / words / write syscalls) — the
    /// fabric bench reads the syscall-batching effect from here.
    pub fn link_stats(&self) -> Arc<LinkClassStats> {
        Arc::clone(&self.inner.link_stats)
    }

    /// The recorded loss cause for `peer`'s link, if its reader has
    /// already classified a failure.
    pub fn peer_lost(&self, peer: usize) -> Option<(PeerLostCause, String)> {
        self.inner.peer_lost(peer)
    }

    /// Every peer whose link has died so far, with the classified cause
    /// the reader thread recorded — the transport-level failure record
    /// the elastic membership layer reads.
    pub fn lost_peers(&self) -> Vec<(usize, PeerLostCause)> {
        self.inner.lost_peers()
    }
}

delegate_transport!(TcpTransport);

/// Rank 0: accept `world - 1` registrations, then publish the directory.
/// The registration connections become the `0 <-> i` mesh links.
fn bootstrap_rank0(opts: &TcpOptions, deadline: Instant) -> io::Result<Vec<Option<TcpStream>>> {
    let world = opts.world;
    let listener = TcpListener::bind(&opts.rendezvous[..])?;
    let mut streams: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
    let mut endpoints: Vec<Option<(Ipv4Addr, u32)>> = (0..world).map(|_| None).collect();

    for _ in 1..world {
        let mut s = accept_deadline(&listener, deadline)?;
        let frame = read_handshake(&mut s, deadline, "registration")?;
        if frame.len() != 4 || frame[0] != REG {
            return Err(bad_data(format!("bad registration frame {frame:?}")));
        }
        let (w, r, port) = (frame[1], frame[2], frame[3]);
        if w as usize != world {
            return Err(bad_data(format!("peer expects world {w}, rank 0 has {world}")));
        }
        let r = r as usize;
        if r == 0 || r >= world {
            return Err(bad_data(format!("registration from invalid rank {r}")));
        }
        if streams[r].is_some() {
            return Err(bad_data(format!("duplicate registration for rank {r}")));
        }
        let IpAddr::V4(ip) = s.peer_addr()?.ip() else {
            return Err(bad_data("tcp fabric directory is IPv4-only".into()));
        };
        endpoints[r] = Some((ip, port));
        streams[r] = Some(s);
    }

    let mut dir = Vec::with_capacity(2 + 2 * (world - 1));
    dir.push(DIR);
    dir.push(world as u32);
    for e in endpoints.into_iter().skip(1) {
        let (ip, port) = e.expect("all ranks registered");
        dir.push(u32::from(ip));
        dir.push(port);
    }
    for s in streams.iter_mut().skip(1) {
        let s = s.as_mut().expect("all ranks registered");
        write_frame(s, &dir)?;
        s.flush()?;
    }
    Ok(streams)
}

/// Nonzero rank: register with rank 0, learn the directory, then dial
/// every lower rank and accept every higher one.
fn bootstrap_peer(opts: &TcpOptions, deadline: Instant) -> io::Result<Vec<Option<TcpStream>>> {
    let (world, rank) = (opts.world, opts.rank);
    let listener = TcpListener::bind((Ipv4Addr::UNSPECIFIED, 0))?;
    let my_port = listener.local_addr()?.port();

    let mut to_zero = connect_retry(&opts.rendezvous[..], deadline)?;
    write_frame(&mut to_zero, &[REG, world as u32, rank as u32, my_port as u32])?;
    to_zero.flush()?;
    let dir = read_handshake(&mut to_zero, deadline, "directory")?;
    if dir.len() != 2 + 2 * (world - 1) || dir[0] != DIR || dir[1] as usize != world {
        return Err(bad_data(format!("bad directory frame (len {})", dir.len())));
    }

    let mut streams: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
    streams[0] = Some(to_zero);

    for peer in 1..rank {
        let ip = Ipv4Addr::from(dir[2 * peer]);
        let port = dir[2 * peer + 1] as u16;
        let mut s = connect_retry(SocketAddrV4::new(ip, port), deadline)?;
        write_frame(&mut s, &[MESH, world as u32, rank as u32])?;
        s.flush()?;
        streams[peer] = Some(s);
    }
    for _ in rank + 1..world {
        let mut s = accept_deadline(&listener, deadline)?;
        let frame = read_handshake(&mut s, deadline, "mesh")?;
        if frame.len() != 3 || frame[0] != MESH {
            return Err(bad_data(format!("bad mesh frame {frame:?}")));
        }
        let (w, peer) = (frame[1], frame[2]);
        let peer = peer as usize;
        if w as usize != world || peer <= rank || peer >= world {
            return Err(bad_data(format!("mesh handshake from invalid rank {peer}")));
        }
        if streams[peer].is_some() {
            return Err(bad_data(format!("duplicate mesh connection from rank {peer}")));
        }
        streams[peer] = Some(s);
    }
    Ok(streams)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::Transport;
    use crate::net::free_loopback_addr;

    fn pair(addr: &str) -> (thread::JoinHandle<TcpTransport>, TcpTransport) {
        let opts0 = TcpOptions::new(2, 0, addr);
        let opts1 = TcpOptions::new(2, 1, addr);
        let h = thread::spawn(move || TcpTransport::connect(&opts0).unwrap());
        let t1 = TcpTransport::connect(&opts1).unwrap();
        (h, t1)
    }

    #[test]
    fn send_recv_pair_over_tcp() {
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let h = thread::spawn(move || {
            t1.send(0, vec![1, 2, 3]);
            t1.recv(0)
        });
        let t0 = h0.join().unwrap();
        assert_eq!(t0.recv(1), vec![1, 2, 3]);
        t0.send(1, vec![9]);
        assert_eq!(h.join().unwrap(), vec![9]);
    }

    #[test]
    fn exchange_is_symmetric_over_tcp() {
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let h = thread::spawn(move || t1.exchange(0, vec![20]));
        let t0 = h0.join().unwrap();
        assert_eq!(t0.exchange(1, vec![10]), vec![20]);
        assert_eq!(h.join().unwrap(), vec![10]);
    }

    #[test]
    fn messages_ordered_per_pair_over_tcp() {
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let h = thread::spawn(move || {
            for i in 0..200u32 {
                t1.send(0, vec![i; 17]);
            }
            t1
        });
        let t0 = h0.join().unwrap();
        for i in 0..200u32 {
            assert_eq!(t0.recv(1), vec![i; 17]);
        }
        drop(h.join().unwrap());
    }

    #[test]
    fn self_channel_without_network() {
        let t = TcpTransport::connect(&TcpOptions::new(1, 0, "127.0.0.1:1")).unwrap();
        t.send(0, vec![7]);
        assert_eq!(t.recv(0), vec![7]);
        assert_eq!(t.exchange(0, vec![8]), vec![8]);
    }

    #[test]
    fn send_shared_crosses_the_wire_like_send() {
        use std::sync::Arc;
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let t0 = h0.join().unwrap();
        let blob = Arc::new(vec![1u32, 2, 3, 4]);
        t1.send_shared(0, &blob);
        assert_eq!(t0.recv(1), vec![1, 2, 3, 4]);
        // accounting identical to an owned send; sender copy untouched
        assert_eq!(t1.stats.message_count(), 1);
        assert_eq!(t1.stats.bytes(), 16);
        assert_eq!(*blob, vec![1, 2, 3, 4]);
    }

    #[test]
    fn stats_count_payload_words() {
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let t0 = h0.join().unwrap();
        t1.send(0, vec![0; 10]);
        assert_eq!(t0.recv(1).len(), 10);
        assert_eq!(t1.stats.message_count(), 1);
        assert_eq!(t1.stats.bytes(), 40);
        assert_eq!(t0.stats.bytes(), 0, "recv side counts nothing, like LocalFabric");
    }

    #[test]
    fn link_traffic_reports_the_tcp_class() {
        use crate::collectives::transport::LinkClass;
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let t0 = h0.join().unwrap();
        t1.send(0, vec![0; 10]);
        assert_eq!(t0.recv(1).len(), 10);
        let lt = t1.link_traffic();
        assert_eq!(lt.len(), 1);
        assert_eq!(lt[0].class, LinkClass::Tcp);
        assert_eq!(lt[0].frames, 1);
        assert_eq!(lt[0].bytes, 40);
        assert!(t0.link_traffic().is_empty(), "recv side counts nothing");
    }

    #[test]
    fn tcp_endpoint_is_sync() {
        // shared across the pipelined engine's comm pool via TagMux
        fn assert_share<T: Send + Sync>() {}
        assert_share::<TcpTransport>();
    }

    #[test]
    fn recv_checked_reports_clean_fin() {
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let t0 = h0.join().unwrap();
        drop(t1); // graceful shutdown: writers flush + FIN
        let err = t0.recv_checked(1).unwrap_err();
        assert_eq!(err.peer, 1);
        assert!(err.reason.contains("closed"), "{err}");
        assert_eq!(err.cause, PeerLostCause::CleanFin, "orderly FIN classification");
        assert_eq!(t0.lost_peers(), vec![(1, PeerLostCause::CleanFin)]);
    }

    #[test]
    fn mid_frame_eof_classified_as_mid_stream() {
        // a raw client writes half a frame then disappears: the reader
        // must classify the mid-stream EOF distinctly from a clean FIN
        let addr = free_loopback_addr();
        let listener = TcpListener::bind(&addr[..]).unwrap();
        let h = thread::spawn(move || {
            let mut s = TcpStream::connect(&addr[..]).unwrap();
            // header promises 4 words, only 1 arrives
            use std::io::Write;
            s.write_all(&4u32.to_le_bytes()).unwrap();
            s.write_all(&7u32.to_le_bytes()).unwrap();
            s.flush().unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        h.join().unwrap();
        let streams: Vec<Option<TcpStream>> = vec![None, Some(stream)];
        let t = TcpTransport::from_streams(0, 2, streams);
        let err = t.recv_checked(1).unwrap_err();
        assert_eq!(err.cause, PeerLostCause::MidStream, "{err}");
    }

    #[test]
    fn sever_converts_a_silent_stall_into_a_timeout_loss() {
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let t0 = h0.join().unwrap();
        // rank 1 never sends (a "stalled" peer); rank 0 severs the link
        t0.sever(1);
        let err = t0.recv_checked(1).unwrap_err();
        assert_eq!(err.cause, PeerLostCause::Timeout, "{err}");
        assert!(err.reason.contains("severed"), "{err}");
        assert_eq!(t0.lost_peers(), vec![(1, PeerLostCause::Timeout)]);
        drop(t1);
    }

    #[test]
    fn try_recv_and_send_checked_over_tcp() {
        let addr = free_loopback_addr();
        let (h0, t1) = pair(&addr);
        let t0 = h0.join().unwrap();
        assert!(t0.try_recv(1).unwrap().is_none(), "idle link polls empty");
        t1.send_checked(0, vec![42]).unwrap();
        // poll until the reader thread lands the frame in the inbox
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match t0.try_recv(1).unwrap() {
                Some(msg) => {
                    assert_eq!(msg, vec![42]);
                    break;
                }
                None if Instant::now() > deadline => panic!("frame never arrived"),
                None => thread::sleep(Duration::from_millis(1)),
            }
        }
        drop(t1);
    }

    #[test]
    fn invalid_options_rejected() {
        assert!(TcpTransport::connect(&TcpOptions::new(0, 0, "127.0.0.1:1")).is_err());
        assert!(TcpTransport::connect(&TcpOptions::new(2, 5, "127.0.0.1:1")).is_err());
    }

    #[test]
    fn connect_retry_survives_a_slow_listener() {
        // the listener binds ~150ms after the dial starts — the backoff
        // loop must ride out the refused connections and succeed
        let addr = free_loopback_addr();
        let bind_addr = addr.clone();
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(150));
            let listener = TcpListener::bind(&bind_addr[..]).expect("late bind");
            let _ = listener.accept();
        });
        let deadline = Instant::now() + Duration::from_secs(10);
        let s = connect_retry(&addr[..], deadline).expect("late listener not reached");
        drop(s);
        h.join().unwrap();
    }

    #[test]
    fn connect_retry_times_out_with_attempt_count() {
        // a port nothing listens on: the retry loop must stop at the
        // deadline and say how hard it tried
        let addr = free_loopback_addr(); // bound then released by the helper
        let deadline = Instant::now() + Duration::from_millis(200);
        let err = connect_retry(&addr[..], deadline).unwrap_err();
        assert!(
            err.to_string().contains("connect attempts"),
            "error should report attempts: {err}"
        );
        assert!(Instant::now() >= deadline, "must keep trying until the deadline");
    }

    #[test]
    fn slow_starting_rank0_does_not_fail_the_fleet() {
        // end-to-end version of the backoff guarantee: rank 1 dials the
        // rendezvous well before rank 0 binds it
        let addr = free_loopback_addr();
        let addr0 = addr.clone();
        let h1 = {
            let addr = addr.clone();
            thread::spawn(move || TcpTransport::connect(&TcpOptions::new(2, 1, addr)).unwrap())
        };
        thread::sleep(Duration::from_millis(200));
        let t0 = TcpTransport::connect(&TcpOptions::new(2, 0, addr0)).unwrap();
        let t1 = h1.join().unwrap();
        t1.send(0, vec![42]);
        assert_eq!(t0.recv(1), vec![42]);
    }
}
