//! Shared stream data plane for the socket fabrics.
//!
//! [`StreamTransport`] is the rank-local endpoint the TCP
//! ([`super::tcp`]), Unix-socket ([`super::unix`]) and mixed
//! ([`super::mixed`]) fabrics all wrap: per-peer writer/reader threads
//! over any [`LinkStream`], the length-prefixed framing of
//! [`super::frame`], [`super::pool::BytePool`] scratch recycling,
//! first-wins loss-cause classification, and clean flush+FIN shutdown.
//! Bootstrap — who dials whom, over which socket family — is the only
//! thing the fabrics do differently.
//!
//! ## Batched vectored writes
//!
//! Each writer thread drains its outgoing channel greedily: the first
//! `recv` blocks, then every message already queued behind it joins the
//! same batch (bounded by [`MAX_BATCH_WORDS`]).  The batch leaves
//! through `write_vectored` — length prefixes and payloads as separate
//! `IoSlice`s, partial writes resumed mid-slice — and the stream is
//! flushed once per drain, exactly when the channel is momentarily
//! empty.  A pipelined step's many small `TagMux` frames therefore cost
//! a few `writev` syscalls instead of one write + flush each.
//! `REDSYNC_NO_WRITE_BATCH=1` falls back to frame-per-write (the
//! fallback, too, flushes once per drain, not once per frame).  Wire
//! bytes are identical either way — batching moves syscall boundaries,
//! never frame boundaries, so concurrent senders still never interleave
//! words inside a frame.
//!
//! ## Link classes
//!
//! Every peer link is classified ([`LinkClass`]) and its traffic —
//! frames, payload bytes, and actual write syscalls — is accounted per
//! class in [`LinkClassStats`], surfaced through
//! [`Transport::link_traffic`] into the train report.  `frames /
//! writes` is the measured syscall batch size, the visible record of
//! the coalescing above.

use super::frame::{read_frame_with, write_frame_with, write_frames_vectored};
use super::pool::BytePool;
use crate::collectives::transport::{
    lock_ok, LinkClass, LinkTraffic, Payload, PeerLostCause, TrafficStats, Transport,
    TransportError,
};
use std::io::{self, BufReader, IoSlice, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

/// Upper bound on the payload words one writer-thread drain coalesces
/// into a single vectored batch (4 MiB of payload): keeps the staging
/// scratch within [`BytePool`]'s recycling cap and bounds the latency
/// of the first frame in a batch behind a deep queue.
pub(crate) const MAX_BATCH_WORDS: usize = 1 << 20;

/// Whether writer threads coalesce queued frames into vectored batches
/// (the default) or write frame-per-syscall.  `REDSYNC_NO_WRITE_BATCH=1`
/// forces the fallback — the A/B lever the fabric bench and CI use.
pub(crate) fn batching_enabled() -> bool {
    std::env::var("REDSYNC_NO_WRITE_BATCH").map(|v| v != "1").unwrap_or(true)
}

/// One established peer connection of either socket family.  Exists so
/// the data plane is written once: reads, writes and shutdown forward
/// to the underlying stream.  `write_vectored` is forwarded explicitly
/// — the `Write` default would degrade every batch to its first slice.
pub enum LinkStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl LinkStream {
    pub fn class(&self) -> LinkClass {
        match self {
            LinkStream::Tcp(_) => LinkClass::Tcp,
            LinkStream::Unix(_) => LinkClass::Unix,
        }
    }

    pub fn try_clone(&self) -> io::Result<LinkStream> {
        match self {
            LinkStream::Tcp(s) => s.try_clone().map(LinkStream::Tcp),
            LinkStream::Unix(s) => s.try_clone().map(LinkStream::Unix),
        }
    }

    pub fn shutdown(&self, how: Shutdown) -> io::Result<()> {
        match self {
            LinkStream::Tcp(s) => s.shutdown(how),
            LinkStream::Unix(s) => s.shutdown(how),
        }
    }
}

impl Read for LinkStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            LinkStream::Tcp(s) => s.read(buf),
            LinkStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for LinkStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            LinkStream::Tcp(s) => s.write(buf),
            LinkStream::Unix(s) => s.write(buf),
        }
    }

    fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> io::Result<usize> {
        match self {
            LinkStream::Tcp(s) => s.write_vectored(bufs),
            LinkStream::Unix(s) => s.write_vectored(bufs),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            LinkStream::Tcp(s) => s.flush(),
            LinkStream::Unix(s) => s.flush(),
        }
    }
}

fn cidx(c: LinkClass) -> usize {
    match c {
        LinkClass::Mem => 0,
        LinkClass::Unix => 1,
        LinkClass::Tcp => 2,
    }
}

const CLASSES: [LinkClass; 3] = [LinkClass::Mem, LinkClass::Unix, LinkClass::Tcp];

/// Per-link-class traffic counters for one endpoint: frames and payload
/// words at `send` (the peer's class), write syscalls from the writer
/// threads.  Same relaxed-atomic discipline as [`TrafficStats`], which
/// keeps counting the class-blind totals unchanged next to this.
#[derive(Default, Debug)]
pub struct LinkClassStats {
    frames: [AtomicU64; 3],
    words: [AtomicU64; 3],
    writes: [AtomicU64; 3],
}

impl LinkClassStats {
    fn count(&self, class: LinkClass, words: u64) {
        self.frames[cidx(class)].fetch_add(1, Ordering::Relaxed);
        self.words[cidx(class)].fetch_add(words, Ordering::Relaxed);
    }

    fn add_writes(&self, class: LinkClass, n: u64) {
        self.writes[cidx(class)].fetch_add(n, Ordering::Relaxed);
    }

    /// Snapshot of every class that carried traffic, in
    /// `Mem < Unix < Tcp` order.  Bytes are payload bytes (`4 * words`),
    /// matching the [`TrafficStats`] convention.
    pub fn snapshot(&self) -> Vec<LinkTraffic> {
        CLASSES
            .iter()
            .filter_map(|&class| {
                let i = cidx(class);
                let frames = self.frames[i].load(Ordering::Relaxed);
                if frames == 0 {
                    return None;
                }
                Some(LinkTraffic {
                    class,
                    frames,
                    bytes: self.words[i].load(Ordering::Relaxed) * 4,
                    writes: self.writes[i].load(Ordering::Relaxed),
                })
            })
            .collect()
    }
}

/// The cause a peer's reader thread recorded before closing its inbox,
/// shared between the reader, `recv_checked` and `sever`.
pub(crate) type CauseCell = Arc<Mutex<Option<(PeerLostCause, String)>>>;

/// Record a loss cause exactly once: the first classification wins, so
/// a sever-then-reset sequence keeps the sever's `Timeout` verdict and a
/// reader racing a sever cannot overwrite it.
pub(crate) fn record_cause(cell: &CauseCell, cause: PeerLostCause, reason: String) {
    let mut slot = lock_ok(cell);
    if slot.is_none() {
        *slot = Some((cause, reason));
    }
}

/// Classify a data-plane stream error into the structured
/// [`PeerLostCause`] vocabulary: mid-frame EOF (peer vanished with data
/// in flight) vs OS-level reset vs read deadline vs corrupt framing.
pub(crate) fn classify_io(e: &io::Error) -> PeerLostCause {
    match e.kind() {
        io::ErrorKind::UnexpectedEof => PeerLostCause::MidStream,
        io::ErrorKind::ConnectionReset
        | io::ErrorKind::ConnectionAborted
        | io::ErrorKind::BrokenPipe => PeerLostCause::Reset,
        io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => PeerLostCause::Timeout,
        io::ErrorKind::InvalidData => PeerLostCause::Corrupt,
        _ => PeerLostCause::Unknown,
    }
}

/// One rank's endpoint over established per-peer streams: the engine
/// room of `TcpTransport`, `UnixTransport` and `MixedFabric`.  The
/// wrappers own bootstrap and delegate every `Transport` method here.
pub struct StreamTransport {
    rank: usize,
    world: usize,
    txs: Vec<Mutex<Sender<Payload>>>,
    rxs: Vec<Mutex<Receiver<Payload>>>,
    /// Why each peer's link died, for `recv_checked` reports and the
    /// elastic layer's detection (set once, right before the inbox
    /// closes — clean FIN vs mid-stream EOF vs reset vs corrupt frame).
    causes: Vec<CauseCell>,
    /// One extra handle per peer socket so [`Transport::sever`] can
    /// force-close a stalled link from the monitor thread.
    sever_handles: Vec<Option<LinkStream>>,
    /// The wire class each peer link rides on (`Mem` for self).
    classes: Vec<LinkClass>,
    writers: Vec<JoinHandle<()>>,
    /// Per-process traffic counters (same accounting as `LocalFabric`:
    /// payload words at `send`; the 4-byte frame header is `4 *
    /// message_count()` extra wire bytes).
    pub stats: Arc<TrafficStats>,
    /// Per-link-class breakdown of the same traffic, plus write-syscall
    /// counts from the writer threads.
    pub link_stats: Arc<LinkClassStats>,
}

impl StreamTransport {
    /// Wire up the data plane over an established stream per peer
    /// (`streams[rank]` is ignored; all others must be `Some`).
    /// `batch` selects coalesced vectored writes vs frame-per-write.
    pub fn from_streams(
        rank: usize,
        world: usize,
        mut streams: Vec<Option<LinkStream>>,
        batch: bool,
    ) -> StreamTransport {
        let stats = Arc::new(TrafficStats::default());
        let link_stats = Arc::new(LinkClassStats::default());
        // Framing scratch recycles through a shared free list: one
        // buffer per writer/reader thread for its lifetime, returned on
        // exit — steady-state framing never allocates staging bytes.
        let pool = Arc::new(BytePool::new(2 * world.max(1)));
        let mut txs = Vec::with_capacity(world);
        let mut rxs = Vec::with_capacity(world);
        let mut causes = Vec::with_capacity(world);
        let mut sever_handles = Vec::with_capacity(world);
        let mut classes = Vec::with_capacity(world);
        let mut writers = Vec::with_capacity(world.saturating_sub(1));
        for peer in 0..world {
            let cause: CauseCell = Arc::new(Mutex::new(None));
            causes.push(Arc::clone(&cause));
            if peer == rank {
                // self-channel: in-memory, like LocalFabric's self pair
                let (tx, rx) = channel::<Payload>();
                txs.push(Mutex::new(tx));
                rxs.push(Mutex::new(rx));
                sever_handles.push(None);
                classes.push(LinkClass::Mem);
                continue;
            }
            let stream = streams[peer].take().expect("bootstrap left a peer unconnected");
            if let LinkStream::Tcp(s) = &stream {
                let _ = s.set_nodelay(true);
            }
            let class = stream.class();
            classes.push(class);
            let reader_stream = stream.try_clone().expect("stream clone");
            sever_handles.push(stream.try_clone().ok());

            let (tx, writer_rx) = channel::<Payload>();
            let writer_pool = Arc::clone(&pool);
            let writer_link_stats = Arc::clone(&link_stats);
            let writer = thread::Builder::new()
                .name(format!("redsync-net-w{rank}-{peer}"))
                .spawn(move || {
                    write_loop(stream, writer_rx, &writer_pool, &writer_link_stats, class, batch, rank, peer)
                })
                .expect("spawn writer thread");

            let (inbox_tx, inbox_rx) = channel::<Payload>();
            let reader_pool = Arc::clone(&pool);
            thread::Builder::new()
                .name(format!("redsync-net-r{rank}-{peer}"))
                .spawn(move || {
                    let mut r = BufReader::new(reader_stream);
                    let mut scratch = reader_pool.get();
                    loop {
                        match read_frame_with(&mut r, &mut scratch) {
                            Ok(Some(msg)) => {
                                if inbox_tx.send(Payload::Owned(msg)).is_err() {
                                    break; // transport dropped
                                }
                            }
                            // clean FIN: the peer shut down between frames
                            Ok(None) => {
                                record_cause(
                                    &cause,
                                    PeerLostCause::CleanFin,
                                    "connection closed by peer".into(),
                                );
                                break;
                            }
                            // mid-frame EOF (peer crash), OS reset,
                            // corrupt or oversized frame: distinct from
                            // clean shutdown — classify and record the
                            // cause for recv_checked (and the elastic
                            // failure detector) before the inbox closes
                            Err(e) => {
                                crate::log_warn!(
                                    "rank {rank}: recv stream from rank {peer} broke: {e}"
                                );
                                record_cause(&cause, classify_io(&e), format!("stream broke: {e}"));
                                break;
                            }
                        }
                    }
                    reader_pool.put(scratch);
                })
                .expect("spawn reader thread");

            txs.push(Mutex::new(tx));
            rxs.push(Mutex::new(inbox_rx));
            writers.push(writer);
        }
        StreamTransport {
            rank,
            world,
            txs,
            rxs,
            causes,
            sever_handles,
            classes,
            writers,
            stats,
            link_stats,
        }
    }

    /// The wire class of the link to `peer` (`Mem` for the self-link).
    pub fn class_of(&self, peer: usize) -> LinkClass {
        self.classes[peer]
    }

    /// The recorded loss cause for `peer`'s link, if its reader has
    /// already classified a failure.
    pub fn peer_lost(&self, peer: usize) -> Option<(PeerLostCause, String)> {
        lock_ok(&self.causes[peer]).clone()
    }

    /// Every peer whose link has died so far, with the classified cause
    /// the reader thread recorded — the transport-level failure record
    /// the elastic membership layer reads.
    pub fn lost_peers(&self) -> Vec<(usize, PeerLostCause)> {
        (0..self.world)
            .filter_map(|p| self.peer_lost(p).map(|(cause, _)| (p, cause)))
            .collect()
    }

    /// Build the error `recv_checked`/`try_recv` report for a closed
    /// inbox from the reader's recorded classification.
    fn lost_error(&self, from: usize) -> TransportError {
        match self.peer_lost(from) {
            Some((cause, reason)) => TransportError::with_cause(from, reason, cause),
            None => TransportError::with_cause(from, "connection closed", PeerLostCause::Unknown),
        }
    }
}

/// One writer thread's life: greedily drain the outgoing channel,
/// coalesce each drain into as few write syscalls as the stream takes
/// (or frame-per-write when `batch` is off), flush once per drain, and
/// on channel close flush + FIN.  Write failures end the thread — the
/// recv side raises the loss.
#[allow(clippy::too_many_arguments)]
fn write_loop(
    mut stream: LinkStream,
    rx: Receiver<Payload>,
    pool: &BytePool,
    link_stats: &LinkClassStats,
    class: LinkClass,
    batch: bool,
    rank: usize,
    peer: usize,
) {
    let mut scratch = pool.get();
    let mut pending: Vec<Payload> = Vec::new();
    loop {
        let Ok(first) = rx.recv() else { break };
        pending.clear();
        pending.push(first);
        // greedy drain: everything queued while the last write was in
        // flight joins this batch (bounded so staging stays poolable)
        let mut words = pending[0].as_slice().len();
        while words < MAX_BATCH_WORDS {
            match rx.try_recv() {
                Ok(m) => {
                    words += m.as_slice().len();
                    pending.push(m);
                }
                // empty or disconnected either way: write what we have
                Err(_) => break,
            }
        }
        let res: io::Result<usize> = if batch {
            let msgs: Vec<&[u32]> = pending.iter().map(|p| p.as_slice()).collect();
            write_frames_vectored(&mut stream, &msgs, &mut scratch)
        } else {
            // frame-per-write fallback: same wire bytes, one syscall
            // per frame — but still one flush per drain, not per frame
            let mut n = 0;
            let mut out = Ok(());
            for p in &pending {
                out = write_frame_with(&mut stream, p.as_slice(), &mut scratch);
                if out.is_err() {
                    break;
                }
                n += 1;
            }
            out.map(|()| n)
        };
        let writes = match res {
            Ok(n) => n,
            Err(e) => {
                // recv side raises the panic; keep the cause
                crate::log_warn!("rank {rank}: send to rank {peer} failed: {e}");
                pool.put(scratch);
                return;
            }
        };
        link_stats.add_writes(class, writes as u64);
        // the channel is momentarily empty here: flush once per drain
        if let Err(e) = stream.flush() {
            crate::log_warn!("rank {rank}: send to rank {peer} failed: {e}");
            pool.put(scratch);
            return;
        }
    }
    // channel closed: graceful shutdown — flush + FIN
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Write);
    pool.put(scratch);
}

impl Transport for StreamTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, to: usize, msg: Vec<u32>) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.words.fetch_add(msg.len() as u64, Ordering::Relaxed);
        self.link_stats.count(self.classes[to], msg.len() as u64);
        self.txs[to]
            .lock()
            .unwrap()
            .send(Payload::Owned(msg))
            .unwrap_or_else(|_| panic!("rank {}: connection to rank {to} closed", self.rank));
    }

    fn send_shared(&self, to: usize, msg: &Arc<Vec<u32>>) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.words.fetch_add(msg.len() as u64, Ordering::Relaxed);
        self.link_stats.count(self.classes[to], msg.len() as u64);
        // the writer thread encodes straight from the shared buffer —
        // the broadcast sender clones nothing
        self.txs[to]
            .lock()
            .unwrap()
            .send(Payload::Shared(Arc::clone(msg)))
            .unwrap_or_else(|_| panic!("rank {}: connection to rank {to} closed", self.rank));
    }

    fn recv_checked(&self, from: usize) -> Result<Vec<u32>, TransportError> {
        lock_ok(&self.rxs[from])
            .recv()
            .map(Payload::into_vec)
            .map_err(|_| self.lost_error(from))
    }

    fn try_recv(&self, from: usize) -> Result<Option<Vec<u32>>, TransportError> {
        match lock_ok(&self.rxs[from]).try_recv() {
            Ok(p) => Ok(Some(p.into_vec())),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(self.lost_error(from)),
        }
    }

    fn send_checked(&self, to: usize, msg: Vec<u32>) -> Result<(), TransportError> {
        let words = msg.len() as u64;
        match lock_ok(&self.txs[to]).send(Payload::Owned(msg)) {
            Ok(()) => {
                self.stats.messages.fetch_add(1, Ordering::Relaxed);
                self.stats.words.fetch_add(words, Ordering::Relaxed);
                self.link_stats.count(self.classes[to], words);
                Ok(())
            }
            Err(_) => Err(self.lost_error(to)),
        }
    }

    /// Force-close the stream to `peer`: its reader errors out (the
    /// recorded cause stays `Timeout` — the sever's verdict), so a
    /// receive blocked on a stalled peer fails instead of hanging.
    fn sever(&self, peer: usize) {
        if let Some(stream) = &self.sever_handles[peer] {
            record_cause(
                &self.causes[peer],
                PeerLostCause::Timeout,
                format!("link to rank {peer} severed after lease expiry"),
            );
            let _ = stream.shutdown(Shutdown::Both);
        }
    }

    fn recv(&self, from: usize) -> Vec<u32> {
        self.recv_checked(from).unwrap_or_else(|e| {
            panic!("rank {}: connection to rank {from} closed ({e})", self.rank)
        })
    }

    fn link_traffic(&self) -> Vec<LinkTraffic> {
        self.link_stats.snapshot()
    }
}

impl Drop for StreamTransport {
    fn drop(&mut self) {
        // Close every writer channel, then join the writers: queued
        // messages are flushed and each socket gets a clean FIN.
        self.txs.clear();
        for h in self.writers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Implement [`Transport`] for a fabric wrapper by delegating every
/// method to its `inner: StreamTransport` field — the three socket
/// fabrics differ only in bootstrap, never in data-plane behavior.
macro_rules! delegate_transport {
    ($t:ty) => {
        impl crate::collectives::transport::Transport for $t {
            fn rank(&self) -> usize {
                crate::collectives::transport::Transport::rank(&self.inner)
            }
            fn world(&self) -> usize {
                crate::collectives::transport::Transport::world(&self.inner)
            }
            fn send(&self, to: usize, msg: Vec<u32>) {
                crate::collectives::transport::Transport::send(&self.inner, to, msg)
            }
            fn send_shared(&self, to: usize, msg: &std::sync::Arc<Vec<u32>>) {
                crate::collectives::transport::Transport::send_shared(&self.inner, to, msg)
            }
            fn recv_checked(
                &self,
                from: usize,
            ) -> Result<Vec<u32>, crate::collectives::transport::TransportError> {
                crate::collectives::transport::Transport::recv_checked(&self.inner, from)
            }
            fn try_recv(
                &self,
                from: usize,
            ) -> Result<Option<Vec<u32>>, crate::collectives::transport::TransportError> {
                crate::collectives::transport::Transport::try_recv(&self.inner, from)
            }
            fn send_checked(
                &self,
                to: usize,
                msg: Vec<u32>,
            ) -> Result<(), crate::collectives::transport::TransportError> {
                crate::collectives::transport::Transport::send_checked(&self.inner, to, msg)
            }
            fn sever(&self, peer: usize) {
                crate::collectives::transport::Transport::sever(&self.inner, peer)
            }
            fn recv(&self, from: usize) -> Vec<u32> {
                crate::collectives::transport::Transport::recv(&self.inner, from)
            }
            fn exchange(&self, peer: usize, msg: Vec<u32>) -> Vec<u32> {
                crate::collectives::transport::Transport::exchange(&self.inner, peer, msg)
            }
            fn link_traffic(&self) -> Vec<crate::collectives::transport::LinkTraffic> {
                crate::collectives::transport::Transport::link_traffic(&self.inner)
            }
        }
    };
}
pub(crate) use delegate_transport;

#[cfg(test)]
mod tests {
    use super::*;

    /// A connected 2-rank fabric over a `UnixStream::pair` — no
    /// filesystem paths, no bootstrap; pure data-plane surface.
    fn pair(batch: bool) -> (StreamTransport, StreamTransport) {
        let (a, b) = UnixStream::pair().expect("socketpair");
        let t0 =
            StreamTransport::from_streams(0, 2, vec![None, Some(LinkStream::Unix(a))], batch);
        let t1 =
            StreamTransport::from_streams(1, 2, vec![Some(LinkStream::Unix(b)), None], batch);
        (t0, t1)
    }

    #[test]
    fn batched_drain_delivers_all_frames_in_order() {
        let (t0, t1) = pair(true);
        for i in 0..300u32 {
            t0.send(1, vec![i; 9]);
        }
        for i in 0..300u32 {
            assert_eq!(t1.recv(0), vec![i; 9]);
        }
        drop(t1);
        drop(t0);
    }

    #[test]
    fn snapshot_reports_unix_class_with_batch_accounting() {
        let (t0, t1) = pair(true);
        for i in 0..100u32 {
            t0.send(1, vec![i, i, i]);
        }
        for _ in 0..100 {
            t1.recv(0);
        }
        assert!(t1.link_traffic().is_empty(), "receiver sent nothing");
        // drop joins the writer thread, making the write counts final
        let ls = Arc::clone(&t0.link_stats);
        drop(t0);
        let lt = ls.snapshot();
        assert_eq!(lt.len(), 1);
        assert_eq!(lt[0].class, LinkClass::Unix);
        assert_eq!(lt[0].frames, 100);
        assert_eq!(lt[0].bytes, 100 * 3 * 4);
        assert!(lt[0].writes >= 1 && lt[0].writes <= 100, "syscalls never exceed frames");
        drop(t1);
    }

    #[test]
    fn unbatched_writer_is_frame_per_write() {
        let (t0, t1) = pair(false);
        for i in 0..50u32 {
            t0.send(1, vec![i]);
        }
        for _ in 0..50 {
            t1.recv(0);
        }
        let ls = Arc::clone(&t0.link_stats);
        drop(t0); // join the writer: counts final
        let lt = ls.snapshot();
        assert_eq!(lt[0].frames, 50);
        assert_eq!(lt[0].writes, 50, "fallback path writes one syscall per frame");
        drop(t1);
    }

    #[test]
    fn batched_writer_delivers_variable_length_frames_bitexact() {
        let (t0, t1) = pair(true);
        let mut expect = Vec::new();
        for i in 0..200u32 {
            let msg: Vec<u32> = (0..(i % 7)).map(|j| i * 31 + j).collect();
            expect.push(msg.clone());
            t0.send(1, msg);
        }
        for e in &expect {
            assert_eq!(&t1.recv(0), e);
        }
        drop(t0);
        drop(t1);
    }

    #[test]
    fn mixed_classes_are_accounted_separately() {
        // hand-build a 3-rank endpoint with one unix and one tcp peer
        use crate::net::free_loopback_addr;
        use std::net::TcpListener;
        let (ua, ub) = UnixStream::pair().unwrap();
        let addr = free_loopback_addr();
        let listener = TcpListener::bind(&addr[..]).unwrap();
        let client = TcpStream::connect(&addr[..]).unwrap();
        let (server, _) = listener.accept().unwrap();
        let t0 = StreamTransport::from_streams(
            0,
            3,
            vec![None, Some(LinkStream::Unix(ua)), Some(LinkStream::Tcp(client))],
            true,
        );
        let t1 =
            StreamTransport::from_streams(1, 3, vec![Some(LinkStream::Unix(ub)), None, None], true);
        let t2 = StreamTransport::from_streams(
            2,
            3,
            vec![Some(LinkStream::Tcp(server)), None, None],
            true,
        );
        assert_eq!(t0.class_of(0), LinkClass::Mem);
        assert_eq!(t0.class_of(1), LinkClass::Unix);
        assert_eq!(t0.class_of(2), LinkClass::Tcp);
        t0.send(1, vec![1, 2]);
        t0.send(2, vec![3, 4, 5]);
        t0.send(0, vec![9]);
        assert_eq!(t1.recv(0), vec![1, 2]);
        assert_eq!(t2.recv(0), vec![3, 4, 5]);
        assert_eq!(t0.recv(0), vec![9]);
        let lt = t0.link_traffic();
        assert_eq!(lt.len(), 3);
        assert_eq!(lt[0].class, LinkClass::Mem);
        assert_eq!((lt[0].frames, lt[0].bytes, lt[0].writes), (1, 4, 0));
        assert_eq!(lt[1].class, LinkClass::Unix);
        assert_eq!((lt[1].frames, lt[1].bytes), (1, 8));
        assert_eq!(lt[2].class, LinkClass::Tcp);
        assert_eq!((lt[2].frames, lt[2].bytes), (1, 12));
        drop(t0);
        drop(t1);
        drop(t2);
    }

    #[test]
    fn multi_megabyte_frames_cross_a_batched_unix_link() {
        let (t0, t1) = pair(true);
        // larger than MAX_BATCH_WORDS: a single frame may exceed the
        // batch bound (the bound caps coalescing, not frame size)
        let big: Vec<u32> = (0..(MAX_BATCH_WORDS as u32 + 1234)).collect();
        let h = thread::spawn(move || {
            t0.send(1, (0..(MAX_BATCH_WORDS as u32 + 1234)).collect());
            t0.recv(1)
        });
        assert_eq!(t1.recv(0), big);
        t1.send(0, vec![42]);
        assert_eq!(h.join().unwrap(), vec![42]);
        drop(t1);
    }

    #[test]
    fn clean_fin_classified_over_unix_link() {
        let (t0, t1) = pair(true);
        drop(t1); // writers flush + FIN
        let err = t0.recv_checked(1).unwrap_err();
        assert_eq!(err.cause, PeerLostCause::CleanFin, "{err}");
        assert_eq!(t0.lost_peers(), vec![(1, PeerLostCause::CleanFin)]);
    }

    #[test]
    fn sever_works_on_a_unix_link() {
        let (t0, t1) = pair(true);
        t0.sever(1);
        let err = t0.recv_checked(1).unwrap_err();
        assert_eq!(err.cause, PeerLostCause::Timeout, "{err}");
        drop(t1);
    }

    #[test]
    fn stream_endpoint_is_sync() {
        fn assert_share<T: Send + Sync>() {}
        assert_share::<StreamTransport>();
    }
}
