//! Real network transport: sockets between worker *processes*.
//!
//! This is the third rung of the transport hierarchy (see DESIGN.md
//! §Transports and `collectives/mod.rs`):
//!
//! * `collectives::LocalFabric` — in-process channels between threads;
//!   real numerics, zero wire cost.  The default for tests and
//!   single-host runs.
//! * the socket fabrics (here) — real sockets between processes, one
//!   per rank, with length-prefixed framing ([`frame`]) and a rank-0
//!   rendezvous bootstrap.  This is where the paper's synchronization
//!   traffic actually crosses a kernel socket layer, so the Eq. 1/2
//!   bandwidth terms meet real wire behavior.
//! * `simnet` — no data at all; virtual-time replay of layer profiles for
//!   the 128-GPU scalability figures.
//!
//! The socket fabrics share one data plane ([`fabric::StreamTransport`]:
//! writer/reader threads, batched vectored frame writes, per-link-class
//! accounting) under three bootstraps:
//!
//! | fabric | link | reaches | picked by |
//! |---|---|---|---|
//! | [`TcpTransport`] ([`tcp`]) | TCP | any node | `--transport tcp` |
//! | [`UnixTransport`] ([`unix`]) | `AF_UNIX` | same host only | `--transport unix` |
//! | [`MixedFabric`] ([`mixed`]) | per-pair Unix/TCP from the `Topology` | any node | `--transport auto` |
//!
//! All of them implement `collectives::Transport` and frame messages
//! identically, so every collective (`allgather`, `allreduce_*`) and the
//! whole coordinator run unchanged over any; loopback integration tests
//! (`tests/tcp_loopback.rs`, `tests/fabric.rs`) hold them bit-identical
//! to each other and to `LocalFabric`.
//!
//! Entry points: `redsync launch --world N` forks one worker process per
//! rank and wires them up; `redsync train --set transport=tcp,rank=R`
//! runs a single rank by hand (see `main.rs`).

pub mod fabric;
pub mod frame;
pub mod mixed;
pub mod pool;
pub mod tcp;
pub mod unix;

pub use fabric::{LinkClassStats, LinkStream, StreamTransport};
pub use mixed::{MixedFabric, MixedOptions};
pub use pool::BytePool;
pub use tcp::{TcpOptions, TcpTransport};
pub use unix::{socket_base, UnixOptions, UnixTransport};

/// Pick a free loopback `ip:port` by binding port 0 and releasing it.
/// Small bind race window (the port could be reused before the caller
/// binds), acceptable for tests and single-host launches; pass an
/// explicit `--port` for anything else.
pub fn free_loopback_addr() -> String {
    let listener =
        std::net::TcpListener::bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = listener.local_addr().expect("local addr");
    format!("127.0.0.1:{}", addr.port())
}
