//! Real network transport: TCP sockets between worker *processes*.
//!
//! This is the third rung of the transport hierarchy (see DESIGN.md
//! §Transports and `collectives/mod.rs`):
//!
//! * `collectives::LocalFabric` — in-process channels between threads;
//!   real numerics, zero wire cost.  The default for tests and
//!   single-host runs.
//! * [`TcpTransport`] (here) — real sockets between processes, one per
//!   rank, with length-prefixed framing ([`frame`]) and a rank-0
//!   rendezvous bootstrap ([`tcp`]).  This is where the paper's
//!   synchronization traffic actually crosses a network stack, so the
//!   Eq. 1/2 bandwidth terms meet real wire behavior.
//! * `simnet` — no data at all; virtual-time replay of layer profiles for
//!   the 128-GPU scalability figures.
//!
//! Both real fabrics implement `collectives::Transport`, so every
//! collective (`allgather`, `allreduce_*`) and the whole coordinator run
//! unchanged over either; a loopback integration test
//! (`tests/tcp_loopback.rs`) holds them bit-identical.
//!
//! Entry points: `redsync launch --world N` forks one worker process per
//! rank and wires them up; `redsync train --set transport=tcp,rank=R`
//! runs a single rank by hand (see `main.rs`).

pub mod frame;
pub mod pool;
pub mod tcp;

pub use pool::BytePool;
pub use tcp::{TcpOptions, TcpTransport};

/// Pick a free loopback `ip:port` by binding port 0 and releasing it.
/// Small bind race window (the port could be reused before the caller
/// binds), acceptable for tests and single-host launches; pass an
/// explicit `--port` for anything else.
pub fn free_loopback_addr() -> String {
    let listener =
        std::net::TcpListener::bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = listener.local_addr().expect("local addr");
    format!("127.0.0.1:{}", addr.port())
}
