//! Unix-domain-socket fabric: intra-node transport for same-host ranks.
//!
//! Wire-identical to the TCP fabric — same length-prefixed frames
//! ([`super::frame`]), same bootstrap frame shapes, same data plane
//! ([`super::fabric::StreamTransport`]) — but over `AF_UNIX` stream
//! sockets, which skip loopback-TCP's per-segment protocol work
//! entirely.  This is the transport-level counterpart of the paper's
//! §5.3 observation that intra-node links are far cheaper than
//! inter-node links: the regime the hierarchical allgather optimizes is
//! now also the regime the fabric serves best.
//!
//! ## Socket-path namespacing
//!
//! All paths derive from one *base*: a rendezvous string containing `/`
//! is used as the base verbatim; anything else (e.g. the TCP rendezvous
//! `127.0.0.1:29500`) is sanitized into `/tmp/redsync-<seed>`, so
//! `--transport unix` works with an unchanged `--rendezvous` flag.
//! Rank 0 listens on `<base>.rdv`; every nonzero rank binds its data
//! listener on `<base>.r<rank>` *before* registering, so once rank 0's
//! directory go-signal arrives, every mesh listener provably exists —
//! peer addresses are derived from the shared base, not advertised.
//!
//! ## Failure classification + cleanup
//!
//! `sockaddr_un` paths are capped (~107 bytes), sockets outlive crashed
//! processes as stale filesystem entries, and `/tmp` permissions vary —
//! all three surface as actionable bootstrap errors here: paths are
//! length-checked up front, a stale socket file (bind says `AddrInUse`
//! but nothing accepts) is reclaimed and rebound automatically, and
//! `PermissionDenied` says which path to move where.  Every listener
//! path is unlinked when bootstrap finishes, successfully or not — only
//! the (invisible, unlinked) connected sockets outlive `connect`.

use super::fabric::{
    batching_enabled, delegate_transport, LinkClassStats, LinkStream, StreamTransport,
};
use super::frame::{read_frame, write_frame};
use super::tcp::{bad_data, timed_out, CONNECT_BACKOFF_CAP, CONNECT_BACKOFF_START, DIR, MESH, REG};
use crate::collectives::transport::{PeerLostCause, TrafficStats};
use std::io::{self, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// `sockaddr_un.sun_path` holds 108 bytes including the trailing NUL on
/// Linux — longer paths fail at bind/connect with an unhelpful error,
/// so they are rejected up front with an actionable one.
const MAX_SOCKET_PATH: usize = 107;

/// Derive the socket-path base from a rendezvous string: a string with
/// `/` is a filesystem prefix already; anything else is sanitized
/// (non-alphanumeric -> `-`) under `/tmp`.
pub fn socket_base(rendezvous: &str) -> String {
    if rendezvous.contains('/') {
        return rendezvous.to_string();
    }
    let san: String = rendezvous
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
        .collect();
    format!("/tmp/redsync-{san}")
}

/// Bootstrap parameters for one rank of a Unix-socket fabric.
#[derive(Clone, Debug)]
pub struct UnixOptions {
    pub world: usize,
    pub rank: usize,
    /// Socket-path namespace seed (see [`socket_base`]); every rank of
    /// the job must pass the same string.
    pub rendezvous: String,
    /// Bound on the whole bootstrap (connect retries, accepts, handshakes).
    pub timeout: Duration,
    /// Coalesce queued frames into vectored write batches (see
    /// `net::fabric`); `false` falls back to frame-per-write.
    pub batch: bool,
}

impl UnixOptions {
    pub fn new(world: usize, rank: usize, rendezvous: impl Into<String>) -> UnixOptions {
        UnixOptions {
            world,
            rank,
            rendezvous: rendezvous.into(),
            timeout: Duration::from_secs(30),
            batch: batching_enabled(),
        }
    }
}

/// Unlink a socket path when bootstrap leaves scope — success or error:
/// listener paths are rendezvous-only artifacts; the connected sockets
/// keep working after the filesystem name is gone.
pub(crate) struct PathGuard(PathBuf);

impl Drop for PathGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn path_too_long(path: &str) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidInput,
        format!(
            "socket path '{path}' is {} bytes; sockaddr_un caps paths at {MAX_SOCKET_PATH} — \
             pass a shorter base via --rendezvous (e.g. /tmp/rs)",
            path.len()
        ),
    )
}

pub(crate) fn check_paths(base: &str, world: usize) -> io::Result<()> {
    // the longest names this job will bind/dial
    for p in [format!("{base}.rdv"), format!("{base}.r{}", world.saturating_sub(1))] {
        if p.len() > MAX_SOCKET_PATH {
            return Err(path_too_long(&p));
        }
    }
    Ok(())
}

/// Bind a listener, reclaiming a stale socket file if the path is
/// occupied by a dead process: `AddrInUse` is probed with a connect —
/// refusal means no listener lives behind the file, so it is removed
/// and bound again; an accepted probe means a live collision.
pub(crate) fn bind_unix(path: &str) -> io::Result<(UnixListener, PathGuard)> {
    match UnixListener::bind(path) {
        Ok(l) => Ok((l, PathGuard(PathBuf::from(path)))),
        Err(e) if e.kind() == io::ErrorKind::AddrInUse => match UnixStream::connect(path) {
            Ok(_) => Err(io::Error::new(
                io::ErrorKind::AddrInUse,
                format!(
                    "socket path '{path}' is in use by a live process — \
                     is another fleet running? pick a different --rendezvous"
                ),
            )),
            Err(_) => {
                // stale file from a crashed run: reclaim and retry once
                std::fs::remove_file(path)?;
                let l = UnixListener::bind(path)?;
                Ok((l, PathGuard(PathBuf::from(path))))
            }
        },
        Err(e) if e.kind() == io::ErrorKind::PermissionDenied => Err(io::Error::new(
            io::ErrorKind::PermissionDenied,
            format!(
                "permission denied binding unix socket at '{path}' — \
                 point --rendezvous at a directory this user can write"
            ),
        )),
        Err(e) => Err(e),
    }
}

/// Dial with the same bounded backoff as the TCP fabric's
/// `connect_retry`: `NotFound` (listener not bound yet) and
/// `ConnectionRefused` (stale file about to be reclaimed by its owner)
/// are retried until the deadline; `PermissionDenied` fails fast with
/// directions.
pub(crate) fn connect_unix_retry(path: &str, deadline: Instant) -> io::Result<UnixStream> {
    let mut delay = CONNECT_BACKOFF_START;
    let mut attempts = 0u32;
    loop {
        attempts += 1;
        match UnixStream::connect(path) {
            Ok(s) => return Ok(s),
            Err(e) if e.kind() == io::ErrorKind::PermissionDenied => {
                return Err(io::Error::new(
                    io::ErrorKind::PermissionDenied,
                    format!(
                        "permission denied dialing unix socket '{path}' — \
                         every rank must run as a user that can reach the rendezvous directory"
                    ),
                ));
            }
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(io::Error::new(
                        e.kind(),
                        format!(
                            "giving up after {attempts} connect attempts on '{path}': {e} \
                             (peer not started, or its socket file was never created?)"
                        ),
                    ));
                }
                thread::sleep(delay.min(deadline.saturating_duration_since(now)));
                delay = (delay * 2).min(CONNECT_BACKOFF_CAP);
            }
        }
    }
}

/// Accept with a deadline (listener switched to non-blocking polling);
/// mirror of the TCP fabric's `accept_deadline`.
pub(crate) fn accept_deadline_unix(
    listener: &UnixListener,
    deadline: Instant,
) -> io::Result<UnixStream> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)?;
                return Ok(s);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(timed_out("timed out waiting for a peer connection"));
                }
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Read one bootstrap frame bounded by the remaining shared deadline;
/// mirror of the TCP fabric's `read_handshake`.
pub(crate) fn read_handshake_unix(
    s: &mut UnixStream,
    deadline: Instant,
    what: &str,
) -> io::Result<Vec<u32>> {
    let remaining = deadline.saturating_duration_since(Instant::now());
    if remaining.is_zero() {
        return Err(timed_out("bootstrap deadline expired"));
    }
    s.set_read_timeout(Some(remaining))?;
    let frame = read_frame(s)?
        .ok_or_else(|| bad_data(format!("peer closed during {what} handshake")))?;
    s.set_read_timeout(None)?;
    Ok(frame)
}

/// One rank's endpoint of a Unix-socket fabric.  Construct with
/// [`UnixTransport::connect`]; every rank of the same-host job calls it
/// with the same `world` and rendezvous seed and its own `rank`.  A
/// thin bootstrap wrapper over [`StreamTransport`].
pub struct UnixTransport {
    inner: StreamTransport,
    /// Per-process traffic counters — identical accounting to
    /// `TcpTransport` and `LocalFabric` (payload words at `send`).
    pub stats: Arc<TrafficStats>,
}

impl UnixTransport {
    /// Run the bootstrap protocol and return this rank's live endpoint.
    /// Blocks until the full mesh is up or `opts.timeout` expires.
    pub fn connect(opts: &UnixOptions) -> io::Result<UnixTransport> {
        if opts.world == 0 {
            return Err(bad_data("world must be >= 1".into()));
        }
        if opts.rank >= opts.world {
            return Err(bad_data(format!("rank {} out of world {}", opts.rank, opts.world)));
        }
        let base = socket_base(&opts.rendezvous);
        check_paths(&base, opts.world)?;
        let deadline = Instant::now() + opts.timeout;
        let streams = if opts.world == 1 {
            Vec::new()
        } else if opts.rank == 0 {
            bootstrap_rank0(opts, &base, deadline)?
        } else {
            bootstrap_peer(opts, &base, deadline)?
        };
        Ok(Self::from_streams_batched(opts.rank, opts.world, streams, opts.batch))
    }

    /// Wire up the data plane over an established socket per peer
    /// (`streams[rank]` is ignored; all others must be `Some`).  Public
    /// for fault-injection tests that hand-craft one side of a link
    /// (e.g. over `UnixStream::pair`).
    pub fn from_streams(
        rank: usize,
        world: usize,
        streams: Vec<Option<UnixStream>>,
    ) -> UnixTransport {
        Self::from_streams_batched(rank, world, streams, batching_enabled())
    }

    fn from_streams_batched(
        rank: usize,
        world: usize,
        streams: Vec<Option<UnixStream>>,
        batch: bool,
    ) -> UnixTransport {
        let links = streams.into_iter().map(|s| s.map(LinkStream::Unix)).collect();
        let inner = StreamTransport::from_streams(rank, world, links, batch);
        let stats = Arc::clone(&inner.stats);
        UnixTransport { inner, stats }
    }

    /// Per-link-class counters (frames / words / write syscalls).
    pub fn link_stats(&self) -> Arc<LinkClassStats> {
        Arc::clone(&self.inner.link_stats)
    }

    /// The recorded loss cause for `peer`'s link, if its reader has
    /// already classified a failure.
    pub fn peer_lost(&self, peer: usize) -> Option<(PeerLostCause, String)> {
        self.inner.peer_lost(peer)
    }

    /// Every peer whose link has died so far, with the classified cause.
    pub fn lost_peers(&self) -> Vec<(usize, PeerLostCause)> {
        self.inner.lost_peers()
    }
}

delegate_transport!(UnixTransport);

/// Rank 0: accept `world - 1` registrations on `<base>.rdv`, then send
/// every peer the `[DIR, world]` go-signal — peer addresses are derived
/// from the shared base, so unlike TCP the directory carries no
/// endpoints, but it still guarantees every data listener is bound
/// before anyone dials the mesh.  The registration connections become
/// the `0 <-> i` links.
fn bootstrap_rank0(
    opts: &UnixOptions,
    base: &str,
    deadline: Instant,
) -> io::Result<Vec<Option<UnixStream>>> {
    let world = opts.world;
    let (listener, _rdv_guard) = bind_unix(&format!("{base}.rdv"))?;
    let mut streams: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();

    for _ in 1..world {
        let mut s = accept_deadline_unix(&listener, deadline)?;
        let frame = read_handshake_unix(&mut s, deadline, "registration")?;
        if frame.len() != 4 || frame[0] != REG {
            return Err(bad_data(format!("bad registration frame {frame:?}")));
        }
        let (w, r) = (frame[1], frame[2]);
        if w as usize != world {
            return Err(bad_data(format!("peer expects world {w}, rank 0 has {world}")));
        }
        let r = r as usize;
        if r == 0 || r >= world {
            return Err(bad_data(format!("registration from invalid rank {r}")));
        }
        if streams[r].is_some() {
            return Err(bad_data(format!("duplicate registration for rank {r}")));
        }
        streams[r] = Some(s);
    }

    for s in streams.iter_mut().skip(1) {
        let s = s.as_mut().expect("all ranks registered");
        write_frame(s, &[DIR, world as u32])?;
        s.flush()?;
    }
    Ok(streams)
}

/// Nonzero rank: bind the data listener *first* (so the go-signal
/// implies it exists), register with rank 0, then dial every lower rank
/// at its derived path and accept every higher one.
fn bootstrap_peer(
    opts: &UnixOptions,
    base: &str,
    deadline: Instant,
) -> io::Result<Vec<Option<UnixStream>>> {
    let (world, rank) = (opts.world, opts.rank);
    // ranks above us dial our listener; the last rank needs none
    let listener = if rank + 1 < world {
        Some(bind_unix(&format!("{base}.r{rank}"))?)
    } else {
        None
    };

    let mut to_zero = connect_unix_retry(&format!("{base}.rdv"), deadline)?;
    write_frame(&mut to_zero, &[REG, world as u32, rank as u32, 0])?;
    to_zero.flush()?;
    let dir = read_handshake_unix(&mut to_zero, deadline, "directory")?;
    if dir.len() != 2 || dir[0] != DIR || dir[1] as usize != world {
        return Err(bad_data(format!("bad directory frame {dir:?}")));
    }

    let mut streams: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();
    streams[0] = Some(to_zero);

    for peer in 1..rank {
        let mut s = connect_unix_retry(&format!("{base}.r{peer}"), deadline)?;
        write_frame(&mut s, &[MESH, world as u32, rank as u32])?;
        s.flush()?;
        streams[peer] = Some(s);
    }
    if let Some((listener, _guard)) = &listener {
        for _ in rank + 1..world {
            let mut s = accept_deadline_unix(listener, deadline)?;
            let frame = read_handshake_unix(&mut s, deadline, "mesh")?;
            if frame.len() != 3 || frame[0] != MESH {
                return Err(bad_data(format!("bad mesh frame {frame:?}")));
            }
            let (w, peer) = (frame[1], frame[2]);
            let peer = peer as usize;
            if w as usize != world || peer <= rank || peer >= world {
                return Err(bad_data(format!("mesh handshake from invalid rank {peer}")));
            }
            if streams[peer].is_some() {
                return Err(bad_data(format!("duplicate mesh connection from rank {peer}")));
            }
            streams[peer] = Some(s);
        }
    }
    Ok(streams)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::{LinkClass, Transport};
    use std::sync::atomic::{AtomicU32, Ordering};

    /// A unique per-test socket base: unix tests in one binary run
    /// concurrently and must not share rendezvous paths.
    fn test_base() -> String {
        static NEXT: AtomicU32 = AtomicU32::new(0);
        format!("/tmp/rs-ut-{}-{}", std::process::id(), NEXT.fetch_add(1, Ordering::Relaxed))
    }

    fn fabric(world: usize, base: &str) -> Vec<UnixTransport> {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let opts = UnixOptions::new(world, rank, base);
                thread::spawn(move || UnixTransport::connect(&opts).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn send_recv_pair_over_unix() {
        let base = test_base();
        let mut ts = fabric(2, &base);
        let t1 = ts.pop().unwrap();
        let t0 = ts.pop().unwrap();
        let h = thread::spawn(move || {
            t1.send(0, vec![1, 2, 3]);
            t1.recv(0)
        });
        assert_eq!(t0.recv(1), vec![1, 2, 3]);
        t0.send(1, vec![9]);
        assert_eq!(h.join().unwrap(), vec![9]);
    }

    #[test]
    fn four_rank_mesh_over_unix_all_pairs() {
        let base = test_base();
        let ts = fabric(4, &base);
        let handles: Vec<_> = ts
            .into_iter()
            .enumerate()
            .map(|(rank, t)| {
                thread::spawn(move || {
                    for peer in 0..4 {
                        t.send(peer, vec![rank as u32 * 10 + peer as u32]);
                    }
                    for peer in 0..4 {
                        assert_eq!(t.recv(peer), vec![peer as u32 * 10 + rank as u32]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn socket_files_are_cleaned_up_after_bootstrap() {
        let base = test_base();
        let ts = fabric(3, &base);
        for suffix in [".rdv", ".r1", ".r2"] {
            assert!(
                !std::path::Path::new(&format!("{base}{suffix}")).exists(),
                "listener path {base}{suffix} must be unlinked once the mesh is up"
            );
        }
        drop(ts);
    }

    #[test]
    fn stale_socket_file_is_reclaimed() {
        let base = test_base();
        let path = format!("{base}.rdv");
        // a dead run's leftover: a bound-then-abandoned socket file
        let l = UnixListener::bind(&path).unwrap();
        drop(l); // closes the listener but leaves the file behind
        assert!(std::path::Path::new(&path).exists(), "stale file is the precondition");
        let (l2, _guard) = bind_unix(&path).expect("stale socket file must be reclaimed");
        drop(l2);
    }

    #[test]
    fn live_socket_collision_is_actionable() {
        let base = test_base();
        let path = format!("{base}.rdv");
        let (_live, _guard) = bind_unix(&path).unwrap();
        let err = bind_unix(&path).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::AddrInUse);
        assert!(err.to_string().contains("live process"), "{err}");
    }

    #[test]
    fn overlong_socket_path_is_rejected_up_front() {
        let base = format!("/tmp/{}", "x".repeat(120));
        let opts = UnixOptions::new(2, 0, base);
        let err = UnixTransport::connect(&opts).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("sockaddr_un"), "{err}");
    }

    #[test]
    fn non_path_rendezvous_is_namespaced_under_tmp() {
        assert_eq!(socket_base("127.0.0.1:29500"), "/tmp/redsync-127-0-0-1-29500");
        assert_eq!(socket_base("/run/rs/base"), "/run/rs/base");
    }

    #[test]
    fn self_channel_without_sockets() {
        let t = UnixTransport::connect(&UnixOptions::new(1, 0, test_base())).unwrap();
        t.send(0, vec![7]);
        assert_eq!(t.recv(0), vec![7]);
        assert_eq!(t.exchange(0, vec![8]), vec![8]);
    }

    #[test]
    fn link_traffic_reports_the_unix_class() {
        let base = test_base();
        let mut ts = fabric(2, &base);
        let t1 = ts.pop().unwrap();
        let t0 = ts.pop().unwrap();
        t0.send(1, vec![0; 25]);
        assert_eq!(t1.recv(0).len(), 25);
        let lt = t0.link_traffic();
        assert_eq!(lt.len(), 1);
        assert_eq!(lt[0].class, LinkClass::Unix);
        assert_eq!((lt[0].frames, lt[0].bytes), (1, 100));
        assert_eq!(t0.stats.bytes(), 100, "class-blind totals agree");
        drop(t1);
    }

    #[test]
    fn invalid_options_rejected() {
        assert!(UnixTransport::connect(&UnixOptions::new(0, 0, test_base())).is_err());
        assert!(UnixTransport::connect(&UnixOptions::new(2, 5, test_base())).is_err());
    }
}
