//! Named experiment presets: one per paper experiment family, tuned to
//! the artifact models this repo ships.
//!
//! The policy thresholds are scaled down from the paper's 128 KB / 4 MB:
//! our proxy models are orders of magnitude smaller than VGG16/LSTM-1500
//! (DESIGN.md §Substitutions), so the same *relative* layer mix — small
//! layers dense, medium trimmed, large binary-search — is reproduced by
//! scaling the cut points to the model sizes.

use super::{TrainConfig, WarmupKind};
use crate::compression::PolicyThresholds;
use crate::optim::{LrSchedule, Optimizer};
use crate::simnet::iteration::Strategy;

/// Thresholds that put our proxy models' layer mix in the same policy
/// regimes as the paper's DNNs: biases/LN dense, medium matrices trimmed,
/// the big embedding/head matrices binary-searched.
pub fn proxy_thresholds() -> PolicyThresholds {
    PolicyThresholds { thsd1: 4 * 1024, thsd2: 256 * 1024 }
}

/// Resolve a named preset.
pub fn preset(name: &str) -> Option<TrainConfig> {
    let base = TrainConfig { thresholds: proxy_thresholds(), ..TrainConfig::default() };
    Some(match name {
        // Fig. 6 / Table 1 proxy: convergence comparison SGD vs RGC vs
        // quant-RGC on the MLP classifier.
        "fig6-mlp" => TrainConfig {
            model: "mlp_small".into(),
            world: 4,
            steps: 600,
            strategy: Strategy::Rgc,
            density: 0.01,
            optimizer: Optimizer::Nesterov { momentum: 0.9 },
            lr: LrSchedule::Constant { lr: 0.05 },
            steps_per_epoch: 100,
            eval_every: 50,
            ..base.clone()
        },
        // Fig. 6 right / Table 1 LM rows: LSTM-proxy language model.
        // Warm-up epoch of dense SGD per §5.7 (the paper applies warm-up
        // to its large models), then 1% density.
        "fig6-lm" => TrainConfig {
            model: "lm_small".into(),
            world: 4,
            steps: 400,
            strategy: Strategy::Rgc,
            density: 0.01,
            optimizer: Optimizer::Sgd,
            lr: LrSchedule::Constant { lr: 0.5 },
            clip: Some(0.25),
            warmup: WarmupKind::DenseEpochs(1),
            steps_per_epoch: 100,
            eval_every: 50,
            ..base.clone()
        },
        // Table 2 proxy: big-batch behaviour.
        "table2" => TrainConfig {
            model: "mlp_small".into(),
            world: 8,
            steps: 400,
            strategy: Strategy::Rgc,
            density: 0.01,
            optimizer: Optimizer::Nesterov { momentum: 0.9 },
            lr: LrSchedule::Constant { lr: 0.05 },
            steps_per_epoch: 100,
            ..base.clone()
        },
        // End-to-end driver: decoder LM with warm-up, momentum correction.
        "e2e-lm" => TrainConfig {
            model: "lm_base".into(),
            world: 4,
            steps: 300,
            strategy: Strategy::Rgc,
            density: 1e-3,
            optimizer: Optimizer::Momentum { momentum: 0.9 },
            lr: LrSchedule::Constant { lr: 0.2 },
            clip: Some(1.0),
            warmup: WarmupKind::DenseEpochs(1),
            steps_per_epoch: 50,
            eval_every: 25,
            ..base.clone()
        },
        // Smoke preset used by quickstart/tests.
        "smoke" => TrainConfig {
            model: "lm_tiny".into(),
            world: 2,
            steps: 20,
            strategy: Strategy::Rgc,
            density: 0.01,
            thresholds: PolicyThresholds { thsd1: 512, thsd2: 8 * 1024 },
            log_every: 5,
            ..base
        },
        _ => return None,
    })
}

pub fn preset_names() -> &'static [&'static str] {
    &["fig6-mlp", "fig6-lm", "table2", "e2e-lm", "smoke"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for name in preset_names() {
            let cfg = preset(name).unwrap_or_else(|| panic!("{name} missing"));
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(preset("nope").is_none());
    }

    #[test]
    fn smoke_is_cheap() {
        let cfg = preset("smoke").unwrap();
        assert!(cfg.steps <= 50 && cfg.world <= 4);
        assert_eq!(cfg.model, "lm_tiny");
    }
}
