//! Configuration system: typed run configs, JSON config files, CLI
//! overrides and named experiment presets.
//!
//! Resolution order (later wins): preset defaults → `--config file.json`
//! → individual `--key value` CLI overrides.

pub mod presets;

pub use presets::preset;

use crate::collectives::group::Topology;
use crate::compression::PolicyThresholds;
use crate::elastic::{FaultSpec, StallSpec, MAX_ELASTIC_WORLD};
use crate::optim::{LrSchedule, Optimizer, WarmupSchedule};
use crate::simnet::iteration::Strategy;
use crate::util::json::{self, Value};

/// Elastic-membership knobs (DESIGN.md §Elastic-Membership): keep the
/// job alive through worker loss, with heartbeat failure detection,
/// deterministic world reshape and residual-preserving rejoin.
#[derive(Clone, Debug, PartialEq)]
pub struct ElasticConfig {
    /// Run the elastic driver instead of the fail-fast worker loop.
    pub enabled: bool,
    /// Heartbeat interval in milliseconds (lease = 4×).
    pub heartbeat_ms: u64,
    /// Abort (instead of reshaping) when the view would shrink below
    /// this many ranks.
    pub min_ranks: usize,
    /// Injected crashes `R@S` (`--kill-rank`).
    pub kill: Vec<FaultSpec>,
    /// Injected stalls `R@S:MS` (`--stall-rank`).
    pub stall: Vec<StallSpec>,
    /// Scheduled rejoin `R@S` of a previously killed rank
    /// (`--rejoin-rank`; local transport, needs checkpoints).
    pub rejoin: Vec<FaultSpec>,
    /// `RSCK` path prefix for periodic/reshape/join checkpoints.
    pub ckpt: Option<String>,
    /// Periodic checkpoint cadence in steps (0 = never).
    pub ckpt_every: usize,
    /// Resume every rank from `{resume}_rank{R}.rsck`.
    pub resume: Option<String>,
    /// Content-addressed chunk repository root (`--ckpt-repo`); each
    /// rank keeps `{root}/rank{R}/{chunks,manifests}` and rejoins by
    /// manifest delta instead of a full parameter image.
    pub ckpt_repo: Option<String>,
    /// How many surviving ranks serve a delta rejoin in parallel
    /// (`--rejoin-donors`).
    pub rejoin_donors: usize,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            enabled: false,
            heartbeat_ms: 25,
            min_ranks: 1,
            kill: Vec::new(),
            stall: Vec::new(),
            rejoin: Vec::new(),
            ckpt: None,
            ckpt_every: 0,
            resume: None,
            ckpt_repo: None,
            rejoin_donors: 2,
        }
    }
}

/// How each fusion bucket's collective algorithm is chosen (DESIGN.md
/// §Topology-Aware-Communication).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AlgoMode {
    /// Flat sparse allgather over the full world for every bucket (the
    /// historical schedule).
    #[default]
    Sparse,
    /// The hierarchical (intra-node / leader / broadcast) schedule for
    /// every bucket.
    Hierarchical,
    /// Cost-model argmin per bucket (`costmodel::pick_algo` against
    /// [`TrainConfig::machine`]): dense allreduce, flat sparse, or
    /// hierarchical.
    Auto,
}

impl AlgoMode {
    pub fn label(&self) -> &'static str {
        match self {
            AlgoMode::Sparse => "sparse",
            AlgoMode::Hierarchical => "hierarchical",
            AlgoMode::Auto => "auto",
        }
    }
}

/// Which fabric carries the synchronization traffic (see DESIGN.md
/// §Transports).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process channels between worker threads (`LocalFabric`); the
    /// trainer owns every rank.
    #[default]
    Local,
    /// TCP sockets between worker processes (`net::TcpTransport`); this
    /// process runs the single rank in [`TrainConfig::rank`] and meets
    /// the others at [`TrainConfig::rendezvous`].
    Tcp,
    /// Unix-domain sockets between same-host worker processes
    /// (`net::UnixTransport`); the rendezvous string seeds the socket
    /// path namespace (`net::socket_base`).
    Unix,
    /// Link-class-aware mix (`net::MixedFabric`): Unix sockets to
    /// same-node peers, TCP across nodes, chosen per pair from the
    /// topology (flat topology = all Unix).
    Auto,
}

impl TransportKind {
    pub fn label(&self) -> &'static str {
        match self {
            TransportKind::Local => "local",
            TransportKind::Tcp => "tcp",
            TransportKind::Unix => "unix",
            TransportKind::Auto => "auto",
        }
    }

    /// A socket fabric between processes (anything but the in-process
    /// `LocalFabric`) — these need a rank + rendezvous to bootstrap.
    pub fn is_socket(&self) -> bool {
        *self != TransportKind::Local
    }
}

/// Warm-up flavor; resolved against the run's target density by
/// [`TrainConfig::warmup_schedule`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WarmupKind {
    /// Target density from step one.
    None,
    /// RedSync §5.7: dense allreduce for the first N epochs.
    DenseEpochs(usize),
    /// DGC ablation: exponential density decay 25% → target.
    Dgc,
}

#[derive(Debug)]
pub enum ConfigError {
    Io(std::io::Error),
    Parse(crate::util::json::ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(e) => write!(f, "config io: {e}"),
            ConfigError::Parse(e) => write!(f, "config parse: {e}"),
            ConfigError::Invalid(msg) => write!(f, "config invalid: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl From<crate::util::json::ParseError> for ConfigError {
    fn from(e: crate::util::json::ParseError) -> Self {
        ConfigError::Parse(e)
    }
}

/// Full specification of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model name in the artifact manifest (`lm_tiny`, `mlp_small`, ...).
    pub model: String,
    /// Number of data-parallel workers (threads; one per simulated GPU).
    pub world: usize,
    /// Optimizer steps to run.
    pub steps: usize,
    /// Synchronization strategy.
    pub strategy: Strategy,
    /// Compression density D (fraction of elements transmitted).
    pub density: f64,
    /// §5.5 per-layer policy thresholds (bytes).
    pub thresholds: PolicyThresholds,
    /// Optimizer flavor.
    pub optimizer: Optimizer,
    /// Learning-rate schedule.
    pub lr: LrSchedule,
    /// DGC local gradient clipping max-norm (None = off; paper: on for
    /// RNN/LSTM, off for CNN §5.6).
    pub clip: Option<f32>,
    /// Warm-up schedule (paper §5.7).
    pub warmup: WarmupKind,
    /// Steps per "epoch" for the warm-up schedule.
    pub steps_per_epoch: usize,
    /// Route selection through the L1 device kernels instead of host
    /// selection (slower per call under CPU-PJRT; exercises the full
    /// three-layer path).
    pub device_select: bool,
    /// Record the (global mean) train loss every this many steps.
    pub log_every: usize,
    /// Run held-out eval every this many steps (0 = never).
    pub eval_every: usize,
    /// RNG seed (params, data).
    pub seed: u64,
    /// Fuse small compressed layers into shared allgather buckets (§5.3);
    /// 0 disables fusion.
    pub fusion_cap_elems: usize,
    /// Run the pipelined sync engine: bucket selection/encoding and the
    /// sparse collectives execute on a comm thread pool, overlapped
    /// across buckets, with a deterministic apply barrier (see
    /// `pipeline`).  Must be uniform across ranks (the wire format gains
    /// a per-message bucket tag).
    pub pipeline: bool,
    /// Pipelined engine: max buckets in flight at once (>= 1).
    pub inflight: usize,
    /// Record the per-layer mask/select/pack phase split inside
    /// `produce` (the Fig. 10 decomposition).  Off = zero clock reads on
    /// the produce hot path, for models whose micro-layers would
    /// otherwise be dominated by timer overhead.
    pub phase_timing: bool,
    /// Write a Chrome trace-event JSON of every rank's span timeline
    /// here after the run (`None` = tracing off; the disabled path is
    /// one relaxed atomic load per probe).
    pub trace_out: Option<String>,
    /// Serve a Prometheus-format metrics scrape endpoint on this
    /// address (rank 0 only; `None` = off).
    pub metrics_addr: Option<String>,
    /// Gather per-rank step-latency histograms to rank 0 every this
    /// many steps for cross-rank aggregation (p50/p99/skew); 0 = never.
    pub obs_every: usize,
    /// Re-run the `--algo auto` picker on the telemetry-calibrated cost
    /// model every this many steps, switching bucket algorithms live at
    /// the step barrier (0 = plan once at startup).  Requires
    /// `algo=auto`.
    pub recalib_every: usize,
    /// Fabric carrying the synchronization traffic.
    pub transport: TransportKind,
    /// This process's rank (TCP transport only; `launch` sets it per
    /// child).
    pub rank: usize,
    /// Rendezvous address rank 0 listens on (TCP transport only).
    pub rendezvous: String,
    /// Physical topology `nodes x ranks-per-node` the world maps onto
    /// (contiguous placement); `None` = flat (one node).  Shapes the
    /// hierarchical schedule — but which buckets actually use it is
    /// [`TrainConfig::algo`]'s call: under the default `sparse` mode a
    /// topology alone changes nothing.
    pub topology: Option<Topology>,
    /// Per-bucket collective algorithm choice.
    pub algo: AlgoMode,
    /// Machine preset the `auto` picker prices Eq. 1/2 and the
    /// hierarchical closed form against (`simnet::Machine::by_name`).
    pub machine: String,
    /// Elastic membership (survive worker loss; `--elastic` and
    /// friends).
    pub elastic: ElasticConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "lm_tiny".into(),
            world: 4,
            steps: 100,
            strategy: Strategy::Rgc,
            density: 1e-3,
            thresholds: PolicyThresholds::default(),
            optimizer: Optimizer::Momentum { momentum: 0.9 },
            lr: LrSchedule::Constant { lr: 0.1 },
            clip: None,
            warmup: WarmupKind::None,
            steps_per_epoch: 100,
            device_select: false,
            log_every: 10,
            eval_every: 0,
            seed: 42,
            fusion_cap_elems: 0,
            pipeline: false,
            inflight: 2,
            phase_timing: true,
            trace_out: None,
            metrics_addr: None,
            obs_every: 0,
            recalib_every: 0,
            transport: TransportKind::Local,
            rank: 0,
            rendezvous: "127.0.0.1:29500".into(),
            topology: None,
            algo: AlgoMode::Sparse,
            machine: "muradin".into(),
            elastic: ElasticConfig::default(),
        }
    }
}

fn parse_transport(s: &str) -> Result<TransportKind, ConfigError> {
    match s {
        "local" | "threads" => Ok(TransportKind::Local),
        "tcp" | "net" => Ok(TransportKind::Tcp),
        "unix" | "uds" => Ok(TransportKind::Unix),
        "auto" | "mixed" => Ok(TransportKind::Auto),
        other => Err(ConfigError::Invalid(format!("unknown transport '{other}'"))),
    }
}

fn parse_algo(s: &str) -> Result<AlgoMode, ConfigError> {
    match s {
        "sparse" | "flat" => Ok(AlgoMode::Sparse),
        "hierarchical" | "hier" => Ok(AlgoMode::Hierarchical),
        "auto" | "costmodel" => Ok(AlgoMode::Auto),
        other => Err(ConfigError::Invalid(format!("unknown algo '{other}'"))),
    }
}

fn parse_topology(s: &str) -> Result<Option<Topology>, ConfigError> {
    match s {
        "" | "flat" | "none" => Ok(None),
        spec => Topology::parse(spec).map(Some).map_err(ConfigError::Invalid),
    }
}

fn parse_strategy(s: &str) -> Result<Strategy, ConfigError> {
    match s {
        "dense" | "baseline" | "sgd" => Ok(Strategy::Dense),
        "rgc" => Ok(Strategy::Rgc),
        "quant" | "quant-rgc" | "quant_rgc" => Ok(Strategy::QuantRgc),
        other => Err(ConfigError::Invalid(format!("unknown strategy '{other}'"))),
    }
}

fn parse_optimizer(s: &str, momentum: f32) -> Result<Optimizer, ConfigError> {
    match s {
        "sgd" => Ok(Optimizer::Sgd),
        "momentum" => Ok(Optimizer::Momentum { momentum }),
        "nesterov" => Ok(Optimizer::Nesterov { momentum }),
        other => Err(ConfigError::Invalid(format!("unknown optimizer '{other}'"))),
    }
}

impl TrainConfig {
    pub fn strategy_label(&self) -> &'static str {
        self.strategy.label()
    }

    /// Resolve the warm-up kind against this run's target density.
    pub fn warmup_schedule(&self) -> WarmupSchedule {
        match self.warmup {
            WarmupKind::None => WarmupSchedule::None { density: self.density },
            WarmupKind::DenseEpochs(epochs) => {
                WarmupSchedule::DenseEpochs { epochs, density: self.density }
            }
            WarmupKind::Dgc => {
                WarmupSchedule::Exponential { start: 0.25, factor: 0.25, density: self.density }
            }
        }
    }

    /// Apply keys from a parsed JSON object onto `self`.
    pub fn apply_json(&mut self, v: &Value) -> Result<(), ConfigError> {
        let obj = v
            .as_obj()
            .ok_or_else(|| ConfigError::Invalid("config root must be an object".into()))?;
        for (key, val) in obj.iter() {
            self.apply_kv(key, val)?;
        }
        Ok(())
    }

    fn apply_kv(&mut self, key: &str, val: &Value) -> Result<(), ConfigError> {
        let as_usize = || {
            val.as_usize().ok_or_else(|| ConfigError::Invalid(format!("{key}: expected integer")))
        };
        let as_f64 = || {
            val.as_f64().ok_or_else(|| ConfigError::Invalid(format!("{key}: expected number")))
        };
        let as_str = || {
            val.as_str().ok_or_else(|| ConfigError::Invalid(format!("{key}: expected string")))
        };
        match key {
            "model" => self.model = as_str()?.to_string(),
            "world" => self.world = as_usize()?,
            "steps" => self.steps = as_usize()?,
            "strategy" => self.strategy = parse_strategy(as_str()?)?,
            "density" => self.density = as_f64()?,
            "thsd1" => self.thresholds.thsd1 = as_usize()?,
            "thsd2" => self.thresholds.thsd2 = as_usize()?,
            "optimizer" => {
                self.optimizer = parse_optimizer(as_str()?, self.optimizer.momentum())?
            }
            "momentum" => {
                let m = as_f64()? as f32;
                self.optimizer = match self.optimizer {
                    Optimizer::Sgd => Optimizer::Momentum { momentum: m },
                    Optimizer::Momentum { .. } => Optimizer::Momentum { momentum: m },
                    Optimizer::Nesterov { .. } => Optimizer::Nesterov { momentum: m },
                };
            }
            "lr" => self.lr = LrSchedule::Constant { lr: as_f64()? as f32 },
            "lr_decay_every" => {
                let lr = self.lr.lr_at(0);
                self.lr = LrSchedule::StepDecay { lr, factor: 0.5, every: as_usize()? };
            }
            "clip" => {
                let c = as_f64()? as f32;
                self.clip = if c > 0.0 { Some(c) } else { None };
            }
            "warmup_dense_epochs" => self.warmup = WarmupKind::DenseEpochs(as_usize()?),
            "warmup_dgc" => {
                if val.as_bool().unwrap_or(false) {
                    self.warmup = WarmupKind::Dgc;
                }
            }
            "steps_per_epoch" => self.steps_per_epoch = as_usize()?.max(1),
            "device_select" => {
                self.device_select = val
                    .as_bool()
                    .ok_or_else(|| ConfigError::Invalid("device_select: expected bool".into()))?
            }
            "log_every" => self.log_every = as_usize()?.max(1),
            "eval_every" => self.eval_every = as_usize()?,
            "seed" => self.seed = as_usize()? as u64,
            "fusion_cap_elems" => self.fusion_cap_elems = as_usize()?,
            "pipeline" => {
                self.pipeline = val
                    .as_bool()
                    .ok_or_else(|| ConfigError::Invalid("pipeline: expected bool".into()))?
            }
            "inflight" => self.inflight = as_usize()?,
            "phase_timing" => {
                self.phase_timing = val
                    .as_bool()
                    .ok_or_else(|| ConfigError::Invalid("phase_timing: expected bool".into()))?
            }
            "trace_out" => {
                let p = as_str()?.to_string();
                self.trace_out = if p.is_empty() { None } else { Some(p) };
            }
            "metrics_addr" => {
                let a = as_str()?.to_string();
                self.metrics_addr = if a.is_empty() { None } else { Some(a) };
            }
            "obs_every" => self.obs_every = as_usize()?,
            "recalib_every" => self.recalib_every = as_usize()?,
            "transport" => self.transport = parse_transport(as_str()?)?,
            "rank" => self.rank = as_usize()?,
            "rendezvous" => self.rendezvous = as_str()?.to_string(),
            "topology" => self.topology = parse_topology(as_str()?)?,
            "algo" => self.algo = parse_algo(as_str()?)?,
            "machine" => self.machine = as_str()?.to_string(),
            "elastic" => {
                self.elastic.enabled = val
                    .as_bool()
                    .ok_or_else(|| ConfigError::Invalid("elastic: expected bool".into()))?
            }
            "heartbeat_ms" => self.elastic.heartbeat_ms = as_usize()? as u64,
            "min_ranks" => self.elastic.min_ranks = as_usize()?,
            "kill_rank" => {
                self.elastic.kill =
                    FaultSpec::parse_list(as_str()?).map_err(ConfigError::Invalid)?
            }
            "stall_rank" => {
                self.elastic.stall =
                    StallSpec::parse_list(as_str()?).map_err(ConfigError::Invalid)?
            }
            "rejoin_rank" => {
                self.elastic.rejoin =
                    FaultSpec::parse_list(as_str()?).map_err(ConfigError::Invalid)?
            }
            "ckpt" => {
                let p = as_str()?.to_string();
                self.elastic.ckpt = if p.is_empty() { None } else { Some(p) };
            }
            "ckpt_every" => self.elastic.ckpt_every = as_usize()?,
            "resume" => {
                let p = as_str()?.to_string();
                self.elastic.resume = if p.is_empty() { None } else { Some(p) };
            }
            "ckpt_repo" => {
                let p = as_str()?.to_string();
                self.elastic.ckpt_repo = if p.is_empty() { None } else { Some(p) };
            }
            "rejoin_donors" => self.elastic.rejoin_donors = as_usize()?,
            other => return Err(ConfigError::Invalid(format!("unknown config key '{other}'"))),
        }
        Ok(())
    }

    /// Load and apply a JSON config file.
    pub fn apply_file(&mut self, path: &str) -> Result<(), ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let v = Value::parse(&text)?;
        self.apply_json(&v)
    }

    /// Apply `key=value` CLI override strings.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<(), ConfigError> {
        for ov in overrides {
            let (key, value) = ov
                .split_once('=')
                .ok_or_else(|| ConfigError::Invalid(format!("override '{ov}' is not key=value")))?;
            // parse the value as JSON (numbers/bools), fall back to string
            let v = Value::parse(value).unwrap_or_else(|_| json::s(value));
            self.apply_kv(key, &v)?;
        }
        Ok(())
    }

    /// Serialize the resolved config (for run logs / reproducibility).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("model", json::s(self.model.clone())),
            ("world", json::num(self.world as f64)),
            ("steps", json::num(self.steps as f64)),
            ("strategy", json::s(self.strategy.label())),
            ("density", json::num(self.density)),
            ("thsd1", json::num(self.thresholds.thsd1 as f64)),
            ("thsd2", json::num(self.thresholds.thsd2 as f64)),
            (
                "optimizer",
                json::s(match self.optimizer {
                    Optimizer::Sgd => "sgd",
                    Optimizer::Momentum { .. } => "momentum",
                    Optimizer::Nesterov { .. } => "nesterov",
                }),
            ),
            ("momentum", json::num(self.optimizer.momentum() as f64)),
            ("lr", json::num(self.lr.lr_at(0) as f64)),
            ("clip", json::num(self.clip.unwrap_or(0.0) as f64)),
            ("steps_per_epoch", json::num(self.steps_per_epoch as f64)),
            ("device_select", Value::Bool(self.device_select)),
            ("log_every", json::num(self.log_every as f64)),
            ("eval_every", json::num(self.eval_every as f64)),
            ("seed", json::num(self.seed as f64)),
            ("fusion_cap_elems", json::num(self.fusion_cap_elems as f64)),
            ("pipeline", Value::Bool(self.pipeline)),
            ("inflight", json::num(self.inflight as f64)),
            ("phase_timing", Value::Bool(self.phase_timing)),
            ("trace_out", json::s(self.trace_out.clone().unwrap_or_default())),
            ("metrics_addr", json::s(self.metrics_addr.clone().unwrap_or_default())),
            ("obs_every", json::num(self.obs_every as f64)),
            ("recalib_every", json::num(self.recalib_every as f64)),
            ("transport", json::s(self.transport.label())),
            ("rank", json::num(self.rank as f64)),
            ("rendezvous", json::s(self.rendezvous.clone())),
            (
                "topology",
                json::s(self.topology.map(|t| t.label()).unwrap_or_else(|| "flat".into())),
            ),
            ("algo", json::s(self.algo.label())),
            ("machine", json::s(self.machine.clone())),
            ("elastic", Value::Bool(self.elastic.enabled)),
            ("heartbeat_ms", json::num(self.elastic.heartbeat_ms as f64)),
            ("min_ranks", json::num(self.elastic.min_ranks as f64)),
            (
                "kill_rank",
                json::s(
                    self.elastic
                        .kill
                        .iter()
                        .map(|f| format!("{}@{}", f.rank, f.step))
                        .collect::<Vec<_>>()
                        .join(";"),
                ),
            ),
            (
                "stall_rank",
                json::s(
                    self.elastic
                        .stall
                        .iter()
                        .map(|f| format!("{}@{}:{}", f.rank, f.step, f.millis))
                        .collect::<Vec<_>>()
                        .join(";"),
                ),
            ),
            (
                "rejoin_rank",
                json::s(
                    self.elastic
                        .rejoin
                        .iter()
                        .map(|f| format!("{}@{}", f.rank, f.step))
                        .collect::<Vec<_>>()
                        .join(";"),
                ),
            ),
            ("ckpt", json::s(self.elastic.ckpt.clone().unwrap_or_default())),
            ("ckpt_every", json::num(self.elastic.ckpt_every as f64)),
            ("resume", json::s(self.elastic.resume.clone().unwrap_or_default())),
            ("ckpt_repo", json::s(self.elastic.ckpt_repo.clone().unwrap_or_default())),
            ("rejoin_donors", json::num(self.elastic.rejoin_donors as f64)),
        ])
    }

    /// Sanity checks before launching a run.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.world == 0 {
            return Err(ConfigError::Invalid("world must be >= 1".into()));
        }
        if !self.world.is_power_of_two() && !self.elastic.enabled {
            // elastic views shrink to arbitrary sizes, so the elastic
            // driver always runs over the ring fallbacks; everything
            // else keeps the historical recursive-doubling contract
            return Err(ConfigError::Invalid(format!(
                "world {} must be a power of two (recursive-doubling collectives); \
                 arbitrary sizes need --elastic",
                self.world
            )));
        }
        if !(self.density > 0.0 && self.density <= 1.0) {
            return Err(ConfigError::Invalid(format!("density {} out of (0,1]", self.density)));
        }
        if self.thresholds.thsd1 > self.thresholds.thsd2 {
            return Err(ConfigError::Invalid("thsd1 > thsd2".into()));
        }
        if self.pipeline {
            if self.inflight == 0 {
                return Err(ConfigError::Invalid(
                    "inflight must be >= 1 for the pipelined engine".into(),
                ));
            }
            if self.device_select {
                return Err(ConfigError::Invalid(
                    "pipeline is incompatible with device_select (PJRT clients are \
                     thread-bound; the comm pool cannot drive device selection)"
                        .into(),
                ));
            }
        }
        if self.transport.is_socket() {
            if self.rank >= self.world {
                return Err(ConfigError::Invalid(format!(
                    "rank {} out of world {}",
                    self.rank, self.world
                )));
            }
            if self.rendezvous.is_empty() {
                return Err(ConfigError::Invalid(format!(
                    "{} transport needs a rendezvous",
                    self.transport.label()
                )));
            }
        }
        if let Some(t) = self.topology {
            if t.world() != self.world {
                return Err(ConfigError::Invalid(format!(
                    "topology {} covers {} ranks but world is {}",
                    t.label(),
                    t.world(),
                    self.world
                )));
            }
        }
        if self.algo != AlgoMode::Sparse && self.topology.is_none() {
            return Err(ConfigError::Invalid(format!(
                "algo '{}' needs a --topology (hierarchical schedules are shaped by it)",
                self.algo.label()
            )));
        }
        if self.algo == AlgoMode::Auto
            && crate::simnet::Machine::by_name(&self.machine).is_none()
        {
            return Err(ConfigError::Invalid(format!(
                "unknown machine preset '{}' for the auto algorithm picker",
                self.machine
            )));
        }
        if self.recalib_every > 0 && self.algo != AlgoMode::Auto {
            return Err(ConfigError::Invalid(
                "recalib_every re-runs the cost-model picker and needs --algo auto".into(),
            ));
        }
        self.validate_elastic()
    }

    fn validate_elastic(&self) -> Result<(), ConfigError> {
        let e = &self.elastic;
        if !e.enabled {
            if !e.kill.is_empty() || !e.stall.is_empty() || !e.rejoin.is_empty() {
                return Err(ConfigError::Invalid(
                    "fault injection (kill/stall/rejoin) requires --elastic".into(),
                ));
            }
            if e.resume.is_some() || e.ckpt.is_some() || e.ckpt_every != 0 || e.ckpt_repo.is_some()
            {
                // the plain trainer never reads these — accepting them
                // would silently train from fresh state
                return Err(ConfigError::Invalid(
                    "resume/ckpt/ckpt_every/ckpt_repo are elastic-run knobs; add --elastic".into(),
                ));
            }
            return Ok(());
        }
        if e.ckpt_every > 0 && e.ckpt.is_none() {
            return Err(ConfigError::Invalid(
                "ckpt_every > 0 writes nothing without a --ckpt prefix".into(),
            ));
        }
        if e.rejoin_donors == 0 {
            return Err(ConfigError::Invalid(
                "rejoin_donors must be >= 1 (the delta rejoin needs a manifest source)".into(),
            ));
        }
        if self.world > MAX_ELASTIC_WORLD {
            return Err(ConfigError::Invalid(format!(
                "elastic views are capped at {MAX_ELASTIC_WORLD} ranks (world {})",
                self.world
            )));
        }
        if e.heartbeat_ms == 0 {
            return Err(ConfigError::Invalid("heartbeat_ms must be >= 1".into()));
        }
        if e.min_ranks == 0 || e.min_ranks > self.world {
            return Err(ConfigError::Invalid(format!(
                "min_ranks {} out of 1..={}",
                e.min_ranks, self.world
            )));
        }
        if self.device_select {
            return Err(ConfigError::Invalid(
                "elastic is incompatible with device_select (a reshaped epoch rebuilds \
                 the engine off-thread state)"
                    .into(),
            ));
        }
        if self.algo == AlgoMode::Auto {
            return Err(ConfigError::Invalid(
                "elastic needs a static --algo (sparse|hierarchical); auto demotion is \
                 planned per world size"
                    .into(),
            ));
        }
        if !matches!(self.warmup, WarmupKind::None) {
            return Err(ConfigError::Invalid(
                "elastic does not support warm-up schedules yet".into(),
            ));
        }
        if self.eval_every != 0 {
            return Err(ConfigError::Invalid(
                "elastic runs do not evaluate mid-run (set eval_every=0)".into(),
            ));
        }
        for f in e.kill.iter().chain(&e.rejoin) {
            if f.rank >= self.world {
                return Err(ConfigError::Invalid(format!(
                    "fault rank {} out of world {}",
                    f.rank, self.world
                )));
            }
        }
        for s in &e.stall {
            if s.rank >= self.world {
                return Err(ConfigError::Invalid(format!(
                    "stall rank {} out of world {}",
                    s.rank, self.world
                )));
            }
        }
        if !e.rejoin.is_empty() {
            if e.rejoin.len() > 1 {
                return Err(ConfigError::Invalid(
                    "one scheduled rejoin per run is supported".into(),
                ));
            }
            if self.transport != TransportKind::Local {
                return Err(ConfigError::Invalid(
                    "rejoin is orchestrated by the in-process trainer (transport=local); \
                     socket fleets support shrink only"
                        .into(),
                ));
            }
            if e.ckpt.is_none() || e.ckpt_every == 0 {
                return Err(ConfigError::Invalid(
                    "rejoin needs --ckpt PREFIX and ckpt_every > 0 (the returning rank \
                     restores from its RSCK checkpoint)"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_applies() {
        let mut cfg = TrainConfig::default();
        let v = Value::parse(
            r#"{"model":"mlp_small","world":8,"strategy":"quant-rgc","density":0.01,
                "optimizer":"nesterov","momentum":0.8,"lr":0.05,"clip":1.0,
                "warmup_dense_epochs":2,"steps_per_epoch":50,"seed":7}"#,
        )
        .unwrap();
        cfg.apply_json(&v).unwrap();
        assert_eq!(cfg.model, "mlp_small");
        assert_eq!(cfg.world, 8);
        assert_eq!(cfg.strategy, Strategy::QuantRgc);
        assert_eq!(cfg.density, 0.01);
        assert_eq!(cfg.optimizer, Optimizer::Nesterov { momentum: 0.8 });
        assert_eq!(cfg.clip, Some(1.0));
        assert_eq!(cfg.warmup, WarmupKind::DenseEpochs(2));
        assert!(matches!(
            cfg.warmup_schedule(),
            WarmupSchedule::DenseEpochs { epochs: 2, .. }
        ));
        cfg.validate().unwrap();
    }

    #[test]
    fn overrides_win() {
        let mut cfg = TrainConfig::default();
        cfg.apply_overrides(&[
            "world=2".into(),
            "strategy=dense".into(),
            "lr=0.3".into(),
            "model=lm_small".into(),
        ])
        .unwrap();
        assert_eq!(cfg.world, 2);
        assert_eq!(cfg.strategy, Strategy::Dense);
        assert!((cfg.lr.lr_at(0) - 0.3).abs() < 1e-6);
        assert_eq!(cfg.model, "lm_small");
    }

    #[test]
    fn bad_inputs_rejected() {
        let mut cfg = TrainConfig::default();
        assert!(cfg.apply_overrides(&["nope=1".into()]).is_err());
        assert!(cfg.apply_overrides(&["strategy=xyz".into()]).is_err());
        assert!(cfg.apply_overrides(&["broken".into()]).is_err());
        cfg.world = 3;
        assert!(cfg.validate().is_err());
        cfg.world = 4;
        cfg.density = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn transport_knobs_apply_and_validate() {
        let mut cfg = TrainConfig::default();
        cfg.apply_overrides(&[
            "transport=tcp".into(),
            "rank=3".into(),
            "rendezvous=127.0.0.1:4242".into(),
        ])
        .unwrap();
        assert_eq!(cfg.transport, TransportKind::Tcp);
        assert_eq!(cfg.rank, 3);
        assert_eq!(cfg.rendezvous, "127.0.0.1:4242");
        cfg.validate().unwrap();
        cfg.rank = cfg.world;
        assert!(cfg.validate().is_err(), "rank must stay below world");
        cfg.rank = 0;
        cfg.rendezvous.clear();
        assert!(cfg.validate().is_err(), "tcp needs a rendezvous");
        assert!(cfg.apply_overrides(&["transport=bogus".into()]).is_err());
        // the socket-fabric checks cover the unix and auto kinds too
        cfg.apply_overrides(&["transport=unix".into()]).unwrap();
        assert_eq!(cfg.transport, TransportKind::Unix);
        assert!(cfg.transport.is_socket());
        assert!(cfg.validate().is_err(), "unix needs a rendezvous");
        cfg.rendezvous = "127.0.0.1:4242".into();
        cfg.validate().unwrap();
        cfg.apply_overrides(&["transport=auto".into()]).unwrap();
        assert_eq!(cfg.transport, TransportKind::Auto);
        cfg.validate().unwrap();
        assert_eq!(TransportKind::Auto.label(), "auto");
        assert!(!TransportKind::Local.is_socket());
    }

    #[test]
    fn pipeline_knobs_apply_and_validate() {
        let mut cfg = TrainConfig::default();
        cfg.apply_overrides(&["pipeline=true".into(), "inflight=4".into()]).unwrap();
        assert!(cfg.pipeline);
        assert_eq!(cfg.inflight, 4);
        cfg.validate().unwrap();
        cfg.inflight = 0;
        assert!(cfg.validate().is_err(), "window must admit at least one bucket");
        cfg.inflight = 2;
        cfg.device_select = true;
        assert!(cfg.validate().is_err(), "comm pool cannot drive device selection");
        cfg.pipeline = false;
        cfg.validate().unwrap();
    }

    #[test]
    fn phase_timing_knob_applies() {
        let mut cfg = TrainConfig::default();
        assert!(cfg.phase_timing, "Fig. 10 phase split records by default");
        cfg.apply_overrides(&["phase_timing=false".into()]).unwrap();
        assert!(!cfg.phase_timing);
        assert!(cfg.apply_overrides(&["phase_timing=7".into()]).is_err());
    }

    #[test]
    fn observability_knobs_apply() {
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.trace_out, None, "tracing is off by default");
        assert_eq!(cfg.metrics_addr, None);
        assert_eq!(cfg.obs_every, 0);
        cfg.apply_overrides(&[
            "trace_out=out/trace.json".into(),
            "metrics_addr=127.0.0.1:9900".into(),
            "obs_every=25".into(),
        ])
        .unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("out/trace.json"));
        assert_eq!(cfg.metrics_addr.as_deref(), Some("127.0.0.1:9900"));
        assert_eq!(cfg.obs_every, 25);
        // empty strings clear the knobs again
        cfg.apply_overrides(&["trace_out=".into(), "metrics_addr=".into()]).unwrap();
        assert_eq!(cfg.trace_out, None);
        assert_eq!(cfg.metrics_addr, None);
        let s = cfg.to_json().to_json();
        assert!(s.contains("\"obs_every\""));
        assert!(s.contains("\"trace_out\""));
        assert!(s.contains("\"recalib_every\""));
        // recalibration re-runs the picker: it requires algo=auto
        cfg.apply_overrides(&["recalib_every=10".into()]).unwrap();
        assert_eq!(cfg.recalib_every, 10);
        assert!(cfg.validate().is_err(), "recalib without algo=auto");
        cfg.apply_overrides(&[
            "world=8".into(),
            "topology=2x4".into(),
            "algo=auto".into(),
            "machine=fatnode".into(),
        ])
        .unwrap();
        cfg.validate().unwrap();
    }

    #[test]
    fn topology_knobs_apply_and_validate() {
        let mut cfg = TrainConfig::default();
        cfg.apply_overrides(&["world=8".into(), "topology=2x4".into(), "algo=hierarchical".into()])
            .unwrap();
        assert_eq!(cfg.topology, Some(Topology::new(2, 4)));
        assert_eq!(cfg.algo, AlgoMode::Hierarchical);
        cfg.validate().unwrap();
        // topology must cover the world
        cfg.world = 4;
        assert!(cfg.validate().is_err(), "2x4 over world 4");
        cfg.world = 8;
        // auto needs a known machine preset
        cfg.apply_overrides(&["algo=auto".into(), "machine=fatnode".into()]).unwrap();
        cfg.validate().unwrap();
        cfg.machine = "warp-drive".into();
        assert!(cfg.validate().is_err(), "unknown machine");
        // hierarchical/auto without a topology is rejected
        let mut flat = TrainConfig::default();
        flat.apply_overrides(&["algo=hierarchical".into()]).unwrap();
        assert!(flat.validate().is_err());
        // 'flat' clears the topology again
        cfg.apply_overrides(&["topology=flat".into(), "algo=sparse".into(), "machine=muradin".into()])
            .unwrap();
        assert_eq!(cfg.topology, None);
        cfg.validate().unwrap();
        assert!(cfg.apply_overrides(&["topology=2by4".into()]).is_err());
        assert!(cfg.apply_overrides(&["algo=psychic".into()]).is_err());
    }

    #[test]
    fn to_json_contains_strategy() {
        let cfg = TrainConfig::default();
        let s = cfg.to_json().to_json();
        assert!(s.contains("\"strategy\""));
        assert!(s.contains("RGC"));
        assert!(s.contains("\"elastic\""));
    }

    #[test]
    fn elastic_knobs_apply_and_validate() {
        use crate::elastic::{FaultSpec, StallSpec};
        let mut cfg = TrainConfig::default();
        cfg.apply_overrides(&[
            "elastic=true".into(),
            "heartbeat_ms=50".into(),
            "min_ranks=2".into(),
            "kill_rank=2@6".into(),
            "stall_rank=1@4:500".into(),
        ])
        .unwrap();
        assert!(cfg.elastic.enabled);
        assert_eq!(cfg.elastic.heartbeat_ms, 50);
        assert_eq!(cfg.elastic.min_ranks, 2);
        assert_eq!(cfg.elastic.kill, vec![FaultSpec { rank: 2, step: 6 }]);
        assert_eq!(cfg.elastic.stall, vec![StallSpec { rank: 1, step: 4, millis: 500 }]);
        cfg.validate().unwrap();
        // elastic admits non-power-of-two worlds (ring collectives)
        cfg.world = 3;
        cfg.elastic.kill.clear();
        cfg.elastic.stall.clear();
        cfg.validate().unwrap();
        cfg.world = 4;
        // fault rank must fit the world
        cfg.apply_overrides(&["kill_rank=7@1".into()]).unwrap();
        assert!(cfg.validate().is_err(), "kill rank outside world");
        cfg.elastic.kill.clear();
        // injection without elastic is rejected
        let mut plain = TrainConfig::default();
        plain.apply_overrides(&["kill_rank=1@2".into()]).unwrap();
        assert!(plain.validate().is_err());
        // so are the checkpoint/resume knobs (the plain trainer never
        // reads them)
        let mut plain = TrainConfig::default();
        plain.apply_overrides(&["resume=/tmp/ck".into()]).unwrap();
        assert!(plain.validate().is_err(), "resume without --elastic is a silent no-op");
        // ckpt_every without a prefix writes nothing
        let mut cadence = TrainConfig::default();
        cadence.apply_overrides(&["elastic=true".into(), "ckpt_every=5".into()]).unwrap();
        assert!(cadence.validate().is_err(), "ckpt_every needs --ckpt");
        // rejoin needs checkpoints and the local transport
        cfg.apply_overrides(&["rejoin_rank=2@12".into()]).unwrap();
        assert!(cfg.validate().is_err(), "rejoin without ckpt");
        cfg.apply_overrides(&["ckpt=/tmp/ck".into(), "ckpt_every=6".into()]).unwrap();
        cfg.validate().unwrap();
        cfg.transport = TransportKind::Tcp;
        assert!(cfg.validate().is_err(), "rejoin over tcp");
        cfg.transport = TransportKind::Local;
        // incompatible modes
        cfg.eval_every = 4;
        assert!(cfg.validate().is_err(), "elastic forbids mid-run eval");
        cfg.eval_every = 0;
        cfg.algo = AlgoMode::Auto;
        cfg.topology = Some(Topology::new(1, 4));
        assert!(cfg.validate().is_err(), "elastic forbids algo=auto");
    }

    #[test]
    fn ckpt_repo_and_donor_knobs() {
        // the chunk repo rides the elastic flag like the other
        // checkpoint knobs; a plain run must not silently ignore it
        let mut plain = TrainConfig::default();
        plain.apply_overrides(&["ckpt_repo=/tmp/repo".into()]).unwrap();
        assert!(plain.validate().is_err(), "ckpt_repo without --elastic is a silent no-op");
        // ...but the rejoin_donors *default* (2) must not trip that
        // guard on a plain run
        TrainConfig::default().validate().unwrap();

        let mut cfg = TrainConfig::default();
        cfg.apply_overrides(&[
            "elastic=true".into(),
            "ckpt_repo=/tmp/repo".into(),
            "rejoin_donors=3".into(),
        ])
        .unwrap();
        assert_eq!(cfg.elastic.ckpt_repo.as_deref(), Some("/tmp/repo"));
        assert_eq!(cfg.elastic.rejoin_donors, 3);
        cfg.validate().unwrap();
        let s = cfg.to_json().to_json();
        assert!(s.contains("ckpt_repo"), "round-trips through the config dump: {s}");

        cfg.apply_overrides(&["rejoin_donors=0".into()]).unwrap();
        assert!(cfg.validate().is_err(), "a delta rejoin needs at least one donor");
    }
}
