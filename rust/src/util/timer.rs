//! Timing utilities: wall-clock scoped timers, accumulating phase timers
//! (the mask/pack/comm/unpack decomposition of Fig. 10), and a tiny
//! statistics helper for the bench harness.

use std::collections::BTreeMap;
use std::time::Instant;

/// Accumulates wall time per named phase.  Used by the coordinator to
/// produce the paper's Fig-10 time decomposition.
///
/// This is the *aggregation* view of the run: each phase total is the
/// sum of the same intervals `obs::span` records as individual timeline
/// entries (`obs::time_phase` measures once and feeds both).  Use the
/// timer for end-of-run breakdowns; use the span rings when you need
/// the per-step, per-lane timeline (`--trace-out`).
#[derive(Default, Debug, Clone)]
pub struct PhaseTimer {
    totals: BTreeMap<String, f64>,
    counts: BTreeMap<String, u64>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase name.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed().as_secs_f64());
        out
    }

    /// Add externally-measured seconds to a phase.
    pub fn add(&mut self, phase: &str, secs: f64) {
        *self.totals.entry(phase.to_string()).or_default() += secs;
        *self.counts.entry(phase.to_string()).or_default() += 1;
    }

    pub fn total(&self, phase: &str) -> f64 {
        self.totals.get(phase).copied().unwrap_or(0.0)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or(0)
    }

    pub fn phases(&self) -> impl Iterator<Item = (&String, &f64)> {
        self.totals.iter()
    }

    pub fn grand_total(&self) -> f64 {
        self.totals.values().sum()
    }

    /// Merge another timer into this one (summing phases).
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += v;
        }
    }

    /// Render a percentage breakdown table.
    pub fn breakdown(&self) -> String {
        let total = self.grand_total().max(1e-12);
        let mut rows: Vec<_> = self.totals.iter().collect();
        rows.sort_by(|a, b| b.1.partial_cmp(a.1).unwrap());
        let mut s = String::new();
        for (k, v) in rows {
            s.push_str(&format!("  {k:<12} {:>10.4}s  {:>5.1}%\n", v, 100.0 * v / total));
        }
        s
    }

    pub fn clear(&mut self) {
        self.totals.clear();
        self.counts.clear();
    }
}

/// Measure a closure `reps` times and return per-rep seconds (min, median,
/// mean).  The bench harness's core primitive (criterion is not in the
/// vendor set).
pub fn bench<T>(reps: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(reps > 0);
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = f();
        samples.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(out);
    }
    BenchStats::from_samples(samples)
}

/// Summary statistics over bench samples.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub samples: Vec<f64>,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        BenchStats {
            min: samples[0],
            median: samples[n / 2],
            mean,
            max: samples[n - 1],
            samples,
        }
    }

    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.add("comm", 0.5);
        t.add("comm", 0.25);
        t.add("pack", 0.25);
        assert!((t.total("comm") - 0.75).abs() < 1e-12);
        assert_eq!(t.count("comm"), 2);
        assert!((t.grand_total() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn phase_timer_merge() {
        let mut a = PhaseTimer::new();
        a.add("x", 1.0);
        let mut b = PhaseTimer::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert!((a.total("x") - 3.0).abs() < 1e-12);
        assert!((a.total("y") - 3.0).abs() < 1e-12);
    }

    #[test]
    fn phase_timer_times_closures() {
        let mut t = PhaseTimer::new();
        let v = t.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(t.total("work") >= 0.004);
    }

    #[test]
    fn bench_stats_ordering() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }

    #[test]
    fn breakdown_sums_to_100() {
        let mut t = PhaseTimer::new();
        t.add("a", 1.0);
        t.add("b", 3.0);
        let b = t.breakdown();
        assert!(b.contains("75.0%"), "{b}");
        assert!(b.contains("25.0%"), "{b}");
    }
}
