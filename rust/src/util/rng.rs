//! Deterministic PRNG (PCG-XSH-RR 64/32) — the vendored crate set has no
//! `rand`, so RedSync carries its own generator.  Everything that needs
//! randomness (data synthesis, parameter init, property tests) threads a
//! seeded [`Pcg32`] for reproducible experiments.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (bound as u64);
            let l = m as u32;
            if l >= bound || l >= (bound.wrapping_neg() % bound) {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box-Muller (uses two uniforms per pair).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Sample from a categorical distribution given cumulative weights.
    pub fn categorical(&mut self, cdf: &[f32]) -> usize {
        let total = *cdf.last().expect("empty cdf");
        let u = self.next_f32() * total;
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(cdf.len() - 1),
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg32::seeded(13);
        let cdf = [0.1f32, 0.3, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..50_000 {
            counts[r.categorical(&cdf)] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
