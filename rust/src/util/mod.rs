//! Utility substrates built in-repo (the crate is zero-dependency; even
//! the `xla` bindings are feature-gated behind a stub): PRNG, JSON, CLI
//! parsing, logging, timing and a mini property-test harness.

pub mod argparse;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod timer;

/// Format a byte count human-readably.
pub fn fmt_bytes(b: usize) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b < K {
        format!("{b:.0}B")
    } else if b < K * K {
        format!("{:.1}KB", b / K)
    } else if b < K * K * K {
        format!("{:.1}MB", b / K / K)
    } else {
        format!("{:.2}GB", b / K / K / K)
    }
}

/// Next power of two >= n (n must be > 0).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0MB");
    }

    #[test]
    fn pow2() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
    }
}
