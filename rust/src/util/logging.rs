//! Leveled stderr logger with a global verbosity switch.
//!
//! Not `log`/`env_logger` (not vendored); a minimal equivalent whose level
//! is set once by the CLI (`--log-level`) or the `REDSYNC_LOG` env var.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Rank attributed to this process's log lines (multi-process fleets
/// interleave on stderr); `usize::MAX` = unset, legacy prefix-free format.
static RANK: AtomicUsize = AtomicUsize::new(usize::MAX);
static START: OnceLock<Instant> = OnceLock::new();

/// Tag this process's log lines with `rank` and start the wall-clock
/// offset (seconds since this call) shown in each prefix — call once
/// per rank before training so interleaved fleet stderr is attributable.
pub fn set_rank(rank: usize) {
    RANK.store(rank, Ordering::Relaxed);
    let _ = START.get_or_init(Instant::now);
}

impl Level {
    pub fn from_str_loose(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Set the global level (also reads `REDSYNC_LOG` if `level` is None).
pub fn init(level: Option<Level>) {
    let l = level
        .or_else(|| std::env::var("REDSYNC_LOG").ok().and_then(|s| Level::from_str_loose(&s)))
        .unwrap_or(Level::Info);
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments) {
    if enabled(level) {
        let rank = RANK.load(Ordering::Relaxed);
        if rank == usize::MAX {
            eprintln!("[{}] {}", level.tag(), args);
        } else {
            let secs = START.get().map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
            eprintln!("[r{rank} +{secs:.3}s] [{}] {}", level.tag(), args);
        }
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str_loose("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str_loose("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str_loose("nope"), None);
    }

    #[test]
    fn enabled_respects_threshold() {
        init(Some(Level::Warn));
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        init(Some(Level::Info));
    }
}
