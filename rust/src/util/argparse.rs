//! Tiny declarative CLI argument parser (no `clap` in the vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands, with auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub enum ArgError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String, String),
    MissingRequired(String),
}

impl fmt::Display for ArgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArgError::Unknown(o) => write!(f, "unknown option '{o}' (try --help)"),
            ArgError::MissingValue(o) => write!(f, "option '--{o}' expects a value"),
            ArgError::Invalid(o, v, why) => write!(f, "invalid value '{v}' for --{o}: {why}"),
            ArgError::MissingRequired(o) => write!(f, "missing required option --{o}"),
        }
    }
}

impl std::error::Error for ArgError {}

struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
    required: bool,
}

/// Declarative parser: declare options, call [`Args::parse`], then read
/// typed values.
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Option taking a value, with default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
        });
        self
    }

    /// Required option taking a value.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
            required: true,
        });
        self
    }

    /// Boolean flag (no value).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
            required: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else if let Some(d) = &spec.default {
                format!("  --{} <val> (default: {})", spec.name, d)
            } else {
                format!("  --{} <val> (required)", spec.name)
            };
            s.push_str(&format!("{head:<44} {}\n", spec.help));
        }
        s.push_str("  --help                                       print this message\n");
        s
    }

    /// Parse a raw arg list (without argv[0]).  On `--help`, prints usage
    /// and exits.
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed, ArgError> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                print!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| ArgError::Unknown(a.clone()))?;
                if spec.is_flag {
                    self.values.insert(name, "true".to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| ArgError::MissingValue(name.clone()))?
                        }
                    };
                    self.values.insert(name, v);
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        for spec in &self.specs {
            if !self.values.contains_key(&spec.name) {
                if spec.required {
                    return Err(ArgError::MissingRequired(spec.name.clone()));
                }
                if let Some(d) = &spec.default {
                    self.values.insert(spec.name.clone(), d.clone());
                }
            }
        }
        Ok(Parsed { values: self.values, positional: self.positional })
    }
}

/// Parsed argument values with typed getters.
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("undeclared option '{name}'"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<T, ArgError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name);
        raw.parse().map_err(|e: T::Err| {
            ArgError::Invalid(name.to_string(), raw.to_string(), e.to_string())
        })
    }

    pub fn usize(&self, name: &str) -> usize {
        self.get_parse(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.get_parse(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn f32(&self, name: &str) -> f32 {
        self.get_parse(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.get_parse(name).unwrap_or_else(|e| panic!("{e}"))
    }
}

fn _sv(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        Args::new("t", "test")
            .opt("steps", "100", "step count")
            .opt("density", "0.001", "compression density")
            .flag("quantize", "enable quantization")
            .req("model", "model name")
    }

    #[test]
    fn defaults_and_required() {
        let p = args().parse(&_sv(&["--model", "lm_tiny"])).unwrap();
        assert_eq!(p.usize("steps"), 100);
        assert_eq!(p.f64("density"), 0.001);
        assert!(!p.get_flag("quantize"));
        assert_eq!(p.get("model"), "lm_tiny");
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = args()
            .parse(&_sv(&["--model=x", "--steps=5", "--quantize"]))
            .unwrap();
        assert_eq!(p.usize("steps"), 5);
        assert!(p.get_flag("quantize"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(matches!(
            args().parse(&_sv(&["--steps", "5"])),
            Err(ArgError::MissingRequired(_))
        ));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(matches!(
            args().parse(&_sv(&["--model", "x", "--nope"])),
            Err(ArgError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_errors() {
        assert!(matches!(
            args().parse(&_sv(&["--model"])),
            Err(ArgError::MissingValue(_))
        ));
    }

    #[test]
    fn positionals_collected() {
        let p = args().parse(&_sv(&["pos1", "--model", "x", "pos2"])).unwrap();
        assert_eq!(p.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn bad_parse_reports_option() {
        let p = args().parse(&_sv(&["--model", "x", "--steps", "abc"])).unwrap();
        let e = p.get_parse::<usize>("steps").unwrap_err();
        assert!(e.to_string().contains("steps"));
    }
}
