//! Mini property-testing harness (the vendor set has no `proptest`).
//!
//! Provides seeded random case generation with failure-seed reporting and
//! a bounded shrink pass for integer/size parameters.  Coordinator and
//! compression invariants (routing, batching, residual conservation,
//! collective correctness) are exercised through this.
//!
//! ```ignore
//! check(100, |g| {
//!     let n = g.size(1..4096);
//!     let xs = g.vec_f32(n, -10.0..10.0);
//!     // ... assert invariant, return Result<(), String>
//! });
//! ```

use super::rng::Pcg32;
use std::ops::Range;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Pcg32,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg32::new(seed, 0xda7a), case_seed: seed }
    }

    pub fn size(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.below((r.end - r.start) as u32) as usize
    }

    pub fn usize_pow2(&mut self, lo_log2: u32, hi_log2: u32) -> usize {
        1usize << (lo_log2 + self.rng.below(hi_log2 - lo_log2 + 1))
    }

    pub fn f32(&mut self, r: Range<f32>) -> f32 {
        self.rng.range_f32(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_f32(&mut self, n: usize, r: Range<f32>) -> Vec<f32> {
        (0..n).map(|_| self.rng.range_f32(r.start, r.end)).collect()
    }

    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0f32; n];
        self.rng.fill_normal(&mut v, std);
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `cases` random cases of a property.  Panics with the failing seed on
/// first failure so the case can be replayed with [`check_one`].
///
/// Respects `REDSYNC_PROPTEST_CASES` to scale case counts globally.
pub fn check(cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let cases = std::env::var("REDSYNC_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    let base = std::env::var("REDSYNC_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed (case {i}/{cases}, seed {seed:#x}):\n  {msg}\n\
                 replay: REDSYNC_PROPTEST_SEED={base} with case index {i}"
            );
        }
    }
}

/// Replay a single seed (used when debugging a reported failure).
pub fn check_one(seed: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Assertion helpers usable inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    let denom = a.abs().max(b.abs()).max(1e-12);
    if (a - b).abs() / denom <= tol || (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (rel {:.3e})", (a - b).abs() / denom))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        // interior mutability via Cell to count invocations
        let c = std::cell::Cell::new(0);
        check(25, |g| {
            c.set(c.get() + 1);
            let n = g.size(1..100);
            ensure(n < 100, "bounded")
        });
        count += c.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, |g| {
            let n = g.size(1..1000);
            ensure(n < 1, format!("n={n} too big"))
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        assert_eq!(a.size(0..1000), b.size(0..1000));
        assert_eq!(a.vec_f32(8, 0.0..1.0), b.vec_f32(8, 0.0..1.0));
    }

    #[test]
    fn pow2_sizes_in_range() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let n = g.usize_pow2(4, 10);
            assert!(n.is_power_of_two() && (16..=1024).contains(&n));
        }
    }

    #[test]
    fn ensure_close_tolerates() {
        assert!(ensure_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-6, "x").is_err());
    }
}
