//! Minimal JSON parser/writer — the vendored crate set has no `serde`, and
//! RedSync needs JSON in two places: the artifact `manifest.json` emitted by
//! `python/compile/aot.py`, and machine-readable experiment/metric dumps.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null).  Object key order is preserved (insertion order)
//! so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Keys kept in a sorted map plus an insertion-order index.
    Obj(Obj),
}

/// JSON object preserving insertion order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Obj {
    map: BTreeMap<String, Value>,
    order: Vec<String>,
}

impl Obj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, val: Value) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, val);
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.order.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ------------------------------------------------------------ accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&Obj> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `v.at(&["models", "lm_tiny", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for p in path {
            cur = cur.as_obj()?.get(p)?;
        }
        Some(cur)
    }

    // ------------------------------------------------------------ parsing

    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ writing

    /// Compact serialization.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with `indent` spaces.
    pub fn to_json_pretty(&self, indent: usize) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(indent), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut obj = Obj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(obj)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Convenience builders for emitting metrics.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    let mut o = Obj::new();
    for (k, v) in pairs {
        o.insert(k, v);
    }
    Value::Obj(o)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

pub fn arr(vs: Vec<Value>) -> Value {
    Value::Arr(vs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.at(&["c"]).unwrap().as_str(), Some("x"));
        let a = v.at(&["a"]).unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64(), Some(2.0));
        assert_eq!(a[2].at(&["b"]), Some(&Value::Null));
    }

    #[test]
    fn parse_string_escapes() {
        let v = Value::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Value::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Value::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"b":[1,2.5,true,null],"a":{"x":"y"},"n":-7}"#;
        let v = Value::parse(src).unwrap();
        let c = v.to_json();
        assert_eq!(Value::parse(&c).unwrap(), v);
        let p = v.to_json_pretty(2);
        assert_eq!(Value::parse(&p).unwrap(), v);
    }

    #[test]
    fn object_preserves_insertion_order() {
        let v = Value::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(num(3.0).to_json(), "3");
        assert_eq!(num(3.25).to_json(), "3.25");
    }

    #[test]
    fn builders() {
        let v = obj(vec![("k", arr(vec![num(1.0), s("two")]))]);
        assert_eq!(v.to_json(), r#"{"k":[1,"two"]}"#);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "source_hash": "abc", "buckets": [1024, 16384],
          "models": {"lm_tiny": {"file": "lm_tiny.hlo.txt",
            "params": [{"name": "embed", "shape": [64, 32],
                        "init": {"kind": "normal", "std": 0.02}}]}},
          "compress_ops": {"abs_stats": {"buckets": {"1024": "abs_stats_1024.hlo.txt"}}}
        }"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(
            v.at(&["models", "lm_tiny", "file"]).unwrap().as_str(),
            Some("lm_tiny.hlo.txt")
        );
        let p = &v.at(&["models", "lm_tiny", "params"]).unwrap().as_arr().unwrap()[0];
        assert_eq!(p.at(&["shape"]).unwrap().as_arr().unwrap()[0].as_usize(), Some(64));
    }
}
