//! Dense tensor substrate: a flat `f32` buffer plus shape, with the
//! vectorizable kernels the coordinator hot path needs (axpy, scale,
//! norms, abs-stats).  No BLAS dependency — heavy compute runs in the
//! AOT-compiled XLA artifacts; these ops cover optimizer/residual
//! bookkeeping on the host.

pub mod sparse;

pub use sparse::{SparseTensor, SparseView};

use crate::compression::simd;

/// Dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { data: vec![0.0; n], shape: shape.to_vec() }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn from_flat(data: Vec<f32>) -> Self {
        let n = data.len();
        Tensor { data, shape: vec![n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        axpy(&mut self.data, alpha, &other.data);
    }

    pub fn scale(&mut self, alpha: f32) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    pub fn l2_norm(&self) -> f32 {
        l2_norm(&self.data)
    }

    pub fn abs_mean_max(&self) -> (f32, f32) {
        abs_mean_max(&self.data)
    }
}

/// y += alpha * x (slice form, the host-side hot kernel).
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = alpha*x + beta*y
pub fn axpby(y: &mut [f32], alpha: f32, x: &[f32], beta: f32) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = alpha * xi + beta * *yi;
    }
}

pub fn l2_norm(x: &[f32]) -> f32 {
    // f64 accumulator: gradient-clipping norms over multi-million-element
    // buffers lose precision in f32.
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
}

/// Single pass (mean |x|, max |x|) — host mirror of the `abs_stats`
/// kernel.  8-lane accumulators let LLVM vectorize the reduction; the
/// per-chunk f32 partial sums feed an f64 total so multi-million-element
/// means stay accurate (§Perf).
pub fn abs_mean_max(x: &[f32]) -> (f32, f32) {
    if x.is_empty() {
        return (0.0, 0.0);
    }
    let mut sum = 0f64;
    let mut max = 0f32;
    for chunk in x.chunks(4096) {
        let mut acc = [0f32; 8];
        let mut mx = [0f32; 8];
        let mut it = chunk.chunks_exact(8);
        for grp in &mut it {
            for l in 0..8 {
                let a = grp[l].abs();
                acc[l] += a;
                if a > mx[l] {
                    mx[l] = a;
                }
            }
        }
        let mut csum = 0f32;
        let mut cmax = 0f32;
        for l in 0..8 {
            csum += acc[l];
            if mx[l] > cmax {
                cmax = mx[l];
            }
        }
        for &v in it.remainder() {
            let a = v.abs();
            csum += a;
            if a > cmax {
                cmax = a;
            }
        }
        sum += csum as f64;
        if cmax > max {
            max = cmax;
        }
    }
    ((sum / x.len() as f64) as f32, max)
}

/// Count of |x| strictly above `thr` — host mirror of `threshold_count`,
/// dispatched through the active SIMD backend (mask popcount; exact).
pub fn count_above(x: &[f32], thr: f32) -> usize {
    simd::count_gt_abs(simd::active(), x, thr)
}

/// Signed variant for quantized selection: counts x*sign > thr.
pub fn count_above_signed(x: &[f32], thr: f32, sign: f32) -> usize {
    simd::count_gt_signed(simd::active(), x, thr, sign)
}

/// L1-cache chunk size (elements) for the blocked streaming kernels —
/// 16 KiB of f32, the host analogue of a VMEM tile.
const CHUNK: usize = 4096;

/// Counts above each of J thresholds in ONE memory pass — the host mirror
/// of the L1 `threshold_count` Pallas kernel and the workhorse of the
/// fast selectors (§Perf).
///
/// Returns `counts[j] = #{ i : key(x[i]) > thrs[j] }` where the key is
/// `|x|` (`sign = None`) or `sign·x` (`sign = Some(±1)`).
///
/// Blocked evaluation: each 16 KiB chunk's keys are materialized once,
/// then all J thresholds scan the chunk from L1 with a branch-free
/// (vectorizable) predicate-count — J compares per element of compute,
/// but only one pass of memory traffic.
pub fn count_above_multi(x: &[f32], thrs: &[f32], sign: Option<f32>) -> Vec<usize> {
    let mut counts = Vec::new();
    count_above_multi_into(x, thrs, sign, &mut counts);
    counts
}

/// [`count_above_multi`] into a reused output buffer (cleared first) —
/// the allocation-free form the selection scratch drives.
pub fn count_above_multi_into(x: &[f32], thrs: &[f32], sign: Option<f32>, counts: &mut Vec<usize>) {
    counts.clear();
    let j = thrs.len();
    if j == 0 {
        return;
    }
    counts.resize(j, 0);
    let b = simd::active();
    match sign {
        None => {
            for chunk in x.chunks(CHUNK) {
                for (c, &t) in counts.iter_mut().zip(thrs) {
                    *c += simd::count_gt_abs(b, chunk, t);
                }
            }
        }
        Some(s) => {
            let mut keys = [0f32; CHUNK];
            for chunk in x.chunks(CHUNK) {
                let m = chunk.len();
                simd::scaled_keys(b, chunk, s, &mut keys[..m]);
                for (c, &t) in counts.iter_mut().zip(thrs) {
                    *c += simd::count_gt(b, &keys[..m], t);
                }
            }
        }
    }
}

/// Sparse-regime variant of [`count_above_multi`]: `thrs` must be sorted
/// **descending**; cost is one compare per element plus a short ladder
/// walk for the (assumed few) elements above `thrs.last()`.  The right
/// tool when every threshold sits in the top-percent tail — e.g. the
/// verification pass of the sample-guided selectors (§Perf); degrades
/// badly when a large fraction qualifies (use the dense variant there).
pub fn count_above_multi_sparse(x: &[f32], thrs: &[f32], sign: Option<f32>) -> Vec<usize> {
    let mut hist = Vec::new();
    count_above_multi_sparse_into(x, thrs, sign, &mut hist);
    hist
}

/// [`count_above_multi_sparse`] into a reused output buffer (cleared
/// first).
pub fn count_above_multi_sparse_into(
    x: &[f32],
    thrs: &[f32],
    sign: Option<f32>,
    hist: &mut Vec<usize>,
) {
    hist.clear();
    let j = thrs.len();
    if j == 0 {
        return;
    }
    debug_assert!(thrs.windows(2).all(|w| w[0] >= w[1]), "thresholds must descend");
    let tmin = thrs[j - 1];
    // hist[b]: elements with key in (thrs[b], thrs[b-1]] (b = 0: > thrs[0])
    hist.resize(j, 0);
    let mut scan = |a: f32| {
        if a > tmin {
            let mut b = j - 1;
            while b > 0 && a > thrs[b - 1] {
                b -= 1;
            }
            hist[b] += 1;
        }
    };
    match sign {
        None => x.iter().for_each(|&v| scan(v.abs())),
        Some(s) => x.iter().for_each(|&v| scan(v * s)),
    }
    for b in 1..j {
        hist[b] += hist[b - 1];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_validates() {
        Tensor::from_vec(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn axpby_works() {
        let mut y = vec![1.0, 2.0];
        axpby(&mut y, 2.0, &[3.0, 4.0], 0.5);
        assert_eq!(y, vec![6.5, 9.0]);
    }

    #[test]
    fn l2_norm_f64_accumulation() {
        let x = vec![1e-4f32; 1_000_000];
        let n = l2_norm(&x);
        assert!((n - 0.1).abs() < 1e-4, "{n}");
    }

    #[test]
    fn abs_stats_simple() {
        let (mean, max) = abs_mean_max(&[-2.0, 1.0, -4.0, 1.0]);
        assert_eq!(max, 4.0);
        assert!((mean - 2.0).abs() < 1e-6);
    }

    #[test]
    fn abs_stats_empty() {
        assert_eq!(abs_mean_max(&[]), (0.0, 0.0));
    }

    #[test]
    fn count_above_strict() {
        assert_eq!(count_above(&[1.0, -1.0, 0.5], 1.0), 0);
        assert_eq!(count_above(&[1.1, -1.2, 0.5], 1.0), 2);
    }

    #[test]
    fn count_above_signed_partitions() {
        let x = [2.0, -2.0, 0.5, -0.5];
        assert_eq!(count_above_signed(&x, 1.0, 1.0), 1);
        assert_eq!(count_above_signed(&x, 1.0, -1.0), 1);
    }

    #[test]
    fn tensor_ops_chain() {
        let mut a = Tensor::from_flat(vec![1.0, 2.0]);
        let b = Tensor::from_flat(vec![3.0, 4.0]);
        a.axpy(0.5, &b);
        a.scale(2.0);
        assert_eq!(a.data(), &[5.0, 8.0]);
        assert!((a.l2_norm() - (89f32).sqrt()).abs() < 1e-6);
    }
}
