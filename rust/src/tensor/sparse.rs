//! Sparse tensor: the compressed communication-set representation.
//!
//! `(indices, values)` pairs extracted from a dense residual; the
//! `scatter_add` decompression is the paper's cuSparse `axpyi()` analogue
//! (§5.4), and the dominant cost at large p (Fig. 10 "unpack").
//!
//! Two shapes share that math: the owned [`SparseTensor`] (selection
//! output, residual masking) and the borrowed [`SparseView`], which
//! parses `[idx…][bits…]` regions of a gathered wire blob *in place* —
//! indices as a slice of the blob, values decoded via `f32::from_bits`
//! on the fly — so the decompression walk never copies p·k words per
//! bucket onto the heap (DESIGN.md §Zero-Copy-Hot-Path).
//!
//! The compaction and scatter walks are dispatched through the
//! [`crate::compression::simd`] kernels (DESIGN.md §SIMD-Kernels): the
//! active backend's output is pinned bit-identical to the scalar loops
//! these methods used to be.

use crate::compression::simd;

/// Compressed communication-set: sorted-by-extraction indices + values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseTensor {
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

/// Borrowed sparse message parsed in place from a wire blob.  Same
/// scatter math as [`SparseTensor`], zero heap traffic: the view cannot
/// outlive the gather buffer it points into.
#[derive(Clone, Copy, Debug)]
pub struct SparseView<'a> {
    pub indices: &'a [u32],
    /// Bit-cast f32 values, decoded lazily.
    value_bits: &'a [u32],
}

impl<'a> SparseView<'a> {
    pub fn new(indices: &'a [u32], value_bits: &'a [u32]) -> SparseView<'a> {
        assert_eq!(indices.len(), value_bits.len());
        SparseView { indices, value_bits }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// The i-th value, decoded from its wire bits.
    pub fn value(&self, i: usize) -> f32 {
        f32::from_bits(self.value_bits[i])
    }

    pub fn values(&self) -> impl Iterator<Item = f32> + 'a {
        self.value_bits.iter().map(|&b| f32::from_bits(b))
    }

    /// dense[idx] += scale * val straight off the wire words — float-op
    /// for float-op identical to `SparseTensor::scatter_add` on the
    /// decoded copy (the bit-identity pins rest on this).  The §5.4
    /// apply walk behind `BucketDone::apply_to`, vectorized.
    pub fn scatter_add(&self, dense: &mut [f32], scale: f32) {
        simd::scatter_add_bits(simd::active(), self.indices, self.value_bits, dense, scale);
    }

    /// Materialize an owned copy (compat / diagnostics — not the hot path).
    pub fn to_tensor(&self) -> SparseTensor {
        SparseTensor::new(self.indices.to_vec(), self.values().collect())
    }
}

impl SparseTensor {
    pub fn new(indices: Vec<u32>, values: Vec<f32>) -> Self {
        assert_eq!(indices.len(), values.len());
        SparseTensor { indices, values }
    }

    pub fn with_capacity(n: usize) -> Self {
        SparseTensor { indices: Vec::with_capacity(n), values: Vec::with_capacity(n) }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn push(&mut self, idx: u32, val: f32) {
        self.indices.push(idx);
        self.values.push(val);
    }

    /// Drop all elements, keeping both buffers' capacity (scratch reuse).
    pub fn clear(&mut self) {
        self.indices.clear();
        self.values.clear();
    }

    /// Extract elements of `dense` whose |value| > thr (stream compaction).
    pub fn compact_above(dense: &[f32], thr: f32) -> Self {
        let mut out = SparseTensor::default();
        SparseTensor::compact_above_into(dense, thr, &mut out);
        out
    }

    /// [`compact_above`](Self::compact_above) into a reused buffer
    /// (cleared first) — the allocation-free steady-state form.
    /// Vectorized via the active [`crate::compression::simd`] backend
    /// (bit-identical to the scalar walk; NaN never passes the ordered
    /// compare on either path).
    pub fn compact_above_into(dense: &[f32], thr: f32, out: &mut SparseTensor) {
        out.clear();
        simd::compact_gt_abs(simd::active(), dense, thr, out);
    }

    /// Signed compaction for quantized selection: keeps v*sign > thr.
    pub fn compact_above_signed(dense: &[f32], thr: f32, sign: f32) -> Self {
        let mut out = SparseTensor::default();
        SparseTensor::compact_above_signed_into(dense, thr, sign, &mut out);
        out
    }

    /// Signed compaction into a reused buffer (cleared first).
    pub fn compact_above_signed_into(dense: &[f32], thr: f32, sign: f32, out: &mut SparseTensor) {
        out.clear();
        simd::compact_gt_signed(simd::active(), dense, thr, sign, out);
    }

    /// Extract elements where mask > 0.5 (device-produced masks).
    pub fn compact_masked(dense: &[f32], mask: &[f32]) -> Self {
        assert_eq!(dense.len(), mask.len());
        let mut out = SparseTensor::default();
        for i in 0..dense.len() {
            if mask[i] > 0.5 {
                out.push(i as u32, dense[i]);
            }
        }
        out
    }

    /// dense[idx] += scale * val for every element (the `axpyi` of §5.4).
    /// Vectorized products, scalar-ordered adds — bit-identical to the
    /// plain loop; out-of-range indices still panic.
    pub fn scatter_add(&self, dense: &mut [f32], scale: f32) {
        simd::scatter_add_values(simd::active(), &self.indices, &self.values, dense, scale);
    }

    /// Zero out `dense` at this tensor's indices (momentum factor masking).
    pub fn zero_at(&self, dense: &mut [f32]) {
        for &i in &self.indices {
            dense[i as usize] = 0.0;
        }
    }

    pub fn value_sum(&self) -> f32 {
        self.values.iter().sum()
    }

    /// Replace all values by a single constant (quantized decompression).
    pub fn with_constant_values(indices: Vec<u32>, value: f32) -> Self {
        let values = vec![value; indices.len()];
        SparseTensor { indices, values }
    }

    /// Densify into a fresh buffer of length n.
    pub fn to_dense(&self, n: usize) -> Vec<f32> {
        let mut out = vec![0f32; n];
        self.scatter_add(&mut out, 1.0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_above_picks_strictly_greater() {
        let d = [0.5, -2.0, 1.0, 3.0, -0.1];
        let s = SparseTensor::compact_above(&d, 1.0);
        assert_eq!(s.indices, vec![1, 3]);
        assert_eq!(s.values, vec![-2.0, 3.0]);
    }

    #[test]
    fn compact_signed_positive_and_negative() {
        let d = [0.5, -2.0, 1.5, 3.0];
        let pos = SparseTensor::compact_above_signed(&d, 1.0, 1.0);
        assert_eq!(pos.indices, vec![2, 3]);
        let neg = SparseTensor::compact_above_signed(&d, 1.0, -1.0);
        assert_eq!(neg.indices, vec![1]);
        assert_eq!(neg.values, vec![-2.0]);
    }

    #[test]
    fn compact_masked_matches_mask() {
        let d = [1.0, 2.0, 3.0];
        let m = [0.0, 1.0, 1.0];
        let s = SparseTensor::compact_masked(&d, &m);
        assert_eq!(s.indices, vec![1, 2]);
    }

    #[test]
    fn scatter_add_accumulates() {
        let s = SparseTensor::new(vec![0, 2, 2], vec![1.0, 2.0, 3.0]);
        let mut d = vec![10.0, 10.0, 10.0];
        s.scatter_add(&mut d, 0.5);
        assert_eq!(d, vec![10.5, 10.0, 12.5]);
    }

    #[test]
    fn compact_then_scatter_roundtrip() {
        let d = [0.0, 5.0, 0.0, -7.0];
        let s = SparseTensor::compact_above(&d, 0.1);
        assert_eq!(s.to_dense(4), d.to_vec());
    }

    #[test]
    fn zero_at_masks_residual() {
        let s = SparseTensor::new(vec![1, 3], vec![9.0, 9.0]);
        let mut d = vec![1.0, 2.0, 3.0, 4.0];
        s.zero_at(&mut d);
        assert_eq!(d, vec![1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn constant_values() {
        let s = SparseTensor::with_constant_values(vec![0, 2], 0.25);
        assert_eq!(s.values, vec![0.25, 0.25]);
    }

    #[test]
    fn view_scatter_matches_owned_bitwise() {
        let s = SparseTensor::new(vec![1, 3, 7], vec![-1.5, f32::MIN_POSITIVE, 1e20]);
        let bits: Vec<u32> = s.values.iter().map(|v| v.to_bits()).collect();
        let v = SparseView::new(&s.indices, &bits);
        assert_eq!(v.len(), 3);
        assert_eq!(v.value(0).to_bits(), (-1.5f32).to_bits());
        let mut a = vec![0.5f32; 8];
        let mut b = a.clone();
        s.scatter_add(&mut a, 0.25);
        v.scatter_add(&mut b, 0.25);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(v.to_tensor(), s);
    }

    #[test]
    fn compact_into_reuses_buffers() {
        let d = [0.5, -2.0, 1.0, 3.0];
        let mut out = SparseTensor::with_capacity(4);
        SparseTensor::compact_above_into(&d, 1.0, &mut out);
        assert_eq!(out, SparseTensor::compact_above(&d, 1.0));
        // a second compaction fully replaces the contents
        SparseTensor::compact_above_signed_into(&d, 0.0, -1.0, &mut out);
        assert_eq!(out, SparseTensor::compact_above_signed(&d, 0.0, -1.0));
        out.clear();
        assert!(out.is_empty());
    }
}
