//! Closed-form communication cost model (§5.5, Appendix B).
//!
//! ```text
//! T_sparse = T_select + lg(p)·α + (p-1)·(M·D·w)·β + p·(M·D)·γ₁      (Eq. 1)
//! T_dense  = 2·lg(p)·α + 2·((p-1)/p)·(4M)·β + ((p-1)/p)·M·γ₂       (Eq. 2)
//! ```
//!
//! with `M` in elements, `w` the wire bytes per selected element (8 for
//! plain `(idx, val)` pairs, 4 for the quantized index-only format), and
//! γ in seconds/element.  The property tests in this module cross-check
//! the closed forms against the step-walked [`crate::simnet`] schedules —
//! the "cost-model validity" row of the experiment index.
//!
//! The paper's §5.5 observations fall out of these functions:
//! * bandwidth compression ≠ model compression: the sparse/dense byte
//!   ratio is `p·D·w/8` — at p = 128, D = 0.1%, plain RGC needs 12.8% of
//!   dense bandwidth, not 0.1% ([`bandwidth_ratio`]).
//! * decompression (`p·γ₁·M·D`) grows linearly with p and becomes the
//!   bottleneck at scale ([`decompress_fraction`]).

use crate::collectives::group::Algo;
use crate::compression::Method;
use crate::simnet::Machine;
pub use crate::simnet::IntraLink;

/// Wire bytes per selected element.
pub const PLAIN_WIRE_BYTES: f64 = 8.0;
pub const QUANT_WIRE_BYTES: f64 = 4.0;

/// Eq. 1 — sparse synchronization cost (seconds).
///
/// * `t_select`: communication-set identification time for this layer
/// * `m_elems`: layer size M in elements
/// * `density`: D
/// * `wire_bytes`: 8.0 plain / 4.0 quantized
pub fn t_sparse(
    machine: &Machine,
    p: usize,
    m_elems: f64,
    density: f64,
    t_select: f64,
    wire_bytes: f64,
) -> f64 {
    t_sparse_ab(machine, machine.alpha, machine.beta, p, m_elems, density, t_select, wire_bytes)
}

/// Eq. 1 with the transfer terms priced on an explicit intra-host link
/// class — what a flat sparse allgather costs when the whole world sits
/// on one host over `net::UnixTransport` or loopback TCP.
pub fn t_sparse_on(
    machine: &Machine,
    link: IntraLink,
    p: usize,
    m_elems: f64,
    density: f64,
    t_select: f64,
    wire_bytes: f64,
) -> f64 {
    let (alpha, beta) = machine.link_params(link);
    t_sparse_ab(machine, alpha, beta, p, m_elems, density, t_select, wire_bytes)
}

/// Eq. 1 over an explicit α-β link (γ₁ stays a device property).
#[allow(clippy::too_many_arguments)]
fn t_sparse_ab(
    machine: &Machine,
    alpha: f64,
    beta: f64,
    p: usize,
    m_elems: f64,
    density: f64,
    t_select: f64,
    wire_bytes: f64,
) -> f64 {
    if p <= 1 {
        return t_select;
    }
    let pf = p as f64;
    let md = m_elems * density;
    t_select
        + pf.log2() * alpha
        + (pf - 1.0) * md * wire_bytes * beta
        + pf * md * machine.gamma_decompress
}

/// Eq. 2 — dense allreduce cost (seconds); 4 bytes per element.
pub fn t_dense(machine: &Machine, p: usize, m_elems: f64) -> f64 {
    t_dense_ab(machine, machine.alpha, machine.beta, p, m_elems)
}

/// Eq. 2 on an explicit intra-host link class (single-host dense
/// baseline over Unix sockets / loopback TCP).
pub fn t_dense_on(machine: &Machine, link: IntraLink, p: usize, m_elems: f64) -> f64 {
    let (alpha, beta) = machine.link_params(link);
    t_dense_ab(machine, alpha, beta, p, m_elems)
}

/// Eq. 2 over an explicit α-β link (γ₂ stays a device property).
fn t_dense_ab(machine: &Machine, alpha: f64, beta: f64, p: usize, m_elems: f64) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let pf = p as f64;
    2.0 * pf.log2() * alpha
        + 2.0 * (pf - 1.0) / pf * (4.0 * m_elems) * beta
        + (pf - 1.0) / pf * m_elems * machine.gamma_reduce
}

/// Exposed wall time of one pipelined window: GPU-side work (selection,
/// encoding) hides behind the in-flight collective, so the window costs
/// the max of the two, not their sum — the §5.3 overlap scheme the
/// `Pipelined` sync engine implements and `simnet` walks per layer.
pub fn t_overlap(compute: f64, comm: f64) -> f64 {
    compute.max(comm)
}

/// Eq. 1 under the pipelined schedule: selection overlaps the transfer
/// (`max` instead of `+`); decompression still serializes after the
/// barrier (it needs the gathered result).
pub fn t_sparse_pipelined(
    machine: &Machine,
    p: usize,
    m_elems: f64,
    density: f64,
    t_select: f64,
    wire_bytes: f64,
) -> f64 {
    if p <= 1 {
        return t_select;
    }
    let pf = p as f64;
    let md = m_elems * density;
    let transfer = pf.log2() * machine.alpha + (pf - 1.0) * md * wire_bytes * machine.beta;
    t_overlap(t_select, transfer) + pf * md * machine.gamma_decompress
}

/// Hierarchical sparse synchronization cost (seconds) on `nodes ×
/// ranks_per_node`: the closed form of the three-phase schedule
/// `collectives::hierarchical` runs (critical path = the node leader).
///
/// ```text
/// T_hier = T_select
///        + (s-1)·(α_i + M·D·w·β_i)            intra gather at the leader
///        + L(n)·α + (n-1)·s·(M·D·w)·β         leader allgather of node blobs
///        + (s-1)·(α_i + p·(M·D·w)·β_i)        intra broadcast of the world blob
///        + p·(M·D)·γ₁                         decompression (same as Eq. 1)
/// ```
///
/// where `L(n)` is the leader-allgather latency term of the schedule
/// actually dispatched: `lg(n)` rounds under recursive doubling
/// (power-of-two node counts), `n-1` under the ring fallback.
///
/// `simnet::hierarchical_allgather_time` walks the same schedule; the
/// proptests pin the two equal.  Versus Eq. 1, the slow-link bandwidth
/// term shrinks from `(p-1)` to `(n-1)·s` message units while the
/// gather/broadcast phases pay the intra link — the schedule wins iff
/// `β/β_i` exceeds roughly `p` (see `Machine::fatnode`).
pub fn t_hierarchical(
    machine: &Machine,
    nodes: usize,
    ranks_per_node: usize,
    m_elems: f64,
    density: f64,
    t_select: f64,
    wire_bytes: f64,
) -> f64 {
    t_hierarchical_on(
        machine,
        IntraLink::Smp,
        nodes,
        ranks_per_node,
        m_elems,
        density,
        t_select,
        wire_bytes,
    )
}

/// [`t_hierarchical`] with the gather/broadcast phases priced on an
/// explicit intra-host link class (`Smp` reproduces the historical form
/// exactly; `Unix`/`Loopback` match what a process-per-rank
/// `--transport unix`/`tcp` run pays on-node).  The leader exchange
/// always rides the inter-node `alpha`/`beta`.
#[allow(clippy::too_many_arguments)]
pub fn t_hierarchical_on(
    machine: &Machine,
    link: IntraLink,
    nodes: usize,
    ranks_per_node: usize,
    m_elems: f64,
    density: f64,
    t_select: f64,
    wire_bytes: f64,
) -> f64 {
    let (ia, ib) = machine.link_params(link);
    let p = nodes * ranks_per_node;
    if p <= 1 {
        return t_select;
    }
    let md = m_elems * density;
    let msg_bytes = md * wire_bytes;
    let (n, s, pf) = (nodes as f64, ranks_per_node as f64, p as f64);
    let mut t = t_select;
    t += (s - 1.0) * (ia + msg_bytes * ib);
    if nodes > 1 {
        let rounds = if nodes.is_power_of_two() { n.log2() } else { n - 1.0 };
        t += rounds * machine.alpha + (n - 1.0) * s * msg_bytes * machine.beta;
    }
    t += (s - 1.0) * (ia + pf * msg_bytes * ib);
    t + pf * md * machine.gamma_decompress
}

/// Total payload words the hierarchical schedule moves across the whole
/// fabric for uniform per-rank messages of `msg_words` words — the
/// bandwidth term [`t_hierarchical`] charges, summed over ranks:
/// `n·(s-1)·m` (gather) + `n·(n-1)·s·m` (leader allgather) +
/// `n·(s-1)·p·m` (broadcast).  The schedule's exact byte count is this
/// plus deterministic block framing
/// (`collectives::hierarchical_traffic_words` — pinned equal in
/// `tests/topology.rs`).
pub fn hierarchical_payload_words(nodes: usize, ranks_per_node: usize, msg_words: usize) -> u64 {
    let (n, s) = (nodes as u64, ranks_per_node as u64);
    let (p, m) = (n * s, msg_words as u64);
    if p <= 1 {
        return 0;
    }
    n * (s - 1) * m + n * (n - 1) * s * m + n * (s - 1) * p * m
}

/// Expected union density of `s` independent density-`d` selections —
/// the size the value-merging intra-node union
/// (`compression::message::merge_plain`) would shrink a node blob to:
/// `1 - (1-d)^s` (the §5.3 "1.55% from 0.1%·16 workers" growth law).
pub fn union_density(density: f64, ranks: usize) -> f64 {
    1.0 - (1.0 - density).powi(ranks as i32)
}

/// Plan-time cost inputs the picker derives from one fusion bucket.
#[derive(Clone, Copy, Debug)]
pub struct BucketCost {
    /// Total elements across the bucket's layers (M).
    pub m_elems: f64,
    /// Modeled selection time for the bucket (Σ per-layer launches).
    pub t_select: f64,
    /// Mean wire bytes per selected element (8 plain / 4 quantized,
    /// selection-weighted across the bucket's layers).
    pub wire_bytes: f64,
}

/// Derive [`BucketCost`] from a bucket's `(elems, method, quantize)`
/// layer specs under density `D` — what `--algo auto` prices.
pub fn bucket_cost(machine: &Machine, layers: &[(usize, Method, bool)], density: f64) -> BucketCost {
    let mut m_elems = 0.0;
    let mut t_select = 0.0;
    let mut sel_elems = 0.0;
    let mut sel_words = 0.0;
    for &(n, method, quantize) in layers {
        let nf = n as f64;
        m_elems += nf;
        let per_elem = match method {
            Method::Dense => 0.0,
            Method::ExactTopk => machine.sel_exact_per_elem,
            Method::TrimmedTopk => machine.sel_trimmed_per_elem,
            Method::SampledBinarySearch => machine.sel_bs_per_elem,
        };
        if method != Method::Dense {
            t_select += machine.sel_launch + nf * per_elem;
        }
        let k = (nf * density).ceil().max(1.0);
        sel_elems += k;
        sel_words += k * if quantize { 1.0 } else { 2.0 };
    }
    let wire_bytes =
        if sel_elems > 0.0 { 4.0 * sel_words / sel_elems } else { PLAIN_WIRE_BYTES };
    BucketCost { m_elems, t_select, wire_bytes }
}

/// The `--algo auto` decision for one fusion bucket: argmin of Eq. 2
/// (dense allreduce), Eq. 1 (flat sparse allgather) and the
/// hierarchical closed form, evaluated at plan time.  Ties resolve
/// dense < sparse < hierarchical (prefer the simpler schedule).
/// Returns the choice plus the three modeled times
/// `[dense, sparse, hierarchical]` for logs and the pinned test.
///
/// Latency conventions are consistent wherever the trainer can reach:
/// `config::validate` requires a power-of-two world, and every
/// factorization of a power of two is pow2 × pow2, so all three forms
/// price recursive-doubling rounds.  Off that path (a raw non-pow2 `p`
/// through this API), Eq. 1/2 keep the paper's `lg p` convention while
/// the hierarchical form prices the ring its leader phase actually
/// dispatches — compare with care.
pub fn pick_algo(
    machine: &Machine,
    nodes: usize,
    ranks_per_node: usize,
    cost: &BucketCost,
    density: f64,
) -> (Algo, [f64; 3]) {
    let p = nodes * ranks_per_node;
    let td = t_dense(machine, p, cost.m_elems);
    let ts = t_sparse(machine, p, cost.m_elems, density, cost.t_select, cost.wire_bytes);
    let th = t_hierarchical(
        machine,
        nodes,
        ranks_per_node,
        cost.m_elems,
        density,
        cost.t_select,
        cost.wire_bytes,
    );
    let algo = if td <= ts && td <= th {
        Algo::Dense
    } else if ts <= th {
        Algo::Sparse
    } else {
        Algo::Hierarchical
    };
    (algo, [td, ts, th])
}

/// [`pick_algo`] made link-class-aware: price the schedules against the
/// intra-host link the configured `--transport` actually uses (see
/// [`IntraLink`]).  Single-host worlds (`nodes <= 1`) run *every*
/// schedule — flat dense, flat sparse, degenerate hierarchical — over
/// the intra link, so all three terms reprice; multi-node worlds keep
/// the flat schedules on the inter-node fabric and reprice only the
/// hierarchical gather/broadcast phases — so for multi-node worlds
/// `pick_algo_on(.., Smp, ..)` is exactly [`pick_algo`] (pinned below).
pub fn pick_algo_on(
    machine: &Machine,
    link: IntraLink,
    nodes: usize,
    ranks_per_node: usize,
    cost: &BucketCost,
    density: f64,
) -> (Algo, [f64; 3]) {
    let p = nodes * ranks_per_node;
    let (td, ts) = if nodes <= 1 {
        (
            t_dense_on(machine, link, p, cost.m_elems),
            t_sparse_on(machine, link, p, cost.m_elems, density, cost.t_select, cost.wire_bytes),
        )
    } else {
        (
            t_dense(machine, p, cost.m_elems),
            t_sparse(machine, p, cost.m_elems, density, cost.t_select, cost.wire_bytes),
        )
    };
    let th = t_hierarchical_on(
        machine,
        link,
        nodes,
        ranks_per_node,
        cost.m_elems,
        density,
        cost.t_select,
        cost.wire_bytes,
    );
    let algo = if td <= ts && td <= th {
        Algo::Dense
    } else if ts <= th {
        Algo::Sparse
    } else {
        Algo::Hierarchical
    };
    (algo, [td, ts, th])
}

/// Structural round/byte coefficients of one collective schedule — the
/// cost-model terms with the link parameters factored out.  For a
/// per-rank serialized message of `B` bytes the schedule costs
///
/// ```text
/// inter_rounds·α + inter_bytes·B·β + intra_rounds·α_i + intra_bytes·B·β_i
/// ```
///
/// which is exactly the transfer part of Eq. 1/2 and the hierarchical
/// closed form above.  `obs::calib` fits measured collective times
/// against these coefficients to recover the α/β the fabric actually
/// delivers; flat schedules report in the `inter` slots (the calibrator
/// reroutes them to whichever link class the flat collective rode).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommCoeffs {
    pub inter_rounds: f64,
    pub inter_bytes: f64,
    pub intra_rounds: f64,
    pub intra_bytes: f64,
}

/// [`CommCoeffs`] of `algo` on a `nodes × ranks_per_node` topology.
/// Purely structural: no machine parameters, no message sizes.
pub fn comm_coeffs(algo: Algo, nodes: usize, ranks_per_node: usize) -> CommCoeffs {
    let p = nodes * ranks_per_node;
    if p <= 1 {
        return CommCoeffs::default();
    }
    let pf = p as f64;
    match algo {
        Algo::Dense => CommCoeffs {
            inter_rounds: 2.0 * pf.log2(),
            inter_bytes: 2.0 * (pf - 1.0) / pf,
            ..Default::default()
        },
        Algo::Sparse => CommCoeffs {
            inter_rounds: pf.log2(),
            inter_bytes: pf - 1.0,
            ..Default::default()
        },
        Algo::Hierarchical => {
            let (n, s) = (nodes as f64, ranks_per_node as f64);
            let (inter_rounds, inter_bytes) = if nodes > 1 {
                let rounds = if nodes.is_power_of_two() { n.log2() } else { n - 1.0 };
                (rounds, (n - 1.0) * s)
            } else {
                (0.0, 0.0)
            };
            CommCoeffs {
                inter_rounds,
                inter_bytes,
                intra_rounds: 2.0 * (s - 1.0),
                intra_bytes: (s - 1.0) * (1.0 + pf),
            }
        }
    }
}

/// Sparse/dense *bandwidth* ratio: `(p-1)·D·w / (2·(p-1)/p · 4)` =
/// `p·D·w/8`.  The §5.5 "12.8% not 0.1%" observation (the paper quotes
/// p·D; the factor the two conventions differ by is dense allreduce's
/// `2(p-1)/p ≈ 2`, which we keep).
pub fn bandwidth_ratio(p: usize, density: f64, wire_bytes: f64) -> f64 {
    let pf = p as f64;
    ((pf - 1.0) * density * wire_bytes) / (2.0 * (pf - 1.0) / pf * 4.0)
}

/// Fraction of Eq. 1 spent in decompression (the scaling bottleneck).
pub fn decompress_fraction(
    machine: &Machine,
    p: usize,
    m_elems: f64,
    density: f64,
    t_select: f64,
    wire_bytes: f64,
) -> f64 {
    let total = t_sparse(machine, p, m_elems, density, t_select, wire_bytes);
    let pf = p as f64;
    pf * m_elems * density * machine.gamma_decompress / total
}

/// Largest density at which sparse sync still beats dense for a layer of
/// `m_elems` at world size `p` (bisection on D; returns None if even
/// D → 0 loses, i.e. select cost alone exceeds dense).
pub fn crossover_density(
    machine: &Machine,
    p: usize,
    m_elems: f64,
    t_select: f64,
    wire_bytes: f64,
) -> Option<f64> {
    let dense = t_dense(machine, p, m_elems);
    if t_sparse(machine, p, m_elems, 0.0, t_select, wire_bytes) >= dense {
        return None;
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        if t_sparse(machine, p, m_elems, mid, t_select, wire_bytes) < dense {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(lo)
}

/// §5.5 policy decision: is sparse sync worthwhile for this layer?
/// (The static thresholds in [`crate::compression::PolicyThresholds`] are
/// the paper's tuned defaults; this is the model-driven version used for
/// ablations.)
pub fn sparse_wins(
    machine: &Machine,
    p: usize,
    m_elems: f64,
    density: f64,
    t_select: f64,
    wire_bytes: f64,
) -> bool {
    t_sparse(machine, p, m_elems, density, t_select, wire_bytes)
        < t_dense(machine, p, m_elems)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{allgather_time, allreduce_time};
    use crate::util::proptest::{check, ensure, ensure_close};

    #[test]
    fn eq1_matches_simnet_allgather_walk() {
        // Eq 1 transfer terms == walked recursive-doubling schedule +
        // select + decompress addenda
        let m = Machine::muradin();
        check(40, |g| {
            let p = 1usize << g.size(1..8);
            let elems = g.size(1024..4_000_000) as f64;
            let d = g.f32(0.0001..0.02) as f64;
            let closed = t_sparse(&m, p, elems, d, 0.0, PLAIN_WIRE_BYTES)
                - p as f64 * elems * d * m.gamma_decompress;
            let walked = allgather_time(&m, p, elems * d * PLAIN_WIRE_BYTES);
            ensure_close(closed, walked, 1e-9, "Eq1 vs schedule")
        });
    }

    #[test]
    fn eq2_matches_simnet_allreduce_walk() {
        let m = Machine::piz_daint();
        check(40, |g| {
            let p = 1usize << g.size(1..8);
            let elems = g.size(1024..8_000_000) as f64;
            let closed = t_dense(&m, p, elems);
            let walked = allreduce_time(&m, p, elems * 4.0);
            ensure_close(closed, walked, 1e-9, "Eq2 vs schedule")
        });
    }

    #[test]
    fn hierarchical_matches_simnet_walk() {
        // closed-form transfer terms == the walked three-phase schedule,
        // over pow2 (recursive doubling) and non-pow2 (ring) node counts
        let m = Machine::fatnode();
        check(60, |g| {
            let nodes = g.size(1..13);
            let s = g.size(1..9);
            let elems = g.size(10_000..4_000_000) as f64;
            let d = g.f32(0.0001..0.02) as f64;
            let p = nodes * s;
            if p == 1 {
                return Ok(());
            }
            let closed = t_hierarchical(&m, nodes, s, elems, d, 0.0, PLAIN_WIRE_BYTES)
                - p as f64 * elems * d * m.gamma_decompress;
            let walked =
                crate::simnet::hierarchical_allgather_time(&m, nodes, s, elems * d * PLAIN_WIRE_BYTES);
            ensure_close(closed, walked, 1e-9, "T_hier vs schedule")
        });
    }

    #[test]
    fn link_class_closed_forms_match_the_walks() {
        // the _on closed forms stay pinned to the walked schedules on
        // every link class, exactly like the legacy Smp pins above
        use crate::simnet::{allgather_time_on, allreduce_time_on, hierarchical_allgather_time_on};
        let m = Machine::muradin();
        check(40, |g| {
            let link = [IntraLink::Smp, IntraLink::Unix, IntraLink::Loopback][g.size(0..3)];
            let p = 1usize << g.size(1..8);
            let elems = g.size(1024..4_000_000) as f64;
            let d = g.f32(0.0001..0.02) as f64;
            let closed = t_sparse_on(&m, link, p, elems, d, 0.0, PLAIN_WIRE_BYTES)
                - p as f64 * elems * d * m.gamma_decompress;
            let walked = allgather_time_on(&m, link, p, elems * d * PLAIN_WIRE_BYTES);
            ensure_close(closed, walked, 1e-9, "Eq1 on link vs schedule")?;
            let closed = t_dense_on(&m, link, p, elems);
            let walked = allreduce_time_on(&m, link, p, elems * 4.0);
            ensure_close(closed, walked, 1e-9, "Eq2 on link vs schedule")?;
            let nodes = g.size(1..13);
            let s = g.size(1..9);
            if nodes * s == 1 {
                return Ok(());
            }
            let pf = (nodes * s) as f64;
            let closed = t_hierarchical_on(&m, link, nodes, s, elems, d, 0.0, PLAIN_WIRE_BYTES)
                - pf * elems * d * m.gamma_decompress;
            let walked =
                hierarchical_allgather_time_on(&m, link, nodes, s, elems * d * PLAIN_WIRE_BYTES);
            ensure_close(closed, walked, 1e-9, "T_hier on link vs schedule")
        });
    }

    #[test]
    fn pick_algo_on_smp_is_pick_algo_across_nodes() {
        // multi-node: Smp delegation must reproduce the legacy picker
        // bit-for-bit (same argmin, same three modeled times)
        let m = Machine::fatnode();
        check(40, |g| {
            let nodes = g.size(2..9);
            let s = g.size(1..9);
            let cost = BucketCost {
                m_elems: g.size(10_000..40_000_000) as f64,
                t_select: g.f32(0.0..0.01) as f64,
                wire_bytes: if g.size(0..2) == 0 { 8.0 } else { 4.0 },
            };
            let d = g.f32(0.0001..0.02) as f64;
            let (a0, t0) = pick_algo(&m, nodes, s, &cost, d);
            let (a1, t1) = pick_algo_on(&m, IntraLink::Smp, nodes, s, &cost, d);
            ensure(a0 == a1, format!("algo {a0:?} vs {a1:?}"))?;
            ensure(t0 == t1, format!("times {t0:?} vs {t1:?}"))
        });
    }

    #[test]
    fn single_host_picker_prices_the_actual_fabric() {
        // one host, 8 ranks, a big bucket: over the fast SMP link the
        // bandwidth term is cheap and selection overhead looms larger
        // than over loopback TCP, so the unix/loopback prices must sit
        // strictly above smp and below/above each other in preset order
        let m = Machine::muradin();
        let big = BucketCost { m_elems: 40e6, t_select: 40e6 * m.sel_bs_per_elem, wire_bytes: 8.0 };
        let d = 1e-3;
        let smp = pick_algo_on(&m, IntraLink::Smp, 1, 8, &big, d).1;
        let uds = pick_algo_on(&m, IntraLink::Unix, 1, 8, &big, d).1;
        let lo = pick_algo_on(&m, IntraLink::Loopback, 1, 8, &big, d).1;
        for i in 0..2 {
            // dense + sparse transfer terms: smp < unix < loopback
            assert!(smp[i] < uds[i] && uds[i] < lo[i], "term {i}: {smp:?} {uds:?} {lo:?}");
        }
        // and with nodes=1 the "hierarchy" degenerates to a serial
        // gather+broadcast on the same link — strictly worse than the
        // recursive-doubling flat schedule, so the picker never invents
        // a hierarchy the topology cannot pay for
        assert!(uds[1] < uds[2], "{uds:?}");
    }

    #[test]
    fn hierarchical_degenerates_to_flat_on_one_rank_nodes() {
        // s = 1: no gather, no broadcast — T_hier == Eq. 1 exactly
        let m = Machine::piz_daint();
        for p in [2usize, 8, 32] {
            let th = t_hierarchical(&m, p, 1, 1e7, 1e-3, 1e-4, PLAIN_WIRE_BYTES);
            let ts = t_sparse(&m, p, 1e7, 1e-3, 1e-4, PLAIN_WIRE_BYTES);
            assert!((th - ts).abs() <= 1e-12 * ts, "p={p}: {th} vs {ts}");
        }
    }

    #[test]
    fn picker_argmin_spans_all_three_regimes() {
        let m = Machine::fatnode();
        // a bucket of many fused small layers: per-layer selection
        // launches dwarf the bandwidth saving -> dense
        let tiny =
            BucketCost { m_elems: 80_000.0, t_select: 20.0 * m.sel_launch, wire_bytes: 8.0 };
        let (a, t) = pick_algo(&m, 4, 4, &tiny, 1e-3);
        assert_eq!(a, Algo::Dense, "{t:?}");
        // big bucket on fat nodes -> hierarchical beats flat sparse
        let big = BucketCost { m_elems: 40e6, t_select: 40e6 * m.sel_bs_per_elem, wire_bytes: 8.0 };
        let (a, t) = pick_algo(&m, 4, 4, &big, 1e-3);
        assert_eq!(a, Algo::Hierarchical, "{t:?}");
        assert!(t[2] < t[1] && t[1] < t[0], "{t:?}");
        // same bucket on piz-daint's thin nodes -> flat sparse
        let pd = Machine::piz_daint();
        let (a, t) = pick_algo(&pd, 4, 4, &big, 1e-3);
        assert_eq!(a, Algo::Sparse, "{t:?}");
    }

    #[test]
    fn comm_coeffs_reproduce_the_closed_forms() {
        // coefficients × link parameters == the transfer part of every
        // closed form, on all three schedules and both link routings
        let m = Machine::fatnode();
        check(40, |g| {
            let nodes = g.size(1..9);
            let s = g.size(1..9);
            let p = nodes * s;
            if p <= 1 {
                return Ok(());
            }
            let pf = p as f64;
            let elems = g.size(10_000..4_000_000) as f64;
            let d = g.f32(0.0001..0.02) as f64;
            let msg_bytes = elems * d * PLAIN_WIRE_BYTES;

            let cc = comm_coeffs(Algo::Sparse, nodes, s);
            let built = cc.inter_rounds * m.alpha
                + cc.inter_bytes * msg_bytes * m.beta
                + pf * elems * d * m.gamma_decompress;
            ensure_close(built, t_sparse(&m, p, elems, d, 0.0, PLAIN_WIRE_BYTES), 1e-9, "sparse")?;

            let cc = comm_coeffs(Algo::Dense, nodes, s);
            let built = cc.inter_rounds * m.alpha
                + cc.inter_bytes * (4.0 * elems) * m.beta
                + (pf - 1.0) / pf * elems * m.gamma_reduce;
            ensure_close(built, t_dense(&m, p, elems), 1e-9, "dense")?;

            let link = [IntraLink::Smp, IntraLink::Unix, IntraLink::Loopback][g.size(0..3)];
            let (ia, ib) = m.link_params(link);
            let cc = comm_coeffs(Algo::Hierarchical, nodes, s);
            let built = cc.inter_rounds * m.alpha
                + cc.inter_bytes * msg_bytes * m.beta
                + cc.intra_rounds * ia
                + cc.intra_bytes * msg_bytes * ib
                + pf * elems * d * m.gamma_decompress;
            let closed = t_hierarchical_on(&m, link, nodes, s, elems, d, 0.0, PLAIN_WIRE_BYTES);
            ensure_close(built, closed, 1e-9, "hierarchical")
        });
        // degenerate worlds carry no transfer terms at all
        assert_eq!(comm_coeffs(Algo::Sparse, 1, 1), CommCoeffs::default());
        assert_eq!(comm_coeffs(Algo::Hierarchical, 1, 1), CommCoeffs::default());
    }

    #[test]
    fn bucket_cost_weights_wire_bytes_by_selection() {
        let m = Machine::muradin();
        // two equal layers, one quantized: mean wire bytes = 6
        let layers = vec![
            (100_000usize, Method::SampledBinarySearch, false),
            (100_000usize, Method::SampledBinarySearch, true),
        ];
        let c = bucket_cost(&m, &layers, 0.01);
        assert_eq!(c.m_elems, 200_000.0);
        assert!((c.wire_bytes - 6.0).abs() < 1e-9, "{}", c.wire_bytes);
        assert!(c.t_select > 2.0 * m.sel_launch);
    }

    #[test]
    fn union_density_growth_law() {
        // §5.3: 0.1% density over 16 workers unions to ~1.55%
        let u = union_density(1e-3, 16);
        assert!(u > 0.0158 && u < 0.016, "{u}");
        assert_eq!(union_density(0.5, 1), 0.5);
    }

    #[test]
    fn paper_bandwidth_observation() {
        // p=128, D=0.1%, plain (8B/elem): 12.8% of dense bandwidth
        let r = bandwidth_ratio(128, 1e-3, PLAIN_WIRE_BYTES);
        assert!((r - 0.128).abs() < 1e-6, "{r}");
        // quantized halves it
        let rq = bandwidth_ratio(128, 1e-3, QUANT_WIRE_BYTES);
        assert!((rq - 0.064).abs() < 1e-6, "{rq}");
    }

    #[test]
    fn decompression_becomes_bottleneck_at_scale() {
        // with the (p-independent) select cost in the denominator, the
        // p·γ₁ term's share of Eq. 1 grows with p — Fig. 10's story
        let m = Machine::piz_daint();
        let elems = 25.6e6; // resnet50-ish
        let t_sel = m.sel_launch + elems * m.sel_trimmed_per_elem;
        let f16 = decompress_fraction(&m, 16, elems, 1e-3, t_sel, PLAIN_WIRE_BYTES);
        let f128 = decompress_fraction(&m, 128, elems, 1e-3, t_sel, PLAIN_WIRE_BYTES);
        assert!(f128 > f16, "fraction must grow with p: {f16} -> {f128}");
    }

    #[test]
    fn small_layers_prefer_dense() {
        // §5.5: below ~128KB the compression overhead (dominated by the
        // fixed selection launch cost) exceeds the bandwidth saving
        let m = Machine::muradin();
        let elems = 16_384.0; // 64 KB
        let t_sel = m.sel_launch + elems * m.sel_trimmed_per_elem;
        assert!(!sparse_wins(&m, 8, elems, 1e-3, t_sel, PLAIN_WIRE_BYTES) ||
                t_sparse(&m, 8, elems, 1e-3, t_sel, PLAIN_WIRE_BYTES) * 2.0
                    > t_dense(&m, 8, elems),
                "64KB layer should be (near) dense-preferred");
    }

    #[test]
    fn big_layers_prefer_sparse() {
        let m = Machine::muradin();
        let elems = 37.7e6; // alexnet fc6
        let t_sel = elems * m.sel_bs_per_elem;
        assert!(sparse_wins(&m, 8, elems, 1e-3, t_sel, PLAIN_WIRE_BYTES));
    }

    #[test]
    fn crossover_density_is_meaningful() {
        let m = Machine::piz_daint();
        let elems = 16e6;
        let d = crossover_density(&m, 64, elems, 0.0, PLAIN_WIRE_BYTES).unwrap();
        assert!(d > 1e-3 && d < 1.0, "crossover {d}");
        // denser than crossover loses, sparser wins
        assert!(sparse_wins(&m, 64, elems, d * 0.5, 0.0, PLAIN_WIRE_BYTES));
        assert!(!sparse_wins(&m, 64, elems, (d * 2.0).min(1.0), 0.0, PLAIN_WIRE_BYTES));
    }

    #[test]
    fn crossover_none_when_select_too_expensive() {
        let m = Machine::muradin();
        // tiny layer, huge select cost
        assert!(crossover_density(&m, 8, 1024.0, 1.0, PLAIN_WIRE_BYTES).is_none());
    }

    #[test]
    fn warmup_density_needs_full_bandwidth_at_64() {
        // §5.7: at 64 GPUs, D = 1.5625% quantized already needs ~100% of
        // dense allreduce bandwidth — warm-up should use dense allreduce
        let r = bandwidth_ratio(64, 0.015625, QUANT_WIRE_BYTES);
        assert!(r > 0.45, "quantized warm-up bandwidth ratio {r}");
        let rp = bandwidth_ratio(64, 0.015625, PLAIN_WIRE_BYTES);
        assert!(rp > 0.9, "plain warm-up bandwidth ratio {rp}");
    }

    #[test]
    fn prop_pipelined_never_exceeds_sequential_eq1() {
        // max(select, transfer) <= select + transfer, with equality only
        // when one side is zero — the overlap can only help
        let m = Machine::piz_daint();
        check(40, |g| {
            let p = 1usize << g.size(1..8);
            let elems = g.size(10_000..40_000_000) as f64;
            let d = g.f32(1e-4..0.02) as f64;
            let t_sel = elems * m.sel_trimmed_per_elem;
            let piped = t_sparse_pipelined(&m, p, elems, d, t_sel, PLAIN_WIRE_BYTES);
            let seq = t_sparse(&m, p, elems, d, t_sel, PLAIN_WIRE_BYTES);
            ensure(piped <= seq + 1e-15, format!("pipelined {piped} > sequential {seq}"))?;
            // the hidden side is exactly min(select, transfer)
            let transfer = seq - t_sel - p as f64 * elems * d * m.gamma_decompress;
            ensure_close(seq - piped, t_sel.min(transfer), 1e-9, "hidden time")
        });
    }

    #[test]
    fn t_overlap_is_the_max() {
        assert_eq!(t_overlap(2.0, 3.0), 3.0);
        assert_eq!(t_overlap(5.0, 1.0), 5.0);
        // single rank: nothing to transfer, select is exposed either way
        let m = Machine::muradin();
        assert_eq!(t_sparse_pipelined(&m, 1, 1e6, 1e-3, 0.5, PLAIN_WIRE_BYTES), 0.5);
    }

    #[test]
    fn prop_sparse_monotone_in_density_and_p() {
        let m = Machine::piz_daint();
        check(40, |g| {
            let p = 1usize << g.size(1..7);
            let elems = g.size(100_000..50_000_000) as f64;
            let d1 = g.f32(0.0001..0.01) as f64;
            let d2 = d1 * 2.0;
            ensure(
                t_sparse(&m, p, elems, d1, 0.0, 8.0) < t_sparse(&m, p, elems, d2, 0.0, 8.0),
                "monotone in D",
            )?;
            ensure(
                t_sparse(&m, p, elems, d1, 0.0, 8.0)
                    < t_sparse(&m, 2 * p, elems, d1, 0.0, 8.0),
                "monotone in p",
            )
        });
    }
}
