//! One data-parallel worker: owns a PJRT runtime, a model replica, the
//! per-layer compression pipelines and one fabric endpoint.  Executes the
//! RGC training loop of Algorithm 4.

use super::metrics::{param_hash, phase, WorkerResult};
use crate::collectives::{allgather, allreduce_mean, Transport};
use crate::compression::message::{pack_plain, pack_quant, unpack_plain, unpack_quant};
use crate::compression::{
    CompressorConfig, Method, QuantizedSet, ResidualState, SignAlternator,
};
use crate::config::TrainConfig;
use crate::data::{ClusterDataset, ZipfMarkovCorpus};
use crate::models::schema::ModelSchema;
use crate::optim::{clip_by_global_norm, local_clip_factor, DenseOptState};
use crate::runtime::step::{Batch, StepRunner};
use crate::runtime::{CompressOps, DeviceSelector, Runtime};
use crate::simnet::iteration::Strategy;
use crate::tensor::SparseTensor;
use crate::util::timer::PhaseTimer;

/// Per-layer synchronization plan (Alg. 5 dispatch, decided once).
struct LayerPlan {
    method: Method,
    /// Quantize this layer's messages (§5.2.3; never the output layer).
    quantize: bool,
    /// Residual + momentum state (compressed layers only).
    residual: Option<ResidualState>,
    /// Sign alternation for quantized layers.
    alternator: SignAlternator,
    /// Cached binary-search threshold (+ age) for the sampled variant.
    cached_thr: Option<(f32, usize)>,
    /// Dense-path optimizer state (used for Dense layers and during
    /// dense warm-up epochs).
    dense_state: DenseOptState,
}

/// Training data source, constructed identically on every rank and
/// sharded by (rank, step).
enum DataSource {
    Lm(ZipfMarkovCorpus),
    Mlp(ClusterDataset),
}

impl DataSource {
    fn for_model(schema: &ModelSchema, seed: u64) -> DataSource {
        match schema.kind.as_str() {
            "lm" => DataSource::Lm(ZipfMarkovCorpus::new(
                schema.cfg("vocab").expect("lm vocab"),
                seed ^ 0xDA7A,
                1.1,
            )),
            // dimension-aware margin: center separation grows ~ √dim, so
            // margin ∝ dim^-1/2 keeps the Bayes error well above zero and
            // strategy-quality differences measurable — no ceiling effect
            _ => {
                let dim = schema.cfg("in_dim").expect("mlp in_dim");
                DataSource::Mlp(ClusterDataset::new(
                    5120,
                    dim,
                    schema.cfg("classes").expect("mlp classes"),
                    1.6 / (dim as f32).sqrt(),
                    seed ^ 0xDA7A,
                ))
            }
        }
    }

    fn batch(&self, schema: &ModelSchema, rank: usize, world: usize, step: usize) -> Batch {
        match self {
            DataSource::Lm(corpus) => {
                let (tokens, targets) = corpus.batch(
                    rank,
                    step,
                    schema.cfg("batch").unwrap(),
                    schema.cfg("seq").unwrap(),
                );
                Batch::Lm { tokens, targets }
            }
            DataSource::Mlp(ds) => {
                let (x, y) = ds.batch(rank, world, step, schema.cfg("batch").unwrap());
                Batch::Mlp { x, y }
            }
        }
    }

}

/// Step id of the fixed held-out LM eval batch (rank id `world + 1` keeps
/// it disjoint from every training shard).
const EVAL_STEP: usize = 0x7E0A;

/// Run one worker to completion.  Generic over the fabric: in-process
/// `LocalTransport` threads under [`super::Trainer::run`], a
/// `net::TcpTransport` rank under [`super::Trainer::run_rank`].  Called
/// on its own thread by the [`super::Trainer`]; panics propagate to the
/// join and become errors.
pub fn run_worker<T: Transport>(
    cfg: &TrainConfig,
    schema: &ModelSchema,
    transport: &T,
) -> Result<WorkerResult, String> {
    let rank = transport.rank();
    let world = transport.world();
    let rt = Runtime::new().map_err(|e| format!("rank {rank}: runtime: {e}"))?;
    let runner = StepRunner::new(&rt, schema).map_err(|e| format!("rank {rank}: load: {e}"))?;

    // the device-selection path needs the compression-op artifacts
    let manifest;
    let device = if cfg.device_select {
        manifest = crate::models::schema::Manifest::load(
            schema.file.parent().expect("artifact dir"),
        )
        .map_err(|e| format!("rank {rank}: manifest: {e}"))?;
        Some(DeviceSelector::new(
            CompressOps::new(&rt, &manifest).map_err(|e| format!("rank {rank}: ops: {e}"))?,
        ))
    } else {
        None
    };

    let mut params = schema.init_params(cfg.seed);
    let mut plans = build_plans(cfg, schema);
    let data = DataSource::for_model(schema, cfg.seed);
    let warmup = cfg.warmup_schedule();

    // §5.3 tensor fusion: batch compressed layers (in backprop order)
    // into shared allgather groups; singleton groups when fusion is off
    let comp_order: Vec<usize> =
        (0..schema.params.len()).rev().filter(|&i| plans[i].method != Method::Dense).collect();
    let fusion_groups: Vec<Vec<usize>> = if cfg.fusion_cap_elems > 0 && !comp_order.is_empty() {
        let sizes: Vec<usize> =
            comp_order.iter().map(|&i| schema.params[i].size()).collect();
        crate::collectives::FusionPlan::greedy(&sizes, cfg.fusion_cap_elems)
            .buckets
            .into_iter()
            .map(|b| b.layers.into_iter().map(|(pos, _)| comp_order[pos]).collect())
            .collect()
    } else {
        comp_order.into_iter().map(|i| vec![i]).collect()
    };

    let mut timer = PhaseTimer::new();
    let mut loss_curve = Vec::new();
    let mut eval_curve = Vec::new();
    let mut union_density = Vec::new();
    let mut sent_density = Vec::new();
    let mut final_loss = f32::NAN;

    // scratch for union-density measurement (largest layer)
    let max_layer = schema.params.iter().map(|p| p.size()).max().unwrap_or(0);
    let mut seen = vec![false; max_layer];

    for step in 0..cfg.steps {
        let epoch = step / cfg.steps_per_epoch;
        let density = warmup.density_at(epoch);
        let dense_step = cfg.strategy == Strategy::Dense || warmup.is_dense_at(epoch);
        let lr = cfg.lr.lr_at(step);
        let log_step = step % cfg.log_every == 0 || step + 1 == cfg.steps;

        let batch = data.batch(schema, rank, world, step);
        let (loss, mut grads) = timer.time(phase::COMPUTE, || runner.step(&rt, &params, &batch))
            .map_err(|e| format!("rank {rank} step {step}: {e}"))?;

        // DGC local clipping (before residual accumulation)
        if let Some(max_norm) = cfg.clip {
            let limit =
                if dense_step { max_norm } else { local_clip_factor(max_norm, world) };
            let mut refs: Vec<&mut [f32]> = grads.iter_mut().map(|g| g.as_mut_slice()).collect();
            clip_by_global_norm(&mut refs, limit);
        }

        let mut selected_elems = 0usize;
        let mut sparse_elems = 0usize;
        let mut union_elems = 0usize;
        let scale = -lr / world as f32;

        // backprop order: last layer first, as the paper's overlap scheme
        // initiates communication for deeper layers first.  Dense layers
        // allreduce inline; compressed layers are handled per fusion
        // group (a group of one when fusion is off, §5.3 batching when
        // `fusion_cap_elems` > 0).
        if dense_step {
            for li in (0..params.len()).rev() {
                timer.time(phase::COMM_DENSE, || allreduce_mean(&transport, &mut grads[li]));
                timer.time(phase::UPDATE, || {
                    plans[li].dense_state.apply(cfg.optimizer, &mut params[li], &grads[li], lr)
                });
            }
        } else {
            for li in (0..params.len()).rev() {
                if plans[li].method != Method::Dense {
                    continue;
                }
                timer.time(phase::COMM_DENSE, || allreduce_mean(&transport, &mut grads[li]));
                timer.time(phase::UPDATE, || {
                    plans[li].dense_state.apply(cfg.optimizer, &mut params[li], &grads[li], lr)
                });
            }
            for group in &fusion_groups {
                // --- compressed path (Alg. 4): select + pack per layer,
                // one allgather per fusion group ---
                let mut blob: Vec<u32> = Vec::new();
                for &li in group {
                    let plan = &mut plans[li];
                    let n = params[li].len();
                    let residual =
                        plan.residual.as_mut().expect("compressed layer has residual");
                    // momentum correction (Alg. 4 lines 11-19): via the
                    // fused L1 kernel on the device path, host otherwise
                    let dev_accum = device
                        .as_ref()
                        .filter(|d| d.ops.has_momentum_accum())
                        .map(|d| &d.ops);
                    timer.time(phase::MASK, || -> Result<(), String> {
                        if let Some(ops) = dev_accum {
                            let (momentum, nesterov) = match residual.accumulation {
                                crate::compression::Accumulation::Sgd => (0.0, false),
                                crate::compression::Accumulation::Momentum { momentum } => {
                                    (momentum, false)
                                }
                                crate::compression::Accumulation::Nesterov { momentum } => {
                                    (momentum, true)
                                }
                            };
                            let (v, u) = ops
                                .momentum_accum(
                                    residual.residual(),
                                    residual.momentum_buf(),
                                    &grads[li],
                                    momentum,
                                    nesterov,
                                )
                                .map_err(|e| format!("momentum_accum: {e}"))?;
                            residual.set_buffers(v, u);
                        } else {
                            residual.accumulate(&grads[li]);
                        }
                        Ok(())
                    })?;

                    let k = k_for(n, density);
                    let sign =
                        if plan.quantize { Some(plan.alternator.next_sign()) } else { None };
                    let sel = timer.time(phase::SELECT, || {
                        select_layer(plan, device.as_ref(), k, sign, cfg)
                    })?;
                    timer.time(phase::MASK, || {
                        plan.residual.as_mut().unwrap().mask(&sel);
                    });
                    selected_elems += sel.len();
                    sparse_elems += n;

                    timer.time(phase::PACK, || {
                        if plan.quantize {
                            blob.extend(pack_quant(&QuantizedSet::from_sparse(&sel)))
                        } else {
                            blob.extend(pack_plain(&sel))
                        }
                    });
                }

                let gathered =
                    timer.time(phase::COMM_SPARSE, || allgather(&transport, blob));

                // §5.4 decompression: walk each rank's blob, scatter-add
                // every layer's set scaled by -lr/N
                timer
                    .time(phase::UNPACK, || -> Result<(), String> {
                        for rank_blob in &gathered {
                            let mut off = 0usize;
                            for &li in group {
                                if plans[li].quantize {
                                    let (q, used) = unpack_quant(&rank_blob[off..])
                                        .map_err(|e| format!("layer {li}: {e}"))?;
                                    let add = q.mean * scale;
                                    for &i in &q.indices {
                                        params[li][i as usize] += add;
                                    }
                                    off += used;
                                } else {
                                    let (s, used) = unpack_plain(&rank_blob[off..])
                                        .map_err(|e| format!("layer {li}: {e}"))?;
                                    s.scatter_add(&mut params[li], scale);
                                    off += used;
                                }
                            }
                        }
                        Ok(())
                    })
                    .map_err(|e| format!("rank {rank} step {step}: wire: {e}"))?;

                // union-density measurement (log steps): distinct indices
                // across all ranks / layer size — the §5.3 observation
                if log_step {
                    union_elems += count_union_fused(&gathered, group, &plans, &mut seen);
                }
            }
        }

        final_loss = loss;
        if log_step {
            // global mean loss (collective: all ranks participate)
            let mut l = [loss];
            allreduce_mean(&transport, &mut l);
            if rank == 0 {
                loss_curve.push((step, l[0]));
                if sparse_elems > 0 {
                    sent_density
                        .push((step, selected_elems as f64 / sparse_elems as f64));
                    union_density.push((step, union_elems as f64 / sparse_elems as f64));
                }
            }
        }

        if cfg.eval_every > 0 && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) && rank == 0
        {
            let metric = timer
                .time(phase::EVAL, || eval_metric(&rt, &runner, schema, &params, &data, world))
                .map_err(|e| format!("rank {rank} eval: {e}"))?;
            eval_curve.push((step, metric));
        }
    }

    Ok(WorkerResult {
        rank,
        timer,
        loss_curve,
        eval_curve,
        union_density,
        sent_density,
        param_hash: param_hash(&params),
        final_loss,
    })
}

fn k_for(n: usize, density: f64) -> usize {
    ((n as f64 * density).ceil() as usize).clamp(1, n)
}

fn build_plans(cfg: &TrainConfig, schema: &ModelSchema) -> Vec<LayerPlan> {
    schema
        .params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let method = if cfg.strategy == Strategy::Dense {
                Method::Dense
            } else {
                Method::for_size(p.bytes(), cfg.thresholds)
            };
            let compressed = method != Method::Dense;
            let quantize = cfg.strategy == Strategy::QuantRgc
                && compressed
                && !schema.is_output_param(i);
            LayerPlan {
                method,
                quantize,
                residual: compressed
                    .then(|| ResidualState::new(p.size(), cfg.optimizer.accumulation())),
                alternator: SignAlternator::new(),
                cached_thr: None,
                dense_state: DenseOptState::new(p.size(), cfg.optimizer),
            }
        })
        .collect()
}

/// Communication-set selection for one layer, host or device flavor.
fn select_layer(
    plan: &mut LayerPlan,
    device: Option<&DeviceSelector>,
    k: usize,
    sign: Option<f32>,
    cfg: &TrainConfig,
) -> Result<SparseTensor, String> {
    let cc = CompressorConfig { density: cfg.density, ..Default::default() };
    let residual = plan.residual.as_mut().expect("residual");

    if let Some(dev) = device {
        // L1-kernel path
        let d = match plan.method {
            Method::TrimmedTopk | Method::ExactTopk => {
                dev.trimmed_topk(residual.residual(), k, cc.trim_eps, sign)
            }
            Method::SampledBinarySearch => dev
                .threshold_binary_search(residual.residual(), k, cc.bs.eps, cc.bs.max_iters, sign),
            Method::Dense => unreachable!("dense layers never select"),
        }
        .map_err(|e| format!("device select: {e}"))?;
        return Ok(d.sparse);
    }

    // host path (mirrors LayerCompressor but with the per-step density and
    // the worker-owned threshold cache)
    let v = residual.residual();
    let sel = match plan.method {
        Method::ExactTopk => crate::compression::exact_topk(v, k, sign),
        Method::TrimmedTopk => crate::compression::trimmed_topk(v, k, cc.trim_eps, sign),
        Method::SampledBinarySearch => {
            // §6.4: threshold reuse is incompatible with sign alternation
            if sign.is_none() {
                if let Some((thr, age)) = plan.cached_thr {
                    if age < cc.interval {
                        let s = SparseTensor::compact_above(v, thr);
                        // cache is valid unless the residual drifted far
                        // from the threshold (the paper's re-select rule)
                        if !s.is_empty() && s.len() <= 4 * k {
                            plan.cached_thr = Some((thr, age + 1));
                            return Ok(s);
                        }
                        // fall through to a fresh search
                    }
                }
            }
            let sel = crate::compression::threshold_binary_search(v, k, cc.bs, sign);
            if sign.is_none() {
                plan.cached_thr = Some((sel.threshold, 1));
            }
            sel
        }
        Method::Dense => unreachable!(),
    };
    Ok(sel.sparse)
}

/// Count the distinct indices each layer of a fusion group received
/// across all ranks' blobs, using (and clearing) the `seen` scratch.
fn count_union_fused(
    gathered: &[Vec<u32>],
    group: &[usize],
    plans: &[LayerPlan],
    seen: &mut [bool],
) -> usize {
    let mut cursors = vec![0usize; gathered.len()];
    let mut total = 0usize;
    for &li in group {
        let quantized = plans[li].quantize;
        let mut marked: Vec<u32> = Vec::new();
        for (r, blob) in gathered.iter().enumerate() {
            if quantized {
                if let Ok((q, used)) = unpack_quant(&blob[cursors[r]..]) {
                    for &i in &q.indices {
                        if !seen[i as usize] {
                            seen[i as usize] = true;
                            marked.push(i);
                        }
                    }
                    cursors[r] += used;
                }
            } else if let Ok((s, used)) = unpack_plain(&blob[cursors[r]..]) {
                for &i in &s.indices {
                    if !seen[i as usize] {
                        seen[i as usize] = true;
                        marked.push(i);
                    }
                }
                cursors[r] += used;
            }
        }
        total += marked.len();
        for i in marked {
            seen[i as usize] = false;
        }
    }
    total
}

fn eval_metric(
    rt: &Runtime,
    runner: &StepRunner,
    schema: &ModelSchema,
    params: &[Vec<f32>],
    data: &DataSource,
    world: usize,
) -> crate::runtime::Result<f32> {
    match data {
        DataSource::Lm(corpus) => {
            let (tokens, targets) = corpus.batch(
                world + 1,
                EVAL_STEP,
                schema.cfg("batch").unwrap(),
                schema.cfg("seq").unwrap(),
            );
            runner.eval_lm(rt, params, &Batch::Lm { tokens, targets })
        }
        DataSource::Mlp(ds) => {
            // generalization accuracy on the held-out split
            let (xs, ys) = ds.eval_split();
            runner.eval_mlp_accuracy(rt, params, xs, ys)
        }
    }
}
