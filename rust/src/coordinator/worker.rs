//! One data-parallel worker: owns a PJRT runtime, a model replica, the
//! per-layer compression pipelines and one fabric endpoint.  Executes the
//! RGC training loop of Algorithm 4.
//!
//! Compressed-bucket synchronization is delegated to a
//! [`crate::pipeline::SyncEngine`]: `Sequential` (inline, the oracle) or
//! `Pipelined` (comm thread pool overlapping selection + collectives
//! across buckets, `cfg.pipeline`).  Under the pipelined engine *all*
//! fabric traffic — including this loop's dense allreduces, loss
//! averaging and the trainer's replica-hash check — flows through a
//! [`TagMux`] control channel so concurrent bucket collectives can share
//! the endpoint.

use super::checkpoint::Checkpoint;
use super::metrics::{param_hash, phase, RejoinStats, RepoStats, WorkerResult};
use crate::collectives::group::{Algo, Topology};
use crate::collectives::mux::{TagChannel, TagMux};
use crate::collectives::{allreduce_mean, Gathered, Transport};
use crate::compression::message::{view_plain, view_quant};
use crate::compression::{CompressorConfig, Method};
use crate::config::{AlgoMode, TrainConfig, TransportKind};
use crate::costmodel;
use crate::data::{ClusterDataset, ZipfMarkovCorpus};
use crate::elastic::{self, ElasticOpts, ElasticStatus, RankOutcome, ShardKey, Workload};
use crate::models::schema::ModelSchema;
use crate::obs;
use crate::optim::{clip_by_global_norm, local_clip_factor, DenseOptState};
use crate::pipeline::{
    build_buckets, BucketDone, LayerSpec, Pipelined, Sequential, SyncEngine, BUCKET_TAG_BASE,
    CTRL_TAG,
};
use crate::runtime::step::{Batch, StepRunner};
use crate::runtime::{CompressOps, DeviceSelector, Runtime};
use crate::simnet::iteration::Strategy;
use crate::simnet::Machine;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-layer synchronization plan (Alg. 5 dispatch, decided once).  The
/// compressed layers' evolving state (residual, alternator, threshold
/// cache) lives inside the sync engine's buckets; this keeps what the
/// training loop itself needs.
struct LayerPlan {
    method: Method,
    /// Quantize this layer's messages (§5.2.3; never the output layer).
    quantize: bool,
    /// Dense-path optimizer state (used for Dense layers and during
    /// dense warm-up epochs).
    dense_state: DenseOptState,
}

/// Training data source, constructed identically on every rank and
/// sharded by (rank, step).
enum DataSource {
    Lm(ZipfMarkovCorpus),
    Mlp(ClusterDataset),
}

impl DataSource {
    fn for_model(schema: &ModelSchema, seed: u64) -> DataSource {
        match schema.kind.as_str() {
            "lm" => DataSource::Lm(ZipfMarkovCorpus::new(
                schema.cfg("vocab").expect("lm vocab"),
                seed ^ 0xDA7A,
                1.1,
            )),
            // dimension-aware margin: center separation grows ~ √dim, so
            // margin ∝ dim^-1/2 keeps the Bayes error well above zero and
            // strategy-quality differences measurable — no ceiling effect
            _ => {
                let dim = schema.cfg("in_dim").expect("mlp in_dim");
                DataSource::Mlp(ClusterDataset::new(
                    5120,
                    dim,
                    schema.cfg("classes").expect("mlp classes"),
                    1.6 / (dim as f32).sqrt(),
                    seed ^ 0xDA7A,
                ))
            }
        }
    }

    fn batch(&self, schema: &ModelSchema, rank: usize, world: usize, step: usize) -> Batch {
        self.batch_salted(schema, rank, world, step, 0)
    }

    /// Shard re-keyed by `(seed, view_epoch, rank)`: the elastic driver
    /// passes the membership view epoch as the salt, so a reshaped
    /// world draws fresh, still-disjoint shards.
    fn batch_salted(
        &self,
        schema: &ModelSchema,
        rank: usize,
        world: usize,
        step: usize,
        salt: u64,
    ) -> Batch {
        match self {
            DataSource::Lm(corpus) => {
                let (tokens, targets) = corpus.batch_salted(
                    rank,
                    step,
                    salt,
                    schema.cfg("batch").unwrap(),
                    schema.cfg("seq").unwrap(),
                );
                Batch::Lm { tokens, targets }
            }
            DataSource::Mlp(ds) => {
                let (x, y) =
                    ds.batch_salted(rank, world, step, salt, schema.cfg("batch").unwrap());
                Batch::Mlp { x, y }
            }
        }
    }
}

/// Step id of the fixed held-out LM eval batch (rank id `world + 1` keeps
/// it disjoint from every training shard).
const EVAL_STEP: usize = 0x7E0A;

/// Slowest/fastest mean-step-latency ratio past which an `--obs-every`
/// window flags a straggler rank.
const STRAGGLER_RATIO: f64 = 1.5;

/// Run one worker to completion.  Generic over the fabric: in-process
/// `LocalTransport` threads under [`super::Trainer::run`], a
/// `net::TcpTransport` rank under [`super::Trainer::run_rank`].  Called
/// on its own thread by the [`super::Trainer`]; panics propagate to the
/// join and become errors.  `Sync` because the pipelined engine shares
/// the endpoint with its comm pool.
pub fn run_worker<T: Transport + Sync>(
    cfg: &TrainConfig,
    schema: &ModelSchema,
    transport: &T,
) -> Result<WorkerResult, String> {
    let rank = transport.rank();
    let world = transport.world();
    // spans are recorded only when a trace sink exists; the switch must
    // flip before any engine is built (rings register at construction)
    if cfg.trace_out.is_some() {
        obs::set_enabled(true);
    }
    let rt = Runtime::new().map_err(|e| format!("rank {rank}: runtime: {e}"))?;
    let runner = StepRunner::new(&rt, schema).map_err(|e| format!("rank {rank}: load: {e}"))?;

    // the device-selection path needs the compression-op artifacts
    let manifest;
    let device = if cfg.device_select {
        if cfg.pipeline {
            // config::validate rejects this too; belt and braces
            return Err(format!(
                "rank {rank}: device_select is incompatible with the pipelined engine \
                 (PJRT clients are thread-bound)"
            ));
        }
        manifest = crate::models::schema::Manifest::load(
            schema.file.parent().expect("artifact dir"),
        )
        .map_err(|e| format!("rank {rank}: manifest: {e}"))?;
        Some(DeviceSelector::new(
            CompressOps::new(&rt, &manifest).map_err(|e| format!("rank {rank}: ops: {e}"))?,
        ))
    } else {
        None
    };

    let mut params = schema.init_params(cfg.seed);
    let mut plans = build_plans(cfg, schema);
    let data = DataSource::for_model(schema, cfg.seed);
    let warmup = cfg.warmup_schedule();

    // §5.3 tensor fusion: compressed layers in backprop order, batched
    // into shared allgather buckets owned by the sync engine (singleton
    // buckets when fusion is off)
    let specs: Vec<LayerSpec> = (0..schema.params.len())
        .rev()
        .filter(|&i| plans[i].method != Method::Dense)
        .map(|i| LayerSpec {
            li: i,
            n: schema.params[i].size(),
            method: plans[i].method,
            quantize: plans[i].quantize,
        })
        .collect();
    let mut buckets = build_buckets(&specs, cfg.fusion_cap_elems, cfg.optimizer.accumulation());

    // Per-bucket collective plan (DESIGN.md §Topology-Aware-
    // Communication): static under `sparse`/`hierarchical`, the
    // cost-model argmin under `auto` — where a dense-picked bucket's
    // layers are demoted to the dense allreduce path before any engine
    // (or the mux tag space) sees them.  Identical on every rank: the
    // inputs are config + schema, never runtime measurements.
    let topo = cfg.topology.unwrap_or_else(|| Topology::flat(world));
    // Calibration state (`--algo auto` with telemetry on): rank 0 owns
    // the estimator + audit ledger and the kept buckets' costs so the
    // `--recalib-every` barrier can re-run the picker; every rank keeps
    // the live per-bucket plan to apply broadcast switches to.
    let mut calibrator: Option<obs::Calibrator> = None;
    let mut bucket_costs: Vec<costmodel::BucketCost> = Vec::new();
    match cfg.algo {
        AlgoMode::Sparse => {}
        AlgoMode::Hierarchical => {
            for b in &mut buckets {
                b.set_algo(Algo::Hierarchical);
            }
        }
        AlgoMode::Auto => {
            let machine = Machine::by_name(&cfg.machine)
                .ok_or_else(|| format!("rank {rank}: unknown machine '{}'", cfg.machine))?;
            // Price intra-host traffic on the link class the configured
            // fabric actually rides (costmodel::pick_algo_on): loopback
            // TCP for --transport tcp, AF_UNIX for unix/auto.  The
            // in-process LocalFabric keeps the legacy picker verbatim —
            // identical decisions to every run before link classes
            // existed.  The mapping is pure config, so it stays
            // rank-deterministic.
            let link = match cfg.transport {
                TransportKind::Local => None,
                TransportKind::Tcp => Some(costmodel::IntraLink::Loopback),
                TransportKind::Unix | TransportKind::Auto => Some(costmodel::IntraLink::Unix),
            };
            let mut kept = Vec::with_capacity(buckets.len());
            let mut kept_costs = Vec::with_capacity(buckets.len());
            for mut b in buckets {
                let layers: Vec<(usize, Method, bool)> =
                    b.specs().map(|s| (s.n, s.method, s.quantize)).collect();
                let cost = costmodel::bucket_cost(&machine, &layers, cfg.density);
                let (algo, _times) = match link {
                    None => costmodel::pick_algo(
                        &machine,
                        topo.nodes,
                        topo.ranks_per_node,
                        &cost,
                        cfg.density,
                    ),
                    Some(link) => costmodel::pick_algo_on(
                        &machine,
                        link,
                        topo.nodes,
                        topo.ranks_per_node,
                        &cost,
                        cfg.density,
                    ),
                };
                if algo == Algo::Dense {
                    for s in b.specs() {
                        plans[s.li].method = Method::Dense;
                    }
                } else {
                    b.set_algo(algo);
                    kept_costs.push(cost);
                    kept.push(b);
                }
            }
            buckets = kept;
            if rank == 0
                && (cfg.recalib_every > 0
                    || cfg.obs_every > 0
                    || cfg.metrics_addr.is_some()
                    || cfg.trace_out.is_some())
            {
                calibrator = Some(obs::Calibrator::new(
                    machine,
                    link,
                    topo.nodes,
                    topo.ranks_per_node,
                    buckets.len(),
                ));
            }
            bucket_costs = kept_costs;
        }
    }
    // the live per-bucket plan, identical on every rank; `--recalib-every`
    // switches it at step barriers (sparse ↔ hierarchical only)
    let mut algos: Vec<Algo> = buckets.iter().map(|b| b.algo()).collect();
    let n_buckets = buckets.len();
    let cc =
        CompressorConfig { density: cfg.density, timing: cfg.phase_timing, ..Default::default() };

    // Engine + the loop's own comm handle.  Sequential keeps the raw
    // endpoint (bit- and byte-identical to the historical schedule);
    // pipelined multiplexes everything: control on tag 0, bucket b on
    // tag 1 + b.
    let mut mux_handle: Option<Arc<TagMux<&T>>> = None;
    let ctrl: TagChannel<&T>;
    let mut pipelined_engine: Pipelined<&T>;
    let mut sequential_engine: Sequential<'_, T>;
    let engine: &mut dyn SyncEngine;
    let comm: &dyn Transport;
    if cfg.pipeline {
        let mux = Arc::new(TagMux::new(transport, BUCKET_TAG_BASE + n_buckets as u32));
        mux_handle = Some(Arc::clone(&mux));
        ctrl = TagChannel::new(Arc::clone(&mux), CTRL_TAG);
        pipelined_engine = Pipelined::with_topology(mux, topo, buckets, cfg.inflight, cc);
        engine = &mut pipelined_engine;
        comm = &ctrl;
    } else {
        sequential_engine = Sequential::with_topology(transport, topo, device, buckets, cc);
        engine = &mut sequential_engine;
        comm = transport;
    }

    // Observability surfaces: the main lane's span ring (tracing), the
    // metric registry (aggregation/scrape) and rank 0's scrape endpoint.
    // All None/off by default — the steady state is then byte-identical
    // to the uninstrumented loop.
    let ring = obs::enabled().then(|| obs::ring(rank, obs::LANE_MAIN, obs::DEFAULT_CAP));
    let want_metrics =
        cfg.obs_every > 0 || cfg.metrics_addr.is_some() || cfg.trace_out.is_some();
    let reg = want_metrics.then(|| Arc::new(obs::Registry::new()));
    let mut scraper = None;
    if rank == 0 {
        if let (Some(addr), Some(reg)) = (&cfg.metrics_addr, &reg) {
            match obs::serve(addr, Arc::clone(reg)) {
                Ok(s) => {
                    crate::log_info!("metrics endpoint listening on {}", s.addr);
                    scraper = Some(s);
                }
                Err(e) => crate::log_warn!("metrics endpoint: {e}"),
            }
        }
    }
    let mut cluster: Option<obs::ClusterStats> = None;
    let mut metrics_lines: Vec<String> = Vec::new();

    // Calibration scratch: per-step (bucket, msg words, comm secs)
    // observations, plus the predicted/measured/skew counter tracks the
    // Chrome trace gets one sample per `--obs-every` window.
    let track_comm = calibrator.is_some();
    let mut comm_obs: Vec<(usize, usize, f64)> = Vec::new();
    let mut counter_pred: Vec<(u64, f64)> = Vec::new();
    let mut counter_meas: Vec<(u64, f64)> = Vec::new();
    let mut counter_skew: Vec<(u64, f64)> = Vec::new();
    let (mut last_pred, mut last_meas) = (0.0f64, 0.0f64);

    let mut timer = crate::util::timer::PhaseTimer::new();
    let mut loss_curve = Vec::new();
    let mut eval_curve = Vec::new();
    let mut union_density = Vec::new();
    let mut sent_density = Vec::new();
    let mut final_loss = f32::NAN;

    // scratch for union-density measurement (largest layer)
    let max_layer = schema.params.iter().map(|p| p.size()).max().unwrap_or(0);
    let mut seen = vec![false; max_layer];

    for step in 0..cfg.steps {
        let epoch = step / cfg.steps_per_epoch;
        let density = warmup.density_at(epoch);
        let dense_step = cfg.strategy == Strategy::Dense || warmup.is_dense_at(epoch);
        let lr = cfg.lr.lr_at(step);
        let log_step = step % cfg.log_every == 0 || step + 1 == cfg.steps;

        let _step_span = ring.as_ref().map(|r| r.guard(obs::SPAN_STEP, step as u32, 0));
        let step_t0 = reg.is_some().then(Instant::now);

        let batch = data.batch(schema, rank, world, step);
        let (loss, mut grads) = obs::time_phase(
            ring.as_ref(),
            obs::SPAN_COMPUTE,
            step as u32,
            0,
            &mut timer,
            phase::COMPUTE,
            || runner.step(&rt, &params, &batch),
        )
        .map_err(|e| format!("rank {rank} step {step}: {e}"))?;

        // DGC local clipping (before residual accumulation)
        if let Some(max_norm) = cfg.clip {
            let limit =
                if dense_step { max_norm } else { local_clip_factor(max_norm, world) };
            let mut refs: Vec<&mut [f32]> = grads.iter_mut().map(|g| g.as_mut_slice()).collect();
            clip_by_global_norm(&mut refs, limit);
        }

        let mut selected_elems = 0usize;
        let mut sparse_elems = 0usize;
        let mut union_elems = 0usize;
        let scale = -lr / world as f32;

        // backprop order: last layer first, as the paper's overlap scheme
        // initiates communication for deeper layers first.  Dense layers
        // allreduce inline; compressed layers go through the sync engine
        // bucket by bucket.
        if dense_step {
            for li in (0..params.len()).rev() {
                obs::time_phase(
                    ring.as_ref(),
                    obs::SPAN_COMM_DENSE,
                    step as u32,
                    li as u32,
                    &mut timer,
                    phase::COMM_DENSE,
                    || allreduce_mean(&comm, &mut grads[li]),
                );
                timer.time(phase::UPDATE, || {
                    plans[li].dense_state.apply(cfg.optimizer, &mut params[li], &grads[li], lr)
                });
            }
        } else {
            for li in (0..params.len()).rev() {
                if plans[li].method != Method::Dense {
                    continue;
                }
                obs::time_phase(
                    ring.as_ref(),
                    obs::SPAN_COMM_DENSE,
                    step as u32,
                    li as u32,
                    &mut timer,
                    phase::COMM_DENSE,
                    || allreduce_mean(&comm, &mut grads[li]),
                );
                timer.time(phase::UPDATE, || {
                    plans[li].dense_state.apply(cfg.optimizer, &mut params[li], &grads[li], lr)
                });
            }

            // engine drives select/pack/allgather per bucket; this
            // closure is the deterministic apply point (§5.4
            // decompression), called in bucket order
            let mut unpack_secs = 0.0f64;
            {
                let params = &mut params;
                let seen = &mut seen;
                let ring = &ring;
                let comm_obs = &mut comm_obs;
                let mut apply = |done: BucketDone| -> Result<(), String> {
                    let _g = ring
                        .as_ref()
                        .map(|r| r.guard(obs::SPAN_UNPACK, step as u32, done.bucket as u32));
                    if track_comm {
                        comm_obs.push((done.bucket, done.msg_words, done.comm_secs));
                    }
                    let t0 = Instant::now();
                    done.apply_to(params, scale)?;
                    unpack_secs += t0.elapsed().as_secs_f64();
                    selected_elems += done.selected;
                    sparse_elems += done.elems;
                    // union-density measurement (log steps): distinct
                    // indices across all ranks / layer size — §5.3
                    if log_step {
                        union_elems += count_union_fused(&done.gathered, &done.layers, seen)?;
                    }
                    Ok(())
                };
                engine
                    .sync_step(&grads, density, &mut timer, &mut apply)
                    .map_err(|e| format!("rank {rank} step {step}: {e}"))?;
            }
            timer.add(phase::UNPACK, unpack_secs);
            if let Some(c) = calibrator.as_mut() {
                for &(b, words, secs) in &comm_obs {
                    c.observe_bucket(b, algos[b], words, secs);
                }
            }
            comm_obs.clear();
        }

        final_loss = loss;
        if log_step {
            // global mean loss (collective: all ranks participate)
            let mut l = [loss];
            allreduce_mean(&comm, &mut l);
            if rank == 0 {
                loss_curve.push((step, l[0]));
                if sparse_elems > 0 {
                    sent_density
                        .push((step, selected_elems as f64 / sparse_elems as f64));
                    union_density.push((step, union_elems as f64 / sparse_elems as f64));
                    if let Some(reg) = &reg {
                        reg.gauge("sent_density", selected_elems as f64 / sparse_elems as f64);
                        reg.gauge("union_density", union_elems as f64 / sparse_elems as f64);
                    }
                }
            }
        }

        if cfg.eval_every > 0 && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) && rank == 0
        {
            let metric = obs::time_phase(
                ring.as_ref(),
                obs::SPAN_EVAL,
                step as u32,
                0,
                &mut timer,
                phase::EVAL,
                || eval_metric(&rt, &runner, schema, &params, &data, world),
            )
            .map_err(|e| format!("rank {rank} eval: {e}"))?;
            eval_curve.push((step, metric));
        }

        if let (Some(reg), Some(t0)) = (&reg, step_t0) {
            reg.observe_us("step_latency_us", t0.elapsed().as_micros() as u64);
            reg.inc("steps_total", 1);
        }

        // cross-rank metric aggregation window: every rank's cumulative
        // step-latency histogram flows to rank 0 over the control
        // channel (deterministic schedule — config is identical on all
        // ranks, so no rank ever waits on a message that never comes)
        if cfg.obs_every > 0 && (step + 1) % cfg.obs_every == 0 {
            if let Some(reg) = &reg {
                let _g = ring.as_ref().map(|r| r.guard(obs::SPAN_GATHER, step as u32, 0));
                if let Some((stats, hists)) = gather_step_hist(rank, world, comm, reg)
                    .map_err(|e| format!("rank {rank} step {step}: {e}"))?
                {
                    crate::log_debug!(
                        "obs window @{step}: step p50 {}us p99 {}us skew {:.2}x",
                        stats.step_p50_us,
                        stats.step_p99_us,
                        stats.rank_skew
                    );
                    if let Some((slow, ratio)) =
                        obs::detect_straggler(&hists, STRAGGLER_RATIO)
                    {
                        crate::log_warn!(
                            "obs window @{step}: rank {slow} is straggling at {ratio:.2}x \
                             the fastest rank's mean step latency"
                        );
                        reg.gauge("straggler_rank", slow as f64);
                        reg.gauge("straggler_ratio", ratio);
                    }
                    if let Some(c) = &calibrator {
                        let s = c.summary();
                        reg.gauge("calib_alpha_us", s.alpha_us);
                        reg.gauge("calib_beta_gbps", s.beta_gbps);
                        reg.gauge("plan_predicted_seconds", s.predicted_secs);
                        reg.gauge("plan_measured_seconds", s.measured_secs);
                        if cfg.trace_out.is_some() {
                            let t = obs::now_us();
                            counter_pred.push((t, (s.predicted_secs - last_pred) * 1e6));
                            counter_meas.push((t, (s.measured_secs - last_meas) * 1e6));
                            last_pred = s.predicted_secs;
                            last_meas = s.measured_secs;
                        }
                    }
                    if cfg.trace_out.is_some() {
                        counter_skew.push((obs::now_us(), stats.rank_skew));
                    }
                    metrics_lines.push(reg.snapshot().to_json().to_json());
                    cluster = Some(stats);
                }
            }
        }

        // Recalibration barrier (`--recalib-every`): rank 0 re-runs the
        // picker on the calibrated machine and broadcasts the next plan
        // over the control channel; every rank applies it before the
        // next step's collectives.  Sparse and hierarchical gather
        // bit-identical blobs, so the switch cannot perturb training —
        // and the schedule is pure config, so no rank waits on a frame
        // that never comes.
        if cfg.recalib_every > 0
            && (step + 1) % cfg.recalib_every == 0
            && step + 1 < cfg.steps
            && !algos.is_empty()
        {
            if rank == 0 {
                let c = calibrator.as_mut().expect("rank 0 owns the calibrator under --recalib");
                let (next, switches) = c.replan(&bucket_costs, density, &algos);
                for peer in 1..world {
                    comm.send(peer, obs::encode_plan((step + 1) as u32, &next));
                }
                if switches > 0 {
                    let s = c.summary();
                    crate::log_info!(
                        "recalibration @{}: {switches} bucket switch(es) on measured link \
                         α {:.1}µs β {:.2} GB/s",
                        step + 1,
                        s.alpha_us,
                        s.beta_gbps
                    );
                    algos = next;
                    engine.set_algos(&algos);
                } else {
                    algos = next;
                }
            } else {
                let w = comm
                    .recv_checked(0)
                    .map_err(|e| format!("rank {rank} replan @{}: {e}", step + 1))?;
                let (echo, next) = obs::decode_plan(&w)
                    .map_err(|e| format!("rank {rank} replan @{}: {e}", step + 1))?;
                if echo as usize != step + 1 {
                    return Err(format!(
                        "rank {rank} replan: step echo {echo} != {}",
                        step + 1
                    ));
                }
                if next.iter().any(|&a| a == Algo::Dense) {
                    return Err(format!(
                        "rank {rank} replan @{}: plan demotes a live bucket to dense",
                        step + 1
                    ));
                }
                if next != algos {
                    algos = next;
                    engine.set_algos(&algos);
                }
            }
        }
    }

    // Mux channel accounting: under the pipelined engine all fabric
    // traffic is tag-multiplexed, and the per-tag counters split the
    // per-fabric totals into bucket streams vs the loop's control
    // collectives (sequential runs have no mux; both stay 0).
    let (mux_bytes, mux_ctrl_bytes) = match &mux_handle {
        Some(m) => {
            let (_msgs, words) = m.aggregate();
            (words * 4, m.tag_stats(CTRL_TAG).bytes())
        }
        None => (0, 0),
    };

    // End-of-run registry fill: the Fig. 10 phase seconds, the per-tag
    // traffic split, and one last aggregation window if the schedule
    // didn't land on the final step.
    if cfg.obs_every > 0 && cfg.steps % cfg.obs_every != 0 {
        if let Some(reg) = &reg {
            if let Some((stats, _)) = gather_step_hist(rank, world, comm, reg)
                .map_err(|e| format!("rank {rank}: {e}"))?
            {
                cluster = Some(stats);
            }
        }
    }
    if let Some(reg) = &reg {
        for &p in phase::ALL {
            let secs = timer.total(p);
            if secs > 0.0 {
                reg.gauge(&format!("phase_{p}_seconds"), secs);
            }
        }
        if let Some(m) = &mux_handle {
            for (tag, b) in m.per_tag_bytes().into_iter().enumerate() {
                if b > 0 {
                    reg.inc(&format!("mux_tag_{tag}_bytes"), b);
                }
            }
        }
        super::metrics::register_run_counters(
            reg,
            &transport.link_traffic(),
            &RejoinStats::default(),
            &RepoStats::default(),
        );
        if let Some(c) = &calibrator {
            let s = c.summary();
            if s.samples > 0 {
                reg.gauge("calib_alpha_us", s.alpha_us);
                reg.gauge("calib_beta_gbps", s.beta_gbps);
                reg.gauge("plan_predicted_seconds", s.predicted_secs);
                reg.gauge("plan_measured_seconds", s.measured_secs);
                reg.inc("calib_replans_total", s.replans);
                reg.inc("calib_switches_total", s.switches);
            }
        }
        if rank == 0 {
            metrics_lines.push(reg.snapshot().to_json().to_json());
            if let Some(stem) = &cfg.trace_out {
                let path = format!("{stem}.metrics.jsonl");
                let body = metrics_lines.join("\n") + "\n";
                if let Err(e) = std::fs::write(&path, body) {
                    crate::log_warn!("metrics flush {path}: {e}");
                }
            }
        }
    }
    drop(scraper);

    // Trace export: every rank drains its span rings (worker main lane,
    // engine comm lanes) and ships them to rank 0 over the control
    // channel; rank 0 merges all ranks into one Chrome-trace timeline.
    let mut span_drops = 0u64;
    if let Some(path) = &cfg.trace_out {
        let dumps = obs::drain_rank(rank);
        span_drops = dumps.iter().map(|l| l.dropped).sum();
        if rank != 0 {
            comm.send(0, obs::encode_dumps(rank as u32, &dumps));
        } else {
            let mut ranks = vec![obs::RankDump { rank: 0, lanes: dumps }];
            for peer in 1..world {
                let w = comm
                    .recv_checked(peer)
                    .map_err(|e| format!("trace gather: rank {peer}: {e}"))?;
                let (r, lanes) =
                    obs::decode_dumps(&w).map_err(|e| format!("trace gather: rank {peer}: {e}"))?;
                ranks.push(obs::RankDump { rank: r, lanes });
            }
            let mut counters: Vec<obs::CounterSeries> = Vec::new();
            for (name, points) in [
                ("plan_predicted_us", counter_pred),
                ("plan_measured_us", counter_meas),
                ("rank_skew", counter_skew),
            ] {
                if !points.is_empty() {
                    counters.push(obs::CounterSeries { name: name.into(), points });
                }
            }
            match obs::write_chrome_trace_with_counters(path, &ranks, &counters) {
                Ok(()) => crate::log_info!(
                    "wrote {} spans + {} counter tracks from {} ranks to {path}",
                    obs::span_count(&ranks),
                    counters.len(),
                    ranks.len()
                ),
                Err(e) => crate::log_warn!("{e}"),
            }
        }
        if span_drops > 0 {
            crate::log_warn!(
                "rank {rank}: {span_drops} spans dropped by full trace rings — the exported \
                 timeline is truncated"
            );
        }
    }

    let (step_p50_us, step_p99_us, rank_skew) = match cluster {
        Some(c) => (c.step_p50_us, c.step_p99_us, c.rank_skew),
        None => match (&reg, rank) {
            (Some(reg), 0) => {
                let h = reg.hist("step_latency_us").unwrap_or_default();
                (h.p50(), h.p99(), 0.0)
            }
            _ => (0, 0, 0.0),
        },
    };

    Ok(WorkerResult {
        rank,
        timer,
        loss_curve,
        eval_curve,
        union_density,
        sent_density,
        param_hash: param_hash(&params),
        final_loss,
        mux_bytes,
        mux_ctrl_bytes,
        membership: Vec::new(),
        step_p50_us,
        step_p99_us,
        rank_skew,
        simd_backend: crate::compression::simd::active().name(),
        link_traffic: transport.link_traffic(),
        rejoin: RejoinStats::default(),
        repo: RepoStats::default(),
        span_drops,
        calib: calibrator.as_ref().map(|c| c.summary()).unwrap_or_default(),
    })
}

/// One aggregation window: every rank sends its cumulative step-latency
/// histogram (fixed 133-word frame) to rank 0, which merges them into
/// cluster quantiles + straggler skew.  Returns `None` on ranks > 0.
fn gather_step_hist(
    rank: usize,
    world: usize,
    comm: &dyn Transport,
    reg: &obs::Registry,
) -> Result<Option<(obs::ClusterStats, Vec<(u32, obs::Hist)>)>, String> {
    let local = reg.hist("step_latency_us").unwrap_or_default();
    if rank != 0 {
        comm.send(0, local.encode(rank as u32));
        return Ok(None);
    }
    let mut hists = vec![(0u32, local)];
    for peer in 1..world {
        let w = comm
            .recv_checked(peer)
            .map_err(|e| format!("metrics gather: rank {peer}: {e}"))?;
        hists.push(obs::Hist::decode(&w).map_err(|e| format!("metrics gather: {e}"))?);
    }
    Ok(Some((obs::aggregate_step_hists(&hists), hists)))
}

// ---------------------------------------------------------------------
// Elastic glue: the real model behind the elastic driver's Workload
// (DESIGN.md §Elastic-Membership)
// ---------------------------------------------------------------------

/// The PJRT-backed model as an elastic [`Workload`]: shard selection is
/// keyed by the driver's group-local `(rank, world)` plus the view
/// epoch, so a reshaped run consumes exactly the batches a fresh
/// shrunken-world run would.
pub struct ModelWorkload<'a> {
    rt: Runtime,
    runner: StepRunner,
    schema: &'a ModelSchema,
    data: DataSource,
}

impl<'a> ModelWorkload<'a> {
    pub fn new(cfg: &TrainConfig, schema: &'a ModelSchema) -> Result<ModelWorkload<'a>, String> {
        let rt = Runtime::new().map_err(|e| format!("runtime: {e}"))?;
        let runner = StepRunner::new(&rt, schema).map_err(|e| format!("load: {e}"))?;
        let data = DataSource::for_model(schema, cfg.seed);
        Ok(ModelWorkload { rt, runner, schema, data })
    }
}

impl Workload for ModelWorkload<'_> {
    fn compute(
        &mut self,
        params: &[Vec<f32>],
        key: &ShardKey,
    ) -> Result<(f32, Vec<Vec<f32>>), String> {
        let batch =
            self.data.batch_salted(self.schema, key.rank, key.world, key.step, key.epoch);
        self.runner
            .step(&self.rt, params, &batch)
            .map_err(|e| format!("step {}: {e}", key.step))
    }
}

/// Per-layer specs for the elastic driver: the §5.5 policy over every
/// schema layer, dense layers included (the driver owns the dense path
/// too).
pub fn elastic_specs(cfg: &TrainConfig, schema: &ModelSchema) -> Vec<LayerSpec> {
    schema
        .params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let method = if cfg.strategy == Strategy::Dense {
                Method::Dense
            } else {
                Method::for_size(p.bytes(), cfg.thresholds)
            };
            let quantize = cfg.strategy == Strategy::QuantRgc
                && method != Method::Dense
                && !schema.is_output_param(i);
            LayerSpec { li: i, n: p.size(), method, quantize }
        })
        .collect()
}

/// Driver options from the run config.
pub fn elastic_opts(cfg: &TrainConfig) -> ElasticOpts {
    ElasticOpts {
        steps: cfg.steps,
        density: cfg.density,
        lr: cfg.lr.clone(),
        clip: cfg.clip,
        optimizer: cfg.optimizer,
        fusion_cap_elems: cfg.fusion_cap_elems,
        pipeline: cfg.pipeline,
        inflight: cfg.inflight,
        topology: cfg.topology,
        hierarchical: cfg.algo == AlgoMode::Hierarchical,
        log_every: cfg.log_every,
        heartbeat: Duration::from_millis(cfg.elastic.heartbeat_ms),
        min_ranks: cfg.elastic.min_ranks,
        kill: cfg.elastic.kill.clone(),
        stall: cfg.elastic.stall.clone(),
        rejoin: cfg.elastic.rejoin.clone(),
        ckpt_prefix: cfg.elastic.ckpt.clone(),
        ckpt_every: cfg.elastic.ckpt_every,
        ckpt_repo: cfg.elastic.ckpt_repo.clone(),
        rejoin_donors: cfg.elastic.rejoin_donors,
        cc: CompressorConfig {
            density: cfg.density,
            timing: cfg.phase_timing,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// A rank's starting state: its resume checkpoint when configured,
/// fresh seeded parameters otherwise.
pub fn elastic_init(
    cfg: &TrainConfig,
    schema: &ModelSchema,
    specs: &[LayerSpec],
    rank: usize,
) -> Result<Checkpoint, String> {
    if let Some(prefix) = &cfg.elastic.resume {
        let path = format!("{prefix}_rank{rank}.rsck");
        // CheckpointError already names the path and a remedy
        return Checkpoint::load(&path).map_err(|e| format!("--resume: {e}"));
    }
    Ok(elastic::fresh_checkpoint(
        schema.init_params(cfg.seed),
        specs,
        cfg.optimizer,
        cfg.seed,
    ))
}

/// Bridge a driver outcome into the run-report shape.
pub fn worker_result_from(rank: usize, o: &RankOutcome) -> WorkerResult {
    WorkerResult {
        rank,
        timer: o.timer.clone(),
        loss_curve: o.loss_curve.clone(),
        eval_curve: Vec::new(),
        union_density: Vec::new(),
        sent_density: Vec::new(),
        param_hash: o.param_hash,
        final_loss: o.final_loss,
        mux_bytes: o.mux_words * 4,
        mux_ctrl_bytes: o.ctrl_words * 4,
        membership: o.events.clone(),
        step_p50_us: 0,
        step_p99_us: 0,
        rank_skew: 0.0,
        simd_backend: crate::compression::simd::active().name(),
        link_traffic: Vec::new(),
        rejoin: o.rejoin,
        repo: o.repo,
        span_drops: 0,
        calib: Default::default(),
    }
}

/// Per-rank trace path of an elastic run: `{stem}_rank{r}{ext}`.
/// Membership can change mid-run, so a wire gather to rank 0 is unsafe
/// (rank 0 itself may be the one that died) — each survivor writes its
/// own timeline and Perfetto merges them.
pub fn rank_trace_path(out: &str, rank: usize) -> String {
    let name = out.rfind('/').map(|i| i + 1).unwrap_or(0);
    match out[name..].rfind('.') {
        Some(d) if d > 0 => {
            let dot = name + d;
            format!("{}_rank{rank}{}", &out[..dot], &out[dot..])
        }
        _ => format!("{out}_rank{rank}"),
    }
}

/// Run one elastic rank over an already-connected transport (the TCP
/// path; the in-process trainer goes through
/// [`crate::elastic::run_local_fleet`] instead, which also handles
/// rejoin generations).
pub fn run_worker_elastic<T: Transport + Sync>(
    cfg: &TrainConfig,
    schema: &ModelSchema,
    transport: &T,
) -> Result<(WorkerResult, RankOutcome), String> {
    let rank = transport.rank();
    if cfg.trace_out.is_some() {
        obs::set_enabled(true);
    }
    let specs = elastic_specs(cfg, schema);
    let init = elastic_init(cfg, schema, &specs, rank)?;
    let mut workload =
        ModelWorkload::new(cfg, schema).map_err(|e| format!("rank {rank}: {e}"))?;
    let opts = elastic_opts(cfg);
    let out = elastic::run_elastic_worker(transport, &specs, init, None, &opts, &mut workload)
        .map_err(|e| format!("rank {rank}: {e}"))?;
    if out.status == ElasticStatus::Killed {
        crate::log_warn!("rank {rank}: exited by injected kill");
    }
    if let Some(stem) = &cfg.trace_out {
        // engine rings register under the *group-local* rank (the view's
        // fabric), driver rings under the world rank; this process owns
        // both, so sweep every key in its registry
        let mut dumps = obs::drain_rank(rank);
        for r in 0..transport.world() {
            if r != rank {
                dumps.extend(obs::drain_rank(r));
            }
        }
        if !dumps.is_empty() {
            let path = rank_trace_path(stem, rank);
            let rd = obs::RankDump { rank: rank as u32, lanes: dumps };
            match obs::write_chrome_trace(&path, std::slice::from_ref(&rd)) {
                Ok(()) => crate::log_info!("rank {rank}: wrote trace to {path}"),
                Err(e) => crate::log_warn!("{e}"),
            }
        }
    }
    Ok((worker_result_from(rank, &out), out))
}

fn build_plans(cfg: &TrainConfig, schema: &ModelSchema) -> Vec<LayerPlan> {
    schema
        .params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let method = if cfg.strategy == Strategy::Dense {
                Method::Dense
            } else {
                Method::for_size(p.bytes(), cfg.thresholds)
            };
            let compressed = method != Method::Dense;
            let quantize = cfg.strategy == Strategy::QuantRgc
                && compressed
                && !schema.is_output_param(i);
            LayerPlan {
                method,
                quantize,
                dense_state: DenseOptState::new(p.size(), cfg.optimizer),
            }
        })
        .collect()
}

/// Count the distinct indices each layer of a fusion bucket received
/// across all ranks' blobs, using (and clearing) the `seen` scratch.
/// Messages are parsed in place ([`view_plain`]/[`view_quant`]) straight
/// out of the gather buffer — the walk copies nothing.
///
/// A malformed blob is an error: the old code skipped bad messages
/// *without* advancing that rank's cursor, silently desynchronizing
/// every later layer's walk (and the Eq. 1 density audit with it).  The
/// per-layer message headers are consumed exactly once per layer per
/// rank — the bucket's framing overhead is never counted as indices.
fn count_union_fused(
    gathered: &Gathered,
    layers: &[(usize, bool)],
    seen: &mut [bool],
) -> Result<usize, String> {
    let mut cursors = vec![0usize; gathered.n_ranks()];
    let mut total = 0usize;
    for &(li, quantized) in layers {
        let mut marked: Vec<u32> = Vec::new();
        for (r, blob) in gathered.blocks().enumerate() {
            let indices: &[u32] = if quantized {
                let (q, used) = view_quant(&blob[cursors[r]..])
                    .map_err(|e| format!("union count: rank {r} layer {li}: {e}"))?;
                cursors[r] += used;
                q.indices
            } else {
                let (s, used) = view_plain(&blob[cursors[r]..])
                    .map_err(|e| format!("union count: rank {r} layer {li}: {e}"))?;
                cursors[r] += used;
                s.indices
            };
            for &i in indices {
                if !seen[i as usize] {
                    seen[i as usize] = true;
                    marked.push(i);
                }
            }
        }
        total += marked.len();
        for i in marked {
            seen[i as usize] = false;
        }
    }
    Ok(total)
}

fn eval_metric(
    rt: &Runtime,
    runner: &StepRunner,
    schema: &ModelSchema,
    params: &[Vec<f32>],
    data: &DataSource,
    world: usize,
) -> crate::runtime::Result<f32> {
    match data {
        DataSource::Lm(corpus) => {
            let (tokens, targets) = corpus.batch(
                world + 1,
                EVAL_STEP,
                schema.cfg("batch").unwrap(),
                schema.cfg("seq").unwrap(),
            );
            runner.eval_lm(rt, params, &Batch::Lm { tokens, targets })
        }
        DataSource::Mlp(ds) => {
            // generalization accuracy on the held-out split
            let (xs, ys) = ds.eval_split();
            runner.eval_mlp_accuracy(rt, params, xs, ys)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::message::{pack_plain, pack_quant};
    use crate::compression::QuantizedSet;
    use crate::tensor::SparseTensor;

    /// Two ranks, a fused bucket of one plain + one quantized layer.
    fn gathered_pair() -> Vec<Vec<u32>> {
        let mk = |plain_idx: Vec<u32>, quant_idx: Vec<u32>| {
            let mut blob =
                pack_plain(&SparseTensor::new(plain_idx.clone(), vec![1.0; plain_idx.len()]));
            blob.extend(pack_quant(&QuantizedSet { indices: quant_idx, mean: 0.5 }));
            blob
        };
        vec![mk(vec![0, 2, 4], vec![1, 3]), mk(vec![2, 6], vec![3, 5, 7])]
    }

    #[test]
    fn rank_trace_paths_keep_the_extension() {
        assert_eq!(rank_trace_path("trace.json", 2), "trace_rank2.json");
        assert_eq!(rank_trace_path("out/run.trace.json", 0), "out/run.trace_rank0.json");
        assert_eq!(rank_trace_path("trace", 3), "trace_rank3");
    }

    #[test]
    fn union_counts_distinct_indices_per_layer() {
        let layers = vec![(0usize, false), (1usize, true)];
        let mut seen = vec![false; 16];
        let g = Gathered::from_parts(&gathered_pair());
        let n = count_union_fused(&g, &layers, &mut seen).unwrap();
        // plain layer: {0,2,4} ∪ {2,6} = 4; quant layer: {1,3} ∪ {3,5,7} = 4
        assert_eq!(n, 8);
        assert!(seen.iter().all(|&s| !s), "scratch must be cleared");
        // counting twice gives the same answer (scratch reuse)
        let n2 = count_union_fused(&g, &layers, &mut seen).unwrap();
        assert_eq!(n2, 8);
    }

    #[test]
    fn union_count_rejects_malformed_blobs() {
        let mut gathered = gathered_pair();
        // truncate rank 1 mid-bucket: the quantized layer's walk must
        // surface an error, not silently desync the cursor
        let cut = gathered[1].len() - 2;
        gathered[1].truncate(cut);
        let layers = vec![(0usize, false), (1usize, true)];
        let mut seen = vec![false; 16];
        let err =
            count_union_fused(&Gathered::from_parts(&gathered), &layers, &mut seen).unwrap_err();
        assert!(err.contains("rank 1"), "{err}");
    }

    #[test]
    fn union_count_headers_once_per_bucket_layer() {
        // single rank, two plain layers back to back: the second layer's
        // count must start exactly after the first message (header
        // consumed once), so index 9 is counted for layer 1 only
        let mut blob = pack_plain(&SparseTensor::new(vec![1, 9], vec![1.0, 2.0]));
        blob.extend(pack_plain(&SparseTensor::new(vec![9], vec![3.0])));
        let layers = vec![(0usize, false), (1usize, false)];
        let mut seen = vec![false; 16];
        let n = count_union_fused(&Gathered::from_parts(&[blob]), &layers, &mut seen).unwrap();
        assert_eq!(n, 3, "layer 0 has {{1, 9}}, layer 1 has {{9}}");
    }
}
