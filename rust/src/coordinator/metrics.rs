//! Run metrics: per-phase timing (the Fig. 10 decomposition), loss and
//! eval curves, traffic accounting and the final run report.

use crate::collectives::transport::LinkTraffic;
use crate::obs::calib::CalibSummary;
use crate::util::timer::PhaseTimer;

/// Phase names used by the workers (Fig. 10 vocabulary).
pub mod phase {
    /// Forward+backward device step.
    pub const COMPUTE: &str = "compute";
    /// Momentum correction + factor masking + residual accumulate.
    pub const MASK: &str = "mask";
    /// Communication-set selection.
    pub const SELECT: &str = "select";
    /// Message packing (§5.3).
    pub const PACK: &str = "pack";
    /// Sparse allgather.
    pub const COMM_SPARSE: &str = "comm_sparse";
    /// Dense allreduce (baseline + small layers + warm-up epochs).
    pub const COMM_DENSE: &str = "comm_dense";
    /// Decompress + apply gathered messages.
    pub const UNPACK: &str = "unpack";
    /// Weight update (dense path optimizer).
    pub const UPDATE: &str = "update";
    /// Held-out evaluation.
    pub const EVAL: &str = "eval";

    /// The Fig. 10 column order.
    pub const ALL: &[&str] =
        &[COMPUTE, MASK, SELECT, PACK, COMM_SPARSE, COMM_DENSE, UNPACK, UPDATE];
}

/// One membership change of an elastic run (DESIGN.md
/// §Elastic-Membership): which ranks left/returned, how long detection
/// and the reshape stall took, and where training resumed.
#[derive(Clone, Debug, PartialEq)]
pub struct MembershipEvent {
    /// The view epoch this event established.
    pub epoch: u64,
    /// World ranks confirmed lost by this reshape.
    pub lost: Vec<usize>,
    /// World ranks that rejoined at this barrier.
    pub joined: Vec<usize>,
    /// Seconds from the last completed step boundary to fault detection.
    pub detect_secs: f64,
    /// Seconds the reshape (agreement + rollback) stalled training.
    pub reshape_secs: f64,
    /// Step the new view resumed from.
    pub resume_step: usize,
    /// View size after the event.
    pub world_after: usize,
}

impl MembershipEvent {
    /// One summary line, e.g.
    /// `epoch 1: lost [2] -> 3 ranks, detect 12ms, reshape 3ms, resume @6`.
    pub fn describe(&self) -> String {
        let what = if !self.joined.is_empty() {
            format!("joined {:?}", self.joined)
        } else {
            format!("lost {:?}", self.lost)
        };
        format!(
            "epoch {}: {} -> {} ranks, detect {:.0}ms, reshape {:.0}ms, resume @{}",
            self.epoch,
            what,
            self.world_after,
            self.detect_secs * 1e3,
            self.reshape_secs * 1e3,
            self.resume_step
        )
    }
}

/// Traffic accounting of the delta-rejoin protocol (DESIGN.md
/// §Checkpoint-Repository): how many chunks the returning rank fetched
/// over the ctrl channel vs satisfied locally, how many survived digest
/// verification, and how the measured join traffic compares to what a
/// full-image stream would have cost.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RejoinStats {
    /// Chunks fetched from donors over the ctrl channel.
    pub fetched_chunks: u64,
    /// Chunks satisfied locally (stale checkpoint state or the local
    /// repository) — no traffic.
    pub reused_chunks: u64,
    /// Fetched chunks that passed digest verification on receipt (equals
    /// `fetched_chunks` on a clean transfer).
    pub verified_chunks: u64,
    /// Chunk re-requests after a digest mismatch or a lost donor.
    pub retries: u64,
    /// Donor failovers mid-transfer (a donor died or went suspect and
    /// its outstanding chunks were re-striped over the survivors).
    pub failovers: u64,
    /// f32 words the join actually moved on the ctrl channel (tag words
    /// included), sampled from the per-tag `TrafficStats`.
    pub join_words: u64,
    /// f32 words the legacy full-image stream would have moved for the
    /// same state.
    pub full_image_words: u64,
}

impl RejoinStats {
    /// Field-wise sum (fleet aggregation).
    pub fn absorb(&mut self, o: &RejoinStats) {
        self.fetched_chunks += o.fetched_chunks;
        self.reused_chunks += o.reused_chunks;
        self.verified_chunks += o.verified_chunks;
        self.retries += o.retries;
        self.failovers += o.failovers;
        self.join_words += o.join_words;
        self.full_image_words += o.full_image_words;
    }
}

/// Content-addressed checkpoint-repository accounting (DESIGN.md
/// §Checkpoint-Repository): chunk dedup and garbage collection across
/// the snapshot ring and across steps.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepoStats {
    /// Chunks written to the store (content previously unseen).
    pub chunks_written: u64,
    /// Chunks whose content was already present — refcounted, not
    /// rewritten.
    pub chunks_deduped: u64,
    /// Zero-refcount chunks unlinked by manifest eviction.
    pub chunks_collected: u64,
    /// Manifests persisted (one per checkpointed step).
    pub manifests_written: u64,
}

impl RepoStats {
    /// Field-wise sum (fleet aggregation).
    pub fn absorb(&mut self, o: &RepoStats) {
        self.chunks_written += o.chunks_written;
        self.chunks_deduped += o.chunks_deduped;
        self.chunks_collected += o.chunks_collected;
        self.manifests_written += o.manifests_written;
    }
}

/// What one worker hands back after its training loop.
#[derive(Debug)]
pub struct WorkerResult {
    pub rank: usize,
    pub timer: PhaseTimer,
    /// (step, global mean train loss) — populated on rank 0 only.
    pub loss_curve: Vec<(usize, f32)>,
    /// (step, eval metric) — rank 0 only. LM: held-out loss; MLP: accuracy.
    pub eval_curve: Vec<(usize, f32)>,
    /// (step, union density of the synchronized residual across ranks) —
    /// the paper's "1.55% from 0.1%·16 workers" §5.3 observation.
    pub union_density: Vec<(usize, f64)>,
    /// (step, mean per-rank selected density across compressed layers).
    pub sent_density: Vec<(usize, f64)>,
    /// FNV-1a hash over the final parameter bits (replica-consistency check).
    pub param_hash: u64,
    pub final_loss: f32,
    /// Bytes this worker pushed through its `TagMux` channels (bucket
    /// streams + control, tag words included) — 0 on the sequential
    /// engine, which does not multiplex.
    pub mux_bytes: u64,
    /// The control-channel (tag 0) share of `mux_bytes`: dense
    /// allreduces, loss averaging, replica-hash checks.
    pub mux_ctrl_bytes: u64,
    /// Membership changes this worker lived through (elastic runs;
    /// empty otherwise).
    pub membership: Vec<MembershipEvent>,
    /// Cluster step-latency quantiles (µs) from the cross-rank metric
    /// gather (rank 0 only; 0 when `--obs-every` is off).
    pub step_p50_us: u64,
    pub step_p99_us: u64,
    /// Straggler skew: slowest rank's mean step latency over the
    /// fastest's (1.0 = perfectly even, 0.0 = unmeasured).
    pub rank_skew: f64,
    /// The select/pack/apply kernel backend this worker dispatched to
    /// ("scalar" / "sse2" / "avx2"), picked once at plan time
    /// (DESIGN.md §SIMD-Kernels).
    pub simd_backend: &'static str,
    /// Per-link-class traffic of this worker's fabric endpoint (frames /
    /// bytes / write syscalls per class) — empty on in-process fabrics,
    /// whose links never touch the kernel.
    pub link_traffic: Vec<LinkTraffic>,
    /// Delta-rejoin traffic accounting (elastic runs with a rejoin;
    /// all-zero otherwise).
    pub rejoin: RejoinStats,
    /// Checkpoint-repository accounting (runs with `--ckpt-repo`;
    /// all-zero otherwise).
    pub repo: RepoStats,
    /// Spans dropped by full ring buffers across this worker's lanes —
    /// nonzero means the exported trace is truncated.
    pub span_drops: u64,
    /// Cost-model calibration + plan-audit summary (`--algo auto` with
    /// telemetry on; all-zero otherwise, rank 0 carries the fleet's).
    pub calib: CalibSummary,
}

/// Sum per-worker [`LinkTraffic`] vectors class-by-class, keeping the
/// `mem < unix < tcp` display order.
pub fn merge_link_traffic<I>(parts: I) -> Vec<LinkTraffic>
where
    I: IntoIterator<Item = Vec<LinkTraffic>>,
{
    let mut merged: Vec<LinkTraffic> = Vec::new();
    for part in parts {
        for lt in part {
            match merged.iter_mut().find(|m| m.class == lt.class) {
                Some(m) => {
                    m.frames += lt.frames;
                    m.bytes += lt.bytes;
                    m.writes += lt.writes;
                }
                None => merged.push(lt),
            }
        }
    }
    merged.sort_by_key(|m| m.class);
    merged
}

/// Register the run's fabric and durability counters into an
/// observability registry so the Prometheus scrape (`--metrics-addr`)
/// and the JSONL flush expose them next to the step metrics: per-link-
/// class traffic (`link_<class>_{frames,bytes,writes}_total`) plus the
/// delta-rejoin and checkpoint-repository totals when nonzero.
pub fn register_run_counters(
    reg: &crate::obs::Registry,
    links: &[LinkTraffic],
    rejoin: &RejoinStats,
    repo: &RepoStats,
) {
    for l in links {
        let label = l.class.label();
        reg.inc(&format!("link_{label}_frames_total"), l.frames);
        reg.inc(&format!("link_{label}_bytes_total"), l.bytes);
        if l.writes > 0 {
            reg.inc(&format!("link_{label}_writes_total"), l.writes);
        }
    }
    let rj: [(&str, u64); 5] = [
        ("rejoin_fetched_chunks_total", rejoin.fetched_chunks),
        ("rejoin_reused_chunks_total", rejoin.reused_chunks),
        ("rejoin_verified_chunks_total", rejoin.verified_chunks),
        ("rejoin_retries_total", rejoin.retries),
        ("rejoin_bytes_total", rejoin.join_words * 4),
    ];
    for (name, v) in rj {
        if v > 0 {
            reg.inc(name, v);
        }
    }
    let rp: [(&str, u64); 4] = [
        ("repo_chunks_written_total", repo.chunks_written),
        ("repo_chunks_deduped_total", repo.chunks_deduped),
        ("repo_chunks_collected_total", repo.chunks_collected),
        ("repo_manifests_total", repo.manifests_written),
    ];
    for (name, v) in rp {
        if v > 0 {
            reg.inc(name, v);
        }
    }
}

/// FNV-1a over f32 bit patterns.
pub fn param_hash(params: &[Vec<f32>]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for p in params {
        for &v in p {
            for b in v.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
    }
    h
}

/// Aggregated result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub model: String,
    pub world: usize,
    pub steps: usize,
    pub strategy: &'static str,
    /// (step, global mean train loss).
    pub loss_curve: Vec<(usize, f32)>,
    /// (step, eval metric).
    pub eval_curve: Vec<(usize, f32)>,
    pub union_density: Vec<(usize, f64)>,
    pub sent_density: Vec<(usize, f64)>,
    /// Per-phase seconds, merged over all workers.
    pub phases: PhaseTimer,
    /// Total fabric traffic (bytes / messages) over the whole run.
    pub bytes: u64,
    pub messages: u64,
    /// Multiplexed traffic summed over workers (0 without the pipelined
    /// engine): total through `TagMux` channels and the control-tag
    /// share of it, so the report can split bucket vs control streams.
    pub mux_bytes: u64,
    pub mux_ctrl_bytes: u64,
    /// Wall-clock of the whole run (leader side).
    pub wall_secs: f64,
    pub final_loss: f32,
    pub final_eval: Option<f32>,
    /// All ranks ended with bit-identical parameters.
    pub replicas_consistent: bool,
    /// Membership-event log of an elastic run: view epochs, lost/joined
    /// ranks, per-event detection and reshape stall times.
    pub membership: Vec<MembershipEvent>,
    /// Set when this rank did not run to completion but that is an
    /// *expected* elastic outcome (killed by injection, evicted from
    /// the view): the launcher treats such ranks as clean exits, and
    /// the summary says why instead of claiming replica consistency.
    pub status_note: Option<String>,
    /// Cluster step-latency quantiles in µs from the `--obs-every`
    /// cross-rank gather (0 when aggregation was off).
    pub step_p50_us: u64,
    pub step_p99_us: u64,
    /// Straggler skew: max/min of per-rank mean step latency
    /// (1.0 = even, 0.0 = unmeasured).
    pub rank_skew: f64,
    /// Hot-path kernel backend the workers ran ("scalar" / "sse2" /
    /// "avx2") — summary-only, deliberately NOT a CSV column.
    pub simd_backend: &'static str,
    /// Per-link-class fabric traffic summed over this process's workers
    /// (frames / bytes / write syscalls, DESIGN.md
    /// §Transport-Link-Classes).  Empty on in-process fabrics; like
    /// `simd_backend`, summary-only and deliberately NOT a CSV column.
    pub link_traffic: Vec<LinkTraffic>,
    /// Delta-rejoin accounting summed over the fleet (all-zero when no
    /// rank rejoined). Summary-only, deliberately NOT a CSV column.
    pub rejoin: RejoinStats,
    /// Checkpoint-repository accounting summed over the fleet (all-zero
    /// without `--ckpt-repo`). Summary-only, NOT a CSV column.
    pub repo: RepoStats,
    /// Spans dropped by full trace rings, summed over workers.  Nonzero
    /// means the Chrome trace is missing intervals — the summary warns.
    /// Summary-only, NOT a CSV column.
    pub span_drops: u64,
    /// Cost-model calibration + plan-audit summary (measured link α/β,
    /// replans/switches, predicted-vs-measured ledger).  Summary-only,
    /// NOT a CSV column.
    pub calib: CalibSummary,
}

impl TrainReport {
    /// Column names matching [`csv_row`](TrainReport::csv_row).
    pub const CSV_HEADER: &'static str = "model,world,strategy,steps,final_loss,bytes,messages,\
         wall_secs,mux_bytes,union_density,membership_events,step_p50_us,step_p99_us,rank_skew";

    /// The header line for CSV output (bench harnesses print it once
    /// before the first [`csv_row`](TrainReport::csv_row)).
    pub fn csv_header() -> &'static str {
        Self::CSV_HEADER
    }
    /// Mean traffic bytes per step per rank.
    pub fn bytes_per_step_per_rank(&self) -> f64 {
        self.bytes as f64 / (self.steps.max(1) * self.world) as f64
    }

    /// Fraction of merged phase time in `name` (Fig. 10 columns).
    pub fn phase_fraction(&self, name: &str) -> f64 {
        let total = self.phases.grand_total();
        if total <= 0.0 {
            return 0.0;
        }
        self.phases.total(name) / total
    }

    /// Render a compact human-readable summary.
    pub fn summary(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{} x{} [{}]: {} steps in {:.1}s wall",
            self.model, self.world, self.strategy, self.steps, self.wall_secs
        );
        let _ = writeln!(
            s,
            "  loss {:.4} -> {:.4}   eval {}",
            self.loss_curve.first().map(|&(_, l)| l).unwrap_or(f32::NAN),
            self.final_loss,
            self.final_eval.map(|e| format!("{e:.4}")).unwrap_or_else(|| "-".into()),
        );
        let _ = writeln!(
            s,
            "  traffic {} total, {:.1} KB/step/rank, {} msgs, replicas_consistent={}",
            crate::util::fmt_bytes(self.bytes as usize),
            self.bytes_per_step_per_rank() / 1024.0,
            self.messages,
            self.replicas_consistent
        );
        if !self.simd_backend.is_empty() {
            let _ = writeln!(s, "  hot-path kernels: {}", self.simd_backend);
        }
        if !self.link_traffic.is_empty() {
            let links: Vec<String> = self
                .link_traffic
                .iter()
                .map(|l| {
                    let mut part = format!(
                        "{} {} / {} frames",
                        l.class.label(),
                        crate::util::fmt_bytes(l.bytes as usize),
                        l.frames,
                    );
                    if l.writes > 0 {
                        part.push_str(&format!(
                            " / {} writes ({:.1} frames/write)",
                            l.writes,
                            l.frames_per_write()
                        ));
                    }
                    part
                })
                .collect();
            let _ = writeln!(s, "  fabric links: {}", links.join("  "));
        }
        let mut parts: Vec<String> = Vec::new();
        for &p in phase::ALL {
            let t = self.phases.total(p);
            if t > 0.0 {
                parts.push(format!("{p} {:.0}%", 100.0 * self.phase_fraction(p)));
            }
        }
        let _ = writeln!(s, "  phases: {}", parts.join("  "));
        if self.mux_bytes > 0 {
            let _ = writeln!(
                s,
                "  muxed streams: {} buckets + {} control",
                crate::util::fmt_bytes((self.mux_bytes - self.mux_ctrl_bytes) as usize),
                crate::util::fmt_bytes(self.mux_ctrl_bytes as usize),
            );
        }
        if let Some(&(_, d)) = self.union_density.last() {
            let _ = writeln!(s, "  union density of synced residual: {:.3}%", d * 100.0);
        }
        if self.step_p50_us > 0 {
            let _ = writeln!(
                s,
                "  cluster step latency: p50 {:.1}ms  p99 {:.1}ms  rank skew {:.2}x",
                self.step_p50_us as f64 / 1e3,
                self.step_p99_us as f64 / 1e3,
                self.rank_skew
            );
        }
        if !self.membership.is_empty() {
            let _ = writeln!(s, "  membership events:");
            for e in &self.membership {
                let _ = writeln!(s, "    {}", e.describe());
            }
        }
        if self.calib.samples > 0 {
            let _ = writeln!(
                s,
                "  calibration: {} obs, {} replans / {} switches, link α {:.1}µs β {:.2} GB/s",
                self.calib.samples,
                self.calib.replans,
                self.calib.switches,
                self.calib.alpha_us,
                self.calib.beta_gbps,
            );
            let _ = writeln!(
                s,
                "  plan audit: predicted {:.3}s vs measured {:.3}s comm ({:.2}x)",
                self.calib.predicted_secs,
                self.calib.measured_secs,
                self.calib.error_ratio(),
            );
        }
        if self.span_drops > 0 {
            let _ = writeln!(
                s,
                "  WARNING: {} spans dropped by full trace rings — the exported timeline is \
                 truncated (shorten the traced window or raise the ring capacity)",
                self.span_drops
            );
        }
        if self.rejoin.join_words > 0 {
            let _ = writeln!(
                s,
                "  rejoin: {} on the wire vs {} full-image ({} fetched / {} reused / {} \
                 verified chunks, {} retries, {} failovers)",
                crate::util::fmt_bytes(self.rejoin.join_words as usize * 4),
                crate::util::fmt_bytes(self.rejoin.full_image_words as usize * 4),
                self.rejoin.fetched_chunks,
                self.rejoin.reused_chunks,
                self.rejoin.verified_chunks,
                self.rejoin.retries,
                self.rejoin.failovers,
            );
        }
        if self.repo.manifests_written > 0 {
            let _ = writeln!(
                s,
                "  ckpt repo: {} manifests, {} chunks written / {} deduped / {} collected",
                self.repo.manifests_written,
                self.repo.chunks_written,
                self.repo.chunks_deduped,
                self.repo.chunks_collected,
            );
        }
        if let Some(note) = &self.status_note {
            let _ = writeln!(s, "  elastic status: {note}");
        }
        s
    }

    /// One-line CSV row (for the bench harnesses); columns are
    /// [`CSV_HEADER`](TrainReport::CSV_HEADER).
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{:.6},{},{},{:.3},{},{:.6},{},{},{},{:.4}",
            self.model,
            self.world,
            self.strategy,
            self.steps,
            self.final_loss,
            self.bytes,
            self.messages,
            self.wall_secs,
            self.mux_bytes,
            self.union_density.last().map(|&(_, d)| d).unwrap_or(0.0),
            self.membership.len(),
            self.step_p50_us,
            self.step_p99_us,
            self.rank_skew
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::LinkClass;

    #[test]
    fn param_hash_sensitive_and_stable() {
        let a = vec![vec![1.0f32, 2.0], vec![3.0]];
        let b = vec![vec![1.0f32, 2.0], vec![3.0]];
        let c = vec![vec![1.0f32, 2.0], vec![3.01]];
        assert_eq!(param_hash(&a), param_hash(&b));
        assert_ne!(param_hash(&a), param_hash(&c));
    }

    #[test]
    fn report_fractions() {
        let mut phases = PhaseTimer::new();
        phases.add(phase::COMPUTE, 3.0);
        phases.add(phase::COMM_SPARSE, 1.0);
        let r = TrainReport {
            model: "m".into(),
            world: 2,
            steps: 10,
            strategy: "RGC",
            loss_curve: vec![(0, 2.0)],
            eval_curve: vec![],
            union_density: vec![(9, 0.015)],
            sent_density: vec![],
            phases,
            bytes: 4096,
            messages: 10,
            mux_bytes: 3000,
            mux_ctrl_bytes: 1000,
            wall_secs: 1.0,
            final_loss: 1.0,
            final_eval: None,
            replicas_consistent: true,
            membership: vec![MembershipEvent {
                epoch: 1,
                lost: vec![2],
                joined: vec![],
                detect_secs: 0.012,
                reshape_secs: 0.003,
                resume_step: 6,
                world_after: 3,
            }],
            status_note: Some("evicted from the view at epoch 1".into()),
            step_p50_us: 1500,
            step_p99_us: 4000,
            rank_skew: 1.25,
            simd_backend: "avx2",
            link_traffic: vec![
                LinkTraffic { class: LinkClass::Mem, frames: 10, bytes: 400, writes: 0 },
                LinkTraffic { class: LinkClass::Unix, frames: 40, bytes: 1600, writes: 10 },
            ],
            rejoin: RejoinStats {
                fetched_chunks: 12,
                reused_chunks: 20,
                verified_chunks: 12,
                retries: 1,
                failovers: 1,
                join_words: 3300,
                full_image_words: 6606,
            },
            repo: RepoStats {
                chunks_written: 30,
                chunks_deduped: 18,
                chunks_collected: 6,
                manifests_written: 3,
            },
            span_drops: 7,
            calib: CalibSummary {
                samples: 60,
                replans: 2,
                switches: 1,
                alpha_us: 24.0,
                beta_gbps: 9.5,
                predicted_secs: 1.0,
                measured_secs: 1.2,
            },
        };
        assert!((r.phase_fraction(phase::COMPUTE) - 0.75).abs() < 1e-12);
        assert_eq!(r.bytes_per_step_per_rank(), 4096.0 / 20.0);
        let s = r.summary();
        assert!(s.contains("RGC") && s.contains("union density"));
        assert!(s.contains("muxed streams"), "{s}");
        assert!(s.contains("membership events"), "{s}");
        assert!(s.contains("lost [2] -> 3 ranks"), "{s}");
        assert!(s.contains("elastic status: evicted"), "{s}");
        assert!(s.contains("cluster step latency"), "{s}");
        assert!(s.contains("hot-path kernels: avx2"), "{s}");
        // per-class line: mem has no syscalls (no writes suffix), the
        // unix link shows the coalescing ratio
        assert!(s.contains("fabric links: mem"), "{s}");
        assert!(s.contains("unix") && s.contains("(4.0 frames/write)"), "{s}");
        // rejoin + repo accounting are summary-only lines, not CSV columns
        assert!(s.contains("12 fetched / 20 reused / 12 verified"), "{s}");
        assert!(s.contains("1 retries, 1 failovers"), "{s}");
        assert!(s.contains("ckpt repo: 3 manifests"), "{s}");
        assert!(s.contains("30 chunks written / 18 deduped / 6 collected"), "{s}");
        // calibration + plan audit are summary-only lines, not CSV columns
        assert!(s.contains("calibration: 60 obs, 2 replans / 1 switches"), "{s}");
        assert!(s.contains("plan audit: predicted 1.000s vs measured 1.200s comm (1.20x)"), "{s}");
        assert!(s.contains("WARNING: 7 spans dropped"), "{s}");
        // absorb sums field-wise
        let mut rj = r.rejoin;
        rj.absorb(&r.rejoin);
        assert_eq!(rj.fetched_chunks, 24);
        assert_eq!(rj.full_image_words, 13212);
        let mut rp = r.repo;
        rp.absorb(&r.repo);
        assert_eq!(rp.chunks_written, 60);
        // csv row tracks the header column-for-column
        let row = r.csv_row();
        assert_eq!(
            row.split(',').count(),
            TrainReport::csv_header().split(',').count(),
            "{row}"
        );
        assert!(row.ends_with(",1,1500,4000,1.2500"), "{row}");
    }

    #[test]
    fn run_counters_reach_the_prometheus_scrape() {
        let reg = crate::obs::Registry::new();
        register_run_counters(
            &reg,
            &[
                LinkTraffic { class: LinkClass::Mem, frames: 10, bytes: 400, writes: 0 },
                LinkTraffic { class: LinkClass::Unix, frames: 40, bytes: 1600, writes: 10 },
            ],
            &RejoinStats { fetched_chunks: 12, join_words: 3300, ..Default::default() },
            &RepoStats { manifests_written: 3, ..Default::default() },
        );
        let text = reg.snapshot().prometheus();
        assert!(text.contains("link_mem_bytes_total 400"), "{text}");
        assert!(text.contains("link_unix_frames_total 40"), "{text}");
        assert!(text.contains("link_unix_writes_total 10"), "{text}");
        // mem links never enter the kernel: no writes counter at all
        assert!(!text.contains("link_mem_writes_total"), "{text}");
        assert!(text.contains("rejoin_fetched_chunks_total 12"), "{text}");
        assert!(text.contains("rejoin_bytes_total 13200"), "{text}");
        assert!(text.contains("repo_manifests_total 3"), "{text}");
        // zero-valued durability counters stay out of the exposition
        assert!(!text.contains("rejoin_retries_total"), "{text}");
    }

    #[test]
    fn link_traffic_merges_by_class_in_display_order() {
        let a = vec![
            LinkTraffic { class: LinkClass::Tcp, frames: 5, bytes: 100, writes: 2 },
            LinkTraffic { class: LinkClass::Mem, frames: 1, bytes: 8, writes: 0 },
        ];
        let b = vec![
            LinkTraffic { class: LinkClass::Unix, frames: 3, bytes: 60, writes: 1 },
            LinkTraffic { class: LinkClass::Tcp, frames: 7, bytes: 140, writes: 3 },
        ];
        let m = merge_link_traffic([a, b]);
        assert_eq!(
            m,
            vec![
                LinkTraffic { class: LinkClass::Mem, frames: 1, bytes: 8, writes: 0 },
                LinkTraffic { class: LinkClass::Unix, frames: 3, bytes: 60, writes: 1 },
                LinkTraffic { class: LinkClass::Tcp, frames: 12, bytes: 240, writes: 5 },
            ]
        );
        assert!(merge_link_traffic(std::iter::empty::<Vec<LinkTraffic>>()).is_empty());
    }

    #[test]
    fn membership_event_describe_covers_joins() {
        let e = MembershipEvent {
            epoch: 2,
            lost: vec![],
            joined: vec![2],
            detect_secs: 0.0,
            reshape_secs: 0.0,
            resume_step: 12,
            world_after: 4,
        };
        let s = e.describe();
        assert!(s.contains("joined [2]") && s.contains("resume @12"), "{s}");
    }
}
